"""Build-time python package: JAX/Pallas author + AOT-compile path.

The accumulator contract is int64, so x64 mode must be on before any jax
import touches dtypes.
"""

import jax

jax.config.update("jax_enable_x64", True)
