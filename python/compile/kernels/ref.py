"""Pure-jnp correctness oracles for the online align-and-add kernels.

Terms are *(raw exponent, signed significand)* integer pairs in the same
fixed-point frame the Rust bit-accurate models use:

* ``e``  — raw biased exponent (``0`` encodes a zero term),
* ``m``  — the integer ``(-1)^s * 1.mant * 2^mbits`` (``0`` for zero terms),
* frame — a partial sum tagged with running max exponent ``lam`` holds the
  value ``acc * 2^(lam - bias - mbits - f)`` where ``f`` is the guard
  (fractional extension) width.

Shift amounts are clamped to 63 because the accumulator is an ``int64``:
an arithmetic shift by >= 63 already yields the sign fill, which is exactly
what a wider datapath would leave in the low 64 bits. The kernels model the
*truncated* hardware datapath (no sticky bit); the Rust side cross-checks
``(lam, acc)`` bit-exactly against its own truncated-mode models.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

MAX_SHIFT = 63  # plain int: a jnp scalar would be a captured constant in pallas


@dataclass(frozen=True)
class Frame:
    """Accumulator frame parameters for one FP format / term count."""

    ebits: int
    mbits: int
    f: int  # guard bits below the significand ("fractional extension")

    @property
    def bias(self) -> int:
        return (1 << (self.ebits - 1)) - 1

    @staticmethod
    def hw_default(ebits: int, mbits: int, n_terms: int) -> "Frame":
        """Mirror of Rust ``AccSpec::hw_default``: sig_bits + ceil(log2 N) + 3."""
        log_n = max(1, int(np.ceil(np.log2(max(n_terms, 2)))))
        return Frame(ebits, mbits, (mbits + 1) + log_n + 3)


def _shr(acc, d):
    """Arithmetic right shift with the int64 clamp described above."""
    return jnp.right_shift(acc, jnp.minimum(d.astype(jnp.int64), MAX_SHIFT))


def combine(lam1, acc1, lam2, acc2):
    """The paper's align-and-add operator (eq. 8) on int64 accumulators."""
    lam = jnp.maximum(lam1, lam2)
    acc = _shr(acc1, lam - lam1) + _shr(acc2, lam - lam2)
    return lam, acc


def leaf(e, m, frame: Frame):
    """Lift terms into the operator domain: ``[e; m << f]``."""
    return e.astype(jnp.int64), m.astype(jnp.int64) << frame.f


def baseline_ref(e, m, frame: Frame):
    """Algorithm 2 (the serial baseline): global max exponent, then align+add.

    ``e, m``: integer arrays with the term axis last. Returns ``(lam, acc)``
    with the term axis reduced.
    """
    lam_n, acc = leaf(e, m, frame)
    lam = jnp.max(lam_n, axis=-1)
    aligned = _shr(acc, lam[..., None] - lam_n)
    return lam, jnp.sum(aligned, axis=-1)


def online_ref(e, m, frame: Frame):
    """Algorithm 3 (the online serial recurrence, eq. 7), term by term."""
    lam_i, acc_i = leaf(e, m, frame)
    lam = jnp.zeros(e.shape[:-1], jnp.int64)
    acc = jnp.zeros(e.shape[:-1], jnp.int64)
    for i in range(e.shape[-1]):
        lam, acc = combine(lam, acc, lam_i[..., i], acc_i[..., i])
    return lam, acc


def tree_ref(e, m, frame: Frame):
    """Balanced radix-2 tree of eq. 8 operators (adjacent pairing), matching
    the Pallas kernel's reduction order bit-for-bit. Term count must be a
    power of two."""
    n = e.shape[-1]
    assert n & (n - 1) == 0, "tree_ref needs a power-of-two term count"
    lam, acc = leaf(e, m, frame)
    while n > 1:
        lam = lam.reshape(*lam.shape[:-1], n // 2, 2)
        acc = acc.reshape(*acc.shape[:-1], n // 2, 2)
        lam, acc = combine(lam[..., 0], acc[..., 0], lam[..., 1], acc[..., 1])
        n //= 2
    return lam[..., 0], acc[..., 0]


def state_to_float(lam, acc, frame: Frame):
    """Decode an ``(lam, acc)`` state to its real value (float64)."""
    scale = np.asarray(lam, np.float64) - frame.bias - frame.mbits - frame.f
    return np.asarray(acc, np.float64) * np.exp2(scale)


def decode_terms(e, m, frame: Frame):
    """Decode ``(e, m)`` term arrays to float64 values."""
    e = np.asarray(e, np.int64)
    m = np.asarray(m, np.int64)
    val = m.astype(np.float64) * np.exp2(e - frame.bias - frame.mbits)
    return np.where(e == 0, 0.0, val)


def encode_terms(x, frame: Frame):
    """Encode exactly-representable float values into ``(e, m)`` int32 pairs.

    Callers pass values already on the format grid (e.g. from ``quantize``);
    a value outside the normal range raises.
    """
    x = np.asarray(x, np.float64)
    e = np.zeros(x.shape, np.int32)
    m = np.zeros(x.shape, np.int32)
    nz = x != 0.0
    mant, ex = np.frexp(np.abs(x))  # mant in [0.5, 1)
    raw_e = (ex - 1 + frame.bias).astype(np.int64)
    sig = np.round(mant * (1 << (frame.mbits + 1))).astype(np.int64)
    # sig lands in [2^mbits, 2^(mbits+1)]; a carry bumps the exponent.
    carry = sig == (1 << (frame.mbits + 1))
    sig = np.where(carry, sig >> 1, sig)
    raw_e = np.where(carry, raw_e + 1, raw_e)
    if np.any(nz & ((raw_e < 1) | (raw_e > (1 << frame.ebits) - 1))):
        raise ValueError("value outside the format's normal range")
    e[nz] = raw_e[nz].astype(np.int32)
    m[nz] = np.where(np.signbit(x), -sig, sig)[nz].astype(np.int32)
    return e, m


def quantize(x, frame: Frame):
    """Round float64 values to the frame's (ebits, mbits) grid (RNE, FTZ on
    underflow, saturate-to-max-finite on overflow)."""
    x = np.asarray(x, np.float64)
    out = np.zeros_like(x)
    nz = x != 0.0
    if not np.any(nz):
        return out
    mant, ex = np.frexp(np.abs(x))
    sig = mant * (1 << (frame.mbits + 1))  # in [2^mbits, 2^(mbits+1))
    rounded = np.round(sig)  # numpy rounds half to even
    carry = rounded >= (1 << (frame.mbits + 1))
    rounded = np.where(carry, rounded / 2.0, rounded)
    ex = np.where(carry, ex + 1, ex)
    raw_e = ex - 1 + frame.bias
    val = rounded * np.exp2(ex - 1 - frame.mbits) * np.sign(x)
    # FTZ below the normal range, saturate above it.
    val = np.where(raw_e < 1, 0.0, val)
    max_val = (2.0 - np.exp2(-float(frame.mbits))) * np.exp2(
        (1 << frame.ebits) - 2 - frame.bias
    )
    val = np.clip(val, -max_val, max_val)
    out[nz] = val[nz]
    return out


# The two concrete frames baked into the AOT artifacts.
BF16_N32 = Frame.hw_default(ebits=8, mbits=7, n_terms=32)
FP32_N16 = Frame.hw_default(ebits=8, mbits=23, n_terms=16)
