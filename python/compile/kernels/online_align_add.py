"""L1 Pallas kernel: the paper's online align-and-add as a parallel reduction.

The hardware-adaptation insight (DESIGN.md §Hardware adaptation): because the
align-and-add operator (eq. 8) is associative, a vector unit can reduce N
floating-point terms with a *log-depth data-parallel tree* instead of the
serial max-then-align-then-add pass — the same move online-softmax makes for
attention. The kernel carries only the tiny ``(lam, acc)`` running state per
batch row, tiles the batch axis HBM->VMEM via BlockSpec, and combines terms
in a fully unrolled balanced tree inside VMEM.

Kernels are lowered with ``interpret=True``: real-TPU Pallas emits Mosaic
custom-calls the CPU PJRT plugin cannot execute; interpret mode lowers to
plain HLO so the Rust runtime can load and run the artifact anywhere, and the
TPU VMEM/MXU story is estimated analytically (EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import Frame, MAX_SHIFT


def _combine(lam1, acc1, lam2, acc2):
    """eq. 8 on int64 accumulators (shift clamp per ref.py contract)."""
    lam = jnp.maximum(lam1, lam2)
    d1 = jnp.minimum((lam - lam1).astype(jnp.int64), MAX_SHIFT)
    d2 = jnp.minimum((lam - lam2).astype(jnp.int64), MAX_SHIFT)
    return lam, jnp.right_shift(acc1, d1) + jnp.right_shift(acc2, d2)


def _online_reduce_kernel(e_ref, m_ref, lam_ref, acc_ref, *, f: int, n: int):
    """One batch tile: reduce the term axis with a balanced ⊙ tree.

    e_ref, m_ref: (TB, N) int32 — raw exponents / signed significands.
    lam_ref:      (TB,)  int32 — output max exponents.
    acc_ref:      (TB,)  int64 — output aligned sums in the ``f`` frame.
    """
    lam = e_ref[...].astype(jnp.int64)
    acc = m_ref[...].astype(jnp.int64) << f
    width = n
    while width > 1:
        half = width // 2
        lam = lam.reshape(lam.shape[0], half, 2)
        acc = acc.reshape(acc.shape[0], half, 2)
        lam, acc = _combine(lam[..., 0], acc[..., 0], lam[..., 1], acc[..., 1])
        width = half
    lam_ref[...] = lam[:, 0].astype(jnp.int32)
    acc_ref[...] = acc[:, 0]


@functools.partial(jax.jit, static_argnames=("frame", "tile"))
def online_reduce(e, m, *, frame: Frame, tile: int = 8):
    """Batched online align-and-add reduction.

    Args:
      e: (B, N) int32 raw exponents (0 = zero term). N must be a power of 2.
      m: (B, N) int32 signed significands.
      frame: accumulator frame (format + guard bits).
      tile: batch rows per VMEM block.

    Returns:
      (lam, acc): (B,) int32 max exponents and (B,) int64 aligned sums.
    """
    b, n = e.shape
    assert n & (n - 1) == 0, "term count must be a power of two"
    assert b % tile == 0, "batch must divide the tile size"
    kernel = functools.partial(_online_reduce_kernel, f=frame.f, n=n)
    return pl.pallas_call(
        kernel,
        grid=(b // tile,),
        in_specs=[
            pl.BlockSpec((tile, n), lambda i: (i, 0)),
            pl.BlockSpec((tile, n), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.int64),
        ],
        interpret=True,  # CPU-PJRT executable HLO; see module docstring
    )(e, m)


def _online_reduce_block_kernel(e_ref, m_ref, lam_ref, acc_ref, *, f: int):
    """One batch tile: single-λ blockwise reduction (the SoA-kernel lowering).

    The term axis reduces with one row-local max-exponent sweep, then every
    lane aligns against that single λ and the aligned lanes sum in one pass
    — the paper's baseline (Fig. 1) corner applied to the whole row. This is
    the exact semantics of the Rust native interpreter
    (``rust/src/runtime/reduce.rs``) and of the batched SoA kernel
    (``rust/src/arith/kernel.rs``): the ``online_reduce_*`` artifacts are
    exported from this kernel so both sides agree bit-for-bit in truncated
    frames too. Vector units prefer this form: max, shift and sum are all
    lane-parallel with no unrolled tree, and no power-of-two term count is
    required.
    """
    m = m_ref[...].astype(jnp.int64)
    # Dead lanes (m == 0) are identities *regardless of their exponent
    # field* — mask them to the identity level 0 before the max sweep,
    # exactly as the Rust SoA kernel does, so padded/stale exponents can
    # neither lift the row λ nor over-shift the live lanes.
    lam_n = jnp.where(m == 0, 0, e_ref[...].astype(jnp.int64))
    acc_n = m << f
    lam = jnp.max(lam_n, axis=-1)
    d = jnp.minimum(lam[..., None] - lam_n, MAX_SHIFT)
    lam_ref[...] = lam.astype(jnp.int32)
    acc_ref[...] = jnp.sum(jnp.right_shift(acc_n, d), axis=-1)


@functools.partial(jax.jit, static_argnames=("frame", "tile"))
def online_reduce_block(e, m, *, frame: Frame, tile: int = 8):
    """Batched blockwise (single-λ) align-and-add reduction.

    Same I/O contract as :func:`online_reduce`, but the row reduces against
    one row-local maximum exponent instead of a balanced ⊙ tree; in frames
    wide enough never to truncate the two are bit-identical (eq. 10), in
    truncated frames this one matches the Rust SoA kernel / native
    interpreter. ``N`` need not be a power of two.
    """
    b, n = e.shape
    assert b % tile == 0, "batch must be a multiple of the tile size"
    kernel = functools.partial(_online_reduce_block_kernel, f=frame.f)
    return pl.pallas_call(
        kernel,
        grid=(b // tile,),
        in_specs=[
            pl.BlockSpec((tile, n), lambda i: (i, 0)),
            pl.BlockSpec((tile, n), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.int64),
        ],
        interpret=True,  # CPU-PJRT executable HLO; see module docstring
    )(e, m)


def _dot_products_kernel(a_ref, b_ref, e_ref, m_ref, *, frame: Frame):
    """Quantize elementwise products of two operand tiles onto the frame's
    FP grid and emit (e, m) term pairs — the matmul-side producer feeding
    the multi-term adder (the paper's power-estimation workload shape).

    a_ref, b_ref: (TB, N) float32; outputs (TB, N) int32 pairs.
    """
    prod = a_ref[...] * b_ref[...]
    sign = jnp.signbit(prod)
    mag = jnp.abs(prod)
    nz = mag > 0.0
    safe = jnp.where(nz, mag, 1.0)
    # frexp-free decomposition: exponent from log2, significand by scaling.
    ex = jnp.floor(jnp.log2(safe)).astype(jnp.int32)
    sig = safe * jnp.exp2(-(ex.astype(jnp.float32)))  # in [1, 2)
    # Renormalize boundary cases from log2 rounding.
    hi = sig >= 2.0
    sig = jnp.where(hi, sig * 0.5, sig)
    ex = jnp.where(hi, ex + 1, ex)
    scaled = sig * (1 << frame.mbits)
    rounded = jnp.round(scaled).astype(jnp.int32)  # RNE
    carry = rounded >= (1 << (frame.mbits + 1))
    rounded = jnp.where(carry, rounded >> 1, rounded)
    ex = jnp.where(carry, ex + 1, ex)
    raw_e = ex + frame.bias
    max_e = (1 << frame.ebits) - 2
    # Saturate overflow, FTZ underflow, zero products.
    overflow = raw_e > max_e
    raw_e = jnp.clip(raw_e, 0, max_e)
    rounded = jnp.where(overflow, (1 << (frame.mbits + 1)) - 1, rounded)
    dead = (~nz) | (raw_e < 1)
    raw_e = jnp.where(dead, 0, raw_e)
    rounded = jnp.where(dead, 0, rounded)
    e_ref[...] = raw_e
    m_ref[...] = jnp.where(sign, -rounded, rounded)


@functools.partial(jax.jit, static_argnames=("frame", "tile"))
def quantized_products(a, b, *, frame: Frame, tile: int = 8):
    """Pallas producer kernel: (B, N) float32 operand pairs -> (e, m) terms."""
    bsz, n = a.shape
    assert bsz % tile == 0
    kernel = functools.partial(_dot_products_kernel, frame=frame)
    return pl.pallas_call(
        kernel,
        grid=(bsz // tile,),
        in_specs=[
            pl.BlockSpec((tile, n), lambda i: (i, 0)),
            pl.BlockSpec((tile, n), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile, n), lambda i: (i, 0)),
            pl.BlockSpec((tile, n), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, n), jnp.int32),
            jax.ShapeDtypeStruct((bsz, n), jnp.int32),
        ],
        interpret=True,
    )(a, b)


def online_dot(a, b, *, frame: Frame, tile: int = 8):
    """End-to-end L1 pipeline: products -> (e, m) -> online ⊙ reduction.

    The fused multi-term dot product the paper's intro motivates: alignment
    of the N addends happens online inside the reduction, never against a
    pre-computed global max exponent.
    """
    e, m = quantized_products(a, b, frame=frame, tile=tile)
    return online_reduce(e, m, frame=frame, tile=tile)


def _online_reduce_tiled_kernel(e_ref, m_ref, lam_ref, acc_ref, *, f: int, tile_n: int):
    """Grid-carried online accumulation: the term axis is tiled HBM->VMEM
    and the kernel carries only the tiny ``(lam, acc)`` running state across
    grid steps — the paper's online recurrence (Algorithm 3) lifted to
    tile granularity, exactly like online-softmax in flash-attention.

    Grid: (terms // tile_n,). Outputs are accumulated in place.
    """
    step = pl.program_id(0)

    # Reduce this tile with the balanced ⊙ tree.
    lam = e_ref[...].astype(jnp.int64)
    acc = m_ref[...].astype(jnp.int64) << f
    width = tile_n
    while width > 1:
        half = width // 2
        lam = lam.reshape(lam.shape[0], half, 2)
        acc = acc.reshape(acc.shape[0], half, 2)
        lam, acc = _combine(lam[..., 0], acc[..., 0], lam[..., 1], acc[..., 1])
        width = half
    tile_lam = lam[:, 0]
    tile_acc = acc[:, 0]

    # ⊙-combine with the carried state (identity at step 0).
    prev_lam = jnp.where(step == 0, jnp.zeros_like(tile_lam), lam_ref[...].astype(jnp.int64))
    prev_acc = jnp.where(step == 0, jnp.zeros_like(tile_acc), acc_ref[...])
    new_lam, new_acc = _combine(prev_lam, prev_acc, tile_lam, tile_acc)
    lam_ref[...] = new_lam.astype(jnp.int32)
    acc_ref[...] = new_acc


@functools.partial(jax.jit, static_argnames=("frame", "tile_n", "tile_b"))
def online_reduce_tiled(e, m, *, frame: Frame, tile_n: int = 8, tile_b: int = 8):
    """Online reduction over a term axis longer than one VMEM tile.

    Args:
      e, m: (B, N) int32 with N a multiple of ``tile_n`` (a power of two).

    Returns the same ``(lam, acc)`` as :func:`online_reduce`; the reduction
    order is tile-major (tile trees combined left-to-right), which matches
    the Rust ``RadixConfig`` ``[2]*log2(tile_n) + [N/tile_n]``... not quite:
    the carried state folds serially, i.e. config ``tile tree`` then a
    serial ⊙ chain — associativity (eq. 10) makes the float value identical
    and tests pin the exact bit pattern against a numpy mirror.
    """
    b, n = e.shape
    assert tile_n & (tile_n - 1) == 0 and n % tile_n == 0
    assert b % tile_b == 0
    kernel = functools.partial(_online_reduce_tiled_kernel, f=frame.f, tile_n=tile_n)
    return pl.pallas_call(
        kernel,
        grid=(n // tile_n,),
        in_specs=[
            pl.BlockSpec((b, tile_n), lambda i: (0, i)),
            pl.BlockSpec((b, tile_n), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((b,), lambda i: (0,)),
            pl.BlockSpec((b,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.int64),
        ],
        interpret=True,
    )(e, m)
