"""L2 JAX model: the paper's power-estimation workload.

The paper estimates adder power by "employing multi-term adders in matrix
multiplication kernels for the BERT Transformer using input data from the
GLUE dataset" (§IV). This module provides:

* :func:`bert_layer` — a single BERT-style encoder layer whose matmul
  operands are exposed so the Rust side can reconstruct every N-term
  dot-product the multi-term adders would see;
* :func:`online_reduce_graph` / :func:`online_dot_graph` — the L1 Pallas
  kernels wrapped for AOT export.

Everything here runs at *build* time only: ``aot.py`` lowers these functions
to HLO text once, and the Rust runtime executes the artifacts via PJRT.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.online_align_add import online_dot, online_reduce_block
from .kernels.ref import Frame


def bert_layer(x, wq, wk, wv, wo, w1, w2):
    """One BERT-style encoder layer (pre-LN omitted for clarity).

    Args:
      x:  (S, D) token activations.
      wq, wk, wv, wo: (D, D) attention projections.
      w1: (D, F), w2: (F, D) feed-forward weights.

    Returns a tuple of every matmul *operand* pair's left/right matrices plus
    the layer output, so the trace extractor can rebuild all dot products:
    (q, k, v, attn, ctx, h, g, out).
    """
    d = x.shape[-1]
    q = x @ wq
    k = x @ wk
    v = x @ wv
    scores = (q @ k.T) / jnp.sqrt(jnp.float32(d))
    attn = jax.nn.softmax(scores, axis=-1)
    ctx = attn @ v
    h = ctx @ wo + x
    g = jax.nn.gelu(h @ w1)
    out = g @ w2 + h
    return q, k, v, attn, ctx, h, g, out


def bert_layer_shapes(seq: int = 128, d: int = 256, ff: int = 1024):
    """ShapeDtypeStructs for :func:`bert_layer` AOT lowering."""
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((seq, d), f32),  # x
        jax.ShapeDtypeStruct((d, d), f32),  # wq
        jax.ShapeDtypeStruct((d, d), f32),  # wk
        jax.ShapeDtypeStruct((d, d), f32),  # wv
        jax.ShapeDtypeStruct((d, d), f32),  # wo
        jax.ShapeDtypeStruct((d, ff), f32),  # w1
        jax.ShapeDtypeStruct((ff, d), f32),  # w2
    )


def online_reduce_graph(frame: Frame, batch: int, n_terms: int):
    """(fn, example_args) computing the batched blockwise (single-λ) ⊙
    reduction — the semantics the Rust native interpreter executes for the
    ``online_reduce_*`` artifacts (see ``rust/src/runtime/reduce.rs``)."""

    def fn(e, m):
        lam, acc = online_reduce_block(e, m, frame=frame)
        return lam, acc

    args = (
        jax.ShapeDtypeStruct((batch, n_terms), jnp.int32),
        jax.ShapeDtypeStruct((batch, n_terms), jnp.int32),
    )
    return fn, args


def online_dot_graph(frame: Frame, batch: int, n_terms: int):
    """(fn, example_args) for the fused products -> ⊙ reduction pipeline."""

    def fn(a, b):
        lam, acc = online_dot(a, b, frame=frame)
        return lam, acc

    args = (
        jax.ShapeDtypeStruct((batch, n_terms), jnp.float32),
        jax.ShapeDtypeStruct((batch, n_terms), jnp.float32),
    )
    return fn, args
