"""AOT lowering: JAX/Pallas -> HLO *text* artifacts for the Rust runtime.

HLO text (NOT ``lowered.compile()`` / serialized protos) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the
``xla`` crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the
text parser on the Rust side reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Usage:  python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from . import model
from .kernels.ref import BF16_N32, FP32_N16

# Batch size baked into the reduction artifacts; the Rust coordinator pads
# the final partial batch with zero terms (identity leaves).
REDUCE_BATCH = 64


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_fn(fn, example_args) -> str:
    return to_hlo_text(jax.jit(fn).lower(*example_args))


def artifacts() -> dict[str, tuple]:
    """name -> (fn, example_args) for every exported graph."""
    reduce_bf16 = model.online_reduce_graph(BF16_N32, REDUCE_BATCH, 32)
    reduce_fp32 = model.online_reduce_graph(FP32_N16, REDUCE_BATCH, 16)
    dot_bf16 = model.online_dot_graph(BF16_N32, REDUCE_BATCH, 32)
    return {
        "bert_layer": (model.bert_layer, model.bert_layer_shapes()),
        "online_reduce_bf16_n32": reduce_bf16,
        "online_reduce_fp32_n16": reduce_fp32,
        "online_dot_bf16_n32": dot_bf16,
    }


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument("--only", help="emit a single artifact by name")
    args = parser.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    for name, (fn, example_args) in artifacts().items():
        if args.only and name != args.only:
            continue
        text = lower_fn(fn, example_args)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as fh:
            fh.write(text)
        print(f"wrote {len(text):>9} chars  {path}")


if __name__ == "__main__":
    main()
