"""AOT pipeline checks: every exported graph lowers to parseable HLO text."""

import jax
import numpy as np

from compile import aot, model
from compile.kernels.ref import BF16_N32


def test_every_artifact_lowers_to_hlo_text():
    for name, (fn, args) in aot.artifacts().items():
        text = aot.lower_fn(fn, args)
        assert text.startswith("HloModule"), f"{name}: not HLO text"
        assert "ROOT" in text, f"{name}: no root instruction"


def test_hlo_text_has_expected_reduce_signature():
    fn, args = aot.artifacts()["online_reduce_bf16_n32"]
    text = aot.lower_fn(fn, args)
    # 64x32 int32 inputs and a tuple of (s32[64], s64[64]) outputs.
    assert "s32[64,32]" in text
    assert "s64[64]" in text


def test_lowered_reduce_executes_like_eager():
    # The lowered+compiled artifact must agree with eager execution — the
    # same check the Rust runtime integration test performs via PJRT.
    fn, _ = model.online_reduce_graph(BF16_N32, 8, 32)
    rng = np.random.default_rng(5)
    e = rng.integers(1, 254, size=(8, 32)).astype(np.int32)
    m = rng.integers(128, 256, size=(8, 32)).astype(np.int32)
    eager = fn(e, m)
    compiled = jax.jit(fn).lower(e, m).compile()(e, m)
    np.testing.assert_array_equal(np.asarray(eager[0]), np.asarray(compiled[0]))
    np.testing.assert_array_equal(np.asarray(eager[1]), np.asarray(compiled[1]))
