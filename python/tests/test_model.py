"""L2 model checks: shapes, determinism, numerical sanity of the BERT layer
and of the exported reduction graphs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels.ref import BF16_N32, Frame


def small_inputs(seq=16, d=32, ff=64, seed=0):
    rng = np.random.default_rng(seed)
    mk = lambda *s: jnp.asarray(rng.normal(size=s, scale=0.1), jnp.float32)
    return (
        mk(seq, d),
        mk(d, d),
        mk(d, d),
        mk(d, d),
        mk(d, d),
        mk(d, ff),
        mk(ff, d),
    )


def test_bert_layer_shapes_and_finiteness():
    args = small_inputs()
    q, k, v, attn, ctx, h, g, out = model.bert_layer(*args)
    seq, d = args[0].shape
    ff = args[5].shape[1]
    assert q.shape == (seq, d) and k.shape == (seq, d) and v.shape == (seq, d)
    assert attn.shape == (seq, seq)
    assert ctx.shape == (seq, d) and h.shape == (seq, d) and out.shape == (seq, d)
    assert g.shape == (seq, ff)
    for t in (q, k, v, attn, ctx, h, g, out):
        assert np.all(np.isfinite(np.asarray(t)))
    # softmax rows sum to one
    np.testing.assert_allclose(np.asarray(attn).sum(axis=-1), 1.0, rtol=1e-5)


def test_bert_layer_deterministic():
    args = small_inputs(seed=1)
    out1 = model.bert_layer(*args)[-1]
    out2 = model.bert_layer(*args)[-1]
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


def test_residual_paths_present():
    # Zero weights: attention/FFN collapse, output must equal the residual x.
    seq, d, ff = 8, 16, 32
    x = jnp.asarray(np.random.default_rng(2).normal(size=(seq, d)), jnp.float32)
    zero_d = jnp.zeros((d, d), jnp.float32)
    out = model.bert_layer(
        x, zero_d, zero_d, zero_d, zero_d, jnp.zeros((d, ff), jnp.float32),
        jnp.zeros((ff, d), jnp.float32),
    )[-1]
    np.testing.assert_allclose(np.asarray(out), np.asarray(x), atol=1e-6)


@pytest.mark.parametrize("batch,n", [(8, 32), (16, 16)])
def test_reduce_graph_lowering_roundtrip(batch, n):
    frame = Frame(8, 7, 16)
    fn, args = model.online_reduce_graph(frame, batch, n)
    lowered = jax.jit(fn).lower(*args)
    text = lowered.as_text()
    assert "stablehlo" in text or "module" in text


def test_graph_executes_on_cpu():
    fn, _ = model.online_reduce_graph(BF16_N32, 8, 32)
    e = np.zeros((8, 32), np.int32)
    m = np.zeros((8, 32), np.int32)
    e[:, 0] = 100
    m[:, 0] = 1 << 7
    lam, acc = fn(e, m)
    assert np.all(np.asarray(lam) == 100)
    assert np.all(np.asarray(acc) == (1 << 7) << BF16_N32.f)
