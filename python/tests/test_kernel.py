"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

This is the core python-side correctness signal: the Pallas online
reduction must match the balanced-tree oracle *bit-exactly*, and both must
match the float sum within the truncated datapath's error bound. Hypothesis
sweeps shapes, formats and operand distributions.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.online_align_add import (
    online_dot,
    online_reduce,
    online_reduce_block,
    quantized_products,
)
from compile.kernels.ref import Frame

FRAMES = {
    "bf16": Frame(8, 7, 16),
    "fp32": Frame(8, 23, 32),
    "e4m3": Frame(4, 3, 9),
    "e5m2": Frame(5, 2, 8),
    "e6m1": Frame(6, 1, 8),
}


def random_terms(rng, frame, shape, p_zero=0.1):
    """Random (e, m) pairs across the full normal exponent range."""
    e = rng.integers(1, (1 << frame.ebits) - 1, size=shape).astype(np.int32)
    mant = rng.integers(0, 1 << frame.mbits, size=shape)
    sign = rng.integers(0, 2, size=shape)
    m = ((1 << frame.mbits) | mant).astype(np.int32)
    m = np.where(sign == 1, -m, m).astype(np.int32)
    zero = rng.random(size=shape) < p_zero
    e = np.where(zero, 0, e).astype(np.int32)
    m = np.where(zero, 0, m).astype(np.int32)
    return e, m


@pytest.mark.parametrize("fmt", list(FRAMES))
@pytest.mark.parametrize("n", [2, 8, 32])
def test_kernel_matches_tree_oracle_bitexact(fmt, n):
    frame = FRAMES[fmt]
    rng = np.random.default_rng(42)
    e, m = random_terms(rng, frame, (16, n))
    lam_k, acc_k = online_reduce(e, m, frame=frame, tile=8)
    lam_r, acc_r = ref.tree_ref(e, m, frame)
    np.testing.assert_array_equal(np.asarray(lam_k), np.asarray(lam_r, np.int32))
    np.testing.assert_array_equal(np.asarray(acc_k), np.asarray(acc_r))


@pytest.mark.parametrize("fmt", list(FRAMES))
@pytest.mark.parametrize("n", [2, 8, 24, 32])
def test_block_kernel_matches_baseline_oracle_bitexact(fmt, n):
    # The blockwise (single-λ) kernel is the artifact-export semantics the
    # Rust native interpreter and SoA kernel reproduce; it must bit-match
    # the pure-jnp baseline oracle, including non-power-of-two term counts.
    frame = FRAMES[fmt]
    rng = np.random.default_rng(1042)
    e, m = random_terms(rng, frame, (16, n))
    lam_k, acc_k = online_reduce_block(e, m, frame=frame, tile=8)
    lam_r, acc_r = ref.baseline_ref(e, m, frame)
    np.testing.assert_array_equal(np.asarray(lam_k), np.asarray(lam_r, np.int32))
    np.testing.assert_array_equal(np.asarray(acc_k), np.asarray(acc_r))
    # Dead lanes are identities regardless of their exponent field (the
    # Rust-side padding convention): stale high exponents on m == 0 lanes
    # must change nothing.
    e_stale = np.where(m == 0, (1 << frame.ebits) - 2, e).astype(np.int32)
    lam_s, acc_s = online_reduce_block(e_stale, m, frame=frame, tile=8)
    np.testing.assert_array_equal(np.asarray(lam_k), np.asarray(lam_s))
    np.testing.assert_array_equal(np.asarray(acc_k), np.asarray(acc_s))


@pytest.mark.parametrize("fmt", ["bf16", "e5m2"])
def test_online_serial_equals_baseline(fmt):
    # Algorithm 3 == Algorithm 2 on the paper's recurrence (eq. 4 -> eq. 7).
    # With a wide-enough frame nothing truncates, so they agree bit-exactly.
    frame = FRAMES[fmt]
    wide = Frame(frame.ebits, frame.mbits, 40)  # no truncation possible? no:
    # e range can exceed 40 for bf16 — restrict exponent spread instead.
    rng = np.random.default_rng(7)
    e, m = random_terms(rng, frame, (32, 16))
    e = np.where(e > 0, (e - 1) % 24 + 1, 0).astype(np.int32)  # spread <= 23 < 40-mbits
    lam_b, acc_b = ref.baseline_ref(e, m, wide)
    lam_o, acc_o = ref.online_ref(e, m, wide)
    np.testing.assert_array_equal(np.asarray(lam_b), np.asarray(lam_o))
    np.testing.assert_array_equal(np.asarray(acc_b), np.asarray(acc_o))


@pytest.mark.parametrize("fmt", list(FRAMES))
def test_reduction_float_value_within_truncation_bound(fmt):
    frame = FRAMES[fmt]
    rng = np.random.default_rng(3)
    # Keep exponent spread inside the guard so truncation error is bounded
    # by N ULPs of the accumulator LSB.
    e, m = random_terms(rng, frame, (16, 32))
    lo = max(1, (1 << frame.ebits) - 2 - min(frame.f - 2, (1 << frame.ebits) - 3))
    e = np.where(e > 0, np.clip(e, lo, (1 << frame.ebits) - 2), 0).astype(np.int32)
    lam, acc = online_reduce(e, m, frame=frame, tile=8)
    got = ref.state_to_float(lam, acc, frame)
    want = ref.decode_terms(e, m, frame).sum(axis=-1)
    lam_f = np.asarray(lam, np.float64)
    # Absolute bound: each of the 32 combines drops < 1 LSB of the acc frame.
    bound = 64.0 * np.exp2(lam_f - frame.bias - frame.mbits - frame.f)
    assert np.all(np.abs(got - want) <= bound)


def test_all_zero_terms_reduce_to_identity():
    frame = FRAMES["bf16"]
    e = np.zeros((8, 32), np.int32)
    m = np.zeros((8, 32), np.int32)
    lam, acc = online_reduce(e, m, frame=frame, tile=8)
    assert np.all(np.asarray(lam) == 0)
    assert np.all(np.asarray(acc) == 0)


def test_single_live_term_passes_through():
    frame = FRAMES["bf16"]
    e = np.zeros((8, 32), np.int32)
    m = np.zeros((8, 32), np.int32)
    e[:, 5] = 130
    m[:, 5] = -(1 << 7 | 3)
    lam, acc = online_reduce(e, m, frame=frame, tile=8)
    assert np.all(np.asarray(lam) == 130)
    assert np.all(np.asarray(acc) == (-(1 << 7 | 3)) << frame.f)


@settings(max_examples=25, deadline=None)
@given(
    fmt=st.sampled_from(list(FRAMES)),
    log_n=st.integers(1, 6),
    batch=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**31 - 1),
    p_zero=st.floats(0.0, 0.9),
)
def test_hypothesis_kernel_vs_oracle(fmt, log_n, batch, seed, p_zero):
    """Hypothesis sweep: shapes x formats x sparsity, bit-exact vs oracle."""
    frame = FRAMES[fmt]
    n = 1 << log_n
    rng = np.random.default_rng(seed)
    e, m = random_terms(rng, frame, (batch, n), p_zero=p_zero)
    lam_k, acc_k = online_reduce(e, m, frame=frame, tile=8)
    lam_r, acc_r = ref.tree_ref(e, m, frame)
    np.testing.assert_array_equal(np.asarray(lam_k), np.asarray(lam_r, np.int32))
    np.testing.assert_array_equal(np.asarray(acc_k), np.asarray(acc_r))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_hypothesis_operator_associativity(seed):
    """eq. 10: random re-parenthesisations agree when nothing truncates."""
    frame = Frame(8, 7, 16)
    rng = np.random.default_rng(seed)
    e, m = random_terms(rng, frame, (4, 8))
    # Clamp exponent spread below the guard so ⊙ is exactly associative.
    live = e > 0
    base = rng.integers(1, 200)
    e = np.where(live, base + (e % 8), 0).astype(np.int32)
    lam_t, acc_t = ref.tree_ref(e, m, frame)
    lam_s, acc_s = ref.online_ref(e, m, frame)
    np.testing.assert_array_equal(np.asarray(lam_t), np.asarray(lam_s))
    np.testing.assert_array_equal(np.asarray(acc_t), np.asarray(acc_s))


def test_quantized_products_match_numpy_quantizer():
    frame = FRAMES["bf16"]
    rng = np.random.default_rng(11)
    a = rng.normal(size=(16, 32)).astype(np.float32)
    b = rng.normal(size=(16, 32)).astype(np.float32)
    e, m = quantized_products(a, b, frame=frame, tile=8)
    got = ref.decode_terms(e, m, frame)
    want = ref.quantize((a.astype(np.float64) * b.astype(np.float64)), frame)
    np.testing.assert_allclose(got, want, rtol=0, atol=0)


def test_online_dot_approximates_float_dot():
    frame = FRAMES["bf16"]
    rng = np.random.default_rng(13)
    a = rng.normal(size=(16, 32)).astype(np.float32)
    b = rng.normal(size=(16, 32)).astype(np.float32)
    lam, acc = online_dot(a, b, frame=frame, tile=8)
    got = ref.state_to_float(lam, acc, frame)
    want = (a.astype(np.float64) * b.astype(np.float64)).sum(axis=-1)
    # bf16 products + truncated accumulation: loose relative tolerance.
    np.testing.assert_allclose(got, want, rtol=0.05, atol=0.05)


def tiled_ref(e, m, frame, tile_n):
    """Numpy mirror of online_reduce_tiled's reduction order: per-tile
    balanced tree, then a serial ⊙ fold of tile states."""
    b, n = e.shape
    lam = np.zeros(b, np.int64)
    acc = np.zeros(b, np.int64)
    for t in range(n // tile_n):
        sl = slice(t * tile_n, (t + 1) * tile_n)
        tl, ta = ref.tree_ref(e[:, sl], m[:, sl], frame)
        lam_new = np.maximum(lam, np.asarray(tl))
        d1 = np.minimum(lam_new - lam, 63)
        d2 = np.minimum(lam_new - np.asarray(tl), 63)
        acc = (acc >> d1) + (np.asarray(ta) >> d2)
        lam = lam_new
    return lam, acc


@pytest.mark.parametrize("fmt", ["bf16", "e5m2"])
@pytest.mark.parametrize("tile_n", [4, 8])
def test_tiled_reduction_matches_numpy_mirror(fmt, tile_n):
    from compile.kernels.online_align_add import online_reduce_tiled

    frame = FRAMES[fmt]
    rng = np.random.default_rng(17)
    e, m = random_terms(rng, frame, (8, 32))
    lam_k, acc_k = online_reduce_tiled(e, m, frame=frame, tile_n=tile_n)
    lam_r, acc_r = tiled_ref(e, m, frame, tile_n)
    np.testing.assert_array_equal(np.asarray(lam_k), lam_r.astype(np.int32))
    np.testing.assert_array_equal(np.asarray(acc_k), acc_r)


def test_tiled_and_flat_reductions_agree_on_float_value():
    # Different ⊙ orders truncate differently at the LSB but decode to the
    # same value within the truncation bound (associativity, eq. 10).
    from compile.kernels.online_align_add import online_reduce, online_reduce_tiled

    frame = FRAMES["bf16"]
    rng = np.random.default_rng(23)
    e, m = random_terms(rng, frame, (8, 32))
    lam_a, acc_a = online_reduce(e, m, frame=frame, tile=8)
    lam_b, acc_b = online_reduce_tiled(e, m, frame=frame, tile_n=8)
    va = ref.state_to_float(lam_a, acc_a, frame)
    vb = ref.state_to_float(lam_b, acc_b, frame)
    lam_f = np.asarray(lam_a, np.float64)
    bound = 64.0 * np.exp2(lam_f - frame.bias - frame.mbits - frame.f)
    assert np.all(np.abs(va - vb) <= bound)
