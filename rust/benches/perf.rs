//! Bench: hot-path microbenchmarks (DESIGN.md §Perf).
//!
//! * bit-accurate `⊙` tree evaluation throughput (terms/s),
//! * the online serial recurrence and the baseline,
//! * switching-activity power simulation throughput (term-events/s),
//! * dynamic-batcher round-trip under concurrency,
//! * PJRT artifact execution latency (when artifacts are present).
//!
//! Besides the human-readable report, results land in `BENCH_perf.json`
//! (via `bench_util::write_json`) so the perf trajectory is tracked
//! machine-readably from PR to PR. `BENCH_SMOKE=1` shrinks the targets for
//! the CI smoke step.
//!
//! Run: `cargo bench --bench perf`

use online_fp_add::arith::adder::{Architecture, MultiTermAdder};
use online_fp_add::arith::tree::RadixConfig;
use online_fp_add::arith::AccSpec;
use online_fp_add::reduce::{registry, ReducePlan};
use online_fp_add::bench_util::{
    bench, black_box, header, suite_label, target_seconds, write_json, BenchRecord,
};
use online_fp_add::coordinator::batcher::{Batcher, BatcherConfig};
use online_fp_add::formats::{Fp, BF16, FP32};
use online_fp_add::hw::datapath::DatapathParams;
use online_fp_add::hw::power::ActivitySim;
use online_fp_add::runtime::{OnlineReduceExe, Runtime};
use online_fp_add::util::prng::XorShift;
use std::path::Path;

fn trace(n: usize, vectors: usize, seed: u64) -> Vec<Vec<Fp>> {
    let mut rng = XorShift::new(seed);
    (0..vectors).map(|_| (0..n).map(|_| rng.gen_fp_sparse(BF16, 0.1)).collect()).collect()
}

fn main() {
    let mut records: Vec<BenchRecord> = Vec::new();

    header("arithmetic hot paths (bit-accurate, 32-term BF16)");
    let vecs = trace(32, 256, 1);
    let spec = AccSpec::hw_default(BF16, 32);
    let cfg: RadixConfig = "8-2-2".parse().unwrap();
    let r = bench("tree_sum 8-2-2 (256 vecs)", target_seconds(1.0), || {
        for v in &vecs {
            black_box(online_fp_add::arith::tree::tree_sum(v, &cfg, spec));
        }
    });
    println!("{}   [{:.1} M terms/s]", r.line(), r.throughput(256.0 * 32.0) / 1e6);
    records.push(BenchRecord::new(r.clone()).param("terms_per_s", r.throughput(256.0 * 32.0)));
    let r = bench("baseline_sum (256 vecs)", target_seconds(1.0), || {
        for v in &vecs {
            black_box(online_fp_add::arith::baseline::baseline_sum(v, spec));
        }
    });
    println!("{}   [{:.1} M terms/s]", r.line(), r.throughput(256.0 * 32.0) / 1e6);
    records.push(BenchRecord::new(r.clone()).param("terms_per_s", r.throughput(256.0 * 32.0)));
    let r = bench("online_sum (256 vecs)", target_seconds(1.0), || {
        for v in &vecs {
            black_box(online_fp_add::arith::online::online_sum(v, spec));
        }
    });
    println!("{}   [{:.1} M terms/s]", r.line(), r.throughput(256.0 * 32.0) / 1e6);
    records.push(BenchRecord::new(r.clone()).param("terms_per_s", r.throughput(256.0 * 32.0)));

    header("SoA kernel vs scalar ⊙ fold (hot reduction path, exact specs)");
    println!("simd dispatch: {}", online_fp_add::arith::simd::active_paths());
    // The acceptance series: one record per (backend, format, block size),
    // names carrying the `reduce scalar` / `reduce kernel` series labels CI
    // asserts on. 1024-term chunks, full-operand-space terms (maximal
    // alignment distances — the scalar fold's worst, and most honest, case).
    let n_reduce = 1024usize;
    for (fmt, fname) in [(FP32, "FP32"), (BF16, "BF16")] {
        let spec = AccSpec::exact(fmt);
        let terms: Vec<Fp> = {
            let mut rng = XorShift::new(0x5EDC ^ fmt.ebits as u64 ^ ((fmt.mbits as u64) << 8));
            (0..n_reduce).map(|_| rng.gen_fp_full(fmt)).collect()
        };
        let scalar_plan = ReducePlan::with_backend(spec, registry::sel("scalar").unwrap());
        let scalar = bench(
            &format!("reduce scalar {fname} n={n_reduce}"),
            target_seconds(0.6),
            || {
                black_box(online_fp_add::stream::reduce_chunk_with(&scalar_plan, &terms));
            },
        );
        let scalar_tput = scalar.throughput(n_reduce as f64);
        println!("{}   [{:.1} M terms/s]", scalar.line(), scalar_tput / 1e6);
        records.push(
            BenchRecord::new(scalar.clone())
                .param("n", n_reduce as f64)
                .param("terms_per_s", scalar_tput),
        );
        for block in [8usize, 64, 256] {
            let plan = ReducePlan::with_backend(
                spec,
                registry::sel("kernel").unwrap().with_block(block).unwrap(),
            );
            let r = bench(
                &format!("reduce kernel {fname} n={n_reduce} b={block}"),
                target_seconds(0.6),
                || {
                    black_box(online_fp_add::stream::reduce_chunk_with(&plan, &terms));
                },
            );
            let tput = r.throughput(n_reduce as f64);
            println!(
                "{}   [{:.1} M terms/s, {:.2}x scalar]",
                r.line(),
                tput / 1e6,
                tput / scalar_tput
            );
            records.push(
                BenchRecord::new(r)
                    .param("n", n_reduce as f64)
                    .param("block", block as f64)
                    .param("terms_per_s", tput)
                    .param("speedup_vs_scalar", tput / scalar_tput),
            );
        }
        // The vectorized kernel: same blocks as the scalar kernel so the
        // two series read side by side; speedup_vs_scalar is the
        // acceptance param the issue gates on.
        for block in [8usize, 64, 256] {
            let plan = ReducePlan::with_backend(
                spec,
                registry::sel("simd").unwrap().with_block(block).unwrap(),
            );
            let r = bench(
                &format!("reduce simd {fname} n={n_reduce} b={block}"),
                target_seconds(0.6),
                || {
                    black_box(online_fp_add::stream::reduce_chunk_with(&plan, &terms));
                },
            );
            let tput = r.throughput(n_reduce as f64);
            println!(
                "{}   [{:.1} M terms/s, {:.2}x scalar]",
                r.line(),
                tput / 1e6,
                tput / scalar_tput
            );
            records.push(
                BenchRecord::new(r)
                    .param("n", n_reduce as f64)
                    .param("block", block as f64)
                    .param("terms_per_s", tput)
                    .param("speedup_vs_scalar", tput / scalar_tput),
            );
        }
        // The deferred-alignment backend: shift-free banking + one drain.
        let eia_plan = ReducePlan::with_backend(spec, registry::sel("eia").unwrap());
        let r = bench(
            &format!("reduce eia {fname} n={n_reduce}"),
            target_seconds(0.6),
            || {
                black_box(online_fp_add::stream::reduce_chunk_with(&eia_plan, &terms));
            },
        );
        let tput = r.throughput(n_reduce as f64);
        println!(
            "{}   [{:.1} M terms/s, {:.2}x scalar]",
            r.line(),
            tput / 1e6,
            tput / scalar_tput
        );
        records.push(
            BenchRecord::new(r)
                .param("n", n_reduce as f64)
                .param("terms_per_s", tput)
                .param("speedup_vs_scalar", tput / scalar_tput),
        );
    }

    header("reduce dispatch: trait-object Reducer vs direct plan path (BF16, exact)");
    // The API-redesign guardrail series: dispatching through a boxed
    // `dyn Reducer` (reset + ingest + finish per reduction) must add no
    // measurable overhead over the direct fn-pointer path the old enum
    // match compiled to. CI asserts the series exists; the ratio param
    // tracks the trajectory.
    {
        let spec = AccSpec::exact(BF16);
        let terms: Vec<Fp> = {
            let mut rng = XorShift::new(0xD15B);
            (0..1024).map(|_| rng.gen_fp_full(BF16)).collect()
        };
        let plan = ReducePlan::negotiate(spec);
        let direct = bench("reduce dispatch direct BF16 n=1024", target_seconds(0.6), || {
            black_box(plan.reduce(&terms));
        });
        let direct_tput = direct.throughput(1024.0);
        println!("{}   [{:.1} M terms/s]", direct.line(), direct_tput / 1e6);
        records.push(BenchRecord::new(direct.clone()).param("terms_per_s", direct_tput));
        let mut reducer = plan.reducer();
        let traitobj = bench("reduce dispatch trait BF16 n=1024", target_seconds(0.6), || {
            black_box(online_fp_add::reduce::backend::reduce_once(&mut *reducer, &terms));
        });
        let trait_tput = traitobj.throughput(1024.0);
        let overhead = direct_tput / trait_tput.max(1e-9);
        println!(
            "{}   [{:.1} M terms/s, {:.3}x direct time]",
            traitobj.line(),
            trait_tput / 1e6,
            overhead
        );
        if overhead > 1.10 {
            println!("WARN: trait-object dispatch measured >10% slower than the direct path");
        }
        records.push(
            BenchRecord::new(traitobj)
                .param("terms_per_s", trait_tput)
                .param("overhead_vs_direct", overhead),
        );
    }

    header("telemetry overhead (instrumented reduce hot path, BF16, exact)");
    // The observability guardrail series: the cross-tier counters threaded
    // through the reduce/kernel hot paths (DESIGN.md §Observability) must
    // stay within a few percent of the disabled hub — and so must the
    // second-generation layer: the lock-free trace ring recording reduce
    // lifecycle events, and span allocation + ambient-span threading on
    // top of it. Legs are interleaved and the best of three runs kept per
    // leg, so a one-off scheduler hiccup in any leg cannot fake (or mask)
    // a regression; CI gates every `overhead_vs_off` param at 1.03.
    {
        use online_fp_add::telemetry::{self, span, SpanContext};
        let spec = AccSpec::exact(BF16);
        let terms: Vec<Fp> = {
            let mut rng = XorShift::new(0x7E1E);
            (0..1024).map(|_| rng.gen_fp_full(BF16)).collect()
        };
        let plan = ReducePlan::negotiate(spec);
        let mut off_best: Option<online_fp_add::bench_util::BenchResult> = None;
        let mut on_best: Option<online_fp_add::bench_util::BenchResult> = None;
        let mut trace_best: Option<online_fp_add::bench_util::BenchResult> = None;
        let mut span_best: Option<online_fp_add::bench_util::BenchResult> = None;
        let keep = |best: &mut Option<online_fp_add::bench_util::BenchResult>,
                    r: online_fp_add::bench_util::BenchResult| {
            if best.as_ref().map(|b| r.median_s < b.median_s).unwrap_or(true) {
                *best = Some(r);
            }
        };
        for _ in 0..3 {
            telemetry::global().set_enabled(false);
            let off = bench("telemetry overhead off BF16 n=1024", target_seconds(0.3), || {
                black_box(plan.reduce(&terms));
            });
            telemetry::global().set_enabled(true);
            let on = bench("telemetry overhead on BF16 n=1024", target_seconds(0.3), || {
                black_box(plan.reduce(&terms));
            });
            telemetry::global().trace.set_enabled(true);
            let tr = bench("telemetry overhead trace on BF16 n=1024", target_seconds(0.3), || {
                black_box(plan.reduce(&terms));
            });
            let sp = bench("telemetry overhead spans on BF16 n=1024", target_seconds(0.3), || {
                // The serving tier's per-batch pattern: allocate a child
                // span, enter it, reduce under the ambient span.
                let _g = span::enter(SpanContext::for_stream("bench").child());
                black_box(plan.reduce(&terms));
            });
            telemetry::global().trace.set_enabled(false);
            keep(&mut off_best, off);
            keep(&mut on_best, on);
            keep(&mut trace_best, tr);
            keep(&mut span_best, sp);
        }
        let off = off_best.expect("three runs");
        let off_tput = off.throughput(1024.0);
        println!("{}   [{:.1} M terms/s]", off.line(), off_tput / 1e6);
        records.push(BenchRecord::new(off).param("terms_per_s", off_tput));
        for (leg, what) in [
            (on_best.expect("three runs"), "telemetry counters"),
            (trace_best.expect("three runs"), "trace-ring records"),
            (span_best.expect("three runs"), "span threading"),
        ] {
            let tput = leg.throughput(1024.0);
            let overhead = off_tput / tput.max(1e-9);
            println!(
                "{}   [{:.1} M terms/s, {:.3}x off time]",
                leg.line(),
                tput / 1e6,
                overhead
            );
            if overhead > 1.03 {
                println!("WARN: {what} measured >3% slower than the disabled hub");
            }
            records.push(
                BenchRecord::new(leg)
                    .param("terms_per_s", tput)
                    .param("overhead_vs_off", overhead),
            );
        }
    }

    header("fused matmul workload (round-once dot products, BF16 16x64x16)");
    {
        use online_fp_add::workload::matmul::matmul_fused;
        let (mm, mk, mn) = (16usize, 64usize, 16usize);
        let mut rng = XorShift::new(0xFA57);
        let a: Vec<f32> = (0..mm * mk).map(|_| rng.gauss() as f32).collect();
        let b: Vec<f32> = (0..mk * mn).map(|_| rng.gauss() as f32).collect();
        let mspec = AccSpec::exact(BF16);
        // One matmul series per registered backend — a new registry entry
        // lands in the perf trajectory automatically.
        for entry in registry::entries() {
            let plan = ReducePlan::with_backend(mspec, entry.sel());
            let r = bench(
                &format!("matmul_fused {} 16x64x16", entry.name),
                target_seconds(0.5),
                || {
                    black_box(matmul_fused(&a, &b, (mm, mk, mn), BF16, &plan));
                },
            );
            let tput = r.throughput((mm * mn * mk) as f64);
            println!("{}   [{:.1} M dot-terms/s]", r.line(), tput / 1e6);
            records.push(BenchRecord::new(r).param("dot_terms_per_s", tput));
        }
    }

    header("full fused adders (incl. normalize/round)");
    let adder = MultiTermAdder::hw(FP32, 32, Architecture::Tree("8-2-2".parse().unwrap()));
    let mut rng = XorShift::new(2);
    let fp32vecs: Vec<Vec<Fp>> =
        (0..256).map(|_| (0..32).map(|_| rng.gen_fp_gauss(FP32, 4.0)).collect()).collect();
    let r = bench("MultiTermAdder FP32 8-2-2 (256 adds)", target_seconds(1.0), || {
        for v in &fp32vecs {
            black_box(adder.add(v));
        }
    });
    println!("{}   [{:.2} M adds/s]", r.line(), r.throughput(256.0) / 1e6);
    records.push(BenchRecord::new(r.clone()).param("adds_per_s", r.throughput(256.0)));

    header("differential oracle (reference sum + round, 16-term FP32)");
    let oracle_vecs: Vec<Vec<Fp>> = {
        let mut rng = XorShift::new(4);
        (0..256).map(|_| (0..16).map(|_| rng.gen_fp_full(FP32)).collect()).collect()
    };
    let r = bench("oracle reference_sum (256 vecs)", target_seconds(0.5), || {
        for v in &oracle_vecs {
            black_box(online_fp_add::arith::oracle::reference_sum(v, FP32));
        }
    });
    println!("{}   [{:.1} M terms/s]", r.line(), r.throughput(256.0 * 16.0) / 1e6);
    records.push(BenchRecord::new(r.clone()).param("terms_per_s", r.throughput(256.0 * 16.0)));

    header("switching-activity power simulation (32-term BF16)");
    let params = DatapathParams::new(BF16, 32, spec);
    for cfgs in ["32", "8-2-2"] {
        let c: RadixConfig = cfgs.parse().unwrap();
        let mut sim = ActivitySim::new(params, &c);
        let r = bench(&format!("ActivitySim {cfgs} (256 vecs)"), target_seconds(1.0), || {
            for v in &vecs {
                sim.step(v);
            }
        });
        println!(
            "{}   [{:.1} M term-events/s]",
            r.line(),
            r.throughput(256.0 * 32.0) / 1e6
        );
        records.push(
            BenchRecord::new(r.clone()).param("term_events_per_s", r.throughput(256.0 * 32.0)),
        );
    }

    header("dynamic batcher (checksum executor, 16 client threads)");
    let batcher = Batcher::spawn(
        BatcherConfig { n_terms: 32, linger: std::time::Duration::from_micros(100), ..Default::default() },
        |rows: &[(Vec<i32>, Vec<i32>)]| {
            rows.iter()
                .map(|(e, m)| (*e.iter().max().unwrap(), m.iter().map(|&x| x as i64).sum()))
                .collect::<Vec<(i32, i64)>>()
        },
    );
    let handle = batcher.handle();
    let r = bench("batched reduce round-trip x512", target_seconds(2.0), || {
        let threads: Vec<_> = (0..16)
            .map(|t| {
                let h = handle.clone();
                std::thread::spawn(move || {
                    for i in 0..32 {
                        let e = vec![(t * 32 + i) as i32 + 1; 32];
                        let m = vec![1i32; 32];
                        h.reduce(e, m).unwrap();
                    }
                })
            })
            .collect();
        for th in threads {
            th.join().unwrap();
        }
    });
    println!("{}   [{:.0} k req/s]", r.line(), r.throughput(512.0) / 1e3);
    println!("batcher metrics: mean fill {:.1}", batcher.metrics().mean_batch_fill());
    records.push(BenchRecord::new(r.clone()).param("req_per_s", r.throughput(512.0)));

    header("PJRT artifact execution (needs `make artifacts`)");
    let dir = Runtime::default_artifact_dir();
    if dir.join("online_reduce_bf16_n32.hlo.txt").exists() {
        let rt = Runtime::new(dir).expect("PJRT client");
        let exe = OnlineReduceExe::load_bf16_n32(&rt).expect("artifact");
        let mut rng = XorShift::new(3);
        let e: Vec<i32> = (0..64 * 32).map(|_| rng.range_i64(1, 254) as i32).collect();
        let m: Vec<i32> = (0..64 * 32).map(|_| rng.range_i64(-255, 255) as i32).collect();
        let r = bench("online_reduce_bf16_n32 (batch 64)", target_seconds(2.0), || {
            black_box(exe.run(&rt, &e, &m).unwrap());
        });
        println!("{}   [{:.0} k rows/s]", r.line(), r.throughput(64.0) / 1e3);
        records.push(BenchRecord::new(r.clone()).param("rows_per_s", r.throughput(64.0)));
    } else {
        println!("SKIP: artifacts missing");
    }

    let path = Path::new("BENCH_perf.json");
    let suite = suite_label("perf");
    write_json(path, &suite, &records).expect("write BENCH_perf.json");
    println!("\nwrote {} (suite {suite}, {} records)", path.display(), records.len());
}
