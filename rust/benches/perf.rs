//! Bench: hot-path microbenchmarks (DESIGN.md §Perf).
//!
//! * bit-accurate `⊙` tree evaluation throughput (terms/s),
//! * the online serial recurrence and the baseline,
//! * switching-activity power simulation throughput (term-events/s),
//! * dynamic-batcher round-trip under concurrency,
//! * PJRT artifact execution latency (when artifacts are present).
//!
//! Besides the human-readable report, results land in `BENCH_perf.json`
//! (via `bench_util::write_json`) so the perf trajectory is tracked
//! machine-readably from PR to PR. `BENCH_SMOKE=1` shrinks the targets for
//! the CI smoke step.
//!
//! Run: `cargo bench --bench perf`

use online_fp_add::arith::adder::{Architecture, MultiTermAdder};
use online_fp_add::arith::tree::RadixConfig;
use online_fp_add::arith::AccSpec;
use online_fp_add::bench_util::{
    bench, black_box, header, suite_label, target_seconds, write_json, BenchRecord,
};
use online_fp_add::coordinator::batcher::{Batcher, BatcherConfig};
use online_fp_add::formats::{Fp, BF16, FP32};
use online_fp_add::hw::datapath::DatapathParams;
use online_fp_add::hw::power::ActivitySim;
use online_fp_add::runtime::{OnlineReduceExe, Runtime};
use online_fp_add::util::prng::XorShift;
use std::path::Path;

fn trace(n: usize, vectors: usize, seed: u64) -> Vec<Vec<Fp>> {
    let mut rng = XorShift::new(seed);
    (0..vectors).map(|_| (0..n).map(|_| rng.gen_fp_sparse(BF16, 0.1)).collect()).collect()
}

fn main() {
    let mut records: Vec<BenchRecord> = Vec::new();

    header("arithmetic hot paths (bit-accurate, 32-term BF16)");
    let vecs = trace(32, 256, 1);
    let spec = AccSpec::hw_default(BF16, 32);
    let cfg: RadixConfig = "8-2-2".parse().unwrap();
    let r = bench("tree_sum 8-2-2 (256 vecs)", target_seconds(1.0), || {
        for v in &vecs {
            black_box(online_fp_add::arith::tree::tree_sum(v, &cfg, spec));
        }
    });
    println!("{}   [{:.1} M terms/s]", r.line(), r.throughput(256.0 * 32.0) / 1e6);
    records.push(BenchRecord::new(r.clone()).param("terms_per_s", r.throughput(256.0 * 32.0)));
    let r = bench("baseline_sum (256 vecs)", target_seconds(1.0), || {
        for v in &vecs {
            black_box(online_fp_add::arith::baseline::baseline_sum(v, spec));
        }
    });
    println!("{}   [{:.1} M terms/s]", r.line(), r.throughput(256.0 * 32.0) / 1e6);
    records.push(BenchRecord::new(r.clone()).param("terms_per_s", r.throughput(256.0 * 32.0)));
    let r = bench("online_sum (256 vecs)", target_seconds(1.0), || {
        for v in &vecs {
            black_box(online_fp_add::arith::online::online_sum(v, spec));
        }
    });
    println!("{}   [{:.1} M terms/s]", r.line(), r.throughput(256.0 * 32.0) / 1e6);
    records.push(BenchRecord::new(r.clone()).param("terms_per_s", r.throughput(256.0 * 32.0)));

    header("full fused adders (incl. normalize/round)");
    let adder = MultiTermAdder::hw(FP32, 32, Architecture::Tree("8-2-2".parse().unwrap()));
    let mut rng = XorShift::new(2);
    let fp32vecs: Vec<Vec<Fp>> =
        (0..256).map(|_| (0..32).map(|_| rng.gen_fp_gauss(FP32, 4.0)).collect()).collect();
    let r = bench("MultiTermAdder FP32 8-2-2 (256 adds)", target_seconds(1.0), || {
        for v in &fp32vecs {
            black_box(adder.add(v));
        }
    });
    println!("{}   [{:.2} M adds/s]", r.line(), r.throughput(256.0) / 1e6);
    records.push(BenchRecord::new(r.clone()).param("adds_per_s", r.throughput(256.0)));

    header("differential oracle (reference sum + round, 16-term FP32)");
    let oracle_vecs: Vec<Vec<Fp>> = {
        let mut rng = XorShift::new(4);
        (0..256).map(|_| (0..16).map(|_| rng.gen_fp_full(FP32)).collect()).collect()
    };
    let r = bench("oracle reference_sum (256 vecs)", target_seconds(0.5), || {
        for v in &oracle_vecs {
            black_box(online_fp_add::arith::oracle::reference_sum(v, FP32));
        }
    });
    println!("{}   [{:.1} M terms/s]", r.line(), r.throughput(256.0 * 16.0) / 1e6);
    records.push(BenchRecord::new(r.clone()).param("terms_per_s", r.throughput(256.0 * 16.0)));

    header("switching-activity power simulation (32-term BF16)");
    let params = DatapathParams::new(BF16, 32, spec);
    for cfgs in ["32", "8-2-2"] {
        let c: RadixConfig = cfgs.parse().unwrap();
        let mut sim = ActivitySim::new(params, &c);
        let r = bench(&format!("ActivitySim {cfgs} (256 vecs)"), target_seconds(1.0), || {
            for v in &vecs {
                sim.step(v);
            }
        });
        println!(
            "{}   [{:.1} M term-events/s]",
            r.line(),
            r.throughput(256.0 * 32.0) / 1e6
        );
        records.push(
            BenchRecord::new(r.clone()).param("term_events_per_s", r.throughput(256.0 * 32.0)),
        );
    }

    header("dynamic batcher (checksum executor, 16 client threads)");
    let batcher = Batcher::spawn(
        BatcherConfig { n_terms: 32, linger: std::time::Duration::from_micros(100), ..Default::default() },
        |rows: &[(Vec<i32>, Vec<i32>)]| {
            rows.iter()
                .map(|(e, m)| (*e.iter().max().unwrap(), m.iter().map(|&x| x as i64).sum()))
                .collect::<Vec<(i32, i64)>>()
        },
    );
    let handle = batcher.handle();
    let r = bench("batched reduce round-trip x512", target_seconds(2.0), || {
        let threads: Vec<_> = (0..16)
            .map(|t| {
                let h = handle.clone();
                std::thread::spawn(move || {
                    for i in 0..32 {
                        let e = vec![(t * 32 + i) as i32 + 1; 32];
                        let m = vec![1i32; 32];
                        h.reduce(e, m).unwrap();
                    }
                })
            })
            .collect();
        for th in threads {
            th.join().unwrap();
        }
    });
    println!("{}   [{:.0} k req/s]", r.line(), r.throughput(512.0) / 1e3);
    println!("batcher metrics: mean fill {:.1}", batcher.metrics().mean_batch_fill());
    records.push(BenchRecord::new(r.clone()).param("req_per_s", r.throughput(512.0)));

    header("PJRT artifact execution (needs `make artifacts`)");
    let dir = Runtime::default_artifact_dir();
    if dir.join("online_reduce_bf16_n32.hlo.txt").exists() {
        let rt = Runtime::new(dir).expect("PJRT client");
        let exe = OnlineReduceExe::load_bf16_n32(&rt).expect("artifact");
        let mut rng = XorShift::new(3);
        let e: Vec<i32> = (0..64 * 32).map(|_| rng.range_i64(1, 254) as i32).collect();
        let m: Vec<i32> = (0..64 * 32).map(|_| rng.range_i64(-255, 255) as i32).collect();
        let r = bench("online_reduce_bf16_n32 (batch 64)", target_seconds(2.0), || {
            black_box(exe.run(&rt, &e, &m).unwrap());
        });
        println!("{}   [{:.0} k rows/s]", r.line(), r.throughput(64.0) / 1e3);
        records.push(BenchRecord::new(r.clone()).param("rows_per_s", r.throughput(64.0)));
    } else {
        println!("SKIP: artifacts missing");
    }

    let path = Path::new("BENCH_perf.json");
    let suite = suite_label("perf");
    write_json(path, &suite, &records).expect("write BENCH_perf.json");
    println!("\nwrote {} (suite {suite}, {} records)", path.display(), records.len());
}
