//! Bench: streaming engine ingest throughput (terms/s) vs thread count and
//! chunk size, on the standard BERT partial-product trace.
//!
//! Besides the human-readable report, results land in `BENCH_stream.json`
//! (via `bench_util::write_json`) so the perf trajectory is tracked
//! machine-readably from PR to PR.
//!
//! Run: `cargo bench --bench stream`

use online_fp_add::arith::AccSpec;
use online_fp_add::bench_util::{
    bench, header, smoke, suite_label, target_seconds, write_json, BenchRecord,
};
use online_fp_add::formats::BF16;
use online_fp_add::reduce::registry;
use online_fp_add::stream::{EngineConfig, StreamEngine};
use online_fp_add::workload::bert::power_trace;
use std::path::Path;

const N_TERMS: usize = 32;

fn main() {
    header("stream engine ingest throughput (BF16, 32-lane BERT trace)");
    let rows_n = if smoke() { 128 } else { 1024 };
    let trace = power_trace(BF16, N_TERMS, rows_n, 0xBE);
    let rows = &trace.vectors;
    let terms_per_replay = (rows.len() * N_TERMS) as f64;
    let spec = AccSpec::exact(BF16);

    let mut records = Vec::new();
    for &threads in &[1usize, 2, 4, 8] {
        for &chunk in &[16usize, 64, 256] {
            let engine = StreamEngine::new(EngineConfig {
                threads,
                chunk,
                spec,
                queue_depth: 8192,
                ..Default::default()
            });
            let mut epoch = 0u64;
            let r = bench(&format!("ingest threads={threads} chunk={chunk}"), target_seconds(0.6), || {
                // Fresh stream per replay; drain keeps the map from growing.
                epoch += 1;
                let id = format!("run-{epoch}");
                for row in rows {
                    engine.ingest_blocking(&id, row.clone()).expect("engine alive");
                }
                engine.quiesce();
                engine.drain(&id);
            });
            let tput = r.throughput(terms_per_replay);
            println!("{}   [{:.1} M terms/s]", r.line(), tput / 1e6);
            records.push(
                BenchRecord::new(r)
                    .param("threads", threads as f64)
                    .param("chunk", chunk as f64)
                    .param("terms_per_s", tput),
            );
        }
    }

    header("chunk-reduction backend (threads=4): every registered backend");
    // Registry-driven: a newly registered backend gets its own
    // `ingest backend=` series with no bench edits.
    for entry in registry::entries() {
        let backend = entry.sel();
        for &chunk in &[64usize, 256] {
            let engine = StreamEngine::new(EngineConfig {
                threads: 4,
                chunk,
                spec,
                backend: Some(backend),
                queue_depth: 8192,
                ..Default::default()
            });
            let mut epoch = 0u64;
            let r = bench(
                &format!("ingest backend={backend} chunk={chunk}"),
                target_seconds(0.6),
                || {
                    epoch += 1;
                    let id = format!("bk-{epoch}");
                    for row in rows {
                        engine.ingest_blocking(&id, row.clone()).expect("engine alive");
                    }
                    engine.quiesce();
                    engine.drain(&id);
                },
            );
            let tput = r.throughput(terms_per_replay);
            println!("{}   [{:.1} M terms/s]", r.line(), tput / 1e6);
            records.push(
                BenchRecord::new(r)
                    .param("threads", 4.0)
                    .param("chunk", chunk as f64)
                    .param("kernel", (backend.name() == "kernel") as u8 as f64)
                    .param("terms_per_s", tput),
            );
        }
    }

    let path = Path::new("BENCH_stream.json");
    let suite = suite_label("stream");
    write_json(path, &suite, &records).expect("write BENCH_stream.json");
    println!("\nwrote {} (suite {suite}, {} records)", path.display(), records.len());
}
