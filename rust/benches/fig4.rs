//! Bench: regenerate paper Fig. 4 — area (a) and average power (b) of
//! 32-term BFloat16 adders across all mixed-radix configurations vs the
//! radix-32 baseline, at the 1 GHz / §IV pipeline-depth operating point.
//!
//! Run: `cargo bench --bench fig4`

use online_fp_add::coordinator::Coordinator;
use online_fp_add::dse::report;
use std::time::Instant;

fn main() {
    let coord = Coordinator::default_parallelism();
    let t0 = Instant::now();
    let (table, points) = report::fig4(512, &coord);
    println!("=== Fig. 4: 32-term BFloat16 adders @ 1 GHz ===\n");
    println!("{}", table.render());
    println!("{}", report::fig4_headline(&points));
    println!(
        "\n[fig4 regenerated in {:.2}s over {} design points]",
        t0.elapsed().as_secs_f64(),
        points.len()
    );
}
