//! Bench: regenerate paper Table I — area and power of 16-, 32- and
//! 64-term adders for FP32 / BFloat16 / FP8_e4m3 / FP8_e5m2 / FP8_e6m1,
//! base vs best-proposed configuration, with the paper's savings alongside.
//!
//! Run: `cargo bench --bench table1`

use online_fp_add::coordinator::Coordinator;
use online_fp_add::dse::report;
use std::time::Instant;

fn main() {
    let coord = Coordinator::default_parallelism();
    for n in [16u32, 32, 64] {
        let t0 = Instant::now();
        let (table, _) = report::table1(n, 512, &coord);
        let label = match n {
            16 => "a",
            32 => "b",
            _ => "c",
        };
        println!("=== Table I({label}) — {n}-term adders ===\n");
        println!("{}", table.render());
        println!("[{n}-term sweep in {:.2}s]\n", t0.elapsed().as_secs_f64());
    }
}
