//! Bench: regenerate paper Fig. 5 — the most area-efficient 32-term
//! BFloat16 designs for clock-period targets with 1–4 pipeline stages,
//! plus the equal-depth clock-speed headline.
//!
//! Run: `cargo bench --bench fig5`

use online_fp_add::coordinator::Coordinator;
use online_fp_add::dse::report;
use std::time::Instant;

fn main() {
    let coord = Coordinator::default_parallelism();
    let t0 = Instant::now();
    println!("=== Fig. 5: area-efficient designs per clock-period target ===\n");
    let table = report::fig5(&coord);
    println!("{}", table.render());
    println!("{}", report::fig5_speed_headline(&coord));
    println!("\n[fig5 regenerated in {:.2}s]", t0.elapsed().as_secs_f64());
}
