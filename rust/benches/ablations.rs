//! Ablation studies over the hardware model's design choices (DESIGN.md):
//!
//! 1. **Scheduling regions** — what happens to the Fig. 4 comparison if the
//!    scheduler may stagger individual lanes (idealized retiming no HLS
//!    has)? This isolates how much of the proposed designs' win is the
//!    modularity/scheduling-flexibility effect the paper claims.
//! 2. **Implementation selection** — disable the compact-variant downgrade
//!    pass to measure how much area slack-aware sizing recovers.
//! 3. **Guard-width sensitivity** — the accuracy/area trade-off of the
//!    truncated datapath: ULP error vs the correctly-rounded oracle and
//!    area as the fractional extension shrinks.
//!
//! Run: `cargo bench --bench ablations`

use online_fp_add::arith::adder::{Architecture, MultiTermAdder};
use online_fp_add::arith::exact::exact_rounded_sum;
use online_fp_add::arith::tree::RadixConfig;
use online_fp_add::arith::AccSpec;
use online_fp_add::formats::{Fp, FpClass, BF16};
use online_fp_add::hw::datapath::{build_adder, DatapathParams};
use online_fp_add::hw::gates;
use online_fp_add::hw::pipeline::{min_clock_ns, paper_stages, pipeline};
use online_fp_add::util::prng::XorShift;
use online_fp_add::util::table::Table;

fn main() {
    ablate_regions_and_implsel();
    ablate_guard_width();
}

/// Ablation 1+2: evaluate baseline vs 8-2-2 under four scheduler variants.
fn ablate_regions_and_implsel() {
    println!("=== Ablation: scheduling regions × implementation selection ===");
    println!("(32-term BFloat16 @ paper operating point; Δ = 8-2-2 vs baseline)\n");
    let fmt = BF16;
    let n = 32u32;
    let stages = paper_stages(fmt, n);
    let mut t = Table::new(vec![
        "variant",
        "base µm²",
        "base regs",
        "8-2-2 µm²",
        "8-2-2 regs",
        "Δ total",
    ]);
    for (label, strip_regions, strip_alts, clock_mult) in [
        ("full model @ tight clock", false, false, 1.0),
        ("no impl-selection @ tight", false, true, 1.0),
        ("no regions @ tight", true, false, 1.0),
        ("full model @ 1.5x clock", false, false, 1.5),
        ("no impl-selection @ 1.5x", false, true, 1.5),
        ("no regions @ 1.5x", true, false, 1.5),
    ] {
        let eval = |cfg: &RadixConfig| {
            let params = DatapathParams::new(fmt, n, AccSpec::hw_default(fmt, n as usize));
            let mut adder = build_adder(params, cfg);
            if strip_regions {
                for node in &mut adder.nl.nodes {
                    node.region.clear();
                }
            }
            if strip_alts {
                for node in &mut adder.nl.nodes {
                    node.alt = None;
                }
            }
            let clock = (min_clock_ns(&adder, stages).max(1.0) * 1.001) * clock_mult;
            let p = pipeline(&adder, stages, clock).expect("feasible at min clock");
            (gates::ge_to_um2(p.total_area), p.reg_bits)
        };
        let base = eval(&RadixConfig::baseline(n));
        let tree = eval(&"8-2-2".parse().unwrap());
        t.row(vec![
            label.to_string(),
            format!("{:.0}", base.0),
            base.1.to_string(),
            format!("{:.0}", tree.0),
            tree.1.to_string(),
            format!("{:+.1}%", 100.0 * (tree.0 - base.0) / base.0),
        ]);
    }
    println!("{}", t.render());
    println!(
        "Reading (measured): at the tight operating point the register-\n\
         boundary structure itself drives the tree's advantage — neither\n\
         knob moves it. Slack (relaxed clock) lets implementation selection\n\
         shrink combinational area, and lane-level retiming mainly helps the\n\
         monolithic baseline, i.e. the regions constraint is what keeps the\n\
         baseline honest about real HLS scheduling.\n"
    );
}

/// Ablation 3: guard bits vs accuracy vs area (32-term BF16, 8-2-2).
fn ablate_guard_width() {
    println!("=== Ablation: guard width (accuracy vs area) ===\n");
    let fmt = BF16;
    let n = 32usize;
    let cfg: RadixConfig = "8-2-2".parse().unwrap();
    let mut rng = XorShift::new(0xAB1A);
    let vectors: Vec<Vec<Fp>> =
        (0..3000).map(|_| (0..n).map(|_| rng.gen_fp_gauss(fmt, 8.0)).collect()).collect();
    let mut t = Table::new(vec![
        "guard bits",
        "area µm² (comb)",
        "mean |err| ULP",
        "max |err| ULP",
        "exact matches",
    ]);
    for guard in [2u32, 4, 8, 12, 16, 24] {
        let adder = MultiTermAdder {
            format: fmt,
            n_terms: n,
            spec: AccSpec::truncated(guard),
            arch: Architecture::Tree(cfg.clone()),
        };
        let params = DatapathParams::new(fmt, n as u32, AccSpec::truncated(guard));
        let area = gates::ge_to_um2(build_adder(params, &cfg).nl.area());
        let mut sum_err = 0f64;
        let mut max_err = 0f64;
        let mut exact = 0usize;
        let mut counted = 0usize;
        for v in &vectors {
            let got = adder.add(v);
            let want = exact_rounded_sum(v, fmt);
            if want.class() != FpClass::Normal || got.class() != FpClass::Normal {
                continue;
            }
            let err = (got.bits as i64 - want.bits as i64).abs() as f64;
            sum_err += err;
            max_err = max_err.max(err);
            exact += (err == 0.0) as usize;
            counted += 1;
        }
        t.row(vec![
            guard.to_string(),
            format!("{area:.0}"),
            format!("{:.3}", sum_err / counted as f64),
            format!("{max_err:.0}"),
            format!("{:.1}%", 100.0 * exact as f64 / counted as f64),
        ]);
    }
    println!("{}", t.render());
    println!(
        "Reading: the hw-default guard (16 bits for BF16/32 terms) buys\n\
         correct rounding on virtually all vectors; tiny guards trade ULPs\n\
         for area — the knob a deployment would tune."
    );
}
