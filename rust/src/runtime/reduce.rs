//! Typed wrapper around the `online_reduce_*` artifacts: the online
//! align-and-add reduction with a fixed `(batch, n_terms)` geometry,
//! executed by the native interpreter.
//!
//! The executor reproduces the Pallas kernel's semantics exactly: each row's
//! `(e, m)` pairs become `⊙` leaves and are reduced by the balanced binary
//! tree the kernel lowers to, in the truncated accumulator frame with
//! `guard` fractional-extension bits — so results are bit-identical to
//! `tree_sum(_, RadixConfig::binary(n), AccSpec::truncated(guard))`.

use super::{LoadedArtifact, Result, Runtime, RuntimeError};
use crate::arith::operator::AlignAcc;
use crate::arith::tree::{reduce_in_place, RadixConfig};
use crate::arith::{AccSpec, WideInt};

/// Output of one reduction batch: per-row `(λ, acc)` states.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ReduceOut {
    pub lambda: Vec<i32>,
    pub acc: Vec<i64>,
}

/// A loaded online-reduction executable with fixed `(batch, n_terms)`
/// geometry (baked in at AOT time — see `python/compile/aot.py`).
pub struct OnlineReduceExe {
    exe: LoadedArtifact,
    /// The balanced binary tree the kernel lowers to.
    cfg: RadixConfig,
    pub batch: usize,
    pub n_terms: usize,
    /// Guard (fractional-extension) bits of the artifact's accumulator
    /// frame — must match the Rust-side `AccSpec` when cross-checking.
    pub guard: u32,
}

impl OnlineReduceExe {
    /// Load an artifact by name, e.g. `"online_reduce_bf16_n32"`.
    pub fn load(
        rt: &Runtime,
        name: &str,
        batch: usize,
        n_terms: usize,
        guard: u32,
    ) -> Result<Self> {
        let cfg = RadixConfig::binary(n_terms as u32).map_err(|e| {
            RuntimeError::msg(format!("artifact {name}: unsupported geometry: {e}"))
        })?;
        let exe = rt.load(name)?;
        exe.expect_kind(super::ArtifactKind::OnlineReduce)?;
        Ok(OnlineReduceExe { exe, cfg, batch, n_terms, guard })
    }

    /// The BF16 32-term artifact with its baked geometry.
    pub fn load_bf16_n32(rt: &Runtime) -> Result<Self> {
        // Frame.hw_default(8, 7, 32): f = 8 + 5 + 3 = 16.
        Self::load(rt, "online_reduce_bf16_n32", 64, 32, 16)
    }

    /// The FP32 16-term artifact with its baked geometry.
    pub fn load_fp32_n16(rt: &Runtime) -> Result<Self> {
        // Frame.hw_default(8, 23, 16): f = 24 + 4 + 3 = 31.
        Self::load(rt, "online_reduce_fp32_n16", 64, 16, 31)
    }

    /// Reduce up to `batch` rows of `(e, m)` terms — effective exponent
    /// ([`crate::formats::Fp::eff_exp`]) and signed significand per lane,
    /// so subnormal operands travel as `(1, ±mantissa)`. Short batches are
    /// accepted (the hardware pads its unused lanes with identity rows;
    /// the native executor simply computes the live rows) and exactly the
    /// live rows are returned.
    pub fn run(&self, rt: &Runtime, e: &[i32], m: &[i32]) -> Result<ReduceOut> {
        let _ = rt; // execution is native; the runtime only gates loading
        assert_eq!(e.len(), m.len());
        assert_eq!(e.len() % self.n_terms, 0, "inputs must be whole rows");
        let rows = e.len() / self.n_terms;
        if rows > self.batch {
            return Err(RuntimeError::msg(format!(
                "artifact {} executes at most {} rows, got {rows}",
                self.exe.name, self.batch
            )));
        }
        let spec = AccSpec::truncated(self.guard);
        let mut lambda = Vec::with_capacity(rows);
        let mut acc = Vec::with_capacity(rows);
        let mut buf = vec![AlignAcc::IDENTITY; self.n_terms];
        for r in 0..rows {
            let base = r * self.n_terms;
            for (lane, slot) in buf.iter_mut().enumerate() {
                *slot = leaf_from_fields(e[base + lane], m[base + lane], spec);
            }
            // The same reduction code path as `tree_sum` — bit-equivalence
            // to the model is by construction.
            let state = reduce_in_place(&mut buf, self.n_terms, &self.cfg, spec);
            lambda.push(state.lambda);
            acc.push(state.acc.to_i128() as i64);
        }
        Ok(ReduceOut { lambda, acc })
    }
}

/// Lift one `(e, m)` lane into the operator domain, matching
/// [`AlignAcc::leaf`]: a zero significand is the identity (a zero operand
/// contributes neither to the max-exponent tree nor to the fraction sum).
///
/// `e` is the term's *effective* exponent ([`crate::formats::Fp::eff_exp`]):
/// callers encode subnormal lanes as `(1, ±mantissa)` — hidden bit 0 at
/// effective exponent 1, the gradual-underflow λ-convention — so a nonzero
/// `m` with `e == 1` may be either a subnormal or a minimal normal; the
/// datapath treats both identically.
fn leaf_from_fields(e: i32, m: i32, spec: AccSpec) -> AlignAcc {
    if m == 0 {
        return AlignAcc::IDENTITY;
    }
    AlignAcc { lambda: e, acc: WideInt::from_i64_shl(m as i64, spec.f), sticky: false }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::tree::tree_sum;
    use crate::formats::{Fp, BF16};
    use crate::util::prng::XorShift;

    #[test]
    fn native_executor_leaves_match_tree_sum_bitexact() {
        // The executor shares reduce_in_place with tree_sum, so the only
        // thing left to check is that (e, m) field lifting matches
        // AlignAcc::leaf on real encoded terms.
        let spec = AccSpec::truncated(16);
        let cfg = RadixConfig::binary(32).unwrap();
        let mut rng = XorShift::new(0x2E0);
        let mut buf = vec![AlignAcc::IDENTITY; 32];
        for _ in 0..200 {
            let terms: Vec<Fp> = (0..32)
                .map(|_| {
                    // Mix zeros, normals and subnormals: every lane kind
                    // the (e, m) field encoding must carry.
                    match rng.below(10) {
                        0 => Fp::zero(BF16),
                        1 => rng.gen_fp_subnormal(BF16),
                        _ => rng.gen_fp_normal(BF16),
                    }
                })
                .collect();
            for (slot, t) in buf.iter_mut().zip(&terms) {
                *slot = leaf_from_fields(t.eff_exp(), t.signed_sig() as i32, spec);
            }
            let got = reduce_in_place(&mut buf, 32, &cfg, spec);
            let want = tree_sum(&terms, &cfg, spec);
            assert_eq!(got, want);
        }
    }
}
