//! Typed wrapper around the `online_reduce_*` artifacts: the online
//! align-and-add reduction with a fixed `(batch, n_terms)` geometry,
//! executed by the native interpreter.
//!
//! The executor reproduces the hardware's fused-adder semantics: each
//! row's `(e, m)` pairs feed a [`crate::reduce::Reducer`] planned for the
//! `"kernel"` backend at `block == n_terms`, so every row reduces against
//! one row-local maximum exponent in the truncated accumulator frame with
//! `guard` fractional-extension bits — the paper's baseline (Fig. 1)
//! datapath, one max-exponent tree feeding one aligned compressor. Results
//! are bit-identical to
//! `tree_sum(_, RadixConfig::baseline(n), AccSpec::truncated(guard))`
//! by construction (a single kernel block *is* the radix-`n` operator).

use super::{LoadedArtifact, Result, Runtime, RuntimeError};
use crate::arith::AccSpec;
use crate::reduce::{ReducePlan, Reducer};
use crate::telemetry;

/// Output of one reduction batch: per-row `(λ, acc)` states.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ReduceOut {
    pub lambda: Vec<i32>,
    pub acc: Vec<i64>,
}

/// A loaded online-reduction executable with fixed `(batch, n_terms)`
/// geometry (baked in at AOT time — see `python/compile/aot.py`).
pub struct OnlineReduceExe {
    exe: LoadedArtifact,
    pub batch: usize,
    pub n_terms: usize,
    /// Guard (fractional-extension) bits of the artifact's accumulator
    /// frame — must match the Rust-side `AccSpec` when cross-checking.
    pub guard: u32,
    /// The reduction plan every row runs: the `"kernel"` backend at
    /// `block == n_terms` under `AccSpec::truncated(guard)`, built once at
    /// load time through the same builder every other consumer uses.
    plan: ReducePlan,
}

impl OnlineReduceExe {
    /// Load an artifact by name, e.g. `"online_reduce_bf16_n32"`.
    pub fn load(
        rt: &Runtime,
        name: &str,
        batch: usize,
        n_terms: usize,
        guard: u32,
    ) -> Result<Self> {
        if n_terms < 2 || n_terms > 4096 {
            return Err(RuntimeError::msg(format!(
                "artifact {name}: unsupported geometry: {n_terms} terms (need 2..=4096)"
            )));
        }
        let exe = rt.load(name)?;
        exe.expect_kind(super::ArtifactKind::OnlineReduce)?;
        let plan = ReducePlan::builder(AccSpec::truncated(guard))
            .backend_name("kernel")
            .and_then(|b| b.block(n_terms))
            .and_then(|b| b.build())
            .map_err(|e| RuntimeError::msg(format!("artifact {name}: {e}")))?;
        Ok(OnlineReduceExe { exe, batch, n_terms, guard, plan })
    }

    /// The reduction plan the executor dispatches rows through.
    pub fn plan(&self) -> ReducePlan {
        self.plan
    }

    /// The BF16 32-term artifact with its baked geometry.
    pub fn load_bf16_n32(rt: &Runtime) -> Result<Self> {
        // Frame.hw_default(8, 7, 32): f = 8 + 5 + 3 = 16.
        Self::load(rt, "online_reduce_bf16_n32", 64, 32, 16)
    }

    /// The FP32 16-term artifact with its baked geometry.
    pub fn load_fp32_n16(rt: &Runtime) -> Result<Self> {
        // Frame.hw_default(8, 23, 16): f = 24 + 4 + 3 = 31.
        Self::load(rt, "online_reduce_fp32_n16", 64, 16, 31)
    }

    /// Reduce up to `batch` rows of `(e, m)` terms — effective exponent
    /// ([`crate::formats::Fp::eff_exp`]) and signed significand per lane,
    /// so subnormal operands travel as `(1, ±mantissa)` and zero/padding
    /// lanes as `(_, 0)` (a zero significand is the identity regardless of
    /// its exponent field, exactly as unused hardware lanes contribute
    /// neither to the max-exponent tree nor to the fraction sum). Short
    /// batches are accepted (the hardware pads its unused lanes with
    /// identity rows; the native executor simply computes the live rows)
    /// and exactly the live rows are returned.
    pub fn run(&self, rt: &Runtime, e: &[i32], m: &[i32]) -> Result<ReduceOut> {
        let _ = rt; // execution is native; the runtime only gates loading
        assert_eq!(e.len(), m.len());
        assert_eq!(e.len() % self.n_terms, 0, "inputs must be whole rows");
        let rows = e.len() / self.n_terms;
        if rows > self.batch {
            return Err(RuntimeError::msg(format!(
                "artifact {} executes at most {} rows, got {rows}",
                self.exe.name, self.batch
            )));
        }
        let mut lambda = Vec::with_capacity(rows);
        let mut acc = Vec::with_capacity(rows);
        let mut sig = vec![0i64; self.n_terms];
        // One reusable reducer from the load-time plan; `reset` between
        // rows keeps this allocation-free on the per-row path.
        let mut reducer = self.plan.reducer();
        for r in 0..rows {
            let base = r * self.n_terms;
            let eff = &e[base..base + self.n_terms];
            for (slot, &mi) in sig.iter_mut().zip(&m[base..base + self.n_terms]) {
                *slot = mi as i64;
            }
            // One SoA kernel block per row (`block == n_terms`):
            // bit-equivalence to the baseline radix-n `⊙` operator (and
            // hence to tree_sum with the baseline config) is by
            // construction.
            reducer.reset();
            reducer.ingest_decoded(eff, &sig);
            let state = reducer.finish();
            lambda.push(state.lambda);
            acc.push(state.acc.to_i128() as i64);
        }
        if telemetry::enabled() {
            let rt_fam = &telemetry::global().runtime;
            rt_fam.batches.inc();
            rt_fam.rows.add(rows as u64);
        }
        Ok(ReduceOut { lambda, acc })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::tree::{tree_sum, RadixConfig};
    use crate::formats::{Fp, BF16};
    use crate::util::prng::XorShift;

    #[test]
    fn native_executor_rows_match_baseline_tree_sum_bitexact() {
        // The executor runs one kernel block per row through the plan's
        // reducer; a single block is the radix-n operator, so the (e, m)
        // field lifting plus reduction must bit-match tree_sum under the
        // baseline (single-level) config on real encoded terms — zeros,
        // normals and subnormals alike.
        let spec = AccSpec::truncated(16);
        let plan = ReducePlan::builder(spec)
            .backend_name("kernel")
            .and_then(|b| b.block(32))
            .and_then(|b| b.build())
            .expect("valid plan");
        let cfg = RadixConfig::baseline(32);
        let mut rng = XorShift::new(0x2E0);
        let mut sig = vec![0i64; 32];
        let mut eff = vec![0i32; 32];
        let mut reducer = plan.reducer();
        for _ in 0..200 {
            let terms: Vec<Fp> = (0..32)
                .map(|_| {
                    // Mix zeros, normals and subnormals: every lane kind
                    // the (e, m) field encoding must carry.
                    match rng.below(10) {
                        0 => Fp::zero(BF16),
                        1 => rng.gen_fp_subnormal(BF16),
                        _ => rng.gen_fp_normal(BF16),
                    }
                })
                .collect();
            for (i, t) in terms.iter().enumerate() {
                eff[i] = t.eff_exp();
                sig[i] = t.signed_sig();
            }
            reducer.reset();
            reducer.ingest_decoded(&eff, &sig);
            let got = reducer.finish();
            let want = tree_sum(&terms, &cfg, spec);
            assert_eq!(got, want);
        }
    }
}
