//! Typed wrapper around the `online_reduce_*` artifacts: the L1 Pallas
//! online align-and-add reduction, executed via PJRT.

use super::{literal_i32_2d, Runtime};
use anyhow::Result;

/// Output of one reduction batch: per-row `(λ, acc)` states.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ReduceOut {
    pub lambda: Vec<i32>,
    pub acc: Vec<i64>,
}

/// A compiled online-reduction executable with fixed `(batch, n_terms)`
/// geometry (baked in at AOT time — see `python/compile/aot.py`).
pub struct OnlineReduceExe {
    exe: xla::PjRtLoadedExecutable,
    pub batch: usize,
    pub n_terms: usize,
    /// Guard (fractional-extension) bits of the artifact's accumulator
    /// frame — must match the Rust-side `AccSpec` when cross-checking.
    pub guard: u32,
}

impl OnlineReduceExe {
    /// Load an artifact by name, e.g. `"online_reduce_bf16_n32"`.
    pub fn load(rt: &Runtime, name: &str, batch: usize, n_terms: usize, guard: u32) -> Result<Self> {
        Ok(OnlineReduceExe { exe: rt.load(name)?, batch, n_terms, guard })
    }

    /// The BF16 32-term artifact with its baked geometry.
    pub fn load_bf16_n32(rt: &Runtime) -> Result<Self> {
        // Frame.hw_default(8, 7, 32): f = 8 + 5 + 3 = 16.
        Self::load(rt, "online_reduce_bf16_n32", 64, 32, 16)
    }

    /// The FP32 16-term artifact with its baked geometry.
    pub fn load_fp32_n16(rt: &Runtime) -> Result<Self> {
        // Frame.hw_default(8, 23, 16): f = 24 + 4 + 3 = 31.
        Self::load(rt, "online_reduce_fp32_n16", 64, 16, 31)
    }

    /// Reduce up to `batch` rows of `(e, m)` terms. Short batches are padded
    /// with zero rows (identity leaves); only the live rows are returned.
    pub fn run(&self, rt: &Runtime, e: &[i32], m: &[i32]) -> Result<ReduceOut> {
        assert_eq!(e.len(), m.len());
        assert_eq!(e.len() % self.n_terms, 0, "inputs must be whole rows");
        let rows = e.len() / self.n_terms;
        assert!(rows <= self.batch, "at most {} rows per execution", self.batch);
        let mut e_pad = e.to_vec();
        let mut m_pad = m.to_vec();
        e_pad.resize(self.batch * self.n_terms, 0);
        m_pad.resize(self.batch * self.n_terms, 0);
        let le = literal_i32_2d(&e_pad, self.batch, self.n_terms)?;
        let lm = literal_i32_2d(&m_pad, self.batch, self.n_terms)?;
        let out = rt.execute(&self.exe, &[le, lm])?;
        anyhow::ensure!(out.len() == 2, "expected (lambda, acc) tuple, got {} elems", out.len());
        let mut lambda = out[0].to_vec::<i32>()?;
        let mut acc = out[1].to_vec::<i64>()?;
        lambda.truncate(rows);
        acc.truncate(rows);
        Ok(ReduceOut { lambda, acc })
    }
}
