//! PJRT runtime: load the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`) and execute them from Rust.
//!
//! This is the only place python output crosses into the request path — as
//! *compiled artifacts*, never as a python process. HLO **text** is the
//! interchange format (jax ≥ 0.5 emits protos with 64-bit instruction ids
//! that xla_extension 0.5.1 rejects; the text parser reassigns ids).
//!
//! The typed wrappers ([`OnlineReduceExe`], [`BertLayerExe`]) hide literal
//! plumbing and pad partial batches with identity (zero) terms, mirroring
//! unused hardware lanes.

mod bert;
mod reduce;

pub use bert::{BertLayerExe, BertWeights};
pub use reduce::{OnlineReduceExe, ReduceOut};

/// (SEQ, DMODEL, DFF) geometry of the BERT-layer artifact.
pub fn bert_dims() -> (usize, usize) {
    (bert::SEQ, bert::DMODEL)
}

use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// A PJRT CPU client plus the artifact directory.
pub struct Runtime {
    client: xla::PjRtClient,
    artifact_dir: PathBuf,
}

impl Runtime {
    /// Create a CPU PJRT client rooted at an artifact directory.
    pub fn new<P: AsRef<Path>>(artifact_dir: P) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, artifact_dir: artifact_dir.as_ref().to_path_buf() })
    }

    /// Locate the artifact directory: `$ONLINE_FP_ADD_ARTIFACTS`, then
    /// `./artifacts`, then `../artifacts` (for running inside `rust/`).
    pub fn default_artifact_dir() -> PathBuf {
        if let Ok(dir) = std::env::var("ONLINE_FP_ADD_ARTIFACTS") {
            return PathBuf::from(dir);
        }
        for cand in ["artifacts", "../artifacts"] {
            let p = PathBuf::from(cand);
            if p.is_dir() {
                return p;
            }
        }
        PathBuf::from("artifacts")
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Load and compile one artifact by stem name (e.g. `"bert_layer"`).
    pub fn load(&self, name: &str) -> Result<xla::PjRtLoadedExecutable> {
        let path = self.artifact_dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {name}"))
    }

    /// Execute a compiled artifact and return the flattened output tuple.
    ///
    /// All artifacts are lowered with `return_tuple=True`, so the single
    /// device output is a tuple literal we decompose here.
    pub fn execute(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let result = exe.execute::<xla::Literal>(inputs).context("executing artifact")?;
        let out = result[0][0].to_literal_sync().context("fetching result literal")?;
        out.to_tuple().context("decomposing output tuple")
    }
}

/// Build a 2-D `i32` literal from row-major data.
pub fn literal_i32_2d(data: &[i32], rows: usize, cols: usize) -> Result<xla::Literal> {
    debug_assert_eq!(data.len(), rows * cols);
    Ok(xla::Literal::vec1(data).reshape(&[rows as i64, cols as i64])?)
}

/// Build a 2-D `f32` literal from row-major data.
pub fn literal_f32_2d(data: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
    debug_assert_eq!(data.len(), rows * cols);
    Ok(xla::Literal::vec1(data).reshape(&[rows as i64, cols as i64])?)
}
