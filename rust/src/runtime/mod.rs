//! Artifact runtime: execute the AOT-compiled reduction / BERT-layer
//! artifacts (`artifacts/*.hlo.txt`) behind a typed, PJRT-shaped API.
//!
//! The offline build image carries no `xla`/PJRT shared libraries, so this
//! module ships a **native interpreter** for the artifact set instead of a
//! PJRT client: each artifact name maps to a bit-accurate Rust executor
//! (the `⊙`-tree models of [`crate::arith`] for the `online_reduce_*`
//! kernels, the f32 encoder layer of [`crate::workload::bert`] for
//! `bert_layer`). The API mirrors the PJRT wrappers exactly — load by
//! artifact stem, fixed batch geometry, identity padding of partial
//! batches — so the integration tests, the dynamic batcher and the
//! examples are byte-for-byte the same code they would be against a real
//! PJRT backend, and the artifact files still gate execution (no file, no
//! executable).
//!
//! The typed wrappers ([`OnlineReduceExe`], [`BertLayerExe`]) hide the
//! dispatch plumbing and pad partial batches with identity (zero) terms,
//! mirroring unused hardware lanes.

mod bert;
mod reduce;

pub use bert::{BertActivations, BertLayerExe, BertWeights};
pub use reduce::{OnlineReduceExe, ReduceOut};

/// (SEQ, DMODEL) geometry of the BERT-layer artifact.
pub fn bert_dims() -> (usize, usize) {
    (bert::SEQ, bert::DMODEL)
}

use std::fmt;
use std::path::{Path, PathBuf};

/// Runtime error: a message chain, `{:#}`-formats like `anyhow` did.
#[derive(Debug)]
pub struct RuntimeError(String);

impl RuntimeError {
    pub fn msg<S: Into<String>>(s: S) -> Self {
        RuntimeError(s.into())
    }
}

impl fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for RuntimeError {}

pub type Result<T> = std::result::Result<T, RuntimeError>;

/// Which native executor an artifact name resolves to.
#[derive(Clone, Debug, PartialEq, Eq)]
enum ArtifactKind {
    OnlineReduce,
    BertLayer,
}

/// A "compiled" artifact: the resolved executor plus its source path.
pub struct LoadedArtifact {
    kind: ArtifactKind,
    pub name: String,
}

/// The artifact runtime: an executor registry rooted at an artifact
/// directory (the native stand-in for a PJRT CPU client).
pub struct Runtime {
    artifact_dir: PathBuf,
}

impl Runtime {
    /// Open a runtime rooted at an artifact directory. Fails when the
    /// directory does not exist — the same failure mode as a PJRT client
    /// with no plugin, which the fault-injection tests rely on.
    pub fn new<P: AsRef<Path>>(artifact_dir: P) -> Result<Self> {
        let dir = artifact_dir.as_ref();
        if !dir.is_dir() {
            return Err(RuntimeError::msg(format!(
                "artifact directory {} not found (run `make artifacts`)",
                dir.display()
            )));
        }
        Ok(Runtime { artifact_dir: dir.to_path_buf() })
    }

    /// Locate the artifact directory: `$ONLINE_FP_ADD_ARTIFACTS`, then
    /// `./artifacts`, then `../artifacts` (for running inside `rust/`).
    pub fn default_artifact_dir() -> PathBuf {
        if let Ok(dir) = std::env::var("ONLINE_FP_ADD_ARTIFACTS") {
            return PathBuf::from(dir);
        }
        for cand in ["artifacts", "../artifacts"] {
            let p = PathBuf::from(cand);
            if p.is_dir() {
                return p;
            }
        }
        PathBuf::from("artifacts")
    }

    /// Backend identifier (mirrors `PjRtClient::platform_name`).
    pub fn platform(&self) -> String {
        "native-interpreter".to_string()
    }

    /// Load one artifact by stem name (e.g. `"bert_layer"`): the
    /// `<name>.hlo.txt` file must exist and the name must map to a known
    /// executor.
    pub fn load(&self, name: &str) -> Result<LoadedArtifact> {
        let path = self.artifact_dir.join(format!("{name}.hlo.txt"));
        if !path.is_file() {
            return Err(RuntimeError::msg(format!(
                "artifact {name} not found: missing {}",
                path.display()
            )));
        }
        let kind = if name.starts_with("online_reduce") {
            ArtifactKind::OnlineReduce
        } else if name == "bert_layer" {
            ArtifactKind::BertLayer
        } else {
            return Err(RuntimeError::msg(format!(
                "artifact {name} has no registered native executor"
            )));
        };
        Ok(LoadedArtifact { kind, name: name.to_string() })
    }
}

impl LoadedArtifact {
    fn expect_kind(&self, kind: ArtifactKind) -> Result<()> {
        if self.kind == kind {
            Ok(())
        } else {
            Err(RuntimeError::msg(format!(
                "artifact {} is a {:?}, not a {kind:?}",
                self.name, self.kind
            )))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_directory_is_an_error() {
        let err = Runtime::new("/nonexistent/artifacts").err().expect("must fail");
        assert!(format!("{err:#}").contains("/nonexistent/artifacts"));
    }

    #[test]
    fn missing_artifact_names_the_artifact() {
        // The repo root always exists; artifacts generally do not.
        let dir = std::env::temp_dir();
        let rt = Runtime::new(&dir).expect("temp dir exists");
        let err = rt.load("no_such_artifact").err().expect("must fail");
        assert!(format!("{err}").contains("no_such_artifact"), "{err}");
    }

    #[test]
    fn unknown_executor_is_rejected_even_with_a_file() {
        let dir = std::env::temp_dir().join("ofa-artifact-test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("mystery.hlo.txt"), "HloModule mystery").unwrap();
        let rt = Runtime::new(&dir).unwrap();
        let err = rt.load("mystery").err().expect("no executor registered");
        assert!(format!("{err}").contains("mystery"));
    }
}
