//! Typed wrapper around the `bert_layer` artifact: one BERT-style encoder
//! layer (the paper's power-estimation workload), executed via PJRT.

use super::{literal_f32_2d, Runtime};
use crate::util::prng::XorShift;
use anyhow::Result;

/// Geometry baked into the artifact at AOT time.
pub const SEQ: usize = 128;
pub const DMODEL: usize = 256;
pub const DFF: usize = 1024;

/// Row-major weight matrices for one encoder layer.
pub struct BertWeights {
    pub wq: Vec<f32>,
    pub wk: Vec<f32>,
    pub wv: Vec<f32>,
    pub wo: Vec<f32>,
    pub w1: Vec<f32>,
    pub w2: Vec<f32>,
}

impl BertWeights {
    /// Xavier-style random initialisation from a seed (deterministic).
    pub fn random(seed: u64) -> Self {
        let mut rng = XorShift::new(seed);
        let mut mk = |rows: usize, cols: usize| -> Vec<f32> {
            let scale = (2.0 / (rows + cols) as f64).sqrt();
            (0..rows * cols).map(|_| (rng.gauss() * scale) as f32).collect()
        };
        BertWeights {
            wq: mk(DMODEL, DMODEL),
            wk: mk(DMODEL, DMODEL),
            wv: mk(DMODEL, DMODEL),
            wo: mk(DMODEL, DMODEL),
            w1: mk(DMODEL, DFF),
            w2: mk(DFF, DMODEL),
        }
    }
}

/// All activations the artifact returns (row-major, shapes in comments).
pub struct BertActivations {
    pub q: Vec<f32>,    // (SEQ, DMODEL)
    pub k: Vec<f32>,    // (SEQ, DMODEL)
    pub v: Vec<f32>,    // (SEQ, DMODEL)
    pub attn: Vec<f32>, // (SEQ, SEQ)
    pub ctx: Vec<f32>,  // (SEQ, DMODEL)
    pub h: Vec<f32>,    // (SEQ, DMODEL)
    pub g: Vec<f32>,    // (SEQ, DFF)
    pub out: Vec<f32>,  // (SEQ, DMODEL)
}

/// A compiled BERT-layer executable.
pub struct BertLayerExe {
    exe: xla::PjRtLoadedExecutable,
}

impl BertLayerExe {
    pub fn load(rt: &Runtime) -> Result<Self> {
        Ok(BertLayerExe { exe: rt.load("bert_layer")? })
    }

    /// Run the layer on `(SEQ, DMODEL)` activations.
    pub fn run(&self, rt: &Runtime, x: &[f32], w: &BertWeights) -> Result<BertActivations> {
        assert_eq!(x.len(), SEQ * DMODEL);
        let inputs = [
            literal_f32_2d(x, SEQ, DMODEL)?,
            literal_f32_2d(&w.wq, DMODEL, DMODEL)?,
            literal_f32_2d(&w.wk, DMODEL, DMODEL)?,
            literal_f32_2d(&w.wv, DMODEL, DMODEL)?,
            literal_f32_2d(&w.wo, DMODEL, DMODEL)?,
            literal_f32_2d(&w.w1, DMODEL, DFF)?,
            literal_f32_2d(&w.w2, DFF, DMODEL)?,
        ];
        let out = rt.execute(&self.exe, &inputs)?;
        anyhow::ensure!(out.len() == 8, "expected 8 outputs, got {}", out.len());
        Ok(BertActivations {
            q: out[0].to_vec::<f32>()?,
            k: out[1].to_vec::<f32>()?,
            v: out[2].to_vec::<f32>()?,
            attn: out[3].to_vec::<f32>()?,
            ctx: out[4].to_vec::<f32>()?,
            h: out[5].to_vec::<f32>()?,
            g: out[6].to_vec::<f32>()?,
            out: out[7].to_vec::<f32>()?,
        })
    }
}
