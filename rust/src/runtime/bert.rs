//! Typed wrapper around the `bert_layer` artifact: one BERT-style encoder
//! layer (the paper's power-estimation workload), executed by the native
//! interpreter via the same f32 kernels as [`crate::workload::bert`].

use super::{LoadedArtifact, Result, Runtime, RuntimeError};
use crate::util::prng::XorShift;
use crate::workload::bert::{gelu, softmax_rows};
use crate::workload::matmul::matmul_f32;

/// Geometry baked into the artifact at AOT time.
pub const SEQ: usize = 128;
pub const DMODEL: usize = 256;
pub const DFF: usize = 1024;

/// Row-major weight matrices for one encoder layer.
pub struct BertWeights {
    pub wq: Vec<f32>,
    pub wk: Vec<f32>,
    pub wv: Vec<f32>,
    pub wo: Vec<f32>,
    pub w1: Vec<f32>,
    pub w2: Vec<f32>,
}

impl BertWeights {
    /// Xavier-style random initialisation from a seed (deterministic).
    #[allow(clippy::disallowed_methods)] // weight init, not datapath
    pub fn random(seed: u64) -> Self {
        let mut rng = XorShift::new(seed);
        let mut mk = |rows: usize, cols: usize| -> Vec<f32> {
            let scale = (2.0 / (rows + cols) as f64).sqrt();
            (0..rows * cols).map(|_| (rng.gauss() * scale) as f32).collect()
        };
        BertWeights {
            wq: mk(DMODEL, DMODEL),
            wk: mk(DMODEL, DMODEL),
            wv: mk(DMODEL, DMODEL),
            wo: mk(DMODEL, DMODEL),
            w1: mk(DMODEL, DFF),
            w2: mk(DFF, DMODEL),
        }
    }
}

/// All activations the artifact returns (row-major, shapes in comments).
pub struct BertActivations {
    pub q: Vec<f32>,    // (SEQ, DMODEL)
    pub k: Vec<f32>,    // (SEQ, DMODEL)
    pub v: Vec<f32>,    // (SEQ, DMODEL)
    pub attn: Vec<f32>, // (SEQ, SEQ)
    pub ctx: Vec<f32>,  // (SEQ, DMODEL)
    pub h: Vec<f32>,    // (SEQ, DMODEL)
    pub g: Vec<f32>,    // (SEQ, DFF)
    pub out: Vec<f32>,  // (SEQ, DMODEL)
}

/// A loaded BERT-layer executable.
pub struct BertLayerExe {
    exe: LoadedArtifact,
}

impl BertLayerExe {
    pub fn load(rt: &Runtime) -> Result<Self> {
        let exe = rt.load("bert_layer")?;
        exe.expect_kind(super::ArtifactKind::BertLayer)?;
        Ok(BertLayerExe { exe })
    }

    /// Run the layer on `(SEQ, DMODEL)` activations.
    #[allow(clippy::disallowed_methods)] // f32 reference model, not the exact path
    pub fn run(&self, rt: &Runtime, x: &[f32], w: &BertWeights) -> Result<BertActivations> {
        let _ = rt; // execution is native; the runtime only gates loading
        if x.len() != SEQ * DMODEL {
            return Err(RuntimeError::msg(format!(
                "artifact {} expects ({SEQ}, {DMODEL}) activations, got {} values",
                self.exe.name,
                x.len()
            )));
        }
        // Shape-check every operand (as the PJRT literal layer used to):
        // a wrong-sized matrix must be an Err, not a panic or wrong math.
        for (name, len, want) in [
            ("wq", w.wq.len(), DMODEL * DMODEL),
            ("wk", w.wk.len(), DMODEL * DMODEL),
            ("wv", w.wv.len(), DMODEL * DMODEL),
            ("wo", w.wo.len(), DMODEL * DMODEL),
            ("w1", w.w1.len(), DMODEL * DFF),
            ("w2", w.w2.len(), DFF * DMODEL),
        ] {
            if len != want {
                return Err(RuntimeError::msg(format!(
                    "artifact {}: weight {name} has {len} values, expected {want}",
                    self.exe.name
                )));
            }
        }
        let (s, d, ff) = (SEQ, DMODEL, DFF);
        let q = matmul_f32(x, &w.wq, s, d, d);
        let k = matmul_f32(x, &w.wk, s, d, d);
        let v = matmul_f32(x, &w.wv, s, d, d);
        // attn = softmax(q @ k^T / sqrt(d)), row-wise.
        let mut kt = vec![0f32; d * s];
        for i in 0..s {
            for j in 0..d {
                kt[j * s + i] = k[i * d + j];
            }
        }
        let mut attn = matmul_f32(&q, &kt, s, d, s);
        let inv = 1.0 / (d as f32).sqrt();
        for a in attn.iter_mut() {
            *a *= inv;
        }
        softmax_rows(&mut attn, s, s);
        let ctx = matmul_f32(&attn, &v, s, s, d);
        // h = ctx @ wo + x (residual), g = gelu(h @ w1), out = g @ w2 + h.
        let mut h = matmul_f32(&ctx, &w.wo, s, d, d);
        for (hv, xv) in h.iter_mut().zip(x) {
            *hv += xv;
        }
        let mut g = matmul_f32(&h, &w.w1, s, d, ff);
        for gv in g.iter_mut() {
            *gv = gelu(*gv);
        }
        let mut out = matmul_f32(&g, &w.w2, s, ff, d);
        for (ov, hv) in out.iter_mut().zip(&h) {
            *ov += hv;
        }
        Ok(BertActivations { q, k, v, attn, ctx, h, g, out })
    }
}
