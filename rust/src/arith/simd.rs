//! Vectorized SoA align-and-add kernel: the `"simd"` entry of the
//! reduction-backend registry (DESIGN.md §Kernel, SIMD subsection).
//!
//! Same block geometry and bit-identical semantics as
//! [`super::kernel::block_state`] — the paper's observation that the fused
//! incremental align-and-add step has *no serial dependence inside a block*
//! (one λ, then every lane aligns independently) is exactly what makes the
//! block body data-parallel. Two loops vectorize:
//!
//! 1. **Block-λ max sweep** — dead lanes (`sig == 0`) are masked to the
//!    identity level into a stack staging buffer, then the max runs
//!    8-lanes-wide. Dispatch, per process, in priority order:
//!    * AVX2 (`_mm256_max_epi32`), detected **at runtime** on x86_64 and
//!      cached — no compile-time feature or `-C target-cpu` required;
//!    * portable `std::simd` (`i32x8::simd_max`), when the crate is built
//!      with the nightly-gated `simd` cargo feature;
//!    * a scalar fold — the guaranteed fallback on every platform.
//! 2. **Narrow-path align-accumulate** — lane-parallel `(sig << f) >> d`
//!    with the dropped-bit masks OR-folded across the vector
//!    (`std::simd` only: x86 lacks a 64-bit arithmetic variable shift
//!    below AVX-512, so there is no AVX2 leg for this loop). The vector
//!    sub-path is entered only when `f <=` [`VEC_NARROW_MAX_F`] and the
//!    chunk's maximum shift distance is ≤ [`VEC_NARROW_MAX_SHIFT`]; any
//!    other chunk falls back to the scalar mirror of the kernel formula.
//!    Per-chunk lane sums stay inside i64 by the bound
//!    `SIG_BOUND_BITS + VEC_NARROW_MAX_F + log2(LANES) + 1 = 64` — pinned
//!    as the `simd-vector-lane` obligation in `analysis::derive`.
//!
//! The wide (`WideInt`) path and every scalar fallback mirror the kernel's
//! formulas verbatim, so `"simd"` is **bit-identical to `"kernel"` at every
//! `(spec, block)`** — not just on exact specs — and inherits the kernel's
//! capability surface. `tests/simd_edge.rs` pins lane tails, sub-vector
//! blocks, all-dead-lane vectors and mixed narrow/wide specs across all
//! five paper formats; the registry rotation puts it under the conformance
//! suite and the differential oracle automatically.

// Exact-datapath module: native float arithmetic and lossy casts are
// forbidden here (clippy.toml, DESIGN.md §Analysis).
#![deny(clippy::float_arithmetic, clippy::cast_precision_loss)]

use super::kernel::{decode_soa, decode_term, flush_kernel_health, DEFAULT_BLOCK};
use super::operator::{op_combine, AlignAcc};
use super::{AccSpec, WideInt};
use crate::formats::Fp;

/// Vector width (i32/i64 lanes per SIMD op): one AVX2 register of i32s,
/// one `i64x8` for the portable align path.
pub const LANES: usize = 8;

/// The vectorized narrow align-accumulate only engages when the frame's
/// guard `f` is at most this: `SIG_BOUND_BITS (25) + 35 + clog2(LANES) (3)
/// + 1 sign = 64` keeps an 8-lane chunk sum exactly inside an i64 lane
/// (the `simd-vector-lane` analysis obligation, margin 0). Every exact
/// spec and wider truncated frame takes the scalar mirror instead.
pub const VEC_NARROW_MAX_F: u32 = 35;

/// Maximum per-chunk alignment distance the vector sub-path handles; a
/// chunk whose max distance exceeds this (possible up to the kernel's 127
/// clamp) falls back to the scalar mirror for that chunk. 62 keeps every
/// vector shift strictly inside the i64 lane width.
pub const VEC_NARROW_MAX_SHIFT: u32 = 62;

// ---- block-λ max sweep -------------------------------------------------

/// Scalar max fold — the guaranteed fallback, and the tail handler for
/// both vector legs.
#[inline]
fn max_scalar(vals: &[i32]) -> i32 {
    vals.iter().copied().fold(0, i32::max)
}

/// Runtime AVX2 probe, cached per process (one `cpuid` ever; probing
/// twice under a race is harmless — both writers store the same answer).
#[cfg(target_arch = "x86_64")]
fn avx2_available() -> bool {
    use std::sync::atomic::{AtomicU8, Ordering};
    // 0 = unprobed, 1 = available, 2 = absent.
    static AVX2: AtomicU8 = AtomicU8::new(0);
    match AVX2.load(Ordering::Relaxed) {
        1 => true,
        2 => false,
        _ => {
            let yes = is_x86_feature_detected!("avx2");
            AVX2.store(if yes { 1 } else { 2 }, Ordering::Relaxed);
            yes
        }
    }
}

/// AVX2 leg of the λ sweep: 8-wide `max_epi32` accumulator, scalar tail.
///
/// # Safety
/// The caller must have verified AVX2 support at runtime
/// ([`avx2_available`]).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn max_avx2(vals: &[i32]) -> i32 {
    use std::arch::x86_64::{_mm256_loadu_si256, _mm256_max_epi32, _mm256_storeu_si256};
    debug_assert!(vals.len() >= LANES);
    let ptr = vals.as_ptr();
    // Unaligned loads: the staging buffer is a plain [i32; 64] on the
    // stack with no 32-byte alignment guarantee.
    let mut acc = _mm256_loadu_si256(ptr.cast());
    let mut i = LANES;
    while i + LANES <= vals.len() {
        acc = _mm256_max_epi32(acc, _mm256_loadu_si256(ptr.add(i).cast()));
        i += LANES;
    }
    let mut lanes = [0i32; LANES];
    _mm256_storeu_si256(lanes.as_mut_ptr().cast(), acc);
    let mut m = max_scalar(&lanes);
    if i < vals.len() {
        m = m.max(max_scalar(&vals[i..]));
    }
    m
}

/// Portable `std::simd` leg of the λ sweep (nightly `simd` feature).
#[cfg(feature = "simd")]
fn max_portable(vals: &[i32]) -> i32 {
    use std::simd::prelude::*;
    debug_assert!(vals.len() >= LANES);
    let mut acc = i32x8::from_slice(&vals[..LANES]);
    let mut i = LANES;
    while i + LANES <= vals.len() {
        acc = acc.simd_max(i32x8::from_slice(&vals[i..i + LANES]));
        i += LANES;
    }
    let mut m = acc.reduce_max().max(0);
    if i < vals.len() {
        m = m.max(max_scalar(&vals[i..]));
    }
    m
}

/// Max of a pre-masked (dead lanes already zeroed) staging slice, through
/// whichever vector leg this process/build has. All legs compute the same
/// exact maximum — dispatch is a pure speed choice.
fn masked_max(vals: &[i32]) -> i32 {
    #[cfg(target_arch = "x86_64")]
    if vals.len() >= LANES && avx2_available() {
        // SAFETY: AVX2 support was verified at runtime just above.
        return unsafe { max_avx2(vals) };
    }
    #[cfg(feature = "simd")]
    if vals.len() >= LANES {
        return max_portable(vals);
    }
    max_scalar(vals)
}

/// Block-λ sweep: mask dead lanes to the identity level into a stack
/// staging buffer ([`DEFAULT_BLOCK`] wide — oversize blocks sweep in
/// stages), then take the vectorized max. Bit-identical to the kernel's
/// branch-free scalar sweep: a masked dead lane contributes 0, exactly
/// what `if s == 0 { 0 } else { e }` contributes.
fn block_lambda(eff: &[i32], sig: &[i64]) -> i32 {
    let mut lambda = 0i32;
    let mut buf = [0i32; DEFAULT_BLOCK];
    for (e_chunk, s_chunk) in eff.chunks(DEFAULT_BLOCK).zip(sig.chunks(DEFAULT_BLOCK)) {
        for ((b, &e), &s) in buf.iter_mut().zip(e_chunk).zip(s_chunk) {
            *b = if s == 0 { 0 } else { e };
        }
        lambda = lambda.max(masked_max(&buf[..e_chunk.len()]));
    }
    lambda
}

// ---- narrow align-accumulate ------------------------------------------

/// The kernel's scalar narrow-path formula, verbatim (the bit-identity
/// contract): widened distance so dead lanes' arbitrary `eff` entries
/// cannot overflow, 127 clamp (pure sign fill past it — every narrow
/// magnitude sits below bit 127), dropped bits OR-folded.
#[inline]
fn narrow_lane(lambda: i32, e: i32, s: i64, f: u32, acc: &mut i128, dropped: &mut u128) {
    let m = (s as i128) << f;
    let d = (lambda as i64 - e as i64).clamp(0, 127) as u32;
    *acc += m >> d;
    *dropped |= (m as u128) & ((1u128 << d) - 1);
}

/// Vectorized prefix of the narrow align-accumulate: processes the
/// longest multiple-of-[`LANES`] prefix and returns how many lanes it
/// covered (the caller mops up the tail with [`narrow_lane`]). Chunks
/// whose max distance exceeds [`VEC_NARROW_MAX_SHIFT`] run the scalar
/// mirror inline, so the return value is always the full prefix.
#[cfg(feature = "simd")]
fn narrow_vec_prefix(
    lambda: i32,
    eff: &[i32],
    sig: &[i64],
    f: u32,
    acc: &mut i128,
    dropped: &mut u128,
) -> usize {
    use std::simd::prelude::*;
    debug_assert!(f <= VEC_NARROW_MAX_F, "caller gates the vector sub-path on f");
    let lam = i64x8::splat(lambda as i64);
    let zero = i64x8::splat(0);
    let clamp = i64x8::splat(127);
    let fv = i64x8::splat(f as i64);
    let ones = u64x8::splat(1);
    let mut done = 0usize;
    while done + LANES <= eff.len() {
        let e: i64x8 = i32x8::from_slice(&eff[done..done + LANES]).cast();
        let d = (lam - e).simd_clamp(zero, clamp);
        if d.reduce_max() > VEC_NARROW_MAX_SHIFT as i64 {
            // Far-spread chunk (d can reach the kernel's 127 clamp, past
            // the i64 lane width): scalar mirror for these 8 lanes, the
            // vector path resumes on the next chunk.
            for (&le, &ls) in eff[done..done + LANES].iter().zip(&sig[done..done + LANES]) {
                narrow_lane(lambda, le, ls, f, acc, dropped);
            }
            done += LANES;
            continue;
        }
        // All shifts in [0, 62]: `(sig << f) >> d` stays exact per lane
        // (|sig| < 2^25, f <= 35) and the 8-lane sum fits i64 with margin
        // 0 (the `simd-vector-lane` obligation), so one horizontal
        // reduce_sum per chunk lands in the i128 accumulator losslessly.
        let m = i64x8::from_slice(&sig[done..done + LANES]) << fv;
        let shifted = m >> d;
        let mask = (ones << d.cast::<u64>()) - ones;
        let bits = m.cast::<u64>() & mask;
        *acc += i128::from(shifted.reduce_sum());
        *dropped |= u128::from(bits.reduce_or());
        done += LANES;
    }
    done
}

/// Stable-build stand-in: no vector prefix, the caller's scalar tail loop
/// covers everything. Keeps [`narrow_state`] branch-free of `cfg` blocks.
#[cfg(not(feature = "simd"))]
#[inline]
fn narrow_vec_prefix(
    _lambda: i32,
    _eff: &[i32],
    _sig: &[i64],
    _f: u32,
    _acc: &mut i128,
    _dropped: &mut u128,
) -> usize {
    0
}

fn narrow_state(lambda: i32, eff: &[i32], sig: &[i64], spec: AccSpec) -> AlignAcc {
    let f = spec.f;
    let mut acc = 0i128;
    let mut dropped = 0u128;
    let tail = if f <= VEC_NARROW_MAX_F {
        narrow_vec_prefix(lambda, eff, sig, f, &mut acc, &mut dropped)
    } else {
        0
    };
    for (&e, &s) in eff[tail..].iter().zip(&sig[tail..]) {
        narrow_lane(lambda, e, s, f, &mut acc, &mut dropped);
    }
    let sticky = dropped != 0;
    debug_assert!(!(spec.exact && sticky), "exact datapath must never drop bits");
    AlignAcc { lambda, acc: WideInt::from_i128(acc), sticky }
}

/// Wide path: the kernel's formulas verbatim (see
/// [`super::kernel::block_state`] for the shift-composition argument).
/// Exact frames always have `d <= f`, so this is one `from_i64_shl` + add
/// per live lane — memory-bound, with nothing left to vectorize that the
/// λ sweep has not already covered.
fn wide_state(lambda: i32, eff: &[i32], sig: &[i64], spec: AccSpec) -> AlignAcc {
    let f = spec.f as i64;
    let mut acc = WideInt::ZERO;
    let mut sticky = false;
    for (&e, &s) in eff.iter().zip(sig) {
        if s == 0 {
            continue;
        }
        let d = (lambda as i64 - e as i64).max(0);
        if d <= f {
            acc = acc.add(&WideInt::from_i64_shl(s, (f - d) as u32));
        } else {
            let sh = ((d - f) as u64).min(127) as u32;
            sticky |= (s as u128) & ((1u128 << sh) - 1) != 0;
            acc = acc.add(&WideInt::from_i128((s as i128) >> sh));
        }
    }
    debug_assert!(!(spec.exact && sticky), "exact datapath must never drop bits");
    AlignAcc { lambda, acc, sticky }
}

/// Vectorized [`super::kernel::block_state`]: bit-identical at every
/// `(eff, sig, spec)` — the conformance/equivalence batteries and
/// `tests/simd_edge.rs` pin this, and the registry publishes the kernel's
/// capability surface for it.
pub fn block_state_simd(eff: &[i32], sig: &[i64], spec: AccSpec) -> AlignAcc {
    debug_assert_eq!(eff.len(), sig.len());
    let lambda = block_lambda(eff, sig);
    if spec.narrow {
        return narrow_state(lambda, eff, sig, spec);
    }
    wide_state(lambda, eff, sig, spec)
}

/// Batched SoA reduction through [`block_state_simd`] — the `"simd"`
/// registry entry's reduce path, mirroring
/// [`super::kernel::reduce_terms`] (same staging, same block chaining,
/// same telemetry flush: the simd backend *is* the kernel datapath
/// geometry, vectorized, so it shares the kernel-health instrumentation
/// the analysis runtime cross-check reads).
pub fn reduce_terms_simd(terms: &[Fp], block: usize, spec: AccSpec) -> AlignAcc {
    assert!(block >= 1, "simd block must be >= 1 (rejected at plan build/parse)");
    if block <= DEFAULT_BLOCK {
        let mut eff = [0i32; DEFAULT_BLOCK];
        let mut sig = [0i64; DEFAULT_BLOCK];
        let mut state = AlignAcc::IDENTITY;
        let (mut blocks, mut sticky_blocks) = (0u64, 0u64);
        for chunk in terms.chunks(block) {
            for (i, t) in chunk.iter().enumerate() {
                (eff[i], sig[i]) = decode_term(t);
            }
            let part = block_state_simd(&eff[..chunk.len()], &sig[..chunk.len()], spec);
            blocks += 1;
            sticky_blocks += part.sticky as u64;
            state = op_combine(&state, &part, spec);
        }
        flush_kernel_health(terms.len(), block, blocks, sticky_blocks, spec);
        return state;
    }
    let mut eff = Vec::new();
    let mut sig = Vec::new();
    let mut state = AlignAcc::IDENTITY;
    let (mut blocks, mut sticky_blocks) = (0u64, 0u64);
    for chunk in terms.chunks(block) {
        decode_soa(chunk, &mut eff, &mut sig);
        let part = block_state_simd(&eff, &sig, spec);
        blocks += 1;
        sticky_blocks += part.sticky as u64;
        state = op_combine(&state, &part, spec);
    }
    flush_kernel_health(terms.len(), block, blocks, sticky_blocks, spec);
    state
}

/// Which dispatch legs this process actually runs — for bench headers and
/// `repro backends` so a recorded speedup is attributable to a concrete
/// code path.
pub fn active_paths() -> &'static str {
    #[cfg(target_arch = "x86_64")]
    {
        if avx2_available() {
            return if cfg!(feature = "simd") {
                "avx2 λ-sweep + portable-simd align"
            } else {
                "avx2 λ-sweep + scalar align"
            };
        }
    }
    if cfg!(feature = "simd") {
        "portable-simd λ-sweep + portable-simd align"
    } else {
        "scalar λ-sweep + scalar align (guaranteed fallback)"
    }
}

#[cfg(test)]
#[allow(clippy::float_arithmetic, clippy::cast_precision_loss, clippy::disallowed_methods)]
mod tests {
    use super::*;
    use crate::arith::kernel::{block_state, reduce_terms, scalar_fold};
    use crate::formats::{FpFormat, BF16, FP8_E5M2, PAPER_FORMATS};
    use crate::util::prng::XorShift;

    fn mixed_terms(rng: &mut XorShift, fmt: FpFormat, n: usize) -> Vec<Fp> {
        (0..n)
            .map(|_| match rng.below(8) {
                0 => Fp::zero(fmt),
                1 | 2 => rng.gen_fp_subnormal(fmt),
                _ => rng.gen_fp_full(fmt),
            })
            .collect()
    }

    /// The load-bearing invariant: simd ≡ kernel bit-for-bit in EVERY
    /// spec (exact, forced-wide, truncated narrow both sides of the
    /// vector-path `f` ceiling), at lengths that exercise lane tails.
    #[test]
    fn block_state_simd_is_bit_identical_to_the_kernel_in_every_spec() {
        let mut rng = XorShift::new(0x51D0);
        for fmt in PAPER_FORMATS {
            let exact = AccSpec::exact(fmt);
            let specs = [
                exact,
                AccSpec { narrow: false, ..exact },
                AccSpec::truncated(3),
                AccSpec::truncated(16),
                // f = 40 > VEC_NARROW_MAX_F: narrow scalar mirror.
                AccSpec::truncated(40),
            ];
            for n in [0usize, 1, 3, 7, 8, 9, 15, 16, 31, 64, 130] {
                let terms = mixed_terms(&mut rng, fmt, n);
                let mut eff = Vec::new();
                let mut sig = Vec::new();
                decode_soa(&terms, &mut eff, &mut sig);
                for spec in specs {
                    assert_eq!(
                        block_state_simd(&eff, &sig, spec),
                        block_state(&eff, &sig, spec),
                        "{fmt} n={n} {spec:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn reduce_terms_simd_matches_the_kernel_and_the_scalar_fold() {
        let mut rng = XorShift::new(0x51D1);
        for fmt in PAPER_FORMATS {
            let exact = AccSpec::exact(fmt);
            for n in [1usize, 5, 9, 63, 200] {
                let terms = mixed_terms(&mut rng, fmt, n);
                let want = scalar_fold(&terms, exact);
                for block in [1usize, 3, 5, 8, 64, n] {
                    assert_eq!(
                        reduce_terms_simd(&terms, block, exact),
                        want,
                        "{fmt} n={n} block={block} (exact ≡ fold)"
                    );
                }
                // Truncated specs: simd must still equal the kernel's
                // [block; block; ...] parenthesisation bit-for-bit.
                for spec in [AccSpec::truncated(2), AccSpec::truncated(16)] {
                    for block in [1usize, 3, 8, 64] {
                        assert_eq!(
                            reduce_terms_simd(&terms, block, spec),
                            reduce_terms(&terms, block, spec),
                            "{fmt} n={n} block={block} {spec:?}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn dead_lane_adversarial_exponents_are_identities() {
        // The runtime field encoding pads dead lanes with arbitrary
        // exponent entries — i32::MIN included (the debug-overflow bug
        // this PR fixes in the kernel). Both paths, all specs.
        for spec in [AccSpec::truncated(16), AccSpec::exact(BF16), AccSpec::exact(FP8_E5M2)] {
            let eff = [i32::MIN, 7, i32::MAX, i32::MIN + 1, 0, -1];
            let sig = [0i64, 3, 0, 0, 0, 0];
            let st = block_state_simd(&eff, &sig, spec);
            assert_eq!(st.lambda, 7, "{spec:?}");
            assert!(!st.sticky, "{spec:?}");
            assert_eq!(st.acc, WideInt::from_i64_shl(3, spec.f), "{spec:?}");
            assert_eq!(st, block_state(&eff, &sig, spec), "{spec:?}");
        }
    }

    #[test]
    fn all_dead_lane_vectors_and_empty_blocks_are_the_identity() {
        let spec = AccSpec::exact(BF16);
        assert!(block_state_simd(&[], &[], spec).is_identity());
        // A full staging buffer of dead lanes with hostile exponents.
        let eff = vec![i32::MIN; 70];
        let sig = vec![0i64; 70];
        assert!(block_state_simd(&eff, &sig, spec).is_identity());
        let zeros = vec![Fp::zero(BF16); 19];
        assert!(reduce_terms_simd(&zeros, 8, spec).is_identity());
        assert!(reduce_terms_simd(&[], 64, spec).is_identity());
    }

    #[test]
    fn far_spread_chunks_take_the_fallback_consistently() {
        // One chunk mixing near (d = 0) and far (d > VEC_NARROW_MAX_SHIFT,
        // up to past the 127 clamp) lanes forces the per-chunk fallback;
        // the result must not depend on which leg ran.
        let spec = AccSpec::truncated(16);
        assert!(spec.narrow && spec.f <= VEC_NARROW_MAX_F);
        for far in [63i32, 100, 127, 128, 200, 253] {
            let lam = 1 + far;
            let eff = [lam, 1, lam, 1, 1, 1, 1, 1, lam, 1];
            let sig = [9i64, -5, 3, 7, -7, 1, -1, 5, 2, -3];
            let got = block_state_simd(&eff, &sig, spec);
            assert_eq!(got, block_state(&eff, &sig, spec), "far={far}");
            assert_eq!(got.lambda, lam, "far={far}");
            assert!(got.sticky, "far={far}: far lanes must drop bits");
        }
    }

    #[test]
    fn active_paths_reports_a_live_dispatch() {
        let p = active_paths();
        assert!(p.contains("sweep"), "{p}");
        // Dispatch probing must be stable across calls.
        assert_eq!(p, active_paths());
    }
}
