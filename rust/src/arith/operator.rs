//! The paper's associative align-and-add operator `⊙` (eq. 8).
//!
//! ```text
//! [λi]   [λj]   [        max(λi, λj)                                 ]
//! [oi] ⊙ [oj] = [ oi ≫ (max−λi)  +  oj ≫ (max−λj)                    ]
//! ```
//!
//! The operand of `⊙` is an [`AlignAcc`]: a partial sum `o` tagged with the
//! maximum exponent `λ` of the terms it covers (plus the sticky bit real
//! datapaths carry for faithful rounding). Leaves are single floating-point
//! terms ([`AlignAcc::leaf`]); eq. 9 states that any parenthesisation of
//! `⊙` over the N leaves yields the final `(max exponent, aligned sum)`.

// Exact-datapath module: native float arithmetic and lossy casts are
// forbidden here (clippy.toml, DESIGN.md §Analysis).
#![deny(clippy::float_arithmetic, clippy::cast_precision_loss)]

use super::{AccSpec, WideInt};
use crate::formats::{Fp, FpClass};

/// A partial alignment-and-addition state: the `[λ; o]` vector of eq. 8.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct AlignAcc {
    /// Running maximum raw (biased) exponent of the covered terms.
    pub lambda: i32,
    /// Partial sum of the covered significands, aligned to `lambda`, in the
    /// frame `acc · 2^(lambda − bias − mbits − f)`.
    pub acc: WideInt,
    /// True if any alignment shift discarded a nonzero bit (hardware sticky).
    pub sticky: bool,
}

impl AlignAcc {
    /// Identity element: λ = 0 (below every nonzero term's effective
    /// exponent — subnormals sit at λ = 1, see [`AlignAcc::leaf`]), o = 0.
    ///
    /// `identity() ⊙ x == x` because the identity's accumulator is zero and
    /// its λ never exceeds a live term's effective exponent — except for
    /// the all-zero-terms case where it keeps λ at 0, which normalizes
    /// to ±0.
    pub const IDENTITY: AlignAcc = AlignAcc { lambda: 0, acc: WideInt::ZERO, sticky: false };

    /// Lift one finite floating-point term into the operator domain:
    /// `[λ_i; m_i << f]` with `λ_i =` [`Fp::eff_exp`]`()`.
    ///
    /// **Subnormal λ-convention**: a subnormal term carries raw exponent 0,
    /// which would collide with [`AlignAcc::IDENTITY`]'s λ = 0. Following
    /// IEEE gradual underflow, subnormals enter the λ domain at the
    /// *effective* exponent 1 with hidden bit 0 — `(-1)^s · 0.m · 2^(1-bias)`
    /// lifts to `[1; (±m) << f]`, exactly where a normal at exponent 1 with
    /// the same significand bits would land. Every nonzero term therefore
    /// has λ ∈ [1, max_normal_exp]: raw exponent 0 never reaches the
    /// max-exponent tree, the identity's λ = 0 stays strictly below every
    /// live term, and the worst-case alignment distance keeps the bound
    /// `max_normal_exp − 1` that [`super::AccSpec::exact`] is derived from.
    ///
    /// Zero terms enter as `[0; 0]` (the identity), matching hardware where
    /// a zero operand contributes neither to the max-exponent tree nor to
    /// the fraction sum. Inf/NaN must be filtered by the caller
    /// (see [`crate::arith::adder`]).
    pub fn leaf(term: Fp, spec: AccSpec) -> AlignAcc {
        debug_assert!(term.is_finite(), "leaf() requires a finite term");
        if term.class() == FpClass::Zero {
            return AlignAcc::IDENTITY;
        }
        AlignAcc {
            lambda: term.eff_exp(),
            acc: WideInt::from_i64_shl(term.signed_sig(), spec.f),
            sticky: false,
        }
    }

    /// True when this state is exactly the identity (no terms absorbed yet,
    /// or only zeros).
    pub fn is_identity(&self) -> bool {
        self.lambda == 0 && self.acc.is_zero() && !self.sticky
    }
}

/// The radix-2 `⊙` operator (eq. 8).
///
/// Note only the smaller-λ operand actually shifts (the other shift amount
/// is zero) — exactly the single-shifter + swap structure the hardware
/// model ascribes to a radix-2 node.
#[inline]
pub fn op_combine(a: &AlignAcc, b: &AlignAcc, spec: AccSpec) -> AlignAcc {
    let lambda = a.lambda.max(b.lambda);
    if spec.narrow {
        // i128 fast path (§Perf); bit-identical to the wide path.
        let (va, vb) = (a.acc.to_i128_narrow(), b.acc.to_i128_narrow());
        let da = ((lambda - a.lambda) as u32).min(127);
        let db = ((lambda - b.lambda) as u32).min(127);
        let dropped = ((va as u128) & ((1u128 << da) - 1) != 0)
            | ((vb as u128) & ((1u128 << db) - 1) != 0);
        debug_assert!(!(spec.exact && dropped), "exact datapath must never drop bits");
        return AlignAcc {
            lambda,
            acc: WideInt::from_i128((va >> da) + (vb >> db)),
            sticky: a.sticky | b.sticky | dropped,
        };
    }
    let (sa, da) = shift_for(a, lambda);
    let (sb, db) = shift_for(b, lambda);
    debug_assert!(!(spec.exact && (da || db)), "exact datapath must never drop bits");
    AlignAcc { lambda, acc: sa.add(&sb), sticky: a.sticky | b.sticky | da | db }
}

/// The radix-r generalisation: one max over all λs, then every operand is
/// aligned by its own distance and all are added in one compressor tree.
/// This is *structurally* the baseline of Fig. 1 applied to `r` operands —
/// the paper's observation that the baseline N-term adder is the
/// single-radix-N corner of the proposed design space.
pub fn op_combine_many(parts: &[AlignAcc], spec: AccSpec) -> AlignAcc {
    debug_assert!(!parts.is_empty());
    let lambda = parts.iter().map(|p| p.lambda).max().unwrap();
    if spec.narrow {
        // Fast path (§Perf): the AccSpec guarantees every accumulator fits
        // an i128, so the shift/add runs on two limbs instead of six.
        // Semantically identical (same arithmetic shift + sticky), checked
        // bit-for-bit against the wide path in tests.
        let mut acc = 0i128;
        let mut sticky = false;
        for p in parts {
            let v = p.acc.to_i128_narrow();
            // d ≤ 127 suffices: a narrow value shifted ≥ 127 is pure sign
            // fill either way, and the mask below still sees all its bits.
            let d = ((lambda - p.lambda) as u32).min(127);
            acc += v >> d;
            let dropped = (v as u128) & ((1u128 << d) - 1) != 0;
            debug_assert!(!(spec.exact && dropped), "exact datapath must never drop bits");
            sticky |= p.sticky | dropped;
        }
        return AlignAcc { lambda, acc: WideInt::from_i128(acc), sticky };
    }
    let mut acc = WideInt::ZERO;
    let mut sticky = false;
    for p in parts {
        let (shifted, dropped) = shift_for(p, lambda);
        debug_assert!(!(spec.exact && dropped), "exact datapath must never drop bits");
        acc = acc.add(&shifted);
        sticky |= p.sticky | dropped;
    }
    AlignAcc { lambda, acc, sticky }
}

#[inline]
fn shift_for(p: &AlignAcc, lambda: i32) -> (WideInt, bool) {
    let d = (lambda - p.lambda) as u32;
    p.acc.shr_sticky(d)
}

#[allow(clippy::float_arithmetic, clippy::cast_precision_loss, clippy::disallowed_methods)]
#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::AccSpec;
    use crate::formats::{Fp, BF16};

    fn leaf(x: f64, spec: AccSpec) -> AlignAcc {
        AlignAcc::leaf(Fp::from_f64(x, BF16), spec)
    }

    #[test]
    fn identity_is_neutral() {
        let spec = AccSpec::exact(BF16);
        let x = leaf(3.25, spec);
        assert_eq!(op_combine(&AlignAcc::IDENTITY, &x, spec), x);
        assert_eq!(op_combine(&x, &AlignAcc::IDENTITY, spec), x);
    }

    #[test]
    fn commutative_in_exact_mode() {
        let spec = AccSpec::exact(BF16);
        let a = leaf(1.5, spec);
        let b = leaf(-0.0078125, spec);
        assert_eq!(op_combine(&a, &b, spec), op_combine(&b, &a, spec));
    }

    #[test]
    fn associative_in_exact_mode() {
        let spec = AccSpec::exact(BF16);
        let (a, b, c) = (leaf(100.0, spec), leaf(-0.125, spec), leaf(7.0, spec));
        let l = op_combine(&op_combine(&a, &b, spec), &c, spec);
        let r = op_combine(&a, &op_combine(&b, &c, spec), spec);
        assert_eq!(l, r); // eq. 10
    }

    #[test]
    fn radix_many_equals_folded_radix2_exact() {
        let spec = AccSpec::exact(BF16);
        let parts = [leaf(1.0, spec), leaf(256.0, spec), leaf(-0.5, spec), leaf(3.0, spec)];
        let folded = parts[1..]
            .iter()
            .fold(parts[0], |acc, p| op_combine(&acc, p, spec));
        assert_eq!(op_combine_many(&parts, spec), folded);
    }

    #[test]
    fn truncation_sets_sticky() {
        // Tiny guard: aligning 1.0 against 2^20 must drop bits.
        let spec = AccSpec::truncated(2);
        let big = leaf(1048576.0, spec);
        let small = leaf(1.0, spec);
        let r = op_combine(&big, &small, spec);
        assert!(r.sticky);
        assert_eq!(r.lambda, big.lambda);
    }

    #[test]
    fn max_exponent_tracked() {
        let spec = AccSpec::exact(BF16);
        let r = op_combine(&leaf(0.5, spec), &leaf(4.0, spec), spec);
        assert_eq!(r.lambda, Fp::from_f64(4.0, BF16).raw_exp());
    }

    #[test]
    fn subnormal_leaf_uses_effective_exponent_one() {
        let spec = AccSpec::exact(BF16);
        // 0.0000001·2^-126 — the smallest positive BF16 subnormal.
        let sub = Fp::pack(false, 0, 1, BF16);
        let l = AlignAcc::leaf(sub, spec);
        assert_eq!(l.lambda, 1, "subnormal λ-convention");
        assert!(!l.is_identity());
        // It lands exactly where a hypothetical normal-frame significand m=1
        // at exponent 1 would: acc = 1 << f.
        assert_eq!(l.acc, crate::arith::WideInt::from_i64_shl(1, spec.f));
        // And the identity is still neutral against it.
        assert_eq!(op_combine(&AlignAcc::IDENTITY, &l, spec), l);
        // A normal at exponent 1 with hidden bit aligns against it with
        // distance 0 — no bits can drop in exact mode.
        let tiny_normal = Fp::pack(true, 1, 0, BF16);
        let r = op_combine(&l, &AlignAcc::leaf(tiny_normal, spec), spec);
        assert_eq!(r.lambda, 1);
        assert!(!r.sticky);
    }
}
