//! Bit-accurate models of every alignment-and-addition algorithm in the
//! paper, over a common fixed-point accumulator representation.
//!
//! All algorithms operate on *(exponent, signed fraction)* pairs in a shared
//! accumulator frame described by [`AccSpec`]:
//!
//! * a term with raw exponent `e` and signed significand `m` (the integer
//!   `(-1)^s · 1.m · 2^mbits`) is loaded as `m << f` and aligned by
//!   arithmetic right shifts;
//! * an accumulator tagged with running maximum exponent `λ` holds the value
//!   `acc · 2^(λ − bias − mbits − f)`.
//!
//! With `f` large enough to cover the format's worst-case alignment distance
//! ([`AccSpec::exact`]) no shift ever discards a bit, and the baseline
//! (Algorithm 2), the online recurrence (Algorithm 3) and every mixed-radix
//! `⊙` tree (eq. 9) produce **bit-identical** accumulators. With a finite
//! guard ([`AccSpec::truncated`]) the models reproduce real datapath
//! truncation, including sticky-bit collection for round-to-nearest-even.

pub mod adder;
pub mod baseline;
pub mod exact;
pub mod kernel;
pub mod normalize;
pub mod online;
pub mod operator;
pub mod oracle;
pub mod simd;
pub mod tree;
pub mod wide;

use crate::formats::FpFormat;
#[allow(deprecated)]
pub use kernel::ReduceBackend;
pub use wide::WideInt;

/// Proof ceiling for the static verifier (`crate::analysis`): every width
/// bound derived there covers reductions of up to `2^PROVED_TERMS_LOG2`
/// terms per accumulator. 15 matches the carry headroom the `narrow`
/// predicates reserve (15 term bits + 1 sign bit inside the 16-bit
/// margin of [`AccSpec::exact`]), and sits far above any in-tree workload
/// (benches top out at 2^12 terms per reduction).
pub const PROVED_TERMS_LOG2: u32 = 15;

/// Per-term signed-significand magnitude bound shared by every datapath:
/// `|signed_sig| < 2^SIG_BOUND_BITS` for all supported formats (FP32's
/// 24-bit significand plus sign is the widest). The EIA fast-lane ingest
/// ([`crate::accum::ExpBins::bank`]) and the analyzer's carry derivations
/// both build on this single constant.
pub const SIG_BOUND_BITS: u32 = 25;

/// Accumulator datapath geometry: how many fractional extension bits `f`
/// sit below the significand when a term is loaded.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct AccSpec {
    /// Fractional extension (guard) bits below the loaded significand.
    pub f: u32,
    /// True when `f` covers the worst-case alignment distance, i.e. no
    /// shift can ever discard a nonzero bit (used for debug assertions).
    pub exact: bool,
    /// True when every accumulator value provably fits in an `i128`
    /// (significand + guard + carry headroom ≤ 120 bits) — enables the
    /// narrow fast path in the `⊙` operators (§Perf).
    pub narrow: bool,
}

impl AccSpec {
    /// A datapath wide enough that alignment never discards bits; in this
    /// mode all algorithms in this crate agree bit-exactly and the rounded
    /// result is the correctly-rounded sum of the inputs.
    pub fn exact(format: FpFormat) -> Self {
        // Alignment-distance bound under gradual underflow: every nonzero
        // term enters the λ domain at its *effective* exponent
        // ([`crate::formats::Fp::eff_exp`]), which is pinned to 1 for
        // subnormals — raw exponent 0 never participates. λ therefore
        // ranges over [1, max_normal_exp] exactly as it did under FTZ, the
        // worst-case alignment distance is max_normal_exp − 1, and
        // f = exp_range = max_normal_exp keeps one bit of margin: a
        // subnormal leaf (LSB at bit f) aligned across the whole range
        // still has its lowest live bit at f − (max_normal_exp − 1) ≥ 1.
        let f = format.exp_range();
        AccSpec { f, exact: true, narrow: f + format.sig_bits() + 16 <= 120 }
    }

    /// A hardware-realistic datapath with `guard` extension bits and sticky
    /// collection; mirrors the fixed-width alignment networks of real fused
    /// multi-term adders.
    pub fn truncated(guard: u32) -> Self {
        // Narrow bound: max significand (25 bits incl. sign) + guard +
        // carry headroom for ≤ 4096 terms (12 bits) must fit i128.
        AccSpec { f: guard, exact: false, narrow: guard + 25 + 12 + 1 <= 120 }
    }

    /// Default truncated geometry used by the hardware models: enough guard
    /// for faithful rounding of an N-term sum (significand + log2(N) + 3).
    pub fn hw_default(format: FpFormat, n_terms: usize) -> Self {
        let log_n = usize::BITS - (n_terms.max(2) - 1).leading_zeros();
        AccSpec::truncated(format.sig_bits() + log_n + 3)
    }

    /// Total accumulator bits needed for `n_terms` of `format` (significand,
    /// sign, carry headroom and the `f` extension), as the hardware model
    /// sees it.
    ///
    /// Gradual underflow does not widen this window: subnormal operands
    /// have a *smaller* significand magnitude (hidden bit 0) at the same
    /// effective exponent 1 a minimal normal occupies, so both the
    /// alignment range `f` covers and the per-term magnitude bound are
    /// unchanged from the FTZ datapath.
    pub fn acc_width(&self, format: FpFormat, n_terms: usize) -> u32 {
        let log_n = usize::BITS - (n_terms.max(2) - 1).leading_zeros();
        format.sig_bits() + 1 + log_n + 1 + self.f
    }

    /// Accumulator bits this geometry is *proved* to need at the analyzer's
    /// term ceiling: the [`SIG_BOUND_BITS`] per-term magnitude lifted by `f`
    /// guard bits, [`PROVED_TERMS_LOG2`] carry bits, and one sign bit. This
    /// is the bound the registry publishes as `Capabilities::proved_acc_bits`
    /// and the `analysis` tier checks against [`Self::storage_width`].
    pub fn proved_width(&self) -> u32 {
        self.f + SIG_BOUND_BITS + PROVED_TERMS_LOG2 + 1
    }

    /// Width of the storage lane the `⊙` operators actually use for this
    /// geometry: the `i128` narrow fast path when [`Self::narrow`], the full
    /// [`WideInt`] otherwise.
    pub fn storage_width(&self) -> u32 {
        if self.narrow {
            128
        } else {
            wide::WIDE_BITS as u32
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::{BF16, FP32};

    #[test]
    fn exact_spec_covers_alignment_range() {
        let s = AccSpec::exact(FP32);
        assert!(s.f as i32 >= FP32.max_normal_exp() - 1);
        assert!(s.exact);
        // And stays comfortably inside the WideInt capacity for 64 terms.
        assert!(s.acc_width(FP32, 64) < wide::WIDE_BITS as u32);
    }

    #[test]
    fn hw_default_guard_scales_with_terms() {
        let s16 = AccSpec::hw_default(BF16, 16);
        let s64 = AccSpec::hw_default(BF16, 64);
        assert!(s64.f > s16.f);
        assert!(!s16.exact);
    }
}
