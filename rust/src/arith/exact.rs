//! Independent golden reference: a Kulisch-style exact fixed-point
//! accumulator over the format's *entire* exponent range.
//!
//! Unlike the λ-frame algorithms (baseline / online / trees), this path
//! never aligns anything: every term lands at its absolute position
//! `m · 2^e` in one global window, so the sum is exact by construction and
//! independent of term order. It cross-checks the other algorithms in the
//! tests and serves as the oracle for the correctly-rounded result.
//! (Kulisch accumulation is the "map FP to fixed-point" alternative the
//! paper's §II contrasts against — refs [15][16].)

use super::normalize::normalize_round;
use super::operator::AlignAcc;
use super::{AccSpec, WideInt};
use crate::formats::{Fp, FpClass, FpFormat};

/// Exact sum of finite terms in a global fixed-point window.
///
/// The returned state uses the frame `λ = f = exp_range`, in which a term
/// with effective exponent `e` ([`Fp::eff_exp`]: the raw exponent for
/// normals, 1 for subnormals) contributes `m << e` — no data-dependent
/// shifts, no bit ever dropped. Because every finite value is an integer
/// multiple of the subnormal LSB `2^(1-bias-mbits)` (= bit 1 of this
/// window), bit 0 of the accumulator is always clear and sums that land in
/// the subnormal range are exact.
pub fn exact_sum(terms: &[Fp], fmt: FpFormat) -> AlignAcc {
    let k = fmt.exp_range() as i32; // frame constant: λ = f = k
    let mut acc = WideInt::ZERO;
    for t in terms {
        debug_assert!(t.is_finite());
        if t.class() == FpClass::Zero {
            continue;
        }
        let m = WideInt::from_i64(t.signed_sig());
        acc = acc.add(&m.shl(t.eff_exp() as u32));
    }
    AlignAcc { lambda: k, acc, sticky: false }
}

/// The correctly-rounded (RNE) sum of finite terms in `fmt` — the oracle
/// every adder configuration is validated against.
pub fn exact_rounded_sum(terms: &[Fp], fmt: FpFormat) -> Fp {
    let k = fmt.exp_range();
    let state = exact_sum(terms, fmt);
    normalize_round(&state, AccSpec { f: k, exact: true, narrow: false }, fmt)
}

#[cfg(test)]
mod tests {
    use super::super::baseline::baseline_sum;
    use super::super::normalize::normalize_round;
    use super::*;
    use crate::formats::{BF16, FP8_E4M3, FP8_E5M2, FP8_E6M1};
    use crate::util::prng::XorShift;

    #[test]
    fn exact_sum_is_order_independent() {
        let mut rng = XorShift::new(0xE0);
        for _ in 0..50 {
            let mut ts: Vec<Fp> = (0..32).map(|_| rng.gen_fp_normal(BF16)).collect();
            let a = exact_sum(&ts, BF16);
            rng.shuffle(&mut ts);
            assert_eq!(exact_sum(&ts, BF16), a);
        }
    }

    #[test]
    fn matches_lambda_frame_baseline_after_rounding() {
        let mut rng = XorShift::new(0xE1);
        for fmt in [BF16, FP8_E5M2, FP8_E6M1] {
            let spec = AccSpec::exact(fmt);
            for _ in 0..200 {
                let ts: Vec<Fp> = (0..16).map(|_| rng.gen_fp_normal(fmt)).collect();
                let via_baseline = normalize_round(&baseline_sum(&ts, spec), spec, fmt);
                let via_exact = exact_rounded_sum(&ts, fmt);
                assert_eq!(via_baseline.bits, via_exact.bits, "{fmt}");
            }
        }
    }

    #[test]
    fn matches_independent_i128_kulisch_for_fp8() {
        // Third opinion: for 8-bit formats the whole window fits i128, so a
        // trivially-simple independent implementation can confirm both.
        let mut rng = XorShift::new(0xE2);
        for fmt in [FP8_E4M3, FP8_E5M2, FP8_E6M1] {
            for _ in 0..500 {
                let ts: Vec<Fp> = (0..64).map(|_| rng.gen_fp_normal(fmt)).collect();
                let mut acc: i128 = 0;
                for t in &ts {
                    acc += (t.signed_sig() as i128) << t.eff_exp();
                }
                let state = exact_sum(&ts, fmt);
                assert_eq!(state.acc.to_i128(), acc, "{fmt}");
            }
        }
    }
}
