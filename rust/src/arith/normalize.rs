//! Step 4 of Algorithm 1: normalize and round the aligned sum back into the
//! input format (leading-zero count, round-to-nearest-even, overflow /
//! underflow handling).
//!
//! This stage is *shared verbatim* by the baseline and all proposed designs
//! (paper §IV-A: "Normalization and rounding are the same for all designs
//! under comparison"), which is why the hardware models reuse a single
//! normalize/round netlist as well.

use super::operator::AlignAcc;
use super::{AccSpec, WideInt};
use crate::formats::{Fp, FpFormat, SpecialsMode};

/// Normalize and round an alignment-and-addition result to `fmt` (RNE).
///
/// Semantics notes:
/// * exact cancellation yields `+0` (IEEE default-rounding sign rule);
/// * results below the normal range **denormalize gradually**: the
///   mantissa is extracted at the fixed subnormal LSB `2^(1-bias-mbits)`
///   and rounded (RNE) there, instead of flushing to zero — in
///   [`AccSpec::exact`] mode such results are in fact always exact, since
///   every term is an integer multiple of the subnormal LSB;
/// * overflow saturates per the format's [`SpecialsMode`];
/// * in truncated mode the sticky flag is applied **sign-aware**: the
///   alignment shifts floor in two's complement, so a *negative*
///   accumulator with `k` bit-dropping operands stores a magnitude that
///   over-estimates the true magnitude by ε ∈ (0, k) accumulator LSBs.
///   Rounding that raw magnitude moves *away* from the infinitely-precise
///   result whenever the guard bit reads 1 only because of the
///   over-estimate; subtracting one LSB from the magnitude first (sticky
///   still set) turns the common single-drop case (ε < 1) back into an
///   exact floor-with-remainder in sign-magnitude form, so guard/sticky
///   RNE below rounds it faithfully. With several dropping operands the
///   residual over-estimate is < (k−1) LSB — the same order as the
///   truncated datapath's inherent alignment error, absorbed by the guard
///   bits of the hw-default geometry ([`AccSpec::hw_default`]); the
///   differential oracle tracks the observed worst-case ULP deviation.
///   Exact specs never set sticky and are unaffected (the result is
///   correctly rounded).
pub fn normalize_round(state: &AlignAcc, spec: AccSpec, fmt: FpFormat) -> Fp {
    if state.acc.is_zero() {
        // True zero or a totally-cancelled sum; a sticky-only residue is
        // below every representable magnitude and rounds to zero too.
        return Fp::zero(fmt);
    }
    let sign = state.acc.is_negative();
    let mut mag = state.acc.abs();
    if sign && state.sticky {
        // Sign-aware sticky correction (see doc comment above): true value
        // = acc + ε with ε ∈ (0, 1) LSB, so |true| = |acc| − ε. Work on
        // |acc| − 1 with sticky kept set: a floor of the true magnitude.
        mag = mag.wrapping_add(&WideInt::from_i64(-1));
        if mag.is_zero() {
            // |true sum| < 1 accumulator LSB: rounds to the signed zero.
            return Fp::pack(sign, 0, 0, fmt);
        }
    }
    let p = mag.abs_msb().expect("nonzero accumulator") as i64;

    // Value = mag · 2^(λ − bias − mbits − f); leading one at position p
    // means result raw exponent r = λ + p − mbits − f.
    let mbits = fmt.mbits as i64;
    let mut r = state.lambda as i64 + p - mbits - spec.f as i64;

    if r <= 0 {
        // Gradual underflow: the leading one sits at or below the top of
        // the subnormal window [2^(1-bias-mbits), 2^(1-bias)). Subnormal
        // mantissa bit k (k = 0 the LSB, weight 2^(1-bias-mbits+k)) is
        // accumulator bit f + 1 − λ + k in this frame.
        let lo = spec.f as i64 + 1 - state.lambda as i64;
        let mut mant = mag.abs_extract(lo, fmt.mbits);
        let guard = mag.abs_bit(lo - 1);
        let sticky = mag.abs_any_below(lo - 1) || state.sticky;
        if guard && (sticky || (mant & 1) == 1) {
            mant += 1;
            if mant == (1u64 << fmt.mbits) {
                // Rounded up into the smallest normal 1.0 · 2^(1-bias).
                return Fp::pack(sign, 1, 0, fmt);
            }
        }
        return Fp::pack(sign, 0, mant, fmt);
    }

    // Normal range: extract mantissa (mbits bits below the leading one),
    // guard and sticky, then round to nearest, ties to even.
    let lo = p - mbits;
    let mut mant = mag.abs_extract(lo, fmt.mbits);
    let guard = mag.abs_bit(lo - 1);
    let sticky = mag.abs_any_below(lo - 1) || state.sticky;
    if guard && (sticky || (mant & 1) == 1) {
        mant += 1;
        if mant == (1u64 << fmt.mbits) {
            mant = 0;
            r += 1;
        }
    }

    if r > fmt.max_normal_exp() as i64
        || (r == fmt.max_normal_exp() as i64
            && fmt.specials == SpecialsMode::NoInf
            && mant > fmt.max_finite_mant())
    {
        return Fp::overflow(sign, fmt);
    }
    Fp::pack(sign, r as i32, mant, fmt)
}

#[allow(clippy::disallowed_methods)] // f64 reference sums (clippy.toml)
#[cfg(test)]
mod tests {
    use super::super::baseline::baseline_sum;
    use super::*;
    use crate::formats::{FpClass, BF16, FP32, FP8_E4M3};

    fn add_bf16(xs: &[f64]) -> Fp {
        let ts: Vec<Fp> = xs.iter().map(|&x| Fp::from_f64(x, BF16)).collect();
        let spec = AccSpec::exact(BF16);
        normalize_round(&baseline_sum(&ts, spec), spec, BF16)
    }

    #[test]
    fn simple_exact_sums() {
        assert_eq!(add_bf16(&[1.0, 2.0, 3.0]).to_f64(), 6.0);
        assert_eq!(add_bf16(&[0.5, 0.25]).to_f64(), 0.75);
        assert_eq!(add_bf16(&[100.0, -100.0]).to_f64(), 0.0);
        assert_eq!(add_bf16(&[-1.0, -2.0]).to_f64(), -3.0);
    }

    #[test]
    fn cancellation_yields_positive_zero() {
        let r = add_bf16(&[5.0, -5.0]);
        assert_eq!(r.class(), FpClass::Zero);
        assert!(!r.sign());
    }

    #[test]
    fn rne_on_aligned_sum() {
        // BF16: 1.0 has 7-bit mantissa; adding 2^-9 twice gives 1 + 2^-8,
        // exactly halfway -> ties to even -> 1.0.
        let r = add_bf16(&[1.0, 0.001953125, 0.001953125]);
        assert_eq!(r.to_f64(), 1.0);
        // Adding 2^-9 three times crosses the tie -> rounds up.
        let r = add_bf16(&[1.0, 0.001953125, 0.001953125, 0.001953125]);
        assert_eq!(r.to_f64(), 1.0 + 1.0 / 128.0);
    }

    #[test]
    fn carry_propagation_renormalizes() {
        // 1.9921875 = largest BF16 mantissa at exponent 0; +ulp/2 rounds to 2.0.
        let r = add_bf16(&[1.9921875, 0.00390625]);
        assert_eq!(r.to_f64(), 2.0);
    }

    #[test]
    fn overflow_saturates_per_format() {
        // BF16 (IEEE): overflow -> Inf.
        let big = 3.0e38;
        let ts: Vec<Fp> = (0..4).map(|_| Fp::from_f64(big, BF16)).collect();
        let spec = AccSpec::exact(BF16);
        let r = normalize_round(&baseline_sum(&ts, spec), spec, BF16);
        assert_eq!(r.class(), FpClass::Inf);
        assert!(!r.sign());
        // e4m3 (NoInf): overflow -> ±448 (max finite).
        let ts: Vec<Fp> = (0..4).map(|_| Fp::from_f64(-448.0, FP8_E4M3)).collect();
        let spec = AccSpec::exact(FP8_E4M3);
        let r = normalize_round(&baseline_sum(&ts, spec), spec, FP8_E4M3);
        assert_eq!(r.to_f64(), -448.0);
    }

    #[test]
    fn underflow_denormalizes_gradually() {
        // Two minimal normals of opposite sign at distance: the result
        // -0.5·2^-126 is exactly the subnormal with the top mantissa bit.
        let tiny = Fp::pack(false, 1, 0, FP32); // 2^-126
        let tiny_neg_half = Fp::pack(true, 1, 1 << 22, FP32); // -1.5 * 2^-126
        let spec = AccSpec::exact(FP32);
        let r = normalize_round(&baseline_sum(&[tiny, tiny_neg_half], spec), spec, FP32);
        assert_eq!(r.class(), FpClass::Subnormal);
        assert!(r.sign());
        assert_eq!((r.raw_exp(), r.mant()), (0, 1 << 22), "-0.5·2^-126 exactly");
        assert_eq!(r.to_f64() as f32, -(0.5 * f32::MIN_POSITIVE as f64) as f32);
    }

    #[test]
    fn subnormal_inputs_sum_exactly() {
        // Sum of subnormals staying subnormal, and crossing up into the
        // normal range — both exact under gradual underflow.
        let spec = AccSpec::exact(FP32);
        let s1 = Fp::pack(false, 0, 3, FP32); // 3·2^-149
        let s2 = Fp::pack(false, 0, 5, FP32); // 5·2^-149
        let r = normalize_round(&baseline_sum(&[s1, s2], spec), spec, FP32);
        assert_eq!((r.class(), r.raw_exp(), r.mant()), (FpClass::Subnormal, 0, 8));
        // Largest subnormal + smallest subnormal = smallest normal.
        let top = Fp::pack(false, 0, (1 << 23) - 1, FP32);
        let lsb = Fp::pack(false, 0, 1, FP32);
        let r = normalize_round(&baseline_sum(&[top, lsb], spec), spec, FP32);
        assert_eq!((r.class(), r.raw_exp(), r.mant()), (FpClass::Normal, 1, 0));
    }

    #[test]
    fn truncated_negative_sticky_rounds_toward_the_true_sum() {
        // Regression for the two's-complement floor bug: with guard f = 2,
        // the BF16 sum (−1.0) + (−2^-8) + (+2^-30) stores acc = −514 with
        // sticky set (the +2^-30 term shifted out entirely). The true sum
        // −(1 + 2^-8) + 2^-30 is just above the RNE midpoint −(1 + 2^-8),
        // so the correctly-rounded result is −1.0. Rounding the raw
        // magnitude 514 reads guard = 1 and sticky = 1 and rounded *up* to
        // −(1 + 2^-7) — 1 ULP in the wrong direction. The sign-aware
        // correction (|acc| − 1 = 513 with sticky) rounds to −1.0.
        let spec = AccSpec::truncated(2);
        let ts: Vec<Fp> = [-1.0, -(2f64).powi(-8), (2f64).powi(-30)]
            .iter()
            .map(|&x| Fp::from_f64(x, BF16))
            .collect();
        let state = baseline_sum(&ts, spec);
        assert!(state.sticky);
        assert_eq!(state.acc.to_i128(), -514);
        let r = normalize_round(&state, spec, BF16);
        assert_eq!(r.to_f64(), -1.0);
    }

    #[test]
    fn truncated_negative_sticky_on_power_of_two_magnitude() {
        // The correction crosses a binade: acc = −512 (= −1.0) with sticky
        // means the true value is in (−1.0, −1.0 + 2^-9·…); |acc| − 1 = 511
        // renormalizes one position down and rounds back up to −1.0 — the
        // nearest representable — rather than sticking at an unreachable
        // over-estimate.
        let spec = AccSpec::truncated(2);
        let ts: Vec<Fp> = [-1.0, (2f64).powi(-30)]
            .iter()
            .map(|&x| Fp::from_f64(x, BF16))
            .collect();
        let state = baseline_sum(&ts, spec);
        assert!(state.sticky);
        assert_eq!(state.acc.to_i128(), -512);
        let r = normalize_round(&state, spec, BF16);
        assert_eq!(r.to_f64(), -1.0);
    }

    #[test]
    fn fp32_matches_native_two_term_addition() {
        // For two-term sums in exact mode, result == native f32 addition
        // (both are correctly rounded) — including subnormal results.
        let min_sub = f32::from_bits(1);
        let cases = [
            (1.0f32, 2.5f32),
            (0.1, 0.2),
            (1e20, -1e20),
            (1e20, 3.0),
            (1.5e-38, 2.5e-38),
            (-7.25, 0.0078125),
            // Subnormal operands and/or subnormal results:
            (min_sub, min_sub),
            (f32::MIN_POSITIVE, -f32::from_bits(0x007f_ffff)),
            (1.0e-40, 2.0e-40),
            (-3.0e-39, 1.0e-39),
            (f32::MIN_POSITIVE, -0.5 * f32::MIN_POSITIVE),
        ];
        let spec = AccSpec::exact(FP32);
        for (a, b) in cases {
            let ts = [Fp::from_f64(a as f64, FP32), Fp::from_f64(b as f64, FP32)];
            let r = normalize_round(&baseline_sum(&ts, spec), spec, FP32);
            assert_eq!(
                (r.to_f64() as f32).to_bits(),
                (a + b).to_bits(),
                "{a:e} + {b:e}"
            );
        }
    }
}
