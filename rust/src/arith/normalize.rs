//! Step 4 of Algorithm 1: normalize and round the aligned sum back into the
//! input format (leading-zero count, round-to-nearest-even, overflow /
//! underflow handling).
//!
//! This stage is *shared verbatim* by the baseline and all proposed designs
//! (paper §IV-A: "Normalization and rounding are the same for all designs
//! under comparison"), which is why the hardware models reuse a single
//! normalize/round netlist as well.

use super::operator::AlignAcc;
use super::AccSpec;
use crate::formats::{Fp, FpFormat, SpecialsMode};

/// Normalize and round an alignment-and-addition result to `fmt` (RNE).
///
/// Semantics notes:
/// * exact cancellation yields `+0` (IEEE default-rounding sign rule);
/// * underflow flushes to a signed zero (FTZ, consistent with decode);
/// * overflow saturates per the format's [`SpecialsMode`];
/// * in truncated mode the sticky flag only participates in tie-breaking.
///   For a *negative* accumulator the dropped (floored) bits make the
///   stored magnitude an over-estimate of the true magnitude by < 1 LSB,
///   so rounding may differ from the infinitely-precise result by one ULP
///   in rare cases — the standard accepted behaviour of fixed-width
///   alignment datapaths (and impossible in [`AccSpec::exact`] mode, where
///   sticky is always false and the result is correctly rounded).
pub fn normalize_round(state: &AlignAcc, spec: AccSpec, fmt: FpFormat) -> Fp {
    if state.acc.is_zero() {
        // True zero or a totally-cancelled sum; sticky-only residue
        // underflows to zero under FTZ either way.
        return Fp::zero(fmt);
    }
    let sign = state.acc.is_negative();
    let p = state.acc.abs_msb().expect("nonzero accumulator") as i64;

    // Value = |acc| · 2^(λ − bias − mbits − f); leading one at position p
    // means result raw exponent r = λ + p − mbits − f.
    let mbits = fmt.mbits as i64;
    let mut r = state.lambda as i64 + p - mbits - spec.f as i64;

    // Extract mantissa (mbits bits below the leading one), guard and sticky.
    let lo = p - mbits;
    let mut mant = state.acc.abs_extract(lo, fmt.mbits);
    let guard = state.acc.abs_bit(lo - 1);
    let sticky = state.acc.abs_any_below(lo - 1) || state.sticky;

    // Round to nearest, ties to even.
    if guard && (sticky || (mant & 1) == 1) {
        mant += 1;
        if mant == (1u64 << fmt.mbits) {
            mant = 0;
            r += 1;
        }
    }

    if r <= 0 {
        // Underflow: flush to signed zero.
        return Fp::pack(sign, 0, 0, fmt);
    }
    if r > fmt.max_normal_exp() as i64
        || (r == fmt.max_normal_exp() as i64
            && fmt.specials == SpecialsMode::NoInf
            && mant > fmt.max_finite_mant())
    {
        return Fp::overflow(sign, fmt);
    }
    Fp::pack(sign, r as i32, mant, fmt)
}

#[cfg(test)]
mod tests {
    use super::super::baseline::baseline_sum;
    use super::*;
    use crate::formats::{FpClass, BF16, FP32, FP8_E4M3};

    fn add_bf16(xs: &[f64]) -> Fp {
        let ts: Vec<Fp> = xs.iter().map(|&x| Fp::from_f64(x, BF16)).collect();
        let spec = AccSpec::exact(BF16);
        normalize_round(&baseline_sum(&ts, spec), spec, BF16)
    }

    #[test]
    fn simple_exact_sums() {
        assert_eq!(add_bf16(&[1.0, 2.0, 3.0]).to_f64(), 6.0);
        assert_eq!(add_bf16(&[0.5, 0.25]).to_f64(), 0.75);
        assert_eq!(add_bf16(&[100.0, -100.0]).to_f64(), 0.0);
        assert_eq!(add_bf16(&[-1.0, -2.0]).to_f64(), -3.0);
    }

    #[test]
    fn cancellation_yields_positive_zero() {
        let r = add_bf16(&[5.0, -5.0]);
        assert_eq!(r.class(), FpClass::Zero);
        assert!(!r.sign());
    }

    #[test]
    fn rne_on_aligned_sum() {
        // BF16: 1.0 has 7-bit mantissa; adding 2^-9 twice gives 1 + 2^-8,
        // exactly halfway -> ties to even -> 1.0.
        let r = add_bf16(&[1.0, 0.001953125, 0.001953125]);
        assert_eq!(r.to_f64(), 1.0);
        // Adding 2^-9 three times crosses the tie -> rounds up.
        let r = add_bf16(&[1.0, 0.001953125, 0.001953125, 0.001953125]);
        assert_eq!(r.to_f64(), 1.0 + 1.0 / 128.0);
    }

    #[test]
    fn carry_propagation_renormalizes() {
        // 1.9921875 = largest BF16 mantissa at exponent 0; +ulp/2 rounds to 2.0.
        let r = add_bf16(&[1.9921875, 0.00390625]);
        assert_eq!(r.to_f64(), 2.0);
    }

    #[test]
    fn overflow_saturates_per_format() {
        // BF16 (IEEE): overflow -> Inf.
        let big = 3.0e38;
        let ts: Vec<Fp> = (0..4).map(|_| Fp::from_f64(big, BF16)).collect();
        let spec = AccSpec::exact(BF16);
        let r = normalize_round(&baseline_sum(&ts, spec), spec, BF16);
        assert_eq!(r.class(), FpClass::Inf);
        assert!(!r.sign());
        // e4m3 (NoInf): overflow -> ±448 (max finite).
        let ts: Vec<Fp> = (0..4).map(|_| Fp::from_f64(-448.0, FP8_E4M3)).collect();
        let spec = AccSpec::exact(FP8_E4M3);
        let r = normalize_round(&baseline_sum(&ts, spec), spec, FP8_E4M3);
        assert_eq!(r.to_f64(), -448.0);
    }

    #[test]
    fn underflow_flushes_to_zero() {
        // Two minimal normals of opposite sign at distance: result below
        // the normal range flushes to zero.
        let tiny = Fp::pack(false, 1, 0, FP32); // 2^-126
        let tiny_neg_half = Fp::pack(true, 1, 1 << 22, FP32); // -1.5 * 2^-126
        let spec = AccSpec::exact(FP32);
        let r = normalize_round(&baseline_sum(&[tiny, tiny_neg_half], spec), spec, FP32);
        assert_eq!(r.class(), FpClass::Zero);
        assert!(r.sign(), "result of -0.5*2^-126 keeps its sign through FTZ");
    }

    #[test]
    fn fp32_matches_native_two_term_addition() {
        // For two-term sums in exact mode, result == native f32 addition
        // (both are correctly rounded).
        let cases = [
            (1.0f32, 2.5f32),
            (0.1, 0.2),
            (1e20, -1e20),
            (1e20, 3.0),
            (1.5e-38, 2.5e-38),
            (-7.25, 0.0078125),
        ];
        let spec = AccSpec::exact(FP32);
        for (a, b) in cases {
            let ts = [Fp::from_f64(a as f64, FP32), Fp::from_f64(b as f64, FP32)];
            let r = normalize_round(&baseline_sum(&ts, spec), spec, FP32);
            assert_eq!(r.to_f64() as f32, a + b, "{a} + {b}");
        }
    }
}
