//! Mixed-radix trees of `⊙` operators (paper §III-C, Fig. 2, eq. 9).
//!
//! A configuration such as `8-2-2` describes a 32-term adder whose first
//! level uses radix-8 operators (32 → 4 partial states), second level
//! radix-2 (4 → 2) and third level radix-2 (2 → 1). The baseline N-term
//! adder is the single-level configuration `N` — a corner of the same
//! design space.

use super::operator::{op_combine_many, AlignAcc};
use super::AccSpec;
use crate::formats::Fp;
use std::fmt;
use std::str::FromStr;

/// A mixed-radix tree configuration: the radix of the operator used at each
/// level, leaves-first. The product of radices is the number of terms.
#[derive(Clone, PartialEq, Eq, Hash)]
pub struct RadixConfig {
    radices: Vec<u32>,
}

impl RadixConfig {
    /// Build from per-level radices (leaf level first). Every radix must be
    /// ≥ 2 and there must be at least one level.
    pub fn new(radices: Vec<u32>) -> Result<Self, String> {
        if radices.is_empty() {
            return Err("configuration needs at least one level".into());
        }
        if let Some(r) = radices.iter().find(|&&r| r < 2) {
            return Err(format!("radix {r} < 2 is not a valid operator"));
        }
        let terms: u64 = radices.iter().map(|&r| r as u64).product();
        if terms > 4096 {
            return Err(format!("configuration covers {terms} terms (> 4096)"));
        }
        Ok(RadixConfig { radices })
    }

    /// The single-level baseline configuration for `n` terms.
    pub fn baseline(n: u32) -> Self {
        RadixConfig { radices: vec![n] }
    }

    /// The full binary tree (`2-2-...-2`) for `n = 2^k` terms.
    pub fn binary(n: u32) -> Result<Self, String> {
        if !n.is_power_of_two() || n < 2 {
            return Err(format!("binary tree needs a power-of-two term count, got {n}"));
        }
        Ok(RadixConfig { radices: vec![2; n.trailing_zeros() as usize] })
    }

    /// Number of input terms the configuration covers (product of radices).
    pub fn terms(&self) -> u32 {
        self.radices.iter().product()
    }

    /// Per-level radices, leaf level first.
    pub fn radices(&self) -> &[u32] {
        &self.radices
    }

    /// Number of operator levels.
    pub fn levels(&self) -> usize {
        self.radices.len()
    }

    /// True for the single-level (baseline, Fig. 1) configuration.
    pub fn is_baseline(&self) -> bool {
        self.radices.len() == 1
    }

    /// Number of operator nodes at level `l` (0 = leaf level).
    pub fn nodes_at_level(&self, l: usize) -> u32 {
        let mut n = self.terms();
        for r in &self.radices[..=l] {
            n /= r;
        }
        n
    }
}

impl fmt::Display for RadixConfig {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let parts: Vec<String> = self.radices.iter().map(|r| r.to_string()).collect();
        f.write_str(&parts.join("-"))
    }
}

impl fmt::Debug for RadixConfig {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "RadixConfig({self})")
    }
}

impl FromStr for RadixConfig {
    type Err = String;

    /// Parse the paper's notation: `"8-2-2"`, `"4-4-2"`, `"32"`, ...
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let radices: Result<Vec<u32>, _> = s
            .split('-')
            .map(|p| p.trim().parse::<u32>().map_err(|e| format!("bad radix {p:?}: {e}")))
            .collect();
        RadixConfig::new(radices?)
    }
}

/// Evaluate a mixed-radix `⊙` tree over `terms` (finite values only;
/// specials are handled by [`crate::arith::adder`]).
///
/// `terms.len()` must equal `config.terms()` — hardware adders have a fixed
/// input width; callers pad shorter vectors with zeros
/// ([`AlignAcc::IDENTITY`] leaves), which is what the real datapath does.
pub fn tree_sum(terms: &[Fp], config: &RadixConfig, spec: AccSpec) -> AlignAcc {
    assert_eq!(
        terms.len(),
        config.terms() as usize,
        "term count must match the configuration width (pad with zeros)"
    );
    // Allocation-free fast path for hardware-sized adders (N ≤ 64): a
    // stack buffer reduced in place level by level. The per-level Vec
    // allocations dominated the profile before this — see DESIGN.md §Perf.
    if terms.len() <= 64 {
        let mut buf = [AlignAcc::IDENTITY; 64];
        for (slot, t) in buf.iter_mut().zip(terms) {
            *slot = AlignAcc::leaf(*t, spec);
        }
        return reduce_in_place(&mut buf, terms.len(), config, spec);
    }
    let mut buf: Vec<AlignAcc> = terms.iter().map(|t| AlignAcc::leaf(*t, spec)).collect();
    let live = buf.len();
    reduce_in_place(&mut buf, live, config, spec)
}

/// Level-by-level in-place reduction over pre-built leaves. (The native
/// artifact executor used to share this code path; it now reduces each row
/// as one [`crate::arith::kernel::block_state`] block, whose
/// bit-equivalence to the baseline single-level tree is by construction.)
pub(crate) fn reduce_in_place(
    buf: &mut [AlignAcc],
    mut live: usize,
    config: &RadixConfig,
    spec: AccSpec,
) -> AlignAcc {
    for &r in &config.radices {
        let r = r as usize;
        // `tree_sum` guarantees divisibility via its width assert, but
        // `runtime` and `stream` call this directly: a non-divisible level
        // would silently drop the trailing partial states from the sum.
        debug_assert_eq!(
            live % r,
            0,
            "level radix {r} does not divide {live} live states (pad with identity leaves)"
        );
        let groups = live / r;
        for g in 0..groups {
            buf[g] = op_combine_many(&buf[g * r..(g + 1) * r], spec);
        }
        live = groups;
    }
    debug_assert_eq!(live, 1);
    buf[0]
}

/// All factorizations of `n` into ordered radices ≥ 2 — the design space
/// the paper sweeps (each entry is one candidate adder architecture).
pub fn enumerate_configs(n: u32) -> Vec<RadixConfig> {
    let mut out = Vec::new();
    let mut prefix = Vec::new();
    fn rec(n: u32, prefix: &mut Vec<u32>, out: &mut Vec<RadixConfig>) {
        if n == 1 {
            if !prefix.is_empty() {
                out.push(RadixConfig { radices: prefix.clone() });
            }
            return;
        }
        for r in 2..=n {
            if n % r == 0 {
                prefix.push(r);
                rec(n / r, prefix, out);
                prefix.pop();
            }
        }
    }
    rec(n, &mut prefix, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::super::baseline::baseline_sum;
    use super::*;
    use crate::formats::{Fp, BF16, FP32, FP8_E5M2};
    use crate::util::prng::XorShift;

    #[test]
    fn parse_and_display() {
        let c: RadixConfig = "8-2-2".parse().unwrap();
        assert_eq!(c.terms(), 32);
        assert_eq!(c.to_string(), "8-2-2");
        assert_eq!(c.levels(), 3);
        assert!("8-0-2".parse::<RadixConfig>().is_err());
        assert!("".parse::<RadixConfig>().is_err());
        assert!(RadixConfig::baseline(32).is_baseline());
    }

    #[test]
    fn nodes_at_level() {
        let c: RadixConfig = "4-4-2".parse().unwrap();
        assert_eq!(c.nodes_at_level(0), 8);
        assert_eq!(c.nodes_at_level(1), 2);
        assert_eq!(c.nodes_at_level(2), 1);
    }

    #[test]
    fn enumerate_counts() {
        // Ordered factorizations of 8 into parts ≥ 2: 8, 2-4, 4-2, 2-2-2.
        let cfgs = enumerate_configs(8);
        assert_eq!(cfgs.len(), 4);
        assert!(cfgs.iter().any(|c| c.to_string() == "2-2-2"));
        // 16: 16, 2-8, 8-2, 4-4, 2-2-4, 2-4-2, 4-2-2, 2-2-2-2 = 8 configs.
        assert_eq!(enumerate_configs(16).len(), 8);
    }

    #[test]
    fn all_trees_match_baseline_bitexact_exact_mode() {
        // eq. 9 / eq. 10: any parenthesisation over the leaves is the same.
        let mut rng = XorShift::new(0x7EE5);
        for fmt in [BF16, FP32, FP8_E5M2] {
            let spec = AccSpec::exact(fmt);
            for n in [8u32, 16, 32] {
                let configs = enumerate_configs(n);
                for _ in 0..20 {
                    let ts: Vec<Fp> = (0..n).map(|_| rng.gen_fp_normal(fmt)).collect();
                    let base = baseline_sum(&ts, spec);
                    for cfg in &configs {
                        let r = tree_sum(&ts, cfg, spec);
                        assert_eq!(r, base, "cfg={cfg} fmt={fmt} n={n}");
                    }
                }
            }
        }
    }

    #[test]
    fn radix_n_config_is_the_baseline() {
        let mut rng = XorShift::new(3);
        let spec = AccSpec::truncated(6);
        for _ in 0..100 {
            let ts: Vec<Fp> = (0..16).map(|_| rng.gen_fp_normal(BF16)).collect();
            let cfg = RadixConfig::baseline(16);
            assert_eq!(tree_sum(&ts, &cfg, spec), baseline_sum(&ts, spec));
        }
    }

    #[test]
    #[should_panic(expected = "term count must match")]
    fn wrong_width_panics() {
        let spec = AccSpec::exact(BF16);
        let ts = vec![Fp::zero(BF16); 7];
        tree_sum(&ts, &RadixConfig::baseline(8), spec);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "does not divide")]
    fn reduce_in_place_rejects_non_divisible_live_count() {
        // Direct callers (runtime, stream) must pad to the config width;
        // 7 live states under a radix-4 level would silently drop 3 terms.
        use super::super::operator::AlignAcc;
        let spec = AccSpec::exact(BF16);
        let mut rng = XorShift::new(0xBAD);
        let mut buf: Vec<AlignAcc> = (0..7)
            .map(|_| AlignAcc::leaf(rng.gen_fp_normal(BF16), spec))
            .collect();
        let cfg: RadixConfig = "4-2".parse().unwrap();
        let _ = reduce_in_place(&mut buf, 7, &cfg, spec);
    }
}
