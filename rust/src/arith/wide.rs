//! `WideInt`: a fixed-width (384-bit) two's-complement integer.
//!
//! 384 bits cover the widest accumulator this crate ever needs — an exact
//! FP32 window (256-bit alignment range + 25-bit significand + carry
//! headroom for ≥ 64 terms) — while staying `Copy` and allocation-free so
//! the bit-accurate simulators can run millions of align-add operations per
//! second. Arithmetic right shifts report whether any dropped bit was
//! nonzero (the hardware *sticky* signal).

// Exact-datapath module: no native float arithmetic or lossy casts may
// appear here (see clippy.toml and DESIGN.md §Analysis). The single
// diagnostic escape hatch is `to_f64_lossy`, allowed explicitly below.
#![deny(clippy::float_arithmetic, clippy::cast_precision_loss)]

/// Number of 64-bit limbs.
pub const LIMBS: usize = 6;
/// Total width in bits.
pub const WIDE_BITS: usize = LIMBS * 64;

/// Two's-complement 384-bit integer (little-endian limbs).
#[derive(Clone, Copy, PartialEq, Eq)]
pub struct WideInt {
    pub limbs: [u64; LIMBS],
}

impl WideInt {
    pub const ZERO: WideInt = WideInt { limbs: [0; LIMBS] };

    /// Sign-extend an `i64`.
    #[inline]
    pub fn from_i64(v: i64) -> Self {
        let ext = if v < 0 { u64::MAX } else { 0 };
        let mut limbs = [ext; LIMBS];
        limbs[0] = v as u64;
        WideInt { limbs }
    }

    /// `from_i64(v) << sh` computed directly (hot path: lifting a term into
    /// the accumulator frame without a full-width shift).
    #[inline]
    pub fn from_i64_shl(v: i64, sh: u32) -> Self {
        debug_assert!((sh as usize) < WIDE_BITS);
        let ext = if v < 0 { u64::MAX } else { 0 };
        let mut limbs = [ext; LIMBS];
        let (limb_sh, bit_sh) = ((sh / 64) as usize, sh % 64);
        for l in limbs.iter_mut().take(limb_sh) {
            *l = 0;
        }
        if bit_sh == 0 {
            limbs[limb_sh] = v as u64;
        } else {
            limbs[limb_sh] = (v as u64) << bit_sh;
            if limb_sh + 1 < LIMBS {
                limbs[limb_sh + 1] = ((v >> (64 - bit_sh)) as u64) | (ext << bit_sh);
            }
        }
        let out = WideInt { limbs };
        debug_assert_eq!(out, Self::from_i64(v).shl(sh));
        out
    }

    #[inline]
    pub fn is_zero(&self) -> bool {
        self.limbs == [0; LIMBS]
    }

    #[inline]
    pub fn is_negative(&self) -> bool {
        (self.limbs[LIMBS - 1] >> 63) == 1
    }

    /// Wrapping two's-complement addition (the accumulator headroom
    /// guarantees no live overflow; a debug assertion catches misuse).
    #[inline]
    pub fn wrapping_add(&self, rhs: &WideInt) -> Self {
        let mut out = [0u64; LIMBS];
        let mut carry = 0u64;
        for i in 0..LIMBS {
            let (s1, c1) = self.limbs[i].overflowing_add(rhs.limbs[i]);
            let (s2, c2) = s1.overflowing_add(carry);
            out[i] = s2;
            carry = (c1 as u64) + (c2 as u64);
        }
        WideInt { limbs: out }
    }

    /// Addition with a debug-mode check that the signed result did not wrap.
    #[inline]
    pub fn add(&self, rhs: &WideInt) -> Self {
        let r = self.wrapping_add(rhs);
        debug_assert!(
            !(self.is_negative() == rhs.is_negative() && r.is_negative() != self.is_negative()),
            "WideInt overflow: accumulator headroom exceeded"
        );
        r
    }

    /// Two's-complement negation.
    #[inline]
    pub fn neg(&self) -> Self {
        let mut out = [0u64; LIMBS];
        let mut carry = 1u64;
        for i in 0..LIMBS {
            let (s, c) = (!self.limbs[i]).overflowing_add(carry);
            out[i] = s;
            carry = c as u64;
        }
        WideInt { limbs: out }
    }

    /// Absolute value (as the same bit width; `MIN` cannot occur given the
    /// accumulator headroom).
    #[inline]
    pub fn abs(&self) -> Self {
        if self.is_negative() {
            self.neg()
        } else {
            *self
        }
    }

    /// Logical/arithmetic left shift by `sh` bits (`sh < WIDE_BITS`).
    pub fn shl(&self, sh: u32) -> Self {
        let sh = sh as usize;
        debug_assert!(sh < WIDE_BITS);
        if sh == 0 {
            return *self;
        }
        let (limb_sh, bit_sh) = (sh / 64, sh % 64);
        let mut out = [0u64; LIMBS];
        for i in (limb_sh..LIMBS).rev() {
            let lo = self.limbs[i - limb_sh] << bit_sh;
            let hi = if bit_sh > 0 && i > limb_sh {
                self.limbs[i - limb_sh - 1] >> (64 - bit_sh)
            } else {
                0
            };
            out[i] = lo | hi;
        }
        WideInt { limbs: out }
    }

    /// Arithmetic right shift by `sh` bits, reporting whether any dropped
    /// bit was nonzero (the *sticky* signal). `sh` may exceed the width; the
    /// result is then the sign fill and sticky covers the whole value.
    pub fn shr_sticky(&self, sh: u32) -> (Self, bool) {
        if sh == 0 {
            return (*self, false);
        }
        let fill = if self.is_negative() { u64::MAX } else { 0 };
        let sh = sh as usize;
        if sh >= WIDE_BITS {
            // Everything shifts out: result is the sign fill; sticky unless
            // the value was zero (a negative value always drops set bits).
            return (WideInt { limbs: [fill; LIMBS] }, !self.is_zero());
        }
        let (limb_sh, bit_sh) = (sh / 64, sh % 64);
        // Sticky: any nonzero bit among the dropped low `sh` bits.
        let mut sticky = false;
        for i in 0..limb_sh {
            sticky |= self.limbs[i] != 0;
        }
        if bit_sh > 0 {
            sticky |= (self.limbs[limb_sh] & ((1u64 << bit_sh) - 1)) != 0;
        }
        let mut out = [fill; LIMBS];
        for i in 0..LIMBS - limb_sh {
            let lo = self.limbs[i + limb_sh] >> bit_sh;
            let hi = if bit_sh > 0 {
                let src = if i + limb_sh + 1 < LIMBS { self.limbs[i + limb_sh + 1] } else { fill };
                src << (64 - bit_sh)
            } else {
                0
            };
            out[i] = lo | hi;
        }
        (WideInt { limbs: out }, sticky)
    }

    /// Arithmetic right shift discarding the sticky signal.
    #[inline]
    pub fn shr(&self, sh: u32) -> Self {
        self.shr_sticky(sh).0
    }

    /// Position of the most significant set bit of `|self|` (0-based), or
    /// `None` if zero.
    pub fn abs_msb(&self) -> Option<u32> {
        let mag = self.abs();
        for i in (0..LIMBS).rev() {
            if mag.limbs[i] != 0 {
                return Some(i as u32 * 64 + 63 - mag.limbs[i].leading_zeros());
            }
        }
        None
    }

    /// Bit `pos` of `|self|` (0 if `pos` is out of range).
    #[inline]
    pub fn abs_bit(&self, pos: i64) -> bool {
        if pos < 0 || pos >= WIDE_BITS as i64 {
            return false;
        }
        let mag = self.abs();
        (mag.limbs[(pos / 64) as usize] >> (pos % 64)) & 1 == 1
    }

    /// True if any bit of `|self|` strictly below `pos` is set.
    pub fn abs_any_below(&self, pos: i64) -> bool {
        if pos <= 0 {
            return false;
        }
        let pos = (pos as usize).min(WIDE_BITS);
        let mag = self.abs();
        let (limb, bit) = (pos / 64, pos % 64);
        for i in 0..limb {
            if mag.limbs[i] != 0 {
                return true;
            }
        }
        if bit > 0 && limb < LIMBS && (mag.limbs[limb] & ((1u64 << bit) - 1)) != 0 {
            return true;
        }
        false
    }

    /// Extract bits `[lo, lo+len)` of `|self|` as a `u64` (`len <= 64`);
    /// out-of-range bits read as zero, negative `lo` shifts in zeros.
    pub fn abs_extract(&self, lo: i64, len: u32) -> u64 {
        debug_assert!(len <= 64);
        let mag = self.abs();
        let mut out = 0u64;
        for k in 0..len {
            let pos = lo + k as i64;
            if pos >= 0 && pos < WIDE_BITS as i64 {
                let bit = (mag.limbs[(pos / 64) as usize] >> (pos % 64)) & 1;
                out |= bit << k;
            }
        }
        out
    }

    /// Narrow load: low two limbs as `i128`. Only valid when the value is
    /// known to fit (the `AccSpec::narrow` invariant, statically proved by
    /// the `analysis` tier as obligation `acc-narrow-fit`). The sign-fill
    /// check runs in release builds too: a mis-set `AccSpec::narrow` must
    /// fail loudly instead of corrupting sums. The scan of four limbs
    /// against a broadcast fill is branch-free and cheap next to the i128
    /// arithmetic it guards.
    #[inline]
    pub fn to_i128_narrow(&self) -> i128 {
        let v = (self.limbs[0] as u128 | ((self.limbs[1] as u128) << 64)) as i128;
        let fill = if v < 0 { u64::MAX } else { 0 };
        if self.limbs[2..].iter().any(|&l| l != fill) {
            narrow_overflow();
        }
        v
    }

    /// Sign-extend an `i128` (inverse of [`Self::to_i128_narrow`]).
    #[inline]
    pub fn from_i128(v: i128) -> Self {
        let ext = if v < 0 { u64::MAX } else { 0 };
        let mut limbs = [ext; LIMBS];
        limbs[0] = v as u64;
        limbs[1] = (v >> 64) as u64;
        WideInt { limbs }
    }

    /// Lossy conversion to `i128` (asserts the value fits in debug builds).
    pub fn to_i128(&self) -> i128 {
        let lo = self.limbs[0] as u128 | ((self.limbs[1] as u128) << 64);
        let fill = if self.is_negative() { u64::MAX } else { 0 };
        debug_assert!(
            self.limbs[2..].iter().all(|&l| l == fill)
                && ((self.limbs[1] >> 63 == 1) == self.is_negative()),
            "WideInt does not fit i128"
        );
        lo as i128
    }

    /// Exact conversion to `f64` would lose bits; this returns the closest
    /// `f64` (used only for diagnostics, never for correctness decisions).
    #[allow(clippy::float_arithmetic, clippy::cast_precision_loss)]
    pub fn to_f64_lossy(&self) -> f64 {
        let neg = self.is_negative();
        let mag = self.abs();
        let mut v = 0.0f64;
        for i in (0..LIMBS).rev() {
            v = v * 1.8446744073709552e19 + mag.limbs[i] as f64;
        }
        if neg {
            -v
        } else {
            v
        }
    }
}

/// Cold panic path for [`WideInt::to_i128_narrow`]: kept out of line so the
/// release-mode invariant check stays a compare-and-branch in the hot loop.
#[cold]
#[inline(never)]
fn narrow_overflow() -> ! {
    panic!("to_i128_narrow on a value wider than i128 (AccSpec::narrow mis-set?)")
}

impl std::cmp::Ord for WideInt {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        match (self.is_negative(), other.is_negative()) {
            (true, false) => std::cmp::Ordering::Less,
            (false, true) => std::cmp::Ordering::Greater,
            _ => self.limbs.iter().rev().cmp(other.limbs.iter().rev()),
        }
    }
}

impl std::cmp::PartialOrd for WideInt {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl std::fmt::Debug for WideInt {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.is_negative() {
            write!(f, "-{:?}", self.neg())
        } else {
            write!(f, "0x")?;
            let mut started = false;
            for i in (0..LIMBS).rev() {
                if started {
                    write!(f, "{:016x}", self.limbs[i])?;
                } else if self.limbs[i] != 0 || i == 0 {
                    write!(f, "{:x}", self.limbs[i])?;
                    started = true;
                }
            }
            Ok(())
        }
    }
}

#[cfg(test)]
#[allow(clippy::float_arithmetic, clippy::cast_precision_loss, clippy::disallowed_methods)]
mod tests {
    use super::*;

    fn w(v: i64) -> WideInt {
        WideInt::from_i64(v)
    }

    #[test]
    fn add_neg_roundtrip() {
        let a = w(12345);
        let b = w(-999);
        assert_eq!(a.add(&b), w(11346));
        assert_eq!(a.neg().neg(), a);
        assert_eq!(w(-1).add(&w(1)), WideInt::ZERO);
    }

    #[test]
    fn shl_shr_inverse_when_no_drop() {
        let a = w(0x1234_5678_9abc_def0);
        for sh in [0u32, 1, 7, 63, 64, 65, 130, 200, 300] {
            let (back, sticky) = a.shl(sh).shr_sticky(sh);
            assert_eq!(back, a, "sh={sh}");
            assert!(!sticky, "sh={sh}");
        }
    }

    #[test]
    fn shr_matches_i128_semantics() {
        // Arithmetic shift (floor division) on negatives, with sticky.
        for v in [-7i64, -8, -1, 7, 8, 1, 12345, -99999] {
            for sh in [1u32, 2, 3, 5, 17] {
                let (r, sticky) = w(v).shr_sticky(sh);
                let expect = (v as i128) >> sh;
                assert_eq!(r.to_i128(), expect, "v={v} sh={sh}");
                let dropped = (v as i128) & ((1i128 << sh) - 1);
                assert_eq!(sticky, dropped != 0, "v={v} sh={sh}");
            }
        }
    }

    #[test]
    fn shift_composition_equals_single_shift() {
        // (x >> a) >> b == x >> (a+b): the property that makes incremental
        // (online) alignment shifts exact-equivalent to one-shot alignment.
        let vals = [w(-123456789), w(987654321), w(-1), w(0x7fff_ffff_ffff_ffff)];
        for v in vals {
            let big = v.shl(200);
            for (a, b) in [(3u32, 5u32), (64, 64), (1, 200), (100, 30)] {
                let (r1, s1a) = big.shr_sticky(a);
                let (r1, s1b) = r1.shr_sticky(b);
                let (r2, s2) = big.shr_sticky(a + b);
                assert_eq!(r1, r2);
                assert_eq!(s1a || s1b, s2);
            }
        }
    }

    #[test]
    fn shr_beyond_width() {
        let (r, sticky) = w(5).shr_sticky(WIDE_BITS as u32 + 10);
        assert!(r.is_zero());
        assert!(sticky);
        let (r, sticky) = w(-5).shr_sticky(WIDE_BITS as u32 + 10);
        assert_eq!(r.to_i128(), -1);
        assert!(sticky);
        let (r, sticky) = WideInt::ZERO.shr_sticky(1000);
        assert!(r.is_zero() && !sticky);
    }

    #[test]
    fn msb_and_extract() {
        let a = w(0b1011).shl(100);
        assert_eq!(a.abs_msb(), Some(103));
        assert_eq!(a.abs_extract(100, 4), 0b1011);
        assert_eq!(a.abs_extract(101, 3), 0b101);
        assert!(a.abs_any_below(101));
        assert!(!a.abs_any_below(100));
        // Negative values are measured on the magnitude.
        let b = a.neg();
        assert_eq!(b.abs_msb(), Some(103));
        assert_eq!(b.abs_extract(100, 4), 0b1011);
    }

    #[test]
    fn narrow_load_roundtrips_narrow_values() {
        for v in [0i128, 1, -1, i64::MAX as i128 + 12345, -(1i128 << 100)] {
            assert_eq!(WideInt::from_i128(v).to_i128_narrow(), v);
        }
    }

    #[test]
    #[should_panic(expected = "to_i128_narrow")]
    fn narrow_load_rejects_wide_values() {
        // A value with live bits above limb 1 violates the narrow
        // invariant and must fail loudly rather than silently truncate —
        // in release builds too (analysis obligation `acc-narrow-fit`).
        let _ = w(1).shl(200).to_i128_narrow();
    }

    #[test]
    #[should_panic(expected = "to_i128_narrow")]
    fn narrow_load_rejects_wide_negative_values() {
        // Negative wide values have non-sign-fill high limbs as well.
        let _ = w(-3).shl(200).to_i128_narrow();
    }

    #[test]
    fn ordering() {
        assert!(w(-2) < w(1));
        assert!(w(5) > w(3));
        assert!(w(-10).shl(100) < w(-10));
        assert!(w(10).shl(100) > w(10));
    }
}
