//! Batched structure-of-arrays align-and-add kernel: the hot-path
//! implementation behind the `"kernel"` entry of the reduction-backend
//! registry ([`crate::reduce::registry`], DESIGN.md §Kernel / §Reducer).
//! The [`ReduceBackend`] enum that used to be the dispatch seam survives
//! here only as a deprecated shim over [`crate::reduce::ReducePlan`].
//!
//! The scalar reference path folds terms one [`op_combine`] at a time over
//! AoS [`AlignAcc`] values — one max, one (or two) full-width shifts and a
//! wide add *per term*. This module exploits the same associativity result
//! (eq. 10) blockwise instead:
//!
//! 1. **Decode** the operands into SoA lanes `(eff_exp[], signed_sig[])`
//!    ([`decode_soa`]) — one pass, no `AlignAcc`/[`WideInt`] per term;
//! 2. **Block λ** — a branch-free max sweep finds the block-local maximum
//!    effective exponent (zero lanes are masked to λ = 0, the identity's
//!    level, so they never lift the max);
//! 3. **Align + accumulate** every lane of the block against that single λ
//!    in a tight loop ([`block_state`]): on narrow [`AccSpec`]s the whole
//!    block runs in `i128` with the dropped bits OR-folded into one sticky
//!    mask; on wide specs each lane becomes a single
//!    [`WideInt::from_i64_shl`] (net shift `f − d`, no 384-bit right-shift
//!    churn at all) whenever `d ≤ f` — which is *always* the case in exact
//!    frames;
//! 4. **Combine** the per-block `[λ; acc; sticky]` partials with the
//!    existing online operator `⊙` ([`op_combine`]).
//!
//! One block is *by construction* the radix-`block` operator
//! [`super::operator::op_combine_many`] over the same leaves — the paper's baseline (Fig. 1)
//! corner applied to the block — so a single full-width block is
//! bit-identical to `tree_sum(_, RadixConfig::baseline(n), spec)` in
//! **every** spec, and the block-then-combine pipeline is bit-identical to
//! the scalar `⊙` fold in every **exact** spec (eq. 10: all
//! parenthesisations agree when no bits drop). With `block == 1` the
//! pipeline degenerates to exactly the scalar fold, truncated specs
//! included. Truncated specs with `block > 1` compute the
//! `[block; block; …]` parenthesisation — a valid `⊙` tree, deterministic
//! and sticky-monotone, but with a different dropped-bit pattern than the
//! radix-2 fold, which is why [`ReduceBackend::Auto`] only selects the
//! kernel for exact frames and keeps the scalar fold as the truncated
//! reference.
//!
//! The kernel-equivalence battery (`tests/kernel_equivalence.rs`), the
//! differential oracle (which fuzzes the kernel through
//! [`super::adder::Architecture::Backend`] in its registry-driven
//! rotation, alongside every other architecture) and the stream
//! end-to-end oracle test pin these guarantees bit-for-bit.

// Exact-datapath module: native float arithmetic and lossy casts are
// forbidden here (clippy.toml, DESIGN.md §Analysis).
#![deny(clippy::float_arithmetic, clippy::cast_precision_loss)]

use super::operator::{op_combine, AlignAcc};
use super::{AccSpec, WideInt};
use crate::formats::Fp;
use crate::telemetry;
use std::fmt;
use std::str::FromStr;

/// Default lanes per block: big enough to amortize the per-block combine,
/// small enough to stay comfortably inside the accumulator carry headroom
/// and the L1 working set.
pub const DEFAULT_BLOCK: usize = 64;

/// Decode one term into its SoA lane: the effective exponent
/// ([`Fp::eff_exp`], masked to 0 for zero terms so they sit at the
/// identity's λ and never lift a block max) and the signed significand.
/// The single source of truth for the lane-encoding convention.
#[inline]
pub(crate) fn decode_term(t: &Fp) -> (i32, i64) {
    debug_assert!(t.is_finite(), "kernel lanes must be finite (screen specials first)");
    let s = t.signed_sig();
    // Zero lanes carry (0, 0): λ = 0 is the identity level, below every
    // live term's effective exponent (≥ 1).
    (if s == 0 { 0 } else { t.eff_exp() }, s)
}

/// Decode terms into SoA lanes via [`decode_term`]. Buffers are cleared and
/// refilled (capacity is reused).
pub fn decode_soa(terms: &[Fp], eff: &mut Vec<i32>, sig: &mut Vec<i64>) {
    eff.clear();
    sig.clear();
    eff.reserve(terms.len());
    sig.reserve(terms.len());
    for t in terms {
        let (e, s) = decode_term(t);
        eff.push(e);
        sig.push(s);
    }
}

/// Reduce one SoA block against its block-local maximum exponent.
///
/// Bit-identical to [`super::operator::op_combine_many`] over the
/// corresponding [`AlignAcc::leaf`] / identity states, in every spec: one λ for the whole
/// block, each lane aligned by its own distance, sticky OR'd across the
/// block. Lanes with `sig == 0` are identities regardless of their `eff`
/// entry (the [`crate::runtime`] field encoding relies on this).
pub fn block_state(eff: &[i32], sig: &[i64], spec: AccSpec) -> AlignAcc {
    debug_assert_eq!(eff.len(), sig.len());
    // Branch-free block-λ sweep: zero lanes are masked to the identity
    // level so an arbitrary exponent field on a dead lane cannot lift λ.
    let mut lambda = 0i32;
    for (&e, &s) in eff.iter().zip(sig) {
        let live = if s == 0 { 0 } else { e };
        lambda = lambda.max(live);
    }
    if spec.narrow {
        // Narrow fast path: the whole block in two-limb arithmetic, one
        // dropped-bit mask OR-folded across the block.
        let f = spec.f;
        let mut acc = 0i128;
        let mut dropped = 0u128;
        for (&e, &s) in eff.iter().zip(sig) {
            let m = (s as i128) << f;
            // Clamps: d ≥ 128 is pure sign fill either way (every narrow
            // magnitude sits below bit 127). The subtraction runs widened
            // to i64: dead (sig == 0) lanes carry *arbitrary* `eff`
            // entries (the runtime field encoding relies on it), and
            // `lambda - i32::MIN` overflows a bare i32 in debug builds.
            let d = (lambda as i64 - e as i64).clamp(0, 127) as u32;
            acc += m >> d;
            dropped |= (m as u128) & ((1u128 << d) - 1);
        }
        let sticky = dropped != 0;
        debug_assert!(!(spec.exact && sticky), "exact datapath must never drop bits");
        return AlignAcc { lambda, acc: WideInt::from_i128(acc), sticky };
    }
    // Wide path: `(m << f) >> d` is `m << (f − d)` whenever `d ≤ f` (shift
    // composition, no dropped bits), so each lane is one cheap
    // `from_i64_shl` + add — no full-width right shifts. Exact frames have
    // `f = exp_range ≥ d` always, so they never leave this arm.
    let f = spec.f as i64;
    let mut acc = WideInt::ZERO;
    let mut sticky = false;
    for (&e, &s) in eff.iter().zip(sig) {
        if s == 0 {
            continue;
        }
        // Widened like the narrow path so the distance arithmetic can
        // never overflow, whatever a (live) exponent field holds.
        let d = (lambda as i64 - e as i64).max(0);
        if d <= f {
            acc = acc.add(&WideInt::from_i64_shl(s, (f - d) as u32));
        } else {
            // Truncating wide frame: the net right shift runs on i128 (a
            // signed significand always fits i64), sticky from the bits it
            // drops — the same bits `(m << f).shr_sticky(d)` would report.
            // min(127) is sign-fill-equivalent past 63 for any i64 lane.
            let sh = ((d - f) as u64).min(127) as u32;
            sticky |= (s as u128) & ((1u128 << sh) - 1) != 0;
            acc = acc.add(&WideInt::from_i128((s as i128) >> sh));
        }
    }
    debug_assert!(!(spec.exact && sticky), "exact datapath must never drop bits");
    AlignAcc { lambda, acc, sticky }
}

/// The scalar reference: the serial radix-2 `⊙` fold over [`AlignAcc::leaf`]
/// states — the exact code path every consumer ran before the kernel
/// existed, kept as [`ReduceBackend::Scalar`]. This *is* the paper's online
/// recurrence (Algorithm 3), so it delegates to [`super::online::online_sum`]
/// rather than duplicating the fold.
pub fn scalar_fold(terms: &[Fp], spec: AccSpec) -> AlignAcc {
    super::online::online_sum(terms, spec)
}

/// Flush one reduction's kernel-health tallies into the telemetry hub.
/// Counts accumulate in locals during the hot loop and land here in a
/// single gated burst of relaxed adds, keeping the per-lane cost at zero
/// (the `telemetry overhead` bench series bounds the total in CI).
/// The widest block's lane count (`lanes.min(block)`) also feeds the
/// `ofa_kernel_block_lanes` histogram so the `analysis` runtime cross-check
/// can assert observed lane widths never exceed the statically proved
/// per-block carry headroom.
#[inline]
pub(crate) fn flush_kernel_health(
    lanes: usize,
    block: usize,
    blocks: u64,
    sticky_blocks: u64,
    spec: AccSpec,
) {
    if !telemetry::enabled() {
        return;
    }
    let k = &telemetry::global().kernel;
    k.block_sweeps.add(blocks);
    k.lanes.add(lanes as u64);
    if lanes > 0 {
        k.block_lanes.observe(lanes.min(block) as u64);
    }
    if spec.narrow {
        k.narrow_blocks.add(blocks);
    } else {
        k.wide_blocks.add(blocks);
    }
    k.sticky_activations.add(sticky_blocks);
}

/// Batched SoA reduction: decode once, reduce `block`-sized SoA slices with
/// [`block_state`], combine the per-block partials with `⊙`.
///
/// Bit-identical to [`scalar_fold`] in exact specs (any block size) and for
/// `block == 1` in every spec; see the module docs for the truncated
/// `block > 1` parenthesisation semantics.
///
/// `block` must be ≥ 1: the plan/parse layer
/// ([`crate::reduce::ReducePlan`], [`crate::reduce::BackendSel`]) rejects a
/// zero block with a proper error before it can reach this function, and the
/// assertion below keeps the contract loud in release builds too (a zero
/// block would silently yield empty chunks — the `analysis` tier lists this
/// as a checked invariant rather than a debug-only one).
pub fn reduce_terms(terms: &[Fp], block: usize, spec: AccSpec) -> AlignAcc {
    assert!(block >= 1, "kernel block must be >= 1 (rejected at plan build/parse)");
    if block <= DEFAULT_BLOCK {
        // Zero-allocation path for hardware-sized blocks (the default
        // geometry, any input length): decode each block into stack lanes,
        // reduce it, chain the partials with ⊙.
        let mut eff = [0i32; DEFAULT_BLOCK];
        let mut sig = [0i64; DEFAULT_BLOCK];
        let mut state = AlignAcc::IDENTITY;
        let (mut blocks, mut sticky_blocks) = (0u64, 0u64);
        for chunk in terms.chunks(block) {
            for (i, t) in chunk.iter().enumerate() {
                (eff[i], sig[i]) = decode_term(t);
            }
            let part = block_state(&eff[..chunk.len()], &sig[..chunk.len()], spec);
            blocks += 1;
            sticky_blocks += part.sticky as u64;
            state = op_combine(&state, &part, spec);
        }
        flush_kernel_health(terms.len(), block, blocks, sticky_blocks, spec);
        return state;
    }
    // Oversized blocks: one block-sized buffer pair, reused (decode_soa
    // keeps the capacity) across every block of the input.
    let mut eff = Vec::new();
    let mut sig = Vec::new();
    let mut state = AlignAcc::IDENTITY;
    let (mut blocks, mut sticky_blocks) = (0u64, 0u64);
    for chunk in terms.chunks(block) {
        decode_soa(chunk, &mut eff, &mut sig);
        let part = block_state(&eff, &sig, spec);
        blocks += 1;
        sticky_blocks += part.sticky as u64;
        state = op_combine(&state, &part, spec);
    }
    flush_kernel_health(terms.len(), block, blocks, sticky_blocks, spec);
    state
}

/// **Deprecated shim** over the [`crate::reduce`] tier: the old ad-hoc
/// backend enum, kept only so pre-refactor call sites keep compiling. It
/// lowers every operation onto the registry/plan API — use
/// [`crate::reduce::ReducePlan`] (negotiation, replacing [`Self::Auto`])
/// and [`crate::reduce::BackendSel`] (explicit registry selection)
/// directly in new code.
#[deprecated(
    since = "0.2.0",
    note = "use reduce::ReducePlan / reduce::BackendSel (the backend registry) instead"
)]
#[derive(Clone, Copy, PartialEq, Eq, Debug, Default)]
pub enum ReduceBackend {
    /// Pick per spec: the kernel for exact frames (bit-identical by
    /// eq. 10), the scalar fold for truncated frames (preserving the
    /// radix-2 dropped-bit pattern every pre-kernel consumer produced).
    #[default]
    Auto,
    /// The serial radix-2 `⊙` fold ([`scalar_fold`]) — the reference.
    Scalar,
    /// The batched SoA kernel ([`reduce_terms`]) with the given block size.
    Kernel {
        /// Lanes per block (clamped to ≥ 1).
        block: usize,
    },
    /// The exponent-indexed accumulator ([`crate::accum`]): shift-free
    /// O(1) banking per term, one reconcile-and-align drain at the end.
    /// Bit-identical to the scalar fold on exact specs; on truncated specs
    /// it is the deferred-alignment parenthesisation — bits drop only in
    /// the single drain, making the result ingest-order invariant even
    /// when truncating.
    Eia,
}

#[allow(deprecated)]
impl ReduceBackend {
    /// The kernel at the default block size.
    pub const KERNEL: ReduceBackend = ReduceBackend::Kernel { block: DEFAULT_BLOCK };

    /// Lower this shim value onto the new API: `None` means "negotiate"
    /// (the old `Auto`); otherwise a validated registry selection. A
    /// `Kernel { block: 0 }` literal — the old silently-clamped case — is
    /// now a proper error.
    pub fn selection(self) -> Result<Option<crate::reduce::BackendSel>, String> {
        use crate::reduce::BackendSel;
        Ok(match self {
            ReduceBackend::Auto => None,
            ReduceBackend::Scalar => Some(BackendSel::named("scalar")?),
            ReduceBackend::Kernel { block } => {
                Some(BackendSel::named("kernel")?.with_block(block)?)
            }
            ReduceBackend::Eia => Some(BackendSel::named("eia")?),
        })
    }

    /// Lower onto an executable [`crate::reduce::ReducePlan`]. Panics on a
    /// `Kernel { block: 0 }` literal (constructible only through this
    /// deprecated shim; the plan/parse layer rejects it with an error).
    pub fn plan(self, spec: AccSpec) -> crate::reduce::ReducePlan {
        match self.selection().expect("deprecated ReduceBackend carried an invalid block") {
            None => crate::reduce::ReducePlan::negotiate(spec),
            Some(sel) => crate::reduce::ReducePlan::with_backend(spec, sel),
        }
    }

    /// Resolve [`ReduceBackend::Auto`] against a spec; concrete backends
    /// pass through unchanged. (Shim: the negotiation now lives in
    /// [`crate::reduce::ReducePlan::negotiate`].)
    pub fn resolve(self, spec: AccSpec) -> ReduceBackend {
        match self {
            ReduceBackend::Auto => {
                // Negotiation only ever picks "kernel" (exact specs) or
                // "scalar" (truncated specs); both have legacy variants.
                let sel = crate::reduce::ReducePlan::negotiate(spec).backend();
                match (sel.name(), sel.block()) {
                    ("kernel", Some(block)) => ReduceBackend::Kernel { block },
                    ("eia", _) => ReduceBackend::Eia,
                    _ => ReduceBackend::Scalar,
                }
            }
            other => other,
        }
    }

    /// Fold `terms` into one state with this backend (lowers onto
    /// [`crate::reduce::ReducePlan::reduce`]).
    pub fn reduce(self, terms: &[Fp], spec: AccSpec) -> AlignAcc {
        self.plan(spec).reduce(terms)
    }
}

#[allow(deprecated)]
impl fmt::Display for ReduceBackend {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ReduceBackend::Auto => write!(f, "auto"),
            ReduceBackend::Scalar => write!(f, "scalar"),
            ReduceBackend::Kernel { block } => write!(f, "kernel:{block}"),
            ReduceBackend::Eia => write!(f, "eia"),
        }
    }
}

#[allow(deprecated)]
impl FromStr for ReduceBackend {
    type Err = String;

    /// Parse `"auto"` or any registry spelling
    /// ([`crate::reduce::BackendSel`]); `"kernel:0"` is rejected there.
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        if s.eq_ignore_ascii_case("auto") {
            return Ok(ReduceBackend::Auto);
        }
        let sel: crate::reduce::BackendSel = s.parse().map_err(|e: String| {
            format!("{e} (or \"auto\" for plan negotiation)")
        })?;
        match (sel.name(), sel.block()) {
            ("scalar", _) => Ok(ReduceBackend::Scalar),
            ("kernel", Some(block)) => Ok(ReduceBackend::Kernel { block }),
            ("eia", _) => Ok(ReduceBackend::Eia),
            // A backend registered after this shim froze (e.g. the planned
            // SIMD entry) has no legacy variant — misrouting it to Scalar
            // would silently run different code than requested.
            (other, _) => Err(format!(
                "backend {other:?} has no deprecated ReduceBackend variant; \
                 use reduce::BackendSel / ReducePlan directly"
            )),
        }
    }
}

#[cfg(test)]
#[allow(
    deprecated,
    clippy::float_arithmetic,
    clippy::cast_precision_loss,
    clippy::disallowed_methods
)]
mod tests {
    use super::*;
    use crate::arith::operator::op_combine_many;
    use crate::formats::{BF16, FP32, PAPER_FORMATS};
    use crate::util::prng::XorShift;

    fn mixed_terms(rng: &mut XorShift, fmt: crate::formats::FpFormat, n: usize) -> Vec<Fp> {
        (0..n)
            .map(|_| match rng.below(8) {
                0 => Fp::zero(fmt),
                1 | 2 => rng.gen_fp_subnormal(fmt),
                _ => rng.gen_fp_full(fmt),
            })
            .collect()
    }

    #[test]
    fn single_block_is_the_radix_n_operator_in_any_spec() {
        // One block == op_combine_many over the same leaves, including the
        // truncated dropped-bit pattern and a dead lane with a stray
        // exponent field.
        let mut rng = XorShift::new(0x50A);
        for fmt in PAPER_FORMATS {
            for spec in [AccSpec::exact(fmt), AccSpec::truncated(3), AccSpec::truncated(16)] {
                for _ in 0..50 {
                    let terms = mixed_terms(&mut rng, fmt, 24);
                    let leaves: Vec<AlignAcc> =
                        terms.iter().map(|t| AlignAcc::leaf(*t, spec)).collect();
                    let want = op_combine_many(&leaves, spec);
                    let mut eff = Vec::new();
                    let mut sig = Vec::new();
                    decode_soa(&terms, &mut eff, &mut sig);
                    assert_eq!(block_state(&eff, &sig, spec), want, "{fmt} {spec:?}");
                }
            }
        }
    }

    #[test]
    fn dead_lane_exponent_fields_never_lift_lambda() {
        // The runtime field encoding pads dead lanes with (e, 0) for
        // arbitrary e; they must behave as identities.
        let spec = AccSpec::truncated(16);
        let eff = [200i32, 5, 300];
        let sig = [0i64, 3, 0];
        let st = block_state(&eff, &sig, spec);
        assert_eq!(st.lambda, 5);
        assert!(!st.sticky);
        assert_eq!(st.acc, WideInt::from_i64_shl(3, spec.f));
    }

    #[test]
    fn dead_lane_extreme_exponents_do_not_overflow_the_distance() {
        // The bugfix this PR pins: the narrow path computes `lambda - e`
        // on dead lanes whose `eff` entry is arbitrary; `e = i32::MIN`
        // used to overflow the i32 subtraction in debug builds. Extreme
        // entries must be plain identities on both accumulator paths.
        for spec in [AccSpec::truncated(16), AccSpec::exact(BF16)] {
            let eff = [i32::MIN, 7, i32::MAX, i32::MIN + 1];
            let sig = [0i64, 3, 0, 0];
            let st = block_state(&eff, &sig, spec);
            assert_eq!(st.lambda, 7, "{spec:?}");
            assert!(!st.sticky, "{spec:?}");
            assert_eq!(st.acc, WideInt::from_i64_shl(3, spec.f), "{spec:?}");
        }
    }

    #[test]
    fn kernel_matches_scalar_fold_exact_all_blocks() {
        let mut rng = XorShift::new(0x5E0A);
        for fmt in [BF16, FP32] {
            let spec = AccSpec::exact(fmt);
            for n in [1usize, 5, 64, 200] {
                let terms = mixed_terms(&mut rng, fmt, n);
                let want = scalar_fold(&terms, spec);
                for block in [1usize, 3, 8, 64, n] {
                    assert_eq!(
                        reduce_terms(&terms, block, spec),
                        want,
                        "{fmt} n={n} block={block}"
                    );
                }
            }
        }
    }

    #[test]
    fn block_one_is_the_scalar_fold_even_truncated() {
        let mut rng = XorShift::new(0xB10C);
        let spec = AccSpec::truncated(4);
        for _ in 0..100 {
            let terms = mixed_terms(&mut rng, BF16, 40);
            assert_eq!(reduce_terms(&terms, 1, spec), scalar_fold(&terms, spec));
        }
    }

    #[test]
    fn wide_and_narrow_paths_agree_bit_for_bit() {
        use crate::formats::FP8_E5M2;
        let mut rng = XorShift::new(0x71DE);
        let narrow = AccSpec::exact(FP8_E5M2);
        assert!(narrow.narrow, "e5m2's exact frame fits the i128 fast path");
        let wide = AccSpec { narrow: false, ..narrow };
        for _ in 0..100 {
            let terms = mixed_terms(&mut rng, FP8_E5M2, 96);
            for block in [1usize, 8, 96] {
                assert_eq!(
                    reduce_terms(&terms, block, narrow),
                    reduce_terms(&terms, block, wide)
                );
            }
        }
    }

    #[test]
    fn truncated_wide_block_matches_radix_operator() {
        // Forces the d > f arm of the wide path (tiny guard, wide spread).
        let mut rng = XorShift::new(0xD0F);
        let spec = AccSpec { narrow: false, ..AccSpec::truncated(2) };
        for _ in 0..200 {
            let terms = mixed_terms(&mut rng, FP32, 16);
            let leaves: Vec<AlignAcc> = terms.iter().map(|t| AlignAcc::leaf(*t, spec)).collect();
            assert_eq!(block_state_from(&terms, spec), op_combine_many(&leaves, spec));
        }
    }

    fn block_state_from(terms: &[Fp], spec: AccSpec) -> AlignAcc {
        let mut eff = Vec::new();
        let mut sig = Vec::new();
        decode_soa(terms, &mut eff, &mut sig);
        block_state(&eff, &sig, spec)
    }

    #[test]
    #[should_panic(expected = "kernel block must be >= 1")]
    fn zero_block_is_rejected_in_release_builds_too() {
        // The plan/parse layer already refuses block == 0; this pins the
        // defense-in-depth assertion at the kernel entry itself (analysis
        // checked invariant, not just a debug_assert).
        let spec = AccSpec::exact(BF16);
        let _ = reduce_terms(&[Fp::zero(BF16)], 0, spec);
    }

    #[test]
    fn empty_and_all_zero_inputs_are_the_identity() {
        let spec = AccSpec::exact(BF16);
        assert!(reduce_terms(&[], 8, spec).is_identity());
        let zeros = vec![Fp::zero(BF16); 10];
        assert!(reduce_terms(&zeros, 3, spec).is_identity());
        assert!(block_state(&[0; 4], &[0; 4], spec).is_identity());
    }

    #[test]
    fn backend_parse_roundtrip_and_resolution() {
        assert_eq!("scalar".parse::<ReduceBackend>().unwrap(), ReduceBackend::Scalar);
        assert_eq!("kernel".parse::<ReduceBackend>().unwrap(), ReduceBackend::KERNEL);
        assert_eq!(
            "kernel:8".parse::<ReduceBackend>().unwrap(),
            ReduceBackend::Kernel { block: 8 }
        );
        assert_eq!("auto".parse::<ReduceBackend>().unwrap(), ReduceBackend::Auto);
        assert_eq!("eia".parse::<ReduceBackend>().unwrap(), ReduceBackend::Eia);
        assert_eq!(ReduceBackend::Eia.to_string(), "eia");
        assert!("kernel:0".parse::<ReduceBackend>().is_err());
        assert!("simd".parse::<ReduceBackend>().is_err());
        let exact = AccSpec::exact(BF16);
        assert_eq!(ReduceBackend::Auto.resolve(exact), ReduceBackend::KERNEL);
        assert_eq!(
            ReduceBackend::Auto.resolve(AccSpec::truncated(4)),
            ReduceBackend::Scalar
        );
        assert_eq!(ReduceBackend::KERNEL.to_string(), format!("kernel:{DEFAULT_BLOCK}"));
    }

    #[test]
    fn backend_reduce_agrees_across_backends_exact() {
        let mut rng = XorShift::new(0xACC0);
        let spec = AccSpec::exact(BF16);
        for _ in 0..50 {
            let terms = mixed_terms(&mut rng, BF16, 70);
            let want = ReduceBackend::Scalar.reduce(&terms, spec);
            assert_eq!(ReduceBackend::Auto.reduce(&terms, spec), want);
            assert_eq!(ReduceBackend::KERNEL.reduce(&terms, spec), want);
            assert_eq!(ReduceBackend::Kernel { block: 7 }.reduce(&terms, spec), want);
            assert_eq!(ReduceBackend::Eia.reduce(&terms, spec), want);
        }
    }

    #[test]
    fn short_and_single_term_inputs_reduce_as_one_partial_block() {
        // `len < block` takes the single-partial-block path: identical to
        // the radix-`len` operator over the same leaves in ANY spec (the
        // identity ⊙ prefix is transparent), and hence to the scalar fold
        // in exact specs. Dedicated coverage — the seam's consumers feed
        // short tails here constantly.
        let mut rng = XorShift::new(0x51E);
        for spec in [AccSpec::exact(BF16), AccSpec::truncated(3)] {
            for n in [1usize, 2, 7] {
                let terms = mixed_terms(&mut rng, BF16, n);
                let leaves: Vec<AlignAcc> =
                    terms.iter().map(|t| AlignAcc::leaf(*t, spec)).collect();
                let want = op_combine_many(&leaves, spec);
                for block in [8usize, 64, 1024] {
                    assert_eq!(
                        reduce_terms(&terms, block, spec),
                        want,
                        "n={n} block={block} {spec:?}"
                    );
                }
            }
        }
        // A single full-space term is exactly its leaf in exact mode.
        let spec = AccSpec::exact(BF16);
        for _ in 0..100 {
            let t = rng.gen_fp_full(BF16);
            assert_eq!(reduce_terms(&[t], 64, spec), AlignAcc::leaf(t, spec), "{t:?}");
        }
    }
}
