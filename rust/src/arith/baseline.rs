//! Algorithm 2 — the serial baseline: find the global maximum exponent,
//! align every fraction against it, then add (paper Fig. 1).
//!
//! This is the architecture used by the majority of multi-term adders
//! (Intel NNP-T, templatized HLS dot products, exact FDPA operators — refs
//! [10][11][12] in the paper) and the comparison target of the evaluation.

use super::operator::AlignAcc;
use super::{AccSpec, WideInt};
use crate::formats::{Fp, FpClass};

/// Serial baseline alignment-and-addition over finite terms.
///
/// Literally Algorithm 2: loop 1 computes `λ_N = max e_i`; loop 2 computes
/// `Σ m_i ≫ (λ_N − e_i)`. The two loops cannot be merged — the second
/// depends on the fully-resolved maximum — which is precisely the serial
/// dependency the paper's online formulation removes.
pub fn baseline_sum(terms: &[Fp], spec: AccSpec) -> AlignAcc {
    // Loop 1 (lines 1-3): maximum effective exponent. Zeros are skipped so
    // they contribute nothing (they must not lift λ to a subnormal's
    // effective exponent 1); subnormals participate at eff_exp() == 1 —
    // the λ-convention of [`AlignAcc::leaf`].
    let mut lambda = 0i32; // λ_0: below every live effective exponent
    for t in terms {
        debug_assert!(t.is_finite());
        if t.class() != FpClass::Zero {
            lambda = lambda.max(t.eff_exp());
        }
    }
    // Loop 2 (lines 4-7): align each fraction to λ_N and accumulate.
    if spec.narrow {
        // i128 fast path (§Perf); bit-identical to the wide path.
        let mut acc = 0i128;
        let mut sticky = false;
        for t in terms {
            if t.class() == FpClass::Zero {
                continue;
            }
            let m = (t.signed_sig() as i128) << spec.f;
            let d = ((lambda - t.eff_exp()) as u32).min(127);
            acc += m >> d;
            sticky |= (m as u128) & ((1u128 << d) - 1) != 0;
        }
        debug_assert!(!(spec.exact && sticky), "exact datapath must never drop bits");
        return AlignAcc { lambda, acc: WideInt::from_i128(acc), sticky };
    }
    let mut acc = WideInt::ZERO;
    let mut sticky = false;
    for t in terms {
        if t.class() == FpClass::Zero {
            continue;
        }
        let m = WideInt::from_i64(t.signed_sig()).shl(spec.f);
        let (am, dropped) = m.shr_sticky((lambda - t.eff_exp()) as u32);
        debug_assert!(!(spec.exact && dropped), "exact datapath must never drop bits");
        acc = acc.add(&am);
        sticky |= dropped;
    }
    AlignAcc { lambda, acc, sticky }
}

#[allow(clippy::disallowed_methods)] // f64 reference sums (clippy.toml)
#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::{Fp, BF16, FP8_E4M3};

    fn terms(xs: &[f64]) -> Vec<Fp> {
        xs.iter().map(|&x| Fp::from_f64(x, BF16)).collect()
    }

    #[test]
    fn empty_and_all_zero_sum_to_identity() {
        let spec = AccSpec::exact(BF16);
        assert!(baseline_sum(&[], spec).is_identity());
        assert!(baseline_sum(&terms(&[0.0, 0.0, -0.0]), spec).is_identity());
    }

    #[test]
    fn simple_sums() {
        let spec = AccSpec::exact(BF16);
        let r = baseline_sum(&terms(&[1.0, 2.0, 3.0]), spec);
        // λ must be the exponent of 2.0/3.0 (raw 128), acc the aligned sum.
        assert_eq!(r.lambda, 128);
        // acc·2^(λ-bias-mbits-f) = 6.0
        let val = r.acc.to_f64_lossy()
            * (2f64).powi(r.lambda - BF16.bias() - BF16.mbits as i32 - spec.f as i32);
        assert_eq!(val, 6.0);
    }

    #[test]
    fn cancellation_to_zero() {
        let spec = AccSpec::exact(BF16);
        let r = baseline_sum(&terms(&[5.0, -5.0, 12.0, -12.0]), spec);
        assert!(r.acc.is_zero());
        assert!(!r.sticky);
    }

    #[test]
    fn fp8_small_format() {
        let spec = AccSpec::exact(FP8_E4M3);
        let xs: Vec<Fp> = [0.5, 1.5, -0.25].iter().map(|&x| Fp::from_f64(x, FP8_E4M3)).collect();
        let r = baseline_sum(&xs, spec);
        let val = r.acc.to_f64_lossy()
            * (2f64).powi(r.lambda - FP8_E4M3.bias() - FP8_E4M3.mbits as i32 - spec.f as i32);
        assert_eq!(val, 1.75);
    }
}
