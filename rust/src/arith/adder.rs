//! Complete multi-term fused floating-point adders (Algorithm 1):
//! special-value screening, alignment + addition by a selectable
//! architecture, then shared normalization and rounding.
//!
//! This is the crate's main user-facing entry point for *numerics*; the
//! hardware models in [`crate::hw`] mirror the same architectures
//! structurally for area/power/delay.

use super::baseline::baseline_sum;
use super::exact::exact_sum;
use super::normalize::normalize_round;
use super::online::online_sum;
use super::operator::AlignAcc;
use super::tree::{tree_sum, RadixConfig};
use super::AccSpec;
use crate::formats::{Fp, FpClass, FpFormat};
use crate::reduce::{BackendSel, ReducePlan};

/// Which alignment-and-addition architecture to run.
#[derive(Clone, PartialEq, Eq, Debug)]
pub enum Architecture {
    /// Algorithm 2 / Fig. 1: global max exponent, then align + add.
    Baseline,
    /// Algorithm 3 / eq. 7: the online fused serial recurrence.
    Online,
    /// eq. 9 / Fig. 2: a mixed-radix tree of `⊙` operators.
    Tree(RadixConfig),
    /// The Kulisch-style exact window (order-independent golden reference).
    Exact,
    /// A registered reduction backend ([`crate::reduce::registry`]), run
    /// through the [`ReducePlan`] API: `"scalar"` (≡ [`Self::Online`]),
    /// `"kernel[:<block>]"` (the batched SoA kernel — bit-identical to the
    /// scalar fold in exact specs, the `[block; block; …]`
    /// parenthesisation when truncating) or `"eia"` (the deferred-
    /// alignment exponent-indexed accumulator). New registry entries are
    /// addressable here — and join the oracle rotation — with no enum
    /// edits.
    Backend(BackendSel),
}

impl Architecture {
    /// Parse `"baseline"`, `"online"`, `"exact"`, any registry backend
    /// spelling (`"scalar"`, `"kernel"` / `"kernel:<block>"`, `"eia"`) or
    /// a radix config (`"8-2-2"`).
    pub fn parse(s: &str, _n_terms: u32) -> Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "baseline" | "base" => Ok(Architecture::Baseline),
            "online" | "serial-online" => Ok(Architecture::Online),
            "exact" | "kulisch" => Ok(Architecture::Exact),
            other => match other.parse::<BackendSel>() {
                // One grammar for backend names: the registry's.
                Ok(sel) => Ok(Architecture::Backend(sel)),
                // A registered name with bad parameters ("kernel:0") must
                // surface its own error, not radix-config noise.
                Err(e)
                    if crate::reduce::registry::by_name(
                        other.split(':').next().unwrap_or(other),
                    )
                    .is_some() =>
                {
                    Err(e)
                }
                Err(_) => other.parse::<RadixConfig>().map(Architecture::Tree),
            },
        }
    }

    /// A registered backend architecture by its registry spelling
    /// (`"kernel:8"`, `"eia"`, …).
    pub fn backend(name: &str) -> Result<Self, String> {
        Ok(Architecture::Backend(name.parse()?))
    }
}

impl std::fmt::Display for Architecture {
    /// Canonical spelling, round-trippable through [`Architecture::parse`]
    /// (property-pinned in `tests/properties.rs`).
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Architecture::Baseline => f.write_str("baseline"),
            Architecture::Online => f.write_str("online"),
            Architecture::Exact => f.write_str("exact"),
            Architecture::Tree(cfg) => write!(f, "{cfg}"),
            Architecture::Backend(sel) => write!(f, "{sel}"),
        }
    }
}

/// A configured N-term fused adder.
#[derive(Clone, Debug)]
pub struct MultiTermAdder {
    pub format: FpFormat,
    pub n_terms: usize,
    pub spec: AccSpec,
    pub arch: Architecture,
}

impl MultiTermAdder {
    /// An adder with an exact (never-truncating) datapath.
    pub fn exact(format: FpFormat, n_terms: usize, arch: Architecture) -> Self {
        MultiTermAdder { format, n_terms, spec: AccSpec::exact(format), arch }
    }

    /// An adder with the hardware-default truncated datapath.
    pub fn hw(format: FpFormat, n_terms: usize, arch: Architecture) -> Self {
        MultiTermAdder { format, n_terms, spec: AccSpec::hw_default(format, n_terms), arch }
    }

    /// Fused multi-term addition: `S = Σ f_i`, rounded once (RNE).
    ///
    /// `terms.len()` must not exceed `n_terms`; shorter inputs are padded
    /// with zeros exactly as unused lanes of the hardware would be.
    ///
    /// Special values (screened before the datapath, as real fused adders
    /// do in their unpack stage):
    /// * any NaN input → NaN;
    /// * `+Inf` and `−Inf` both present → NaN (invalid operation);
    /// * any Inf → that Inf;
    /// * otherwise the finite datapath result.
    pub fn add(&self, terms: &[Fp]) -> Fp {
        assert!(
            terms.len() <= self.n_terms,
            "adder has {} input lanes, got {} terms",
            self.n_terms,
            terms.len()
        );
        // Unpack/screen stage.
        let mut pos_inf = false;
        let mut neg_inf = false;
        for t in terms {
            debug_assert_eq!(t.format, self.format, "term format mismatch");
            match t.class() {
                FpClass::Nan => return Fp::nan(self.format),
                FpClass::Inf => {
                    if t.sign() {
                        neg_inf = true;
                    } else {
                        pos_inf = true;
                    }
                }
                _ => {}
            }
        }
        if pos_inf && neg_inf {
            return Fp::nan(self.format);
        }
        if pos_inf || neg_inf {
            return Fp::overflow(neg_inf, self.format);
        }
        // Finite datapath: pad to the lane count and run the architecture.
        let mut lanes: Vec<Fp> = Vec::with_capacity(self.n_terms);
        lanes.extend_from_slice(terms);
        lanes.resize(self.n_terms, Fp::zero(self.format));
        let state = self.run_finite(&lanes);
        normalize_round(&state, self.effective_spec(), self.format)
    }

    /// The raw alignment-and-addition state (before normalize/round) —
    /// used by tests and by the switching-activity power model, which needs
    /// the intermediate signals.
    pub fn run_finite(&self, lanes: &[Fp]) -> AlignAcc {
        match &self.arch {
            Architecture::Baseline => baseline_sum(lanes, self.spec),
            Architecture::Online => online_sum(lanes, self.spec),
            Architecture::Tree(cfg) => tree_sum(lanes, cfg, self.spec),
            Architecture::Exact => exact_sum(lanes, self.format),
            Architecture::Backend(sel) => {
                ReducePlan::with_backend(self.spec, *sel).reduce(lanes)
            }
        }
    }

    fn effective_spec(&self) -> AccSpec {
        match self.arch {
            // The exact window uses its own frame λ = f = exp_range.
            Architecture::Exact => AccSpec { f: self.format.exp_range(), exact: true, narrow: false },
            _ => self.spec,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::exact::exact_rounded_sum;
    use crate::formats::{BF16, FP32, PAPER_FORMATS};
    use crate::util::prng::XorShift;

    #[test]
    fn special_value_rules() {
        let adder = MultiTermAdder::exact(BF16, 4, Architecture::Baseline);
        let inf = Fp::overflow(false, BF16);
        let ninf = Fp::overflow(true, BF16);
        let nan = Fp::nan(BF16);
        let one = Fp::from_f64(1.0, BF16);
        assert_eq!(adder.add(&[one, nan, one, one]).class(), FpClass::Nan);
        assert_eq!(adder.add(&[inf, ninf, one, one]).class(), FpClass::Nan);
        assert_eq!(adder.add(&[inf, one, one, one]).class(), FpClass::Inf);
        let r = adder.add(&[ninf, one, one, one]);
        assert_eq!(r.class(), FpClass::Inf);
        assert!(r.sign());
    }

    #[test]
    fn padding_with_zeros_is_transparent() {
        let adder = MultiTermAdder::exact(BF16, 16, Architecture::Online);
        let ts: Vec<Fp> = [1.0, 2.0, 3.0].iter().map(|&x| Fp::from_f64(x, BF16)).collect();
        assert_eq!(adder.add(&ts).to_f64(), 6.0);
    }

    #[test]
    fn all_architectures_agree_with_oracle_in_exact_mode() {
        let mut rng = XorShift::new(0xADD);
        for fmt in PAPER_FORMATS {
            // Hand-picked algorithm models plus every registered backend —
            // a new registry entry is covered here automatically.
            let mut archs = vec![
                Architecture::Baseline,
                Architecture::Online,
                Architecture::Exact,
                Architecture::Tree("4-4".parse().unwrap()),
                Architecture::Tree("2-2-2-2".parse().unwrap()),
                Architecture::Tree("8-2".parse().unwrap()),
            ];
            archs.extend(
                crate::reduce::registry::entries()
                    .iter()
                    .map(|e| Architecture::Backend(e.sel())),
            );
            for _ in 0..30 {
                let ts: Vec<Fp> = (0..16).map(|_| rng.gen_fp_normal(fmt)).collect();
                let oracle = exact_rounded_sum(&ts, fmt);
                for arch in &archs {
                    let adder = MultiTermAdder::exact(fmt, 16, arch.clone());
                    assert_eq!(adder.add(&ts).bits, oracle.bits, "{fmt} {arch:?}");
                }
            }
        }
    }

    #[test]
    fn truncated_datapath_stays_within_one_ulp_for_fp32_dot_products() {
        // The hw-default guard keeps results faithful (≤ 1 ulp from the
        // correctly-rounded sum) on realistic magnitudes.
        let mut rng = XorShift::new(0x0DD);
        let adder = MultiTermAdder::hw(FP32, 32, Architecture::Tree("8-2-2".parse().unwrap()));
        for _ in 0..200 {
            let ts: Vec<Fp> = (0..32).map(|_| rng.gen_fp_gauss(FP32, 10.0)).collect();
            let got = adder.add(&ts);
            let oracle = exact_rounded_sum(&ts, FP32);
            let diff = (got.bits as i64 - oracle.bits as i64).abs();
            assert!(diff <= 1, "got {got:?} oracle {oracle:?}");
        }
    }
}
