//! Differential rounding oracle: an *independent* software reference for
//! the correctly-rounded multi-term sum, plus an adversarial fuzzing
//! harness that diffs every algorithm family against it.
//!
//! The reference deliberately shares no arithmetic with the datapath
//! models: where the `⊙` algorithms track a two's-complement [`super::WideInt`]
//! in a λ-aligned frame, the reference decodes raw bit patterns itself,
//! accumulates positive and negative magnitudes in two unsigned big-integer
//! windows (sign-magnitude, limb arithmetic written from scratch), takes
//! one exact difference, and re-derives RNE rounding — gradual underflow,
//! normal range and overflow — from first principles. Agreement between two
//! structurally different implementations is the evidence the differential
//! test provides; a bug must be introduced twice, in two representations,
//! to slip through.
//!
//! [`run_oracle`] fuzzes adversarial operand distributions (uniform
//! full-range, subnormal-dense, cancellation-heavy, mixed-sign
//! near-overflow) through baseline / online / Kulisch / SoA-kernel /
//! exponent-indexed-accumulator / mixed-radix-tree architectures under
//! exact [`AccSpec`]s (narrow and wide paths) and
//! reports every bit mismatch, plus a faithfulness bound for the
//! hardware-default truncated datapath. The `repro oracle` CLI subcommand
//! and `tests/oracle_differential.rs` drive it; see DESIGN.md §Oracle.

use super::adder::{Architecture, MultiTermAdder};
use super::tree::enumerate_configs;
use super::AccSpec;
use crate::formats::{Fp, FpClass, FpFormat, SpecialsMode};
use crate::util::prng::XorShift;
use std::cmp::Ordering;

/// Limbs of the reference magnitude window. 512 bits cover the widest
/// format window (FP32: effective exponent ≤ 254 plus a 24-bit significand
/// is < 2^279 per term, < 2^291 for 4096 terms) with ample slack.
const REF_LIMBS: usize = 8;

/// An unsigned little-endian magnitude in the global fixed-point window
/// `value = mag · 2^(-bias - mbits)`.
type Mag = [u64; REF_LIMBS];

/// `mag += m << sh` (with `m < 2^25`, `sh < 7·64`); carries propagate.
fn mag_add_shifted(mag: &mut Mag, m: u64, sh: u32) {
    debug_assert!((sh as usize) < (REF_LIMBS - 1) * 64);
    let (limb, bit) = ((sh / 64) as usize, sh % 64);
    let lo = m << bit;
    let hi = if bit == 0 { 0 } else { m >> (64 - bit) };
    let (s, c) = mag[limb].overflowing_add(lo);
    mag[limb] = s;
    let mut carry = c as u64;
    let mut add = hi;
    let mut i = limb + 1;
    while (carry > 0 || add > 0) && i < REF_LIMBS {
        let (s1, c1) = mag[i].overflowing_add(add);
        let (s2, c2) = s1.overflowing_add(carry);
        mag[i] = s2;
        carry = (c1 as u64) + (c2 as u64);
        add = 0;
        i += 1;
    }
    debug_assert!(carry == 0, "reference window overflow");
}

fn mag_cmp(a: &Mag, b: &Mag) -> Ordering {
    for i in (0..REF_LIMBS).rev() {
        if a[i] != b[i] {
            return a[i].cmp(&b[i]);
        }
    }
    Ordering::Equal
}

/// `a - b`; requires `a >= b`.
fn mag_sub(a: &Mag, b: &Mag) -> Mag {
    let mut out = [0u64; REF_LIMBS];
    let mut borrow = 0u64;
    for i in 0..REF_LIMBS {
        let (d1, b1) = a[i].overflowing_sub(b[i]);
        let (d2, b2) = d1.overflowing_sub(borrow);
        out[i] = d2;
        borrow = (b1 as u64) + (b2 as u64);
    }
    debug_assert_eq!(borrow, 0, "mag_sub requires a >= b");
    out
}

/// Position of the most significant set bit, or `None` if zero.
fn mag_msb(mag: &Mag) -> Option<i64> {
    for i in (0..REF_LIMBS).rev() {
        if mag[i] != 0 {
            return Some(i as i64 * 64 + 63 - mag[i].leading_zeros() as i64);
        }
    }
    None
}

/// Bit `pos` (0 when out of range, including negative positions).
fn mag_bit(mag: &Mag, pos: i64) -> bool {
    if pos < 0 || pos >= (REF_LIMBS * 64) as i64 {
        return false;
    }
    (mag[(pos / 64) as usize] >> (pos % 64)) & 1 == 1
}

/// Any set bit strictly below `pos`.
fn mag_any_below(mag: &Mag, pos: i64) -> bool {
    if pos <= 0 {
        return false;
    }
    let pos = (pos as usize).min(REF_LIMBS * 64);
    let (limb, bit) = (pos / 64, pos % 64);
    if mag[..limb].iter().any(|&l| l != 0) {
        return true;
    }
    bit > 0 && limb < REF_LIMBS && (mag[limb] & ((1u64 << bit) - 1)) != 0
}

/// Bits `[lo, lo+len)` as a `u64` (`len <= 64`); out-of-range bits read 0.
fn mag_extract(mag: &Mag, lo: i64, len: u32) -> u64 {
    debug_assert!(len <= 64);
    let mut out = 0u64;
    for k in 0..len {
        if mag_bit(mag, lo + k as i64) {
            out |= 1u64 << k;
        }
    }
    out
}

/// Round a sign-magnitude window value to `fmt` (RNE, gradual underflow,
/// overflow per [`SpecialsMode`]). Written independently of
/// [`super::normalize::normalize_round`].
fn ref_round(sign: bool, mag: &Mag, fmt: FpFormat) -> Fp {
    let Some(p) = mag_msb(mag) else {
        return Fp::zero(fmt);
    };
    let mbits = fmt.mbits as i64;
    let (mut r, mut mant, guard, sticky) = if p - mbits >= 1 {
        // Normal window: mantissa below the leading one.
        (
            p - mbits,
            mag_extract(mag, p - mbits, fmt.mbits),
            mag_bit(mag, p - mbits - 1),
            mag_any_below(mag, p - mbits - 1),
        )
    } else {
        // Subnormal window: the mantissa LSB 2^(1-bias-mbits) is bit 1 of
        // the global frame. (Bit 0 is provably always clear — every term
        // is an integer multiple of the subnormal LSB — so subnormal
        // results are exact; the guard bit is still read for robustness.)
        (0, mag_extract(mag, 1, fmt.mbits), mag_bit(mag, 0), false)
    };
    if guard && (sticky || (mant & 1) == 1) {
        mant += 1;
        if mant == (1u64 << fmt.mbits) {
            mant = 0;
            r += 1;
        }
    }
    if r > fmt.max_normal_exp() as i64
        || (r == fmt.max_normal_exp() as i64
            && fmt.specials == SpecialsMode::NoInf
            && mant > fmt.max_finite_mant())
    {
        return Fp::overflow(sign, fmt);
    }
    Fp::pack(sign, r as i32, mant, fmt)
}

/// The ground-truth correctly-rounded sum of finite terms: exact
/// sign-magnitude accumulation over the whole exponent range, then one RNE
/// rounding. Decodes raw bit patterns directly (no shared decode helpers).
pub fn reference_sum(terms: &[Fp], fmt: FpFormat) -> Fp {
    let mut pos = [0u64; REF_LIMBS];
    let mut neg = [0u64; REF_LIMBS];
    for t in terms {
        debug_assert_eq!(t.format, fmt, "term format mismatch");
        debug_assert!(t.is_finite(), "reference_sum takes finite terms only");
        let w = t.format;
        let sign = (t.bits >> (w.ebits + w.mbits)) & 1 == 1;
        let e = ((t.bits >> w.mbits) & w.exp_mask()) as u32;
        let m = t.bits & w.mant_mask();
        // Gradual underflow: raw exponent 0 means effective exponent 1
        // with no hidden bit.
        let (sig, eff) = if e == 0 { (m, 1) } else { (m | (1u64 << w.mbits), e) };
        if sig == 0 {
            continue; // ±0 contributes nothing
        }
        mag_add_shifted(if sign { &mut neg } else { &mut pos }, sig, eff);
    }
    match mag_cmp(&pos, &neg) {
        Ordering::Greater => ref_round(false, &mag_sub(&pos, &neg), fmt),
        Ordering::Less => ref_round(true, &mag_sub(&neg, &pos), fmt),
        // Exact cancellation rounds to +0 (IEEE default-rounding rule).
        Ordering::Equal => Fp::zero(fmt),
    }
}

/// Adversarial operand distributions the oracle fuzzes.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Distribution {
    /// Uniform over every finite bit pattern (zeros, subnormals, normals).
    Uniform,
    /// Mostly subnormals plus small normals hugging the underflow boundary.
    SubnormalDense,
    /// Pairs `x, -x ± 1 ulp`: heavy cancellation, residues deep below the
    /// operand magnitudes (often subnormal).
    Cancellation,
    /// Mixed-sign values within two binades of the overflow boundary.
    NearOverflow,
}

/// All distributions, in fuzzing rotation order.
pub const DISTRIBUTIONS: [Distribution; 4] = [
    Distribution::Uniform,
    Distribution::SubnormalDense,
    Distribution::Cancellation,
    Distribution::NearOverflow,
];

impl Distribution {
    pub fn name(self) -> &'static str {
        match self {
            Distribution::Uniform => "uniform",
            Distribution::SubnormalDense => "subnormal-dense",
            Distribution::Cancellation => "cancellation",
            Distribution::NearOverflow => "near-overflow",
        }
    }

    /// One fuzzed operand vector of `n` finite terms.
    pub fn gen_vector(self, rng: &mut XorShift, fmt: FpFormat, n: usize) -> Vec<Fp> {
        match self {
            Distribution::Uniform => (0..n).map(|_| rng.gen_fp_full(fmt)).collect(),
            Distribution::SubnormalDense => (0..n)
                .map(|_| {
                    if rng.below(10) < 7 {
                        rng.gen_fp_subnormal(fmt)
                    } else {
                        let hi = (fmt.max_normal_exp() as i64).min(3);
                        let e = rng.range_i64(1, hi) as i32;
                        let m = rng.next_u64() & fmt.mant_mask();
                        Fp::pack(rng.below(2) == 1, e, m, fmt)
                    }
                })
                .collect(),
            Distribution::Cancellation => {
                let sign_bit = 1u64 << (fmt.width() - 1);
                let top = ((fmt.max_normal_exp() as u64) << fmt.mbits) | fmt.max_finite_mant();
                let mut out = Vec::with_capacity(n);
                while out.len() + 1 < n {
                    let x = rng.gen_fp_full(fmt);
                    out.push(x);
                    // The negation, half the time nudged by ±1 on the
                    // magnitude ordinal (clamped into the finite range) so
                    // the pair cancels to a ±1-ulp residue.
                    let neg = x.bits ^ sign_bit;
                    let mut mag = neg & !sign_bit;
                    if rng.below(2) == 0 {
                        mag = if rng.below(2) == 0 {
                            mag.saturating_sub(1)
                        } else {
                            (mag + 1).min(top)
                        };
                    }
                    out.push(Fp::from_bits((neg & sign_bit) | mag, fmt));
                }
                while out.len() < n {
                    out.push(Fp::zero(fmt));
                }
                out
            }
            Distribution::NearOverflow => (0..n)
                .map(|_| {
                    let lo = (fmt.max_normal_exp() as i64 - 2).max(1);
                    let e = rng.range_i64(lo, fmt.max_normal_exp() as i64) as i32;
                    let mut m = rng.next_u64() & fmt.mant_mask();
                    if e == fmt.max_normal_exp() && m > fmt.max_finite_mant() {
                        m = fmt.max_finite_mant();
                    }
                    Fp::pack(rng.below(2) == 1, e, m, fmt)
                })
                .collect(),
        }
    }
}

/// Fuzzing-run geometry.
#[derive(Clone, Copy, Debug)]
pub struct OracleConfig {
    /// Fuzzed vectors per format.
    pub vectors: usize,
    /// Terms per vector (power of two ≥ 4, so every tree config applies).
    pub terms: usize,
    /// Base PRNG seed (per-format streams are derived from it).
    pub seed: u64,
}

impl Default for OracleConfig {
    fn default() -> Self {
        OracleConfig { vectors: 2000, terms: 16, seed: 0x0D1F_F0DD }
    }
}

/// One bit-level disagreement between an exact-mode adder and the
/// reference — enough context to replay it by hand.
#[derive(Clone, Debug)]
pub struct Mismatch {
    pub format: FpFormat,
    pub distribution: Distribution,
    /// Architecture / accumulator-path label, e.g. `"tree-4-4/wide"`.
    pub arch: String,
    pub expected_bits: u64,
    pub got_bits: u64,
    pub term_bits: Vec<u64>,
}

/// Result of one per-format oracle run.
#[derive(Clone, Debug)]
pub struct OracleReport {
    pub format: FpFormat,
    pub vectors: usize,
    /// Exact-mode (architecture × accumulator-path) result comparisons.
    pub exact_checks: u64,
    /// Every exact-mode bit mismatch (must be empty).
    pub mismatches: Vec<Mismatch>,
    /// Truncated-datapath comparisons that met the faithfulness filter.
    pub truncated_checks: u64,
    /// Worst observed truncated-datapath deviation, in result ULPs.
    pub truncated_max_ulp: i64,
}

/// Fuzz `fmt` against the reference: every vector runs through baseline,
/// online, the Kulisch window and a rotating mixed-radix tree, each under
/// the exact spec (and, where the format permits, both the narrow-i128 and
/// wide-`WideInt` accumulator paths); results must match the reference bit
/// for bit. The hardware-default truncated spec is tracked as a
/// faithfulness bound on the side.
pub fn run_oracle(fmt: FpFormat, cfg: &OracleConfig) -> OracleReport {
    assert!(
        cfg.terms.is_power_of_two() && cfg.terms >= 4,
        "terms must be a power of two >= 4"
    );
    let n = cfg.terms;
    let mut rng = XorShift::new(
        cfg.seed ^ ((fmt.ebits as u64) << 32) ^ ((fmt.mbits as u64) << 40),
    );
    let exact = AccSpec::exact(fmt);
    // Where the exact spec fits the i128 fast path, also exercise the
    // 384-bit wide path; otherwise one spec covers both labels.
    let mut specs: Vec<(&'static str, AccSpec)> = vec![(
        if exact.narrow { "narrow" } else { "wide" },
        exact,
    )];
    if exact.narrow {
        specs.push(("wide", AccSpec { narrow: false, ..exact }));
    }
    // Architectures and display labels are fixed for the whole run; only
    // the tree config rotates, so format each label once up front rather
    // than per vector. The reduction backends come from the registry — the
    // one source of truth — so a newly registered backend joins this
    // rotation with no edits here; the SoA kernel additionally runs at a
    // deliberately awkward block size (the vector length never divides
    // evenly) so the partial-tail block path is fuzzed too.
    let mut fixed_archs: Vec<(String, Architecture)> = vec![
        ("baseline".to_string(), Architecture::Baseline),
        ("kulisch".to_string(), Architecture::Exact),
    ];
    // The "scalar" registry entry IS Algorithm 3 (scalar_fold delegates to
    // online_sum), so the registry sweep below covers the former hand-listed
    // "online" rotation slot without fuzzing the same code path twice.
    for entry in crate::reduce::registry::entries() {
        let sel = entry.sel();
        fixed_archs.push((sel.to_string(), Architecture::Backend(sel)));
    }
    fixed_archs.push((
        "kernel:5".to_string(),
        Architecture::backend("kernel:5").expect("registered"),
    ));
    let tree_archs: Vec<(String, Architecture)> = enumerate_configs(n as u32)
        .into_iter()
        .map(|c| (format!("tree-{c}"), Architecture::Tree(c)))
        .collect();
    let hw = AccSpec::hw_default(fmt, n);
    let mut report = OracleReport {
        format: fmt,
        vectors: cfg.vectors,
        exact_checks: 0,
        mismatches: Vec::new(),
        truncated_checks: 0,
        truncated_max_ulp: 0,
    };
    for v in 0..cfg.vectors {
        let dist = DISTRIBUTIONS[v % DISTRIBUTIONS.len()];
        let terms = dist.gen_vector(&mut rng, fmt, n);
        let expected = reference_sum(&terms, fmt);
        let (tree_label, tree_arch) = &tree_archs[v % tree_archs.len()];
        let archs = fixed_archs
            .iter()
            .map(|(l, a)| (l.as_str(), a))
            .chain(std::iter::once((tree_label.as_str(), tree_arch)));
        for (label, arch) in archs {
            for (spec_label, spec) in &specs {
                let adder = MultiTermAdder { format: fmt, n_terms: n, spec: *spec, arch: arch.clone() };
                let got = adder.add(&terms);
                report.exact_checks += 1;
                if got.bits != expected.bits {
                    report.mismatches.push(Mismatch {
                        format: fmt,
                        distribution: dist,
                        arch: format!("{label}/{spec_label}"),
                        expected_bits: expected.bits,
                        got_bits: got.bits,
                        term_bits: terms.iter().map(|t| t.bits).collect(),
                    });
                }
            }
        }
        // Truncated-datapath faithfulness bound (same filter as the
        // property tests: deep cancellation amplifies the absolute guard
        // error into arbitrarily many result ULPs, so it is excluded).
        let adder = MultiTermAdder { format: fmt, n_terms: n, spec: hw, arch: tree_arch.clone() };
        let got = adder.add(&terms);
        if got.class() == FpClass::Normal
            && expected.class() == FpClass::Normal
            && got.sign() == expected.sign()
        {
            let emax = terms
                .iter()
                .filter(|t| t.class() == FpClass::Normal)
                .map(|t| t.raw_exp())
                .max()
                .unwrap_or(0);
            if emax - expected.raw_exp() <= 2 {
                let diff = (got.bits as i64 - expected.bits as i64).abs();
                report.truncated_checks += 1;
                report.truncated_max_ulp = report.truncated_max_ulp.max(diff);
            }
        }
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::exact::exact_rounded_sum;
    use crate::formats::{BF16, FP32, FP8_E4M3, PAPER_FORMATS};

    #[test]
    fn reference_agrees_with_kulisch_oracle_on_all_distributions() {
        // Two independent implementations (sign-magnitude limb reference
        // vs WideInt Kulisch window + normalize_round) must agree bit for
        // bit over every distribution and format.
        let mut rng = XorShift::new(0x0_D1FF);
        for fmt in PAPER_FORMATS {
            for dist in DISTRIBUTIONS {
                for _ in 0..100 {
                    let terms = dist.gen_vector(&mut rng, fmt, 16);
                    let a = reference_sum(&terms, fmt);
                    let b = exact_rounded_sum(&terms, fmt);
                    assert_eq!(
                        a.bits, b.bits,
                        "{fmt} {}: {a:?} vs {b:?} over {:x?}",
                        dist.name(),
                        terms.iter().map(|t| t.bits).collect::<Vec<_>>()
                    );
                }
            }
        }
    }

    #[test]
    fn reference_matches_native_f32_two_term() {
        let mut rng = XorShift::new(0x2F32);
        for _ in 0..2000 {
            let a = rng.gen_fp_full(FP32);
            let b = rng.gen_fp_full(FP32);
            let native = (a.to_f64() as f32) + (b.to_f64() as f32);
            // Both-zero operands: the reference returns +0 for an all-zero
            // sum; IEEE keeps -0 for (-0) + (-0). Skip that one case.
            if a.class() == FpClass::Zero && b.class() == FpClass::Zero {
                continue;
            }
            let r = reference_sum(&[a, b], FP32);
            assert_eq!(
                (r.to_f64() as f32).to_bits(),
                native.to_bits(),
                "{a:?} + {b:?}"
            );
        }
    }

    #[test]
    fn reference_handles_signed_zero_and_empty() {
        let z = Fp::zero(BF16);
        let nz = Fp::from_bits(1 << (BF16.width() - 1), BF16);
        assert_eq!(reference_sum(&[], BF16).bits, 0);
        assert_eq!(reference_sum(&[z, nz, nz], BF16).bits, 0);
        let one = Fp::from_f64(1.0, BF16);
        let none = Fp::from_f64(-1.0, BF16);
        assert_eq!(reference_sum(&[one, none], BF16).bits, 0, "cancellation -> +0");
    }

    #[test]
    fn reference_saturates_noinf_formats() {
        let big = Fp::pack(false, FP8_E4M3.max_normal_exp(), FP8_E4M3.max_finite_mant(), FP8_E4M3);
        let r = reference_sum(&[big, big, big], FP8_E4M3);
        assert_eq!(r.to_f64(), 448.0, "e4m3 overflow saturates");
    }

    #[test]
    fn small_oracle_run_is_clean() {
        let cfg = OracleConfig { vectors: 200, terms: 8, seed: 0x5EED };
        for fmt in [BF16, FP8_E4M3] {
            let rep = run_oracle(fmt, &cfg);
            assert!(rep.mismatches.is_empty(), "{fmt}: {:?}", rep.mismatches.first());
            assert!(rep.exact_checks >= 200 * 4, "{fmt}");
            assert!(rep.truncated_checks > 0, "{fmt}");
            assert!(rep.truncated_max_ulp <= 2, "{fmt}: {}", rep.truncated_max_ulp);
        }
    }
}
