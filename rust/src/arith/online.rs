//! Algorithm 3 — the online fused alignment-and-addition recurrence (eq. 7):
//!
//! ```text
//! λ_i  = max(λ_{i-1}, e_i)
//! o'_i = o'_{i-1} ≫ (λ_i − λ_{i-1})  +  m_i ≫ (λ_i − e_i)
//! ```
//!
//! A *single* loop replaces Algorithm 2's two unmergeable loops: each step
//! updates a running maximum exponent, incrementally re-aligns the partial
//! sum, aligns the incoming fraction against the running maximum, and adds.
//! The derivation (eqs. 4-6) shows `o'_N = o_N`, i.e. the online result is
//! identical to the baseline — which the tests here pin down bit-exactly.

// Exact-datapath module: native float arithmetic and lossy casts are
// forbidden here (clippy.toml, DESIGN.md §Analysis).
#![deny(clippy::float_arithmetic, clippy::cast_precision_loss)]

use super::operator::{op_combine, AlignAcc};
use super::AccSpec;
use crate::formats::Fp;

/// Online serial alignment-and-addition over finite terms (Algorithm 3).
pub fn online_sum(terms: &[Fp], spec: AccSpec) -> AlignAcc {
    let mut state = AlignAcc::IDENTITY; // (λ_0, o'_0)
    for t in terms {
        debug_assert!(t.is_finite());
        // One fused step: λ update, incremental re-alignment of the partial
        // sum, alignment of the incoming term, addition. Expressed via the
        // ⊙ operator with a leaf right-hand side — Algorithm 3 is exactly
        // the left-to-right fold of eq. 9.
        state = op_combine(&state, &AlignAcc::leaf(*t, spec), spec);
    }
    state
}

#[allow(clippy::float_arithmetic, clippy::cast_precision_loss, clippy::disallowed_methods)]
#[cfg(test)]
mod tests {
    use super::super::baseline::baseline_sum;
    use super::*;
    use crate::formats::{Fp, BF16, FP32};
    use crate::util::prng::XorShift;

    fn random_terms(rng: &mut XorShift, n: usize, fmt: crate::formats::FpFormat) -> Vec<Fp> {
        (0..n).map(|_| rng.gen_fp_normal(fmt)).collect()
    }

    #[test]
    fn online_equals_baseline_bitexact_exact_mode() {
        // The paper's central claim (o'_N == o_N), checked bit-for-bit on
        // the full accumulator state across random vectors.
        let mut rng = XorShift::new(0xA11E);
        for fmt in [BF16, FP32] {
            let spec = AccSpec::exact(fmt);
            for n in [1usize, 2, 3, 7, 16, 32, 64] {
                for _ in 0..50 {
                    let ts = random_terms(&mut rng, n, fmt);
                    let a = baseline_sum(&ts, spec);
                    let b = online_sum(&ts, spec);
                    assert_eq!(a, b, "n={n} fmt={fmt}");
                }
            }
        }
    }

    #[test]
    fn online_lambda_is_running_max() {
        let spec = AccSpec::exact(BF16);
        let ts: Vec<Fp> = [1.0, 1024.0, 0.5].iter().map(|&x| Fp::from_f64(x, BF16)).collect();
        let r = online_sum(&ts, spec);
        assert_eq!(r.lambda, Fp::from_f64(1024.0, BF16).raw_exp());
    }

    #[test]
    fn truncated_mode_online_equals_baseline_on_shift_composition() {
        // With truncation, the incremental shifts still compose exactly
        // ((x≫a)≫b == x≫(a+b)); online vs baseline can only differ through
        // add-before-shift reordering, which for N=2 cannot occur. Check
        // bit-exact equality for all 2-term cases over a coarse sweep.
        let spec = AccSpec::truncated(3);
        let mut rng = XorShift::new(7);
        for _ in 0..500 {
            let ts = random_terms(&mut rng, 2, BF16);
            assert_eq!(baseline_sum(&ts, spec), online_sum(&ts, spec));
        }
    }
}
