//! # online-fp-add
//!
//! Production-grade reproduction of *"Online Alignment and Addition in
//! Multi-Term Floating-Point Adders"* (Alexandridis & Dimitrakopoulos, 2024).
//!
//! The crate is organised in five tiers:
//!
//! * [`formats`] + [`arith`] + [`accum`] — bit-accurate models of every
//!   algorithm in the paper: the serial baseline (Algorithm 2), the online
//!   fused recurrence (Algorithm 3, eq. 7), the associative align-and-add
//!   operator `⊙` (eq. 8), arbitrary mixed-radix operator trees (eq. 9,
//!   Fig. 2), and the deferred-alignment exponent-indexed accumulator
//!   (the `eia` backend) as the opposite corner of the same design space.
//! * [`hw`] — structural hardware cost models (unit-gate area/delay,
//!   pipeline-stage scheduling, switching-activity power) that regenerate
//!   the paper's evaluation (Fig. 4, Fig. 5, Table I).
//! * [`dse`] + [`workload`] — design-space exploration across formats,
//!   term counts and radix configurations, driven by realistic
//!   BERT-style matmul operand traces (the paper's power methodology).
//! * [`coordinator`] + [`runtime`] — a leader/worker experiment
//!   orchestrator and the artifact runtime executing the AOT-lowered
//!   kernels (`artifacts/*.hlo.txt`); python never runs on this path.
//! * [`stream`] — the serving tier: a sharded streaming align-and-add
//!   reduction engine that exploits the associativity of `⊙` (eq. 10) to
//!   split live traffic across chunks, threads and arrival orders with
//!   bit-identical results in exact mode.
//!
//! See `DESIGN.md` for the crate map and the experiment index (including
//! the perf and calibration notes the code comments cite).

pub mod accum;
pub mod arith;
pub mod bench_util;
pub mod coordinator;
pub mod dse;
pub mod formats;
pub mod hw;
pub mod runtime;
pub mod stream;
pub mod util;
pub mod workload;

pub use accum::{Eia, EiaSnapshot};
pub use arith::{
    baseline::baseline_sum,
    kernel::ReduceBackend,
    online::online_sum,
    operator::{op_combine, AlignAcc},
    tree::{tree_sum, RadixConfig},
    AccSpec,
};
pub use formats::{Fp, FpClass, FpFormat};
pub use stream::{EngineConfig, Snapshot, StreamEngine, StreamService};
