//! # online-fp-add
//!
//! Production-grade reproduction of *"Online Alignment and Addition in
//! Multi-Term Floating-Point Adders"* (Alexandridis & Dimitrakopoulos, 2024).
//!
//! The crate is organised in five tiers:
//!
//! * [`formats`] + [`arith`] + [`accum`] + [`reduce`] — bit-accurate
//!   models of every algorithm in the paper: the serial baseline
//!   (Algorithm 2), the online fused recurrence (Algorithm 3, eq. 7), the
//!   associative align-and-add operator `⊙` (eq. 8), arbitrary mixed-radix
//!   operator trees (eq. 9, Fig. 2), and the deferred-alignment
//!   exponent-indexed accumulator — all dispatched through the [`reduce`]
//!   tier: the [`reduce::Reducer`] trait, mergeable typed
//!   [`reduce::Partial`]s with one byte codec, [`reduce::ReducePlan`]
//!   capability negotiation, and the name-indexed backend registry
//!   ([`reduce::registry`]) that is the single source of truth for every
//!   backend consumer.
//! * [`hw`] — structural hardware cost models (unit-gate area/delay,
//!   pipeline-stage scheduling, switching-activity power) that regenerate
//!   the paper's evaluation (Fig. 4, Fig. 5, Table I).
//! * [`dse`] + [`workload`] — design-space exploration across formats,
//!   term counts and radix configurations, driven by realistic
//!   BERT-style matmul operand traces (the paper's power methodology).
//! * [`coordinator`] + [`runtime`] — a leader/worker experiment
//!   orchestrator and the artifact runtime executing the AOT-lowered
//!   kernels (`artifacts/*.hlo.txt`); python never runs on this path.
//! * [`stream`] — the serving tier: a sharded streaming align-and-add
//!   reduction engine that exploits the associativity of `⊙` (eq. 10) to
//!   split live traffic across chunks, threads and arrival orders with
//!   bit-identical results in exact mode.
//!
//! Cutting across the tiers, [`telemetry`] is the observability layer:
//! lock-free metric families recording each tier's numeric-health events
//! (alignment sweeps, sticky activations, spill promotions, partial
//! merges), a span/event trace ring, and Prometheus/JSON exposition —
//! see DESIGN.md §Observability and `repro stats`.
//!
//! Sitting on top of all of them, [`analysis`] is the static verifier: an
//! abstract-interpretation pass deriving per-(format × backend) width
//! bounds for every datapath intermediate and checking them against the
//! storage actually provisioned — emitted as the checked-in proof
//! artifact `ANALYSIS_report.json` (`repro analyze`, DESIGN.md §Analysis).
//!
//! Most applications only need the [`prelude`].
//!
//! See `DESIGN.md` for the crate map and the experiment index (including
//! the perf and calibration notes the code comments cite).

// The portable-SIMD leg of the `"simd"` reduction backend (`arith::simd`)
// uses the nightly `portable_simd` std API; the off-by-default cargo
// feature gates it so stable builds compile the runtime-dispatched
// AVX2/scalar legs unchanged (DESIGN.md §Kernel, SIMD subsection).
#![cfg_attr(feature = "simd", feature(portable_simd))]

pub mod accum;
pub mod analysis;
pub mod arith;
pub mod bench_util;
pub mod coordinator;
pub mod dse;
pub mod formats;
pub mod hw;
pub mod reduce;
pub mod runtime;
pub mod stream;
pub mod telemetry;
pub mod util;
pub mod workload;

pub use accum::{Eia, EiaSnapshot};
pub use analysis::{AnalysisReport, StorageEnv};
#[allow(deprecated)]
pub use arith::kernel::ReduceBackend;
pub use arith::{
    baseline::baseline_sum,
    online::online_sum,
    operator::{op_combine, AlignAcc},
    tree::{tree_sum, RadixConfig},
    AccSpec,
};
pub use formats::{Fp, FpClass, FpFormat};
pub use reduce::{BackendSel, Partial, PlanBuilder, ReducePlan, Reducer};
pub use stream::{EngineConfig, Snapshot, StreamEngine, StreamService};
pub use telemetry::{TelemetrySnapshot, TraceEvent};

/// The one-stop import for applications: formats, the accumulator spec,
/// the reduction API tier (plan + registry + trait), the adder, and the
/// serving tier.
///
/// ```
/// use online_fp_add::prelude::*;
///
/// let plan = ReducePlan::negotiate(AccSpec::exact(BF16));
/// let terms: Vec<Fp> = [1.0, 2.0, 0.5].iter().map(|&x| Fp::from_f64(x, BF16)).collect();
/// assert!(!plan.reduce(&terms).is_identity());
/// ```
pub mod prelude {
    pub use crate::arith::adder::{Architecture, MultiTermAdder};
    pub use crate::arith::normalize::normalize_round;
    pub use crate::arith::operator::{op_combine, AlignAcc};
    pub use crate::arith::AccSpec;
    pub use crate::formats::{
        Fp, FpClass, FpFormat, BF16, FP32, FP8_E4M3, FP8_E5M2, PAPER_FORMATS,
    };
    pub use crate::reduce::{
        registry, BackendSel, Capabilities, Partial, PartialState, PlanBuilder, ReducePlan,
        Reducer,
    };
    pub use crate::stream::{
        EngineConfig, Segment, Snapshot, StreamEngine, StreamService,
    };
}
