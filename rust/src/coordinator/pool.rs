//! A small work-stealing-free thread pool (`std` only — no tokio/rayon in
//! the offline environment).
//!
//! Supports fire-and-forget jobs and an ordered [`ThreadPool::par_map`]
//! used by the DSE sweeps and the power simulator to parallelise over
//! configurations / trace shards.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size thread pool.
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    in_flight: Arc<AtomicUsize>,
}

impl ThreadPool {
    /// Spawn `size` workers (`size >= 1`).
    pub fn new(size: usize) -> Self {
        assert!(size >= 1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let in_flight = Arc::new(AtomicUsize::new(0));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let in_flight = Arc::clone(&in_flight);
                std::thread::Builder::new()
                    .name(format!("ofa-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                // A panicking job must not poison the pool;
                                // par_map turns the dropped channel into an
                                // error on the caller side.
                                let _ = catch_unwind(AssertUnwindSafe(job));
                                in_flight.fetch_sub(1, Ordering::SeqCst);
                            }
                            Err(_) => break, // pool dropped
                        }
                    })
                    .expect("spawning pool worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, in_flight }
    }

    /// A pool sized to the machine (cores, capped at 16).
    pub fn default_size() -> usize {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Submit a fire-and-forget job.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.in_flight.fetch_add(1, Ordering::SeqCst);
        self.tx.as_ref().unwrap().send(Box::new(f)).expect("pool alive");
    }

    /// Number of jobs submitted but not yet finished.
    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::SeqCst)
    }

    /// Parallel map preserving input order. A panic in `f` resumes on the
    /// caller with the worker's **original payload** (so the root cause —
    /// message, custom payload type, everything — survives the thread hop),
    /// never as a hung receiver; the failing item index goes to stderr.
    pub fn par_map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        let f = Arc::new(f);
        let (tx, rx): (Sender<(usize, std::thread::Result<R>)>, Receiver<_>) = channel();
        for (i, item) in items.into_iter().enumerate() {
            let tx = tx.clone();
            let f = Arc::clone(&f);
            self.submit(move || {
                let r = catch_unwind(AssertUnwindSafe(|| f(item)));
                let _ = tx.send((i, r));
            });
        }
        drop(tx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, r) = rx.recv().expect("worker alive");
            match r {
                Ok(v) => out[i] = Some(v),
                Err(payload) => {
                    eprintln!("par_map job {i} panicked; resuming its panic on the caller");
                    std::panic::resume_unwind(payload);
                }
            }
        }
        out.into_iter().map(|o| o.expect("all indices filled")).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close the channel; workers drain and exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn par_map_preserves_order() {
        let pool = ThreadPool::new(4);
        let out = pool.par_map((0..100u64).collect(), |x| x * x);
        assert_eq!(out, (0..100u64).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn all_submitted_jobs_run() {
        let pool = ThreadPool::new(3);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..50 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 50);
    }

    #[test]
    #[should_panic(expected = "boom")]
    fn panicking_job_propagates_its_message() {
        let pool = ThreadPool::new(2);
        let _ = pool.par_map(vec![1, 2, 3], |x| {
            if x == 2 {
                panic!("boom");
            }
            x
        });
    }

    #[test]
    fn panic_payload_is_propagated_verbatim() {
        // Non-string payloads (e.g. structured job errors) must survive the
        // worker→caller hop intact, not be replaced by a synthesized string.
        #[derive(Debug, PartialEq)]
        struct JobFault(u32);
        let pool = ThreadPool::new(2);
        let caught = catch_unwind(AssertUnwindSafe(|| {
            let _ = pool.par_map(vec![0u32], |_| -> u32 { std::panic::panic_any(JobFault(42)) });
        }))
        .expect_err("par_map must propagate the panic");
        assert_eq!(caught.downcast_ref::<JobFault>(), Some(&JobFault(42)));
    }

    #[test]
    fn pool_survives_panicking_fire_and_forget() {
        let pool = ThreadPool::new(1);
        pool.submit(|| panic!("ignored"));
        let out = pool.par_map(vec![7], |x| x + 1);
        assert_eq!(out, vec![8]);
    }
}
