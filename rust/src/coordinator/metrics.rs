//! Lightweight metrics: counters and latency histograms for the batcher and
//! the experiment coordinator.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// A monotonically increasing counter.
#[derive(Default, Debug)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Latency histogram with exponential buckets from 1 µs to ~17 s, plus
/// exact min/max/sum for summary statistics.
#[derive(Debug)]
pub struct LatencyHistogram {
    buckets: Vec<AtomicU64>, // bucket i: [2^i, 2^(i+1)) µs
    count: AtomicU64,
    sum_us: AtomicU64,
    minmax: Mutex<(u64, u64)>,
}

const NBUCKETS: usize = 25;

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram {
            buckets: (0..NBUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            minmax: Mutex::new((u64::MAX, 0)),
        }
    }
}

impl LatencyHistogram {
    pub fn observe(&self, d: Duration) {
        let us = d.as_micros().max(1) as u64;
        let bucket = (63 - us.leading_zeros() as usize).min(NBUCKETS - 1);
        self.buckets[bucket].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        let mut mm = self.minmax.lock().unwrap();
        mm.0 = mm.0.min(us);
        mm.1 = mm.1.max(us);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_us.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    /// Approximate quantile from the exponential buckets (upper bound of the
    /// bucket containing the quantile rank).
    pub fn quantile_us(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let rank = ((total as f64) * q).ceil() as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= rank {
                return 1u64 << (i + 1); // bucket upper bound
            }
        }
        self.minmax.lock().unwrap().1
    }

    pub fn summary(&self) -> String {
        let (min, max) = *self.minmax.lock().unwrap();
        format!(
            "n={} mean={:.0}µs p50≤{}µs p99≤{}µs min={}µs max={}µs",
            self.count(),
            self.mean_us(),
            self.quantile_us(0.5),
            self.quantile_us(0.99),
            if min == u64::MAX { 0 } else { min },
            max
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_accumulates() {
        let c = Counter::default();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn histogram_quantiles_bracket_observations() {
        let h = LatencyHistogram::default();
        for us in [10u64, 20, 30, 40, 1000] {
            h.observe(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 5);
        assert!((h.mean_us() - 220.0).abs() < 1.0);
        // p50 upper bound must be >= 30µs and well below 1000µs bucket top.
        let p50 = h.quantile_us(0.5);
        assert!((32..=64).contains(&p50), "p50 bound {p50}");
        let p99 = h.quantile_us(0.99);
        assert!(p99 >= 1024, "p99 bound {p99}");
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = LatencyHistogram::default();
        assert_eq!(h.quantile_us(0.99), 0);
        assert_eq!(h.mean_us(), 0.0);
    }
}
