//! Thin alias of the [`crate::telemetry`] metric primitives, kept so the
//! batcher/engine call sites (and anything downstream) keep compiling
//! unchanged.
//!
//! The types used to live here; they were promoted to
//! `telemetry::metrics` when the cross-tier observability layer landed —
//! and the promotion fixed the old [`LatencyHistogram::observe`] hot-path
//! defect of taking a `Mutex` per observation for min/max tracking (now a
//! lock-free CAS loop; see `telemetry::metrics`).

pub use crate::telemetry::{Counter, Gauge, LatencyHistogram, ValueHistogram};

#[cfg(test)]
mod tests {
    // The original tests of this module, kept verbatim: they pin that the
    // re-exported primitives preserve the old API and semantics exactly.
    use super::*;
    use std::time::Duration;

    #[test]
    fn counter_accumulates() {
        let c = Counter::default();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn histogram_quantiles_bracket_observations() {
        let h = LatencyHistogram::default();
        for us in [10u64, 20, 30, 40, 1000] {
            h.observe(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 5);
        assert!((h.mean_us() - 220.0).abs() < 1.0);
        // p50 upper bound must be >= 30µs and well below 1000µs bucket top.
        let p50 = h.quantile_us(0.5);
        assert!((32..=64).contains(&p50), "p50 bound {p50}");
        let p99 = h.quantile_us(0.99);
        assert!(p99 >= 1024, "p99 bound {p99}");
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = LatencyHistogram::default();
        assert_eq!(h.quantile_us(0.99), 0);
        assert_eq!(h.mean_us(), 0.0);
    }
}
