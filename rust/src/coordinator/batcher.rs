//! Dynamic batcher: the leader-side request path for the PJRT reduction
//! executables.
//!
//! The AOT artifacts have a fixed batch geometry (64 rows), so serving
//! individual dot-product requests efficiently requires vLLM-router-style
//! dynamic batching: requests queue up, a dispatcher thread drains up to a
//! full batch (or whatever arrived within the linger window), executes one
//! PJRT call, and completes each request's one-shot channel. A bounded
//! queue provides backpressure.

use super::metrics::{Counter, LatencyHistogram};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// One N-term reduction request: the `(e, m)` pairs of a single row.
pub struct ReduceRequest {
    pub e: Vec<i32>,
    pub m: Vec<i32>,
    submitted: Instant,
    reply: SyncSender<ReduceResponse>,
}

/// The completed `(λ, acc)` state for one request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReduceResponse {
    pub lambda: i32,
    pub acc: i64,
}

/// Shared metrics for a batcher instance.
#[derive(Default, Debug)]
pub struct BatcherMetrics {
    pub requests: Counter,
    pub rejected: Counter,
    pub batches: Counter,
    pub batch_fill: Counter, // total rows over all batches (fill = rows/batches)
    pub latency: LatencyHistogram,
    pub exec_latency: LatencyHistogram,
}

impl BatcherMetrics {
    pub fn mean_batch_fill(&self) -> f64 {
        let b = self.batches.get();
        if b == 0 {
            0.0
        } else {
            self.batch_fill.get() as f64 / b as f64
        }
    }
}

/// Handle used by request producers.
#[derive(Clone)]
pub struct BatcherHandle {
    tx: SyncSender<ReduceRequest>,
    n_terms: usize,
    metrics: Arc<BatcherMetrics>,
}

/// Error returned when the bounded queue is full (backpressure) or closed.
#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// Queue full — caller should retry or shed load.
    Overloaded,
    /// Batcher shut down.
    Closed,
}

impl BatcherHandle {
    /// Submit one reduction row and wait for its result.
    pub fn reduce(&self, e: Vec<i32>, m: Vec<i32>) -> Result<ReduceResponse, SubmitError> {
        let rx = self.submit(e, m)?;
        rx.recv().map_err(|_| SubmitError::Closed)
    }

    /// Submit without waiting; returns the one-shot receiver.
    pub fn submit(
        &self,
        e: Vec<i32>,
        m: Vec<i32>,
    ) -> Result<Receiver<ReduceResponse>, SubmitError> {
        assert_eq!(e.len(), self.n_terms, "row width must match the artifact");
        assert_eq!(m.len(), self.n_terms);
        let (reply, rx) = sync_channel(1);
        let req = ReduceRequest { e, m, submitted: Instant::now(), reply };
        match self.tx.try_send(req) {
            Ok(()) => {
                self.metrics.requests.inc();
                Ok(rx)
            }
            Err(TrySendError::Full(_)) => {
                self.metrics.rejected.inc();
                Err(SubmitError::Overloaded)
            }
            Err(TrySendError::Disconnected(_)) => Err(SubmitError::Closed),
        }
    }

    pub fn metrics(&self) -> &BatcherMetrics {
        &self.metrics
    }
}

/// The executor side: anything that can reduce a padded batch of rows.
///
/// Implemented by the PJRT wrapper ([`crate::runtime::OnlineReduceExe`] via
/// a closure) and by pure-Rust mocks in tests/fault-injection. PJRT handles
/// are not `Send`, so they must be *created on* the dispatcher thread via
/// [`Batcher::spawn_with`].
pub trait BatchExecutor: 'static {
    /// `rows` elements, each `(e, m)` of width `n_terms`; returns one
    /// `(λ, acc)` per row, in order.
    fn execute(&mut self, rows: &[(Vec<i32>, Vec<i32>)]) -> Vec<(i32, i64)>;
}

impl<F> BatchExecutor for F
where
    F: FnMut(&[(Vec<i32>, Vec<i32>)]) -> Vec<(i32, i64)> + 'static,
{
    fn execute(&mut self, rows: &[(Vec<i32>, Vec<i32>)]) -> Vec<(i32, i64)> {
        (self)(rows)
    }
}

/// Batching policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Max rows per PJRT execution (the artifact's baked batch size).
    pub max_batch: usize,
    /// Row width (the artifact's term count).
    pub n_terms: usize,
    /// How long the dispatcher lingers for more rows once one arrived.
    pub linger: Duration,
    /// Bounded queue depth (backpressure threshold).
    pub queue_depth: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 64,
            n_terms: 32,
            linger: Duration::from_micros(200),
            queue_depth: 1024,
        }
    }
}

/// A running batcher: dispatcher thread + handle.
pub struct Batcher {
    handle: BatcherHandle,
    dispatcher: Option<std::thread::JoinHandle<()>>,
}

impl Batcher {
    /// Spawn the dispatcher loop around a `Send` executor.
    pub fn spawn<E: BatchExecutor + Send>(cfg: BatcherConfig, exe: E) -> Self {
        Self::spawn_with(cfg, move || exe)
    }

    /// Spawn the dispatcher loop, constructing the executor *on* the
    /// dispatcher thread — required for PJRT executables, which are not
    /// `Send`.
    pub fn spawn_with<E, F>(cfg: BatcherConfig, make_exe: F) -> Self
    where
        E: BatchExecutor,
        F: FnOnce() -> E + Send + 'static,
    {
        let (tx, rx) = sync_channel::<ReduceRequest>(cfg.queue_depth);
        let metrics = Arc::new(BatcherMetrics::default());
        let m = Arc::clone(&metrics);
        let dispatcher = std::thread::Builder::new()
            .name("ofa-batcher".into())
            .spawn(move || {
                let mut exe = make_exe();
                dispatch_loop(cfg, rx, &mut exe, &m)
            })
            .expect("spawning batcher");
        Batcher {
            handle: BatcherHandle { tx, n_terms: cfg.n_terms, metrics },
            dispatcher: Some(dispatcher),
        }
    }

    pub fn handle(&self) -> BatcherHandle {
        self.handle.clone()
    }

    pub fn metrics(&self) -> &BatcherMetrics {
        &self.handle.metrics
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        // Close the queue: after in-flight handles drop, dispatcher exits.
        let (dead_tx, _) = sync_channel(1);
        self.handle.tx = dead_tx;
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
    }
}

fn dispatch_loop(
    cfg: BatcherConfig,
    rx: Receiver<ReduceRequest>,
    exe: &mut dyn BatchExecutor,
    metrics: &BatcherMetrics,
) {
    loop {
        // Block for the first request of the next batch.
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return, // all handles dropped
        };
        let mut batch = vec![first];
        // Linger briefly to fill the batch.
        let deadline = Instant::now() + cfg.linger;
        while batch.len() < cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => batch.push(r),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        // Execute one padded PJRT call for the whole batch.
        let rows: Vec<(Vec<i32>, Vec<i32>)> =
            batch.iter().map(|r| (r.e.clone(), r.m.clone())).collect();
        let t0 = Instant::now();
        let results = exe.execute(&rows);
        metrics.exec_latency.observe(t0.elapsed());
        metrics.batches.inc();
        metrics.batch_fill.add(batch.len() as u64);
        debug_assert_eq!(results.len(), batch.len());
        for (req, (lambda, acc)) in batch.into_iter().zip(results) {
            metrics.latency.observe(req.submitted.elapsed());
            let _ = req.reply.send(ReduceResponse { lambda, acc }); // receiver may be gone
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Executor that computes a trivial checksum so tests can verify
    /// request/response pairing survives batching.
    fn checksum_exe() -> impl BatchExecutor {
        |rows: &[(Vec<i32>, Vec<i32>)]| {
            rows.iter()
                .map(|(e, m)| {
                    let lam = *e.iter().max().unwrap();
                    let acc: i64 = m.iter().map(|&x| x as i64).sum();
                    (lam, acc)
                })
                .collect::<Vec<_>>()
        }
    }

    fn cfg(n_terms: usize) -> BatcherConfig {
        BatcherConfig { n_terms, linger: Duration::from_millis(2), ..Default::default() }
    }

    #[test]
    fn responses_match_their_requests() {
        let batcher = Batcher::spawn(cfg(4), checksum_exe());
        let handle = batcher.handle();
        let workers: Vec<_> = (0..32)
            .map(|i| {
                let h = handle.clone();
                std::thread::spawn(move || {
                    let e = vec![i as i32 + 1; 4];
                    let m = vec![i as i32; 4];
                    let r = h.reduce(e, m).unwrap();
                    assert_eq!(r.lambda, i as i32 + 1);
                    assert_eq!(r.acc, 4 * i as i64);
                })
            })
            .collect();
        for w in workers {
            w.join().unwrap();
        }
        assert_eq!(batcher.metrics().requests.get(), 32);
        assert!(batcher.metrics().batches.get() <= 32);
    }

    #[test]
    fn batches_actually_coalesce() {
        let batcher = Batcher::spawn(
            BatcherConfig { linger: Duration::from_millis(50), n_terms: 2, ..Default::default() },
            checksum_exe(),
        );
        let handle = batcher.handle();
        // Pre-load many requests, then wait: the linger window must merge
        // them into far fewer executions than requests.
        let rxs: Vec<_> =
            (0..64).map(|i| handle.submit(vec![1, 2], vec![i, i]).unwrap()).collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        let batches = batcher.metrics().batches.get();
        assert!(batches <= 4, "expected coalescing, got {batches} batches");
        assert!(batcher.metrics().mean_batch_fill() >= 16.0);
    }

    #[test]
    fn backpressure_rejects_when_full() {
        // Executor that blocks until told, so the queue can fill up.
        let (gate_tx, gate_rx) = sync_channel::<()>(0);
        let exe = move |rows: &[(Vec<i32>, Vec<i32>)]| {
            let _ = gate_rx.recv();
            rows.iter().map(|_| (0, 0i64)).collect::<Vec<_>>()
        };
        let batcher = Batcher::spawn(
            BatcherConfig {
                queue_depth: 4,
                max_batch: 1,
                n_terms: 1,
                linger: Duration::ZERO,
            },
            exe,
        );
        let handle = batcher.handle();
        let mut pending = Vec::new();
        let mut overloaded = false;
        for i in 0..32 {
            match handle.submit(vec![i], vec![i]) {
                Ok(rx) => pending.push(rx),
                Err(SubmitError::Overloaded) => {
                    overloaded = true;
                    break;
                }
                Err(e) => panic!("unexpected {e:?}"),
            }
        }
        assert!(overloaded, "bounded queue must reject past its depth");
        assert!(batcher.metrics().rejected.get() >= 1);
        // Release the gate so the dispatcher can drain before drop.
        for _ in 0..pending.len() {
            let _ = gate_tx.send(());
        }
        for rx in pending {
            let _ = rx.recv();
        }
    }
}
