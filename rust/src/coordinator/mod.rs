//! L3 experiment coordinator: a leader/worker orchestrator for hardware
//! evaluation sweeps plus a dynamic batcher for the PJRT request path.
//!
//! Two roles, mirroring the two things the evaluation needs:
//!
//! * [`Coordinator`] — fans experiment jobs (one per adder configuration ×
//!   workload) out over a [`pool::ThreadPool`], collects structured
//!   results in input order, tracks progress and throughput; this is what
//!   drives Fig. 4 / Fig. 5 / Table I regeneration.
//! * [`batcher::Batcher`] — coalesces single dot-product requests into the
//!   fixed-geometry PJRT executions of the AOT artifacts with bounded-queue
//!   backpressure (the serving-shaped demo in `examples/bert_e2e.rs`).

pub mod batcher;
pub mod metrics;
pub mod pool;

use metrics::Counter;
use pool::ThreadPool;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Leader-side orchestration of a sweep of independent jobs.
pub struct Coordinator {
    pool: ThreadPool,
    verbose: bool,
    pub jobs_done: Arc<Counter>,
}

impl Coordinator {
    pub fn new(threads: usize) -> Self {
        Coordinator {
            pool: ThreadPool::new(threads.max(1)),
            verbose: false,
            jobs_done: Arc::new(Counter::default()),
        }
    }

    /// Machine-sized coordinator.
    pub fn default_parallelism() -> Self {
        Self::new(ThreadPool::default_size())
    }

    pub fn verbose(mut self, on: bool) -> Self {
        self.verbose = on;
        self
    }

    pub fn threads(&self) -> usize {
        self.pool.size()
    }

    /// Run `f` over all jobs in parallel, preserving order; logs progress
    /// when verbose. Each job's wall time is folded into the throughput
    /// line printed at the end.
    pub fn run<T, R, F>(&self, label: &str, jobs: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = jobs.len();
        let t0 = Instant::now();
        if self.verbose {
            eprintln!("[coordinator] {label}: {n} jobs on {} workers", self.pool.size());
        }
        let done = Arc::clone(&self.jobs_done);
        let logged = Arc::new(AtomicBool::new(!self.verbose));
        let out = self.pool.par_map(jobs, move |job| {
            let r = f(job);
            done.inc();
            r
        });
        if !logged.load(Ordering::Relaxed) || self.verbose {
            let dt = t0.elapsed().as_secs_f64();
            if self.verbose {
                eprintln!(
                    "[coordinator] {label}: {n} jobs in {dt:.2}s ({:.1} jobs/s)",
                    n as f64 / dt.max(1e-9)
                );
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_preserves_order_and_counts() {
        let c = Coordinator::new(4);
        let out = c.run("square", (0..50i64).collect(), |x| x * x);
        assert_eq!(out, (0..50i64).map(|x| x * x).collect::<Vec<_>>());
        assert_eq!(c.jobs_done.get(), 50);
    }

    #[test]
    fn multiple_sweeps_reuse_the_pool() {
        let c = Coordinator::new(2);
        let a = c.run("a", vec![1, 2, 3], |x| x + 1);
        let b = c.run("b", vec![10, 20], |x| x * 2);
        assert_eq!(a, vec![2, 3, 4]);
        assert_eq!(b, vec![20, 40]);
        assert_eq!(c.jobs_done.get(), 5);
    }
}
