//! Design-space exploration: sweep every mixed-radix configuration of a
//! multi-term adder (the paper's §IV methodology), attach workload-driven
//! power, and render the paper's tables and figures with paper-vs-measured
//! columns.

pub mod artifact;
pub mod explore;
pub mod paper;
pub mod report;

pub use artifact::{dse_report, DseReport};
pub use explore::{sweep_format, SweepOptions};
