//! Paper-style report rendering: Fig. 4, Fig. 5 and Table I with
//! paper-vs-measured columns (the reproduction contract is the *shape* —
//! who wins, by roughly what factor — not absolute 28-nm numbers).

#![deny(clippy::cast_precision_loss)]

use super::explore::{best_proposed, sweep_format, SweepOptions};
use super::paper;
use crate::coordinator::Coordinator;
use crate::formats::{FpFormat, PAPER_FORMATS};
use crate::hw::datapath::{build_adder, DatapathParams};
use crate::hw::design::DesignPoint;
use crate::hw::pipeline::{min_clock_ns, paper_stages, pipeline};
use crate::hw::gates;
use crate::arith::tree::{enumerate_configs, RadixConfig};
use crate::arith::AccSpec;
use crate::util::table::Table;
use crate::workload::bert::power_trace;
use crate::workload::Trace;
use std::sync::Arc;

/// Fig. 4: area and power of all 32-term BFloat16 configurations relative
/// to the baseline.
pub fn fig4(trace_vectors: usize, coord: &Coordinator) -> (Table, Vec<DesignPoint>) {
    let fmt = crate::formats::BF16;
    let trace = Arc::new(power_trace(fmt, 32, trace_vectors, 0xF16));
    let points = sweep_format(fmt, 32, &SweepOptions::default(), Some(trace), coord);
    let base = points[0].clone();
    let mut t = Table::new(vec![
        "config",
        "area µm²",
        "area Δ",
        "power mW",
        "power Δ",
        "met 1GHz",
    ]);
    for p in &points {
        let pw = p.power_mw.unwrap_or(0.0);
        let bpw = base.power_mw.unwrap_or(1.0);
        t.row(vec![
            p.config.to_string(),
            format!("{:.0}", p.area_um2),
            format!("{:+.1}%", 100.0 * (p.area_um2 - base.area_um2) / base.area_um2),
            format!("{pw:.2}"),
            format!("{:+.1}%", 100.0 * (pw - bpw) / bpw),
            if p.feasible { "yes".into() } else { format!("min {:.2} ns", p.clock_ns) },
        ]);
    }
    (t, points)
}

/// Summarise Fig. 4 against the paper's headline (best-config savings).
pub fn fig4_headline(points: &[DesignPoint]) -> String {
    let base = &points[0];
    let best_area = best_proposed(points, |p| p.area_um2);
    let best_power = best_proposed(points, |p| p.power_mw.unwrap_or(f64::MAX));
    let area_save = 100.0 * (1.0 - best_area.area_um2 / base.area_um2);
    let power_save = 100.0
        * (1.0 - best_power.power_mw.unwrap_or(0.0) / base.power_mw.unwrap_or(1.0));
    format!(
        "best area   : {} saves {:.1}%  (paper: {} saves {:.0}%)\n\
         best power  : {} saves {:.1}%  (paper: {} saves {:.0}%)",
        best_area.config,
        area_save,
        paper::FIG4_BEST_AREA.0,
        paper::FIG4_BEST_AREA.1,
        best_power.config,
        power_save,
        paper::FIG4_BEST_POWER.0,
        paper::FIG4_BEST_POWER.1,
    )
}

/// Fig. 5: area-vs-clock Pareto for 32-term BFloat16 at 1–4 stages.
/// Returns one row per (config, stages, clock target) that met timing.
pub fn fig5(coord: &Coordinator) -> Table {
    let fmt = crate::formats::BF16;
    let n = 32;
    let clocks: Vec<f64> = (0..=14).map(|i| 0.8 + 0.2 * i as f64).collect();
    let clocks_for_jobs = clocks.clone();
    let mut configs = enumerate_configs(n);
    configs.sort_by_key(|c| (c.levels(), c.to_string()));
    let jobs: Vec<RadixConfig> = configs;
    let rows = coord.run("fig5 sweep", jobs, move |cfg: RadixConfig| {
        let params = DatapathParams::new(fmt, n, AccSpec::hw_default(fmt, n as usize));
        let adder = build_adder(params, &cfg);
        let mut out = Vec::new();
        for stages in 1..=4u32 {
            let minclk = min_clock_ns(&adder, stages);
            for &t in &clocks_for_jobs {
                if t >= minclk {
                    if let Some(p) = pipeline(&adder, stages, t) {
                        out.push((
                            cfg.to_string(),
                            stages,
                            t,
                            gates::ge_to_um2(p.total_area),
                            minclk,
                        ));
                    }
                }
            }
        }
        out
    });
    let mut t = Table::new(vec!["clock ns", "best config", "stages", "area µm²", "min clk"]);
    // For each clock target report the area-minimal design (paper Fig. 5's
    // "most area efficient designs per clock target").
    let flat: Vec<_> = rows.into_iter().flatten().collect();
    let mut clocks_sorted = clocks.clone();
    clocks_sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    for &c in &clocks_sorted {
        if let Some(bestrow) = flat
            .iter()
            .filter(|r| (r.2 - c).abs() < 1e-9)
            .min_by(|a, b| a.3.partial_cmp(&b.3).unwrap())
        {
            t.row(vec![
                format!("{c:.1}"),
                bestrow.0.clone(),
                bestrow.1.to_string(),
                format!("{:.0}", bestrow.3),
                format!("{:.2}", bestrow.4),
            ]);
        }
    }
    t
}

/// Fig. 5 headline: fastest configuration at the paper's stage count vs
/// the baseline's fastest clock at the same depth.
pub fn fig5_speed_headline(coord: &Coordinator) -> String {
    let fmt = crate::formats::BF16;
    let n = 32;
    let stages = paper_stages(fmt, n);
    let mut configs = enumerate_configs(n);
    configs.sort_by_key(|c| (c.levels(), c.to_string()));
    let rows = coord.run("fig5 speed", configs, move |cfg: RadixConfig| {
        let params = DatapathParams::new(fmt, n, AccSpec::hw_default(fmt, n as usize));
        let adder = build_adder(params, &cfg);
        (cfg.to_string(), cfg.is_baseline(), min_clock_ns(&adder, stages))
    });
    let base = rows.iter().find(|r| r.1).unwrap().2;
    let fastest = rows
        .iter()
        .filter(|r| !r.1)
        .min_by(|a, b| a.2.partial_cmp(&b.2).unwrap())
        .unwrap();
    format!(
        "fastest proposed @{stages} stages: {} at {:.2} ns vs baseline {:.2} ns \
         ({:+.1}% clock; paper: {} is {:.1}% faster)",
        fastest.0,
        fastest.2,
        base,
        100.0 * (base - fastest.2) / base,
        paper::FIG5_SPEEDUP_CONFIG.0,
        paper::FIG5_SPEEDUP_CONFIG.1,
    )
}

/// One measured Table I row.
pub struct Table1Row {
    pub format: FpFormat,
    pub base: DesignPoint,
    pub best_area: DesignPoint,
    pub best_power: DesignPoint,
}

/// Table I for one term count: sweep all five formats with workload power.
pub fn table1(n: u32, trace_vectors: usize, coord: &Coordinator) -> (Table, Vec<Table1Row>) {
    let mut rows = Vec::new();
    for fmt in PAPER_FORMATS {
        let trace: Arc<Trace> =
            Arc::new(power_trace(fmt, n as usize, trace_vectors, 0x7AB1 ^ n as u64));
        let points = sweep_format(fmt, n, &SweepOptions::default(), Some(trace), coord);
        let base = points[0].clone();
        let best_area = best_proposed(&points, |p| p.area_um2).clone();
        let best_power = best_proposed(&points, |p| p.power_mw.unwrap_or(f64::MAX)).clone();
        rows.push(Table1Row { format: fmt, base, best_area, best_power });
    }
    let paper_rows = paper::table1(n);
    let mut t = Table::new(vec![
        "format",
        "base µm²",
        "best µm² (cfg)",
        "save",
        "paper save",
        "base mW",
        "best mW (cfg)",
        "save",
        "paper save",
    ]);
    for (i, r) in rows.iter().enumerate() {
        let area_save = 100.0 * (1.0 - r.best_area.area_um2 / r.base.area_um2);
        let power_save = 100.0
            * (1.0 - r.best_power.power_mw.unwrap_or(0.0) / r.base.power_mw.unwrap_or(1.0));
        let (psa, psp) = paper_rows
            .map(|rows| (rows[i].area_save_pct, rows[i].power_save_pct))
            .unwrap_or((f64::NAN, f64::NAN));
        t.row(vec![
            r.format.name.to_string(),
            format!("{:.0}", r.base.area_um2),
            format!("{:.0} ({})", r.best_area.area_um2, r.best_area.config),
            format!("{area_save:+.0}%"),
            format!("{psa:+.0}%"),
            format!("{:.2}", r.base.power_mw.unwrap_or(0.0)),
            format!("{:.2} ({})", r.best_power.power_mw.unwrap_or(0.0), r.best_power.config),
            format!("{power_save:+.0}%"),
            format!("{psp:+.0}%"),
        ]);
    }
    (t, rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4_produces_all_configs_and_headline() {
        let coord = Coordinator::new(4);
        let (table, points) = fig4(64, &coord);
        assert_eq!(points.len(), 16);
        let rendered = table.render();
        assert!(rendered.contains("8-2-2"));
        let headline = fig4_headline(&points);
        assert!(headline.contains("paper"));
    }

    #[test]
    fn table1_small_smoke() {
        // N=8 is not a paper row but exercises the full path quickly.
        let coord = Coordinator::new(4);
        let (table, rows) = table1(8, 32, &coord);
        assert_eq!(rows.len(), 5);
        assert!(table.render().contains("FP8_e4m3"));
    }
}
