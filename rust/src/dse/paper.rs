//! The paper's published evaluation numbers (Table I and the §IV-A text),
//! kept here so every report can print paper-vs-measured side by side.

#![deny(clippy::cast_precision_loss)]

/// One Table I row: (format name, base area 10³µm², proposed area, proposed
/// area config, area saving %, base power mW, proposed power, power config
/// is the same as the area config in the paper, power saving %).
#[derive(Clone, Copy, Debug)]
pub struct PaperRow {
    pub format: &'static str,
    pub base_area_kum2: f64,
    pub prop_area_kum2: f64,
    pub config: &'static str,
    pub area_save_pct: f64,
    pub base_power_mw: f64,
    pub prop_power_mw: f64,
    pub power_save_pct: f64,
}

const fn row(
    format: &'static str,
    base_area_kum2: f64,
    prop_area_kum2: f64,
    config: &'static str,
    area_save_pct: f64,
    base_power_mw: f64,
    prop_power_mw: f64,
    power_save_pct: f64,
) -> PaperRow {
    PaperRow {
        format,
        base_area_kum2,
        prop_area_kum2,
        config,
        area_save_pct,
        base_power_mw,
        prop_power_mw,
        power_save_pct,
    }
}

/// Table I(a): 16-term adders.
pub const TABLE1_N16: [PaperRow; 5] = [
    row("FP32", 8.87, 6.80, "8-2", 23.0, 3.03, 2.65, 13.0),
    row("BFloat16", 2.92, 2.69, "8-2", 8.0, 1.61, 1.35, 16.0),
    row("FP8_e4m3", 1.29, 1.23, "8-2", 4.0, 0.83, 0.69, 17.0),
    row("FP8_e5m2", 1.17, 1.23, "2-4-2", -5.0, 0.62, 0.70, -13.0),
    row("FP8_e6m1", 1.33, 1.36, "4-2-2", -2.0, 0.49, 0.54, -10.0),
];

/// Table I(b): 32-term adders.
pub const TABLE1_N32: [PaperRow; 5] = [
    row("FP32", 16.24, 14.02, "2-2-2-2-2", 14.0, 6.69, 5.78, 14.0),
    row("BFloat16", 6.44, 5.50, "8-2-2", 15.0, 3.97, 2.92, 26.0),
    row("FP8_e4m3", 3.02, 2.51, "8-2-2", 17.0, 1.85, 1.53, 17.0),
    row("FP8_e5m2", 2.73, 2.44, "8-2-2", 11.0, 1.74, 1.44, 17.0),
    row("FP8_e6m1", 2.80, 2.48, "8-2-2", 11.0, 0.76, 0.63, 18.0),
];

/// Table I(c): 64-term adders.
pub const TABLE1_N64: [PaperRow; 5] = [
    row("FP32", 32.51, 28.67, "2-2-2-4", 12.0, 13.26, 10.82, 19.0),
    row("BFloat16", 12.84, 11.73, "2-4-2-2-2", 9.0, 7.30, 7.05, 4.0),
    row("FP8_e4m3", 5.79, 5.09, "8-4-2", 12.0, 3.62, 3.01, 17.0),
    row("FP8_e5m2", 5.34, 4.78, "8-8", 11.0, 3.35, 2.78, 17.0),
    row("FP8_e6m1", 5.39, 4.86, "2-8-4", 10.0, 1.62, 1.35, 17.0),
];

/// Table I rows for a term count.
pub fn table1(n: u32) -> Option<&'static [PaperRow; 5]> {
    match n {
        16 => Some(&TABLE1_N16),
        32 => Some(&TABLE1_N32),
        64 => Some(&TABLE1_N64),
        _ => None,
    }
}

/// Fig. 4 headline numbers (32-term BFloat16): best area config and
/// saving, best power config and saving.
pub const FIG4_BEST_AREA: (&str, f64) = ("4-4-2", 15.0);
pub const FIG4_BEST_POWER: (&str, f64) = ("8-2-2", 26.0);

/// Fig. 5 headline: 2-2-8 clocks 16.6 % faster than the baseline at equal
/// pipeline depth.
pub const FIG5_SPEEDUP_CONFIG: (&str, f64) = ("2-2-8", 16.6);

/// §IV-A summary bands: across the positive Table I rows the online
/// operator trees save 3–23 % area and 4–26 % power against the
/// serial-alignment baselines. `DSE_report.json`'s summary flags each
/// measured best-config saving as inside or outside these bands.
pub const PAPER_AREA_BAND: (f64, f64) = (3.0, 23.0);
pub const PAPER_POWER_BAND: (f64, f64) = (4.0, 26.0);

/// Band membership with the paper's whole-percent rounding slack.
pub fn in_band(save_pct: f64, band: (f64, f64)) -> bool {
    save_pct >= band.0 - 0.5 && save_pct <= band.1 + 0.5
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_savings_are_consistent_with_absolute_numbers() {
        for rows in [&TABLE1_N16, &TABLE1_N32, &TABLE1_N64] {
            for r in rows.iter() {
                let area_save = 100.0 * (1.0 - r.prop_area_kum2 / r.base_area_kum2);
                // The paper rounds to whole percent; allow 1.5 % slack.
                assert!(
                    (area_save - r.area_save_pct).abs() < 1.6,
                    "{}: {} vs {}",
                    r.format,
                    area_save,
                    r.area_save_pct
                );
            }
        }
    }

    #[test]
    fn lookup() {
        assert!(table1(32).is_some());
        assert!(table1(8).is_none());
    }

    #[test]
    fn every_positive_table1_saving_sits_inside_the_summary_bands() {
        for rows in [&TABLE1_N16, &TABLE1_N32, &TABLE1_N64] {
            for r in rows.iter().filter(|r| r.area_save_pct > 0.0) {
                assert!(in_band(r.area_save_pct, PAPER_AREA_BAND), "{}", r.format);
                assert!(in_band(r.power_save_pct, PAPER_POWER_BAND), "{}", r.format);
            }
        }
        assert!(!in_band(2.0, PAPER_AREA_BAND));
        assert!(!in_band(27.0, PAPER_POWER_BAND));
    }
}
