//! The checked-in DSE artifact: `DSE_report.json`.
//!
//! Where `dse::report` renders the paper's tables for humans, this module
//! emits the machine-checkable sweep the CI lane regenerates and greps:
//! serial-alignment baseline vs the online fused-operator trees of
//! [`SUITE_RADICES`], per paper format, at the per-format pipeline-depth
//! policy and one stage deeper. Each online row carries its area/power
//! delta against the serial baseline *at the same depth*, and the summary
//! flags the per-format best savings as inside or outside the paper's
//! §IV-A bands ([`PAPER_AREA_BAND`] / [`PAPER_POWER_BAND`]).
//!
//! The JSON is hand-rolled (schema `ofa-dse-v1`) with fixed-decimal float
//! formatting so a double render is byte-identical — the same contract as
//! `ANALYSIS_report.json`.
#![deny(clippy::cast_precision_loss)]

use super::paper::{in_band, PAPER_AREA_BAND, PAPER_POWER_BAND};
use crate::arith::tree::RadixConfig;
use crate::coordinator::Coordinator;
use crate::formats::PAPER_FORMATS;
use crate::hw::design::{attach_power, evaluate_area_at, DesignPoint};
use crate::hw::generate::{radix_tree_config, SUITE_RADICES};
use crate::hw::pipeline::paper_stages;
use crate::workload::bert::power_trace;
use std::fmt::Write as _;
use std::sync::Arc;

/// One evaluated design: a (format, config, depth) cell of the sweep with
/// its deltas against the serial baseline at the same depth.
#[derive(Clone, Debug)]
pub struct DseRow {
    pub format: &'static str,
    pub config: String,
    /// Operator radix knob that produced `config` (`0` = serial baseline).
    pub radix: u32,
    pub stages: u32,
    /// Achieved clock (the target, or the bumped minimum when infeasible).
    pub clock_ns: f64,
    pub feasible: bool,
    pub area_um2: f64,
    pub power_mw: f64,
    pub reg_bits: u64,
    pub area_delta_pct: f64,
    pub power_delta_pct: f64,
}

/// Per-format verdict at the paper's pipeline-depth policy.
#[derive(Clone, Debug)]
pub struct DseSummary {
    pub format: &'static str,
    pub stages: u32,
    pub best_area_config: String,
    pub best_area_save_pct: f64,
    pub area_in_band: bool,
    pub best_power_config: String,
    pub best_power_save_pct: f64,
    pub power_in_band: bool,
}

/// The full artifact behind `repro dse`.
#[derive(Clone, Debug)]
pub struct DseReport {
    pub n_terms: u32,
    pub vectors: usize,
    pub clock_ns: f64,
    pub rows: Vec<DseRow>,
    pub summary: Vec<DseSummary>,
}

/// Run the sweep: for every paper format, evaluate the serial baseline and
/// one online tree per [`SUITE_RADICES`] entry at the per-format policy
/// depth and one stage deeper, with workload-driven power from `vectors`
/// BERT-shaped operand vectors. Deterministic for fixed inputs — the
/// coordinator preserves job order and the trace seed is pinned.
pub fn dse_report(n: u32, vectors: usize, clock_ns: f64, coord: &Coordinator) -> DseReport {
    let mut rows = Vec::new();
    let mut summary = Vec::new();
    for fmt in PAPER_FORMATS {
        let trace = Arc::new(power_trace(fmt, n as usize, vectors, 0xD5E ^ u64::from(n)));
        let policy = paper_stages(fmt, n);
        let mut jobs: Vec<(u32, u32, RadixConfig)> = Vec::new();
        for off in [0u32, 1] {
            jobs.push((policy + off, 0, RadixConfig::baseline(n)));
            for r in SUITE_RADICES {
                let cfg = radix_tree_config(n, r).expect("suite radices factor n");
                jobs.push((policy + off, r, cfg));
            }
        }
        let tv = Arc::clone(&trace);
        let points: Vec<(u32, u32, DesignPoint)> = coord.run(
            &format!("dse {} N={n}", fmt.name),
            jobs,
            move |(stages, radix, cfg): (u32, u32, RadixConfig)| {
                let mut p = evaluate_area_at(fmt, n, &cfg, clock_ns, stages);
                attach_power(&mut p, &tv.vectors);
                (stages, radix, p)
            },
        );
        for off in [0u32, 1] {
            let stages = policy + off;
            let group: Vec<_> = points.iter().filter(|(s, _, _)| *s == stages).collect();
            let base = &group[0].2;
            debug_assert!(base.config.is_baseline());
            let bpw = base.power_mw.unwrap_or(1.0);
            for (_, radix, p) in &group {
                let pw = p.power_mw.unwrap_or(0.0);
                rows.push(DseRow {
                    format: fmt.name,
                    config: p.config.to_string(),
                    radix: *radix,
                    stages,
                    clock_ns: p.clock_ns,
                    feasible: p.feasible,
                    area_um2: p.area_um2,
                    power_mw: pw,
                    reg_bits: p.reg_bits,
                    area_delta_pct: 100.0 * (p.area_um2 - base.area_um2) / base.area_um2,
                    power_delta_pct: 100.0 * (pw - bpw) / bpw,
                });
            }
            if stages == policy {
                let online: Vec<&DseRow> = rows
                    .iter()
                    .filter(|r| r.format == fmt.name && r.stages == stages && r.radix != 0)
                    .collect();
                let ba = online
                    .iter()
                    .min_by(|a, b| a.area_delta_pct.partial_cmp(&b.area_delta_pct).unwrap())
                    .expect("at least one online row");
                let bp = online
                    .iter()
                    .min_by(|a, b| a.power_delta_pct.partial_cmp(&b.power_delta_pct).unwrap())
                    .expect("at least one online row");
                summary.push(DseSummary {
                    format: fmt.name,
                    stages,
                    best_area_config: ba.config.clone(),
                    best_area_save_pct: -ba.area_delta_pct,
                    area_in_band: in_band(-ba.area_delta_pct, PAPER_AREA_BAND),
                    best_power_config: bp.config.clone(),
                    best_power_save_pct: -bp.power_delta_pct,
                    power_in_band: in_band(-bp.power_delta_pct, PAPER_POWER_BAND),
                });
            }
        }
    }
    DseReport { n_terms: n, vectors, clock_ns, rows, summary }
}

impl DseReport {
    /// Byte-deterministic JSON (schema `ofa-dse-v1`): fixed key order,
    /// fixed-decimal floats, two renders of the same report are identical.
    pub fn to_json(&self) -> String {
        let mut s = String::with_capacity(32 * 1024);
        s.push_str("{\n");
        s.push_str("  \"schema\": \"ofa-dse-v1\",\n");
        let _ = writeln!(s, "  \"n_terms\": {},", self.n_terms);
        let _ = writeln!(s, "  \"vectors\": {},", self.vectors);
        let _ = writeln!(s, "  \"clock_ns\": {:.2},", self.clock_ns);
        let _ = writeln!(
            s,
            "  \"paper_area_band_pct\": [{:.1}, {:.1}],",
            PAPER_AREA_BAND.0, PAPER_AREA_BAND.1
        );
        let _ = writeln!(
            s,
            "  \"paper_power_band_pct\": [{:.1}, {:.1}],",
            PAPER_POWER_BAND.0, PAPER_POWER_BAND.1
        );
        s.push_str("  \"rows\": [\n");
        let n = self.rows.len();
        for (i, r) in self.rows.iter().enumerate() {
            s.push_str("    {\n");
            let _ = writeln!(s, "      \"format\": \"{}\",", r.format);
            let _ = writeln!(s, "      \"config\": \"{}\",", r.config);
            let _ = writeln!(s, "      \"radix\": {},", r.radix);
            let _ = writeln!(s, "      \"stages\": {},", r.stages);
            let _ = writeln!(s, "      \"clock_ns\": {:.2},", r.clock_ns);
            let _ = writeln!(s, "      \"feasible\": {},", r.feasible);
            let _ = writeln!(s, "      \"area_um2\": {:.1},", r.area_um2);
            let _ = writeln!(s, "      \"power_mw\": {:.3},", r.power_mw);
            let _ = writeln!(s, "      \"reg_bits\": {},", r.reg_bits);
            let _ = writeln!(s, "      \"area_delta_pct\": {:.1},", r.area_delta_pct);
            let _ = writeln!(s, "      \"power_delta_pct\": {:.1}", r.power_delta_pct);
            s.push_str(if i + 1 == n { "    }\n" } else { "    },\n" });
        }
        s.push_str("  ],\n");
        s.push_str("  \"summary\": [\n");
        let m = self.summary.len();
        for (i, v) in self.summary.iter().enumerate() {
            s.push_str("    {\n");
            let _ = writeln!(s, "      \"format\": \"{}\",", v.format);
            let _ = writeln!(s, "      \"stages\": {},", v.stages);
            let _ = writeln!(s, "      \"best_area_config\": \"{}\",", v.best_area_config);
            let _ = writeln!(s, "      \"best_area_save_pct\": {:.1},", v.best_area_save_pct);
            let _ = writeln!(s, "      \"area_in_band\": {},", v.area_in_band);
            let _ = writeln!(s, "      \"best_power_config\": \"{}\",", v.best_power_config);
            let _ = writeln!(s, "      \"best_power_save_pct\": {:.1},", v.best_power_save_pct);
            let _ = writeln!(s, "      \"power_in_band\": {}", v.power_in_band);
            s.push_str(if i + 1 == m { "    }\n" } else { "    },\n" });
        }
        s.push_str("  ]\n");
        s.push_str("}\n");
        s
    }

    /// Human summary: one paper-savings row per format.
    pub fn summary_lines(&self) -> String {
        let mut out = String::new();
        for v in &self.summary {
            let _ = writeln!(
                out,
                "{:<10} @{} stages: best area {} saves {:.1}% [{}], best power {} saves {:.1}% [{}]",
                v.format,
                v.stages,
                v.best_area_config,
                v.best_area_save_pct,
                if v.area_in_band { "in band" } else { "out of band" },
                v.best_power_config,
                v.best_power_save_pct,
                if v.power_in_band { "in band" } else { "out of band" },
            );
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_sweep_has_expected_shape() {
        let coord = Coordinator::new(4);
        let report = dse_report(16, 16, 1.0, &coord);
        // 5 formats x 2 depths x (serial + 3 radices).
        assert_eq!(report.rows.len(), 5 * 2 * 4);
        assert_eq!(report.summary.len(), 5);
        for chunk in report.rows.chunks(4) {
            assert_eq!(chunk[0].radix, 0);
            assert!((chunk[0].area_delta_pct).abs() < 1e-12);
            assert!(chunk.iter().all(|r| r.area_um2 > 0.0 && r.power_mw > 0.0));
        }
        // Radix 8 over 16 terms is the paper's 8-2 structure.
        assert!(report.rows.iter().any(|r| r.radix == 8 && r.config == "8-2"));
    }

    #[test]
    fn json_renders_byte_identically_twice() {
        let coord = Coordinator::new(4);
        let report = dse_report(16, 16, 1.0, &coord);
        let a = report.to_json();
        assert_eq!(a, report.to_json());
        assert!(a.contains("\"schema\": \"ofa-dse-v1\""));
        assert!(a.contains("\"best_power_save_pct\""));
        assert!(report.summary_lines().contains("best area"));
    }
}
