//! Sweep engine: evaluate every radix configuration of an N-term adder in
//! parallel over the experiment coordinator.

#![deny(clippy::cast_precision_loss)]

use super::super::coordinator::Coordinator;
use crate::arith::tree::{enumerate_configs, RadixConfig};
use crate::formats::FpFormat;
use crate::hw::design::{attach_power, evaluate_area_at, DesignPoint};
use crate::hw::pipeline::paper_stages;
use crate::workload::Trace;
use std::sync::Arc;

/// Sweep parameters (defaults = the paper's §IV operating point).
#[derive(Clone, Debug)]
pub struct SweepOptions {
    /// Clock period target (paper: 1 GHz ⇒ 1.0 ns).
    pub clock_ns: f64,
    /// Pipeline depth; `None` = the paper's per-format policy.
    pub stages: Option<u32>,
    /// Cap on enumerated configurations (the N=64 space has 32 entries; a
    /// cap keeps quick runs quick). `0` = no cap.
    pub max_configs: usize,
}

impl Default for SweepOptions {
    fn default() -> Self {
        SweepOptions { clock_ns: 1.0, stages: None, max_configs: 0 }
    }
}

/// Evaluate all configurations of an `n`-term `fmt` adder; attaches power
/// when a workload trace is supplied. The baseline (radix-N) is always the
/// first returned point.
pub fn sweep_format(
    fmt: FpFormat,
    n: u32,
    opts: &SweepOptions,
    trace: Option<Arc<Trace>>,
    coord: &Coordinator,
) -> Vec<DesignPoint> {
    let stages = opts.stages.unwrap_or_else(|| paper_stages(fmt, n));
    let mut configs = enumerate_configs(n);
    // Baseline first, then by level count (the paper's Fig. 4 ordering).
    configs.sort_by_key(|c| (c.levels(), c.to_string()));
    let baseline_pos = configs.iter().position(|c| c.is_baseline()).unwrap();
    configs.swap(0, baseline_pos);
    if opts.max_configs > 0 && configs.len() > opts.max_configs {
        configs.truncate(opts.max_configs);
    }
    let clock = opts.clock_ns;
    coord.run(
        &format!("sweep {fmt} N={n}"),
        configs,
        move |cfg: RadixConfig| {
            let mut point = evaluate_area_at(fmt, n, &cfg, clock, stages);
            if let Some(t) = &trace {
                attach_power(&mut point, &t.vectors);
            }
            point
        },
    )
}

/// The best (minimum) point by a key, never the baseline itself.
pub fn best_proposed<'a, F: Fn(&DesignPoint) -> f64>(
    points: &'a [DesignPoint],
    key: F,
) -> &'a DesignPoint {
    points
        .iter()
        .filter(|p| !p.config.is_baseline())
        .min_by(|a, b| key(a).partial_cmp(&key(b)).unwrap())
        .expect("at least one proposed configuration")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::BF16;
    use crate::workload::bert::power_trace;

    #[test]
    fn sweep_covers_all_configs_with_baseline_first() {
        let coord = Coordinator::new(4);
        let points = sweep_format(BF16, 16, &SweepOptions::default(), None, &coord);
        assert_eq!(points.len(), 8); // ordered factorizations of 16
        assert!(points[0].config.is_baseline());
        assert!(points.iter().all(|p| p.area_um2 > 0.0));
    }

    #[test]
    fn sweep_with_power_attaches_power_everywhere() {
        let coord = Coordinator::new(4);
        let trace = Arc::new(power_trace(BF16, 16, 64, 3));
        let opts = SweepOptions { max_configs: 4, ..Default::default() };
        let points = sweep_format(BF16, 16, &opts, Some(trace), &coord);
        assert_eq!(points.len(), 4);
        assert!(points.iter().all(|p| p.power_mw.unwrap() > 0.0));
    }

    #[test]
    fn best_proposed_is_not_baseline() {
        let coord = Coordinator::new(2);
        let points = sweep_format(BF16, 8, &SweepOptions::default(), None, &coord);
        let best = best_proposed(&points, |p| p.area_um2);
        assert!(!best.config.is_baseline());
    }
}
