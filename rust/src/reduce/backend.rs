//! The [`Reducer`] trait — the one lifecycle contract every reduction
//! backend implements (DESIGN.md §Reducer) — plus the four in-tree
//! implementations the registry ships.
//!
//! The lifecycle is `ingest → partial → merge/absorb → finish`:
//!
//! * [`Reducer::ingest`] / [`Reducer::ingest_decoded`] absorb finite terms
//!   (specials are screened by the caller, exactly as for
//!   [`crate::arith::adder::MultiTermAdder`]);
//! * [`Reducer::partial`] captures the state as a mergeable, serializable
//!   [`Partial`];
//! * [`Reducer::absorb`] folds in a partial produced by **any** backend of
//!   the same [`AccSpec`] (cross-backend merges resolve through the
//!   aligned domain — bit-identical on exact specs);
//! * [`Reducer::finish`] resolves to the final `[λ; acc; sticky]` state,
//!   ready for [`crate::arith::normalize::normalize_round`].
//!
//! Contract every registered backend is held to (and the registry-driven
//! conformance suite verifies, see [`super::conformance`]): under an exact
//! [`AccSpec`], any interleaving of `ingest`/`absorb` calls over the same
//! multiset of terms finishes with the **bit-identical** state of the
//! scalar `⊙` fold (eq. 10). Under a truncated spec each backend is its
//! own deterministic parenthesisation; [`super::Capabilities`] says which
//! additional guarantees (fold-identical dropped bits, order invariance)
//! survive.

// Exact-datapath module: native float arithmetic and lossy casts are
// forbidden here (clippy.toml, DESIGN.md §Analysis).
#![deny(clippy::float_arithmetic, clippy::cast_precision_loss)]

use super::partial::{Partial, PartialState};
use super::registry::tele_family_named;
use crate::accum::Eia;
use crate::arith::kernel::{block_state, reduce_terms};
use crate::arith::simd::{block_state_simd, reduce_terms_simd};
use crate::arith::operator::{op_combine, AlignAcc};
use crate::arith::{AccSpec, WideInt};
use crate::formats::Fp;
use crate::telemetry::{self, TraceEvent};

/// Lift one pre-decoded `(eff_exp, signed_sig)` lane into the operator
/// domain — the runtime's `(e, m)` field convention: a zero significand is
/// the identity regardless of its exponent field.
#[inline]
fn leaf_decoded(eff: i32, sig: i64, spec: AccSpec) -> AlignAcc {
    if sig == 0 {
        return AlignAcc::IDENTITY;
    }
    AlignAcc { lambda: eff, acc: WideInt::from_i64_shl(sig, spec.f), sticky: false }
}

/// Trace one reducer-lifecycle resolution under the caller's ambient
/// span (no-op while the ring is off) — the `reduce::backend` leg of
/// the causal trace.
fn trace_finish(backend: &'static str, terms: u64) {
    telemetry::global().trace.record(TraceEvent::ReduceFinished { backend, terms });
}

/// A stateful reduction backend (see the module docs for the lifecycle and
/// the cross-backend equivalence contract).
pub trait Reducer {
    /// The registry name of the backend this reducer runs.
    fn backend_name(&self) -> &'static str;

    /// The accumulator spec this reducer was planned for.
    fn spec(&self) -> AccSpec;

    /// Absorb a slice of finite terms (screen Inf/NaN first).
    fn ingest(&mut self, terms: &[Fp]);

    /// Absorb pre-decoded `(eff_exp, signed_sig)` lanes — the artifact
    /// runtime's field convention; dead lanes carry `sig == 0` and are
    /// identities regardless of their exponent entry.
    fn ingest_decoded(&mut self, eff: &[i32], sig: &[i64]);

    /// Fold in a partial produced by any reducer under the same spec.
    fn absorb(&mut self, partial: &Partial);

    /// Capture the current state as a mergeable, serializable partial.
    fn partial(&self) -> Partial;

    /// Resolve to the final `[λ; acc; sticky]` state.
    fn finish(&self) -> AlignAcc;

    /// Terms covered so far (zeros included).
    fn terms(&self) -> u64;

    /// Forget everything — hot loops reuse one reducer across many
    /// independent reductions instead of re-boxing per reduction.
    fn reset(&mut self);
}

/// The scalar reference backend: the serial radix-2 `⊙` fold (Algorithm 3).
/// Incremental ingest is the same left fold, so any split of the input
/// across `ingest` calls is bit-identical to one flat
/// [`crate::arith::kernel::scalar_fold`] in **every** spec, truncated
/// included.
pub struct FoldReducer {
    spec: AccSpec,
    state: AlignAcc,
    terms: u64,
    tele: &'static telemetry::ReduceFamily,
}

impl FoldReducer {
    pub fn new(spec: AccSpec) -> Self {
        FoldReducer {
            spec,
            state: AlignAcc::IDENTITY,
            terms: 0,
            tele: tele_family_named("scalar"),
        }
    }
}

impl Reducer for FoldReducer {
    fn backend_name(&self) -> &'static str {
        "scalar"
    }

    fn spec(&self) -> AccSpec {
        self.spec
    }

    fn ingest(&mut self, terms: &[Fp]) {
        for t in terms {
            debug_assert!(t.is_finite(), "reducers require finite terms");
            self.state = op_combine(&self.state, &AlignAcc::leaf(*t, self.spec), self.spec);
        }
        self.terms += terms.len() as u64;
        if telemetry::enabled() {
            self.tele.ingest_calls.inc();
            self.tele.ingest_terms.add(terms.len() as u64);
        }
    }

    fn ingest_decoded(&mut self, eff: &[i32], sig: &[i64]) {
        debug_assert_eq!(eff.len(), sig.len());
        for (&e, &s) in eff.iter().zip(sig) {
            self.state = op_combine(&self.state, &leaf_decoded(e, s, self.spec), self.spec);
        }
        self.terms += eff.len() as u64;
        if telemetry::enabled() {
            self.tele.ingest_calls.inc();
            self.tele.ingest_terms.add(eff.len() as u64);
        }
    }

    fn absorb(&mut self, partial: &Partial) {
        self.state = op_combine(&self.state, &partial.resolve(self.spec), self.spec);
        self.terms += partial.terms;
        if telemetry::enabled() {
            self.tele.absorbs.inc();
        }
    }

    fn partial(&self) -> Partial {
        Partial::aligned(self.state, self.terms)
    }

    fn finish(&self) -> AlignAcc {
        if telemetry::enabled() {
            self.tele.finishes.inc();
        }
        trace_finish(self.backend_name(), self.terms);
        self.state
    }

    fn terms(&self) -> u64 {
        self.terms
    }

    fn reset(&mut self) {
        self.state = AlignAcc::IDENTITY;
        self.terms = 0;
    }
}

/// The batched SoA kernel backend: each ingested slice reduces blockwise
/// ([`reduce_terms`] / [`block_state`]) and chains into the running state
/// with `⊙`. A single `ingest` of a whole slice is bit-identical to the
/// free-function kernel (the identity prefix is transparent); block
/// boundaries restart at every `ingest` call, which exact specs cannot
/// observe (eq. 10).
pub struct KernelReducer {
    spec: AccSpec,
    block: usize,
    state: AlignAcc,
    terms: u64,
    tele: &'static telemetry::ReduceFamily,
}

impl KernelReducer {
    /// `block` must be ≥ 1 — the plan/parse layer rejects 0 before a
    /// reducer is ever built; the assertion keeps the contract loud in
    /// release builds (analysis checked invariant).
    pub fn new(spec: AccSpec, block: usize) -> Self {
        assert!(block >= 1, "kernel block must be >= 1 (enforced at plan build)");
        KernelReducer {
            spec,
            block,
            state: AlignAcc::IDENTITY,
            terms: 0,
            tele: tele_family_named("kernel"),
        }
    }
}

impl Reducer for KernelReducer {
    fn backend_name(&self) -> &'static str {
        "kernel"
    }

    fn spec(&self) -> AccSpec {
        self.spec
    }

    fn ingest(&mut self, terms: &[Fp]) {
        if !terms.is_empty() {
            // Kernel-path health counters flush inside `reduce_terms`.
            let part = reduce_terms(terms, self.block, self.spec);
            self.state = op_combine(&self.state, &part, self.spec);
        }
        self.terms += terms.len() as u64;
        if telemetry::enabled() {
            self.tele.ingest_calls.inc();
            self.tele.ingest_terms.add(terms.len() as u64);
        }
    }

    fn ingest_decoded(&mut self, eff: &[i32], sig: &[i64]) {
        debug_assert_eq!(eff.len(), sig.len());
        // Accumulate per-call locals and flush once: the enabled path
        // costs a handful of relaxed adds per *call*, not per block.
        let (mut blocks, mut sticky) = (0u64, 0u64);
        for (e_chunk, s_chunk) in eff.chunks(self.block).zip(sig.chunks(self.block)) {
            let part = block_state(e_chunk, s_chunk, self.spec);
            blocks += 1;
            sticky += part.sticky as u64;
            self.state = op_combine(&self.state, &part, self.spec);
        }
        self.terms += eff.len() as u64;
        if telemetry::enabled() {
            self.tele.ingest_calls.inc();
            self.tele.ingest_terms.add(eff.len() as u64);
            let k = &telemetry::global().kernel;
            k.block_sweeps.add(blocks);
            k.lanes.add(eff.len() as u64);
            if !eff.is_empty() {
                k.block_lanes.observe(eff.len().min(self.block) as u64);
            }
            if self.spec.narrow {
                k.narrow_blocks.add(blocks);
            } else {
                k.wide_blocks.add(blocks);
            }
            k.sticky_activations.add(sticky);
        }
    }

    fn absorb(&mut self, partial: &Partial) {
        self.state = op_combine(&self.state, &partial.resolve(self.spec), self.spec);
        self.terms += partial.terms;
        if telemetry::enabled() {
            self.tele.absorbs.inc();
        }
    }

    fn partial(&self) -> Partial {
        Partial::aligned(self.state, self.terms)
    }

    fn finish(&self) -> AlignAcc {
        if telemetry::enabled() {
            self.tele.finishes.inc();
        }
        trace_finish(self.backend_name(), self.terms);
        self.state
    }

    fn terms(&self) -> u64 {
        self.terms
    }

    fn reset(&mut self) {
        self.state = AlignAcc::IDENTITY;
        self.terms = 0;
    }
}

/// The vectorized SoA kernel backend: [`KernelReducer`]'s exact lifecycle
/// over the SIMD block datapath ([`reduce_terms_simd`] /
/// [`block_state_simd`]) — bit-identical to the kernel at every
/// `(spec, block)` by construction, so everything the kernel's docs say
/// about ingest seams and block boundaries applies verbatim.
pub struct SimdReducer {
    spec: AccSpec,
    block: usize,
    state: AlignAcc,
    terms: u64,
    tele: &'static telemetry::ReduceFamily,
}

impl SimdReducer {
    /// `block` must be ≥ 1 (same contract as [`KernelReducer::new`]).
    pub fn new(spec: AccSpec, block: usize) -> Self {
        assert!(block >= 1, "simd block must be >= 1 (enforced at plan build)");
        SimdReducer {
            spec,
            block,
            state: AlignAcc::IDENTITY,
            terms: 0,
            tele: tele_family_named("simd"),
        }
    }
}

impl Reducer for SimdReducer {
    fn backend_name(&self) -> &'static str {
        "simd"
    }

    fn spec(&self) -> AccSpec {
        self.spec
    }

    fn ingest(&mut self, terms: &[Fp]) {
        if !terms.is_empty() {
            // Kernel-path health counters flush inside `reduce_terms_simd`.
            let part = reduce_terms_simd(terms, self.block, self.spec);
            self.state = op_combine(&self.state, &part, self.spec);
        }
        self.terms += terms.len() as u64;
        if telemetry::enabled() {
            self.tele.ingest_calls.inc();
            self.tele.ingest_terms.add(terms.len() as u64);
        }
    }

    fn ingest_decoded(&mut self, eff: &[i32], sig: &[i64]) {
        debug_assert_eq!(eff.len(), sig.len());
        let (mut blocks, mut sticky) = (0u64, 0u64);
        for (e_chunk, s_chunk) in eff.chunks(self.block).zip(sig.chunks(self.block)) {
            let part = block_state_simd(e_chunk, s_chunk, self.spec);
            blocks += 1;
            sticky += part.sticky as u64;
            self.state = op_combine(&self.state, &part, self.spec);
        }
        self.terms += eff.len() as u64;
        if telemetry::enabled() {
            self.tele.ingest_calls.inc();
            self.tele.ingest_terms.add(eff.len() as u64);
            let k = &telemetry::global().kernel;
            k.block_sweeps.add(blocks);
            k.lanes.add(eff.len() as u64);
            if !eff.is_empty() {
                k.block_lanes.observe(eff.len().min(self.block) as u64);
            }
            if self.spec.narrow {
                k.narrow_blocks.add(blocks);
            } else {
                k.wide_blocks.add(blocks);
            }
            k.sticky_activations.add(sticky);
        }
    }

    fn absorb(&mut self, partial: &Partial) {
        self.state = op_combine(&self.state, &partial.resolve(self.spec), self.spec);
        self.terms += partial.terms;
        if telemetry::enabled() {
            self.tele.absorbs.inc();
        }
    }

    fn partial(&self) -> Partial {
        Partial::aligned(self.state, self.terms)
    }

    fn finish(&self) -> AlignAcc {
        if telemetry::enabled() {
            self.tele.finishes.inc();
        }
        trace_finish(self.backend_name(), self.terms);
        self.state
    }

    fn terms(&self) -> u64 {
        self.terms
    }

    fn reset(&mut self) {
        self.state = AlignAcc::IDENTITY;
        self.terms = 0;
    }
}

/// The deferred-alignment backend: terms bank into an exponent-indexed
/// accumulator ([`Eia`]) and the alignment bill is paid once at `finish`.
/// Deferred partials absorbed from peers merge losslessly (exact pointwise
/// bin adds under any spec); an *aligned* partial cannot re-enter the
/// deferred domain, so it parks in a `⊙` carry that joins at the end —
/// bit-identical to any other grouping on exact specs.
pub struct EiaReducer {
    spec: AccSpec,
    eia: Eia,
    carry: AlignAcc,
    carry_terms: u64,
    tele: &'static telemetry::ReduceFamily,
}

impl EiaReducer {
    pub fn new(spec: AccSpec) -> Self {
        EiaReducer {
            spec,
            eia: Eia::new(),
            carry: AlignAcc::IDENTITY,
            carry_terms: 0,
            tele: tele_family_named("eia"),
        }
    }
}

impl Reducer for EiaReducer {
    fn backend_name(&self) -> &'static str {
        "eia"
    }

    fn spec(&self) -> AccSpec {
        self.spec
    }

    fn ingest(&mut self, terms: &[Fp]) {
        self.eia.ingest_terms(terms);
        if telemetry::enabled() {
            self.tele.ingest_calls.inc();
            self.tele.ingest_terms.add(terms.len() as u64);
        }
    }

    fn ingest_decoded(&mut self, eff: &[i32], sig: &[i64]) {
        debug_assert_eq!(eff.len(), sig.len());
        for (&e, &s) in eff.iter().zip(sig) {
            self.eia.ingest_decoded(e, s);
        }
        if telemetry::enabled() {
            self.tele.ingest_calls.inc();
            self.tele.ingest_terms.add(eff.len() as u64);
        }
    }

    fn absorb(&mut self, partial: &Partial) {
        match &partial.state {
            PartialState::Deferred(snap) => self.eia.merge_from(&snap.restore()),
            PartialState::Aligned(a) => {
                self.carry = op_combine(&self.carry, a, self.spec);
                self.carry_terms += partial.terms;
            }
        }
        if telemetry::enabled() {
            self.tele.absorbs.inc();
        }
    }

    fn partial(&self) -> Partial {
        if self.carry_terms == 0 && self.carry.is_identity() {
            Partial::deferred(self.eia.snapshot())
        } else {
            Partial::aligned(self.finish(), self.terms())
        }
    }

    fn finish(&self) -> AlignAcc {
        if telemetry::enabled() {
            self.tele.finishes.inc();
        }
        trace_finish(self.backend_name(), self.terms());
        let drained = self.eia.drain(self.spec);
        if self.carry.is_identity() {
            drained
        } else {
            op_combine(&drained, &self.carry, self.spec)
        }
    }

    fn terms(&self) -> u64 {
        self.eia.terms() + self.carry_terms
    }

    fn reset(&mut self) {
        self.eia = Eia::new();
        self.carry = AlignAcc::IDENTITY;
        self.carry_terms = 0;
    }
}

/// One-shot slice reduction through a trait-object reducer
/// (reset → ingest → finish). This is the exact loop body of the
/// `reduce dispatch trait` series in `BENCH_perf.json`, benchmarked
/// against the registry's direct fn-pointer `reduce` path.
pub fn reduce_once(reducer: &mut dyn Reducer, terms: &[Fp]) -> AlignAcc {
    reducer.reset();
    reducer.ingest(terms);
    reducer.finish()
}

#[cfg(test)]
#[allow(clippy::float_arithmetic, clippy::cast_precision_loss, clippy::disallowed_methods)]
mod tests {
    use super::*;
    use crate::arith::kernel::scalar_fold;
    use crate::formats::{Fp, BF16, FP32};
    use crate::util::prng::XorShift;

    fn mixed(rng: &mut XorShift, n: usize) -> Vec<Fp> {
        (0..n)
            .map(|_| match rng.below(8) {
                0 => Fp::zero(BF16),
                1 | 2 => rng.gen_fp_subnormal(BF16),
                _ => rng.gen_fp_full(BF16),
            })
            .collect()
    }

    fn reducers(spec: AccSpec) -> Vec<Box<dyn Reducer>> {
        vec![
            Box::new(FoldReducer::new(spec)),
            Box::new(KernelReducer::new(spec, 7)),
            Box::new(SimdReducer::new(spec, 7)),
            Box::new(EiaReducer::new(spec)),
        ]
    }

    #[test]
    fn split_ingest_matches_one_shot_fold_on_exact_specs() {
        let spec = AccSpec::exact(BF16);
        let mut rng = XorShift::new(0xBEC1);
        for n in [1usize, 9, 64, 150] {
            let ts = mixed(&mut rng, n);
            let want = scalar_fold(&ts, spec);
            for mut r in reducers(spec) {
                // Ingest in ragged slices; exact specs cannot see the seams.
                for chunk in ts.chunks(5) {
                    r.ingest(chunk);
                }
                assert_eq!(r.finish(), want, "{} n={n}", r.backend_name());
                assert_eq!(r.terms(), n as u64);
                r.reset();
                assert!(r.finish().is_identity());
                assert_eq!(r.terms(), 0);
                // Reuse after reset: one-shot ingest, same bits.
                r.ingest(&ts);
                assert_eq!(r.finish(), want, "{} reused", r.backend_name());
            }
        }
    }

    #[test]
    fn absorb_cross_backend_partials_matches_one_shot() {
        let spec = AccSpec::exact(BF16);
        let mut rng = XorShift::new(0xBEC2);
        let ts = mixed(&mut rng, 120);
        let want = scalar_fold(&ts, spec);
        // Every (consumer, producer) backend pair: producer reduces the
        // tail, consumer ingests the head and absorbs the producer's
        // partial — bit-identical to the flat fold.
        for mut consumer in reducers(spec) {
            for mut producer in reducers(spec) {
                consumer.reset();
                producer.reset();
                producer.ingest(&ts[70..]);
                consumer.ingest(&ts[..70]);
                consumer.absorb(&producer.partial());
                assert_eq!(
                    consumer.finish(),
                    want,
                    "{} absorbing {}",
                    consumer.backend_name(),
                    producer.backend_name()
                );
                assert_eq!(consumer.terms(), 120);
            }
        }
    }

    #[test]
    fn decoded_lane_ingest_matches_term_ingest() {
        let mut rng = XorShift::new(0xBEC3);
        for spec in [AccSpec::exact(FP32), AccSpec::truncated(16)] {
            let ts: Vec<Fp> = (0..48).map(|_| rng.gen_fp_full(FP32)).collect();
            let eff: Vec<i32> = ts.iter().map(|t| t.eff_exp()).collect();
            let sig: Vec<i64> = ts.iter().map(|t| t.signed_sig()).collect();
            for mut r in [
                Box::new(FoldReducer::new(spec)) as Box<dyn Reducer>,
                Box::new(KernelReducer::new(spec, 48)),
                Box::new(SimdReducer::new(spec, 48)),
                Box::new(EiaReducer::new(spec)),
            ] {
                let by_terms = reduce_once(&mut *r, &ts);
                r.reset();
                r.ingest_decoded(&eff, &sig);
                assert_eq!(r.finish(), by_terms, "{}", r.backend_name());
                assert_eq!(r.terms(), 48);
            }
        }
    }

    #[test]
    fn eia_partial_stays_deferred_until_an_aligned_absorb() {
        let spec = AccSpec::exact(BF16);
        let mut rng = XorShift::new(0xBEC4);
        let ts = mixed(&mut rng, 40);
        let mut r = EiaReducer::new(spec);
        r.ingest(&ts);
        assert!(matches!(r.partial().state, PartialState::Deferred(_)));
        let aligned = Partial::aligned(scalar_fold(&ts[..3], spec), 3);
        r.absorb(&aligned);
        assert!(matches!(r.partial().state, PartialState::Aligned(_)));
        assert_eq!(r.terms(), 43);
    }
}
