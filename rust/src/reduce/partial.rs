//! [`Partial`]: the one mergeable, serializable partial-reduction state
//! every registered backend produces (DESIGN.md §Reducer).
//!
//! Before this type existed each backend leaked its own partial state
//! through the crate — `AlignAcc`-based [`crate::stream::Segment`]s for the
//! online backends, [`EiaSnapshot`]s for the deferred-alignment EIA — and
//! cross-backend consumers grew special cases (`ShardMap::merge_eia`). A
//! [`Partial`] is the union of both domains behind one `merge`/`resolve`
//! surface and **one byte codec**, so shards, checkpoints and peers ship a
//! single wire type regardless of which backend produced the state.
//!
//! Two variants, because the two domains genuinely differ:
//!
//! * [`PartialState::Aligned`] — the paper's `[λ; acc; sticky]` vector
//!   (eq. 8), produced by the scalar `⊙` fold and the SoA kernel. Merging
//!   two aligned partials is one [`op_combine`].
//! * [`PartialState::Deferred`] — a canonical exponent-bin checkpoint
//!   ([`EiaSnapshot`]), produced by the EIA backend. Merging two deferred
//!   partials is exact (pointwise integer adds) under *any* spec.
//!
//! Cross-domain merges resolve the deferred side under the merge's
//! [`AccSpec`] and combine with `⊙`. Under an exact spec every grouping —
//! pure aligned, pure deferred, or mixed — resolves to bit-identical
//! `(λ, acc, sticky)` (eq. 10 plus the EIA drain-equivalence contract);
//! under a truncated spec each grouping is its own deterministic
//! parenthesisation, exactly as for the backends themselves.

// Exact-datapath module: native float arithmetic and lossy casts are
// forbidden here (clippy.toml, DESIGN.md §Analysis).
#![deny(clippy::float_arithmetic, clippy::cast_precision_loss)]

use crate::accum::EiaSnapshot;
use crate::arith::operator::{op_combine, AlignAcc};
use crate::arith::wide::LIMBS;
use crate::arith::{AccSpec, WideInt};
use crate::telemetry;

/// The backend-domain payload of a [`Partial`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum PartialState {
    /// An aligned `[λ; acc; sticky]` state (scalar fold / SoA kernel).
    Aligned(AlignAcc),
    /// A deferred-alignment exponent-bin checkpoint (EIA).
    Deferred(EiaSnapshot),
}

/// One backend-agnostic partial-reduction state: the payload plus the
/// number of terms it covers (zeros included — the same bookkeeping
/// [`crate::stream::Segment`] carries).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Partial {
    pub state: PartialState,
    pub terms: u64,
}

/// Byte-codec magic + version ("RDP" = reduce partial, format 1).
const MAGIC: [u8; 4] = *b"RDP1";
/// Header: magic (4) + tag (1) + terms (8).
const HEADER_LEN: usize = 13;
/// Aligned payload: lambda (4) + sticky (1) + acc limbs (8 × `LIMBS`).
const ALIGNED_LEN: usize = 4 + 1 + 8 * LIMBS;
const TAG_ALIGNED: u8 = 0;
const TAG_DEFERRED: u8 = 1;

impl Partial {
    /// The identity partial: no terms covered, merges as a no-op.
    pub const IDENTITY: Partial =
        Partial { state: PartialState::Aligned(AlignAcc::IDENTITY), terms: 0 };

    /// An aligned partial over `terms` covered values.
    pub fn aligned(state: AlignAcc, terms: u64) -> Partial {
        Partial { state: PartialState::Aligned(state), terms }
    }

    /// A deferred partial; the term count is the snapshot's own.
    pub fn deferred(snap: EiaSnapshot) -> Partial {
        let terms = snap.terms;
        Partial { state: PartialState::Deferred(snap), terms }
    }

    /// True when no live value has been absorbed (identity of `merge`).
    pub fn is_identity(&self) -> bool {
        match &self.state {
            PartialState::Aligned(a) => a.is_identity(),
            PartialState::Deferred(s) => s.is_identity(),
        }
    }

    /// Resolve to the aligned `[λ; acc; sticky]` state under `spec`
    /// (deferred partials pay their alignment bill here; aligned partials
    /// are returned as-is).
    pub fn resolve(&self, spec: AccSpec) -> AlignAcc {
        match &self.state {
            PartialState::Aligned(a) => *a,
            PartialState::Deferred(s) => s.drain(spec),
        }
    }

    /// Merge two partials under `spec`. Deferred ⊙ deferred stays in the
    /// deferred domain (exact under any spec); any aligned operand forces
    /// an aligned result via `⊙`. Associative in exact specs across all
    /// variant combinations (see the module docs).
    pub fn merge(&self, other: &Partial, spec: AccSpec) -> Partial {
        match (&self.state, &other.state) {
            (PartialState::Deferred(a), PartialState::Deferred(b)) => {
                Partial::deferred(a.merge(b))
            }
            _ => Partial {
                state: PartialState::Aligned(op_combine(
                    &self.resolve(spec),
                    &other.resolve(spec),
                    spec,
                )),
                terms: self.terms + other.terms,
            },
        }
    }

    /// Serialize to the portable little-endian wire format (see `MAGIC`).
    /// This is the **one** codec for shipping reduction state across
    /// shard/checkpoint boundaries, whichever backend produced it.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(HEADER_LEN + ALIGNED_LEN);
        out.extend_from_slice(&MAGIC);
        match &self.state {
            PartialState::Aligned(a) => {
                out.push(TAG_ALIGNED);
                out.extend_from_slice(&self.terms.to_le_bytes());
                out.extend_from_slice(&a.lambda.to_le_bytes());
                out.push(a.sticky as u8);
                for limb in &a.acc.limbs {
                    out.extend_from_slice(&limb.to_le_bytes());
                }
            }
            PartialState::Deferred(s) => {
                out.push(TAG_DEFERRED);
                out.extend_from_slice(&self.terms.to_le_bytes());
                out.extend_from_slice(&s.to_bytes());
            }
        }
        if telemetry::enabled() {
            telemetry::global().stream.codec_bytes_out.add(out.len() as u64);
        }
        out
    }

    /// Deserialize and validate. A corrupted or cross-version buffer must
    /// fail loudly — a garbage partial merged into a live stream would
    /// silently poison every later query.
    pub fn from_bytes(bytes: &[u8]) -> Result<Partial, String> {
        if telemetry::enabled() {
            telemetry::global().stream.codec_bytes_in.add(bytes.len() as u64);
        }
        if bytes.len() < HEADER_LEN {
            return Err(format!("reduce partial too short: {} bytes", bytes.len()));
        }
        if bytes[..4] != MAGIC {
            return Err("reduce partial: bad magic".into());
        }
        let tag = bytes[4];
        let terms = u64::from_le_bytes(bytes[5..13].try_into().unwrap());
        let body = &bytes[HEADER_LEN..];
        match tag {
            TAG_ALIGNED => {
                if body.len() != ALIGNED_LEN {
                    return Err(format!(
                        "reduce partial: aligned payload is {} bytes, expected {ALIGNED_LEN}",
                        body.len()
                    ));
                }
                let lambda = i32::from_le_bytes(body[..4].try_into().unwrap());
                let sticky = match body[4] {
                    0 => false,
                    1 => true,
                    other => {
                        return Err(format!("reduce partial: bad sticky byte {other:#x}"))
                    }
                };
                let mut limbs = [0u64; LIMBS];
                for (i, limb) in limbs.iter_mut().enumerate() {
                    let at = 5 + 8 * i;
                    *limb = u64::from_le_bytes(body[at..at + 8].try_into().unwrap());
                }
                if lambda < 0 {
                    return Err(format!("reduce partial: negative λ {lambda}"));
                }
                Ok(Partial::aligned(
                    AlignAcc { lambda, acc: WideInt { limbs }, sticky },
                    terms,
                ))
            }
            TAG_DEFERRED => {
                let snap = EiaSnapshot::from_bytes(body)?;
                if snap.terms != terms {
                    return Err(format!(
                        "reduce partial: header covers {terms} terms but snapshot covers {}",
                        snap.terms
                    ));
                }
                Ok(Partial::deferred(snap))
            }
            other => Err(format!("reduce partial: unknown state tag {other:#x}")),
        }
    }
}

impl Default for Partial {
    fn default() -> Self {
        Partial::IDENTITY
    }
}

#[allow(clippy::float_arithmetic, clippy::cast_precision_loss, clippy::disallowed_methods)]
#[cfg(test)]
mod tests {
    use super::*;
    use crate::accum::merge::snapshot_terms;
    use crate::arith::kernel::scalar_fold;
    use crate::formats::{Fp, BF16};
    use crate::util::prng::XorShift;

    fn terms(rng: &mut XorShift, n: usize) -> Vec<Fp> {
        (0..n).map(|_| rng.gen_fp_full(BF16)).collect()
    }

    #[test]
    fn identity_is_neutral_in_both_domains() {
        let spec = AccSpec::exact(BF16);
        let mut rng = XorShift::new(0x9A27);
        let ts = terms(&mut rng, 30);
        let aligned = Partial::aligned(scalar_fold(&ts, spec), 30);
        let deferred = Partial::deferred(snapshot_terms(&ts));
        for p in [&aligned, &deferred] {
            let m = Partial::IDENTITY.merge(p, spec);
            assert_eq!(m.resolve(spec), p.resolve(spec));
            assert_eq!(m.terms, 30);
            let m = p.merge(&Partial::IDENTITY, spec);
            assert_eq!(m.resolve(spec), p.resolve(spec));
        }
        assert!(Partial::IDENTITY.is_identity());
        assert_eq!(Partial::default(), Partial::IDENTITY);
    }

    #[test]
    fn mixed_domain_merge_is_bit_identical_on_exact_specs() {
        // aligned ⊙ deferred == deferred ⊙ deferred == the one-shot fold:
        // the drain-equivalence contract lifted to the Partial surface.
        let spec = AccSpec::exact(BF16);
        let mut rng = XorShift::new(0x9A28);
        for n in [2usize, 17, 90] {
            let ts = terms(&mut rng, n);
            let want = scalar_fold(&ts, spec);
            let cut = 1 + rng.below(n as u64 - 1) as usize;
            let a = Partial::aligned(scalar_fold(&ts[..cut], spec), cut as u64);
            let d = Partial::deferred(snapshot_terms(&ts[cut..]));
            for merged in [a.merge(&d, spec), d.merge(&a, spec)] {
                assert_eq!(merged.resolve(spec), want, "n={n} cut={cut}");
                assert_eq!(merged.terms, n as u64);
            }
            // Pure deferred merges stay deferred (lossless under any spec).
            let d2 = Partial::deferred(snapshot_terms(&ts[..cut]));
            let dd = d2.merge(&d, spec);
            assert!(matches!(dd.state, PartialState::Deferred(_)));
            assert_eq!(dd.resolve(spec), want);
        }
    }

    #[test]
    fn codec_roundtrips_aligned_deferred_and_identity() {
        let mut rng = XorShift::new(0x9A29);
        let ts = terms(&mut rng, 64);
        // Truncated-spec aligned snapshot: sticky set, bits already dropped.
        let trunc = AccSpec::truncated(2);
        let cases = [
            Partial::IDENTITY,
            Partial::aligned(scalar_fold(&ts, AccSpec::exact(BF16)), 64),
            Partial::aligned(scalar_fold(&ts, trunc), 64),
            Partial::deferred(snapshot_terms(&ts)),
            Partial::deferred(EiaSnapshot::IDENTITY),
        ];
        for p in &cases {
            let bytes = p.to_bytes();
            let back = Partial::from_bytes(&bytes).expect("roundtrip");
            assert_eq!(&back, p);
        }
    }

    #[test]
    fn codec_rejects_garbage_loudly() {
        let mut rng = XorShift::new(0x9A2A);
        let ts = terms(&mut rng, 40);
        let aligned = Partial::aligned(scalar_fold(&ts, AccSpec::exact(BF16)), 40);
        let deferred = Partial::deferred(snapshot_terms(&ts));
        // Too short / empty.
        assert!(Partial::from_bytes(b"").is_err());
        assert!(Partial::from_bytes(b"RDP1").is_err());
        // Wrong magic (e.g. a raw EIA snapshot shipped on the wrong wire).
        assert!(Partial::from_bytes(&snapshot_terms(&ts).to_bytes()).is_err());
        let mut bad = aligned.to_bytes();
        bad[0] ^= 0xFF;
        assert!(Partial::from_bytes(&bad).is_err());
        // Unknown tag.
        let mut bad = aligned.to_bytes();
        bad[4] = 9;
        assert!(Partial::from_bytes(&bad).is_err());
        // Truncated and padded payloads.
        let bytes = aligned.to_bytes();
        assert!(Partial::from_bytes(&bytes[..bytes.len() - 1]).is_err());
        let mut padded = bytes.clone();
        padded.push(0);
        assert!(Partial::from_bytes(&padded).is_err());
        // Non-boolean sticky byte.
        let mut bad = bytes.clone();
        bad[HEADER_LEN + 4] = 2;
        assert!(Partial::from_bytes(&bad).is_err());
        // Deferred: inner snapshot corruption and term-count mismatch.
        let bytes = deferred.to_bytes();
        assert!(Partial::from_bytes(&bytes[..bytes.len() - 3]).is_err());
        let mut bad = bytes.clone();
        bad[5] ^= 0xFF; // header term count no longer matches the snapshot
        assert!(Partial::from_bytes(&bad).is_err());
    }
}
