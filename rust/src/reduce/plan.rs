//! [`ReducePlan`] and [`PlanBuilder`]: capability negotiation that turned
//! `ReduceBackend::Auto`'s hidden heuristics into an **inspectable plan**
//! (DESIGN.md §Reducer).
//!
//! A plan binds a validated backend selection to an [`AccSpec`] together
//! with the [`Capabilities`] the pair guarantees and a human-readable
//! rationale for *why* that backend was chosen — so a config dump or a
//! `repro backends` listing can answer "which code will run and what does
//! it promise" without reading the dispatch code.

// Exact-datapath module: native float arithmetic and lossy casts are
// forbidden here (clippy.toml, DESIGN.md §Analysis).
#![deny(clippy::float_arithmetic, clippy::cast_precision_loss)]

use super::backend::Reducer;
use super::registry::{self, BackendSel, Capabilities};
use crate::arith::operator::AlignAcc;
use crate::arith::AccSpec;
use crate::formats::Fp;
use crate::telemetry::{self, TraceEvent};

const EXPLICIT: &str = "explicit backend selection";
const NEGOTIATED_EXACT: &str =
    "negotiated: exact spec → SoA kernel (bit-identical to the ⊙ fold by eq. 10)";
const NEGOTIATED_TRUNCATED: &str =
    "negotiated: truncated spec → scalar ⊙ fold (preserves the radix-2 dropped-bit pattern)";
const NEGOTIATED_ORDER_INVARIANT: &str =
    "negotiated: truncated spec + order-invariance → exponent-indexed accumulator";

/// Count a successfully built plan under its negotiation outcome and leave
/// a trace span with the rationale (the trace ring gates itself).
fn record_plan(sel: BackendSel, rationale: &'static str) {
    if telemetry::enabled() {
        let plan = &telemetry::global().plan;
        plan.builds.inc();
        match rationale {
            EXPLICIT => plan.explicit.inc(),
            NEGOTIATED_EXACT => plan.negotiated_exact.inc(),
            NEGOTIATED_TRUNCATED => plan.negotiated_truncated.inc(),
            NEGOTIATED_ORDER_INVARIANT => plan.negotiated_order_invariant.inc(),
            _ => {}
        }
    }
    telemetry::global().trace.record(TraceEvent::PlanNegotiated { backend: sel.name(), rationale });
}

/// An executable reduction plan: spec + backend + negotiated capabilities.
///
/// Plans are `Copy` — build once, hand to every worker.
///
/// ```
/// use online_fp_add::prelude::*;
///
/// // Negotiation (the old `ReduceBackend::Auto`, now inspectable): exact
/// // specs pick the SoA kernel, truncated specs keep the scalar fold.
/// let spec = AccSpec::exact(BF16);
/// let plan = ReducePlan::negotiate(spec);
/// assert_eq!(plan.backend().name(), "kernel");
/// assert!(plan.capabilities().fold_bit_identical);
///
/// // Explicit selection by registry name, through the builder:
/// let eia = ReducePlan::builder(spec).backend_name("eia").unwrap().build().unwrap();
///
/// // On exact specs every registered backend resolves to the same bits:
/// let terms: Vec<Fp> = [1.5, -0.25, 3.0].iter().map(|&x| Fp::from_f64(x, BF16)).collect();
/// assert_eq!(plan.reduce(&terms), eia.reduce(&terms));
///
/// // A zero block is rejected at plan-build time, never clamped:
/// assert!(ReducePlan::builder(spec).block(0).is_err());
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ReducePlan {
    spec: AccSpec,
    sel: BackendSel,
    caps: Capabilities,
    rationale: &'static str,
}

impl ReducePlan {
    /// Negotiate a backend for `spec` with no further requirements — the
    /// replacement for `ReduceBackend::Auto`: the SoA kernel on exact
    /// specs (bit-identical by eq. 10, fastest measured), the scalar fold
    /// on truncated specs (preserving the pre-kernel dropped-bit pattern).
    pub fn negotiate(spec: AccSpec) -> ReducePlan {
        // One negotiation rule, owned by the builder's no-backend branch.
        ReducePlan::builder(spec).build().expect("unconstrained negotiation is infallible")
    }

    /// A plan for an explicit, already-validated selection.
    pub fn with_backend(spec: AccSpec, sel: BackendSel) -> ReducePlan {
        record_plan(sel, EXPLICIT);
        ReducePlan { spec, sel, caps: sel.capabilities(spec), rationale: EXPLICIT }
    }

    /// Start a builder (explicit backend, block size, requirements).
    pub fn builder(spec: AccSpec) -> PlanBuilder {
        PlanBuilder {
            spec,
            sel: None,
            block: None,
            require_order_invariant: false,
            require_fold_bits: false,
        }
    }

    pub fn spec(&self) -> AccSpec {
        self.spec
    }

    /// The backend this plan dispatches to.
    pub fn backend(&self) -> BackendSel {
        self.sel
    }

    /// What the (backend, spec) pair guarantees.
    pub fn capabilities(&self) -> Capabilities {
        self.caps
    }

    /// Why this backend was chosen ("explicit backend selection" or the
    /// negotiation rule that fired).
    pub fn rationale(&self) -> &'static str {
        self.rationale
    }

    /// The rationale compressed to a stable machine-friendly key
    /// (`"explicit"`, `"exact"`, `"truncated"`, `"order-invariant"`) —
    /// what provenance records and dashboards key on, while
    /// [`Self::rationale`] stays the full human-readable sentence.
    pub fn rationale_key(&self) -> &'static str {
        match self.rationale {
            EXPLICIT => "explicit",
            NEGOTIATED_EXACT => "exact",
            NEGOTIATED_TRUNCATED => "truncated",
            NEGOTIATED_ORDER_INVARIANT => "order-invariant",
            _ => "unknown",
        }
    }

    /// One-shot slice reduction on the direct (fn-pointer) dispatch path —
    /// what the old `ReduceBackend::reduce` enum match compiled to.
    pub fn reduce(&self, terms: &[Fp]) -> AlignAcc {
        self.sel.reduce(terms, self.spec)
    }

    /// Build a stateful [`Reducer`] for streaming/mergeable use; call
    /// [`Reducer::reset`] to reuse it across independent reductions.
    pub fn reducer(&self) -> Box<dyn Reducer> {
        self.sel.reducer(self.spec)
    }

    /// One human-readable line: backend, spec, capabilities, rationale.
    pub fn describe(&self) -> String {
        let c = &self.caps;
        format!(
            "{} on {} spec (f={}) — fold_bits={} order_invariant={} lossless_merge={} [{}]",
            self.sel,
            if self.spec.exact { "exact" } else { "truncated" },
            self.spec.f,
            c.fold_bit_identical,
            c.order_invariant,
            c.lossless_merge,
            self.rationale,
        )
    }
}

/// Builder for [`ReducePlan`]: explicit backend and/or block plus
/// capability requirements, validated at [`PlanBuilder::build`].
#[derive(Clone, Debug)]
pub struct PlanBuilder {
    spec: AccSpec,
    sel: Option<BackendSel>,
    block: Option<usize>,
    require_order_invariant: bool,
    require_fold_bits: bool,
}

impl PlanBuilder {
    /// Request an explicit (already validated) selection.
    pub fn backend(mut self, sel: BackendSel) -> Self {
        self.sel = Some(sel);
        self
    }

    /// Request a backend by registry name (`"scalar"`, `"kernel"`,
    /// `"kernel:<block>"`, `"eia"`); errors on unknown names or bad
    /// parameters.
    pub fn backend_name(mut self, name: &str) -> Result<Self, String> {
        self.sel = Some(registry::sel(name)?);
        Ok(self)
    }

    /// Request a block size (block-taking backends only). Zero is an
    /// error here — the plan layer never clamps.
    pub fn block(mut self, block: usize) -> Result<Self, String> {
        if block == 0 {
            return Err("reduce plan: block must be >= 1".into());
        }
        self.block = Some(block);
        Ok(self)
    }

    /// Require a truncated-spec result that is invariant to ingest order
    /// and merge grouping **at the reducer/partial level**: the guarantee
    /// holds while state stays in one [`super::Reducer`] or merges through
    /// deferred [`super::Partial`]s. A pipeline that resolves partials to
    /// aligned states early and `⊙`-merges them in completion order (the
    /// multi-threaded [`crate::stream::StreamEngine`] does exactly that
    /// per chunk) reintroduces order sensitivity in truncated frames —
    /// see the engine docs for its reproducible-replay recipe.
    pub fn require_order_invariant(mut self) -> Self {
        self.require_order_invariant = true;
        self
    }

    /// Require the scalar radix-2 fold's exact dropped-bit pattern.
    pub fn require_fold_bits(mut self) -> Self {
        self.require_fold_bits = true;
        self
    }

    /// Validate and negotiate. With an explicit backend the requirements
    /// are checked against its capabilities; without one, the negotiation
    /// picks the first registered backend that satisfies them.
    pub fn build(self) -> Result<ReducePlan, String> {
        let (sel, rationale) = match self.sel {
            Some(sel) => {
                let sel = match self.block {
                    Some(b) => sel.with_block(b)?,
                    None => sel,
                };
                (sel, EXPLICIT)
            }
            None => {
                if self.spec.exact {
                    // Every backend qualifies on exact specs; the kernel is
                    // the fastest measured (§Perf), honoring a block hint.
                    let mut sel = BackendSel::named("kernel").expect("registered");
                    if let Some(b) = self.block {
                        sel = sel.with_block(b)?;
                    }
                    (sel, NEGOTIATED_EXACT)
                } else if self.block.is_some() {
                    // A block hint must not be dropped on the floor: the
                    // truncated negotiation picks a non-batched backend.
                    return Err(
                        "reduce plan: a block size requires an explicit \"kernel\" \
                         selection under a truncated spec (negotiation picks a \
                         non-batched backend there)"
                            .into(),
                    );
                } else if self.require_order_invariant && self.require_fold_bits {
                    return Err(
                        "reduce plan: no registered backend is both order-invariant and \
                         fold-bit-identical under a truncated spec (the radix-2 fold's \
                         dropped bits depend on term order by construction)"
                            .into(),
                    );
                } else if self.require_order_invariant {
                    (BackendSel::named("eia").expect("registered"), NEGOTIATED_ORDER_INVARIANT)
                } else {
                    (BackendSel::named("scalar").expect("registered"), NEGOTIATED_TRUNCATED)
                }
            }
        };
        let caps = sel.capabilities(self.spec);
        if self.require_order_invariant && !caps.order_invariant {
            return Err(format!(
                "reduce plan: backend {sel} is not order-invariant under this spec \
                 (its truncated dropped bits depend on ingest order); use \"eia\" or an \
                 exact spec"
            ));
        }
        if self.require_fold_bits && !caps.fold_bit_identical {
            return Err(format!(
                "reduce plan: backend {sel} does not reproduce the scalar fold's \
                 dropped-bit pattern under this spec; use \"scalar\" (or \"kernel:1\")"
            ));
        }
        record_plan(sel, rationale);
        Ok(ReducePlan { spec: self.spec, sel, caps, rationale })
    }
}

#[allow(clippy::float_arithmetic, clippy::cast_precision_loss, clippy::disallowed_methods)]
#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::{Fp, BF16};
    use crate::util::prng::XorShift;

    #[test]
    fn negotiation_replaces_the_auto_heuristics_inspectably() {
        let exact = ReducePlan::negotiate(AccSpec::exact(BF16));
        assert_eq!(exact.backend().name(), "kernel");
        assert!(exact.rationale().contains("exact spec"));
        let trunc = ReducePlan::negotiate(AccSpec::truncated(4));
        assert_eq!(trunc.backend().name(), "scalar");
        assert!(trunc.rationale().contains("truncated spec"));
        assert!(trunc.describe().contains("scalar"));
    }

    #[test]
    fn zero_block_is_a_build_error_never_a_clamp() {
        let spec = AccSpec::exact(BF16);
        assert!(ReducePlan::builder(spec).block(0).is_err());
        assert!(ReducePlan::builder(spec).backend_name("kernel:0").is_err());
        // An explicit backend with a later zero block override also fails.
        let b = ReducePlan::builder(spec).backend_name("kernel").unwrap();
        assert!(b.block(0).is_err());
        // And a valid block flows into the selection.
        let plan = ReducePlan::builder(spec)
            .backend_name("kernel")
            .unwrap()
            .block(7)
            .unwrap()
            .build()
            .unwrap();
        assert_eq!(plan.backend().block(), Some(7));
    }

    #[test]
    fn requirements_steer_or_reject_truncated_negotiation() {
        let trunc = AccSpec::truncated(8);
        let plan = ReducePlan::builder(trunc).require_order_invariant().build().unwrap();
        assert_eq!(plan.backend().name(), "eia");
        let plan = ReducePlan::builder(trunc).require_fold_bits().build().unwrap();
        assert_eq!(plan.backend().name(), "scalar");
        assert!(ReducePlan::builder(trunc)
            .require_order_invariant()
            .require_fold_bits()
            .build()
            .is_err());
        // Explicit backends that cannot satisfy a requirement are rejected.
        assert!(ReducePlan::builder(trunc)
            .backend_name("kernel")
            .unwrap()
            .require_order_invariant()
            .build()
            .is_err());
        assert!(ReducePlan::builder(trunc)
            .backend_name("eia")
            .unwrap()
            .require_fold_bits()
            .build()
            .is_err());
        // On exact specs every requirement is free.
        let plan = ReducePlan::builder(AccSpec::exact(BF16))
            .backend_name("eia")
            .unwrap()
            .require_order_invariant()
            .require_fold_bits()
            .build()
            .unwrap();
        assert_eq!(plan.backend().name(), "eia");
    }

    #[test]
    fn plans_reduce_bit_identically_across_backends_on_exact_specs() {
        let spec = AccSpec::exact(BF16);
        let mut rng = XorShift::new(0x91A0);
        let terms: Vec<Fp> = (0..90).map(|_| rng.gen_fp_full(BF16)).collect();
        let want = ReducePlan::builder(spec)
            .backend_name("scalar")
            .unwrap()
            .build()
            .unwrap()
            .reduce(&terms);
        for entry in registry::entries() {
            let plan = ReducePlan::with_backend(spec, entry.sel());
            assert_eq!(plan.reduce(&terms), want, "{}", entry.name);
            // The stateful reducer path resolves to the same bits.
            let mut r = plan.reducer();
            r.ingest(&terms);
            assert_eq!(r.finish(), want, "{} reducer", entry.name);
        }
    }
}
