//! Registry-driven conformance battery: every backend the registry knows
//! is automatically held to the same contract (DESIGN.md §Reducer).
//!
//! For each registered backend × paper format × exact accumulator path
//! (narrow `i128` and forced-wide `WideInt` where the format offers both),
//! over the differential oracle's adversarial operand distributions:
//!
//! 1. **Equivalence** — the plan's one-shot `reduce` bit-matches the
//!    scalar `⊙` fold (eq. 10);
//! 2. **Split ingest** — a stateful [`super::Reducer`] fed the same terms
//!    in ragged chunks finishes with the same bits;
//! 3. **Merge** — two reducers over a random split, combined both via
//!    [`super::Reducer::absorb`] and via [`Partial::merge`], resolve to
//!    the same bits (merge associativity at the partial surface);
//! 4. **Codec** — every produced partial round-trips through the unified
//!    byte codec;
//! 5. **Specials** — the backend behind
//!    [`crate::arith::adder::Architecture::Backend`] applies the same
//!    NaN/Inf screening as the baseline architecture;
//! 6. **Identity** — empty and all-zero inputs reduce to the identity.
//!
//! Registering a new backend (the SIMD kernel variant, a GPU fold, …)
//! requires **zero** test edits: `tests/reduce_conformance.rs` and
//! `repro conform` iterate [`crate::reduce::registry::entries`].

use super::backend::Reducer;
use super::partial::Partial;
use super::plan::ReducePlan;
use super::registry::{self, BackendSel};
use crate::arith::adder::{Architecture, MultiTermAdder};
use crate::arith::kernel::scalar_fold;
use crate::arith::oracle::DISTRIBUTIONS;
use crate::arith::AccSpec;
use crate::formats::{Fp, FpClass, FpFormat, SpecialsMode};
use crate::util::prng::XorShift;

/// Battery size knobs.
#[derive(Clone, Copy, Debug)]
pub struct ConformanceConfig {
    /// Vectors per (distribution, spec) cell.
    pub vectors: usize,
    /// Maximum vector length (lengths are randomized in `1..=max_terms`).
    pub max_terms: usize,
    pub seed: u64,
}

impl Default for ConformanceConfig {
    fn default() -> Self {
        ConformanceConfig { vectors: 20, max_terms: 96, seed: 0xC0F0_12ED }
    }
}

/// Outcome of one backend's battery on one format.
#[derive(Clone, Debug)]
pub struct BackendReport {
    pub backend: String,
    pub format: FpFormat,
    /// Individual assertions evaluated.
    pub checks: u64,
    /// One-shot `reduce` states differing from the scalar fold.
    pub reduce_mismatches: u64,
    /// Split-ingest reducer states differing from the scalar fold.
    pub split_mismatches: u64,
    /// Absorb/merge resolutions differing from the scalar fold.
    pub merge_mismatches: u64,
    /// Partial-codec round-trip failures.
    pub codec_failures: u64,
    /// Special-value screening divergences from the baseline adder.
    pub specials_failures: u64,
}

impl BackendReport {
    pub fn failures(&self) -> u64 {
        self.reduce_mismatches
            + self.split_mismatches
            + self.merge_mismatches
            + self.codec_failures
            + self.specials_failures
    }

    pub fn clean(&self) -> bool {
        self.failures() == 0
    }
}

/// The exact spec plus its forced-wide twin where the format's exact frame
/// fits the narrow path — the same coverage rule the equivalence batteries
/// use.
pub fn exact_specs(fmt: FpFormat) -> Vec<AccSpec> {
    let exact = AccSpec::exact(fmt);
    let mut specs = vec![exact];
    if exact.narrow {
        specs.push(AccSpec { narrow: false, ..exact });
    }
    specs
}

/// Run the battery for one backend selection on one format.
pub fn run_backend(sel: BackendSel, fmt: FpFormat, cfg: &ConformanceConfig) -> BackendReport {
    let mut rep = BackendReport {
        backend: sel.to_string(),
        format: fmt,
        checks: 0,
        reduce_mismatches: 0,
        split_mismatches: 0,
        merge_mismatches: 0,
        codec_failures: 0,
        specials_failures: 0,
    };
    let mut rng = XorShift::new(
        cfg.seed ^ ((fmt.ebits as u64) << 32) ^ ((fmt.mbits as u64) << 40),
    );
    for spec in exact_specs(fmt) {
        let plan = ReducePlan::with_backend(spec, sel);
        // Identity contract.
        rep.checks += 2;
        if !plan.reduce(&[]).is_identity() {
            rep.reduce_mismatches += 1;
        }
        let zeros = [Fp::zero(fmt); 9];
        if !plan.reduce(&zeros).is_identity() {
            rep.reduce_mismatches += 1;
        }
        for dist in DISTRIBUTIONS {
            for _ in 0..cfg.vectors {
                let n = 1 + rng.below(cfg.max_terms as u64) as usize;
                let terms = dist.gen_vector(&mut rng, fmt, n);
                let want = scalar_fold(&terms, spec);

                // 1. One-shot equivalence.
                rep.checks += 1;
                if plan.reduce(&terms) != want {
                    rep.reduce_mismatches += 1;
                }

                // 2. Split ingest through the stateful reducer.
                let mut r = plan.reducer();
                let chunk = 1 + rng.below(n as u64) as usize;
                for c in terms.chunks(chunk) {
                    r.ingest(c);
                }
                rep.checks += 1;
                if r.finish() != want || r.terms() != n as u64 {
                    rep.split_mismatches += 1;
                }

                // 3. Merge: head reducer absorbs the tail's partial, and
                // the two partials also merge at the Partial surface.
                let cut = rng.below(n as u64 + 1) as usize;
                let (mut head, mut tail) = (plan.reducer(), plan.reducer());
                head.ingest(&terms[..cut]);
                tail.ingest(&terms[cut..]);
                let (hp, tp) = (head.partial(), tail.partial());
                head.absorb(&tp);
                rep.checks += 2;
                if head.finish() != want {
                    rep.merge_mismatches += 1;
                }
                if hp.merge(&tp, spec).resolve(spec) != want {
                    rep.merge_mismatches += 1;
                }

                // 4. Codec round-trip on both partials.
                for p in [&hp, &tp] {
                    rep.checks += 1;
                    match Partial::from_bytes(&p.to_bytes()) {
                        Ok(back) if &back == p => {}
                        _ => rep.codec_failures += 1,
                    }
                }
            }
        }
    }
    rep.specials_failures = specials_battery(sel, fmt, &mut rep.checks);
    rep
}

/// Run the battery for **every registered backend** on one format.
pub fn run_format(fmt: FpFormat, cfg: &ConformanceConfig) -> Vec<BackendReport> {
    registry::entries().iter().map(|e| run_backend(e.sel(), fmt, cfg)).collect()
}

/// Special-value screening through the adder seam: the backend must apply
/// exactly the baseline architecture's Fp semantics.
fn specials_battery(sel: BackendSel, fmt: FpFormat, checks: &mut u64) -> u64 {
    let mut failures = 0u64;
    let backend = MultiTermAdder::exact(fmt, 4, Architecture::Backend(sel));
    let baseline = MultiTermAdder::exact(fmt, 4, Architecture::Baseline);
    let one = Fp::from_f64(1.0, fmt);
    let nan = Fp::nan(fmt);
    let nan_vec = [one, nan, one, one];
    *checks += 2;
    if backend.add(&nan_vec).class() != FpClass::Nan {
        failures += 1;
    }
    if backend.add(&nan_vec).bits != baseline.add(&nan_vec).bits {
        failures += 1;
    }
    if fmt.specials == SpecialsMode::Ieee {
        let inf = Fp::overflow(false, fmt);
        let ninf = Fp::overflow(true, fmt);
        let invalid = [inf, ninf, one, one];
        *checks += 1;
        if backend.add(&invalid).class() != FpClass::Nan {
            failures += 1;
        }
        for sign in [false, true] {
            let v = [Fp::overflow(sign, fmt), one, one, one];
            let r = backend.add(&v);
            *checks += 1;
            if r.class() != FpClass::Inf
                || r.sign() != sign
                || r.bits != baseline.add(&v).bits
            {
                failures += 1;
            }
        }
    } else {
        // NoInf formats: saturation clamps to the maximum finite value.
        let max = Fp::pack(false, fmt.max_normal_exp(), fmt.max_finite_mant(), fmt);
        let v = [max; 4];
        *checks += 1;
        if backend.add(&v).bits != baseline.add(&v).bits {
            failures += 1;
        }
    }
    failures
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::BF16;

    #[test]
    fn quick_battery_is_clean_for_every_registered_backend() {
        // The full-format battery lives in tests/reduce_conformance.rs;
        // this is a fast in-module smoke over one format.
        let cfg = ConformanceConfig { vectors: 4, max_terms: 40, ..Default::default() };
        for rep in run_format(BF16, &cfg) {
            assert!(rep.clean(), "{}: {rep:?}", rep.backend);
            assert!(rep.checks > 0);
        }
    }
}
