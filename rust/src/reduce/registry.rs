//! The name-indexed reduction-backend registry: the **one source of
//! truth** every backend consumer enumerates (DESIGN.md §Reducer).
//!
//! CLI parsing (`repro --backend`, `Architecture::parse`), the
//! differential-oracle rotation, the equivalence batteries and the
//! conformance suite all iterate [`entries`] instead of hand-maintained
//! lists — registering a new backend here automatically puts it in front
//! of every gate and every CLI surface (the `"simd"` entry landed exactly
//! that way: zero consumer edits).
//!
//! A [`BackendSel`] is a validated selection of one registry entry plus
//! its parameters; it is the `Copy` value configs and plans carry, and its
//! `Display`/`FromStr` grammar (`"scalar"`, `"kernel"`, `"kernel:<block>"`,
//! `"eia"`, `"simd[:<block>]"`) is the one spelling used everywhere.

// Exact-datapath module: native float arithmetic and lossy casts are
// forbidden here (clippy.toml, DESIGN.md §Analysis).
#![deny(clippy::float_arithmetic, clippy::cast_precision_loss)]

use super::backend::{EiaReducer, FoldReducer, KernelReducer, Reducer, SimdReducer};
use crate::arith::kernel::DEFAULT_BLOCK;
use crate::arith::operator::AlignAcc;
use crate::arith::AccSpec;
use crate::formats::Fp;
use crate::telemetry::{self, TraceEvent};
use std::fmt;
use std::str::FromStr;
use std::sync::Once;

/// What a backend guarantees under a given [`AccSpec`] — the negotiation
/// surface [`super::PlanBuilder`] matches requirements against.
///
/// Every registered backend is bit-identical to the scalar `⊙` fold under
/// **exact** specs (the conformance suite enforces it); the capabilities
/// describe what additionally holds, per spec.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Capabilities {
    /// Dropped-bit pattern (and therefore the full `[λ; acc; sticky]`
    /// state) matches the scalar radix-2 `⊙` fold under this spec.
    pub fold_bit_identical: bool,
    /// Result is invariant to ingest order and merge grouping under this
    /// spec (always true on exact specs — eq. 10). For truncated specs
    /// this is a property of the reducer/partial lifecycle itself; a
    /// consumer that drops to aligned `⊙` merges mid-pipeline (e.g. the
    /// stream engine's per-chunk reduce) forfeits it — see
    /// [`super::PlanBuilder::require_order_invariant`].
    pub order_invariant: bool,
    /// Partials merge without a lossy resolve under this spec (deferred
    /// domain, or exact aligned merges).
    pub lossless_merge: bool,
    /// SoA lanes per block, when the backend is batched.
    pub block: Option<usize>,
    /// Accumulator bits the backend is statically proved to need under
    /// this spec at the analyzer's `2^PROVED_TERMS_LOG2` term ceiling
    /// ([`AccSpec::proved_width`]); checked against
    /// [`Self::storage_acc_bits`] by `repro analyze`.
    pub proved_acc_bits: u32,
    /// Bits of the storage lane the backend actually accumulates in under
    /// this spec ([`AccSpec::storage_width`]: `i128` narrow fast path or
    /// the full `WideInt`).
    pub storage_acc_bits: u32,
}

/// One registered reduction backend.
pub struct BackendEntry {
    /// Registry name — the canonical CLI/config spelling.
    pub name: &'static str,
    /// One-line description for `repro backends`.
    pub summary: &'static str,
    /// Whether the backend takes a `:<block>` parameter.
    pub takes_block: bool,
    /// Default block size for block-taking backends.
    pub default_block: Option<usize>,
    caps_fn: fn(AccSpec, Option<usize>) -> Capabilities,
    reduce_fn: fn(&[Fp], AccSpec, Option<usize>) -> AlignAcc,
    make_fn: fn(AccSpec, Option<usize>) -> Box<dyn Reducer>,
}

impl BackendEntry {
    /// The default selection of this backend (default block, if any).
    pub fn sel(&'static self) -> BackendSel {
        BackendSel { entry: self, block: self.default_block }
    }

    /// Capabilities under `spec` at `block` (None = default).
    pub fn capabilities(&self, spec: AccSpec, block: Option<usize>) -> Capabilities {
        (self.caps_fn)(spec, block)
    }
}

// ---- the four in-tree backends ---------------------------------------

fn scalar_caps(spec: AccSpec, _block: Option<usize>) -> Capabilities {
    Capabilities {
        fold_bit_identical: true,
        order_invariant: spec.exact,
        lossless_merge: spec.exact,
        block: None,
        proved_acc_bits: spec.proved_width(),
        storage_acc_bits: spec.storage_width(),
    }
}

fn scalar_reduce(terms: &[Fp], spec: AccSpec, _block: Option<usize>) -> AlignAcc {
    crate::arith::kernel::scalar_fold(terms, spec)
}

fn scalar_make(spec: AccSpec, _block: Option<usize>) -> Box<dyn Reducer> {
    Box::new(FoldReducer::new(spec))
}

fn kernel_caps(spec: AccSpec, block: Option<usize>) -> Capabilities {
    let b = block.unwrap_or(DEFAULT_BLOCK);
    Capabilities {
        fold_bit_identical: spec.exact || b == 1,
        order_invariant: spec.exact,
        lossless_merge: spec.exact,
        block: Some(b),
        proved_acc_bits: spec.proved_width(),
        storage_acc_bits: spec.storage_width(),
    }
}

fn kernel_reduce(terms: &[Fp], spec: AccSpec, block: Option<usize>) -> AlignAcc {
    crate::arith::kernel::reduce_terms(terms, block.unwrap_or(DEFAULT_BLOCK), spec)
}

fn kernel_make(spec: AccSpec, block: Option<usize>) -> Box<dyn Reducer> {
    Box::new(KernelReducer::new(spec, block.unwrap_or(DEFAULT_BLOCK)))
}

fn simd_caps(spec: AccSpec, block: Option<usize>) -> Capabilities {
    // Bit-identical to the kernel at every (spec, block) by construction
    // (same block-λ/align semantics, vectorized — see arith::simd), so it
    // publishes exactly the kernel's capability surface.
    kernel_caps(spec, block)
}

fn simd_reduce(terms: &[Fp], spec: AccSpec, block: Option<usize>) -> AlignAcc {
    crate::arith::simd::reduce_terms_simd(terms, block.unwrap_or(DEFAULT_BLOCK), spec)
}

fn simd_make(spec: AccSpec, block: Option<usize>) -> Box<dyn Reducer> {
    Box::new(SimdReducer::new(spec, block.unwrap_or(DEFAULT_BLOCK)))
}

fn eia_caps(spec: AccSpec, _block: Option<usize>) -> Capabilities {
    Capabilities {
        fold_bit_identical: spec.exact,
        // Banking is exact; bits can only drop in the single drain, so the
        // EIA result is ingest-order invariant even when truncating.
        order_invariant: true,
        lossless_merge: true,
        block: None,
        proved_acc_bits: spec.proved_width(),
        storage_acc_bits: spec.storage_width(),
    }
}

fn eia_reduce(terms: &[Fp], spec: AccSpec, _block: Option<usize>) -> AlignAcc {
    crate::accum::reduce_terms_eia(terms, spec)
}

fn eia_make(spec: AccSpec, _block: Option<usize>) -> Box<dyn Reducer> {
    Box::new(EiaReducer::new(spec))
}

static REGISTRY: [BackendEntry; 4] = [
    BackendEntry {
        name: "scalar",
        summary: "serial radix-2 ⊙ fold (Algorithm 3) — the reference",
        takes_block: false,
        default_block: None,
        caps_fn: scalar_caps,
        reduce_fn: scalar_reduce,
        make_fn: scalar_make,
    },
    BackendEntry {
        name: "kernel",
        summary: "batched SoA align-and-add kernel (blockwise single-λ)",
        takes_block: true,
        default_block: Some(DEFAULT_BLOCK),
        caps_fn: kernel_caps,
        reduce_fn: kernel_reduce,
        make_fn: kernel_make,
    },
    BackendEntry {
        name: "eia",
        summary: "exponent-indexed accumulator (deferred alignment, O(1) ingest)",
        takes_block: false,
        default_block: None,
        caps_fn: eia_caps,
        reduce_fn: eia_reduce,
        make_fn: eia_make,
    },
    BackendEntry {
        name: "simd",
        summary: "vectorized SoA kernel (runtime AVX2 λ-sweep, lane-parallel align)",
        takes_block: true,
        default_block: Some(DEFAULT_BLOCK),
        caps_fn: simd_caps,
        reduce_fn: simd_reduce,
        make_fn: simd_make,
    },
];

/// All registered backends, in registration order.
pub fn entries() -> &'static [BackendEntry] {
    &REGISTRY
}

// ---- telemetry slot mapping -------------------------------------------
//
// Backend-indexed metrics live in fixed telemetry slots keyed by registry
// position; the names are registered once so snapshots can label samples
// `backend="scalar"` etc. Slot resolution is a scan over four entries —
// cheap enough for the per-call dispatch path, and reducers cache the
// returned `&'static` family at construction anyway.

static TELE_SLOTS: Once = Once::new();

fn tele_init() {
    TELE_SLOTS.call_once(|| {
        for (i, e) in REGISTRY.iter().enumerate() {
            telemetry::global().register_backend_slot(i, e.name);
        }
    });
}

/// The telemetry metric family of a registry entry.
fn tele_family(entry: &'static BackendEntry) -> &'static telemetry::ReduceFamily {
    tele_init();
    let slot = REGISTRY.iter().position(|e| std::ptr::eq(e, entry)).unwrap_or(0);
    telemetry::global().reduce_slot(slot)
}

/// The telemetry metric family of a backend by registry name (unknown
/// names map to slot 0; only in-tree reducers call this).
pub(crate) fn tele_family_named(name: &str) -> &'static telemetry::ReduceFamily {
    tele_init();
    let slot = REGISTRY.iter().position(|e| e.name == name).unwrap_or(0);
    telemetry::global().reduce_slot(slot)
}

/// Look a backend up by its registry name (case-sensitive, lowercase).
pub fn by_name(name: &str) -> Option<&'static BackendEntry> {
    REGISTRY.iter().find(|e| e.name == name)
}

/// Registered backend names, for error messages and listings.
pub fn names() -> Vec<&'static str> {
    REGISTRY.iter().map(|e| e.name).collect()
}

/// Parse a backend selection (`"name"` / `"name:<block>"`); the top-level
/// convenience over [`BackendSel::from_str`].
pub fn sel(spec: &str) -> Result<BackendSel, String> {
    spec.parse()
}

/// A validated selection of one registered backend plus its parameters —
/// the `Copy` value configs, plans and CLIs carry. Constructors reject
/// invalid parameters (a block of 0 is an error, never a silent clamp).
#[derive(Clone, Copy)]
pub struct BackendSel {
    entry: &'static BackendEntry,
    block: Option<usize>,
}

impl BackendSel {
    /// Select `entry` with an explicit block (None = the entry's default).
    pub fn new(entry: &'static BackendEntry, block: Option<usize>) -> Result<Self, String> {
        match block {
            None => Ok(BackendSel { entry, block: entry.default_block }),
            Some(_) if !entry.takes_block => {
                Err(format!("backend {} takes no block parameter", entry.name))
            }
            Some(0) => Err(format!("backend {}: block must be >= 1", entry.name)),
            Some(b) => Ok(BackendSel { entry, block: Some(b) }),
        }
    }

    /// Select a backend by registry name, at its default parameters.
    pub fn named(name: &str) -> Result<Self, String> {
        let entry = by_name(name).ok_or_else(|| {
            format!("unknown backend {name:?} (registered: {})", names().join(", "))
        })?;
        Ok(entry.sel())
    }

    /// The registry entry backing this selection.
    pub fn entry(&self) -> &'static BackendEntry {
        self.entry
    }

    /// The registry name.
    pub fn name(&self) -> &'static str {
        self.entry.name
    }

    /// The selected block size, for block-taking backends.
    pub fn block(&self) -> Option<usize> {
        self.block
    }

    /// This selection with a different block size (errors on 0 or on a
    /// backend that takes no block).
    pub fn with_block(&self, block: usize) -> Result<Self, String> {
        BackendSel::new(self.entry, Some(block))
    }

    /// Capabilities of this selection under `spec`.
    pub fn capabilities(&self, spec: AccSpec) -> Capabilities {
        (self.entry.caps_fn)(spec, self.block)
    }

    /// One-shot slice reduction — the direct (fn-pointer) dispatch path.
    pub fn reduce(&self, terms: &[Fp], spec: AccSpec) -> AlignAcc {
        if telemetry::enabled() {
            let fam = tele_family(self.entry);
            fam.reduce_calls.inc();
            fam.ingest_terms.add(terms.len() as u64);
        }
        let out = (self.entry.reduce_fn)(terms, spec, self.block);
        // Span-tagged via the caller's ambient span (e.g. the worker
        // batch): one record per resolved one-shot reduction.
        telemetry::global().trace.record(TraceEvent::ReduceFinished {
            backend: self.entry.name,
            terms: terms.len() as u64,
        });
        out
    }

    /// Build a stateful [`Reducer`] for this selection.
    pub fn reducer(&self, spec: AccSpec) -> Box<dyn Reducer> {
        (self.entry.make_fn)(spec, self.block)
    }
}

impl PartialEq for BackendSel {
    fn eq(&self, other: &Self) -> bool {
        self.entry.name == other.entry.name && self.block == other.block
    }
}

impl Eq for BackendSel {}

impl fmt::Debug for BackendSel {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "BackendSel({})", self)
    }
}

impl fmt::Display for BackendSel {
    /// Canonical spelling, round-trippable through [`FromStr`]: the
    /// registry name, plus `:<block>` for block-taking backends.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.block {
            Some(b) => write!(f, "{}:{}", self.entry.name, b),
            None => f.write_str(self.entry.name),
        }
    }
}

impl FromStr for BackendSel {
    type Err = String;

    /// Parse `"name"` or `"name:<block>"` against the registry. A zero
    /// block is rejected here — never clamped.
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let lower = s.to_ascii_lowercase();
        let (name, block) = match lower.split_once(':') {
            Some((n, b)) => {
                let parsed: usize = b
                    .parse()
                    .map_err(|e| format!("bad block {b:?} in backend {s:?}: {e}"))?;
                (n, Some(parsed))
            }
            None => (lower.as_str(), None),
        };
        let entry = by_name(name).ok_or_else(|| {
            format!("unknown backend {s:?} (registered: {})", names().join(", "))
        })?;
        BackendSel::new(entry, block)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::BF16;

    #[test]
    fn registry_lists_all_four_backends() {
        assert_eq!(names(), vec!["scalar", "kernel", "eia", "simd"]);
        for e in entries() {
            assert!(by_name(e.name).is_some());
            assert_eq!(e.sel().name(), e.name);
        }
        assert!(by_name("avx2").is_none());
    }

    #[test]
    fn selection_parse_display_roundtrip() {
        for s in ["scalar", "kernel:64", "kernel:3", "eia", "simd:8", "simd:64"] {
            let parsed: BackendSel = s.parse().unwrap();
            assert_eq!(parsed.to_string(), s);
            assert_eq!(parsed.to_string().parse::<BackendSel>().unwrap(), parsed);
        }
        // Bare block-taking names fill the default block in the canonical
        // spelling.
        let k: BackendSel = "kernel".parse().unwrap();
        assert_eq!(k.block(), Some(DEFAULT_BLOCK));
        assert_eq!(k.to_string(), format!("kernel:{DEFAULT_BLOCK}"));
        let v: BackendSel = "simd".parse().unwrap();
        assert_eq!(v.block(), Some(DEFAULT_BLOCK));
        assert_eq!(v.to_string(), format!("simd:{DEFAULT_BLOCK}"));
        assert!("avx2".parse::<BackendSel>().is_err());
        assert!("kernel:x".parse::<BackendSel>().is_err());
        assert!("simd:0".parse::<BackendSel>().is_err());
    }

    #[test]
    fn zero_and_misplaced_blocks_are_rejected_not_clamped() {
        // The satellite fix: a zero block used to be silently clamped to 1
        // deep in the kernel; it is now a parse/build-time error.
        let err = "kernel:0".parse::<BackendSel>().unwrap_err();
        assert!(err.contains("block must be >= 1"), "{err}");
        assert!(BackendSel::named("kernel").unwrap().with_block(0).is_err());
        // Non-batched backends take no block at all.
        assert!("scalar:8".parse::<BackendSel>().is_err());
        assert!("eia:2".parse::<BackendSel>().is_err());
    }

    #[test]
    fn capabilities_match_the_documented_contracts() {
        let exact = AccSpec::exact(BF16);
        let trunc = AccSpec::truncated(4);
        for e in entries() {
            let c = e.sel().capabilities(exact);
            assert!(c.fold_bit_identical, "{}: exact specs are fold-identical", e.name);
            assert!(c.order_invariant, "{}: exact specs are order-invariant", e.name);
        }
        let scalar = BackendSel::named("scalar").unwrap().capabilities(trunc);
        assert!(scalar.fold_bit_identical && !scalar.order_invariant);
        let kernel = BackendSel::named("kernel").unwrap().capabilities(trunc);
        assert!(!kernel.fold_bit_identical && !kernel.order_invariant);
        let k1 = sel("kernel:1").unwrap().capabilities(trunc);
        assert!(k1.fold_bit_identical, "block=1 degenerates to the fold");
        let eia = BackendSel::named("eia").unwrap().capabilities(trunc);
        assert!(!eia.fold_bit_identical && eia.order_invariant && eia.lossless_merge);
        // simd mirrors the kernel's contract exactly, block semantics
        // included (bit-identical to the kernel at every spec).
        let simd = BackendSel::named("simd").unwrap().capabilities(trunc);
        assert_eq!(simd, BackendSel::named("kernel").unwrap().capabilities(trunc));
        let v1 = sel("simd:1").unwrap().capabilities(trunc);
        assert!(v1.fold_bit_identical, "block=1 degenerates to the fold");
    }

    #[test]
    fn capabilities_publish_consistent_proved_widths() {
        // Every backend must claim a proved bound that fits its storage
        // lane — the same inequality `repro analyze` gates in CI.
        for spec in [AccSpec::exact(BF16), AccSpec::truncated(4)] {
            for e in entries() {
                let c = e.sel().capabilities(spec);
                assert_eq!(c.proved_acc_bits, spec.proved_width(), "{}", e.name);
                assert_eq!(c.storage_acc_bits, spec.storage_width(), "{}", e.name);
                assert!(
                    c.proved_acc_bits <= c.storage_acc_bits,
                    "{}: proved {} > storage {}",
                    e.name,
                    c.proved_acc_bits,
                    c.storage_acc_bits
                );
            }
        }
    }
}
