//! The reduction API tier: **one** contract every reduction backend is
//! held to, and the one surface every consumer dispatches through
//! (DESIGN.md §Reducer).
//!
//! The paper's associativity result (eq. 10) makes max-exponent search,
//! alignment and addition composable in any order — which is why this
//! crate grew four interchangeable backends (the scalar `⊙` fold, the
//! batched SoA kernel, its vectorized SIMD variant, the exponent-indexed
//! accumulator). This module is
//! the seam that keeps them interchangeable *by construction* instead of
//! by hand-maintained pattern matches:
//!
//! * [`backend`] — the [`Reducer`] trait: the
//!   `ingest → partial → merge → finish` lifecycle plus the four in-tree
//!   implementations.
//! * [`partial`] — [`Partial`], the backend-agnostic mergeable state with
//!   the **one** byte codec that ships reduction state across shard and
//!   checkpoint boundaries (replacing the `AlignAcc`-vs-`EiaSnapshot`
//!   special-casing that used to leak into `stream::shard`).
//! * [`registry`] — the name-indexed backend registry: the single source
//!   of truth CLI parsing, the differential-oracle rotation and the
//!   equivalence batteries enumerate. [`BackendSel`] is a validated
//!   `Copy` selection of one entry.
//! * [`plan`] — [`ReducePlan`] / [`PlanBuilder`]: capability negotiation
//!   per [`crate::arith::AccSpec`], replacing the old
//!   `ReduceBackend::Auto` hidden heuristics with an inspectable plan.
//! * [`conformance`] — the registry-driven acceptance battery every
//!   registered backend (present and future) runs through automatically.
//!
//! The pre-existing `crate::arith::kernel::ReduceBackend` enum survives
//! only as a deprecated shim that lowers onto this API.

pub mod backend;
pub mod conformance;
pub mod partial;
pub mod plan;
pub mod registry;

pub use backend::{EiaReducer, FoldReducer, KernelReducer, Reducer, SimdReducer};
pub use partial::{Partial, PartialState};
pub use plan::{PlanBuilder, ReducePlan};
pub use registry::{BackendEntry, BackendSel, Capabilities};
