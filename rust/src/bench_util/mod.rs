//! Benchmark harness (the offline environment has no criterion): warmup +
//! repeated timed runs with median/mean/stddev reporting, plus a tiny
//! `black_box` to defeat dead-code elimination.

use crate::util::stats;
use std::time::Instant;

/// Opaque identity the optimizer cannot see through.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median_s: f64,
    pub mean_s: f64,
    pub stddev_s: f64,
}

impl BenchResult {
    /// Events-per-second given how many logical events one iteration covers.
    pub fn throughput(&self, events_per_iter: f64) -> f64 {
        events_per_iter / self.median_s
    }

    pub fn line(&self) -> String {
        format!(
            "{:<44} {:>12} /iter   (mean {:>12}, ±{:.1}%, {} iters)",
            self.name,
            fmt_duration(self.median_s),
            fmt_duration(self.mean_s),
            if self.mean_s > 0.0 { 100.0 * self.stddev_s / self.mean_s } else { 0.0 },
            self.iters
        )
    }
}

/// Format seconds scaled to a readable unit.
pub fn fmt_duration(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Run `f` with warmup, auto-scaling the iteration count so the measured
/// phase takes roughly `target_s` seconds, and report robust statistics.
pub fn bench<F: FnMut()>(name: &str, target_s: f64, mut f: F) -> BenchResult {
    // Warmup + calibration: time single runs until 5% of target elapsed.
    let t0 = Instant::now();
    let mut single = Vec::new();
    loop {
        let t = Instant::now();
        f();
        single.push(t.elapsed().as_secs_f64());
        if t0.elapsed().as_secs_f64() > target_s * 0.05 && !single.is_empty() {
            break;
        }
    }
    let per_iter = stats::median(&single).max(1e-9);
    // Samples of `batch` iterations each; at least 5 samples.
    let samples = 10usize;
    let batch = ((target_s / samples as f64) / per_iter).ceil().max(1.0) as usize;
    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t = Instant::now();
        for _ in 0..batch {
            f();
        }
        times.push(t.elapsed().as_secs_f64() / batch as f64);
    }
    BenchResult {
        name: name.to_string(),
        iters: samples * batch,
        median_s: stats::median(&times),
        mean_s: stats::mean(&times),
        stddev_s: stats::stddev(&times),
    }
}

/// Print a bench-suite header (used by the `cargo bench` binaries).
pub fn header(title: &str) {
    println!("\n=== {title} ===");
}

/// True when `BENCH_SMOKE` is enabled in the environment (any value other
/// than empty, `0` or `false`): CI smoke mode, where suites shrink their
/// workloads/targets so every `BENCH_*.json` is emitted in seconds.
/// Smoke-mode suites write their records under a `<suite>-smoke` label so
/// the trajectory never mixes smoke figures with full-length runs.
pub fn smoke() -> bool {
    match std::env::var("BENCH_SMOKE") {
        Ok(v) => !(v.is_empty() || v == "0" || v.eq_ignore_ascii_case("false")),
        Err(_) => false,
    }
}

/// The JSON suite label for the current mode: `name` for full runs,
/// `name-smoke` under [`smoke`] mode.
pub fn suite_label(name: &str) -> String {
    if smoke() {
        format!("{name}-smoke")
    } else {
        name.to_string()
    }
}

/// A bench target time scaled for the current mode: `full` seconds
/// locally, a fast fraction under smoke mode.
pub fn target_seconds(full: f64) -> f64 {
    if smoke() {
        (full * 0.1).max(0.05)
    } else {
        full
    }
}

/// One machine-readable benchmark record: a [`BenchResult`] plus labeled
/// numeric parameters (thread count, chunk size, throughput, ...).
#[derive(Debug, Clone)]
pub struct BenchRecord {
    pub result: BenchResult,
    pub params: Vec<(String, f64)>,
}

impl BenchRecord {
    pub fn new(result: BenchResult) -> Self {
        BenchRecord { result, params: Vec::new() }
    }

    /// Attach one labeled numeric parameter (builder style). At
    /// serialization time, any key that collides with the record's own
    /// fields (`name`, `iters`, `median_s`, `mean_s`, `stddev_s`) or with
    /// an earlier param is prefixed with `param_` until unique, so the
    /// emitted JSON never contains duplicate keys.
    pub fn param<S: Into<String>>(mut self, key: S, value: f64) -> Self {
        self.params.push((key.into(), value));
        self
    }
}

/// Keys owned by the record itself; user params colliding with these are
/// prefixed on output.
const RESERVED_KEYS: [&str; 5] = ["name", "iters", "median_s", "mean_s", "stddev_s"];

/// Serialize bench records to a JSON file (`BENCH_<suite>.json` by
/// convention) so the perf trajectory is machine-trackable across PRs.
/// Hand-rolled emitter — the offline environment has no serde.
pub fn write_json(
    path: &std::path::Path,
    suite: &str,
    records: &[BenchRecord],
) -> std::io::Result<()> {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str(&format!("  \"suite\": {},\n", json_str(suite)));
    out.push_str("  \"records\": [\n");
    for (i, rec) in records.iter().enumerate() {
        let r = &rec.result;
        out.push_str("    {");
        out.push_str(&format!("\"name\": {}, ", json_str(&r.name)));
        out.push_str(&format!("\"iters\": {}, ", r.iters));
        out.push_str(&format!("\"median_s\": {}, ", json_num(r.median_s)));
        out.push_str(&format!("\"mean_s\": {}, ", json_num(r.mean_s)));
        out.push_str(&format!("\"stddev_s\": {}", json_num(r.stddev_s)));
        let mut seen: std::collections::HashSet<String> =
            RESERVED_KEYS.iter().map(|k| k.to_string()).collect();
        for (k, v) in &rec.params {
            let mut key = k.clone();
            while !seen.insert(key.clone()) {
                key = format!("param_{key}");
            }
            out.push_str(&format!(", {}: {}", json_str(&key), json_num(*v)));
        }
        out.push_str(if i + 1 < records.len() { "},\n" } else { "}\n" });
    }
    out.push_str("  ]\n}\n");
    std::fs::write(path, out)
}

fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// JSON number: Rust's `f64` Display never emits exponent notation and
/// round-trips, which is exactly JSON-safe; non-finite becomes `null`.
fn json_num(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "null".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something_positive() {
        let r = bench("noop-ish", 0.05, || {
            black_box((0..100u64).sum::<u64>());
        });
        assert!(r.median_s > 0.0);
        assert!(r.iters >= 10);
        assert!(r.line().contains("noop-ish"));
    }

    #[test]
    fn json_report_is_wellformed() {
        let rec = BenchRecord::new(BenchResult {
            name: "ingest \"q\"".into(),
            iters: 7,
            median_s: 0.25,
            mean_s: 0.3,
            stddev_s: f64::NAN,
        })
        .param("threads", 4.0)
        .param("terms_per_s", 1.5e6)
        .param("iters", 9.0) // collides with a record field → prefixed
        .param("threads", 8.0); // collides with an earlier param → prefixed
        let path = std::env::temp_dir().join("ofa-bench-json-test.json");
        write_json(&path, "unit", &[rec]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"suite\": \"unit\""), "{text}");
        assert!(text.contains("\\\"q\\\""), "escaped quotes: {text}");
        assert!(text.contains("\"stddev_s\": null"), "{text}");
        assert!(text.contains("\"threads\": 4"), "{text}");
        assert!(text.contains("\"median_s\": 0.25"), "{text}");
        assert!(text.contains("\"param_iters\": 9"), "reserved key prefixed: {text}");
        assert_eq!(text.matches("\"iters\"").count(), 1, "no duplicate keys: {text}");
        assert!(text.contains("\"param_threads\": 8"), "repeated param prefixed: {text}");
        assert_eq!(text.matches("\"threads\"").count(), 1, "no duplicate keys: {text}");
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration(2.0), "2.000 s");
        assert_eq!(fmt_duration(2.5e-3), "2.500 ms");
        assert_eq!(fmt_duration(3.2e-6), "3.200 µs");
        assert_eq!(fmt_duration(5e-9), "5.0 ns");
    }
}
