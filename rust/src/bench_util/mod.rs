//! Benchmark harness (the offline environment has no criterion): warmup +
//! repeated timed runs with median/mean/stddev reporting, plus a tiny
//! `black_box` to defeat dead-code elimination.

use crate::util::stats;
use std::time::Instant;

/// Opaque identity the optimizer cannot see through.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median_s: f64,
    pub mean_s: f64,
    pub stddev_s: f64,
}

impl BenchResult {
    /// Events-per-second given how many logical events one iteration covers.
    pub fn throughput(&self, events_per_iter: f64) -> f64 {
        events_per_iter / self.median_s
    }

    pub fn line(&self) -> String {
        format!(
            "{:<44} {:>12} /iter   (mean {:>12}, ±{:.1}%, {} iters)",
            self.name,
            fmt_duration(self.median_s),
            fmt_duration(self.mean_s),
            if self.mean_s > 0.0 { 100.0 * self.stddev_s / self.mean_s } else { 0.0 },
            self.iters
        )
    }
}

/// Format seconds scaled to a readable unit.
pub fn fmt_duration(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Run `f` with warmup, auto-scaling the iteration count so the measured
/// phase takes roughly `target_s` seconds, and report robust statistics.
pub fn bench<F: FnMut()>(name: &str, target_s: f64, mut f: F) -> BenchResult {
    // Warmup + calibration: time single runs until 5% of target elapsed.
    let t0 = Instant::now();
    let mut single = Vec::new();
    loop {
        let t = Instant::now();
        f();
        single.push(t.elapsed().as_secs_f64());
        if t0.elapsed().as_secs_f64() > target_s * 0.05 && !single.is_empty() {
            break;
        }
    }
    let per_iter = stats::median(&single).max(1e-9);
    // Samples of `batch` iterations each; at least 5 samples.
    let samples = 10usize;
    let batch = ((target_s / samples as f64) / per_iter).ceil().max(1.0) as usize;
    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t = Instant::now();
        for _ in 0..batch {
            f();
        }
        times.push(t.elapsed().as_secs_f64() / batch as f64);
    }
    BenchResult {
        name: name.to_string(),
        iters: samples * batch,
        median_s: stats::median(&times),
        mean_s: stats::mean(&times),
        stddev_s: stats::stddev(&times),
    }
}

/// Print a bench-suite header (used by the `cargo bench` binaries).
pub fn header(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something_positive() {
        let r = bench("noop-ish", 0.05, || {
            black_box((0..100u64).sum::<u64>());
        });
        assert!(r.median_s > 0.0);
        assert!(r.iters >= 10);
        assert!(r.line().contains("noop-ish"));
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration(2.0), "2.000 s");
        assert_eq!(fmt_duration(2.5e-3), "2.500 ms");
        assert_eq!(fmt_duration(3.2e-6), "3.200 µs");
        assert_eq!(fmt_duration(5e-9), "5.0 ns");
    }
}
