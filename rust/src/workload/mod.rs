//! Workload generation: the operand traces that drive power estimation.
//!
//! The paper estimates power by running its multi-term adders inside matrix
//! multiplication kernels of a BERT transformer on GLUE inputs (§IV). This
//! module reproduces that pipeline: a synthetic GLUE-like token corpus
//! ([`glue`]), a BERT-style encoder layer ([`bert`] natively, or the PJRT
//! artifact via [`crate::runtime`]), and extraction of the N-term
//! partial-product vectors every output element feeds through the adder
//! ([`matmul`]).

pub mod bert;
pub mod glue;
pub mod matmul;
pub mod trace;

pub use matmul::partial_product_trace;
pub use trace::Trace;
