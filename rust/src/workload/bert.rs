//! Native BERT-style encoder layer (f32) mirroring the L2 JAX model, plus
//! the standard trace bundle used by the power experiments.
//!
//! Two execution paths produce identical operand statistics: this native
//! implementation (used by benches so they run without artifacts) and the
//! PJRT artifact (`examples/bert_e2e.rs`, the end-to-end driver). Both feed
//! [`super::matmul::partial_product_trace`].

use super::glue::{GlueConfig, GlueCorpus};
use super::matmul::{matmul_f32, partial_product_trace};
use super::trace::Trace;
use crate::formats::FpFormat;
use crate::util::prng::XorShift;

/// The layer's matmuls, exposed as (name, A, B, (m, k, n)) operand sets.
pub struct BertTrace {
    pub matmuls: Vec<(String, Vec<f32>, Vec<f32>, (usize, usize, usize))>,
}

/// Layer geometry (matches the AOT artifact defaults).
#[derive(Clone, Copy, Debug)]
pub struct BertDims {
    pub seq: usize,
    pub d: usize,
    pub ff: usize,
}

impl Default for BertDims {
    fn default() -> Self {
        BertDims { seq: 128, d: 256, ff: 1024 }
    }
}

/// Row-wise softmax, shared by this trace generator and the native
/// `bert_layer` executor in [`crate::runtime`] (one implementation, so the
/// two paths cannot drift numerically).
#[allow(clippy::disallowed_methods)] // f32 reference model, not the exact path
pub(crate) fn softmax_rows(x: &mut [f32], rows: usize, cols: usize) {
    for r in 0..rows {
        let row = &mut x[r * cols..(r + 1) * cols];
        let max = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let mut sum = 0.0;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        for v in row.iter_mut() {
            *v /= sum;
        }
    }
}

/// Tanh-approximation GELU (shared with [`crate::runtime`], see above).
pub(crate) fn gelu(x: f32) -> f32 {
    0.5 * x * (1.0 + ((0.7978845608 * (x + 0.044715 * x * x * x)) as f32).tanh())
}

/// Run one encoder layer on embedded GLUE-like input and collect every
/// matmul's operand matrices.
#[allow(clippy::disallowed_methods)] // trace generator, not the exact path
pub fn bert_layer_trace(dims: BertDims, seed: u64) -> BertTrace {
    let corpus = GlueCorpus::new(
        GlueConfig { seq: dims.seq, d_model: dims.d, ..Default::default() },
        seed,
    );
    let mut rng = XorShift::new(seed ^ 0xBE27);
    let x = corpus.embed_sentence(&mut rng);
    let (s, d, ff) = (dims.seq, dims.d, dims.ff);
    let mut mk = |rows: usize, cols: usize| -> Vec<f32> {
        let scale = (2.0 / (rows + cols) as f64).sqrt();
        (0..rows * cols).map(|_| (rng.gauss() * scale) as f32).collect()
    };
    let (wq, wk, wv, wo) = (mk(d, d), mk(d, d), mk(d, d), mk(d, d));
    let (w1, w2) = (mk(d, ff), mk(ff, d));

    let q = matmul_f32(&x, &wq, s, d, d);
    let k = matmul_f32(&x, &wk, s, d, d);
    let v = matmul_f32(&x, &wv, s, d, d);
    // scores = q @ k^T / sqrt(d)
    let mut kt = vec![0f32; d * s];
    for i in 0..s {
        for j in 0..d {
            kt[j * s + i] = k[i * d + j];
        }
    }
    let mut scores = matmul_f32(&q, &kt, s, d, s);
    let inv = 1.0 / (d as f32).sqrt();
    for v in scores.iter_mut() {
        *v *= inv;
    }
    softmax_rows(&mut scores, s, s);
    let ctx = matmul_f32(&scores, &v, s, s, d);
    let mut h = matmul_f32(&ctx, &wo, s, d, d);
    for (hv, xv) in h.iter_mut().zip(&x) {
        *hv += xv;
    }
    let mut g = matmul_f32(&h, &w1, s, d, ff);
    for v in g.iter_mut() {
        *v = gelu(*v);
    }

    BertTrace {
        matmuls: vec![
            ("q_proj".into(), x.clone(), wq, (s, d, d)),
            ("scores".into(), q, kt, (s, d, s)),
            ("ctx".into(), scores, v, (s, s, d)),
            ("out_proj".into(), ctx, wo, (s, d, d)),
            ("ffn1".into(), h, w1, (s, d, ff)),
            ("ffn2".into(), g, w2, (s, ff, d)),
        ],
    }
}

/// The standard power-estimation trace: partial products pooled evenly from
/// every matmul of the layer, rounded into `fmt`, `n_terms` lanes.
pub fn power_trace(fmt: FpFormat, n_terms: usize, vectors: usize, seed: u64) -> Trace {
    let bundle = bert_layer_trace(BertDims::default(), seed);
    let per = vectors.div_ceil(bundle.matmuls.len());
    let mut out = Trace::new(fmt, n_terms);
    for (i, (_, a, b, shape)) in bundle.matmuls.iter().enumerate() {
        let t = partial_product_trace(a, b, *shape, fmt, n_terms, per, seed ^ (i as u64) << 8);
        out.vectors.extend(t.vectors);
        if out.len() >= vectors {
            break;
        }
    }
    out.vectors.truncate(vectors);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::BF16;

    #[test]
    fn trace_bundle_covers_all_matmuls() {
        let dims = BertDims { seq: 16, d: 32, ff: 64 };
        let t = bert_layer_trace(dims, 1);
        assert_eq!(t.matmuls.len(), 6);
        for (name, a, b, (m, k, n)) in &t.matmuls {
            assert_eq!(a.len(), m * k, "{name}");
            assert_eq!(b.len(), k * n, "{name}");
            assert!(a.iter().all(|v| v.is_finite()), "{name}");
        }
    }

    #[test]
    fn power_trace_is_deterministic_and_realistic() {
        let t1 = power_trace(BF16, 32, 128, 42);
        let t2 = power_trace(BF16, 32, 128, 42);
        assert_eq!(t1.len(), 128);
        assert_eq!(
            t1.vectors[5].iter().map(|f| f.bits).collect::<Vec<_>>(),
            t2.vectors[5].iter().map(|f| f.bits).collect::<Vec<_>>()
        );
        // Realistic matmul data has a nonzero exponent spread and some
        // (padding/underflow) zeros.
        assert!(t1.mean_exponent_spread() > 2.0);
    }
}
