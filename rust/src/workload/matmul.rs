//! Matrix multiplication kernels and partial-product trace extraction.
//!
//! An `(M, K) × (K, N)` matmul computed with `n_terms`-wide fused adders
//! presents each output element's K products in ⌈K/n⌉ chunks of `n` lanes.
//! [`partial_product_trace`] reconstructs exactly those lane vectors
//! (products rounded to the adder's format, zero-padded tail), which is
//! what the switching-activity power model consumes.

use super::trace::Trace;
use crate::arith::normalize::normalize_round;
use crate::formats::{Fp, FpFormat};
use crate::reduce::ReducePlan;
use crate::util::prng::XorShift;

/// Plain row-major f32 matmul (the reference workload kernel).
pub fn matmul_f32(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    let mut out = vec![0f32; m * n];
    for i in 0..m {
        for l in 0..k {
            let av = a[i * k + l];
            if av == 0.0 {
                continue;
            }
            let brow = &b[l * n..(l + 1) * n];
            let orow = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                orow[j] += av * brow[j];
            }
        }
    }
    out
}

/// Fused-adder matmul: every output element is the **once-rounded** sum of
/// its K partial products (each product rounded into `fmt` exactly as
/// [`partial_product_trace`] captures them), reduced through the
/// [`ReducePlan`] API — this is the hot reduction path the SoA kernel
/// accelerates. With an exact-spec plan the result per element is the
/// correctly-rounded dot product regardless of the plan's backend; with a
/// truncated spec it models the hardware datapath under the chosen
/// backend's parenthesisation.
pub fn matmul_fused(
    a: &[f32],
    b: &[f32],
    (m, k, n): (usize, usize, usize),
    fmt: FpFormat,
    plan: &ReducePlan,
) -> Vec<Fp> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    let spec = plan.spec();
    let mut out = Vec::with_capacity(m * n);
    let mut prods: Vec<Fp> = Vec::with_capacity(k);
    for i in 0..m {
        for j in 0..n {
            prods.clear();
            for l in 0..k {
                let p = (a[i * k + l] as f64) * (b[l * n + j] as f64);
                prods.push(Fp::from_f64(p, fmt).finite_or_saturated());
            }
            out.push(normalize_round(&plan.reduce(&prods), spec, fmt));
        }
    }
    out
}

/// Extract multi-term adder input vectors from one matmul: for sampled
/// output elements `(i, j)`, the K partial products `a[i,l]·b[l,j]` rounded
/// into `fmt`, chunked into `n_terms` lanes. At most `max_vectors` vectors
/// are collected (sampled deterministically from `seed`).
pub fn partial_product_trace(
    a: &[f32],
    b: &[f32],
    (m, k, n): (usize, usize, usize),
    fmt: FpFormat,
    n_terms: usize,
    max_vectors: usize,
    seed: u64,
) -> Trace {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    let mut trace = Trace::new(fmt, n_terms);
    let mut rng = XorShift::new(seed ^ 0x7ACE);
    let chunks_per_elem = k.div_ceil(n_terms);
    while trace.len() < max_vectors {
        let i = rng.below(m as u64) as usize;
        let j = rng.below(n as u64) as usize;
        for c in 0..chunks_per_elem {
            if trace.len() >= max_vectors {
                break;
            }
            let mut vec = Vec::with_capacity(n_terms);
            for lane in 0..n_terms {
                let l = c * n_terms + lane;
                let p = if l < k { (a[i * k + l] as f64) * (b[l * n + j] as f64) } else { 0.0 };
                vec.push(Fp::from_f64(p, fmt).finite_or_saturated());
            }
            trace.push(vec);
        }
    }
    trace
}

impl Fp {
    /// Power traces must contain finite values only: NoInf formats saturate
    /// already, IEEE Inf is clamped to the max finite value (a rounding
    /// mode real accumulators configure for trace capture).
    pub fn finite_or_saturated(self) -> Fp {
        match self.class() {
            crate::formats::FpClass::Inf => {
                Fp::pack(self.sign(), self.format.max_normal_exp(), self.format.max_finite_mant(), self.format)
            }
            crate::formats::FpClass::Nan => Fp::zero(self.format),
            _ => self,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::{FpClass, BF16, FP8_E4M3};

    #[test]
    fn matmul_reference() {
        // [[1,2],[3,4]] x [[1,0],[0,1]] = same matrix
        let a = [1.0, 2.0, 3.0, 4.0];
        let eye = [1.0, 0.0, 0.0, 1.0];
        assert_eq!(matmul_f32(&a, &eye, 2, 2, 2), a.to_vec());
    }

    #[test]
    fn fused_matmul_backends_agree_and_round_correctly() {
        use crate::arith::exact::exact_rounded_sum;
        use crate::formats::FP32;
        use crate::reduce::registry;
        let (m, k, n) = (4usize, 40usize, 3usize);
        let mut rng = XorShift::new(0xFA5);
        let a: Vec<f32> = (0..m * k).map(|_| rng.gauss() as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.gauss() as f32).collect();
        let spec = crate::arith::AccSpec::exact(FP32);
        let scalar_plan = ReducePlan::with_backend(spec, registry::sel("scalar").unwrap());
        let scalar = matmul_fused(&a, &b, (m, k, n), FP32, &scalar_plan);
        assert_eq!(scalar.len(), m * n);
        // Every registered backend produces bit-identical elements.
        let mut kernel = scalar.clone();
        for entry in registry::entries() {
            let plan = ReducePlan::with_backend(spec, entry.sel());
            let got = matmul_fused(&a, &b, (m, k, n), FP32, &plan);
            for (s, g) in scalar.iter().zip(&got) {
                assert_eq!(
                    s.bits, g.bits,
                    "{}: backends must be bit-identical on exact specs",
                    entry.name
                );
            }
            if entry.name == "kernel" {
                kernel = got;
            }
        }
        // Spot-check one element against the independent correctly-rounded
        // oracle over the same rounded products.
        let (i, j) = (2usize, 1usize);
        let prods: Vec<Fp> = (0..k)
            .map(|l| {
                Fp::from_f64((a[i * k + l] as f64) * (b[l * n + j] as f64), FP32)
                    .finite_or_saturated()
            })
            .collect();
        assert_eq!(kernel[i * n + j].bits, exact_rounded_sum(&prods, FP32).bits);
    }

    #[test]
    fn trace_has_requested_geometry() {
        let mut rng = XorShift::new(1);
        let a: Vec<f32> = (0..16 * 40).map(|_| rng.gauss() as f32).collect();
        let b: Vec<f32> = (0..40 * 8).map(|_| rng.gauss() as f32).collect();
        let t = partial_product_trace(&a, &b, (16, 40, 8), BF16, 32, 100, 5);
        assert_eq!(t.len(), 100);
        assert!(t.vectors.iter().all(|v| v.len() == 32));
        // K=40 with 32 lanes: second chunk has 40-32=8 live + 24 zeros, so
        // global sparsity must be visible.
        assert!(t.zero_fraction() > 0.2);
    }

    #[test]
    fn products_are_finite_in_small_formats() {
        let a = vec![400.0f32; 8 * 8];
        let b = vec![400.0f32; 8 * 8];
        let t = partial_product_trace(&a, &b, (8, 8, 8), FP8_E4M3, 8, 50, 2);
        for v in &t.vectors {
            for x in v {
                assert!(x.is_finite(), "{x:?}");
            }
        }
    }

    #[test]
    fn tiny_products_land_in_the_subnormal_range() {
        // 0.05 · 0.05 = 0.0025 sits below e4m3's smallest normal (2^-6)
        // but above its smallest subnormal (2^-9): under gradual underflow
        // the trace keeps these lanes live instead of flushing them.
        let a = vec![0.05f32; 8 * 8];
        let b = vec![0.05f32; 8 * 8];
        let t = partial_product_trace(&a, &b, (8, 8, 8), FP8_E4M3, 8, 50, 2);
        let subnormals = t
            .vectors
            .iter()
            .flatten()
            .filter(|x| x.class() == FpClass::Subnormal)
            .count();
        assert!(subnormals > 0, "expected live subnormal product lanes");
        assert!(t.vectors.iter().flatten().all(|x| x.is_finite()));
    }
}
