//! Synthetic GLUE-like corpus: deterministic token sequences with natural
//! language statistics (Zipf-distributed token frequencies, shared
//! embedding table, positional signal) — the stand-in for the paper's GLUE
//! inputs documented in DESIGN.md §Substitutions.
//!
//! What matters for adder power is the *statistical shape* of matmul
//! operands (correlated rows, heavy-tailed magnitudes, realistic exponent
//! spread), which Zipf-weighted embeddings reproduce far better than white
//! noise.

use crate::util::prng::XorShift;

/// Corpus generation parameters.
#[derive(Clone, Copy, Debug)]
pub struct GlueConfig {
    pub vocab: usize,
    pub seq: usize,
    pub d_model: usize,
    /// Zipf exponent for token frequencies (~1.0 for natural text).
    pub zipf_s: f64,
}

impl Default for GlueConfig {
    fn default() -> Self {
        GlueConfig { vocab: 8192, seq: 128, d_model: 256, zipf_s: 1.05 }
    }
}

/// A deterministic synthetic corpus: embedding table + sentence sampler.
pub struct GlueCorpus {
    cfg: GlueConfig,
    embeddings: Vec<f32>, // (vocab, d_model)
    zipf_cdf: Vec<f64>,
}

impl GlueCorpus {
    #[allow(clippy::disallowed_methods)] // corpus generator, not datapath
    pub fn new(cfg: GlueConfig, seed: u64) -> Self {
        let mut rng = XorShift::new(seed ^ 0x617E5);
        let mut embeddings = Vec::with_capacity(cfg.vocab * cfg.d_model);
        // Token embeddings: cluster structure (32 topics) + token noise,
        // mimicking trained-embedding geometry.
        let topics = 32usize;
        let topic_means: Vec<f32> =
            (0..topics * cfg.d_model).map(|_| (rng.gauss() * 0.35) as f32).collect();
        for tok in 0..cfg.vocab {
            let topic = tok % topics;
            for d in 0..cfg.d_model {
                let mean = topic_means[topic * cfg.d_model + d];
                embeddings.push(mean + (rng.gauss() * 0.12) as f32);
            }
        }
        // Zipf CDF over the vocabulary.
        let mut weights: Vec<f64> =
            (1..=cfg.vocab).map(|r| 1.0 / (r as f64).powf(cfg.zipf_s)).collect();
        let total: f64 = weights.iter().sum();
        let mut acc = 0.0;
        for w in &mut weights {
            acc += *w / total;
            *w = acc;
        }
        GlueCorpus { cfg, embeddings, zipf_cdf: weights }
    }

    pub fn config(&self) -> GlueConfig {
        self.cfg
    }

    /// Sample one sentence as token ids (Zipf unigram + local repetition,
    /// which natural text has and white noise does not).
    pub fn sample_tokens(&self, rng: &mut XorShift) -> Vec<usize> {
        let mut toks = Vec::with_capacity(self.cfg.seq);
        for i in 0..self.cfg.seq {
            if i > 0 && rng.unit_f64() < 0.08 {
                toks.push(toks[i - 1]); // repeated word
                continue;
            }
            let u = rng.unit_f64();
            let tok = match self.zipf_cdf.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
                Ok(idx) | Err(idx) => idx.min(self.cfg.vocab - 1),
            };
            toks.push(tok);
        }
        toks
    }

    /// Embed one sentence: `(seq, d_model)` row-major activations with a
    /// sinusoidal positional component.
    #[allow(clippy::disallowed_methods)] // corpus generator, not datapath
    pub fn embed_sentence(&self, rng: &mut XorShift) -> Vec<f32> {
        let toks = self.sample_tokens(rng);
        let d = self.cfg.d_model;
        let mut out = Vec::with_capacity(self.cfg.seq * d);
        for (pos, &tok) in toks.iter().enumerate() {
            for i in 0..d {
                let emb = self.embeddings[tok * d + i];
                let angle = pos as f64 / (10000f64).powf(2.0 * (i / 2) as f64 / d as f64);
                let posenc = if i % 2 == 0 { angle.sin() } else { angle.cos() } as f32;
                out.push(emb + 0.1 * posenc);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_shaped() {
        let cfg = GlueConfig { vocab: 512, seq: 16, d_model: 32, ..Default::default() };
        let corpus = GlueCorpus::new(cfg, 7);
        let mut r1 = XorShift::new(1);
        let mut r2 = XorShift::new(1);
        let a = corpus.embed_sentence(&mut r1);
        let b = corpus.embed_sentence(&mut r2);
        assert_eq!(a, b);
        assert_eq!(a.len(), 16 * 32);
        assert!(a.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn zipf_skews_token_frequencies() {
        let cfg = GlueConfig { vocab: 1024, seq: 64, d_model: 8, ..Default::default() };
        let corpus = GlueCorpus::new(cfg, 3);
        let mut rng = XorShift::new(9);
        let mut counts = vec![0usize; 1024];
        for _ in 0..200 {
            for t in corpus.sample_tokens(&mut rng) {
                counts[t] += 1;
            }
        }
        let head: usize = counts[..16].iter().sum();
        let tail: usize = counts[512..].iter().sum();
        assert!(head > 4 * tail.max(1), "head {head} tail {tail}");
    }
}
