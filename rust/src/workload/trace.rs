//! Operand trace container + statistics.

use crate::formats::{Fp, FpClass, FpFormat};

/// A workload trace: each entry is one adder invocation — the `n_terms`
/// finite values presented to the input lanes in one cycle.
#[derive(Clone, Debug)]
pub struct Trace {
    pub format: FpFormat,
    pub n_terms: usize,
    pub vectors: Vec<Vec<Fp>>,
}

impl Trace {
    pub fn new(format: FpFormat, n_terms: usize) -> Self {
        Trace { format, n_terms, vectors: Vec::new() }
    }

    pub fn push(&mut self, v: Vec<Fp>) {
        debug_assert_eq!(v.len(), self.n_terms);
        debug_assert!(v.iter().all(|t| t.is_finite()));
        self.vectors.push(v);
    }

    pub fn len(&self) -> usize {
        self.vectors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.vectors.is_empty()
    }

    /// Fraction of zero operands (sparsity seen by the adder lanes).
    pub fn zero_fraction(&self) -> f64 {
        let total = self.len() * self.n_terms;
        if total == 0 {
            return 0.0;
        }
        let zeros: usize = self
            .vectors
            .iter()
            .map(|v| v.iter().filter(|t| t.class() == FpClass::Zero).count())
            .sum();
        zeros as f64 / total as f64
    }

    /// Mean intra-vector exponent spread (max − min over live lanes) — the
    /// quantity that decides how hard alignment works. Subnormal lanes
    /// participate at their effective exponent 1, exactly as the alignment
    /// datapath sees them.
    pub fn mean_exponent_spread(&self) -> f64 {
        let mut sum = 0.0;
        let mut n = 0usize;
        for v in &self.vectors {
            let exps: Vec<i32> = v
                .iter()
                .filter(|t| t.class() != FpClass::Zero)
                .map(|t| t.eff_exp())
                .collect();
            if exps.len() >= 2 {
                sum += (exps.iter().max().unwrap() - exps.iter().min().unwrap()) as f64;
                n += 1;
            }
        }
        if n == 0 {
            0.0
        } else {
            sum / n as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::BF16;

    #[test]
    fn stats() {
        let mut t = Trace::new(BF16, 4);
        t.push(vec![
            Fp::from_f64(1.0, BF16),
            Fp::from_f64(256.0, BF16),
            Fp::zero(BF16),
            Fp::from_f64(-2.0, BF16),
        ]);
        assert_eq!(t.len(), 1);
        assert!((t.zero_fraction() - 0.25).abs() < 1e-12);
        // exponents: 127, 135, 128 -> spread 8
        assert!((t.mean_exponent_spread() - 8.0).abs() < 1e-12);
    }
}
