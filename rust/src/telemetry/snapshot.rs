//! Point-in-time telemetry snapshots: a typed, ordered sample list that
//! the exposition layer (text table, Prometheus, JSON) renders without
//! touching live atomics.
//!
//! Determinism contract: with writers quiesced, two snapshots of the same
//! hub are `==` — samples appear in fixed code order, backend slots in
//! registry order, shard slots ascending, and only *labeled* slot samples
//! with activity are emitted (unlabeled slots carry no information).

use super::metrics::HistogramSnapshot;
use super::registry::{LatencyFamily, Telemetry, FORMAT_SLOTS, MAX_BACKEND_SLOTS, SHARD_SLOTS};

/// One exported metric value.
#[derive(Clone, Debug, PartialEq)]
pub enum MetricValue {
    Counter(u64),
    Gauge(i64),
    Histogram(HistogramSnapshot),
}

/// One exported sample: a metric name (see DESIGN.md §Observability for the
/// `ofa_<tier>_<name>` convention), its label set, and its value.
#[derive(Clone, Debug, PartialEq)]
pub struct MetricSample {
    pub name: &'static str,
    pub labels: Vec<(&'static str, String)>,
    pub value: MetricValue,
}

/// An ordered snapshot of every exported metric.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TelemetrySnapshot {
    pub samples: Vec<MetricSample>,
}

impl TelemetrySnapshot {
    pub fn push_counter(
        &mut self,
        name: &'static str,
        labels: Vec<(&'static str, String)>,
        v: u64,
    ) {
        self.samples.push(MetricSample { name, labels, value: MetricValue::Counter(v) });
    }

    pub fn push_gauge(&mut self, name: &'static str, labels: Vec<(&'static str, String)>, v: i64) {
        self.samples.push(MetricSample { name, labels, value: MetricValue::Gauge(v) });
    }

    pub fn push_histogram(
        &mut self,
        name: &'static str,
        labels: Vec<(&'static str, String)>,
        h: HistogramSnapshot,
    ) {
        self.samples.push(MetricSample { name, labels, value: MetricValue::Histogram(h) });
    }

    /// First sample with this metric name.
    pub fn get(&self, name: &str) -> Option<&MetricSample> {
        self.samples.iter().find(|s| s.name == name)
    }

    /// Sum of every counter sample with this name, across all label sets.
    pub fn counter(&self, name: &str) -> u64 {
        self.samples
            .iter()
            .filter(|s| s.name == name)
            .map(|s| match s.value {
                MetricValue::Counter(v) => v,
                _ => 0,
            })
            .sum()
    }

    /// The counter sample with this name carrying label `key="value"`.
    pub fn counter_labeled(&self, name: &str, key: &str, value: &str) -> u64 {
        self.samples
            .iter()
            .filter(|s| s.name == name && s.labels.iter().any(|(k, v)| *k == key && v == value))
            .map(|s| match s.value {
                MetricValue::Counter(v) => v,
                _ => 0,
            })
            .sum()
    }

    /// Prometheus text exposition (see [`super::expose::prometheus`]).
    pub fn to_prometheus(&self) -> String {
        super::expose::prometheus(self)
    }

    /// JSON exposition (see [`super::expose::json`]).
    pub fn to_json(&self) -> String {
        super::expose::json(self)
    }
}

fn label(key: &'static str, value: &str) -> Vec<(&'static str, String)> {
    vec![(key, value.to_string())]
}

/// Build the canonical snapshot of a hub (used by `Telemetry::snapshot`).
pub fn snapshot_of(t: &Telemetry) -> TelemetrySnapshot {
    let mut out = TelemetrySnapshot::default();

    // -- reduce: one counter set per *named* backend slot ----------------
    let names = t.backend_slot_names();
    for slot in 0..MAX_BACKEND_SLOTS {
        let name = names[slot];
        if name.is_empty() {
            continue;
        }
        let fam = t.reduce_slot(slot);
        out.push_counter("ofa_reduce_ingest_calls", label("backend", name), fam.ingest_calls.get());
        out.push_counter("ofa_reduce_ingest_terms", label("backend", name), fam.ingest_terms.get());
        out.push_counter("ofa_reduce_absorbs", label("backend", name), fam.absorbs.get());
        out.push_counter("ofa_reduce_finishes", label("backend", name), fam.finishes.get());
        out.push_counter("ofa_reduce_reduce_calls", label("backend", name), fam.reduce_calls.get());
    }

    // -- plan negotiation ------------------------------------------------
    out.push_counter("ofa_plan_builds", vec![], t.plan.builds.get());
    out.push_counter("ofa_plan_explicit", vec![], t.plan.explicit.get());
    out.push_counter("ofa_plan_negotiated_exact", vec![], t.plan.negotiated_exact.get());
    out.push_counter("ofa_plan_negotiated_truncated", vec![], t.plan.negotiated_truncated.get());
    out.push_counter(
        "ofa_plan_negotiated_order_invariant",
        vec![],
        t.plan.negotiated_order_invariant.get(),
    );

    // -- accum (EIA) numeric health --------------------------------------
    out.push_counter("ofa_accum_spills", vec![], t.accum.spills.get());
    out.push_counter("ofa_accum_wide_banks", vec![], t.accum.wide_banks.get());
    out.push_counter("ofa_accum_drains", vec![], t.accum.drains.get());
    out.push_counter("ofa_accum_drain_bins", vec![], t.accum.drain_bins.get());
    out.push_counter("ofa_accum_drain_sticky", vec![], t.accum.drain_sticky.get());
    out.push_histogram("ofa_accum_bin_occupancy", vec![], t.accum.occupancy.snapshot());

    // -- kernel path health ----------------------------------------------
    out.push_counter("ofa_kernel_block_sweeps", vec![], t.kernel.block_sweeps.get());
    out.push_counter("ofa_kernel_lanes", vec![], t.kernel.lanes.get());
    out.push_counter("ofa_kernel_narrow_blocks", vec![], t.kernel.narrow_blocks.get());
    out.push_counter("ofa_kernel_wide_blocks", vec![], t.kernel.wide_blocks.get());
    out.push_counter("ofa_kernel_sticky_activations", vec![], t.kernel.sticky_activations.get());
    out.push_histogram("ofa_kernel_block_lanes", vec![], t.kernel.block_lanes.snapshot());

    // -- streaming tier ---------------------------------------------------
    out.push_counter("ofa_stream_batches", vec![], t.stream.batches.get());
    out.push_counter("ofa_stream_batch_terms", vec![], t.stream.batch_terms.get());
    out.push_gauge("ofa_stream_queue_depth", vec![], t.stream.queue_depth.get());
    out.push_counter("ofa_stream_partial_merges", vec![], t.stream.partial_merges.get());
    out.push_counter("ofa_stream_codec_bytes_out", vec![], t.stream.codec_bytes_out.get());
    out.push_counter("ofa_stream_codec_bytes_in", vec![], t.stream.codec_bytes_in.get());
    for slot in 0..SHARD_SLOTS {
        let (merges, terms) = (t.stream.shard_merges[slot].get(), t.stream.shard_terms[slot].get());
        if merges == 0 && terms == 0 {
            continue; // untouched stripes carry no information
        }
        let shard = slot.to_string();
        out.push_counter("ofa_stream_shard_merges", label("shard", &shard), merges);
        out.push_counter("ofa_stream_shard_terms", label("shard", &shard), terms);
    }

    // -- serving-latency SLOs: one histogram per (named format × op) ------
    let formats = t.latency.format_names();
    for slot in 0..FORMAT_SLOTS {
        let format = formats[slot];
        if format.is_empty() {
            continue;
        }
        for (op_idx, op) in LatencyFamily::OPS.iter().enumerate() {
            out.push_histogram(
                "ofa_stream_latency",
                vec![("format", format.to_string()), ("op", op.to_string())],
                t.latency.cell(slot, op_idx).snapshot(),
            );
        }
    }

    // -- runtime executor -------------------------------------------------
    out.push_counter("ofa_runtime_batches", vec![], t.runtime.batches.get());
    out.push_counter("ofa_runtime_rows", vec![], t.runtime.rows.get());

    // -- tracing ----------------------------------------------------------
    out.push_counter("ofa_trace_events", vec![], t.trace.total());

    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshots_of_a_quiesced_hub_are_equal_and_queryable() {
        let t = Telemetry::new();
        t.register_backend_slot(0, "scalar");
        t.reduce_slot(0).ingest_terms.add(64);
        t.stream.shard_merges[3].inc();
        t.stream.shard_terms[3].add(9);
        t.accum.occupancy.observe(5);
        let (a, b) = (snapshot_of(&t), snapshot_of(&t));
        assert_eq!(a, b);
        assert_eq!(a.counter_labeled("ofa_reduce_ingest_terms", "backend", "scalar"), 64);
        assert_eq!(a.counter_labeled("ofa_stream_shard_merges", "shard", "3"), 1);
        assert_eq!(a.counter("ofa_stream_shard_terms"), 9);
        // Untouched stripes are not emitted; registered-but-idle backend
        // samples are (they are part of the stable surface).
        assert!(!a.samples.iter().any(|s| s.labels.contains(&("shard", "0".to_string()))));
        assert_eq!(a.counter_labeled("ofa_reduce_absorbs", "backend", "scalar"), 0);
        match &a.get("ofa_accum_bin_occupancy").unwrap().value {
            MetricValue::Histogram(h) => assert_eq!((h.count, h.sum), (1, 5)),
            other => panic!("expected histogram, got {other:?}"),
        }
    }
}
