//! Lock-free metric primitives: counters, gauges and log2-bucket
//! histograms, all const-constructible so whole metric families can live
//! in `static`s with zero startup cost.
//!
//! Design rules (see DESIGN.md §Observability):
//!
//! * every update is a handful of `Relaxed` atomic RMWs — no locks, no
//!   allocation, no syscalls on any record path (min/max tracking uses an
//!   explicit compare-exchange loop instead of the `Mutex` the old
//!   `coordinator::metrics` histogram took per observation);
//! * reads are racy-but-coherent per cell: a snapshot taken while writers
//!   run may split an update across cells (count vs sum), which is the
//!   standard monitoring trade — quiesce writers for exact cuts;
//! * `reset` is for tests and tools, not for concurrent use with writers.
//!
//! [`Counter`] and [`LatencyHistogram`] keep the exact API of the old
//! `coordinator::metrics` module, which now re-exports them.

use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::time::Duration;

/// Number of log2 histogram buckets: bucket `i` counts values in
/// `[2^i, 2^(i+1))`, so the range spans 1 to ~33.5M (µs: 1µs to ~17s).
pub const NBUCKETS: usize = 25;

/// A monotonically increasing counter. One relaxed `fetch_add` per update.
#[derive(Debug)]
pub struct Counter(AtomicU64);

impl Counter {
    pub const fn new() -> Self {
        Counter(AtomicU64::new(0))
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.0.store(0, Ordering::Relaxed);
    }
}

impl Default for Counter {
    fn default() -> Self {
        Counter::new()
    }
}

/// A signed instantaneous value (queue depths, in-flight work).
#[derive(Debug)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub const fn new() -> Self {
        Gauge(AtomicI64::new(0))
    }

    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn dec(&self) {
        self.0.fetch_sub(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: i64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.0.store(0, Ordering::Relaxed);
    }
}

impl Default for Gauge {
    fn default() -> Self {
        Gauge::new()
    }
}

/// Lock-free monotone min: CAS loop, settles in one iteration when the
/// current value already bounds `v` (the overwhelmingly common case).
fn atomic_min(cell: &AtomicU64, v: u64) {
    let mut cur = cell.load(Ordering::Relaxed);
    while v < cur {
        match cell.compare_exchange_weak(cur, v, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(seen) => cur = seen,
        }
    }
}

/// Lock-free monotone max (see [`atomic_min`]).
fn atomic_max(cell: &AtomicU64, v: u64) {
    let mut cur = cell.load(Ordering::Relaxed);
    while v > cur {
        match cell.compare_exchange_weak(cur, v, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(seen) => cur = seen,
        }
    }
}

/// An owned, comparable copy of a histogram's state — the exposition
/// layer renders these without holding any reference to the live cells.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HistogramSnapshot {
    pub count: u64,
    pub sum: u64,
    /// 0 when the histogram is empty.
    pub min: u64,
    pub max: u64,
    /// `(upper_bound, count)` per **non-empty** bucket, ascending; the
    /// bound is exclusive (`2^(i+1)` for bucket `i`), counts are
    /// per-bucket (not cumulative — Prometheus rendering cumulates).
    pub buckets: Vec<(u64, u64)>,
}

/// Histogram of nonnegative values in fixed log2 buckets, with exact
/// count/sum and CAS-tracked min/max.
#[derive(Debug)]
pub struct ValueHistogram {
    buckets: [AtomicU64; NBUCKETS], // bucket i: [2^i, 2^(i+1))
    count: AtomicU64,
    sum: AtomicU64,
    min: AtomicU64, // u64::MAX while empty
    max: AtomicU64,
}

impl ValueHistogram {
    pub const fn new() -> Self {
        #[allow(clippy::declare_interior_mutable_const)] // array-init template
        const ZERO: AtomicU64 = AtomicU64::new(0);
        ValueHistogram {
            buckets: [ZERO; NBUCKETS],
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
            max: AtomicU64::new(0),
        }
    }

    /// Record one value: five relaxed RMWs, no locks.
    pub fn observe(&self, v: u64) {
        let bucket = (63 - v.max(1).leading_zeros() as usize).min(NBUCKETS - 1);
        self.buckets[bucket].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        atomic_min(&self.min, v);
        atomic_max(&self.max, v);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum() as f64 / c as f64
        }
    }

    /// 0 while empty.
    pub fn min(&self) -> u64 {
        let m = self.min.load(Ordering::Relaxed);
        if m == u64::MAX {
            0
        } else {
            m
        }
    }

    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    /// Approximate quantile: the upper bound of the bucket holding the
    /// quantile rank (0 while empty, exact max past the last bucket).
    pub fn quantile(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let rank = ((total as f64) * q).ceil() as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= rank {
                return 1u64 << (i + 1); // bucket upper bound
            }
        }
        self.max()
    }

    pub fn snapshot(&self) -> HistogramSnapshot {
        let buckets = self
            .buckets
            .iter()
            .enumerate()
            .filter_map(|(i, b)| {
                let n = b.load(Ordering::Relaxed);
                (n > 0).then_some((1u64 << (i + 1), n))
            })
            .collect();
        HistogramSnapshot {
            count: self.count(),
            sum: self.sum(),
            min: self.min(),
            max: self.max(),
            buckets,
        }
    }

    pub fn reset(&self) {
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
        self.count.store(0, Ordering::Relaxed);
        self.sum.store(0, Ordering::Relaxed);
        self.min.store(u64::MAX, Ordering::Relaxed);
        self.max.store(0, Ordering::Relaxed);
    }
}

impl Default for ValueHistogram {
    fn default() -> Self {
        ValueHistogram::new()
    }
}

/// Latency histogram over microseconds (1 µs to ~17 s): a
/// [`ValueHistogram`] with `Duration` observation and the summary API the
/// batcher/engine call sites have always used — minus the old
/// per-observation `Mutex` for min/max, which is now the CAS loop.
#[derive(Debug)]
pub struct LatencyHistogram {
    inner: ValueHistogram,
}

impl LatencyHistogram {
    pub const fn new() -> Self {
        LatencyHistogram { inner: ValueHistogram::new() }
    }

    pub fn observe(&self, d: Duration) {
        self.inner.observe(d.as_micros().max(1) as u64);
    }

    pub fn count(&self) -> u64 {
        self.inner.count()
    }

    pub fn mean_us(&self) -> f64 {
        self.inner.mean()
    }

    /// Approximate quantile in µs (see [`ValueHistogram::quantile`]).
    pub fn quantile_us(&self, q: f64) -> u64 {
        self.inner.quantile(q)
    }

    pub fn min_us(&self) -> u64 {
        self.inner.min()
    }

    pub fn max_us(&self) -> u64 {
        self.inner.max()
    }

    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.0}µs p50≤{}µs p99≤{}µs min={}µs max={}µs",
            self.count(),
            self.mean_us(),
            self.quantile_us(0.5),
            self.quantile_us(0.99),
            self.min_us(),
            self.max_us()
        )
    }

    pub fn snapshot(&self) -> HistogramSnapshot {
        self.inner.snapshot()
    }

    pub fn reset(&self) {
        self.inner.reset();
    }
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_basics() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        c.reset();
        assert_eq!(c.get(), 0);
        let g = Gauge::new();
        g.inc();
        g.inc();
        g.dec();
        g.add(-3);
        assert_eq!(g.get(), -1);
        g.set(7);
        assert_eq!(g.get(), 7);
    }

    #[test]
    fn cas_minmax_tracks_exactly() {
        let h = ValueHistogram::new();
        assert_eq!(h.min(), 0); // empty
        for v in [500u64, 3, 90, 3, 1_000_000] {
            h.observe(v);
        }
        assert_eq!(h.min(), 3);
        assert_eq!(h.max(), 1_000_000);
        assert_eq!(h.count(), 5);
        assert_eq!(h.sum(), 500 + 3 + 90 + 3 + 1_000_000);
    }

    #[test]
    fn value_histogram_buckets_and_quantiles() {
        let h = ValueHistogram::new();
        for v in [10u64, 20, 30, 40, 1000] {
            h.observe(v);
        }
        assert!((h.mean() - 220.0).abs() < 1.0);
        let p50 = h.quantile(0.5);
        assert!((32..=64).contains(&p50), "p50 bound {p50}");
        assert!(h.quantile(0.99) >= 1024);
        let snap = h.snapshot();
        assert_eq!(snap.count, 5);
        assert_eq!(snap.buckets.iter().map(|&(_, n)| n).sum::<u64>(), 5);
        // Bucket bounds ascend and every recorded value fits under one.
        assert!(snap.buckets.windows(2).all(|w| w[0].0 < w[1].0));
    }

    #[test]
    fn latency_histogram_preserves_the_old_contract() {
        let h = LatencyHistogram::default();
        for us in [10u64, 20, 30, 40, 1000] {
            h.observe(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 5);
        assert!((h.mean_us() - 220.0).abs() < 1.0);
        assert!((32..=64).contains(&h.quantile_us(0.5)));
        assert!(h.quantile_us(0.99) >= 1024);
        assert_eq!(h.min_us(), 10);
        assert_eq!(h.max_us(), 1000);
        let s = h.summary();
        assert!(s.starts_with("n=5 "), "{s}");
        let empty = LatencyHistogram::default();
        assert_eq!(empty.quantile_us(0.99), 0);
        assert_eq!(empty.mean_us(), 0.0);
        assert!(empty.summary().contains("min=0µs"), "{}", empty.summary());
    }

    #[test]
    fn zero_observation_lands_in_the_first_bucket() {
        let h = ValueHistogram::new();
        h.observe(0);
        assert_eq!(h.count(), 1);
        assert_eq!(h.sum(), 0);
        assert_eq!(h.min(), 0);
        assert_eq!(h.snapshot().buckets, vec![(2, 1)]);
    }
}
