//! Exposition: render a [`TelemetrySnapshot`] as Prometheus text format
//! or JSON. Both renderers are pure functions of the snapshot — no live
//! atomics, no allocation surprises, no external dependencies (the
//! offline environment has no serde; the JSON is hand-rolled over a
//! closed, known-safe value space).

use super::metrics::HistogramSnapshot;
use super::snapshot::{MetricSample, MetricValue, TelemetrySnapshot};
use std::fmt::Write;

/// Escape a label/string value for both exposition formats (the value
/// space is metric/backend/format names — escaping is belt-and-braces).
/// Also reused by the provenance/flight JSON emitters.
pub(crate) fn escape(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

/// `{k="v",…}` or the empty string; `extra` appends one more pair (used
/// for histogram `le` bounds).
fn label_block(labels: &[(&'static str, String)], extra: Option<(&str, &str)>) -> String {
    let mut parts: Vec<String> =
        labels.iter().map(|(k, v)| format!("{k}=\"{}\"", escape(v))).collect();
    if let Some((k, v)) = extra {
        parts.push(format!("{k}=\"{v}\""));
    }
    if parts.is_empty() {
        String::new()
    } else {
        format!("{{{}}}", parts.join(","))
    }
}

fn prom_histogram(
    out: &mut String,
    name: &str,
    labels: &[(&'static str, String)],
    h: &HistogramSnapshot,
) {
    let mut cum = 0u64;
    for &(bound, n) in &h.buckets {
        cum += n;
        let lb = label_block(labels, Some(("le", &bound.to_string())));
        let _ = writeln!(out, "{name}_bucket{lb} {cum}");
    }
    let lb_inf = label_block(labels, Some(("le", "+Inf")));
    let _ = writeln!(out, "{name}_bucket{lb_inf} {}", h.count);
    let lb = label_block(labels, None);
    let _ = writeln!(out, "{name}_sum{lb} {}", h.sum);
    let _ = writeln!(out, "{name}_count{lb} {}", h.count);
}

/// Prometheus text exposition format 0.0.4: one `# TYPE` line per metric
/// name (samples of one name are contiguous in snapshot order), counters
/// suffixed `_total`, histograms expanded to cumulative `_bucket{le=}` /
/// `_sum` / `_count` series.
pub fn prometheus(snap: &TelemetrySnapshot) -> String {
    let mut out = String::new();
    let mut last_name = "";
    for s in &snap.samples {
        let kind = match s.value {
            MetricValue::Counter(_) => "counter",
            MetricValue::Gauge(_) => "gauge",
            MetricValue::Histogram(_) => "histogram",
        };
        if s.name != last_name {
            let _ = writeln!(out, "# TYPE {} {kind}", s.name);
            last_name = s.name;
        }
        match &s.value {
            MetricValue::Counter(v) => {
                let lb = label_block(&s.labels, None);
                let _ = writeln!(out, "{}_total{lb} {v}", s.name);
            }
            MetricValue::Gauge(v) => {
                let lb = label_block(&s.labels, None);
                let _ = writeln!(out, "{}{lb} {v}", s.name);
            }
            MetricValue::Histogram(h) => prom_histogram(&mut out, s.name, &s.labels, h),
        }
    }
    out
}

fn json_labels(labels: &[(&'static str, String)]) -> String {
    let parts: Vec<String> =
        labels.iter().map(|(k, v)| format!("\"{k}\":\"{}\"", escape(v))).collect();
    format!("{{{}}}", parts.join(","))
}

fn json_sample(s: &MetricSample) -> String {
    let head = format!("{{\"name\":\"{}\",\"labels\":{}", s.name, json_labels(&s.labels));
    match &s.value {
        MetricValue::Counter(v) => format!("{head},\"type\":\"counter\",\"value\":{v}}}"),
        MetricValue::Gauge(v) => format!("{head},\"type\":\"gauge\",\"value\":{v}}}"),
        MetricValue::Histogram(h) => {
            let buckets: Vec<String> =
                h.buckets.iter().map(|&(bound, n)| format!("[{bound},{n}]")).collect();
            format!(
                "{head},\"type\":\"histogram\",\"count\":{},\"sum\":{},\"min\":{},\"max\":{},\"buckets\":[{}]}}",
                h.count,
                h.sum,
                h.min,
                h.max,
                buckets.join(",")
            )
        }
    }
}

/// JSON exposition: `{"samples":[…]}`, one object per sample, in snapshot
/// order (deterministic for fixed inputs, like the snapshot itself).
pub fn json(snap: &TelemetrySnapshot) -> String {
    let body: Vec<String> = snap.samples.iter().map(|s| format!("  {}", json_sample(s))).collect();
    format!("{{\"samples\":[\n{}\n]}}\n", body.join(",\n"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_snapshot() -> TelemetrySnapshot {
        let mut snap = TelemetrySnapshot::default();
        snap.push_counter("ofa_reduce_ingest_terms", vec![("backend", "scalar".into())], 64);
        snap.push_gauge("ofa_stream_queue_depth", vec![], -2);
        snap.push_histogram(
            "ofa_accum_bin_occupancy",
            vec![],
            HistogramSnapshot { count: 3, sum: 9, min: 1, max: 5, buckets: vec![(2, 1), (8, 2)] },
        );
        snap
    }

    #[test]
    fn prometheus_renders_types_labels_and_cumulative_buckets() {
        let text = prometheus(&sample_snapshot());
        assert!(text.contains("# TYPE ofa_reduce_ingest_terms counter"), "{text}");
        assert!(text.contains("ofa_reduce_ingest_terms_total{backend=\"scalar\"} 64"), "{text}");
        assert!(text.contains("# TYPE ofa_stream_queue_depth gauge"), "{text}");
        assert!(text.contains("ofa_stream_queue_depth -2"), "{text}");
        assert!(text.contains("ofa_accum_bin_occupancy_bucket{le=\"2\"} 1"), "{text}");
        assert!(text.contains("ofa_accum_bin_occupancy_bucket{le=\"8\"} 3"), "{text}");
        assert!(text.contains("ofa_accum_bin_occupancy_bucket{le=\"+Inf\"} 3"), "{text}");
        assert!(text.contains("ofa_accum_bin_occupancy_sum 9"), "{text}");
        assert!(text.contains("ofa_accum_bin_occupancy_count 3"), "{text}");
    }

    #[test]
    fn json_is_deterministic_and_structurally_sound() {
        let (a, b) = (json(&sample_snapshot()), json(&sample_snapshot()));
        assert_eq!(a, b);
        assert!(a.contains("\"name\":\"ofa_reduce_ingest_terms\""), "{a}");
        assert!(a.contains("\"labels\":{\"backend\":\"scalar\"}"), "{a}");
        assert!(a.contains("\"type\":\"histogram\",\"count\":3,\"sum\":9"), "{a}");
        assert!(a.contains("\"buckets\":[[2,1],[8,2]]"), "{a}");
        // Balanced braces/brackets — cheap structural sanity without serde.
        for (open, close) in [('{', '}'), ('[', ']')] {
            let n_open = a.chars().filter(|&c| c == open).count();
            let n_close = a.chars().filter(|&c| c == close).count();
            assert_eq!(n_open, n_close, "{a}");
        }
    }

    #[test]
    fn label_values_escape_quotes_and_backslashes() {
        assert_eq!(escape(r#"a"b\c"#), r#"a\"b\\c"#);
    }
}
