//! The global telemetry hub: statically-allocated metric families for
//! every hot tier, one `enabled` gate, and the trace ring.
//!
//! Everything here is const-constructed into one `static` ([`TELEMETRY`])
//! so instrumentation sites hold `&'static` handles with no lazy-init
//! check: the enabled path is a relaxed atomic add per cell, the disabled
//! path is one relaxed load and a predictable branch. Call sites gate on
//! [`enabled`] **once** per operation and batch their updates (the kernel
//! accumulates per-call locals and flushes ≤ 5 adds per reduce call) so
//! the instrumented/uninstrumented throughput gap stays inside the CI
//! overhead gate (see `telemetry overhead` in `benches/perf.rs`).
//!
//! Backend-indexed metrics live in fixed slots ([`MAX_BACKEND_SLOTS`])
//! keyed by registry position; `reduce::registry` registers each slot's
//! name once so snapshots can label samples `backend="scalar"` etc.

use super::metrics::{Counter, Gauge, LatencyHistogram, ValueHistogram};
use super::snapshot::TelemetrySnapshot;
use super::trace::TraceRing;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, MutexGuard, PoisonError};
use std::time::Duration;

/// Fixed number of per-backend metric slots (the registry holds 3 today;
/// extra slots are free — 64 B each — and keep registration lock-free).
pub const MAX_BACKEND_SLOTS: usize = 8;

/// Fixed number of per-shard-stripe metric slots; stripe `i` maps to slot
/// `i % SHARD_SLOTS` (engines default to 16 stripes, a perfect fit).
pub const SHARD_SLOTS: usize = 16;

/// Fixed number of per-format serving-latency slots (five formats ship
/// today; spare slots keep registration allocation-free).
pub const FORMAT_SLOTS: usize = 8;

/// Per-backend reduction lifecycle counters (one slot per registered
/// backend, cache-line aligned so backends don't false-share).
#[repr(align(64))]
#[derive(Debug, Default)]
pub struct ReduceFamily {
    /// `Reducer::ingest`/`ingest_decoded` calls.
    pub ingest_calls: Counter,
    /// Terms absorbed across ingest and one-shot reduce calls.
    pub ingest_terms: Counter,
    /// Partials absorbed (`Reducer::absorb`).
    pub absorbs: Counter,
    /// `Reducer::finish` resolutions.
    pub finishes: Counter,
    /// One-shot `BackendSel::reduce` calls (the plan fast path).
    pub reduce_calls: Counter,
}

impl ReduceFamily {
    pub const fn new() -> Self {
        ReduceFamily {
            ingest_calls: Counter::new(),
            ingest_terms: Counter::new(),
            absorbs: Counter::new(),
            finishes: Counter::new(),
            reduce_calls: Counter::new(),
        }
    }

    fn reset(&self) {
        self.ingest_calls.reset();
        self.ingest_terms.reset();
        self.absorbs.reset();
        self.finishes.reset();
        self.reduce_calls.reset();
    }

    /// True iff every counter in the slot is zero (slot never touched).
    pub fn is_zero(&self) -> bool {
        self.ingest_calls.get() == 0
            && self.ingest_terms.get() == 0
            && self.absorbs.get() == 0
            && self.finishes.get() == 0
            && self.reduce_calls.get() == 0
    }
}

/// Plan-negotiation outcomes (`reduce::plan`), keyed by rationale.
#[repr(align(64))]
#[derive(Debug, Default)]
pub struct PlanFamily {
    /// Every successfully built plan.
    pub builds: Counter,
    /// Explicit backend selections (`ReducePlan::with_backend`).
    pub explicit: Counter,
    /// Negotiated: exact spec → kernel.
    pub negotiated_exact: Counter,
    /// Negotiated: truncated spec → scalar reference fold.
    pub negotiated_truncated: Counter,
    /// Negotiated: order-invariance required → EIA.
    pub negotiated_order_invariant: Counter,
}

impl PlanFamily {
    pub const fn new() -> Self {
        PlanFamily {
            builds: Counter::new(),
            explicit: Counter::new(),
            negotiated_exact: Counter::new(),
            negotiated_truncated: Counter::new(),
            negotiated_order_invariant: Counter::new(),
        }
    }

    fn reset(&self) {
        self.builds.reset();
        self.explicit.reset();
        self.negotiated_exact.reset();
        self.negotiated_truncated.reset();
        self.negotiated_order_invariant.reset();
    }
}

/// Exponent-indexed accumulator health (`accum/`).
#[repr(align(64))]
#[derive(Debug, Default)]
pub struct AccumFamily {
    /// Fast-lane `i64` → `i128` spill-lane promotions.
    pub spills: Counter,
    /// Values banked straight onto the wide lane (snapshot restores of
    /// magnitudes an `i64` cannot hold).
    pub wide_banks: Counter,
    /// Reconcile-and-align drains.
    pub drains: Counter,
    /// Occupied bins reconciled across all drains.
    pub drain_bins: Counter,
    /// Drains whose aligned result carried a sticky bit.
    pub drain_sticky: Counter,
    /// Occupied-bin count per drain.
    pub occupancy: ValueHistogram,
}

impl AccumFamily {
    pub const fn new() -> Self {
        AccumFamily {
            spills: Counter::new(),
            wide_banks: Counter::new(),
            drains: Counter::new(),
            drain_bins: Counter::new(),
            drain_sticky: Counter::new(),
            occupancy: ValueHistogram::new(),
        }
    }

    fn reset(&self) {
        self.spills.reset();
        self.wide_banks.reset();
        self.drains.reset();
        self.drain_bins.reset();
        self.drain_sticky.reset();
        self.occupancy.reset();
    }
}

/// SoA kernel path health (`arith::kernel`). Updated by one batched
/// flush per reduce call, not per block — see the module docs.
#[repr(align(64))]
#[derive(Debug, Default)]
pub struct KernelFamily {
    /// Block-λ max sweeps (= blocks processed).
    pub block_sweeps: Counter,
    /// SoA lanes (terms) pushed through the kernel.
    pub lanes: Counter,
    /// Blocks taking the narrow `i128` accumulate path.
    pub narrow_blocks: Counter,
    /// Blocks taking the wide `WideInt` accumulate path.
    pub wide_blocks: Counter,
    /// Block partials that activated the sticky bit.
    pub sticky_activations: Counter,
    /// Widest block's lane count per reduce call — the runtime side of the
    /// `analysis` tier's per-block carry-headroom bound (`kernel-block-acc`):
    /// CI asserts the observed max never exceeds the statically proved
    /// `2^PROVED_TERMS_LOG2` term ceiling.
    pub block_lanes: ValueHistogram,
}

impl KernelFamily {
    pub const fn new() -> Self {
        KernelFamily {
            block_sweeps: Counter::new(),
            lanes: Counter::new(),
            narrow_blocks: Counter::new(),
            wide_blocks: Counter::new(),
            sticky_activations: Counter::new(),
            block_lanes: ValueHistogram::new(),
        }
    }

    fn reset(&self) {
        self.block_sweeps.reset();
        self.lanes.reset();
        self.narrow_blocks.reset();
        self.wide_blocks.reset();
        self.sticky_activations.reset();
        self.block_lanes.reset();
    }
}

/// Streaming tier health (`stream/`).
#[repr(align(64))]
#[derive(Debug, Default)]
pub struct StreamFamily {
    /// Batches accepted onto the ingest queue.
    pub batches: Counter,
    /// Terms accepted onto the ingest queue.
    pub batch_terms: Counter,
    /// Batches currently queued (accepted, not yet reduced).
    pub queue_depth: Gauge,
    /// Backend-agnostic `Partial`s merged into shard state.
    pub partial_merges: Counter,
    /// Checkpoint-codec bytes serialized (`Partial::to_bytes`).
    pub codec_bytes_out: Counter,
    /// Checkpoint-codec bytes parsed (`Partial::from_bytes`, valid only).
    pub codec_bytes_in: Counter,
    /// Segment merges per shard-stripe slot (stripe `i % SHARD_SLOTS`).
    pub shard_merges: [Counter; SHARD_SLOTS],
    /// Terms merged per shard-stripe slot.
    pub shard_terms: [Counter; SHARD_SLOTS],
}

impl StreamFamily {
    pub const fn new() -> Self {
        #[allow(clippy::declare_interior_mutable_const)] // array-init template
        const C: Counter = Counter::new();
        StreamFamily {
            batches: Counter::new(),
            batch_terms: Counter::new(),
            queue_depth: Gauge::new(),
            partial_merges: Counter::new(),
            codec_bytes_out: Counter::new(),
            codec_bytes_in: Counter::new(),
            shard_merges: [C; SHARD_SLOTS],
            shard_terms: [C; SHARD_SLOTS],
        }
    }

    fn reset(&self) {
        self.batches.reset();
        self.batch_terms.reset();
        self.queue_depth.reset();
        self.partial_merges.reset();
        self.codec_bytes_out.reset();
        self.codec_bytes_in.reset();
        for c in &self.shard_merges {
            c.reset();
        }
        for c in &self.shard_terms {
            c.reset();
        }
    }
}

/// Per-(format × op) serving-latency SLO histograms (`stream::service`):
/// the `ofa_stream_latency{format=...,op=...}` exposition family.
/// Format slots register-or-find by name (like backend slots) so any
/// number of services over the same format share one slot.
#[derive(Debug)]
pub struct LatencyFamily {
    names: Mutex<[&'static str; FORMAT_SLOTS]>,
    hist: [[LatencyHistogram; LatencyFamily::OPS.len()]; FORMAT_SLOTS],
}

impl LatencyFamily {
    /// Served operations, in exposition order.
    pub const OPS: [&'static str; 3] = ["ingest", "query", "drain"];
    pub const OP_INGEST: usize = 0;
    pub const OP_QUERY: usize = 1;
    pub const OP_DRAIN: usize = 2;

    pub const fn new() -> Self {
        #[allow(clippy::declare_interior_mutable_const)] // array-init template
        const H: LatencyHistogram = LatencyHistogram::new();
        #[allow(clippy::declare_interior_mutable_const)] // array-init template
        const ROW: [LatencyHistogram; 3] = [H; 3];
        LatencyFamily { names: Mutex::new([""; FORMAT_SLOTS]), hist: [ROW; FORMAT_SLOTS] }
    }

    fn names(&self) -> MutexGuard<'_, [&'static str; FORMAT_SLOTS]> {
        self.names.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Find or claim the slot for a format name. Once per service
    /// construction — never on the serving path. When every slot is
    /// taken by other names, overflow formats share the last slot
    /// (clamped, like `reduce_slot`) rather than panic.
    pub fn register_format(&self, name: &'static str) -> usize {
        let mut names = self.names();
        for (i, n) in names.iter_mut().enumerate() {
            if *n == name {
                return i;
            }
            if n.is_empty() {
                *n = name;
                return i;
            }
        }
        FORMAT_SLOTS - 1
    }

    /// The registered format name per slot (`""` = unregistered).
    pub fn format_names(&self) -> [&'static str; FORMAT_SLOTS] {
        *self.names()
    }

    /// Record one served operation (indices clamp rather than panic).
    pub fn observe(&self, slot: usize, op: usize, elapsed: Duration) {
        self.hist[slot.min(FORMAT_SLOTS - 1)][op.min(Self::OPS.len() - 1)].observe(elapsed);
    }

    /// The histogram for one (slot, op) cell (indices clamp).
    pub fn cell(&self, slot: usize, op: usize) -> &LatencyHistogram {
        &self.hist[slot.min(FORMAT_SLOTS - 1)][op.min(Self::OPS.len() - 1)]
    }

    fn reset(&self) {
        for row in &self.hist {
            for h in row {
                h.reset();
            }
        }
    }
}

impl Default for LatencyFamily {
    fn default() -> Self {
        LatencyFamily::new()
    }
}

/// Artifact-runtime reduction executor (`runtime::reduce`).
#[repr(align(64))]
#[derive(Debug, Default)]
pub struct RuntimeFamily {
    /// Batches executed by `OnlineReduceExe::run`.
    pub batches: Counter,
    /// Rows reduced across all batches.
    pub rows: Counter,
}

impl RuntimeFamily {
    pub const fn new() -> Self {
        RuntimeFamily { batches: Counter::new(), rows: Counter::new() }
    }

    fn reset(&self) {
        self.batches.reset();
        self.rows.reset();
    }
}

/// Every metric family plus the trace ring, behind one enabled gate.
#[derive(Debug)]
pub struct Telemetry {
    enabled: AtomicBool,
    slot_names: Mutex<[&'static str; MAX_BACKEND_SLOTS]>,
    pub reduce: [ReduceFamily; MAX_BACKEND_SLOTS],
    pub plan: PlanFamily,
    pub accum: AccumFamily,
    pub kernel: KernelFamily,
    pub stream: StreamFamily,
    pub latency: LatencyFamily,
    pub runtime: RuntimeFamily,
    pub trace: TraceRing,
}

impl Telemetry {
    pub const fn new() -> Self {
        #[allow(clippy::declare_interior_mutable_const)] // array-init template
        const RF: ReduceFamily = ReduceFamily::new();
        Telemetry {
            enabled: AtomicBool::new(true),
            slot_names: Mutex::new([""; MAX_BACKEND_SLOTS]),
            reduce: [RF; MAX_BACKEND_SLOTS],
            plan: PlanFamily::new(),
            accum: AccumFamily::new(),
            kernel: KernelFamily::new(),
            stream: StreamFamily::new(),
            latency: LatencyFamily::new(),
            runtime: RuntimeFamily::new(),
            trace: TraceRing::new(),
        }
    }

    /// Master gate for metric recording. Instrumentation sites check this
    /// once per operation; when false they skip every update.
    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    /// The per-backend family for a registry slot (out-of-range indices
    /// clamp to the last slot rather than panic on the hot path).
    pub fn reduce_slot(&self, slot: usize) -> &ReduceFamily {
        &self.reduce[slot.min(MAX_BACKEND_SLOTS - 1)]
    }

    /// Name a backend slot for snapshot labels (idempotent; called once
    /// per backend by `reduce::registry`).
    pub fn register_backend_slot(&self, slot: usize, name: &'static str) {
        if slot < MAX_BACKEND_SLOTS {
            self.slot_names()[slot] = name;
        }
    }

    /// The registered backend name per slot (`""` = unregistered).
    pub fn backend_slot_names(&self) -> [&'static str; MAX_BACKEND_SLOTS] {
        *self.slot_names()
    }

    fn slot_names(&self) -> MutexGuard<'_, [&'static str; MAX_BACKEND_SLOTS]> {
        self.slot_names.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// A deterministic point-in-time copy of every exported metric.
    pub fn snapshot(&self) -> TelemetrySnapshot {
        super::snapshot::snapshot_of(self)
    }

    /// Zero every counter, gauge, histogram and the trace ring. Slot-name
    /// registrations and both enabled gates survive. For tests/tools —
    /// not safe to interleave with concurrent writers expecting exact
    /// counts.
    pub fn reset(&self) {
        for fam in &self.reduce {
            fam.reset();
        }
        self.plan.reset();
        self.accum.reset();
        self.kernel.reset();
        self.stream.reset();
        self.latency.reset();
        self.runtime.reset();
        self.trace.reset();
    }
}

impl Default for Telemetry {
    fn default() -> Self {
        Telemetry::new()
    }
}

/// The process-wide telemetry hub (const-initialized, always present).
pub static TELEMETRY: Telemetry = Telemetry::new();

/// The global hub — the handle every instrumentation site uses.
pub fn global() -> &'static Telemetry {
    &TELEMETRY
}

/// Shorthand for `global().enabled()`.
pub fn enabled() -> bool {
    TELEMETRY.enabled()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn local_hub_gates_and_resets() {
        // A local (non-global) hub: tests of the global live in
        // tests/telemetry.rs where they can serialize.
        let t = Telemetry::new();
        assert!(t.enabled());
        t.stream.batches.add(3);
        t.accum.occupancy.observe(4);
        t.reduce_slot(1).ingest_calls.inc();
        assert!(!t.reduce_slot(1).is_zero());
        t.reset();
        assert_eq!(t.stream.batches.get(), 0);
        assert_eq!(t.accum.occupancy.count(), 0);
        assert!(t.reduce_slot(1).is_zero());
        t.set_enabled(false);
        assert!(!t.enabled());
    }

    #[test]
    fn slot_registration_is_bounded_and_idempotent() {
        let t = Telemetry::new();
        t.register_backend_slot(0, "scalar");
        t.register_backend_slot(0, "scalar");
        t.register_backend_slot(MAX_BACKEND_SLOTS + 5, "ignored");
        let names = t.backend_slot_names();
        assert_eq!(names[0], "scalar");
        assert!(names[1..].iter().all(|n| n.is_empty()));
        // Out-of-range slot access clamps instead of panicking.
        t.reduce_slot(MAX_BACKEND_SLOTS + 5).ingest_calls.inc();
        assert_eq!(t.reduce[MAX_BACKEND_SLOTS - 1].ingest_calls.get(), 1);
    }

    #[test]
    fn latency_slots_register_find_and_reset() {
        let t = Telemetry::new();
        let a = t.latency.register_format("bf16");
        let b = t.latency.register_format("fp32");
        assert_eq!(t.latency.register_format("bf16"), a);
        assert_ne!(a, b);
        assert_eq!(t.latency.format_names()[a], "bf16");
        t.latency.observe(a, LatencyFamily::OP_QUERY, Duration::from_micros(250));
        assert_eq!(t.latency.cell(a, LatencyFamily::OP_QUERY).count(), 1);
        assert_eq!(t.latency.cell(a, LatencyFamily::OP_INGEST).count(), 0);
        t.reset();
        // Histograms clear; name registrations survive (like backends).
        assert_eq!(t.latency.cell(a, LatencyFamily::OP_QUERY).count(), 0);
        assert_eq!(t.latency.format_names()[b], "fp32");
        // Registration saturates at the last slot instead of panicking.
        for i in 0..2 * FORMAT_SLOTS {
            t.latency.register_format(["x0", "x1", "x2", "x3", "x4", "x5", "x6", "x7"][i % 8]);
        }
        assert!(t.latency.register_format("overflow") < FORMAT_SLOTS);
    }
}
