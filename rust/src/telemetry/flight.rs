//! The crash flight recorder: when the serving tier dies, leave behind
//! enough deterministic evidence to reconstruct what it was doing.
//!
//! A postmortem is one JSON document with three sections:
//!
//! * `"telemetry"` — the full [`super::TelemetrySnapshot`] (same JSON
//!   renderer as `repro stats --json`);
//! * `"trace_tail"` — the newest [`TAIL_LEN`] records of the global
//!   trace ring, span tags included;
//! * `"provenance"` — the bounded ring of the most recently cut
//!   [`ProvenanceRecord`]s ([`note_provenance`]), i.e. the streams that
//!   were in flight.
//!
//! Determinism: the document is a pure function of recorded state — no
//! wall-clock timestamps, no pointers, no environment echoes — so two
//! crashes after identical event histories dump identical files, and
//! CI can archive them as artifacts without noise.
//!
//! The panic hook is **opt-in** ([`install_panic_hook`], idempotent): it
//! chains the previously installed hook and fires even for panics later
//! swallowed by `catch_unwind`, which is exactly what covers the stream
//! engine's worker isolation. Dumps land in `$OFA_FLIGHT_DIR` (read at
//! install/dump time) or `target/flight/`, under a deterministic
//! reason-derived file name.

use std::collections::VecDeque;
use std::fmt::Write as _;
use std::io;
use std::path::{Path, PathBuf};
use std::sync::{Mutex, MutexGuard, Once, PoisonError};

use super::provenance::ProvenanceRecord;

/// Trace-ring records preserved in a postmortem.
pub const TAIL_LEN: usize = 64;

/// In-flight provenance records preserved (newest win).
pub const PROVENANCE_RING: usize = 16;

static INSTALL: Once = Once::new();
static RECENT: Mutex<VecDeque<ProvenanceRecord>> = Mutex::new(VecDeque::new());

fn recent() -> MutexGuard<'static, VecDeque<ProvenanceRecord>> {
    RECENT.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Remember a freshly cut provenance record so a later postmortem can
/// report the streams that were in flight. Bounded: keeps the newest
/// [`PROVENANCE_RING`] records.
pub fn note_provenance(rec: &ProvenanceRecord) {
    let mut ring = recent();
    if ring.len() == PROVENANCE_RING {
        ring.pop_front();
    }
    ring.push_back(rec.clone());
}

/// The in-flight provenance ring, oldest first (tests/postmortems).
pub fn recent_provenance() -> Vec<ProvenanceRecord> {
    recent().iter().cloned().collect()
}

/// Clear the in-flight provenance ring (tests).
pub fn reset_provenance() {
    recent().clear();
}

/// Where dumps land: `$OFA_FLIGHT_DIR`, else `target/flight`.
pub fn dump_dir() -> PathBuf {
    match std::env::var_os("OFA_FLIGHT_DIR") {
        Some(dir) if !dir.is_empty() => PathBuf::from(dir),
        _ => PathBuf::from("target").join("flight"),
    }
}

/// Deterministic file name for a dump reason: `postmortem-<slug>.json`
/// with the reason lowercased and squeezed to `[a-z0-9-]`.
pub fn dump_file_name(reason: &str) -> String {
    let mut slug = String::new();
    for c in reason.chars().take(48) {
        if c.is_ascii_alphanumeric() {
            slug.push(c.to_ascii_lowercase());
        } else if !slug.ends_with('-') && !slug.is_empty() {
            slug.push('-');
        }
    }
    let slug = slug.trim_matches('-');
    if slug.is_empty() {
        "postmortem.json".to_string()
    } else {
        format!("postmortem-{slug}.json")
    }
}

/// Render the postmortem JSON document for the global hub.
pub fn postmortem(reason: &str) -> String {
    let hub = super::registry::global();
    let mut out = String::new();
    out.push_str("{\"reason\":\"");
    out.push_str(&super::expose::escape(reason));
    out.push_str("\",\n\"trace_total\":");
    let _ = write!(out, "{}", hub.trace.total());
    out.push_str(",\n\"trace_tail\":[\n");
    let tail = hub.trace.tail(TAIL_LEN);
    for (i, rec) in tail.iter().enumerate() {
        if i > 0 {
            out.push_str(",\n");
        }
        let _ = write!(
            out,
            "  {{\"seq\":{},\"trace_id\":\"0x{:016x}\",\"span_id\":{},\"parent_id\":{},\"event\":\"{}\"}}",
            rec.seq,
            rec.span.trace_id,
            rec.span.span_id,
            rec.span.parent_id,
            super::expose::escape(&rec.event.to_string()),
        );
    }
    out.push_str("\n],\n\"provenance\":[\n");
    for (i, rec) in recent().iter().enumerate() {
        if i > 0 {
            out.push_str(",\n");
        }
        out.push_str("  ");
        out.push_str(&rec.to_json());
    }
    out.push_str("\n],\n\"telemetry\":");
    out.push_str(&hub.snapshot().to_json());
    out.push_str("}\n");
    out
}

/// Write the postmortem for `reason` into `dir`, returning the path.
pub fn dump_to(dir: &Path, reason: &str) -> io::Result<PathBuf> {
    std::fs::create_dir_all(dir)?;
    let path = dir.join(dump_file_name(reason));
    std::fs::write(&path, postmortem(reason))?;
    Ok(path)
}

/// Dump into the default directory (see [`dump_dir`]).
pub fn dump(reason: &str) -> io::Result<PathBuf> {
    dump_to(&dump_dir(), reason)
}

/// Install the flight-recorder panic hook (idempotent; chains whatever
/// hook was installed before, so default backtrace printing survives).
/// Opt-in because panic hooks are process-global: the CLI and the fault
/// tests install it; `#[should_panic]` unit tests stay unaffected.
pub fn install_panic_hook() {
    INSTALL.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let reason = match info.payload().downcast_ref::<&str>() {
                Some(s) => format!("panic: {s}"),
                None => match info.payload().downcast_ref::<String>() {
                    Some(s) => format!("panic: {s}"),
                    None => "panic".to_string(),
                },
            };
            let _ = dump(&reason);
            prev(info);
        }));
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::{AccSpec, WideInt};

    fn rec(stream: &str, terms: u64) -> ProvenanceRecord {
        ProvenanceRecord::new(
            stream,
            "bf16",
            AccSpec { f: 24, exact: true, narrow: false },
            "kernel",
            "why",
            terms,
            1,
            1,
            0,
            0,
            0,
            WideInt { limbs: [terms, 0, 0, 0, 0, 0] },
            false,
        )
    }

    #[test]
    fn file_names_are_deterministic_slugs() {
        assert_eq!(
            dump_file_name("panic: index out of bounds"),
            "postmortem-panic-index-out-of-bounds.json"
        );
        assert_eq!(dump_file_name(""), "postmortem.json");
        assert_eq!(dump_file_name("???"), "postmortem.json");
        assert_eq!(dump_file_name("selftest"), "postmortem-selftest.json");
    }

    #[test]
    fn provenance_ring_is_bounded_and_fifo() {
        reset_provenance();
        for i in 0..(PROVENANCE_RING as u64 + 3) {
            note_provenance(&rec(&format!("s{i}"), i));
        }
        let recent = recent_provenance();
        assert_eq!(recent.len(), PROVENANCE_RING);
        assert_eq!(recent[0].stream, "s3");
        assert_eq!(recent.last().unwrap().stream, format!("s{}", PROVENANCE_RING + 2));
        reset_provenance();
        assert!(recent_provenance().is_empty());
    }

    #[test]
    fn postmortem_is_deterministic_and_structurally_sound() {
        reset_provenance();
        note_provenance(&rec("pm-stream", 42));
        let (a, b) = (postmortem("unit"), postmortem("unit"));
        // Global-hub counters may move under concurrent tests, but the
        // document structure and the provenance section are stable.
        assert!(a.contains("\"reason\":\"unit\""));
        assert!(a.contains("\"stream\":\"pm-stream\""));
        assert!(a.contains("\"trace_tail\":["));
        assert!(a.contains("\"telemetry\":{\"samples\":["));
        assert!(b.contains("\"stream\":\"pm-stream\""));
        for (open, close) in [('{', '}'), ('[', ']')] {
            let n_open = a.chars().filter(|&c| c == open).count();
            let n_close = a.chars().filter(|&c| c == close).count();
            assert_eq!(n_open, n_close, "{a}");
        }
        reset_provenance();
    }
}
