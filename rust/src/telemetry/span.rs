//! Causal span contexts: the identity half of the tracing layer.
//!
//! A [`SpanContext`] names one unit of work on a stream's life —
//! an ingest batch, a worker reduction, a drain — and links it to its
//! parent so the trace ring can reconstruct a single stream end-to-end.
//! Three design rules keep it cheap enough for the hot path:
//!
//! * **`Copy`, three words.** `{trace_id, span_id, parent_id}` — no
//!   allocation, no refcount. Passing a context through a queue or a
//!   thread boundary is a struct copy.
//! * **Deterministic trace ids.** `trace_id` is the FNV-1a hash of the
//!   stream id, so any tier that knows the stream name can compute the
//!   trace id without plumbing — and two runs over the same streams
//!   produce the same trace ids.
//! * **Ambient current span.** The active span lives in a thread-local
//!   cell behind an RAII [`SpanGuard`]. [`super::TraceRing::record`]
//!   captures it automatically after the enabled gate, so existing
//!   record sites get span-tagged with zero call-site churn. The ring's
//!   per-record sequence number doubles as the monotonic clock that
//!   orders events within and across spans.
//!
//! Span ids come from one process-global counter: unique and monotone
//! in allocation order, never meaningful in absolute value.

use std::cell::Cell;
use std::sync::atomic::{AtomicU64, Ordering};

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// FNV-1a over a byte string — the repo-wide deterministic 64-bit hash
/// (also the base of the provenance hash in [`super::provenance`]).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// The causal identity of one unit of work. `trace_id` groups every
/// span of one stream's life; `parent_id` is the `span_id` of the span
/// that caused this one (0 = root). A zeroed context means "no span".
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SpanContext {
    pub trace_id: u64,
    pub span_id: u64,
    pub parent_id: u64,
}

static NEXT_SPAN_ID: AtomicU64 = AtomicU64::new(1);

fn next_span_id() -> u64 {
    NEXT_SPAN_ID.fetch_add(1, Ordering::Relaxed)
}

/// Deterministic trace id for a stream: FNV-1a of the id bytes, nudged
/// off 0 (0 is reserved for "no span").
pub fn trace_id_for(stream: &str) -> u64 {
    let h = fnv1a(stream.as_bytes());
    if h == 0 {
        1
    } else {
        h
    }
}

impl SpanContext {
    pub const NONE: SpanContext = SpanContext { trace_id: 0, span_id: 0, parent_id: 0 };

    pub fn is_none(&self) -> bool {
        self.trace_id == 0 && self.span_id == 0
    }

    /// A fresh root span on the given trace.
    pub fn root(trace_id: u64) -> SpanContext {
        SpanContext { trace_id, span_id: next_span_id(), parent_id: 0 }
    }

    /// A fresh root span on the stream's deterministic trace.
    pub fn for_stream(stream: &str) -> SpanContext {
        SpanContext::root(trace_id_for(stream))
    }

    /// A fresh child span: same trace, parented to `self`.
    pub fn child(&self) -> SpanContext {
        SpanContext {
            trace_id: self.trace_id,
            span_id: next_span_id(),
            parent_id: self.span_id,
        }
    }
}

thread_local! {
    static CURRENT: Cell<SpanContext> = const { Cell::new(SpanContext::NONE) };
}

/// The thread's ambient span (`NONE` outside any [`SpanGuard`]).
pub fn current() -> SpanContext {
    CURRENT.with(Cell::get)
}

/// RAII scope for the ambient span: restores the previous span on drop,
/// so guards nest correctly through re-entrant reduce/drain paths.
#[must_use = "dropping the guard immediately exits the span"]
pub struct SpanGuard {
    prev: SpanContext,
}

/// Make `ctx` the thread's ambient span until the guard drops.
pub fn enter(ctx: SpanContext) -> SpanGuard {
    let prev = CURRENT.with(|c| c.replace(ctx));
    SpanGuard { prev }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        CURRENT.with(|c| c.set(self.prev));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv1a_matches_reference_vectors() {
        // Published FNV-1a 64 test vectors.
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn trace_ids_are_deterministic_and_nonzero() {
        assert_eq!(trace_id_for("stats-0"), trace_id_for("stats-0"));
        assert_ne!(trace_id_for("stats-0"), trace_id_for("stats-1"));
        assert_ne!(trace_id_for(""), 0);
    }

    #[test]
    fn children_link_to_parents_on_the_same_trace() {
        let root = SpanContext::for_stream("s");
        let child = root.child();
        let grandchild = child.child();
        assert_eq!(root.parent_id, 0);
        assert_eq!(child.trace_id, root.trace_id);
        assert_eq!(child.parent_id, root.span_id);
        assert_eq!(grandchild.parent_id, child.span_id);
        assert_ne!(child.span_id, root.span_id);
        assert_ne!(grandchild.span_id, child.span_id);
    }

    #[test]
    fn guards_set_and_restore_the_ambient_span() {
        assert!(current().is_none());
        let outer = SpanContext::for_stream("outer");
        {
            let _g = enter(outer);
            assert_eq!(current(), outer);
            let inner = outer.child();
            {
                let _g2 = enter(inner);
                assert_eq!(current(), inner);
            }
            assert_eq!(current(), outer);
        }
        assert!(current().is_none());
    }
}
