//! Numeric provenance records: the per-stream audit trail behind every
//! served sum.
//!
//! A [`ProvenanceRecord`] explains *why a result is trustworthy*: which
//! format and spec governed the arithmetic, which backend the plan chose
//! and why, how much work flowed through (terms / segments / merges),
//! which numeric-health events fired (sticky activations, spill
//! promotions), the resolved `[λ; acc; sticky]` state, and a
//! deterministic **provenance hash**.
//!
//! ## The hash and its reproducibility contract
//!
//! The hash is FNV-1a 64 over a canonical byte encoding of the
//! **order-invariant value facts only**:
//!
//! ```text
//! format name ‖ 0x00 ‖ spec.f ‖ spec.exact ‖ terms ‖ λ ‖ acc limbs ‖ sticky
//! ```
//!
//! Execution-shape facts — backend, plan rationale, segment/merge
//! counts, sticky/spill event counts — ride along in the record for
//! humans but are deliberately **excluded** from the hash. That is what
//! makes the contract checkable: on an exact spec, `⊙` associativity and
//! commutativity (eq. 10) guarantee the resolved `[λ; acc; sticky]`
//! state is bit-identical under any arrival order, chunking, shard
//! split, or backend — so the hash must collapse to a single value per
//! (multiset of terms, format, spec). `tests/observability.rs` enforces
//! exactly that, ≥1k shuffled trials per format × backend.

use std::fmt::Write as _;

use super::span;
use crate::arith::{AccSpec, WideInt};

/// The audit record returned alongside `query`/`drain` results
/// (`StreamService::query_with_provenance` / `drain_with_provenance`)
/// and printed by `repro stats --provenance`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ProvenanceRecord {
    /// Stream id this record describes.
    pub stream: String,
    /// Format name (e.g. `"bf16"`).
    pub format: &'static str,
    /// Accumulator fraction width `f` of the governing spec.
    pub spec_f: u32,
    /// Exact (full-width) vs truncated accumulation.
    pub exact: bool,
    /// Backend the plan resolved to.
    pub backend: &'static str,
    /// The plan's full negotiation rationale.
    pub rationale: &'static str,
    /// Terms absorbed into the stream.
    pub terms: u64,
    /// Reduced segments merged into the stream's shard state.
    pub segments: u64,
    /// Shard merges applied engine-wide when the record was cut.
    pub merges: u64,
    /// Sticky-bit activations observed hub-wide when the record was cut.
    pub sticky_events: u64,
    /// EIA spill promotions observed hub-wide when the record was cut.
    pub spill_events: u64,
    /// Resolved max-exponent λ.
    pub lambda: i32,
    /// Resolved accumulator significand.
    pub acc: WideInt,
    /// Resolved sticky bit.
    pub sticky: bool,
    /// Deterministic trace id of the stream (FNV-1a of the id).
    pub trace_id: u64,
    /// Order-invariant provenance hash (see the module docs).
    pub hash: u64,
}

/// Incremental FNV-1a over the canonical encoding.
struct Fnv(u64);

impl Fnv {
    fn new() -> Fnv {
        Fnv(span::fnv1a(b""))
    }

    fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= u64::from(b);
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
}

/// The order-invariant provenance hash of a resolved stream state.
/// Covers value facts only (format identity, spec width/exactness, term
/// count, resolved `[λ; acc; sticky]`) — never execution shape — so on
/// exact specs any arrival order, chunking, or backend yields the same
/// hash for the same multiset of terms.
pub fn provenance_hash(
    format: &str,
    spec: AccSpec,
    terms: u64,
    lambda: i32,
    acc: &WideInt,
    sticky: bool,
) -> u64 {
    let mut h = Fnv::new();
    h.update(format.as_bytes());
    h.update(&[0]);
    h.update(&spec.f.to_le_bytes());
    h.update(&[u8::from(spec.exact)]);
    h.update(&terms.to_le_bytes());
    h.update(&(lambda as u32).to_le_bytes());
    for limb in &acc.limbs {
        h.update(&limb.to_le_bytes());
    }
    h.update(&[u8::from(sticky)]);
    h.0
}

impl ProvenanceRecord {
    /// Build a record from a resolved stream state plus execution-shape
    /// context, computing the hash and the deterministic trace id.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        stream: &str,
        format: &'static str,
        spec: AccSpec,
        backend: &'static str,
        rationale: &'static str,
        terms: u64,
        segments: u64,
        merges: u64,
        sticky_events: u64,
        spill_events: u64,
        lambda: i32,
        acc: WideInt,
        sticky: bool,
    ) -> ProvenanceRecord {
        ProvenanceRecord {
            stream: stream.to_string(),
            format,
            spec_f: spec.f,
            exact: spec.exact,
            backend,
            rationale,
            terms,
            segments,
            merges,
            sticky_events,
            spill_events,
            lambda,
            acc,
            sticky,
            trace_id: span::trace_id_for(stream),
            hash: provenance_hash(format, spec, terms, lambda, &acc, sticky),
        }
    }

    /// Human-readable multi-line rendering (CLI `--provenance` output).
    pub fn render(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(
            out,
            "provenance stream={:?} trace={:016x} hash={:016x}",
            self.stream, self.trace_id, self.hash
        );
        let _ = writeln!(
            out,
            "  format={} f={} exact={} backend={} terms={} segments={} merges={}",
            self.format, self.spec_f, self.exact, self.backend, self.terms, self.segments,
            self.merges
        );
        let _ = writeln!(
            out,
            "  lambda={} sticky={} sticky_events={} spill_events={}",
            self.lambda, self.sticky, self.sticky_events, self.spill_events
        );
        let _ = writeln!(out, "  acc={:?}", self.acc.limbs);
        let _ = write!(out, "  rationale={:?}", self.rationale);
        out
    }

    /// Deterministic JSON object fragment (flight-recorder postmortems).
    pub fn to_json(&self) -> String {
        let mut limbs = String::new();
        for (i, l) in self.acc.limbs.iter().enumerate() {
            if i > 0 {
                limbs.push(',');
            }
            let _ = write!(limbs, "\"0x{l:016x}\"");
        }
        format!(
            concat!(
                "{{\"stream\":\"{}\",\"format\":\"{}\",\"f\":{},\"exact\":{},",
                "\"backend\":\"{}\",\"rationale\":\"{}\",\"terms\":{},\"segments\":{},",
                "\"merges\":{},\"sticky_events\":{},\"spill_events\":{},\"lambda\":{},",
                "\"sticky\":{},\"acc\":[{}],\"trace_id\":\"0x{:016x}\",\"hash\":\"0x{:016x}\"}}"
            ),
            super::expose::escape(&self.stream),
            super::expose::escape(self.format),
            self.spec_f,
            self.exact,
            super::expose::escape(self.backend),
            super::expose::escape(self.rationale),
            self.terms,
            self.segments,
            self.merges,
            self.sticky_events,
            self.spill_events,
            self.lambda,
            self.sticky,
            limbs,
            self.trace_id,
            self.hash,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(f: u32, exact: bool) -> AccSpec {
        AccSpec { f, exact, narrow: false }
    }

    fn acc(limbs: [u64; crate::arith::wide::LIMBS]) -> WideInt {
        WideInt { limbs }
    }

    #[test]
    fn hash_depends_on_value_facts_only() {
        let a = acc([1, 2, 3, 0, 0, 0]);
        let base = provenance_hash("bf16", spec(24, true), 100, -5, &a, false);
        // Same value facts => same hash, regardless of who computed it.
        assert_eq!(base, provenance_hash("bf16", spec(24, true), 100, -5, &a, false));
        // Every value fact perturbs the hash.
        assert_ne!(base, provenance_hash("fp16", spec(24, true), 100, -5, &a, false));
        assert_ne!(base, provenance_hash("bf16", spec(25, true), 100, -5, &a, false));
        assert_ne!(base, provenance_hash("bf16", spec(24, false), 100, -5, &a, false));
        assert_ne!(base, provenance_hash("bf16", spec(24, true), 101, -5, &a, false));
        assert_ne!(base, provenance_hash("bf16", spec(24, true), 100, -4, &a, false));
        assert_ne!(base, provenance_hash("bf16", spec(24, true), 100, -5, &a, true));
        let a2 = acc([1, 2, 4, 0, 0, 0]);
        assert_ne!(base, provenance_hash("bf16", spec(24, true), 100, -5, &a2, false));
        // `narrow` is an execution-width choice, not a value fact.
        let narrow = AccSpec { f: 24, exact: true, narrow: true };
        assert_eq!(base, provenance_hash("bf16", narrow, 100, -5, &a, false));
    }

    #[test]
    fn record_seals_hash_and_trace_id_and_renders() {
        let rec = ProvenanceRecord::new(
            "stream-a",
            "bf16",
            spec(24, true),
            "kernel",
            "why",
            10,
            2,
            2,
            0,
            0,
            3,
            acc([7, 0, 0, 0, 0, 0]),
            false,
        );
        assert_eq!(rec.trace_id, span::trace_id_for("stream-a"));
        assert_eq!(
            rec.hash,
            provenance_hash("bf16", spec(24, true), 10, 3, &acc([7, 0, 0, 0, 0, 0]), false)
        );
        let text = rec.render();
        assert!(text.contains("stream=\"stream-a\""));
        assert!(text.contains("backend=kernel"));
        assert!(text.contains(&format!("hash={:016x}", rec.hash)));
        let json = rec.to_json();
        assert!(json.contains("\"backend\":\"kernel\""));
        assert!(json.contains("\"acc\":[\"0x0000000000000007\""));
        // Execution shape must not move the hash.
        let rec2 = ProvenanceRecord::new(
            "stream-a",
            "bf16",
            spec(24, true),
            "eia",
            "other",
            10,
            7,
            9,
            4,
            2,
            3,
            acc([7, 0, 0, 0, 0, 0]),
            false,
        );
        assert_eq!(rec.hash, rec2.hash);
    }
}
