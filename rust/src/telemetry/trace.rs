//! Structured span/event tracing: a fixed-capacity ring buffer of
//! numeric-health events, dumped on demand.
//!
//! Tracing is **off by default** and independently gated from the metric
//! counters: when disabled, [`TraceRing::record`] is one relaxed load and
//! an early return, so hot paths can call it unconditionally. When
//! enabled, each record takes the ring's mutex briefly — tracing is a
//! diagnostic mode, not a production-hot-path mode, and the capacity
//! bound keeps memory flat no matter how long the process runs.

use std::fmt;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, PoisonError};

/// Ring capacity: old events are overwritten once this many are live.
pub const TRACE_CAPACITY: usize = 1024;

/// One numeric-health event on the reduction path. Payloads are small
/// `Copy` scalars — recording never allocates beyond the ring slot.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceEvent {
    /// A `ReducePlan` was built: which backend won and why.
    PlanNegotiated { backend: &'static str, rationale: &'static str },
    /// A sequence-numbered segment reached an assembler (`parked` =
    /// buffered waiting for a predecessor under a truncated spec).
    SegmentOffered { seq: u64, parked: bool },
    /// An assembler merged segment `seq` into its running state.
    SegmentMerged { seq: u64 },
    /// A stream-engine worker reduced one ingest batch.
    BatchReduced { terms: u64, segments: u64 },
    /// An accumulator bin's fast `i64` lane promoted into the `i128`
    /// spill lane (bin index within the accumulator's window).
    SpillPromoted { bin: usize },
    /// An EIA drain reconciled `bins` occupied bins; `sticky` reports
    /// whether alignment dropped any bits.
    DrainReconciled { bins: u64, sticky: bool },
    /// A stream was drained from the shard map with this many terms.
    StreamDrained { terms: u64 },
}

impl fmt::Display for TraceEvent {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            TraceEvent::PlanNegotiated { backend, rationale } => {
                write!(f, "plan-negotiated backend={backend} rationale={rationale:?}")
            }
            TraceEvent::SegmentOffered { seq, parked } => {
                write!(f, "segment-offered seq={seq} parked={parked}")
            }
            TraceEvent::SegmentMerged { seq } => write!(f, "segment-merged seq={seq}"),
            TraceEvent::BatchReduced { terms, segments } => {
                write!(f, "batch-reduced terms={terms} segments={segments}")
            }
            TraceEvent::SpillPromoted { bin } => write!(f, "spill-promoted bin={bin}"),
            TraceEvent::DrainReconciled { bins, sticky } => {
                write!(f, "drain-reconciled bins={bins} sticky={sticky}")
            }
            TraceEvent::StreamDrained { terms } => write!(f, "stream-drained terms={terms}"),
        }
    }
}

/// A recorded event with its global sequence number (records only — the
/// sequence does not advance while tracing is disabled).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SpanRecord {
    pub seq: u64,
    pub event: TraceEvent,
}

impl fmt::Display for SpanRecord {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "#{:<6} {}", self.seq, self.event)
    }
}

/// Poison-tolerant lock: a panicked recorder must not kill tracing.
fn lock(ring: &Mutex<Vec<SpanRecord>>) -> MutexGuard<'_, Vec<SpanRecord>> {
    ring.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Fixed-capacity event ring, const-constructible for `static` use.
#[derive(Debug)]
pub struct TraceRing {
    enabled: AtomicBool,
    seq: AtomicU64,
    ring: Mutex<Vec<SpanRecord>>,
}

impl TraceRing {
    pub const fn new() -> Self {
        TraceRing {
            enabled: AtomicBool::new(false),
            seq: AtomicU64::new(0),
            ring: Mutex::new(Vec::new()),
        }
    }

    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    /// Record one event (no-op unless tracing is enabled). Events past
    /// capacity overwrite the oldest slots.
    pub fn record(&self, event: TraceEvent) {
        if !self.enabled() {
            return;
        }
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        let rec = SpanRecord { seq, event };
        let mut ring = lock(&self.ring);
        if ring.len() < TRACE_CAPACITY {
            ring.push(rec);
        } else {
            ring[(seq as usize) % TRACE_CAPACITY] = rec;
        }
    }

    /// Total events ever recorded (including any overwritten in the ring).
    pub fn total(&self) -> u64 {
        self.seq.load(Ordering::Relaxed)
    }

    /// Copy out the live records in sequence order.
    pub fn dump(&self) -> Vec<SpanRecord> {
        let mut out = lock(&self.ring).clone();
        out.sort_by_key(|r| r.seq);
        out
    }

    /// Drop all records and restart the sequence (leaves `enabled` as-is).
    pub fn reset(&self) {
        lock(&self.ring).clear();
        self.seq.store(0, Ordering::Relaxed);
    }
}

impl Default for TraceRing {
    fn default() -> Self {
        TraceRing::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_ring_records_nothing() {
        let ring = TraceRing::new();
        ring.record(TraceEvent::SegmentMerged { seq: 0 });
        assert_eq!(ring.total(), 0);
        assert!(ring.dump().is_empty());
    }

    #[test]
    fn ring_keeps_sequence_order_and_caps_memory() {
        let ring = TraceRing::new();
        ring.set_enabled(true);
        for i in 0..(TRACE_CAPACITY as u64 + 10) {
            ring.record(TraceEvent::SegmentMerged { seq: i });
        }
        assert_eq!(ring.total(), TRACE_CAPACITY as u64 + 10);
        let dump = ring.dump();
        assert_eq!(dump.len(), TRACE_CAPACITY);
        // Oldest 10 overwritten; the rest survive in ascending order.
        assert_eq!(dump[0].seq, 10);
        assert!(dump.windows(2).all(|w| w[0].seq < w[1].seq));
        ring.reset();
        assert_eq!(ring.total(), 0);
        assert!(ring.dump().is_empty());
    }

    #[test]
    fn events_render_for_dumps() {
        let e = TraceEvent::DrainReconciled { bins: 3, sticky: true };
        assert_eq!(e.to_string(), "drain-reconciled bins=3 sticky=true");
        let r = SpanRecord { seq: 7, event: TraceEvent::SpillPromoted { bin: 12 } };
        assert!(r.to_string().contains("#7"));
        assert!(r.to_string().contains("spill-promoted bin=12"));
    }
}
