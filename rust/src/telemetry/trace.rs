//! Structured span/event tracing: a fixed-capacity, **lock-free** ring
//! of numeric-health events, dumped on demand.
//!
//! Tracing is **off by default** and independently gated from the metric
//! counters: when disabled, [`TraceRing::record`] is one relaxed load and
//! an early return, so hot paths can call it unconditionally. When
//! enabled, a record is an atomic slot claim plus a handful of relaxed
//! word stores — no mutex anywhere on the path. Each record is
//! automatically tagged with the thread's ambient [`SpanContext`]
//! (see [`super::span`]), so a dump reconstructs a stream's life
//! end-to-end: ingest → queued batch → worker reduce → shard merge →
//! drain, all sharing one `trace_id`.
//!
//! ## Slot protocol (seqlock over atomics — no `unsafe` data races)
//!
//! Every slot is a group of atomic words guarded by a `version` word:
//! `0` = never written, odd = writer inside, even ≠ 0 = stable. A writer
//! claims the global sequence number (the ring's monotonic clock), CASes
//! the slot's version even→odd, stores the payload words relaxed, and
//! releases with `version + 2`. A reader snapshots the version, reads
//! the words, and keeps the record only if the version is unchanged,
//! even, and nonzero — torn reads are *discarded before decoding*, so
//! the `&'static str` payloads (stored as provenance-preserving
//! `AtomicPtr` + length pairs) are only ever materialized from a
//! consistent write. Under pathological contention a writer gives up
//! after a bounded spin and drops its record — never tears one —
//! while [`TraceRing::total`] still counts it.

use std::fmt;
use std::ptr;
use std::sync::atomic::{fence, AtomicBool, AtomicPtr, AtomicU64, Ordering};

use super::span::{self, SpanContext};

/// Ring capacity: old events are overwritten once this many are live.
pub const TRACE_CAPACITY: usize = 1024;

/// Bounded writer spin before a contended record is dropped (not torn).
const MAX_CLAIM_SPINS: usize = 256;

/// Bounded reader retries against a slot mid-write.
const MAX_READ_RETRIES: usize = 64;

/// One numeric-health event on the reduction path. Payloads are small
/// `Copy` scalars — recording never allocates beyond the ring slot.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceEvent {
    /// A `ReducePlan` was built: which backend won and why.
    PlanNegotiated { backend: &'static str, rationale: &'static str },
    /// A sequence-numbered segment reached an assembler (`parked` =
    /// buffered waiting for a predecessor under a truncated spec).
    SegmentOffered { seq: u64, parked: bool },
    /// An assembler merged segment `seq` into its running state.
    SegmentMerged { seq: u64 },
    /// An ingest batch was accepted onto the engine queue.
    BatchQueued { terms: u64 },
    /// A stream-engine worker reduced one ingest batch.
    BatchReduced { terms: u64, segments: u64 },
    /// A shard stripe absorbed a reduced segment into a stream's state.
    ShardMerged { stripe: usize, terms: u64 },
    /// A registry backend resolved a reduction to its final state.
    ReduceFinished { backend: &'static str, terms: u64 },
    /// An accumulator bin's fast `i64` lane promoted into the `i128`
    /// spill lane (bin index within the accumulator's window).
    SpillPromoted { bin: usize },
    /// An EIA drain reconciled `bins` occupied bins; `sticky` reports
    /// whether alignment dropped any bits.
    DrainReconciled { bins: u64, sticky: bool },
    /// A stream was drained from the shard map with this many terms.
    StreamDrained { terms: u64 },
}

impl fmt::Display for TraceEvent {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            TraceEvent::PlanNegotiated { backend, rationale } => {
                write!(f, "plan-negotiated backend={backend} rationale={rationale:?}")
            }
            TraceEvent::SegmentOffered { seq, parked } => {
                write!(f, "segment-offered seq={seq} parked={parked}")
            }
            TraceEvent::SegmentMerged { seq } => write!(f, "segment-merged seq={seq}"),
            TraceEvent::BatchQueued { terms } => write!(f, "batch-queued terms={terms}"),
            TraceEvent::BatchReduced { terms, segments } => {
                write!(f, "batch-reduced terms={terms} segments={segments}")
            }
            TraceEvent::ShardMerged { stripe, terms } => {
                write!(f, "shard-merged stripe={stripe} terms={terms}")
            }
            TraceEvent::ReduceFinished { backend, terms } => {
                write!(f, "reduce-finished backend={backend} terms={terms}")
            }
            TraceEvent::SpillPromoted { bin } => write!(f, "spill-promoted bin={bin}"),
            TraceEvent::DrainReconciled { bins, sticky } => {
                write!(f, "drain-reconciled bins={bins} sticky={sticky}")
            }
            TraceEvent::StreamDrained { terms } => write!(f, "stream-drained terms={terms}"),
        }
    }
}

/// A recorded event with its global sequence number and the span it
/// happened under. The sequence is the ring's monotonic clock (records
/// only — it does not advance while tracing is disabled).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SpanRecord {
    pub seq: u64,
    pub span: SpanContext,
    pub event: TraceEvent,
}

impl fmt::Display for SpanRecord {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "#{:<6} {}", self.seq, self.event)?;
        if !self.span.is_none() {
            write!(
                f,
                " trace={:016x} span={} parent={}",
                self.span.trace_id, self.span.span_id, self.span.parent_id
            )?;
        }
        Ok(())
    }
}

// Event wire tags for the slot encoding (0 = empty/invalid).
const TAG_PLAN: u64 = 1;
const TAG_SEG_OFFERED: u64 = 2;
const TAG_SEG_MERGED: u64 = 3;
const TAG_BATCH_QUEUED: u64 = 4;
const TAG_BATCH_REDUCED: u64 = 5;
const TAG_SHARD_MERGED: u64 = 6;
const TAG_REDUCE_FINISHED: u64 = 7;
const TAG_SPILL: u64 = 8;
const TAG_DRAIN: u64 = 9;
const TAG_STREAM_DRAINED: u64 = 10;

/// A `&'static str` flattened to plain words for atomic storage.
#[derive(Clone, Copy)]
struct RawStr {
    ptr: *const u8,
    len: u64,
}

const NO_STR: RawStr = RawStr { ptr: ptr::null(), len: 0 };

impl RawStr {
    fn of(s: &'static str) -> RawStr {
        RawStr { ptr: s.as_ptr(), len: s.len() as u64 }
    }

    /// Rebuild the `&'static str`. Only called on word pairs that
    /// passed the slot's version check, i.e. that were stored together
    /// from one writer's `RawStr::of(&'static str)`.
    fn get(self) -> &'static str {
        if self.ptr.is_null() {
            return "";
        }
        // SAFETY: `ptr`/`len` were derived from a live `&'static str`
        // by `RawStr::of` and read back consistently (the caller's
        // version check rejects torn pairs before this runs). The
        // AtomicPtr round-trip preserves provenance, the bytes are
        // 'static, and they were valid UTF-8 when flattened.
        unsafe {
            std::str::from_utf8_unchecked(std::slice::from_raw_parts(self.ptr, self.len as usize))
        }
    }
}

/// The payload words of one event, pre-validation.
#[derive(Clone, Copy)]
struct RawEvent {
    tag: u64,
    a: u64,
    b: u64,
    s0: RawStr,
    s1: RawStr,
}

fn encode(event: TraceEvent) -> RawEvent {
    let (tag, a, b, s0, s1) = match event {
        TraceEvent::PlanNegotiated { backend, rationale } => {
            (TAG_PLAN, 0, 0, RawStr::of(backend), RawStr::of(rationale))
        }
        TraceEvent::SegmentOffered { seq, parked } => {
            (TAG_SEG_OFFERED, seq, u64::from(parked), NO_STR, NO_STR)
        }
        TraceEvent::SegmentMerged { seq } => (TAG_SEG_MERGED, seq, 0, NO_STR, NO_STR),
        TraceEvent::BatchQueued { terms } => (TAG_BATCH_QUEUED, terms, 0, NO_STR, NO_STR),
        TraceEvent::BatchReduced { terms, segments } => {
            (TAG_BATCH_REDUCED, terms, segments, NO_STR, NO_STR)
        }
        TraceEvent::ShardMerged { stripe, terms } => {
            (TAG_SHARD_MERGED, stripe as u64, terms, NO_STR, NO_STR)
        }
        TraceEvent::ReduceFinished { backend, terms } => {
            (TAG_REDUCE_FINISHED, terms, 0, RawStr::of(backend), NO_STR)
        }
        TraceEvent::SpillPromoted { bin } => (TAG_SPILL, bin as u64, 0, NO_STR, NO_STR),
        TraceEvent::DrainReconciled { bins, sticky } => {
            (TAG_DRAIN, bins, u64::from(sticky), NO_STR, NO_STR)
        }
        TraceEvent::StreamDrained { terms } => (TAG_STREAM_DRAINED, terms, 0, NO_STR, NO_STR),
    };
    RawEvent { tag, a, b, s0, s1 }
}

fn decode(raw: RawEvent) -> Option<TraceEvent> {
    Some(match raw.tag {
        TAG_PLAN => TraceEvent::PlanNegotiated { backend: raw.s0.get(), rationale: raw.s1.get() },
        TAG_SEG_OFFERED => TraceEvent::SegmentOffered { seq: raw.a, parked: raw.b != 0 },
        TAG_SEG_MERGED => TraceEvent::SegmentMerged { seq: raw.a },
        TAG_BATCH_QUEUED => TraceEvent::BatchQueued { terms: raw.a },
        TAG_BATCH_REDUCED => TraceEvent::BatchReduced { terms: raw.a, segments: raw.b },
        TAG_SHARD_MERGED => TraceEvent::ShardMerged { stripe: raw.a as usize, terms: raw.b },
        TAG_REDUCE_FINISHED => {
            TraceEvent::ReduceFinished { backend: raw.s0.get(), terms: raw.a }
        }
        TAG_SPILL => TraceEvent::SpillPromoted { bin: raw.a as usize },
        TAG_DRAIN => TraceEvent::DrainReconciled { bins: raw.a, sticky: raw.b != 0 },
        TAG_STREAM_DRAINED => TraceEvent::StreamDrained { terms: raw.a },
        _ => return None,
    })
}

/// One ring slot: a version-guarded group of atomic words. All payload
/// state is atomic, so even racy access is defined behavior; the
/// version protocol only decides which reads are *kept*.
#[derive(Debug)]
struct Slot {
    /// 0 = empty, odd = writer inside, even ≠ 0 = stable.
    version: AtomicU64,
    seq: AtomicU64,
    tag: AtomicU64,
    a: AtomicU64,
    b: AtomicU64,
    s0_ptr: AtomicPtr<u8>,
    s0_len: AtomicU64,
    s1_ptr: AtomicPtr<u8>,
    s1_len: AtomicU64,
    trace_id: AtomicU64,
    span_id: AtomicU64,
    parent_id: AtomicU64,
}

impl Slot {
    const fn new() -> Self {
        Slot {
            version: AtomicU64::new(0),
            seq: AtomicU64::new(0),
            tag: AtomicU64::new(0),
            a: AtomicU64::new(0),
            b: AtomicU64::new(0),
            s0_ptr: AtomicPtr::new(ptr::null_mut()),
            s0_len: AtomicU64::new(0),
            s1_ptr: AtomicPtr::new(ptr::null_mut()),
            s1_len: AtomicU64::new(0),
            trace_id: AtomicU64::new(0),
            span_id: AtomicU64::new(0),
            parent_id: AtomicU64::new(0),
        }
    }

    /// Claim the write section: CAS the version even→odd. Returns the
    /// prior (even) version, or `None` after a bounded spin.
    fn claim(&self) -> Option<u64> {
        let mut v = self.version.load(Ordering::Relaxed);
        for _ in 0..MAX_CLAIM_SPINS {
            if v % 2 == 0 {
                match self.version.compare_exchange_weak(
                    v,
                    v + 1,
                    Ordering::AcqRel,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => return Some(v),
                    Err(cur) => v = cur,
                }
            } else {
                std::hint::spin_loop();
                v = self.version.load(Ordering::Relaxed);
            }
        }
        None
    }

    fn write(&self, seq: u64, span: SpanContext, raw: RawEvent) {
        let Some(v) = self.claim() else {
            return; // contended past the spin bound: drop, never tear
        };
        // Monotone guard: a writer delayed past a full ring wrap must
        // not clobber the newer record that took its slot.
        if v != 0 && self.seq.load(Ordering::Relaxed) > seq {
            self.version.store(v, Ordering::Release);
            return;
        }
        self.seq.store(seq, Ordering::Relaxed);
        self.tag.store(raw.tag, Ordering::Relaxed);
        self.a.store(raw.a, Ordering::Relaxed);
        self.b.store(raw.b, Ordering::Relaxed);
        self.s0_ptr.store(raw.s0.ptr.cast_mut(), Ordering::Relaxed);
        self.s0_len.store(raw.s0.len, Ordering::Relaxed);
        self.s1_ptr.store(raw.s1.ptr.cast_mut(), Ordering::Relaxed);
        self.s1_len.store(raw.s1.len, Ordering::Relaxed);
        self.trace_id.store(span.trace_id, Ordering::Relaxed);
        self.span_id.store(span.span_id, Ordering::Relaxed);
        self.parent_id.store(span.parent_id, Ordering::Relaxed);
        self.version.store(v + 2, Ordering::Release);
    }

    /// Read the slot's record, or `None` if empty / mid-write past the
    /// retry bound / holding an unknown tag.
    fn read(&self) -> Option<SpanRecord> {
        for _ in 0..MAX_READ_RETRIES {
            let v1 = self.version.load(Ordering::Acquire);
            if v1 == 0 {
                return None;
            }
            if v1 % 2 == 1 {
                std::hint::spin_loop();
                continue;
            }
            let seq = self.seq.load(Ordering::Relaxed);
            let raw = RawEvent {
                tag: self.tag.load(Ordering::Relaxed),
                a: self.a.load(Ordering::Relaxed),
                b: self.b.load(Ordering::Relaxed),
                s0: RawStr {
                    ptr: self.s0_ptr.load(Ordering::Relaxed),
                    len: self.s0_len.load(Ordering::Relaxed),
                },
                s1: RawStr {
                    ptr: self.s1_ptr.load(Ordering::Relaxed),
                    len: self.s1_len.load(Ordering::Relaxed),
                },
            };
            let span = SpanContext {
                trace_id: self.trace_id.load(Ordering::Relaxed),
                span_id: self.span_id.load(Ordering::Relaxed),
                parent_id: self.parent_id.load(Ordering::Relaxed),
            };
            fence(Ordering::Acquire);
            if self.version.load(Ordering::Relaxed) == v1 {
                // Consistent snapshot — only now is decoding (incl. the
                // &'static str rebuild) allowed.
                return decode(raw).map(|event| SpanRecord { seq, span, event });
            }
        }
        None
    }

    fn clear(&self) {
        self.tag.store(0, Ordering::Relaxed);
        self.seq.store(0, Ordering::Relaxed);
        self.version.store(0, Ordering::Release);
    }
}

/// Fixed-capacity lock-free event ring, const-constructible for
/// `static` use.
#[derive(Debug)]
pub struct TraceRing {
    enabled: AtomicBool,
    seq: AtomicU64,
    ring: [Slot; TRACE_CAPACITY],
}

impl TraceRing {
    pub const fn new() -> Self {
        #[allow(clippy::declare_interior_mutable_const)]
        const EMPTY: Slot = Slot::new();
        TraceRing {
            enabled: AtomicBool::new(false),
            seq: AtomicU64::new(0),
            ring: [EMPTY; TRACE_CAPACITY],
        }
    }

    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    /// Record one event under the thread's ambient span (no-op unless
    /// tracing is enabled). Events past capacity overwrite the oldest
    /// slots; the claim is a global `fetch_add` plus one slot CAS —
    /// no lock anywhere.
    pub fn record(&self, event: TraceEvent) {
        if !self.enabled() {
            return;
        }
        self.record_with(span::current(), event);
    }

    /// Record under an explicit span (no-op unless tracing is enabled).
    pub fn record_with(&self, span: SpanContext, event: TraceEvent) {
        if !self.enabled() {
            return;
        }
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        let slot = &self.ring[(seq as usize) % TRACE_CAPACITY];
        slot.write(seq, span, encode(event));
    }

    /// Total events ever recorded (including any overwritten in the
    /// ring or dropped under write contention).
    pub fn total(&self) -> u64 {
        self.seq.load(Ordering::Relaxed)
    }

    /// Copy out the live records in sequence order. Concurrent with
    /// writers this is a consistent *sample*: every returned record is
    /// whole (never torn), sequence numbers are unique and ascending.
    pub fn dump(&self) -> Vec<SpanRecord> {
        let mut out: Vec<SpanRecord> = self.ring.iter().filter_map(Slot::read).collect();
        out.sort_by_key(|r| r.seq);
        out
    }

    /// The newest `n` records in sequence order (flight-recorder tail).
    pub fn tail(&self, n: usize) -> Vec<SpanRecord> {
        let mut out = self.dump();
        if out.len() > n {
            out.drain(..out.len() - n);
        }
        out
    }

    /// Drop all records and restart the sequence (leaves `enabled`
    /// as-is). Not meant to race with writers: a writer mid-record may
    /// survive the sweep, which the next `dump()` tolerates.
    pub fn reset(&self) {
        for slot in &self.ring {
            slot.clear();
        }
        self.seq.store(0, Ordering::Relaxed);
    }
}

impl Default for TraceRing {
    fn default() -> Self {
        TraceRing::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn disabled_ring_records_nothing() {
        let ring = TraceRing::new();
        ring.record(TraceEvent::SegmentMerged { seq: 0 });
        assert_eq!(ring.total(), 0);
        assert!(ring.dump().is_empty());
    }

    #[test]
    fn ring_keeps_sequence_order_and_caps_memory() {
        let ring = TraceRing::new();
        ring.set_enabled(true);
        for i in 0..(TRACE_CAPACITY as u64 + 10) {
            ring.record(TraceEvent::SegmentMerged { seq: i });
        }
        assert_eq!(ring.total(), TRACE_CAPACITY as u64 + 10);
        let dump = ring.dump();
        assert_eq!(dump.len(), TRACE_CAPACITY);
        // Oldest 10 overwritten; the rest survive in ascending order.
        assert_eq!(dump[0].seq, 10);
        assert!(dump.windows(2).all(|w| w[0].seq < w[1].seq));
        ring.reset();
        assert_eq!(ring.total(), 0);
        assert!(ring.dump().is_empty());
    }

    #[test]
    fn events_render_for_dumps() {
        let e = TraceEvent::DrainReconciled { bins: 3, sticky: true };
        assert_eq!(e.to_string(), "drain-reconciled bins=3 sticky=true");
        let r = SpanRecord {
            seq: 7,
            span: SpanContext::NONE,
            event: TraceEvent::SpillPromoted { bin: 12 },
        };
        assert!(r.to_string().contains("#7"));
        assert!(r.to_string().contains("spill-promoted bin=12"));
        assert!(!r.to_string().contains("trace="));
    }

    #[test]
    fn records_carry_the_ambient_span_and_str_payloads_survive() {
        let ring = TraceRing::new();
        ring.set_enabled(true);
        let root = SpanContext::for_stream("span-test");
        {
            let _g = span::enter(root);
            ring.record(TraceEvent::PlanNegotiated { backend: "kernel", rationale: "why" });
        }
        ring.record(TraceEvent::SegmentMerged { seq: 1 });
        let dump = ring.dump();
        assert_eq!(dump.len(), 2);
        assert_eq!(dump[0].span, root);
        assert_eq!(
            dump[0].event,
            TraceEvent::PlanNegotiated { backend: "kernel", rationale: "why" }
        );
        assert!(dump[0].to_string().contains("trace="));
        // Outside the guard, records are span-free.
        assert!(dump[1].span.is_none());
    }

    /// Satellite pin: concurrent writers + a concurrent reader. Every
    /// dumped record must be whole (payload invariant intact), sequence
    /// numbers unique and ascending, capacity respected — both while
    /// writers run and after they finish.
    #[test]
    fn concurrent_records_are_never_torn_and_stay_ordered() {
        const THREADS: u64 = 4;
        const PER_THREAD: u64 = 2 * TRACE_CAPACITY as u64;
        let ring = Arc::new(TraceRing::new());
        ring.set_enabled(true);

        let check = |dump: &[SpanRecord]| {
            assert!(dump.len() <= TRACE_CAPACITY);
            assert!(dump.windows(2).all(|w| w[0].seq < w[1].seq), "dump not ascending");
            for r in dump {
                match r.event {
                    // Writers only ever store pairs with b == a ^ 0x5a:
                    // a torn record would break the invariant.
                    TraceEvent::BatchReduced { terms, segments } => {
                        assert_eq!(segments, terms ^ 0x5a, "torn record at seq {}", r.seq);
                    }
                    ref other => panic!("unexpected event in dump: {other}"),
                }
            }
        };

        std::thread::scope(|s| {
            for t in 0..THREADS {
                let ring = Arc::clone(&ring);
                s.spawn(move || {
                    for i in 0..PER_THREAD {
                        let a = t * PER_THREAD + i;
                        ring.record(TraceEvent::BatchReduced { terms: a, segments: a ^ 0x5a });
                    }
                });
            }
            // Sample concurrently with the writers.
            for _ in 0..20 {
                check(&ring.dump());
                std::thread::yield_now();
            }
        });

        assert_eq!(ring.total(), THREADS * PER_THREAD);
        let dump = ring.dump();
        check(&dump);
        // Quiesced: every surviving slot holds a decodable record, and
        // the newest record made it in (its writer was last to finish
        // claiming, so nothing newer could have dropped it).
        assert!(!dump.is_empty());
        assert!(dump.iter().all(|r| r.seq < THREADS * PER_THREAD));
    }

    #[test]
    fn tail_returns_newest_records() {
        let ring = TraceRing::new();
        ring.set_enabled(true);
        for i in 0..10 {
            ring.record(TraceEvent::SegmentMerged { seq: i });
        }
        let tail = ring.tail(3);
        assert_eq!(tail.len(), 3);
        assert_eq!(tail[0].seq, 7);
        assert_eq!(tail[2].seq, 9);
        assert_eq!(ring.tail(100).len(), 10);
    }
}
