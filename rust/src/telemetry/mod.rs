//! Cross-tier observability for the reduction stack: lock-free metrics,
//! numeric-health tracing, and exposition.
//!
//! The paper's argument is about *where work happens* on the multi-term
//! align-and-add path — incremental max-exponent tracking, alignment
//! shifts, sticky-bit accumulation fused into `⊙` (eq. 7/8). This tier
//! makes that work observable end to end: every hot tier records into one
//! statically-allocated hub, and three surfaces read it back out.
//!
//! Layering:
//!
//! * [`metrics`] — const-constructible primitives: [`Counter`], [`Gauge`],
//!   [`ValueHistogram`], [`LatencyHistogram`] (promoted from
//!   `coordinator::metrics`, which now re-exports them). Updates are
//!   relaxed atomic RMWs; min/max tracking is a CAS loop, never a lock.
//! * [`registry`] — the metric families per tier (`reduce`, `plan`,
//!   `accum`, `kernel`, `stream`, `runtime`) in the global [`TELEMETRY`]
//!   hub, gated by one `enabled` flag (default **on**; the disabled path
//!   is one relaxed load + a predictable branch per operation).
//! * [`span`] — causal span contexts ([`SpanContext`], ambient
//!   thread-local current span behind an RAII guard): the identity that
//!   lets a trace dump reconstruct one stream's life end-to-end.
//! * [`trace`] — the lock-free span/event ring ([`TraceRing`], default
//!   **off**): plan-negotiation rationale, segment lifecycle, batch and
//!   shard causality, spill promotions, drain reconciles — every record
//!   span-tagged, dump-on-demand with bounded memory.
//! * [`provenance`] — [`ProvenanceRecord`]: the per-stream numeric audit
//!   record returned by `query`/`drain`, carrying an order-invariant
//!   provenance hash over the resolved `[λ; acc; sticky]` state.
//! * [`flight`] — the crash flight recorder: a chained panic hook that
//!   dumps a deterministic JSON postmortem (telemetry snapshot +
//!   trace-ring tail + in-flight provenance) to disk.
//! * [`snapshot`] — [`TelemetrySnapshot`]: a deterministic, typed,
//!   ordered copy of every exported sample.
//! * [`expose`] — Prometheus-text and JSON renderers over a snapshot
//!   (served by `StreamService::stats_prometheus`/`stats_json` and the
//!   `repro stats` CLI).
//!
//! Metric naming, the counter/span contract, the overhead budget and the
//! full exported-metric table live in DESIGN.md §Observability. The
//! instrumented-vs-disabled throughput gap is bounded in CI by the
//! `telemetry overhead` series in `benches/perf.rs`.

pub mod expose;
pub mod flight;
pub mod metrics;
pub mod provenance;
pub mod registry;
pub mod snapshot;
pub mod span;
pub mod trace;

pub use metrics::{Counter, Gauge, HistogramSnapshot, LatencyHistogram, ValueHistogram};
pub use provenance::{provenance_hash, ProvenanceRecord};
pub use registry::{
    enabled, global, AccumFamily, KernelFamily, LatencyFamily, PlanFamily, ReduceFamily,
    RuntimeFamily, StreamFamily, Telemetry, FORMAT_SLOTS, MAX_BACKEND_SLOTS, SHARD_SLOTS,
    TELEMETRY,
};
pub use snapshot::{MetricSample, MetricValue, TelemetrySnapshot};
pub use span::SpanContext;
pub use trace::{SpanRecord, TraceEvent, TraceRing, TRACE_CAPACITY};
