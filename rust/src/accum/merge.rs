//! [`EiaSnapshot`]: a canonical, mergeable, serializable checkpoint of an
//! exponent-indexed accumulator.
//!
//! The snapshot stores each occupied bin's *total* value (the carry-save
//! lane split is an ingest-side detail that canonicalizes away), sorted by
//! exponent with zero-valued bins dropped. That canonical form makes merge
//! results comparable bit-for-bit: two snapshots combine by pointwise
//! exact integer adds plus a λ max and a term-count sum — associative
//! *and* commutative, so any grouping of per-shard partials collapses to
//! the same snapshot, exactly like `[λ; acc; sticky]` partials under `⊙`
//! in exact frames (eq. 10) but without ever leaving the deferred-alignment
//! domain. The byte codec below is what ships EIA state across shard /
//! checkpoint boundaries — as the `Deferred` variant of the unified
//! [`crate::reduce::Partial`] codec consumed by
//! `stream::shard::ShardMap::merge_partial`.

// Exact-datapath module: native float arithmetic and lossy casts are
// forbidden here (clippy.toml, DESIGN.md §Analysis).
#![deny(clippy::float_arithmetic, clippy::cast_precision_loss)]

use super::drain::drain_parts;
use super::eia::Eia;
use crate::arith::operator::AlignAcc;
use crate::arith::AccSpec;

/// Canonical checkpoint of one [`Eia`] (see the module docs).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct EiaSnapshot {
    /// Running maximum effective exponent over the covered live terms
    /// (0 = identity level) — survives even full cancellation, matching
    /// the `⊙` fold's λ semantics.
    pub max_lambda: i32,
    /// Terms covered (zeros included).
    pub terms: u64,
    /// `(eff_exp, exact bin value)`, ascending by exponent, no zeros.
    pub bins: Vec<(i32, i128)>,
}

/// Byte-codec magic + version ("EIA", format 1).
const MAGIC: [u8; 4] = *b"EIA1";
/// Header: magic (4) + max_lambda (4) + terms (8) + bin count (4).
const HEADER_LEN: usize = 20;
/// Per-bin record: eff_exp (4) + value (16).
const BIN_LEN: usize = 20;

impl EiaSnapshot {
    /// The identity checkpoint (no terms covered).
    pub const IDENTITY: EiaSnapshot =
        EiaSnapshot { max_lambda: 0, terms: 0, bins: Vec::new() };

    /// Capture `eia`'s state in canonical form.
    pub fn of(eia: &Eia) -> EiaSnapshot {
        let mut bins = Vec::new();
        if let Some((lo, hi)) = eia.bins().live_range() {
            for e in lo..=hi {
                let v = eia.bins().value(e);
                if v != 0 {
                    bins.push((e, v));
                }
            }
        }
        EiaSnapshot { max_lambda: eia.max_lambda(), terms: eia.terms(), bins }
    }

    /// True when this is the identity checkpoint.
    pub fn is_identity(&self) -> bool {
        self.max_lambda == 0 && self.bins.is_empty()
    }

    /// Combine two checkpoints (associative and commutative; canonical
    /// output, so any merge grouping of the same partials is `==`).
    pub fn merge(&self, other: &EiaSnapshot) -> EiaSnapshot {
        let mut bins = Vec::with_capacity(self.bins.len() + other.bins.len());
        let (mut i, mut j) = (0usize, 0usize);
        while i < self.bins.len() || j < other.bins.len() {
            let take_left = match (self.bins.get(i), other.bins.get(j)) {
                (Some((ea, _)), Some((eb, _))) if ea == eb => {
                    let v = self.bins[i]
                        .1
                        .checked_add(other.bins[j].1)
                        .expect("EIA bin overflow: accumulator headroom exceeded");
                    if v != 0 {
                        bins.push((self.bins[i].0, v));
                    }
                    i += 1;
                    j += 1;
                    continue;
                }
                (Some((ea, _)), Some((eb, _))) => ea < eb,
                (Some(_), None) => true,
                (None, _) => false,
            };
            if take_left {
                bins.push(self.bins[i]);
                i += 1;
            } else {
                bins.push(other.bins[j]);
                j += 1;
            }
        }
        EiaSnapshot {
            max_lambda: self.max_lambda.max(other.max_lambda),
            terms: self.terms + other.terms,
            bins,
        }
    }

    /// Reconcile-and-align this checkpoint into an [`AlignAcc`] under
    /// `spec` (same contract as [`Eia::drain`]).
    pub fn drain(&self, spec: AccSpec) -> AlignAcc {
        drain_parts(self.max_lambda, self.bins.iter().copied(), spec)
    }

    /// Restore a live accumulator from this checkpoint.
    pub fn restore(&self) -> Eia {
        let mut eia = Eia::new();
        for &(e, v) in &self.bins {
            eia.bins_mut().bank_wide(e, v);
        }
        eia.set_bookkeeping(self.max_lambda, self.terms);
        eia
    }

    /// Serialize to the portable little-endian byte format (see `MAGIC`).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(HEADER_LEN + BIN_LEN * self.bins.len());
        out.extend_from_slice(&MAGIC);
        out.extend_from_slice(&self.max_lambda.to_le_bytes());
        out.extend_from_slice(&self.terms.to_le_bytes());
        out.extend_from_slice(&(self.bins.len() as u32).to_le_bytes());
        for (e, v) in &self.bins {
            out.extend_from_slice(&e.to_le_bytes());
            out.extend_from_slice(&v.to_le_bytes());
        }
        out
    }

    /// Deserialize and validate (magic, length, canonical bin order and
    /// bin range) — a corrupted checkpoint must fail loudly, never bank
    /// garbage into a live sum.
    pub fn from_bytes(bytes: &[u8]) -> Result<EiaSnapshot, String> {
        if bytes.len() < HEADER_LEN {
            return Err(format!("EIA snapshot too short: {} bytes", bytes.len()));
        }
        if bytes[..4] != MAGIC {
            return Err("EIA snapshot: bad magic".into());
        }
        let max_lambda = i32::from_le_bytes(bytes[4..8].try_into().unwrap());
        let terms = u64::from_le_bytes(bytes[8..16].try_into().unwrap());
        let count = u32::from_le_bytes(bytes[16..20].try_into().unwrap()) as usize;
        if bytes.len() != HEADER_LEN + BIN_LEN * count {
            return Err(format!(
                "EIA snapshot: expected {} bytes for {count} bins, got {}",
                HEADER_LEN + BIN_LEN * count,
                bytes.len()
            ));
        }
        let mut bins = Vec::with_capacity(count);
        let mut prev_e = 0i32;
        for k in 0..count {
            let at = HEADER_LEN + BIN_LEN * k;
            let e = i32::from_le_bytes(bytes[at..at + 4].try_into().unwrap());
            let v = i128::from_le_bytes(bytes[at + 4..at + 20].try_into().unwrap());
            if !(1..super::bins::MAX_BINS as i32).contains(&e) {
                return Err(format!("EIA snapshot: bin exponent {e} out of range"));
            }
            if e <= prev_e && k > 0 {
                return Err("EIA snapshot: bins not strictly ascending".into());
            }
            if e > max_lambda {
                return Err(format!("EIA snapshot: bin {e} above λ {max_lambda}"));
            }
            if v == 0 {
                return Err(format!("EIA snapshot: non-canonical zero bin at {e}"));
            }
            bins.push((e, v));
            prev_e = e;
        }
        Ok(EiaSnapshot { max_lambda, terms, bins })
    }
}

impl Default for EiaSnapshot {
    fn default() -> Self {
        EiaSnapshot::IDENTITY
    }
}

/// Convenience: snapshot-level equivalent of
/// [`crate::reduce::ReducePlan::reduce`] for callers that want
/// to stay in the deferred domain.
pub fn snapshot_terms(terms: &[crate::formats::Fp]) -> EiaSnapshot {
    let mut eia = Eia::new();
    eia.ingest_terms(terms);
    eia.snapshot()
}

#[allow(clippy::float_arithmetic, clippy::cast_precision_loss, clippy::disallowed_methods)]
#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::{Fp, BF16};
    use crate::util::prng::XorShift;

    fn terms(rng: &mut XorShift, n: usize) -> Vec<Fp> {
        (0..n).map(|_| rng.gen_fp_sparse(BF16, 0.15)).collect()
    }

    #[test]
    fn snapshot_merge_matches_one_shot_and_is_canonical() {
        let mut rng = XorShift::new(0x5AA1);
        let spec = AccSpec::exact(BF16);
        for n in [2usize, 17, 64, 200] {
            let ts = terms(&mut rng, n);
            let whole = snapshot_terms(&ts);
            let cut = 1 + rng.below(n as u64 - 1) as usize;
            let (a, b) = (snapshot_terms(&ts[..cut]), snapshot_terms(&ts[cut..]));
            // Commutative and equal to the one-shot snapshot, field for
            // field (canonical form), hence also drain-equal.
            assert_eq!(a.merge(&b), whole, "n={n} cut={cut}");
            assert_eq!(b.merge(&a), whole, "n={n} cut={cut}");
            assert_eq!(a.merge(&b).drain(spec), whole.drain(spec));
        }
    }

    #[test]
    fn merge_is_associative_over_arbitrary_groupings() {
        let mut rng = XorShift::new(0x5AA2);
        let ts = terms(&mut rng, 120);
        let parts: Vec<EiaSnapshot> =
            ts.chunks(17).map(snapshot_terms).collect();
        let left = parts[1..]
            .iter()
            .fold(parts[0].clone(), |acc, p| acc.merge(p));
        let mut right = parts[parts.len() - 1].clone();
        for p in parts[..parts.len() - 1].iter().rev() {
            right = p.merge(&right);
        }
        assert_eq!(left, right);
        assert_eq!(left, snapshot_terms(&ts));
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = XorShift::new(0x5AA3);
        let s = snapshot_terms(&terms(&mut rng, 30));
        assert_eq!(EiaSnapshot::IDENTITY.merge(&s), s);
        assert_eq!(s.merge(&EiaSnapshot::IDENTITY), s);
        assert!(EiaSnapshot::IDENTITY.is_identity());
        assert!(EiaSnapshot::IDENTITY.drain(AccSpec::exact(BF16)).is_identity());
    }

    #[test]
    fn bytes_roundtrip_and_validation() {
        let mut rng = XorShift::new(0x5AA4);
        let s = snapshot_terms(&terms(&mut rng, 50));
        let bytes = s.to_bytes();
        assert_eq!(EiaSnapshot::from_bytes(&bytes).unwrap(), s);
        // Restore path: a round-tripped snapshot re-snapshots identically.
        assert_eq!(EiaSnapshot::from_bytes(&bytes).unwrap().restore().snapshot(), s);
        // Corruptions fail loudly.
        assert!(EiaSnapshot::from_bytes(&bytes[..bytes.len() - 1]).is_err());
        let mut bad_magic = bytes.clone();
        bad_magic[0] ^= 0xFF;
        assert!(EiaSnapshot::from_bytes(&bad_magic).is_err());
        let empty = EiaSnapshot::IDENTITY.to_bytes();
        assert_eq!(EiaSnapshot::from_bytes(&empty).unwrap(), EiaSnapshot::IDENTITY);
        assert!(EiaSnapshot::from_bytes(b"nope").is_err());
    }
}
