//! Exponent-indexed accumulator (EIA): the deferred-alignment backend
//! (DESIGN.md §Accumulator).
//!
//! Every other backend in this crate — the scalar `⊙` fold (Algorithm 3)
//! and the batched SoA kernel — performs *online* alignment: each term (or
//! block) pays a max-exponent update and a shift on the ingest path. This
//! subsystem is the opposite corner of that design space: alignment is
//! **deferred entirely**. A decoded term `(eff_exp, signed_sig)` is banked
//! into an accumulator bin indexed by its effective exponent — one integer
//! add, no max sweep, no shifter — and the whole alignment bill is paid
//! once, at query time, by a reconcile-and-round drain.
//!
//! Layering, bottom up:
//!
//! * [`bins`] — per-exponent-bin storage with a carry-save split: a fast
//!   `i64` lane absorbing ingests plus a spill lane for the (astronomically
//!   rare) carries, so banking never propagates a wide carry.
//! * [`eia`] — the accumulator itself: O(1) shift-free ingest of decoded
//!   terms, tracking the running maximum effective exponent `λ`.
//! * [`merge`] — [`EiaSnapshot`], a canonical, serializable checkpoint;
//!   two snapshots combine associatively and commutatively (pointwise
//!   exact integer adds), exactly like `[λ; acc; sticky]` partials do
//!   under `⊙` in exact frames — which is what lets EIA state ship
//!   between shards.
//! * [`drain`] — the single reconcile step: align every bin against the
//!   tracked `λ` and produce an [`crate::arith::operator::AlignAcc`].
//!
//! **Equivalence contract**: under an exact [`crate::arith::AccSpec`] the
//! drained `(λ, acc, sticky)` is **bit-identical** to the scalar `⊙` fold
//! over the same terms (both compute `λ = max eff_exp` and the same exact
//! integer sum `Σ sig_i · 2^(f − (λ − e_i))`; addition of exactly
//! represented integers commutes). Under a truncated spec the EIA is its
//! own parenthesisation — banking is still exact, bits drop only in the
//! one drain alignment — which buys a *stronger* reproducibility property
//! than the online backends: the truncated EIA result is invariant to
//! ingest order, chunking and merge grouping, because nothing lossy
//! happens before the final drain. `tests/eia_equivalence.rs` pins both
//! properties, plus a ≥ 5k-vector-per-format differential-oracle gate.

pub mod bins;
pub mod drain;
pub mod eia;
pub mod merge;

pub use bins::{ExpBins, MAX_BINS, SPILL_LIMIT_LOG2};
pub use eia::{reduce_terms_eia, Eia};
pub use merge::EiaSnapshot;
