//! The exponent-indexed accumulator: O(1) shift-free ingest of decoded
//! `(eff_exp, signed_sig)` terms, reconciled once at drain time.
//!
//! Where the online backends pay a max-exponent update and an alignment
//! shift per term (scalar fold) or per block (SoA kernel), the EIA ingest
//! is a single integer add into the term's exponent bin plus a running
//! `max` — the entire alignment network is deferred to
//! [`crate::accum::drain`]. The price is a query-time reconcile over the
//! occupied exponent range; the prize is an ingest path with no shifter at
//! all and a state that merges associatively across shards
//! ([`crate::accum::merge::EiaSnapshot`]).

// Exact-datapath module: native float arithmetic and lossy casts are
// forbidden here (clippy.toml, DESIGN.md §Analysis).
#![deny(clippy::float_arithmetic, clippy::cast_precision_loss)]

use super::bins::ExpBins;
use super::drain;
use super::merge::EiaSnapshot;
use crate::arith::operator::AlignAcc;
use crate::arith::AccSpec;
use crate::formats::Fp;

/// An exponent-indexed accumulator over decoded finite terms.
#[derive(Clone, Debug)]
pub struct Eia {
    bins: ExpBins,
    /// Running maximum effective exponent over *live* (nonzero) terms;
    /// 0 is the identity level, exactly as in
    /// [`AlignAcc::IDENTITY`] — so the drained λ
    /// matches the scalar `⊙` fold's λ bit for bit.
    max_lambda: i32,
    /// Terms ingested, zeros included (bookkeeping parity with
    /// [`crate::stream::Segment`]).
    terms: u64,
}

impl Eia {
    pub fn new() -> Self {
        Eia { bins: ExpBins::new(), max_lambda: 0, terms: 0 }
    }

    /// Ingest one finite term: decode to `(eff_exp, signed_sig)` and bank.
    /// Inf/NaN must be screened by the caller (same contract as
    /// [`crate::arith::kernel`]; see [`crate::arith::adder`] for the rules).
    #[inline]
    pub fn ingest(&mut self, t: Fp) {
        debug_assert!(t.is_finite(), "EIA ingest requires finite terms (screen specials first)");
        self.ingest_decoded(t.eff_exp(), t.signed_sig());
    }

    /// Ingest a pre-decoded `(eff_exp, signed_sig)` lane — the runtime's
    /// `(e, m)` field convention: a zero significand is the identity
    /// regardless of its exponent field (it neither banks nor lifts λ).
    #[inline]
    pub fn ingest_decoded(&mut self, eff_exp: i32, signed_sig: i64) {
        self.terms += 1;
        if signed_sig == 0 {
            return; // ±0 / dead lane: contributes nothing
        }
        self.max_lambda = self.max_lambda.max(eff_exp);
        self.bins.bank(eff_exp, signed_sig);
    }

    /// Ingest a slice of finite terms.
    pub fn ingest_terms(&mut self, terms: &[Fp]) {
        for t in terms {
            self.ingest(*t);
        }
    }

    /// Terms ingested so far (zeros included).
    pub fn terms(&self) -> u64 {
        self.terms
    }

    /// The running maximum effective exponent (0 = identity level).
    pub fn max_lambda(&self) -> i32 {
        self.max_lambda
    }

    /// True when only zeros (or nothing) have been ingested — the drain of
    /// such a state is [`AlignAcc::IDENTITY`].
    pub fn is_identity(&self) -> bool {
        self.max_lambda == 0 && self.bins.is_untouched()
    }

    pub(crate) fn bins(&self) -> &ExpBins {
        &self.bins
    }

    pub(crate) fn bins_mut(&mut self) -> &mut ExpBins {
        &mut self.bins
    }

    pub(crate) fn set_bookkeeping(&mut self, max_lambda: i32, terms: u64) {
        self.max_lambda = max_lambda;
        self.terms = terms;
    }

    /// Reconcile-and-align: produce the `[λ; acc; sticky]` state
    /// (bit-identical to the scalar `⊙` fold under exact specs — see
    /// [`crate::accum::drain`]).
    pub fn drain(&self, spec: AccSpec) -> AlignAcc {
        drain::drain_eia(self, spec)
    }

    /// A canonical, mergeable, serializable checkpoint of this state.
    pub fn snapshot(&self) -> EiaSnapshot {
        EiaSnapshot::of(self)
    }

    /// Fold another accumulator's state into this one (exact pointwise bin
    /// adds + λ max — associative and commutative).
    pub fn merge_from(&mut self, other: &Eia) {
        self.bins.merge_from(&other.bins);
        self.max_lambda = self.max_lambda.max(other.max_lambda);
        self.terms += other.terms;
    }
}

impl Default for Eia {
    fn default() -> Self {
        Self::new()
    }
}

/// One-shot EIA reduction of a term slice — the `"eia"` registry entry's
/// direct path ([`crate::reduce::registry`]): bank every term, reconcile
/// once.
pub fn reduce_terms_eia(terms: &[Fp], spec: AccSpec) -> AlignAcc {
    let mut eia = Eia::new();
    eia.ingest_terms(terms);
    eia.drain(spec)
}

#[allow(clippy::float_arithmetic, clippy::cast_precision_loss, clippy::disallowed_methods)]
#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::kernel::scalar_fold;
    use crate::formats::{BF16, FP32};
    use crate::util::prng::XorShift;

    fn mixed_terms(rng: &mut XorShift, fmt: crate::formats::FpFormat, n: usize) -> Vec<Fp> {
        (0..n)
            .map(|_| match rng.below(8) {
                0 => Fp::zero(fmt),
                1 | 2 => rng.gen_fp_subnormal(fmt),
                _ => rng.gen_fp_full(fmt),
            })
            .collect()
    }

    #[test]
    fn empty_and_all_zero_ingest_drain_to_the_identity() {
        let spec = AccSpec::exact(BF16);
        let eia = Eia::new();
        assert!(eia.is_identity());
        assert!(eia.drain(spec).is_identity());
        assert!(reduce_terms_eia(&[], spec).is_identity());
        let mut zeros = Eia::new();
        zeros.ingest_terms(&[Fp::zero(BF16); 12]);
        assert!(zeros.is_identity());
        assert_eq!(zeros.terms(), 12);
        assert!(zeros.drain(spec).is_identity());
    }

    #[test]
    fn single_term_drains_to_its_leaf() {
        let mut rng = XorShift::new(0xE1A1);
        for fmt in [BF16, FP32] {
            let spec = AccSpec::exact(fmt);
            for _ in 0..200 {
                let t = rng.gen_fp_full(fmt);
                assert_eq!(reduce_terms_eia(&[t], spec), AlignAcc::leaf(t, spec), "{t:?}");
            }
        }
    }

    #[test]
    fn drain_bit_matches_scalar_fold_exact() {
        let mut rng = XorShift::new(0xE1A2);
        for fmt in [BF16, FP32] {
            let spec = AccSpec::exact(fmt);
            for n in [1usize, 2, 16, 64, 300] {
                let terms = mixed_terms(&mut rng, fmt, n);
                assert_eq!(reduce_terms_eia(&terms, spec), scalar_fold(&terms, spec), "n={n}");
            }
        }
    }

    #[test]
    fn lambda_survives_full_cancellation() {
        // {x, -x}: the fold keeps λ = e_x with a zero accumulator; so must
        // the EIA (a cancelled bin stays inside the tracked state).
        let spec = AccSpec::exact(BF16);
        let x = Fp::from_f64(3.5, BF16);
        let nx = Fp::from_f64(-3.5, BF16);
        let got = reduce_terms_eia(&[x, nx], spec);
        assert_eq!(got, scalar_fold(&[x, nx], spec));
        assert_eq!(got.lambda, x.eff_exp());
        assert!(got.acc.is_zero());
    }

    #[test]
    fn merge_from_equals_single_accumulator() {
        let mut rng = XorShift::new(0xE1A3);
        let spec = AccSpec::exact(BF16);
        let terms = mixed_terms(&mut rng, BF16, 100);
        let mut whole = Eia::new();
        whole.ingest_terms(&terms);
        let (mut a, mut b) = (Eia::new(), Eia::new());
        a.ingest_terms(&terms[..37]);
        b.ingest_terms(&terms[37..]);
        a.merge_from(&b);
        assert_eq!(a.terms(), whole.terms());
        assert_eq!(a.drain(spec), whole.drain(spec));
    }
}
