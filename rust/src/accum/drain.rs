//! The reconcile-and-align drain: pay the whole deferred alignment bill in
//! one pass over the occupied exponent bins.
//!
//! Each bin holds the *exact* integer sum `v_e` of the significands banked
//! at effective exponent `e`; the drain aligns every bin value against the
//! tracked maximum `λ` and produces the standard `[λ; acc; sticky]` state:
//!
//! ```text
//! acc = Σ_e  v_e · 2^(f − (λ − e))        (sticky from any dropped bits)
//! ```
//!
//! **Exact specs** (`f ≥` the worst-case alignment distance): no shift
//! drops a bit, so the drain computes exactly the integer the scalar `⊙`
//! fold computes term by term — same `λ` (both track `max eff_exp` over
//! live terms, identity level 0), same two's-complement accumulator, same
//! (false) sticky: **bit-identical**, on both the narrow-`i128` and the
//! wide-`WideInt` accumulator paths.
//!
//! **Truncated specs**: a bin with alignment distance `d > f` contributes
//! `v_e ≫ (d − f)` with the dropped bits OR-folded into sticky — the same
//! net-shift arithmetic as [`crate::arith::kernel::block_state`]'s `d > f`
//! arm, applied to the exact bin sum. Because banking itself never drops a
//! bit, the truncated drain is invariant to ingest order and merge
//! grouping (the reproducibility gate in `tests/eia_equivalence.rs`);
//! its dropped-bit pattern is the "defer everything" parenthesisation,
//! deliberately distinct from the radix-2 fold's.

// Exact-datapath module: native float arithmetic and lossy casts are
// forbidden here (clippy.toml, DESIGN.md §Analysis).
#![deny(clippy::float_arithmetic, clippy::cast_precision_loss)]

use super::eia::Eia;
use crate::arith::operator::AlignAcc;
use crate::arith::{AccSpec, WideInt};
use crate::telemetry::{self, TraceEvent};
use std::cell::Cell;

/// Drain an [`Eia`] into an [`AlignAcc`] (see the module docs for the
/// equivalence contract).
pub fn drain_eia(eia: &Eia, spec: AccSpec) -> AlignAcc {
    let lambda = eia.max_lambda();
    // Count the occupied bins as the lazy sweep visits them, so the
    // occupancy metric costs nothing beyond the drain itself.
    let bins_seen = Cell::new(0u64);
    let parts = eia.bins().live_range().into_iter().flat_map(|(lo, hi)| {
        (lo..=hi).filter_map(|e| {
            let v = eia.bins().value(e);
            (v != 0).then(|| {
                bins_seen.set(bins_seen.get() + 1);
                (e, v)
            })
        })
    });
    let out = drain_parts(lambda, parts, spec);
    if telemetry::enabled() {
        let accum = &telemetry::global().accum;
        accum.drains.inc();
        accum.drain_bins.add(bins_seen.get());
        accum.occupancy.observe(bins_seen.get());
        if out.sticky {
            accum.drain_sticky.inc();
        }
    }
    telemetry::global()
        .trace
        .record(TraceEvent::DrainReconciled { bins: bins_seen.get(), sticky: out.sticky });
    out
}

/// Core drain over `(eff_exp, exact bin value)` parts. `lambda` must be at
/// least every part's exponent (the ingest-side running max guarantees
/// it). An empty iterator yields `[λ; 0; false]` — for λ = 0 that is the
/// identity, and for λ > 0 the fully-cancelled state the `⊙` fold also
/// produces.
pub(crate) fn drain_parts(
    lambda: i32,
    parts: impl Iterator<Item = (i32, i128)>,
    spec: AccSpec,
) -> AlignAcc {
    if spec.narrow {
        // Narrow fast path: the whole reconcile in two-limb arithmetic,
        // one dropped-bit mask OR-folded across the bins (§Perf).
        let f = spec.f;
        let mut acc = 0i128;
        let mut dropped = 0u128;
        for (e, v) in parts {
            debug_assert!(e <= lambda, "bin {e} above the tracked λ {lambda}");
            let d = (lambda - e) as u32;
            if d <= f {
                // (v << f) >> d with d ≤ f is v << (f − d): no bits drop
                // (shift composition), no full-width right shift.
                acc += v << (f - d);
            } else {
                // Net right shift ≥ 128 is pure sign fill either way, and
                // the mask still sees every magnitude bit of v.
                let sh = (d - f).min(127);
                acc += v >> sh;
                dropped |= (v as u128) & ((1u128 << sh) - 1);
            }
        }
        let sticky = dropped != 0;
        debug_assert!(!(spec.exact && sticky), "exact datapath must never drop bits");
        return AlignAcc { lambda, acc: WideInt::from_i128(acc), sticky };
    }
    let f = spec.f as i32;
    let mut acc = WideInt::ZERO;
    let mut sticky = false;
    for (e, v) in parts {
        debug_assert!(e <= lambda, "bin {e} above the tracked λ {lambda}");
        let d = lambda - e;
        if d <= f {
            acc = acc.add(&WideInt::from_i128(v).shl((f - d) as u32));
        } else {
            let sh = ((d - f) as u32).min(127);
            sticky |= (v as u128) & ((1u128 << sh) - 1) != 0;
            acc = acc.add(&WideInt::from_i128(v >> sh));
        }
    }
    debug_assert!(!(spec.exact && sticky), "exact datapath must never drop bits");
    AlignAcc { lambda, acc, sticky }
}

#[allow(clippy::float_arithmetic, clippy::cast_precision_loss, clippy::disallowed_methods)]
#[cfg(test)]
mod tests {
    use super::*;
    use crate::accum::reduce_terms_eia;
    use crate::arith::kernel::scalar_fold;
    use crate::formats::{Fp, BF16, FP32, FP8_E5M2};
    use crate::util::prng::XorShift;

    #[test]
    fn narrow_and_wide_drains_agree_bit_for_bit() {
        let mut rng = XorShift::new(0xD2A1);
        let narrow = AccSpec::exact(FP8_E5M2);
        assert!(narrow.narrow);
        let wide = AccSpec { narrow: false, ..narrow };
        for _ in 0..300 {
            let terms: Vec<Fp> = (0..48).map(|_| rng.gen_fp_full(FP8_E5M2)).collect();
            assert_eq!(reduce_terms_eia(&terms, narrow), reduce_terms_eia(&terms, wide));
        }
    }

    #[test]
    fn truncated_drain_is_ingest_order_invariant() {
        // Banking is exact, so even a bit-dropping drain cannot see the
        // ingest order — unlike the online fold, whose truncated result is
        // order-sensitive. This is the EIA's reproducibility edge.
        let mut rng = XorShift::new(0xD2A2);
        for spec in [AccSpec::truncated(2), AccSpec::truncated(8)] {
            for _ in 0..100 {
                let mut terms: Vec<Fp> = (0..40).map(|_| rng.gen_fp_full(FP32)).collect();
                let want = reduce_terms_eia(&terms, spec);
                rng.shuffle(&mut terms);
                assert_eq!(reduce_terms_eia(&terms, spec), want);
            }
        }
    }

    #[test]
    fn truncated_drain_sets_sticky_on_dropped_bits() {
        // 2^20 against 1.0 under a 2-bit guard: the small bin must drop
        // bits into sticky, with λ pinned to the big term.
        let spec = AccSpec::truncated(2);
        let big = Fp::from_f64(1048576.0, BF16);
        let small = Fp::from_f64(1.0, BF16);
        let r = reduce_terms_eia(&[big, small], spec);
        assert!(r.sticky);
        assert_eq!(r.lambda, big.eff_exp());
        // The radix-2 fold over two terms drops the same bits.
        assert_eq!(r, scalar_fold(&[big, small], spec));
    }
}
