//! Per-exponent-bin carry-save lanes: the storage layer of the
//! exponent-indexed accumulator.
//!
//! A bin holds the exact integer sum of the signed significands of every
//! term banked at one effective exponent. The sum is kept in two lanes in
//! the carry-save spirit: a fast `i64` lane (`lo`) that every ingest adds
//! into, and an `i128` spill lane (`hi`) that absorbs the fast lane
//! whenever it approaches its headroom — so the O(1) ingest never
//! propagates a carry wider than one machine word. The bin's value is
//! always `hi + lo`, and with per-term significands below 2^25 the fast
//! lane alone covers ~2^37 terms per bin before the first spill; the spill
//! lane then extends the exact range to ~2^127 — unreachable in practice,
//! and guarded by a checked add so saturation can never be silent.
//!
//! Bins are indexed by *effective* exponent ([`crate::formats::Fp::eff_exp`]):
//! subnormals bank at exponent 1 with hidden bit 0, zeros never reach a
//! bin, so every live index is in `[1, MAX_BINS)`. The spill lane is
//! allocated lazily — an accumulator that never spills carries only the
//! `i64` lanes.

// Exact-datapath module: native float arithmetic and lossy casts are
// forbidden here (clippy.toml, DESIGN.md §Analysis).
#![deny(clippy::float_arithmetic, clippy::cast_precision_loss)]

use crate::arith::SIG_BOUND_BITS;
use crate::telemetry::{self, TraceEvent};

/// Number of exponent bins: covers every paper format's effective-exponent
/// range (`eff_exp` ∈ `[1, max_normal_exp]`, and `max_normal_exp ≤ 254`
/// for 8-bit-exponent formats). Index 0 is the identity level and stays
/// untouched.
pub const MAX_BINS: usize = 256;

/// log2 of the fast-lane spill threshold — published so the `analysis`
/// tier can prove the no-overflow obligation (`eia-fast-lane`): a lane at
/// `SPILL_LIMIT − 1` plus one `< 2^SIG_BOUND_BITS` ingest needs
/// `max(62, 25) + 2 = 64` bits, exactly an `i64`.
pub const SPILL_LIMIT_LOG2: u32 = 62;

/// Fast-lane spill threshold: once `|lo|` reaches this, the lane is folded
/// into the wide lane. Leaves 2^25 of headroom below `i64::MAX`, so a
/// single post-threshold ingest can never overflow the fast lane.
const SPILL_LIMIT: u64 = 1 << SPILL_LIMIT_LOG2;

/// Per-exponent-bin carry-save storage (see the module docs).
#[derive(Clone, Debug)]
pub struct ExpBins {
    /// Fast lane: one `i64` per bin, absorbing every ingest. A fixed
    /// inline array (2 KB) — constructing an accumulator performs **no**
    /// heap allocation, so per-chunk `"eia"`-backend reductions don't
    /// pay allocator traffic on the hot path.
    lo: [i64; MAX_BINS],
    /// Spill (carry) lane: empty until the first spill, then `MAX_BINS`
    /// wide. A bin's value is `hi + lo`.
    hi: Vec<i128>,
    /// Touched-bin occupancy range; `min_e > max_e` means no bin has ever
    /// been banked into (only zeros, or nothing, ingested).
    min_e: i32,
    max_e: i32,
}

impl ExpBins {
    pub fn new() -> Self {
        ExpBins { lo: [0; MAX_BINS], hi: Vec::new(), min_e: MAX_BINS as i32, max_e: 0 }
    }

    /// O(1) shift-free ingest: add one term's signed significand to its
    /// exponent bin. Callers screen zeros (a zero significand is the
    /// identity and must not widen the occupancy range).
    #[inline]
    pub fn bank(&mut self, e: i32, sig: i64) {
        debug_assert!(
            (1..MAX_BINS as i32).contains(&e),
            "effective exponent {e} outside the bin range"
        );
        debug_assert!(sig != 0, "zero significands never reach a bin");
        debug_assert!(
            sig.unsigned_abs() < (1 << SIG_BOUND_BITS),
            "significand wider than any paper format"
        );
        let slot = &mut self.lo[e as usize];
        // |lo| < SPILL_LIMIT and |sig| < 2^25, so this add cannot overflow.
        *slot += sig;
        if slot.unsigned_abs() >= SPILL_LIMIT {
            self.spill(e as usize);
        }
        self.min_e = self.min_e.min(e);
        self.max_e = self.max_e.max(e);
    }

    /// Bank an arbitrary exact value into a bin (snapshot restore and
    /// cross-accumulator merge, where a bin sum no longer fits the
    /// single-term bound of [`ExpBins::bank`]).
    pub fn bank_wide(&mut self, e: i32, v: i128) {
        debug_assert!(
            (1..MAX_BINS as i32).contains(&e),
            "effective exponent {e} outside the bin range"
        );
        if v == 0 {
            return;
        }
        match i64::try_from(v) {
            // Small enough for the fast lane without overflowing it
            // (|lo| < 2^62 and |small| < 2^62 sum below i64::MAX).
            Ok(small) if small.unsigned_abs() < SPILL_LIMIT => {
                let slot = &mut self.lo[e as usize];
                *slot += small;
                if slot.unsigned_abs() >= SPILL_LIMIT {
                    self.spill(e as usize);
                }
            }
            _ => {
                self.ensure_hi();
                self.hi[e as usize] = self.hi[e as usize]
                    .checked_add(v)
                    .expect("EIA bin overflow: accumulator headroom exceeded");
                if telemetry::enabled() {
                    telemetry::global().accum.wide_banks.inc();
                }
            }
        }
        self.min_e = self.min_e.min(e);
        self.max_e = self.max_e.max(e);
    }

    fn ensure_hi(&mut self) {
        if self.hi.is_empty() {
            self.hi = vec![0; MAX_BINS];
        }
    }

    fn spill(&mut self, idx: usize) {
        self.ensure_hi();
        self.hi[idx] = self.hi[idx]
            .checked_add(self.lo[idx] as i128)
            .expect("EIA bin overflow: accumulator headroom exceeded");
        self.lo[idx] = 0;
        if telemetry::enabled() {
            telemetry::global().accum.spills.inc();
        }
        telemetry::global().trace.record(TraceEvent::SpillPromoted { bin: idx });
    }

    /// The bin's exact value (`hi + lo`). The lanes are a carry-save
    /// split of a value far below `i128` range, so this add is exact.
    #[inline]
    pub fn value(&self, e: i32) -> i128 {
        let lo = self.lo[e as usize] as i128;
        if self.hi.is_empty() {
            lo
        } else {
            self.hi[e as usize] + lo
        }
    }

    /// Inclusive range of bins ever banked into, or `None` if untouched.
    /// (A touched bin may still hold value 0 after exact cancellation.)
    pub fn live_range(&self) -> Option<(i32, i32)> {
        if self.min_e > self.max_e {
            None
        } else {
            Some((self.min_e, self.max_e))
        }
    }

    /// True when no bin was ever banked into.
    pub fn is_untouched(&self) -> bool {
        self.min_e > self.max_e
    }

    /// Fold every bin of `other` into this store (pointwise exact integer
    /// adds — associative and commutative by construction).
    pub fn merge_from(&mut self, other: &ExpBins) {
        let Some((lo_e, hi_e)) = other.live_range() else { return };
        for e in lo_e..=hi_e {
            self.bank_wide(e, other.value(e));
        }
        // bank_wide skips zero-valued bins; keep the full touched range so
        // cancelled-but-live bins stay inside the drain sweep.
        self.min_e = self.min_e.min(lo_e);
        self.max_e = self.max_e.max(hi_e);
    }
}

impl Default for ExpBins {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bank_accumulates_exactly_per_bin() {
        let mut b = ExpBins::new();
        b.bank(5, 7);
        b.bank(5, -3);
        b.bank(9, 1);
        assert_eq!(b.value(5), 4);
        assert_eq!(b.value(9), 1);
        assert_eq!(b.value(6), 0);
        assert_eq!(b.live_range(), Some((5, 9)));
    }

    #[test]
    fn untouched_store_reports_empty() {
        let b = ExpBins::new();
        assert!(b.is_untouched());
        assert_eq!(b.live_range(), None);
        assert_eq!(b.value(1), 0);
    }

    #[test]
    fn fast_lane_spills_without_losing_a_bit() {
        let mut b = ExpBins::new();
        // Drive the fast lane past the spill threshold via bank_wide
        // (single-term ingests would need ~2^37 calls).
        let step = (1i128 << 61) + 12345;
        for _ in 0..8 {
            b.bank_wide(3, step);
        }
        assert_eq!(b.value(3), 8 * step);
        // And negative traffic cancels exactly across the lane split.
        for _ in 0..8 {
            b.bank_wide(3, -step);
        }
        assert_eq!(b.value(3), 0);
        assert_eq!(b.live_range(), Some((3, 3)), "cancelled bins stay live");
    }

    #[test]
    fn merge_is_pointwise_and_order_independent() {
        let (mut a, mut b, mut both) = (ExpBins::new(), ExpBins::new(), ExpBins::new());
        for (e, s) in [(2, 10i64), (7, -4), (200, 1)] {
            a.bank(e, s);
            both.bank(e, s);
        }
        for (e, s) in [(2, -10i64), (3, 9), (253, -2)] {
            b.bank(e, s);
            both.bank(e, s);
        }
        let mut ab = a.clone();
        ab.merge_from(&b);
        let mut ba = b.clone();
        ba.merge_from(&a);
        for e in 1..MAX_BINS as i32 {
            assert_eq!(ab.value(e), both.value(e), "bin {e}");
            assert_eq!(ba.value(e), both.value(e), "bin {e}");
        }
        assert_eq!(ab.live_range(), Some((2, 253)));
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "outside the bin range")]
    fn out_of_range_exponent_fails_loudly() {
        ExpBins::new().bank(MAX_BINS as i32, 1);
    }
}
