//! Parameterized datapath component cost models (unit-gate units).
//!
//! Every function returns a [`Comp`] — area in GE and propagation delay in
//! τ — for one *schedulable* component. Big structures (barrel shifters,
//! CSA trees, max trees, prefix adders) are decomposed by the netlist
//! builders into per-stage components so the pipeline scheduler can place
//! register cuts inside them, which is exactly the freedom HLS has.

#![deny(clippy::cast_precision_loss)]

use super::gates::*;

/// Area/delay of one component instance.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Comp {
    pub area: f64,
    pub delay: f64,
}

impl Comp {
    pub const fn new(area: f64, delay: f64) -> Self {
        Comp { area, delay }
    }
}

/// w-bit 2:1 multiplexer row.
pub fn mux2(w: u32) -> Comp {
    Comp::new(A_MUX2 * w as f64, D_MUX2)
}

/// w-bit XOR row (conditional inversion for sign handling).
pub fn xor_row(w: u32) -> Comp {
    Comp::new(A_XOR2 * w as f64, D_XOR2)
}

/// w-bit magnitude comparator (parallel-prefix style): delay grows with
/// log(w), area linear with a prefix-merge overhead.
pub fn comparator(w: u32) -> Comp {
    let levels = clog2(w.max(2)) as f64;
    Comp::new(4.5 * w as f64 + 1.5 * w as f64 * levels / 2.0, D_XOR2 + levels * D_AND2)
}

/// w-bit maximum unit: comparator + select mux (one `max` node of the
/// exponent tree in Fig. 1 / eq. 8).
pub fn max2(w: u32) -> Comp {
    let c = comparator(w);
    let m = mux2(w);
    Comp::new(c.area + m.area, c.delay + m.delay)
}

/// w-bit subtractor (`λ − e`, always ≥ 0 by construction): a parallel-prefix
/// adder with inverted operand.
pub fn subtractor(w: u32) -> Comp {
    let a = prefix_adder(w);
    Comp::new(a.area + A_INV * w as f64, a.delay + D_INV)
}

/// w-bit parallel-prefix (Sklansky-ish) adder: pre/post-processing linear,
/// prefix network w/2 cells per level.
pub fn prefix_adder(w: u32) -> Comp {
    let levels = clog2(w.max(2)) as f64;
    let pre = 2.0 * w as f64; // p/g generation
    let prefix = 0.75 * w as f64 * levels / 2.0; // sparse (Brent-Kung-ish) tree
    let post = A_XOR2 * w as f64; // sum XOR
    Comp::new(pre + prefix + post, D_XOR2 + levels * D_AND2 + D_XOR2)
}

/// One stage of a logarithmic barrel shifter on a w-bit bus: a mux row plus
/// the sticky-OR gates collecting the bits shifted out at this stage.
pub fn shift_stage(w: u32, sticky: bool) -> Comp {
    let base = mux2(w);
    if sticky {
        // Sticky rails are modeled numerically but the paper's HLS C++
        // uses plain `>>` (truncation without sticky), so the hardware
        // model prices the bare mux row. Kept as a parameter so sticky-
        // collecting designs can be costed in ablations.
        base
    } else {
        base
    }
}

/// Number of mux stages a right-shifter needs: shift distances up to
/// `max_shift`, but anything ≥ datapath width saturates to the sticky/fill
/// path, so stages are bounded by the bus width too.
pub fn shifter_stages(max_shift: u32, w: u32) -> u32 {
    let s = max_shift.min(w);
    if s == 0 {
        return 0;
    }
    clog2(s + 1)
}

/// w-bit 3:2 carry-save compressor row (one CSA level for one operand trio).
pub fn csa_row(w: u32) -> Comp {
    Comp::new(A_FA * w as f64, D_FA_SUM)
}

/// Number of 3:2 compressor levels to reduce `n` operands to 2 (Wallace).
pub fn csa_levels(n: u32) -> u32 {
    let mut rows = n;
    let mut levels = 0;
    while rows > 2 {
        rows = rows - (rows / 3); // each full trio becomes 2
        levels += 1;
    }
    levels
}

/// w-bit leading-zero counter.
pub fn lzc(w: u32) -> Comp {
    let levels = clog2(w.max(2)) as f64;
    Comp::new(3.0 * w as f64, levels * (D_NAND2 + D_MUX2) * 0.75)
}

/// w-bit incrementer (rounding +1 on the mantissa): half-adder chain with
/// fast carry (treated as prefix).
pub fn incrementer(w: u32) -> Comp {
    let levels = clog2(w.max(2)) as f64;
    Comp::new(A_HA * w as f64, levels * D_AND2 + D_XOR2)
}

/// Unpack stage per input term: field extraction, hidden-bit insertion and
/// two's-complement conditional inversion of the significand.
pub fn unpack(sig_w: u32) -> Comp {
    Comp::new(A_XOR2 * sig_w as f64 + 2.0, D_XOR2 + D_AND2)
}

/// Final pack stage: sign/exponent/mantissa field assembly with special
/// handling (overflow/underflow muxes).
pub fn pack(width: u32) -> Comp {
    Comp::new(A_MUX2 * width as f64 * 2.0, 2.0 * D_MUX2)
}

/// Pipeline register of `bits` (area only; timing handled as stage budget).
pub fn register_area(bits: u32) -> f64 {
    A_DFF * bits as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn costs_scale_with_width() {
        assert!(max2(8).area > max2(4).area);
        assert!(prefix_adder(32).delay > prefix_adder(8).delay);
        assert!(subtractor(8).area > prefix_adder(8).area);
    }

    #[test]
    fn csa_levels_match_wallace() {
        assert_eq!(csa_levels(2), 0);
        assert_eq!(csa_levels(3), 1);
        assert_eq!(csa_levels(4), 2);
        assert_eq!(csa_levels(8), 4); // 8→6→4→3→2
        assert_eq!(csa_levels(32), 8);
    }

    #[test]
    fn shifter_stage_count_saturates_at_width() {
        // BF16 exponent range 253, but a 21-bit bus only needs 5 stages
        // (shifts ≥ 21 all collapse to the sticky path, handled by compare).
        assert_eq!(shifter_stages(253, 21), 5);
        assert_eq!(shifter_stages(7, 64), 3);
        assert_eq!(shifter_stages(0, 8), 0);
    }

    #[test]
    fn register_area_is_linear() {
        assert_eq!(register_area(10), 45.0);
    }
}

/// The compact (slower, smaller) implementation variant of an adder-like
/// component — ripple/carry-skip instead of parallel-prefix. HLS selects it
/// when the schedule leaves slack (Catapult's implementation selection);
/// the pipeline scheduler applies the same downgrade under slack.
pub fn compact_variant(fast: Comp) -> Comp {
    Comp::new(fast.area * 0.45, fast.delay * 2.2)
}
