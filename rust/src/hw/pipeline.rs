//! Pipeline-stage scheduling: assign every component of a netlist to one of
//! `k` stages under a clock budget, minimizing pipeline-register bits — the
//! HLS freedom the paper credits for the proposed designs' efficiency
//! ("allows HLS to schedule intermediate alignment and addition steps to
//! pipeline stages with better flexibility", §IV-A).
//!
//! Model (retiming-style): a stage assignment `s(v)` must be monotone along
//! edges, and within every stage the longest combinational path must fit
//! the stage budget (clock period minus register overhead). An edge
//! spanning `g` stages pays `g · bits` register bits.
//!
//! **Scheduling regions.** HLS schedules the symmetric lanes of one
//! unrolled expression identically — it cannot stagger lane 7 of a 32-wide
//! alignment array into a different stage than lane 3. Nodes sharing a
//! [`region`](crate::hw::netlist::Node::region) therefore collapse into one
//! super-node before scheduling. This is where the paper's modularity
//! argument becomes concrete: a monolithic radix-N operator yields a few
//! very wide regions (whole 32-lane shifter stages move together, dragging
//! hundreds of register bits to whatever boundary they land on), while a
//! tree of small `⊙` operators yields many narrow regions the scheduler
//! can place independently.
//!
//! After stage assignment an implementation-selection pass (Catapult-style)
//! downgrades adder-like regions with slack to compact (smaller, slower)
//! variants; feasibility is re-validated exactly after every move.

#![deny(clippy::cast_precision_loss)]

use super::components::register_area;
use super::datapath::AdderNetlist;
use super::gates::{self, clog2 as _clog2};
use super::netlist::Netlist;
use std::collections::HashMap;

/// Result of pipelining a netlist.
#[derive(Clone, Debug)]
pub struct PipelineResult {
    pub stages: u32,
    /// Total pipeline register bits over all stage boundaries.
    pub reg_bits: u64,
    /// Register area in GE.
    pub reg_area: f64,
    /// Combinational area after implementation selection, in GE.
    pub comb_area: f64,
    /// Combinational + register area in GE.
    pub total_area: f64,
    /// Critical combinational delay in τ (whole netlist, unpipelined).
    pub comb_delay: f64,
    /// Stage of every *node* (expanded from the region assignment).
    pub assignment: Vec<u32>,
}

/// Region-collapsed scheduling graph.
struct Regions {
    /// Topological order of region ids.
    order: Vec<usize>,
    preds: Vec<Vec<(usize, u32)>>,
    succs: Vec<Vec<(usize, u32)>>,
    delay: Vec<f64>,
    area: Vec<f64>,
    /// Compact variant (delay, area) when every member offers one.
    alt: Vec<Option<(f64, f64)>>,
    /// Region id of every node.
    node_region: Vec<usize>,
}

fn build_regions(nl: &Netlist) -> Regions {
    let n = nl.nodes.len();
    let mut ids: HashMap<&str, usize> = HashMap::new();
    let mut node_region = vec![usize::MAX; n];
    let mut delay = Vec::new();
    let mut area = Vec::new();
    let mut alt: Vec<Option<(f64, f64)>> = Vec::new();
    for (i, node) in nl.nodes.iter().enumerate() {
        let rid = if node.region.is_empty() {
            delay.push(node.delay);
            area.push(node.area);
            alt.push(node.alt.map(|a| (a.delay, a.area)));
            delay.len() - 1
        } else {
            match ids.get(node.region.as_str()) {
                Some(&r) => {
                    delay[r] = f64::max(delay[r], node.delay);
                    area[r] += node.area;
                    alt[r] = match (alt[r], node.alt) {
                        (Some((d, a)), Some(na)) => Some((d.max(na.delay), a + na.area)),
                        _ => None,
                    };
                    r
                }
                None => {
                    delay.push(node.delay);
                    area.push(node.area);
                    alt.push(node.alt.map(|a| (a.delay, a.area)));
                    let r = delay.len() - 1;
                    ids.insert(node.region.as_str(), r);
                    r
                }
            }
        };
        node_region[i] = rid;
    }
    let m = delay.len();
    let mut preds: Vec<Vec<(usize, u32)>> = vec![Vec::new(); m];
    let mut succs: Vec<Vec<(usize, u32)>> = vec![Vec::new(); m];
    let mut indeg = vec![0usize; m];
    for e in &nl.edges {
        let (ru, rv) = (node_region[e.from], node_region[e.to]);
        debug_assert_ne!(ru, rv, "edge inside a scheduling region");
        preds[rv].push((ru, e.bits));
        succs[ru].push((rv, e.bits));
        indeg[rv] += 1;
    }
    let mut queue: Vec<usize> = (0..m).filter(|&i| indeg[i] == 0).collect();
    let mut order = Vec::with_capacity(m);
    while let Some(u) = queue.pop() {
        order.push(u);
        for &(v, _) in &succs[u] {
            indeg[v] -= 1;
            if indeg[v] == 0 {
                queue.push(v);
            }
        }
    }
    assert_eq!(order.len(), m, "region graph contains a cycle");
    Regions { order, preds, succs, delay, area, alt, node_region }
}

/// Greedy minimal-stage (ASAP) packing on the region graph.
fn asap_stages(g: &Regions, budget: f64) -> Option<(Vec<u32>, u32)> {
    let m = g.delay.len();
    let mut stage = vec![0u32; m];
    let mut arrive = vec![0f64; m];
    let mut k_used = 1u32;
    for &v in &g.order {
        let d = g.delay[v];
        if d > budget {
            return None;
        }
        let mut s = 0u32;
        for &(u, _) in &g.preds[v] {
            s = s.max(stage[u]);
        }
        let mut a = 0f64;
        for &(u, _) in &g.preds[v] {
            if stage[u] == s {
                a = a.max(arrive[u] + g.delay[u]);
            }
        }
        if a + d > budget {
            s += 1;
            a = 0.0;
        }
        stage[v] = s;
        arrive[v] = a;
        k_used = k_used.max(s + 1);
    }
    Some((stage, k_used))
}

/// ALAP stages for a fixed depth `k` (ASAP on the reverse graph).
fn alap_stages(g: &Regions, budget: f64, k: u32) -> Option<Vec<u32>> {
    let m = g.delay.len();
    let mut rstage = vec![0u32; m];
    let mut rarrive = vec![0f64; m];
    for &v in g.order.iter().rev() {
        let d = g.delay[v];
        if d > budget {
            return None;
        }
        let mut s = 0u32;
        for &(u, _) in &g.succs[v] {
            s = s.max(rstage[u]);
        }
        let mut a = 0f64;
        for &(u, _) in &g.succs[v] {
            if rstage[u] == s {
                a = a.max(rarrive[u] + g.delay[u]);
            }
        }
        if a + d > budget {
            s += 1;
            a = 0.0;
        }
        rstage[v] = s;
        rarrive[v] = a;
        if s >= k {
            return None;
        }
    }
    Some(rstage.iter().map(|&rs| k - 1 - rs).collect())
}

/// Exact feasibility of a stage assignment with the given region delays.
fn validate(g: &Regions, stage: &[u32], delays: &[f64], budget: f64) -> bool {
    let mut arrive = vec![0f64; g.delay.len()];
    for &v in &g.order {
        let mut a = 0f64;
        for &(u, _) in &g.preds[v] {
            if stage[u] > stage[v] {
                return false;
            }
            if stage[u] == stage[v] {
                a = a.max(arrive[u] + delays[u]);
            }
        }
        if a + delays[v] > budget + 1e-9 {
            return false;
        }
        arrive[v] = a;
    }
    true
}

fn reg_bits(g: &Regions, stage: &[u32]) -> u64 {
    let mut bits = 0u64;
    for (v, preds) in g.preds.iter().enumerate() {
        for &(u, b) in preds {
            bits += (stage[v] - stage[u]) as u64 * b as u64;
        }
    }
    bits
}

/// Pipeline `adder` into exactly `stages` stages at clock `clock_ns`.
/// Returns `None` when infeasible.
pub fn pipeline(adder: &AdderNetlist, stages: u32, clock_ns: f64) -> Option<PipelineResult> {
    let nl = &adder.nl;
    let budget = gates::ns_to_stage_budget(clock_ns);
    if budget <= 0.0 {
        return None;
    }
    let g = build_regions(nl);
    let (asap, k_min) = asap_stages(&g, budget)?;
    if k_min > stages {
        return None;
    }
    let comb_delay = nl.critical_path();
    let m = g.delay.len();
    let mut stage = if stages == 1 { vec![0u32; m] } else { asap.clone() };
    let alap =
        if stages == 1 { vec![0u32; m] } else { alap_stages(&g, budget, stages)? };

    // Initial assignment: cost-aware greedy in topo order. Sink regions are
    // pinned to the last stage: a k-stage design registers its output at
    // stage k-1 (anything else would be a shallower pipeline in disguise).
    if stages > 1 {
        for &v in &g.order {
            if g.succs[v].is_empty() {
                stage[v] = stages - 1;
                continue;
            }
            let lo = g.preds[v].iter().map(|&(u, _)| stage[u]).max().unwrap_or(0).max(asap[v]);
            let hi = alap[v];
            if lo >= hi {
                stage[v] = lo.min(hi);
                continue;
            }
            let mut best = lo;
            let mut best_cost = u64::MAX;
            for s in lo..=hi {
                let cost: u64 = g.preds[v]
                    .iter()
                    .map(|&(u, b)| (s - stage[u]) as u64 * b as u64)
                    .sum();
                if cost < best_cost {
                    best_cost = cost;
                    best = s;
                }
            }
            stage[v] = best;
        }
        if !validate(&g, &stage, &g.delay, budget) {
            stage = asap.clone();
        }

        // Coordinate-descent refinement over single-region moves.
        for _ in 0..3 {
            let mut improved = false;
            for &v in &g.order {
                if g.succs[v].is_empty() {
                    continue; // sinks stay pinned to the last stage
                }
                let lo = g.preds[v].iter().map(|&(u, _)| stage[u]).max().unwrap_or(0);
                let hi = g.succs[v]
                    .iter()
                    .map(|&(u, _)| stage[u])
                    .min()
                    .unwrap_or(stages - 1)
                    .min(alap[v]);
                if lo >= hi {
                    continue;
                }
                let here = stage[v];
                let incident = |s: u32| -> u64 {
                    let inn: u64 = g.preds[v]
                        .iter()
                        .map(|&(u, b)| (s - stage[u]) as u64 * b as u64)
                        .sum();
                    let out: u64 = g.succs[v]
                        .iter()
                        .map(|&(u, b)| (stage[u] - s) as u64 * b as u64)
                        .sum();
                    inn + out
                };
                let base_cost = incident(here);
                let (mut best_s, mut best_cost) = (here, base_cost);
                for s in lo..=hi {
                    if s != here && incident(s) < best_cost {
                        best_cost = incident(s);
                        best_s = s;
                    }
                }
                if best_s != here {
                    let old = stage[v];
                    stage[v] = best_s;
                    if validate(&g, &stage, &g.delay, budget) {
                        improved = true;
                    } else {
                        stage[v] = old;
                    }
                }
            }
            if !improved {
                break;
            }
        }
    }

    // Implementation selection under slack: downgrade regions with compact
    // variants (largest saving first) while the schedule still validates.
    let mut delays = g.delay.clone();
    let mut areas = g.area.clone();
    let mut candidates: Vec<usize> = (0..m).filter(|&v| g.alt[v].is_some()).collect();
    candidates.sort_by(|&a, &b| {
        let sa = g.area[a] - g.alt[a].unwrap().1;
        let sb = g.area[b] - g.alt[b].unwrap().1;
        sb.partial_cmp(&sa).unwrap()
    });
    for v in candidates {
        let (alt_d, alt_a) = g.alt[v].unwrap();
        let old = delays[v];
        delays[v] = alt_d;
        if validate(&g, &stage, &delays, budget) {
            areas[v] = alt_a;
        } else {
            delays[v] = old;
        }
    }

    let bits = reg_bits(&g, &stage);
    let reg_area = register_area(bits.min(u32::MAX as u64) as u32);
    let comb_area: f64 = areas.iter().sum();
    let assignment = g.node_region.iter().map(|&r| stage[r]).collect();
    Some(PipelineResult {
        stages,
        reg_bits: bits,
        reg_area,
        comb_area,
        total_area: comb_area + reg_area,
        comb_delay,
        assignment,
    })
}

/// Minimum feasible clock period (ns) for `stages` stages (binary search on
/// the ASAP region packing).
pub fn min_clock_ns(adder: &AdderNetlist, stages: u32) -> f64 {
    let nl = &adder.nl;
    let g = build_regions(nl);
    let feasible = |clock_ns: f64| -> bool {
        let budget = gates::ns_to_stage_budget(clock_ns);
        if budget <= 0.0 {
            return false;
        }
        match asap_stages(&g, budget) {
            Some((_, k)) => k <= stages,
            None => false,
        }
    };
    let total = nl.critical_path();
    let mut lo = gates::tau_to_ns(nl.max_node_delay() + gates::D_DFF) * 0.5;
    let mut hi = gates::tau_to_ns(total + gates::D_DFF) * 1.05;
    while !feasible(hi) {
        hi *= 1.5;
        if hi > 1e3 {
            return f64::INFINITY;
        }
    }
    for _ in 0..40 {
        let mid = 0.5 * (lo + hi);
        if feasible(mid) {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    hi
}

/// The paper's pipeline-depth policy (§IV): `log2(N)` stages for FP32,
/// one fewer for the 16-bit and 8-bit formats.
pub fn paper_stages(fmt: crate::formats::FpFormat, n_terms: u32) -> u32 {
    let log_n = _clog2(n_terms);
    if fmt.mbits > 10 {
        log_n
    } else {
        (log_n - 1).max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::tree::RadixConfig;
    use crate::arith::AccSpec;
    use crate::formats::{BF16, FP32};
    use crate::hw::datapath::{build_adder, DatapathParams};

    fn adder(cfg: &str) -> AdderNetlist {
        let c: RadixConfig = cfg.parse().unwrap();
        let p = DatapathParams::new(BF16, c.terms(), AccSpec::hw_default(BF16, c.terms() as usize));
        build_adder(p, &c)
    }

    #[test]
    fn single_stage_needs_full_path_budget() {
        let a = adder("8-2-2");
        let d_ns = a.nl.critical_path() * gates::NS_PER_TAU;
        let overhead = gates::D_DFF * gates::NS_PER_TAU;
        assert!(pipeline(&a, 1, d_ns * 0.8 + overhead).is_none());
        assert!(pipeline(&a, 1, d_ns * 1.2 + overhead).is_some());
    }

    #[test]
    fn more_stages_enable_faster_clocks() {
        let a = adder("8-2-2");
        let c1 = min_clock_ns(&a, 1);
        let c2 = min_clock_ns(&a, 2);
        let c4 = min_clock_ns(&a, 4);
        assert!(c2 < c1, "2 stages {c2} vs 1 stage {c1}");
        assert!(c4 < c2, "4 stages {c4} vs 2 stages {c2}");
    }

    #[test]
    fn register_bits_are_positive_and_grow_with_stages() {
        let a = adder("8-2-2");
        let t = min_clock_ns(&a, 2) * 1.05;
        let p2 = pipeline(&a, 2, t).unwrap();
        let p4 = pipeline(&a, 4, t).unwrap();
        assert!(p2.reg_bits > 0);
        assert!(p4.reg_bits > p2.reg_bits);
        assert!(p4.total_area > p4.comb_area);
    }

    #[test]
    fn assignments_are_monotone_and_within_range() {
        let a = adder("4-4-2");
        let t = min_clock_ns(&a, 3) * 1.02;
        let p = pipeline(&a, 3, t).unwrap();
        for e in &a.nl.edges {
            assert!(p.assignment[e.from] <= p.assignment[e.to]);
        }
        assert!(p.assignment.iter().all(|&s| s < 3));
    }

    #[test]
    fn lanes_of_one_region_share_a_stage() {
        let a = adder("32");
        let t = min_clock_ns(&a, 4) * 1.02;
        let p = pipeline(&a, 4, t).unwrap();
        // All 32 lanes of the baseline's first shifter stage share a region
        // and therefore a stage.
        let stages: Vec<u32> = a
            .nl
            .nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| n.kind.starts_with("opr.") && n.kind.contains("shift.") && n.kind.ends_with(".s0"))
            .map(|(i, _)| p.assignment[i])
            .collect();
        assert!(stages.len() >= 32);
        assert!(stages.windows(2).all(|w| w[0] == w[1]), "{stages:?}");
    }

    #[test]
    fn tree_cuts_are_cheaper_than_baseline_cuts() {
        // The modularity claim: at a tight shared clock the tree pays fewer
        // register bits than the radix-N baseline.
        let tree = adder("8-2-2");
        let base = adder("32");
        let stages = 4;
        let t = min_clock_ns(&base, stages).max(min_clock_ns(&tree, stages)) * 1.02;
        let pt = pipeline(&tree, stages, t).unwrap();
        let pb = pipeline(&base, stages, t).unwrap();
        assert!(
            pt.reg_bits < pb.reg_bits,
            "tree {} bits vs baseline {} bits",
            pt.reg_bits,
            pb.reg_bits
        );
    }

    #[test]
    fn implementation_selection_reduces_area_under_slack() {
        let a = adder("4-4-2");
        let tight = min_clock_ns(&a, 3) * 1.01;
        let relaxed = tight * 2.0;
        let p_tight = pipeline(&a, 3, tight).unwrap();
        let p_relax = pipeline(&a, 3, relaxed).unwrap();
        assert!(
            p_relax.comb_area < p_tight.comb_area,
            "relaxed {} vs tight {}",
            p_relax.comb_area,
            p_tight.comb_area
        );
    }

    #[test]
    fn paper_stage_policy() {
        assert_eq!(paper_stages(FP32, 32), 5);
        assert_eq!(paper_stages(BF16, 32), 4);
        assert_eq!(paper_stages(BF16, 16), 3);
    }
}
