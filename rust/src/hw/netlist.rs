//! Structural netlist: a DAG of schedulable components connected by buses.
//!
//! The netlist is the single source of truth for area (sum of node areas),
//! combinational delay (longest path) and pipelining (register bits on
//! edges crossing stage cuts — see [`super::pipeline`]).

#![deny(clippy::cast_precision_loss)]

use super::components::Comp;
use std::fmt;

/// Node index.
pub type NodeId = usize;

/// Why an edge was rejected at construction. Malformed edges used to slip
/// through release builds silently (only a `debug_assert` guarded them) and
/// would then corrupt every downstream area/delay/power figure; endpoints
/// are now validated eagerly so the netlist lint pass is a second line of
/// defense, never the first.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum EdgeError {
    /// An endpoint names a node that does not exist (yet).
    OutOfRange { from: NodeId, to: NodeId, nodes: usize },
    /// `from == to`: a combinational self-loop can never be scheduled.
    SelfLoop { node: NodeId },
    /// A zero-width bus carries no value and breaks register accounting.
    ZeroWidth { from: NodeId, to: NodeId },
}

impl fmt::Display for EdgeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            EdgeError::OutOfRange { from, to, nodes } => {
                write!(f, "edge {from}->{to} references a node outside 0..{nodes}")
            }
            EdgeError::SelfLoop { node } => write!(f, "self-loop on node {node}"),
            EdgeError::ZeroWidth { from, to } => write!(f, "zero-width bus {from}->{to}"),
        }
    }
}

/// One schedulable component instance.
#[derive(Clone, Debug)]
pub struct Node {
    /// Human-readable kind, e.g. `"max2.L1"`, `"shift.s3"`, `"csa.row2"`.
    pub kind: String,
    pub area: f64,
    pub delay: f64,
    /// ASAP start time (filled by [`Netlist::schedule_asap`]).
    pub start: f64,
    /// Optional compact (slower, smaller) implementation the scheduler may
    /// select when the node has slack — HLS implementation selection.
    pub alt: Option<Comp>,
    /// Scheduling region: nodes sharing a region are symmetric lanes of one
    /// unrolled HLS expression and must be assigned to the same pipeline
    /// stage (empty string = the node is its own region).
    pub region: String,
}

/// A directed bus between two components.
#[derive(Clone, Copy, Debug)]
pub struct Edge {
    pub from: NodeId,
    pub to: NodeId,
    /// Bus width in bits (register cost if a pipeline cut lands here).
    pub bits: u32,
}

/// The datapath graph.
#[derive(Clone, Debug, Default)]
pub struct Netlist {
    pub nodes: Vec<Node>,
    pub edges: Vec<Edge>,
    scheduled: bool,
}

impl Netlist {
    pub fn new() -> Self {
        Netlist::default()
    }

    /// Add a component; returns its id.
    pub fn add(&mut self, kind: impl Into<String>, comp: Comp) -> NodeId {
        self.scheduled = false;
        self.nodes.push(Node {
            kind: kind.into(),
            area: comp.area,
            delay: comp.delay,
            start: 0.0,
            alt: None,
            region: String::new(),
        });
        self.nodes.len() - 1
    }

    /// Assign the scheduling region of the most recently added node.
    /// Regions redefine the pipeline super-node graph, so any previously
    /// computed schedule is invalidated like every other mutation.
    pub fn set_region(&mut self, id: NodeId, region: impl Into<String>) {
        self.scheduled = false;
        self.nodes[id].region = region.into();
    }

    /// Add a component that also has a compact (slower, smaller) variant.
    pub fn add_with_alt(&mut self, kind: impl Into<String>, fast: Comp, compact: Comp) -> NodeId {
        let id = self.add(kind, fast);
        debug_assert!(compact.area <= fast.area && compact.delay >= fast.delay);
        self.scheduled = false;
        self.nodes[id].alt = Some(compact);
        id
    }

    /// Add a zero-area/zero-delay source node (primary input).
    pub fn input(&mut self, kind: impl Into<String>) -> NodeId {
        self.add(kind, Comp::new(0.0, 0.0))
    }

    /// Connect `from → to` with a `bits`-wide bus, validating the edge at
    /// construction (release builds included).
    pub fn add_edge(&mut self, from: NodeId, to: NodeId, bits: u32) -> Result<(), EdgeError> {
        let nodes = self.nodes.len();
        if from >= nodes || to >= nodes {
            return Err(EdgeError::OutOfRange { from, to, nodes });
        }
        if from == to {
            return Err(EdgeError::SelfLoop { node: from });
        }
        if bits == 0 {
            return Err(EdgeError::ZeroWidth { from, to });
        }
        self.scheduled = false;
        self.edges.push(Edge { from, to, bits });
        Ok(())
    }

    /// Infallible [`Self::add_edge`] for the netlist builders, which only
    /// ever wire nodes they just created: a malformed edge there is a
    /// construction bug and panics immediately instead of corrupting the
    /// graph.
    pub fn connect(&mut self, from: NodeId, to: NodeId, bits: u32) {
        if let Err(e) = self.add_edge(from, to, bits) {
            panic!("invalid netlist edge: {e}");
        }
    }

    /// Whether a schedule computed by [`Self::schedule_asap`] is still
    /// valid (no mutation since).
    pub fn is_scheduled(&self) -> bool {
        self.scheduled
    }

    /// Total combinational area in GE.
    pub fn area(&self) -> f64 {
        self.nodes.iter().map(|n| n.area).sum()
    }

    /// ASAP schedule: every node starts when its slowest predecessor
    /// finishes. Returns the critical-path delay in τ.
    pub fn schedule_asap(&mut self) -> f64 {
        // Topological order via Kahn (the builders only create forward
        // edges, but don't rely on it).
        let n = self.nodes.len();
        let mut indeg = vec![0usize; n];
        let mut succ: Vec<Vec<usize>> = vec![Vec::new(); n];
        for (ei, e) in self.edges.iter().enumerate() {
            indeg[e.to] += 1;
            succ[e.from].push(ei);
        }
        for node in &mut self.nodes {
            node.start = 0.0;
        }
        let mut queue: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut seen = 0usize;
        while let Some(u) = queue.pop() {
            seen += 1;
            let finish = self.nodes[u].start + self.nodes[u].delay;
            for &ei in &succ[u] {
                let v = self.edges[ei].to;
                if finish > self.nodes[v].start {
                    self.nodes[v].start = finish;
                }
                indeg[v] -= 1;
                if indeg[v] == 0 {
                    queue.push(v);
                }
            }
        }
        assert_eq!(seen, n, "netlist contains a cycle");
        self.scheduled = true;
        self.critical_path()
    }

    /// Longest finish time over all nodes (requires a prior schedule).
    pub fn critical_path(&self) -> f64 {
        debug_assert!(
            self.scheduled || self.nodes.is_empty(),
            "stale schedule read: the netlist was mutated after schedule_asap"
        );
        self.nodes.iter().map(|n| n.start + n.delay).fold(0.0, f64::max)
    }

    /// Largest single-component delay (lower bound on any stage budget).
    pub fn max_node_delay(&self) -> f64 {
        self.nodes.iter().map(|n| n.delay).fold(0.0, f64::max)
    }

    /// Sum of node areas whose kind starts with `prefix` (diagnostics).
    pub fn area_of(&self, prefix: &str) -> f64 {
        self.nodes.iter().filter(|n| n.kind.starts_with(prefix)).map(|n| n.area).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn asap_longest_path() {
        let mut nl = Netlist::new();
        let a = nl.input("in.a");
        let b = nl.input("in.b");
        let m = nl.add("mul", Comp::new(10.0, 5.0));
        let s = nl.add("add", Comp::new(4.0, 2.0));
        nl.connect(a, m, 8);
        nl.connect(b, m, 8);
        nl.connect(m, s, 16);
        nl.connect(b, s, 16);
        let d = nl.schedule_asap();
        assert_eq!(d, 7.0);
        assert_eq!(nl.nodes[s].start, 5.0);
        assert_eq!(nl.area(), 14.0);
    }

    #[test]
    fn area_of_prefix() {
        let mut nl = Netlist::new();
        nl.add("shift.s0", Comp::new(5.0, 1.0));
        nl.add("shift.s1", Comp::new(5.0, 1.0));
        nl.add("csa.row0", Comp::new(7.0, 1.0));
        assert_eq!(nl.area_of("shift"), 10.0);
        assert_eq!(nl.area_of("csa"), 7.0);
    }

    #[test]
    #[should_panic(expected = "cycle")]
    fn cycle_detected() {
        let mut nl = Netlist::new();
        let a = nl.add("a", Comp::new(1.0, 1.0));
        let b = nl.add("b", Comp::new(1.0, 1.0));
        nl.connect(a, b, 1);
        nl.connect(b, a, 1);
        nl.schedule_asap();
    }

    #[test]
    fn add_edge_rejects_malformed_edges_with_typed_errors() {
        let mut nl = Netlist::new();
        let a = nl.add("a", Comp::new(1.0, 1.0));
        let b = nl.add("b", Comp::new(1.0, 1.0));
        // Out-of-range endpoints — both directions.
        assert_eq!(
            nl.add_edge(a, 7, 4),
            Err(EdgeError::OutOfRange { from: a, to: 7, nodes: 2 })
        );
        assert_eq!(
            nl.add_edge(9, b, 4),
            Err(EdgeError::OutOfRange { from: 9, to: b, nodes: 2 })
        );
        // Self-loop and zero-width bus.
        assert_eq!(nl.add_edge(a, a, 4), Err(EdgeError::SelfLoop { node: a }));
        assert_eq!(nl.add_edge(a, b, 0), Err(EdgeError::ZeroWidth { from: a, to: b }));
        // None of the rejected edges landed in the graph.
        assert!(nl.edges.is_empty());
        assert!(nl.add_edge(a, b, 4).is_ok());
        assert_eq!(nl.edges.len(), 1);
        // The errors render actionable messages.
        let msg = EdgeError::OutOfRange { from: 0, to: 7, nodes: 2 }.to_string();
        assert!(msg.contains("0..2"), "{msg}");
    }

    #[test]
    #[should_panic(expected = "invalid netlist edge")]
    fn connect_panics_on_malformed_edge_in_release_too() {
        let mut nl = Netlist::new();
        let a = nl.add("a", Comp::new(1.0, 1.0));
        nl.connect(a, 42, 8);
    }

    #[test]
    fn every_mutator_invalidates_the_schedule() {
        let mut nl = Netlist::new();
        let a = nl.input("in.a");
        let b = nl.add("b", Comp::new(1.0, 1.0));
        nl.connect(a, b, 4);
        nl.schedule_asap();
        assert!(nl.is_scheduled());

        // add
        let c = nl.add("c", Comp::new(1.0, 1.0));
        assert!(!nl.is_scheduled(), "add left a stale schedule readable");
        nl.schedule_asap();

        // add_edge
        nl.add_edge(b, c, 4).unwrap();
        assert!(!nl.is_scheduled(), "add_edge left a stale schedule readable");
        nl.schedule_asap();

        // alt-selection metadata
        let d = nl.add_with_alt("d", Comp::new(2.0, 1.0), Comp::new(1.0, 2.0));
        assert!(!nl.is_scheduled(), "add_with_alt left a stale schedule readable");
        nl.connect(c, d, 4);
        nl.schedule_asap();

        // region reassignment redefines the pipeline super-node graph
        nl.set_region(d, "lane");
        assert!(!nl.is_scheduled(), "set_region left a stale schedule readable");
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "stale schedule")]
    fn stale_schedule_cannot_be_read_after_mutation() {
        let mut nl = Netlist::new();
        let a = nl.input("in.a");
        let b = nl.add("b", Comp::new(1.0, 1.0));
        nl.connect(a, b, 4);
        nl.schedule_asap();
        nl.add("late", Comp::new(1.0, 1.0)); // mutation invalidates
        nl.critical_path(); // reading the stale schedule must trip
    }
}
