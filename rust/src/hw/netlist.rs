//! Structural netlist: a DAG of schedulable components connected by buses.
//!
//! The netlist is the single source of truth for area (sum of node areas),
//! combinational delay (longest path) and pipelining (register bits on
//! edges crossing stage cuts — see [`super::pipeline`]).

use super::components::Comp;

/// Node index.
pub type NodeId = usize;

/// One schedulable component instance.
#[derive(Clone, Debug)]
pub struct Node {
    /// Human-readable kind, e.g. `"max2.L1"`, `"shift.s3"`, `"csa.row2"`.
    pub kind: String,
    pub area: f64,
    pub delay: f64,
    /// ASAP start time (filled by [`Netlist::schedule_asap`]).
    pub start: f64,
    /// Optional compact (slower, smaller) implementation the scheduler may
    /// select when the node has slack — HLS implementation selection.
    pub alt: Option<Comp>,
    /// Scheduling region: nodes sharing a region are symmetric lanes of one
    /// unrolled HLS expression and must be assigned to the same pipeline
    /// stage (empty string = the node is its own region).
    pub region: String,
}

/// A directed bus between two components.
#[derive(Clone, Copy, Debug)]
pub struct Edge {
    pub from: NodeId,
    pub to: NodeId,
    /// Bus width in bits (register cost if a pipeline cut lands here).
    pub bits: u32,
}

/// The datapath graph.
#[derive(Clone, Debug, Default)]
pub struct Netlist {
    pub nodes: Vec<Node>,
    pub edges: Vec<Edge>,
    scheduled: bool,
}

impl Netlist {
    pub fn new() -> Self {
        Netlist::default()
    }

    /// Add a component; returns its id.
    pub fn add(&mut self, kind: impl Into<String>, comp: Comp) -> NodeId {
        self.scheduled = false;
        self.nodes.push(Node {
            kind: kind.into(),
            area: comp.area,
            delay: comp.delay,
            start: 0.0,
            alt: None,
            region: String::new(),
        });
        self.nodes.len() - 1
    }

    /// Assign the scheduling region of the most recently added node.
    pub fn set_region(&mut self, id: NodeId, region: impl Into<String>) {
        self.nodes[id].region = region.into();
    }

    /// Add a component that also has a compact (slower, smaller) variant.
    pub fn add_with_alt(&mut self, kind: impl Into<String>, fast: Comp, compact: Comp) -> NodeId {
        let id = self.add(kind, fast);
        debug_assert!(compact.area <= fast.area && compact.delay >= fast.delay);
        self.nodes[id].alt = Some(compact);
        id
    }

    /// Add a zero-area/zero-delay source node (primary input).
    pub fn input(&mut self, kind: impl Into<String>) -> NodeId {
        self.add(kind, Comp::new(0.0, 0.0))
    }

    /// Connect `from → to` with a `bits`-wide bus.
    pub fn connect(&mut self, from: NodeId, to: NodeId, bits: u32) {
        debug_assert!(from < self.nodes.len() && to < self.nodes.len());
        debug_assert!(from != to, "self-loop");
        self.scheduled = false;
        self.edges.push(Edge { from, to, bits });
    }

    /// Total combinational area in GE.
    pub fn area(&self) -> f64 {
        self.nodes.iter().map(|n| n.area).sum()
    }

    /// ASAP schedule: every node starts when its slowest predecessor
    /// finishes. Returns the critical-path delay in τ.
    pub fn schedule_asap(&mut self) -> f64 {
        // Topological order via Kahn (the builders only create forward
        // edges, but don't rely on it).
        let n = self.nodes.len();
        let mut indeg = vec![0usize; n];
        let mut succ: Vec<Vec<usize>> = vec![Vec::new(); n];
        for (ei, e) in self.edges.iter().enumerate() {
            indeg[e.to] += 1;
            succ[e.from].push(ei);
        }
        for node in &mut self.nodes {
            node.start = 0.0;
        }
        let mut queue: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut seen = 0usize;
        while let Some(u) = queue.pop() {
            seen += 1;
            let finish = self.nodes[u].start + self.nodes[u].delay;
            for &ei in &succ[u] {
                let v = self.edges[ei].to;
                if finish > self.nodes[v].start {
                    self.nodes[v].start = finish;
                }
                indeg[v] -= 1;
                if indeg[v] == 0 {
                    queue.push(v);
                }
            }
        }
        assert_eq!(seen, n, "netlist contains a cycle");
        self.scheduled = true;
        self.critical_path()
    }

    /// Longest finish time over all nodes (requires a prior schedule).
    pub fn critical_path(&self) -> f64 {
        debug_assert!(self.scheduled || self.nodes.is_empty());
        self.nodes.iter().map(|n| n.start + n.delay).fold(0.0, f64::max)
    }

    /// Largest single-component delay (lower bound on any stage budget).
    pub fn max_node_delay(&self) -> f64 {
        self.nodes.iter().map(|n| n.delay).fold(0.0, f64::max)
    }

    /// Sum of node areas whose kind starts with `prefix` (diagnostics).
    pub fn area_of(&self, prefix: &str) -> f64 {
        self.nodes.iter().filter(|n| n.kind.starts_with(prefix)).map(|n| n.area).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn asap_longest_path() {
        let mut nl = Netlist::new();
        let a = nl.input("in.a");
        let b = nl.input("in.b");
        let m = nl.add("mul", Comp::new(10.0, 5.0));
        let s = nl.add("add", Comp::new(4.0, 2.0));
        nl.connect(a, m, 8);
        nl.connect(b, m, 8);
        nl.connect(m, s, 16);
        nl.connect(b, s, 16);
        let d = nl.schedule_asap();
        assert_eq!(d, 7.0);
        assert_eq!(nl.nodes[s].start, 5.0);
        assert_eq!(nl.area(), 14.0);
    }

    #[test]
    fn area_of_prefix() {
        let mut nl = Netlist::new();
        nl.add("shift.s0", Comp::new(5.0, 1.0));
        nl.add("shift.s1", Comp::new(5.0, 1.0));
        nl.add("csa.row0", Comp::new(7.0, 1.0));
        assert_eq!(nl.area_of("shift"), 10.0);
        assert_eq!(nl.area_of("csa"), 7.0);
    }

    #[test]
    #[should_panic(expected = "cycle")]
    fn cycle_detected() {
        let mut nl = Netlist::new();
        let a = nl.add("a", Comp::new(1.0, 1.0));
        let b = nl.add("b", Comp::new(1.0, 1.0));
        nl.connect(a, b, 1);
        nl.connect(b, a, 1);
        nl.schedule_asap();
    }
}
