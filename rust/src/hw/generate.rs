//! Generator of radix-N multi-term align-and-add adder netlists,
//! parameterized by (format, radix, accumulator width) — the verified
//! front door to the `hw::datapath` builders.
//!
//! The paper's §III contrast is between the **serial-alignment baseline**
//! (one monolithic radix-N node: every input aligned against the global
//! maximum exponent in a single step) and **online fused operators** (a
//! tree of small `⊙` nodes, radix `r`, each aligning locally). This module
//! derives the corresponding [`RadixConfig`]s from a single radix knob and
//! builds the netlists under an explicit [`AccSpec`] accumulator width, so
//! the static verifier (`analysis::netlist`) and the DSE sweep (`dse`)
//! share one parameterization:
//!
//! * radix `r` over `n` terms ⇒ divide by `r` while divisible, then by 2 —
//!   `n=32, r=8` yields `8-2-2` (the paper's best Table I(b) config),
//!   `n=16, r=8` yields `8-2`, `n=32, r=4` yields `4-4-2`;
//! * radix `0` (or [`GenParams::serial`]) ⇒ the radix-N baseline.
//!
//! Every generated [`AdderNetlist`] carries the fraction-spine taps
//! ([`super::datapath::OperatorTap`]) the width-obligation bridge checks.
#![deny(clippy::cast_precision_loss)]

use super::datapath::{build_adder, AdderNetlist, DatapathParams};
use crate::arith::tree::RadixConfig;
use crate::arith::AccSpec;
use crate::formats::FpFormat;

/// The radii the verifier suite and the DSE sweep exercise per format:
/// binary tree, quad tree, and the paper's radix-8-first mixes.
pub const SUITE_RADICES: [u32; 3] = [2, 4, 8];

/// Parameters of one generated adder: format, term count, operator radix
/// (`0` = serial-alignment baseline), and the accumulator width model.
#[derive(Clone, Copy, Debug)]
pub struct GenParams {
    pub fmt: FpFormat,
    pub n_terms: u32,
    /// `⊙` operator radix; `0` selects the serial radix-N baseline.
    pub radix: u32,
    /// Accumulator width model (guard bits + storage width).
    pub spec: AccSpec,
}

impl GenParams {
    /// An online fused operator tree of radix `r` at the hardware-default
    /// accumulator width.
    pub fn online(fmt: FpFormat, n_terms: u32, radix: u32) -> Self {
        GenParams { fmt, n_terms, radix, spec: AccSpec::hw_default(fmt, n_terms as usize) }
    }

    /// The serial-alignment baseline (single radix-N node).
    pub fn serial(fmt: FpFormat, n_terms: u32) -> Self {
        GenParams { fmt, n_terms, radix: 0, spec: AccSpec::hw_default(fmt, n_terms as usize) }
    }

    /// The mixed-radix configuration this parameterization denotes.
    pub fn config(&self) -> Result<RadixConfig, String> {
        if self.radix == 0 {
            Ok(RadixConfig::baseline(self.n_terms))
        } else {
            radix_tree_config(self.n_terms, self.radix)
        }
    }

    /// Signed accumulator width of the model this netlist must respect.
    pub fn acc_width(&self) -> u32 {
        self.spec.acc_width(self.fmt, self.n_terms as usize)
    }
}

/// Derive the operator tree for radix `r` over `n` terms: divide by `r`
/// while divisible, then by 2, then (for non-2^k·r^m counts) one residual
/// level. The product of the level radii always equals `n`.
pub fn radix_tree_config(n: u32, r: u32) -> Result<RadixConfig, String> {
    if n < 2 {
        return Err(format!("need at least 2 terms, got {n}"));
    }
    if r < 2 {
        return Err(format!("operator radix must be >= 2, got {r}"));
    }
    let mut radices = Vec::new();
    let mut rem = n;
    while rem % r == 0 && rem >= r {
        radices.push(r);
        rem /= r;
    }
    while rem % 2 == 0 && rem >= 2 {
        radices.push(2);
        rem /= 2;
    }
    if rem > 1 {
        radices.push(rem);
    }
    let cfg: RadixConfig = radices
        .iter()
        .map(|x| x.to_string())
        .collect::<Vec<_>>()
        .join("-")
        .parse()?;
    debug_assert_eq!(cfg.terms(), n);
    Ok(cfg)
}

/// Build the netlist for one parameterization. The result is scheduled and
/// carries the fraction-spine taps the width bridge checks.
pub fn generate(p: &GenParams) -> Result<AdderNetlist, String> {
    let cfg = p.config()?;
    let params = DatapathParams::new(p.fmt, p.n_terms, p.spec);
    let adder = build_adder(params, &cfg);
    debug_assert_eq!(adder.taps.last().map(|t| t.terms), Some(p.n_terms));
    Ok(adder)
}

/// The per-format verification suite: the serial baseline plus one online
/// tree per [`SUITE_RADICES`] entry, in that order.
pub fn generate_suite(fmt: FpFormat, n_terms: u32) -> Vec<AdderNetlist> {
    let mut out = vec![generate(&GenParams::serial(fmt, n_terms)).expect("baseline generates")];
    for r in SUITE_RADICES {
        out.push(generate(&GenParams::online(fmt, n_terms, r)).expect("online tree generates"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::{BF16, FP32};

    #[test]
    fn radix_tree_configs_match_the_paper_structures() {
        assert_eq!(radix_tree_config(32, 8).unwrap().to_string(), "8-2-2");
        assert_eq!(radix_tree_config(16, 8).unwrap().to_string(), "8-2");
        assert_eq!(radix_tree_config(32, 4).unwrap().to_string(), "4-4-2");
        assert_eq!(radix_tree_config(16, 4).unwrap().to_string(), "4-4");
        assert_eq!(radix_tree_config(32, 2).unwrap().to_string(), "2-2-2-2-2");
        assert_eq!(radix_tree_config(64, 8).unwrap().to_string(), "8-8");
        // Residual odd factor collapses into one final level.
        assert_eq!(radix_tree_config(24, 8).unwrap().to_string(), "8-3");
        assert!(radix_tree_config(1, 2).is_err());
        assert!(radix_tree_config(8, 1).is_err());
    }

    #[test]
    fn generated_adders_carry_a_full_fraction_spine() {
        for p in [GenParams::serial(BF16, 16), GenParams::online(BF16, 16, 4)] {
            let adder = generate(&p).unwrap();
            // One tap per leaf plus one per operator output.
            let leaves = adder.taps.iter().filter(|t| t.level == 0).count();
            assert_eq!(leaves, 16);
            let root = adder.taps.last().unwrap();
            assert_eq!(root.terms, 16);
            // The root fraction bus fits the model's accumulator window.
            assert!(root.frac_w <= p.acc_width());
        }
    }

    #[test]
    fn suite_covers_serial_plus_all_radices() {
        let suite = generate_suite(FP32, 16);
        assert_eq!(suite.len(), 1 + SUITE_RADICES.len());
        assert!(suite[0].config.is_baseline());
        assert_eq!(suite[2].config.to_string(), "4-4");
        for a in &suite {
            assert!(a.nl.is_scheduled());
            assert!(a.nl.area() > 0.0);
        }
    }
}
