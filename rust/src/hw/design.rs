//! One-stop design evaluation: build the netlist, pipeline it at the
//! paper's operating point, and (optionally) run a workload trace through
//! the activity simulator — producing the `(area µm², power mW)` pairs the
//! paper's tables and figures report.

#![deny(clippy::cast_precision_loss)]

use super::datapath::{build_adder, DatapathParams};
use super::gates;
use super::pipeline::{min_clock_ns, paper_stages, pipeline, PipelineResult};
use super::power::ActivitySim;
use crate::arith::tree::RadixConfig;
use crate::arith::AccSpec;
use crate::formats::{Fp, FpFormat};

/// Evaluated design point.
#[derive(Clone, Debug)]
pub struct DesignPoint {
    pub config: RadixConfig,
    pub format: FpFormat,
    pub n_terms: u32,
    pub stages: u32,
    pub clock_ns: f64,
    /// Total area (combinational + pipeline registers) in µm².
    pub area_um2: f64,
    /// Register bits the schedule needs.
    pub reg_bits: u64,
    /// Combinational critical path in ns.
    pub comb_delay_ns: f64,
    /// Average power in mW at the evaluation clock (None until a trace ran).
    pub power_mw: Option<f64>,
    /// Whether the design met the clock at the requested depth.
    pub feasible: bool,
}

/// Evaluate one configuration at the paper's operating point (1 GHz, the
/// §IV pipeline-depth policy), without power (area/timing only).
pub fn evaluate_area(fmt: FpFormat, n: u32, config: &RadixConfig, clock_ns: f64) -> DesignPoint {
    let stages = paper_stages(fmt, n);
    evaluate_area_at(fmt, n, config, clock_ns, stages)
}

/// Evaluate at an explicit stage count. When the requested clock is
/// infeasible at that depth the design is marked infeasible and costed at
/// its minimum feasible clock instead (HLS would relax timing the same way).
pub fn evaluate_area_at(
    fmt: FpFormat,
    n: u32,
    config: &RadixConfig,
    clock_ns: f64,
    stages: u32,
) -> DesignPoint {
    let params = DatapathParams::new(fmt, n, AccSpec::hw_default(fmt, n as usize));
    let adder = build_adder(params, config);
    let (pipe, feasible, clock) = match pipeline(&adder, stages, clock_ns) {
        Some(p) => (p, true, clock_ns),
        None => {
            let t = min_clock_ns(&adder, stages) * 1.001;
            let p = pipeline(&adder, stages, t).expect("min clock must be feasible");
            (p, false, t)
        }
    };
    DesignPoint {
        config: config.clone(),
        format: fmt,
        n_terms: n,
        stages,
        clock_ns: clock,
        area_um2: gates::ge_to_um2(pipe.total_area),
        reg_bits: pipe.reg_bits,
        comb_delay_ns: gates::tau_to_ns(pipe.comb_delay),
        power_mw: None,
        feasible,
    }
}

/// Run a workload trace (vectors of `n` finite terms) through the activity
/// simulator and attach average power at `1/clock_ns` GHz.
pub fn attach_power(point: &mut DesignPoint, trace: &[Vec<Fp>]) {
    let params =
        DatapathParams::new(point.format, point.n_terms, AccSpec::hw_default(point.format, point.n_terms as usize));
    let adder = build_adder(params, &point.config);
    let pipe: Option<PipelineResult> = pipeline(&adder, point.stages, point.clock_ns);
    let mut sim = ActivitySim::new(params, &point.config);
    for vec in trace {
        sim.step(vec);
    }
    let ghz = 1.0 / point.clock_ns;
    point.power_mw = Some(sim.power_mw(ghz, pipe.as_ref()));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::BF16;
    use crate::util::prng::XorShift;

    #[test]
    fn evaluate_baseline_32term_bf16() {
        let p = evaluate_area(BF16, 32, &RadixConfig::baseline(32), 1.0);
        assert!(p.area_um2 > 1000.0, "area {}", p.area_um2);
        assert!(p.reg_bits > 0);
        assert_eq!(p.stages, 4);
    }

    #[test]
    fn power_attaches_and_is_positive() {
        let mut p = evaluate_area(BF16, 32, &"8-2-2".parse().unwrap(), 1.0);
        let mut rng = XorShift::new(0xF00D);
        let trace: Vec<Vec<Fp>> =
            (0..100).map(|_| (0..32).map(|_| rng.gen_fp_normal(BF16)).collect()).collect();
        attach_power(&mut p, &trace);
        assert!(p.power_mw.unwrap() > 0.0);
    }
}
