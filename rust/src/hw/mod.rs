//! Hardware cost models: the substitution for the paper's Catapult-HLS →
//! Oasys → PowerPro flow (see DESIGN.md §Substitutions).
//!
//! * [`gates`] — unit-gate technology constants + 28-nm calibration;
//! * [`components`] — parameterized cost models of every datapath block;
//! * [`netlist`] — the scheduled component DAG;
//! * [`datapath`] — netlist builders for baseline and mixed-radix adders;
//! * [`generate`] — (format, radix, acc-width)-parameterized generator;
//! * [`pipeline`] — register-minimal stage cutting (the HLS scheduler);
//! * [`power`] — switching-activity power from real operand traces;
//! * [`design`] — one-stop evaluation of a configuration (area/power/clock).

pub mod components;
pub mod datapath;
pub mod design;
pub mod generate;
pub mod gates;
pub mod netlist;
pub mod pipeline;
pub mod power;
