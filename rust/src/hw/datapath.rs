//! Netlist builders for complete multi-term adders: the baseline radix-N
//! architecture (Fig. 1) and mixed-radix `⊙` operator trees (Fig. 2),
//! sharing one normalization/rounding tail (paper §IV-A).
//!
//! Components are decomposed to scheduler granularity (individual shifter
//! stages, CSA levels, max-tree levels, prefix-adder instances) so the
//! pipeline scheduler can cut anywhere HLS could.
//!
//! Structural notes the models encode:
//!
//! * a radix-2 `⊙` node needs only **one** shifter: `max(λi,λj)` minus the
//!   max is zero, so the smaller-exponent operand is swapped into the
//!   single shift path (comparator + swap muxes);
//! * fraction widths grow by `clog2(r)` per tree level (carry headroom);
//! * shifter depth saturates at the datapath width — shifts beyond it
//!   collapse into the sticky bit, which is what bounds the hardware even
//!   though the exponent range is much larger;
//! * the baseline is exactly the single radix-N node of the same generator
//!   (the paper's observation in §III-C).

#![deny(clippy::cast_precision_loss)]

use super::components as comp;
use super::gates::clog2;
use super::netlist::{Netlist, NodeId};
use crate::arith::tree::RadixConfig;
use crate::arith::AccSpec;
use crate::formats::FpFormat;

/// Add a multi-level component as a chain of `levels` schedulable
/// sub-nodes (evenly split area/delay). HLS can retime through the prefix
/// levels of adders, comparators and LZCs, so the pipeline scheduler must
/// be able to cut inside them; the inter-level bus width is `bus_bits`.
fn add_chain(
    nl: &mut Netlist,
    tag: &str,
    region: &str,
    total: comp::Comp,
    levels: u32,
    bus_bits: u32,
    feeds: &[(NodeId, u32)],
) -> NodeId {
    let k = levels.max(1);
    let sub = comp::Comp::new(total.area / k as f64, total.delay / k as f64);
    let compact = comp::compact_variant(sub);
    let mut prev: Option<NodeId> = None;
    for i in 0..k {
        let id = nl.add_with_alt(format!("{tag}.p{i}"), sub, compact);
        nl.set_region(id, format!("{region}.p{i}"));
        if let Some(p) = prev {
            nl.connect(p, id, bus_bits);
        } else {
            for &(src, bits) in feeds {
                nl.connect(src, id, bits);
            }
        }
        prev = Some(id);
    }
    prev.unwrap()
}

/// A partial alignment state inside the netlist: where its exponent and
/// fraction buses come from, and the fraction width.
#[derive(Clone, Copy, Debug)]
pub struct BusPair {
    pub exp: NodeId,
    pub frac: NodeId,
    pub frac_w: u32,
}

/// Build parameters shared by all adder netlists.
#[derive(Clone, Copy, Debug)]
pub struct DatapathParams {
    pub fmt: FpFormat,
    pub n_terms: u32,
    /// Guard (fractional extension) bits — [`AccSpec::f`] of the numeric
    /// model, bounding the alignment window exactly as in the simulator.
    pub guard: u32,
}

impl DatapathParams {
    pub fn new(fmt: FpFormat, n_terms: u32, spec: AccSpec) -> Self {
        DatapathParams { fmt, n_terms, guard: spec.f }
    }

    /// Leaf fraction width: signed significand plus guard bits.
    pub fn leaf_frac_w(&self) -> u32 {
        self.fmt.sig_bits() + 1 + self.guard
    }

    /// Worst-case alignment distance: the full effective exponent range
    /// [1, max_normal_exp]. Gradual underflow does not widen this —
    /// subnormal operands are pinned at effective exponent 1 (hidden bit
    /// 0), the same slot a minimal normal occupies, so the shifter and the
    /// accumulator window ([`AccSpec::acc_width`]) are unchanged from an
    /// FTZ datapath.
    pub fn max_shift(&self) -> u32 {
        (self.fmt.max_normal_exp() - 1) as u32
    }
}

/// One point of the alignment-fraction spine: a node whose output bus
/// carries the (λ-aligned, two's-complement) partial sum of `terms` input
/// terms, provisioned `frac_w` bits wide. The builders record one tap per
/// leaf and per `⊙` operator output so the static verifier
/// (`analysis::netlist`) can bridge the software-side magnitude bounds
/// ([`crate::analysis::domain::MagBits`]) onto hardware bus widths.
#[derive(Clone, Copy, Debug)]
pub struct OperatorTap {
    pub node: NodeId,
    /// Input terms accumulated into this bus.
    pub terms: u32,
    /// Provisioned fraction-bus width in bits.
    pub frac_w: u32,
    /// Tree level (0 = leaves).
    pub level: u32,
}

/// Complete adder netlist plus handles used by diagnostics.
pub struct AdderNetlist {
    pub nl: Netlist,
    pub params: DatapathParams,
    pub config: RadixConfig,
    /// Fraction-spine taps, leaves first, root last (see [`OperatorTap`]).
    pub taps: Vec<OperatorTap>,
}

/// Build the full adder netlist for a mixed-radix configuration (the
/// baseline is `RadixConfig::baseline(n)`), including unpack and the shared
/// normalize/round tail.
pub fn build_adder(params: DatapathParams, config: &RadixConfig) -> AdderNetlist {
    assert_eq!(config.terms(), params.n_terms, "config width mismatch");
    let mut nl = Netlist::new();
    let fmt = params.fmt;
    let mut taps = Vec::new();

    // Primary inputs + unpack (field split, hidden bit, 2's complement).
    let mut level: Vec<BusPair> = (0..params.n_terms)
        .map(|i| {
            let input = nl.input(format!("in.{i}"));
            let unp = nl.add(format!("unpack.{i}"), comp::unpack(fmt.sig_bits()));
            nl.set_region(unp, "unpack");
            nl.connect(input, unp, fmt.width());
            let pair = BusPair { exp: unp, frac: unp, frac_w: params.leaf_frac_w() };
            taps.push(OperatorTap { node: pair.frac, terms: 1, frac_w: pair.frac_w, level: 0 });
            pair
        })
        .collect();

    // Operator levels.
    let mut terms_covered = 1u32;
    for (li, &r) in config.radices().iter().enumerate() {
        terms_covered *= r;
        let mut next = Vec::with_capacity(level.len() / r as usize);
        for (gi, group) in level.chunks(r as usize).enumerate() {
            let tag = format!("L{li}.g{gi}");
            let out = if r == 2 {
                radix2_node(&mut nl, &params, &tag, group[0], group[1])
            } else {
                radix_r_node(&mut nl, &params, &tag, group)
            };
            taps.push(OperatorTap {
                node: out.frac,
                terms: terms_covered,
                frac_w: out.frac_w,
                level: li as u32 + 1,
            });
            next.push(out);
        }
        level = next;
    }
    debug_assert_eq!(level.len(), 1);

    // Shared normalization/rounding tail.
    normalize_tail(&mut nl, &params, level[0]);

    let mut out = AdderNetlist { nl, params, config: config.clone(), taps };
    out.nl.schedule_asap();
    out
}

/// Radix-2 `⊙` node with the swap + single-shifter structure.
fn radix2_node(
    nl: &mut Netlist,
    p: &DatapathParams,
    tag: &str,
    a: BusPair,
    b: BusPair,
) -> BusPair {
    let e = p.fmt.ebits;
    let w_in = a.frac_w.max(b.frac_w);
    let w_out = w_in + 1;

    // λi − λj: ONE subtractor provides both |diff| (after conditional
    // inversion) and the swap control (its sign) — the comparator is free.
    let diff_raw = add_chain(
        nl,
        &format!("op2.{tag}.diff"),
        &format!("op2.{tag}.diff"),
        comp::subtractor(e),
        clog2(e.max(2)),
        2 * e,
        &[(a.exp, e), (b.exp, e)],
    );
    let diff = nl.add(format!("op2.{tag}.absdiff"), comp::xor_row(e));
    nl.connect(diff_raw, diff, e);
    let cmp = diff_raw; // sign bit drives the muxes
    let emax = nl.add(format!("op2.{tag}.emax"), comp::mux2(e));
    nl.connect(cmp, emax, 1);
    nl.connect(a.exp, emax, e);
    nl.connect(b.exp, emax, e);

    // Swap muxes route the smaller-exponent fraction into the shifter.
    let swap = nl.add(format!("op2.{tag}.swap"), comp::mux2(2 * w_in));
    nl.connect(cmp, swap, 1);
    nl.connect(a.frac, swap, a.frac_w);
    nl.connect(b.frac, swap, b.frac_w);

    // One logarithmic right-shifter (arithmetic, sticky-collecting).
    let stages = comp::shifter_stages(p.max_shift(), w_in);
    let mut prev = swap;
    for s in 0..stages {
        let st = nl.add(format!("op2.{tag}.shift.s{s}"), comp::shift_stage(w_in, true));
        nl.connect(prev, st, w_in);
        if s == 0 {
            nl.connect(diff, st, clog2(p.max_shift() + 1));
        }
        prev = st;
    }

    // Plain two-operand addition (o_i + o_j); the prefix levels are
    // individually schedulable (internal carry state is ~2w wide).
    let add = add_chain(
        nl,
        &format!("op2.{tag}.add"),
        &format!("op2.{tag}.add"),
        comp::prefix_adder(w_out),
        clog2(w_out.max(2)),
        2 * w_out,
        &[(prev, w_in), (swap, w_in)],
    );
    BusPair { exp: emax, frac: add, frac_w: w_out }
}

/// Radix-r (r ≥ 3) `⊙` node: max tree, per-input subtract + shift, CSA
/// compression, final CPA. Structurally the baseline of Fig. 1 over its
/// `r` inputs.
fn radix_r_node(
    nl: &mut Netlist,
    p: &DatapathParams,
    tag: &str,
    inputs: &[BusPair],
) -> BusPair {
    let e = p.fmt.ebits;
    let r = inputs.len() as u32;
    let w_in = inputs.iter().map(|b| b.frac_w).max().unwrap();
    let w_out = w_in + clog2(r);

    // Max-exponent tree: ceil(log2 r) levels of max2 units.
    let mut frontier: Vec<NodeId> = inputs.iter().map(|b| b.exp).collect();
    let mut lvl = 0;
    while frontier.len() > 1 {
        let mut next = Vec::with_capacity(frontier.len().div_ceil(2));
        for pair in frontier.chunks(2) {
            if pair.len() == 2 {
                let mx = nl.add_with_alt(
                    format!("opr.{tag}.max.l{lvl}"),
                    comp::max2(e),
                    comp::compact_variant(comp::max2(e)),
                );
                nl.set_region(mx, format!("opr.{tag}.max.l{lvl}"));
                nl.connect(pair[0], mx, e);
                nl.connect(pair[1], mx, e);
                next.push(mx);
            } else {
                next.push(pair[0]);
            }
        }
        frontier = next;
        lvl += 1;
    }
    let emax = frontier[0];

    // Per-input alignment: subtract + logarithmic shifter.
    let stages = comp::shifter_stages(p.max_shift(), w_in);
    let mut aligned = Vec::with_capacity(inputs.len());
    for (i, inp) in inputs.iter().enumerate() {
        let sub = add_chain(
            nl,
            &format!("opr.{tag}.sub.{i}"),
            &format!("opr.{tag}.sub"),
            comp::subtractor(e),
            clog2(e.max(2)),
            2 * e,
            &[(emax, e), (inp.exp, e)],
        );
        let mut prev = inp.frac;
        for s in 0..stages {
            let st = nl.add(format!("opr.{tag}.shift.{i}.s{s}"), comp::shift_stage(w_in, true));
            nl.set_region(st, format!("opr.{tag}.shift.s{s}"));
            nl.connect(prev, st, w_in);
            if s == 0 {
                nl.connect(sub, st, clog2(p.max_shift() + 1));
            }
            prev = st;
        }
        aligned.push(prev);
    }

    // CSA reduction to two operands (Wallace levels), then the CPA.
    // `ops` is a multiset of operand buses: duplicates mean several buses
    // (sum + carry vectors) leave the same scheduling node.
    let mut ops = aligned;
    let mut level_idx = 0;
    while ops.len() > 2 {
        let k = ops.len();
        let trios = (k / 3) as u32;
        // One scheduling node models all the level's 3:2 compressors.
        let mut row_cost = comp::csa_row(w_out);
        row_cost.area *= trios as f64;
        let row = nl.add(format!("opr.{tag}.csa.l{level_idx}"), row_cost);
        let mut next = Vec::with_capacity(k - trios as usize);
        for (i, &op) in ops.iter().enumerate() {
            if (i as u32) < 3 * trios {
                nl.connect(op, row, w_out);
            } else {
                next.push(op); // leftover operand passes through
            }
        }
        // sum + carry buses per trio continue to the next level.
        for _ in 0..2 * trios {
            next.push(row);
        }
        ops = next;
        level_idx += 1;
    }
    // Duplicate feed edges are deliberate: sum + carry buses both cross
    // any pipeline cut between the last CSA level and the CPA.
    let feeds: Vec<(NodeId, u32)> = ops.iter().map(|&o| (o, w_out)).collect();
    let cpa = add_chain(
        nl,
        &format!("opr.{tag}.cpa"),
        &format!("opr.{tag}.cpa"),
        comp::prefix_adder(w_out),
        clog2(w_out.max(2)),
        2 * w_out,
        &feeds,
    );
    BusPair { exp: emax, frac: cpa, frac_w: w_out }
}

/// Shared normalize + round tail: LZC, left shift, RNE increment, pack,
/// exponent adjust.
fn normalize_tail(nl: &mut Netlist, p: &DatapathParams, root: BusPair) {
    let w = root.frac_w;
    let fmt = p.fmt;
    // Sign/magnitude recovery of the two's-complement sum.
    let abs = nl.add("norm.abs", comp::xor_row(w));
    nl.connect(root.frac, abs, w);
    let lzc = add_chain(nl, "norm.lzc", "norm.lzc", comp::lzc(w), clog2(w.max(2)), w, &[(abs, w)]);
    // Left normalization shifter.
    let stages = comp::shifter_stages(w, w);
    let mut prev = abs;
    for s in 0..stages {
        let st = nl.add(format!("norm.shift.s{s}"), comp::shift_stage(w, false));
        nl.connect(prev, st, w);
        if s == 0 {
            nl.connect(lzc, st, clog2(w + 1));
        }
        prev = st;
    }
    // Exponent adjust: λ + msb-position − guard.
    let eadj = add_chain(
        nl,
        "norm.eadj",
        "norm.eadj",
        comp::subtractor(fmt.ebits + 2),
        clog2(fmt.ebits.max(2)),
        2 * (fmt.ebits + 2),
        &[(root.exp, fmt.ebits), (lzc, clog2(w + 1))],
    );
    // RNE rounding increment on the mantissa.
    let rnd = add_chain(
        nl,
        "norm.round",
        "norm.round",
        comp::incrementer(fmt.mbits + 2),
        clog2((fmt.mbits + 2).max(2)),
        fmt.mbits + 2,
        &[(prev, fmt.mbits + 2)],
    );
    // Final field assembly with overflow/underflow handling.
    let pk = nl.add("norm.pack", comp::pack(fmt.width()));
    nl.connect(rnd, pk, fmt.mbits + 1);
    nl.connect(eadj, pk, fmt.ebits + 2);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::{BF16, FP32};

    fn params(fmt: FpFormat, n: u32) -> DatapathParams {
        DatapathParams::new(fmt, n, AccSpec::hw_default(fmt, n as usize))
    }

    #[test]
    fn baseline_netlist_builds_and_schedules() {
        let p = params(BF16, 32);
        let adder = build_adder(p, &RadixConfig::baseline(32));
        assert!(adder.nl.area() > 0.0);
        assert!(adder.nl.critical_path() > 0.0);
        // 32 shifters worth of mux stages + one CSA tree must exist.
        assert!(adder.nl.area_of("opr") > adder.nl.area_of("norm"));
    }

    #[test]
    fn tree_configs_build_for_all_radix_mixes() {
        let p = params(BF16, 32);
        for cfg in ["2-2-2-2-2", "8-2-2", "4-4-2", "2-2-8", "16-2", "4-8", "32"] {
            let c: RadixConfig = cfg.parse().unwrap();
            let adder = build_adder(p, &c);
            assert!(adder.nl.area() > 0.0, "{cfg}");
        }
    }

    #[test]
    fn radix2_nodes_use_single_shifter() {
        // A 2-2-...-2 tree has N-1 nodes, each with ONE shifter chain; the
        // baseline has N parallel shifters. Compare stage-node counts.
        let p = params(BF16, 16);
        let bin = build_adder(p, &RadixConfig::binary(16).unwrap());
        let base = build_adder(p, &RadixConfig::baseline(16));
        let count = |nl: &Netlist, pat: &str| {
            nl.nodes.iter().filter(|n| n.kind.contains(pat) && n.kind.contains("shift.")).count()
        };
        let bin_shift_stages = count(&bin.nl, "op2");
        let base_shift_stages = count(&base.nl, "opr");
        assert!(
            bin_shift_stages < base_shift_stages,
            "binary tree {bin_shift_stages} stages vs baseline {base_shift_stages}"
        );
    }

    #[test]
    fn wider_formats_cost_more() {
        let bf = build_adder(params(BF16, 16), &RadixConfig::baseline(16));
        let fp = build_adder(params(FP32, 16), &RadixConfig::baseline(16));
        assert!(fp.nl.area() > 2.0 * bf.nl.area());
    }

    #[test]
    fn fraction_widths_grow_with_levels() {
        let p = params(BF16, 8);
        // Leaf width 9+16? = sig(8)+1+guard; after three radix-2 levels +3.
        let leaf = p.leaf_frac_w();
        let cfg = RadixConfig::binary(8).unwrap();
        let adder = build_adder(p, &cfg);
        // The final CPA before normalize must be wider than the leaf.
        let norm_abs_width_proxy = adder
            .nl
            .nodes
            .iter()
            .find(|n| n.kind == "norm.abs")
            .map(|n| n.area / super::super::gates::A_XOR2)
            .unwrap();
        assert!(norm_abs_width_proxy as u32 > leaf);
    }
}
