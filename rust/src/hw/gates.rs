//! Unit-gate technology model and 28-nm calibration constants.
//!
//! The classic unit-gate convention (Knowles / Zimmermann): a 2-input
//! NAND/NOR is 1 gate-equivalent (GE) of area and 1 τ of delay; everything
//! else is expressed in those units. Absolute µm² / ns / mW come from three
//! global calibration constants chosen once against the paper's 28-nm
//! numbers (see `DESIGN.md` §Calibration) — *relative* results, which
//! are what the reproduction compares, do not depend on them.

#![deny(clippy::cast_precision_loss)]

/// Area of one gate-equivalent in µm² (28-nm standard cell, routed).
///
/// Calibrated so the baseline 32-term BFloat16 adder (combinational +
/// pipeline registers at the paper's 1 GHz / 4-stage point) lands near the
/// paper's 6.44·10³ µm² (Table I(b)).
pub const UM2_PER_GE: f64 = 0.22;

/// Delay of one unit-gate τ in nanoseconds (FO4-like with wire load, 28-nm,
/// slow corner).
///
/// Calibrated so the paper's operating point is *tight*: the §IV policy
/// (log2 N − 1 stages for 16/8-bit formats at 1 GHz) just closes timing for
/// the 32-term BFloat16 baseline, matching the paper's observation that
/// deeper pipelines are required as terms/precision grow.
pub const NS_PER_TAU: f64 = 0.025;

/// Dynamic energy per gate-equivalent per *toggling* bit-event, in
/// femtojoules. Combined with toggle counts from the activity simulator it
/// yields mW at the 1 GHz evaluation clock.
pub const FJ_PER_GE_TOGGLE: f64 = 0.37;

/// Static/idle activity floor: fraction of a block's gates that toggle per
/// cycle regardless of data (clock network, glitching floor).
pub const IDLE_ACTIVITY: f64 = 0.04;

// --- per-cell unit-gate costs -------------------------------------------

/// Inverter.
pub const A_INV: f64 = 0.5;
pub const D_INV: f64 = 0.5;

/// 2-input NAND/NOR (the definition of 1 GE / 1 τ).
pub const A_NAND2: f64 = 1.0;
pub const D_NAND2: f64 = 1.0;

/// 2-input AND/OR (NAND + INV).
pub const A_AND2: f64 = 1.5;
pub const D_AND2: f64 = 1.5;

/// 2-input XOR/XNOR.
pub const A_XOR2: f64 = 3.0;
pub const D_XOR2: f64 = 2.0;

/// 2:1 multiplexer.
pub const A_MUX2: f64 = 2.5;
pub const D_MUX2: f64 = 2.0;

/// Full adder (3:2 compressor cell).
pub const A_FA: f64 = 7.5;
pub const D_FA_SUM: f64 = 4.0;
pub const D_FA_CARRY: f64 = 2.0;

/// Half adder.
pub const A_HA: f64 = 4.0;
pub const D_HA: f64 = 2.0;

/// D flip-flop (pipeline register bit), including local clock buffer share.
pub const A_DFF: f64 = 4.5;
/// Register timing overhead per stage (clk→Q + setup), in τ.
pub const D_DFF: f64 = 3.0;

/// ceil(log2 n) for n >= 1.
#[inline]
pub fn clog2(n: u32) -> u32 {
    debug_assert!(n >= 1);
    if n <= 1 {
        return 0;
    }
    32 - (n - 1).leading_zeros()
}

/// Convert GE to µm².
#[inline]
pub fn ge_to_um2(ge: f64) -> f64 {
    ge * UM2_PER_GE
}

/// Convert τ to ns.
#[inline]
pub fn tau_to_ns(tau: f64) -> f64 {
    tau * NS_PER_TAU
}

/// Convert a clock period in ns to the τ budget per pipeline stage
/// (subtracting the register overhead).
#[inline]
pub fn ns_to_stage_budget(clock_ns: f64) -> f64 {
    (clock_ns / NS_PER_TAU) - D_DFF
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clog2_values() {
        assert_eq!(clog2(1), 0);
        assert_eq!(clog2(2), 1);
        assert_eq!(clog2(3), 2);
        assert_eq!(clog2(8), 3);
        assert_eq!(clog2(9), 4);
        assert_eq!(clog2(32), 5);
    }

    #[test]
    fn conversions_roundtrip() {
        assert!((ge_to_um2(1000.0) - 1000.0 * UM2_PER_GE).abs() < 1e-9);
        assert!((tau_to_ns(100.0) - 100.0 * NS_PER_TAU).abs() < 1e-9);
        // 1 ns clock leaves a positive stage budget.
        assert!(ns_to_stage_budget(1.0) > 20.0);
    }
}
