//! Switching-activity power model: run real operand traces through a
//! value-level mirror of the datapath, count per-bus toggles, and weight
//! them by the area of the logic driving each bus.
//!
//! This reproduces the paper's methodology (PowerPro after synthesis, with
//! activity from BERT/GLUE matmul traces) at the abstraction our netlists
//! support: dynamic power ∝ Σ_signals toggles · C(signal), plus register
//! power at pipeline cuts and an idle (clock-tree / glitch floor) term.
//!
//! The simulator works on the *truncated* hardware frame in `i64` (the
//! datapath is ≤ 64 bits wide for every paper configuration), with the same
//! semantics as `arith::operator` — bit-accuracy is cross-checked against
//! the `WideInt` models in the tests.

#![deny(clippy::cast_precision_loss)]

use super::datapath::DatapathParams;
use super::gates::{self, FJ_PER_GE_TOGGLE, IDLE_ACTIVITY};
use super::pipeline::PipelineResult;
use super::{components as comp, datapath};
use crate::arith::tree::RadixConfig;
use crate::formats::Fp;

/// One signal of the value-level datapath mirror.
struct Signal {
    /// Energy weight: GE of driving logic per bit of this bus.
    weight: f64,
    /// Bus width in bits (toggles beyond it cannot occur).
    width: u32,
    /// Previous cycle's value (for toggle counting).
    prev: u128,
}

/// Per-node precomputed evaluation plan.
struct NodePlan {
    /// Indices of the input states (into the previous level's outputs).
    inputs: Vec<usize>,
    /// Signal indices: lambda, shift amounts (r), shifted fracs (r), sum.
    sig_lambda: usize,
    sig_shamt: Vec<usize>,
    sig_shifted: Vec<usize>,
    sig_sum: usize,
}

/// Activity-driven power estimator for one adder design.
pub struct ActivitySim {
    params: DatapathParams,
    config: RadixConfig,
    signals: Vec<Signal>,
    levels: Vec<Vec<NodePlan>>,
    term_signals: Vec<usize>,
    norm_signal: usize,
    /// Accumulated toggle energy (fJ) and cycle count.
    energy_fj: f64,
    cycles: u64,
    /// Scratch: (lambda, acc) state per live node, per level.
    scratch: Vec<Vec<(i64, i128)>>,
    comb_area: f64,
}

impl ActivitySim {
    pub fn new(params: DatapathParams, config: &RadixConfig) -> Self {
        assert!(
            params.leaf_frac_w() + gates::clog2(params.n_terms) <= 126,
            "activity simulator requires a <=126-bit hardware frame"
        );
        let fmt = params.fmt;
        let e = fmt.ebits;
        let mut signals = Vec::new();
        let mut term_signals = Vec::new();
        // Input/unpack signals: raw term bits.
        let unp = comp::unpack(fmt.sig_bits());
        for _ in 0..params.n_terms {
            term_signals.push(push_sig(&mut signals, unp.area, fmt.width()));
        }
        // Operator levels.
        let mut width = params.leaf_frac_w();
        let mut count = params.n_terms as usize;
        let mut levels = Vec::new();
        let mut scratch = vec![vec![(0i64, 0i128); count]];
        for &r in config.radices() {
            let w_out = width + gates::clog2(r);
            let groups = count / r as usize;
            let mut plans = Vec::with_capacity(groups);
            for g in 0..groups {
                let inputs: Vec<usize> = (g * r as usize..(g + 1) * r as usize).collect();
                let (maxtree_a, sub_a, shift_a, add_a) = node_areas(&params, r, width, w_out);
                let sig_lambda = push_sig(&mut signals, maxtree_a, e);
                let mut sig_shamt = Vec::with_capacity(r as usize);
                let mut sig_shifted = Vec::with_capacity(r as usize);
                let shamt_bits = gates::clog2(params.max_shift() + 1);
                for _ in 0..r {
                    sig_shamt.push(push_sig(&mut signals, sub_a, shamt_bits));
                    sig_shifted.push(push_sig(&mut signals, shift_a, width));
                }
                let sig_sum = push_sig(&mut signals, add_a, w_out);
                plans.push(NodePlan { inputs, sig_lambda, sig_shamt, sig_shifted, sig_sum });
            }
            levels.push(plans);
            scratch.push(vec![(0i64, 0i128); groups]);
            width = w_out;
            count = groups;
        }
        debug_assert_eq!(count, 1);
        // Normalize tail: one output signal weighted by the tail's area.
        let norm_area = normalize_area(&params, width);
        let norm_signal = push_sig(&mut signals, norm_area, fmt.width());

        // Total combinational area consistent with the netlist builder.
        let nl = datapath::build_adder(params, config);
        let comb_area = nl.nl.area();

        ActivitySim {
            params,
            config: config.clone(),
            signals,
            levels,
            term_signals,
            norm_signal,
            energy_fj: 0.0,
            cycles: 0,
            scratch,
            comb_area,
        }
    }

    /// Feed one vector of `n_terms` finite values (one adder invocation).
    pub fn step(&mut self, terms: &[Fp]) {
        let p = &self.params;
        assert_eq!(terms.len(), p.n_terms as usize);
        let guard = p.guard;
        let mut cycle_energy = 0.0;
        // Leaf states + input signal toggles.
        for (i, t) in terms.iter().enumerate() {
            debug_assert!(t.is_finite());
            // Leaf lift mirrors `AlignAcc::leaf`: zeros are the identity
            // (λ = 0), every other term — subnormals included — enters at
            // its effective exponent.
            let sig = t.signed_sig();
            let lam = if sig == 0 { 0 } else { t.eff_exp() as i64 };
            let acc = (sig as i128) << guard;
            self.scratch[0][i] = (lam, acc);
            cycle_energy += observe(&mut self.signals[self.term_signals[i]], t.bits as u128);
        }
        // Operator levels (value semantics identical to arith::operator on
        // the truncated frame, shift clamped by the i64 width).
        for (li, plans) in self.levels.iter().enumerate() {
            // Split scratch at li+1: the borrow checker needs disjoint refs.
            let (prev_levels, rest) = self.scratch.split_at_mut(li + 1);
            let inputs = &prev_levels[li];
            let outputs = &mut rest[0];
            for (gi, plan) in plans.iter().enumerate() {
                let mut lam = 0i64;
                for &ii in &plan.inputs {
                    lam = lam.max(inputs[ii].0);
                }
                cycle_energy += observe(&mut self.signals[plan.sig_lambda], lam as u128);
                let mut sum = 0i128;
                for (k, &ii) in plan.inputs.iter().enumerate() {
                    let (l, a) = inputs[ii];
                    let d = (lam - l).min(127) as u32;
                    let shifted = a >> d;
                    sum += shifted;
                    cycle_energy += observe(&mut self.signals[plan.sig_shamt[k]], d as u128);
                    cycle_energy +=
                        observe(&mut self.signals[plan.sig_shifted[k]], shifted as u128);
                }
                outputs[gi] = (lam, sum);
                cycle_energy += observe(&mut self.signals[plan.sig_sum], sum as u128);
            }
        }
        // Normalize tail activity: keyed by the packed rounded result.
        let (lam, acc) = self.scratch[self.levels.len()][0];
        let norm_proxy = (acc as u128) ^ ((lam as u128) << 96);
        cycle_energy += observe(&mut self.signals[self.norm_signal], norm_proxy);

        self.energy_fj += cycle_energy * FJ_PER_GE_TOGGLE;
        self.cycles += 1;
    }

    /// Final `(λ, acc)` of the last step — lets tests cross-check the
    /// simulator against `arith::tree_sum` bit-exactly.
    pub fn last_state(&self) -> (i64, i128) {
        self.scratch[self.levels.len()][0]
    }

    /// Average dynamic power in mW at `clock_ghz`, for a design pipelined
    /// per `pipe` (register power from toggle density × reg bits).
    #[allow(clippy::cast_precision_loss)] // energy/cycle and reg-bit counts enter the float model here
    pub fn power_mw(&self, clock_ghz: f64, pipe: Option<&PipelineResult>) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        let mean_fj = self.energy_fj / self.cycles as f64;
        // Toggle density estimate: energy-weighted toggles already include
        // area weights; approximate bus density from energy vs full-swing.
        let full_swing: f64 = self
            .signals
            .iter()
            .map(|s| s.weight * s.width as f64)
            .sum::<f64>()
            * FJ_PER_GE_TOGGLE;
        let density = (mean_fj / full_swing.max(1e-12)).clamp(0.0, 1.0);
        // Pipeline registers: every bit samples each cycle; toggling bits
        // cost dynamic energy, the rest clock-pin energy (~30%).
        let reg_fj = pipe
            .map(|p| {
                let bits = p.reg_bits as f64;
                bits * gates::A_DFF * FJ_PER_GE_TOGGLE * (0.3 + 0.7 * density)
            })
            .unwrap_or(0.0);
        // Idle/clock floor on the combinational area.
        let idle_fj = self.comb_area * IDLE_ACTIVITY * FJ_PER_GE_TOGGLE;
        // P[mW] = fJ/cycle × GHz × 1e-3.
        (mean_fj + reg_fj + idle_fj) * clock_ghz * 1e-3
    }

    pub fn config(&self) -> &RadixConfig {
        &self.config
    }

    pub fn cycles(&self) -> u64 {
        self.cycles
    }
}

fn push_sig(signals: &mut Vec<Signal>, total_area: f64, width: u32) -> usize {
    signals.push(Signal { weight: total_area / width.max(1) as f64, width, prev: 0 });
    signals.len() - 1
}

/// Count toggles of `value` vs the signal's previous value, returning the
/// energy-weighted toggle count (GE units).
#[inline]
fn observe(sig: &mut Signal, value: u128) -> f64 {
    let mask = if sig.width >= 128 { u128::MAX } else { (1u128 << sig.width) - 1 };
    let v = value & mask;
    let toggles = (v ^ sig.prev).count_ones() as f64;
    sig.prev = v;
    toggles * sig.weight
}

/// Area of the logic blocks of one operator node, split by driven signal:
/// (max tree, one subtractor, one shifter chain, CSA+CPA).
fn node_areas(p: &DatapathParams, r: u32, w_in: u32, w_out: u32) -> (f64, f64, f64, f64) {
    let e = p.fmt.ebits;
    let stages = comp::shifter_stages(p.max_shift(), w_in);
    if r == 2 {
        let maxtree = comp::comparator(e).area + comp::mux2(e).area;
        let sub = comp::subtractor(e).area;
        let shift =
            comp::mux2(2 * w_in).area + stages as f64 * comp::shift_stage(w_in, true).area;
        let add = comp::prefix_adder(w_out).area;
        (maxtree, sub, shift, add)
    } else {
        let maxtree = (r - 1) as f64 * comp::max2(e).area;
        let sub = comp::subtractor(e).area;
        let shift = stages as f64 * comp::shift_stage(w_in, true).area;
        let csa: f64 = {
            let mut total = 0.0;
            let mut k = r;
            while k > 2 {
                let trios = k / 3;
                total += trios as f64 * comp::csa_row(w_out).area;
                k -= trios;
            }
            total
        };
        let add = csa + comp::prefix_adder(w_out).area;
        (maxtree, sub, shift, add)
    }
}

fn normalize_area(p: &DatapathParams, w: u32) -> f64 {
    let fmt = p.fmt;
    let stages = comp::shifter_stages(w, w);
    comp::xor_row(w).area
        + comp::lzc(w).area
        + stages as f64 * comp::shift_stage(w, false).area
        + comp::subtractor(fmt.ebits + 2).area
        + comp::incrementer(fmt.mbits + 2).area
        + comp::pack(fmt.width()).area
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::tree::tree_sum;
    use crate::arith::AccSpec;
    use crate::formats::BF16;
    use crate::util::prng::XorShift;

    fn params() -> DatapathParams {
        DatapathParams::new(BF16, 32, AccSpec::hw_default(BF16, 32))
    }

    #[test]
    fn simulator_state_matches_arith_tree_bitexact() {
        let cfg: RadixConfig = "8-2-2".parse().unwrap();
        let mut sim = ActivitySim::new(params(), &cfg);
        let spec = AccSpec::hw_default(BF16, 32);
        let mut rng = XorShift::new(0x90);
        for _ in 0..200 {
            let ts: Vec<Fp> = (0..32).map(|_| rng.gen_fp_sparse(BF16, 0.1)).collect();
            sim.step(&ts);
            let want = tree_sum(&ts, &cfg, spec);
            let (lam, acc) = sim.last_state();
            assert_eq!(lam, want.lambda as i64);
            assert_eq!(acc, want.acc.to_i128());
        }
    }

    #[test]
    fn constant_inputs_draw_only_floor_power() {
        let cfg = RadixConfig::baseline(32);
        let mut sim = ActivitySim::new(params(), &cfg);
        let ts: Vec<Fp> = (0..32).map(|_| Fp::from_f64(1.5, BF16)).collect();
        for _ in 0..100 {
            sim.step(&ts);
        }
        // After the first cycle nothing toggles: mean energy ≈ first cycle
        // divided by 100 — far below one full-swing cycle.
        let p = sim.power_mw(1.0, None);
        let mut sim2 = ActivitySim::new(params(), &cfg);
        let mut rng = XorShift::new(5);
        for _ in 0..100 {
            let ts: Vec<Fp> = (0..32).map(|_| rng.gen_fp_normal(BF16)).collect();
            sim2.step(&ts);
        }
        let p_random = sim2.power_mw(1.0, None);
        assert!(p < 0.3 * p_random, "constant {p} mW vs random {p_random} mW");
    }

    #[test]
    fn power_scales_with_clock() {
        let cfg = RadixConfig::baseline(16);
        let p16 = DatapathParams::new(BF16, 16, AccSpec::hw_default(BF16, 16));
        let mut sim = ActivitySim::new(p16, &cfg);
        let mut rng = XorShift::new(6);
        for _ in 0..50 {
            let ts: Vec<Fp> = (0..16).map(|_| rng.gen_fp_normal(BF16)).collect();
            sim.step(&ts);
        }
        let p1 = sim.power_mw(1.0, None);
        let p2 = sim.power_mw(2.0, None);
        assert!((p2 / p1 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn registers_add_power() {
        let cfg: RadixConfig = "8-2-2".parse().unwrap();
        let adder = datapath::build_adder(params(), &cfg);
        let t = crate::hw::pipeline::min_clock_ns(&adder, 3) * 1.05;
        let pipe = crate::hw::pipeline::pipeline(&adder, 3, t).unwrap();
        let mut sim = ActivitySim::new(params(), &cfg);
        let mut rng = XorShift::new(8);
        for _ in 0..50 {
            let ts: Vec<Fp> = (0..32).map(|_| rng.gen_fp_normal(BF16)).collect();
            sim.step(&ts);
        }
        assert!(sim.power_mw(1.0, Some(&pipe)) > sim.power_mw(1.0, None));
    }
}
