//! A miniature property-testing engine (the offline environment has no
//! `proptest`): run a property over many seeded random cases and, on
//! failure, greedily shrink the failing input before reporting.
//!
//! Usage (`no_run`: keeps doctest wall time near zero; the same snippet
//! runs as a unit test below):
//! ```no_run
//! use online_fp_add::util::proptest::{check, Gen};
//! check("sum is commutative", 200, |g: &mut Gen| {
//!     let a = g.rng.range_i64(-100, 100);
//!     let b = g.rng.range_i64(-100, 100);
//!     if a + b != b + a { return Err(format!("{a} {b}")); }
//!     Ok(())
//! });
//! ```

use super::prng::XorShift;
use crate::formats::{Fp, FpFormat};

/// Per-case context handed to a property.
pub struct Gen {
    pub rng: XorShift,
    pub case: u64,
}

impl Gen {
    /// One operand from the format's **entire** finite space — signed
    /// zeros, subnormals and normals (see
    /// [`XorShift::gen_fp_full`]). Gradual-underflow properties must hold
    /// over this space, not just over normals.
    pub fn fp_full(&mut self, fmt: FpFormat) -> Fp {
        self.rng.gen_fp_full(fmt)
    }

    /// A full-space operand vector of length `n`, with an extra bias
    /// toward the underflow boundary: each lane is drawn from the full
    /// space, then with probability ~1/4 replaced by a subnormal/zero.
    pub fn fp_full_vec(&mut self, fmt: FpFormat, n: usize) -> Vec<Fp> {
        (0..n)
            .map(|_| {
                if self.rng.below(4) == 0 {
                    self.rng.gen_fp_subnormal(fmt)
                } else {
                    self.rng.gen_fp_full(fmt)
                }
            })
            .collect()
    }
}

/// Run `prop` over `cases` seeded cases; panic with the first failing case
/// (re-runnable via its reported seed) if any returns `Err`.
pub fn check<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    check_seeded(name, cases, 0xC0FFEE, &mut prop);
}

/// Like [`check`] with an explicit base seed.
pub fn check_seeded<F>(name: &str, cases: u64, base_seed: u64, prop: &mut F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = base_seed ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut g = Gen { rng: XorShift::new(seed), case };
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property {name:?} failed at case {case} (seed {seed:#x}): {msg}\n\
                 rerun: check_seeded({name:?}, 1, {seed:#x}, ..)"
            );
        }
    }
}

/// Shrinkable vector property: run over random `Vec<T>` inputs and shrink a
/// failing vector by removing chunks, then single elements, reporting the
/// smallest still-failing input.
pub fn check_vec<T, GenF, PropF>(
    name: &str,
    cases: u64,
    mut generate: GenF,
    mut prop: PropF,
) where
    T: Clone + std::fmt::Debug,
    GenF: FnMut(&mut XorShift) -> Vec<T>,
    PropF: FnMut(&[T]) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = 0xBEEF ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = XorShift::new(seed);
        let input = generate(&mut rng);
        if let Err(first_msg) = prop(&input) {
            // Greedy shrink: drop halves, then quarters, ..., then singles.
            let mut best = input.clone();
            let mut chunk = best.len() / 2;
            while chunk >= 1 {
                let mut i = 0;
                while i + chunk <= best.len() {
                    let mut candidate = best.clone();
                    candidate.drain(i..i + chunk);
                    if prop(&candidate).is_err() {
                        best = candidate; // keep the smaller failing input
                    } else {
                        i += chunk;
                    }
                }
                chunk /= 2;
            }
            let final_msg = prop(&best).err().unwrap_or(first_msg);
            panic!(
                "property {name:?} failed at case {case} (seed {seed:#x});\n\
                 shrunk to {} elements: {best:?}\nerror: {final_msg}",
                best.len()
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("addition commutes", 100, |g| {
            let (a, b) = (g.rng.range_i64(-9, 9), g.rng.range_i64(-9, 9));
            (a + b == b + a).then_some(()).ok_or_else(|| "no".into())
        });
    }

    #[test]
    #[should_panic(expected = "property \"always fails\"")]
    fn failing_property_panics_with_seed() {
        check("always fails", 10, |_| Err("boom".into()));
    }

    #[test]
    fn shrinking_finds_minimal_counterexample() {
        // Property: "no vector contains 7". Generator plants a single 7 in
        // noise; the shrinker must reduce to exactly [7].
        let result = std::panic::catch_unwind(|| {
            check_vec(
                "no sevens",
                5,
                |rng| {
                    let mut v: Vec<i64> = (0..20).map(|_| rng.range_i64(0, 6)).collect();
                    let pos = rng.below(v.len() as u64) as usize;
                    v[pos] = 7;
                    v
                },
                |v| {
                    if v.contains(&7) {
                        Err("contains 7".into())
                    } else {
                        Ok(())
                    }
                },
            )
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("shrunk to 1 elements"), "{msg}");
        assert!(msg.contains("[7]"), "{msg}");
    }
}
