//! Deterministic xorshift* PRNG with floating-point sampling helpers.
//!
//! Everything in this crate that needs randomness (tests, workload
//! generation, property testing) goes through this generator so every run
//! is reproducible from a seed.

use crate::formats::{Fp, FpFormat};

/// xorshift64* — tiny, fast, good enough for workload sampling (not crypto).
#[derive(Clone, Debug)]
pub struct XorShift {
    state: u64,
}

impl XorShift {
    pub fn new(seed: u64) -> Self {
        // Avoid the all-zero fixed point and decorrelate small seeds.
        XorShift { state: seed.wrapping_mul(0x9E3779B97F4A7C15).max(1) | 1 }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)`.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Modulo bias is negligible for our n << 2^64 use cases.
        self.next_u64() % n
    }

    /// Uniform in `[lo, hi]` (inclusive).
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Uniform float in `[0, 1)`.
    #[inline]
    pub fn unit_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box-Muller (one value per call).
    #[allow(clippy::disallowed_methods)] // generator, not datapath
    pub fn gauss(&mut self) -> f64 {
        let u1 = self.unit_f64().max(1e-300);
        let u2 = self.unit_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// A random *finite* value of the format: uniform sign/mantissa bits and
    /// uniform raw exponent over the normal range. This stresses alignment
    /// across the full exponent range (the corner Table I's FP8_e6m1 row
    /// probes) far harder than gaussian data does.
    pub fn gen_fp_normal(&mut self, fmt: FpFormat) -> Fp {
        let sign = self.next_u64() & 1 == 1;
        let e = self.range_i64(1, fmt.max_normal_exp() as i64) as i32;
        let mut m = self.next_u64() & fmt.mant_mask();
        // Keep NoInf formats away from their NaN pattern.
        if e == fmt.max_normal_exp() && m > fmt.max_finite_mant() {
            m = fmt.max_finite_mant();
        }
        Fp::pack(sign, e, m, fmt)
    }

    /// A random finite value over the format's *entire* finite space:
    /// uniform raw exponent over `[0, max_normal_exp]` — raw exponent 0
    /// yields signed zeros and subnormals — with uniform sign and mantissa
    /// bits. This is the full-operand-space generator the gradual-underflow
    /// property tests and the differential oracle fuzz with.
    pub fn gen_fp_full(&mut self, fmt: FpFormat) -> Fp {
        let sign = self.next_u64() & 1 == 1;
        let e = self.range_i64(0, fmt.max_normal_exp() as i64) as i32;
        let mut m = self.next_u64() & fmt.mant_mask();
        // Keep NoInf formats away from their NaN pattern.
        if e == fmt.max_normal_exp() && m > fmt.max_finite_mant() {
            m = fmt.max_finite_mant();
        }
        Fp::pack(sign, e, m, fmt)
    }

    /// A random subnormal (or, when the mantissa draws 0, signed-zero)
    /// value: raw exponent 0, uniform sign and mantissa. Dense sampling of
    /// the gradual-underflow range.
    pub fn gen_fp_subnormal(&mut self, fmt: FpFormat) -> Fp {
        let sign = self.next_u64() & 1 == 1;
        let m = self.next_u64() & fmt.mant_mask();
        Fp::pack(sign, 0, m, fmt)
    }

    /// A random finite value with gaussian magnitude distribution (matmul
    /// activation statistics; used by the workload generators). Magnitudes
    /// below the subnormal range round to signed zero; small draws land in
    /// the format's subnormal range (gradual underflow).
    pub fn gen_fp_gauss(&mut self, fmt: FpFormat, sigma: f64) -> Fp {
        Fp::from_f64(self.gauss() * sigma, fmt)
    }

    /// A random value that may be zero with probability `p_zero`.
    pub fn gen_fp_sparse(&mut self, fmt: FpFormat, p_zero: f64) -> Fp {
        if self.unit_f64() < p_zero {
            Fp::zero(fmt)
        } else {
            self.gen_fp_normal(fmt)
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::{FpClass, BF16, PAPER_FORMATS};

    #[test]
    fn deterministic_from_seed() {
        let mut a = XorShift::new(42);
        let mut b = XorShift::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn gen_fp_is_always_finite() {
        let mut rng = XorShift::new(1);
        for fmt in PAPER_FORMATS {
            for _ in 0..2000 {
                let x = rng.gen_fp_normal(fmt);
                assert!(
                    matches!(x.class(), FpClass::Normal),
                    "{fmt}: {x:?} not normal"
                );
            }
        }
    }

    #[test]
    fn unit_f64_in_range_and_mixed() {
        let mut rng = XorShift::new(9);
        let mut sum = 0.0;
        for _ in 0..1000 {
            let u = rng.unit_f64();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        // Mean of 1000 uniforms should be near 0.5.
        assert!((sum / 1000.0 - 0.5).abs() < 0.05);
    }

    #[test]
    fn gen_fp_full_covers_subnormals_zeros_and_normals() {
        let mut rng = XorShift::new(3);
        for fmt in PAPER_FORMATS {
            let mut seen = [false; 3]; // zero-ish, subnormal, normal
            for _ in 0..4000 {
                let x = rng.gen_fp_full(fmt);
                match x.class() {
                    FpClass::Zero => seen[0] = true,
                    FpClass::Subnormal => seen[1] = true,
                    FpClass::Normal => seen[2] = true,
                    other => panic!("{fmt}: non-finite {other:?}"),
                }
            }
            // Subnormals and normals must both appear; zeros are rare for
            // wide-mantissa formats (mantissa must draw exactly 0).
            assert!(seen[1] && seen[2], "{fmt}: coverage {seen:?}");
        }
    }

    #[test]
    fn gen_fp_subnormal_stays_in_the_underflow_range() {
        let mut rng = XorShift::new(7);
        for fmt in PAPER_FORMATS {
            for _ in 0..500 {
                let x = rng.gen_fp_subnormal(fmt);
                assert_eq!(x.raw_exp(), 0, "{fmt}");
                assert!(
                    matches!(x.class(), FpClass::Zero | FpClass::Subnormal),
                    "{fmt}: {x:?}"
                );
            }
        }
    }

    #[test]
    fn sparse_generates_zeros() {
        let mut rng = XorShift::new(5);
        let zeros = (0..1000)
            .filter(|_| rng.gen_fp_sparse(BF16, 0.3).class() == FpClass::Zero)
            .count();
        assert!((200..400).contains(&zeros));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = XorShift::new(11);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
