//! Small self-contained utilities: deterministic PRNG, CLI parsing, table
//! rendering, statistics and a property-testing engine.
//!
//! These exist because the offline build environment only vendors the `xla`
//! crate's dependency closure — no `rand`, `clap`, `serde` or `proptest`.

pub mod cli;
pub mod prng;
pub mod proptest;
pub mod stats;
pub mod table;
