//! Tiny statistics helpers for benches and reports.

/// Mean of a slice (0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
#[allow(clippy::disallowed_methods)] // stats harness: sqrt is the point
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Median (by sorting a copy).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// `p` quantile in [0,1] (nearest-rank).
pub fn quantile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((v.len() as f64 - 1.0) * p).round() as usize;
    v[idx.min(v.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basics() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert_eq!(median(&xs), 2.5);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert!((stddev(&xs) - 1.118033988749895).abs() < 1e-12);
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert_eq!(mean(&[]), 0.0);
    }
}
