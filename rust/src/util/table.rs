//! ASCII table + CSV rendering for reports (no external crates offline).

/// A simple column-aligned text table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Table { header: header.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        debug_assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
        self
    }

    /// Render with padded columns and a separator under the header.
    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate().take(ncols) {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
                .trim_end()
                .to_string()
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncols - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// Render as CSV (naive quoting: cells with commas get double quotes).
    pub fn to_csv(&self) -> String {
        let quote = |c: &str| {
            if c.contains(',') || c.contains('"') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(&self.header.iter().map(|c| quote(c)).collect::<Vec<_>>().join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| quote(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Format a float with `digits` significant decimals, trimming noise.
pub fn fnum(x: f64, digits: usize) -> String {
    format!("{:.*}", digits, x)
}

/// Format a fraction as a signed percentage ("15%", "-5%").
pub fn pct(frac: f64) -> String {
    format!("{:.0}%", frac * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns_columns() {
        let mut t = Table::new(vec!["config", "area"]);
        t.row(vec!["8-2-2", "5.5"]);
        t.row(vec!["2-2-2-2-2", "5.9"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert!(lines[0].starts_with("config"));
        assert!(lines[2].starts_with("8-2-2"));
        // Columns align: "area" header and values start at same offset.
        let pos = lines[0].find("area").unwrap();
        assert_eq!(&lines[2][pos..pos + 3], "5.5");
    }

    #[test]
    fn csv_quotes_commas() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["x,y", "2"]);
        assert_eq!(t.to_csv(), "a,b\n\"x,y\",2\n");
    }

    #[test]
    fn pct_rounds() {
        assert_eq!(pct(0.151), "15%");
        assert_eq!(pct(-0.052), "-5%");
    }
}
