//! Minimal hand-rolled CLI argument parser (the offline environment has no
//! `clap`). Supports `--flag`, `--key value`, `--key=value` and positional
//! arguments, with typed accessors and error messages that name the flag.

use std::collections::HashMap;

/// Parsed command-line arguments.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: HashMap<String, String>,
}

impl Args {
    /// Parse an iterator of raw arguments (without the program name).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.flags.insert(stripped.to_string(), v);
                } else {
                    out.flags.insert(stripped.to_string(), String::from("true"));
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    /// Parse the process arguments (skipping argv[0]).
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| format!("--{key} {v:?}: {e}")),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| format!("--{key} {v:?}: {e}")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| format!("--{key} {v:?}: {e}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string()))
    }

    #[test]
    fn mixed_styles() {
        let a = parse(&["fig4", "--n", "32", "--format=bf16", "--verbose", "--clock", "1.0"]);
        assert_eq!(a.positional, vec!["fig4"]);
        assert_eq!(a.get("n"), Some("32"));
        assert_eq!(a.get("format"), Some("bf16"));
        assert!(a.has("verbose"));
        assert_eq!(a.get_f64("clock", 0.0).unwrap(), 1.0);
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
    }

    #[test]
    fn bad_value_reports_flag() {
        let a = parse(&["--n", "abc"]);
        let err = a.get_usize("n", 0).unwrap_err();
        assert!(err.contains("--n"), "{err}");
    }

    #[test]
    fn trailing_flag_is_boolean() {
        let a = parse(&["--dry-run"]);
        assert!(a.has("dry-run"));
    }
}
