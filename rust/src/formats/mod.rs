//! Floating-point format definitions (paper Fig. 3).
//!
//! A format is parametric in exponent and mantissa width; the five concrete
//! formats evaluated by the paper are provided as constants:
//! FP32 (e8m23), BFloat16 (e8m7), FP8_e4m3, FP8_e5m2 and the corner-case
//! FP8_e6m1 (large exponent range relative to the mantissa).
//!
//! Semantics notes (matching IEEE-754 / OCP-FP8 behaviour; the paper's
//! "corner cases … can be also encoded or skipped" are encoded here):
//!
//! * **Gradual underflow is fully supported.** Raw exponent 0 with a
//!   nonzero mantissa decodes as the subnormal `(-1)^s · 0.m · 2^(1-bias)`
//!   ([`FpClass::Subnormal`]), and [`Fp::from_f64`] rounds into the
//!   subnormal range (RNE at the fixed LSB `2^(1-bias-mbits)`) instead of
//!   flushing to zero. For alignment purposes subnormals sit at the
//!   *effective* exponent 1 with hidden bit 0 ([`Fp::eff_exp`] /
//!   [`Fp::signed_sig`]), so exponent 0 never enters the λ domain of the
//!   `⊙` datapath.
//! * **Zero signs in sums**: the fused adders treat every ±0 operand as the
//!   additive identity, so an all-zero (or exactly cancelled) sum rounds to
//!   `+0` — the IEEE default-rounding sign rule for cancellation, applied
//!   uniformly (a two-operand IEEE adder would return `-0` for
//!   `(-0) + (-0)`; multi-term fused adders do not track that case).
//! * **Specials** follow the format's [`SpecialsMode`]:
//!   [`SpecialsMode::Ieee`] (FP32/BF16/e5m2) reserves the all-ones exponent
//!   for Inf/NaN; [`SpecialsMode::NoInf`] (e4m3, e6m1) reserves only the
//!   single all-ones pattern `S.1..1.1..1` for NaN (OCP-style) and has no
//!   infinities — overflow saturates to the largest finite value.

mod fp;
pub use fp::{Fp, FpClass};

/// A binary floating-point format `(-1)^s · 1.m · 2^(e - bias)`.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
pub struct FpFormat {
    /// Exponent field width in bits (2..=11 supported).
    pub ebits: u32,
    /// Mantissa (fraction) field width in bits (1..=52 supported).
    pub mbits: u32,
    /// How the format encodes Inf/NaN.
    pub specials: SpecialsMode,
    /// Short human-readable name ("FP32", "FP8_e4m3", ...).
    pub name: &'static str,
}

/// How a format encodes non-finite values.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum SpecialsMode {
    /// IEEE-754 style: exponent all-ones is Inf (mantissa 0) or NaN.
    Ieee,
    /// OCP FP8 e4m3 style: only `exp=all-ones, mant=all-ones` is NaN;
    /// there is no Inf and overflow saturates to the maximum finite value.
    NoInf,
}

impl FpFormat {
    pub const fn new(name: &'static str, ebits: u32, mbits: u32, specials: SpecialsMode) -> Self {
        FpFormat { ebits, mbits, specials, name }
    }

    /// Exponent bias `2^(ebits-1) - 1`.
    #[inline]
    pub const fn bias(&self) -> i32 {
        (1 << (self.ebits - 1)) - 1
    }

    /// Total encoded width in bits (sign + exponent + mantissa).
    #[inline]
    pub const fn width(&self) -> u32 {
        1 + self.ebits + self.mbits
    }

    /// Largest raw (biased) exponent value that encodes a *normal* number.
    #[inline]
    pub const fn max_normal_exp(&self) -> i32 {
        match self.specials {
            // all-ones exponent reserved for Inf/NaN
            SpecialsMode::Ieee => (1 << self.ebits) - 2,
            // all-ones exponent is normal except the single NaN pattern
            SpecialsMode::NoInf => (1 << self.ebits) - 1,
        }
    }

    /// Mantissa of the largest finite value (used for overflow saturation
    /// in [`SpecialsMode::NoInf`] formats, where the all-ones mantissa at
    /// the top exponent is NaN).
    #[inline]
    pub const fn max_finite_mant(&self) -> u64 {
        match self.specials {
            SpecialsMode::Ieee => (1 << self.mbits) - 1,
            SpecialsMode::NoInf => (1 << self.mbits) - 2,
        }
    }

    /// Number of representable *effective* exponent values for finite
    /// nonzero numbers (1 ..= max_normal_exp — subnormals are pinned at
    /// effective exponent 1), i.e. the worst-case alignment distance + 1.
    #[inline]
    pub const fn exp_range(&self) -> u32 {
        self.max_normal_exp() as u32
    }

    /// Significand width including the hidden bit (`1.m`).
    #[inline]
    pub const fn sig_bits(&self) -> u32 {
        self.mbits + 1
    }

    /// Bit mask for the mantissa field.
    #[inline]
    pub const fn mant_mask(&self) -> u64 {
        (1u64 << self.mbits) - 1
    }

    /// Bit mask for the exponent field.
    #[inline]
    pub const fn exp_mask(&self) -> u64 {
        (1u64 << self.ebits) - 1
    }
}

impl std::fmt::Debug for FpFormat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}(e{}m{})", self.name, self.ebits, self.mbits)
    }
}

impl std::fmt::Display for FpFormat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name)
    }
}

/// IEEE-754 binary32.
pub const FP32: FpFormat = FpFormat::new("FP32", 8, 23, SpecialsMode::Ieee);
/// Google brain-float 16.
pub const BF16: FpFormat = FpFormat::new("BFloat16", 8, 7, SpecialsMode::Ieee);
/// OCP FP8 E4M3 (no Inf, single NaN).
pub const FP8_E4M3: FpFormat = FpFormat::new("FP8_e4m3", 4, 3, SpecialsMode::NoInf);
/// OCP FP8 E5M2 (IEEE-style specials).
pub const FP8_E5M2: FpFormat = FpFormat::new("FP8_e5m2", 5, 2, SpecialsMode::Ieee);
/// The paper's corner-case format: 6-bit exponent, 1-bit mantissa.
pub const FP8_E6M1: FpFormat = FpFormat::new("FP8_e6m1", 6, 1, SpecialsMode::NoInf);

/// The five formats evaluated in the paper (Fig. 3 + Table I).
pub const PAPER_FORMATS: [FpFormat; 5] = [FP32, BF16, FP8_E4M3, FP8_E5M2, FP8_E6M1];

/// Look a paper format up by (case-insensitive) name.
pub fn format_by_name(name: &str) -> Option<FpFormat> {
    let lower = name.to_ascii_lowercase();
    PAPER_FORMATS
        .into_iter()
        .find(|f| f.name.to_ascii_lowercase() == lower || matches_alias(&lower, f))
}

fn matches_alias(lower: &str, f: &FpFormat) -> bool {
    match f.name {
        "FP32" => lower == "f32" || lower == "fp32" || lower == "float32",
        "BFloat16" => lower == "bf16" || lower == "bfloat16",
        "FP8_e4m3" => lower == "e4m3" || lower == "fp8e4m3",
        "FP8_e5m2" => lower == "e5m2" || lower == "fp8e5m2",
        "FP8_e6m1" => lower == "e6m1" || lower == "fp8e6m1",
        _ => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_formats_match_fig3() {
        // Fig. 3: FP32 = 1/8/23, BF16 = 1/8/7, FP8 variants 1/4/3, 1/5/2, 1/6/1.
        assert_eq!((FP32.ebits, FP32.mbits, FP32.width()), (8, 23, 32));
        assert_eq!((BF16.ebits, BF16.mbits, BF16.width()), (8, 7, 16));
        assert_eq!((FP8_E4M3.ebits, FP8_E4M3.mbits, FP8_E4M3.width()), (4, 3, 8));
        assert_eq!((FP8_E5M2.ebits, FP8_E5M2.mbits, FP8_E5M2.width()), (5, 2, 8));
        assert_eq!((FP8_E6M1.ebits, FP8_E6M1.mbits, FP8_E6M1.width()), (6, 1, 8));
    }

    #[test]
    fn biases() {
        assert_eq!(FP32.bias(), 127);
        assert_eq!(BF16.bias(), 127);
        assert_eq!(FP8_E4M3.bias(), 7);
        assert_eq!(FP8_E5M2.bias(), 15);
        assert_eq!(FP8_E6M1.bias(), 31);
    }

    #[test]
    fn max_normal_exponents() {
        assert_eq!(FP32.max_normal_exp(), 254); // 255 reserved
        assert_eq!(FP8_E4M3.max_normal_exp(), 15); // NoInf keeps all-ones
        assert_eq!(FP8_E5M2.max_normal_exp(), 30);
        assert_eq!(FP8_E6M1.max_normal_exp(), 63);
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(format_by_name("bf16").unwrap().name, "BFloat16");
        assert_eq!(format_by_name("FP32").unwrap().name, "FP32");
        assert_eq!(format_by_name("e4m3").unwrap().name, "FP8_e4m3");
        assert!(format_by_name("fp64").is_none());
    }
}
