//! Encoded floating-point values and decode/encode/convert helpers.

use super::{FpFormat, SpecialsMode};

/// Classification of a decoded value.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum FpClass {
    /// ±0 (raw exponent 0, mantissa 0).
    Zero,
    /// A subnormal number `(-1)^s · 0.m · 2^(1-bias)` (raw exponent 0,
    /// nonzero mantissa): gradual underflow, IEEE-754 semantics.
    Subnormal,
    /// A normal number `(-1)^s · 1.m · 2^(e-bias)`.
    Normal,
    /// ±Infinity (only in [`SpecialsMode::Ieee`] formats).
    Inf,
    /// Not-a-number.
    Nan,
}

/// A floating-point value: raw bits plus its format.
///
/// `bits` holds the sign/exponent/mantissa fields packed MSB-first in the
/// low `format.width()` bits, exactly as the hardware would see them.
#[derive(Clone, Copy, PartialEq, Eq)]
pub struct Fp {
    pub bits: u64,
    pub format: FpFormat,
}

impl Fp {
    /// Wrap raw bits (the upper bits beyond `format.width()` must be zero).
    #[inline]
    pub fn from_bits(bits: u64, format: FpFormat) -> Self {
        debug_assert_eq!(bits >> format.width(), 0, "stray bits above the format width");
        Fp { bits, format }
    }

    /// Positive zero.
    #[inline]
    pub fn zero(format: FpFormat) -> Self {
        Fp { bits: 0, format }
    }

    /// The sign bit.
    #[inline]
    pub fn sign(&self) -> bool {
        (self.bits >> (self.format.ebits + self.format.mbits)) & 1 == 1
    }

    /// Raw (biased) exponent field.
    #[inline]
    pub fn raw_exp(&self) -> i32 {
        ((self.bits >> self.format.mbits) & self.format.exp_mask()) as i32
    }

    /// Mantissa field (without the hidden bit).
    #[inline]
    pub fn mant(&self) -> u64 {
        self.bits & self.format.mant_mask()
    }

    /// Effective (biased) exponent for alignment: the raw exponent for
    /// normals, and 1 for subnormals and zeros — the IEEE gradual-underflow
    /// convention `(-1)^s · 0.m · 2^(1-bias)`, under which raw exponent 0
    /// never enters the alignment (λ) domain. See
    /// [`crate::arith::operator`] for how the `⊙` datapath relies on this.
    #[inline]
    pub fn eff_exp(&self) -> i32 {
        let e = self.raw_exp();
        if e == 0 {
            1
        } else {
            e
        }
    }

    /// Classify the value under the format's special-value rules.
    pub fn class(&self) -> FpClass {
        let e = self.raw_exp();
        let m = self.mant();
        match self.format.specials {
            SpecialsMode::Ieee => {
                if e == (self.format.exp_mask() as i32) {
                    if m == 0 {
                        FpClass::Inf
                    } else {
                        FpClass::Nan
                    }
                } else if e == 0 {
                    if m == 0 {
                        FpClass::Zero
                    } else {
                        FpClass::Subnormal
                    }
                } else {
                    FpClass::Normal
                }
            }
            SpecialsMode::NoInf => {
                if e == (self.format.exp_mask() as i32) && m == self.format.mant_mask() {
                    FpClass::Nan
                } else if e == 0 {
                    if m == 0 {
                        FpClass::Zero
                    } else {
                        FpClass::Subnormal
                    }
                } else {
                    FpClass::Normal
                }
            }
        }
    }

    /// Signed significand as an integer scaled by `2^mbits`: `(-1)^s · 1.m`
    /// for normals, `(-1)^s · 0.m` (hidden bit 0) for subnormals.
    ///
    /// Together with [`Self::eff_exp`] this decodes every finite value as
    /// `signed_sig · 2^(eff_exp - bias - mbits)`. Zero for
    /// [`FpClass::Zero`]; callers must handle Inf/NaN separately.
    #[inline]
    pub fn signed_sig(&self) -> i64 {
        match self.class() {
            FpClass::Zero => 0,
            FpClass::Subnormal => {
                let mag = self.mant() as i64;
                if self.sign() {
                    -mag
                } else {
                    mag
                }
            }
            _ => {
                let mag = ((1u64 << self.format.mbits) | self.mant()) as i64;
                if self.sign() {
                    -mag
                } else {
                    mag
                }
            }
        }
    }

    /// Exact conversion to `f64` (every paper format fits losslessly).
    pub fn to_f64(&self) -> f64 {
        match self.class() {
            FpClass::Zero => {
                if self.sign() {
                    -0.0
                } else {
                    0.0
                }
            }
            FpClass::Inf => {
                if self.sign() {
                    f64::NEG_INFINITY
                } else {
                    f64::INFINITY
                }
            }
            FpClass::Nan => f64::NAN,
            FpClass::Normal | FpClass::Subnormal => {
                // (-1)^s · 1.m · 2^mbits (normal) or (-1)^s · 0.m · 2^mbits
                // (subnormal, at the effective exponent 1 - bias).
                let sig = self.signed_sig() as f64;
                let scale = self.eff_exp() - self.format.bias() - self.format.mbits as i32;
                sig * pow2(scale)
            }
        }
    }

    /// Round an `f64` into the format (round-to-nearest-even, gradual
    /// underflow into the subnormal range, saturation per [`SpecialsMode`]
    /// on overflow).
    #[allow(clippy::disallowed_methods)] // THE decode boundary (clippy.toml)
    pub fn from_f64(x: f64, format: FpFormat) -> Self {
        if x.is_nan() {
            return Self::nan(format);
        }
        let sign = x.is_sign_negative();
        if x == 0.0 {
            return Self::encode_sign_zero(sign, format);
        }
        if x.is_infinite() {
            return Self::overflow(sign, format);
        }
        let mag = x.abs();
        // Decompose: mag = frac · 2^exp2 with frac ∈ [1, 2)
        let exp2 = mag.log2().floor() as i32;
        // Guard against log2 edge cases by renormalizing explicitly.
        let mut e2 = exp2;
        let mut frac = mag * pow2(-e2);
        if frac >= 2.0 {
            frac *= 0.5;
            e2 += 1;
        } else if frac < 1.0 {
            frac *= 2.0;
            e2 -= 1;
        }
        debug_assert!((1.0..2.0).contains(&frac));
        if e2 + format.bias() <= 0 {
            // Gradual underflow: round in the subnormal frame, whose
            // mantissa LSB has the fixed weight 2^(1 - bias - mbits)
            // regardless of the value's own binade.
            let scaled = mag * pow2(format.mbits as i32 + format.bias() - 1);
            let mant = round_half_even(scaled);
            if mant == 0 {
                return Self::encode_sign_zero(sign, format);
            }
            if mant >= (1u64 << format.mbits) {
                // Rounded up into the smallest normal 1.0 · 2^(1-bias).
                return Self::pack(sign, 1, 0, format);
            }
            return Self::pack(sign, 0, mant, format);
        }
        // Round mantissa to mbits (RNE) using the f64 representation.
        let scaled = frac * pow2(format.mbits as i32); // in [2^mbits, 2^(mbits+1))
        let mut mant = round_half_even(scaled);
        let mut raw_e = e2 + format.bias();
        if mant == (1u64 << (format.mbits + 1)) {
            mant >>= 1;
            raw_e += 1;
        }
        mant &= format.mant_mask();
        if raw_e > format.max_normal_exp()
            || (raw_e == format.max_normal_exp() && mant > format.max_finite_mant())
        {
            return Self::overflow(sign, format);
        }
        Self::pack(sign, raw_e, mant, format)
    }

    /// The canonical NaN of the format.
    pub fn nan(format: FpFormat) -> Self {
        match format.specials {
            SpecialsMode::Ieee => Self::pack(false, format.exp_mask() as i32, 1 << (format.mbits - 1).max(0), format),
            SpecialsMode::NoInf => Self::pack(false, format.exp_mask() as i32, format.mant_mask(), format),
        }
    }

    /// ±Infinity for IEEE formats; the saturated maximum finite value for
    /// NoInf formats (OCP overflow behaviour).
    pub fn overflow(sign: bool, format: FpFormat) -> Self {
        match format.specials {
            SpecialsMode::Ieee => Self::pack(sign, format.exp_mask() as i32, 0, format),
            SpecialsMode::NoInf => {
                Self::pack(sign, format.max_normal_exp(), format.max_finite_mant(), format)
            }
        }
    }

    /// Pack fields into bits.
    #[inline]
    pub fn pack(sign: bool, raw_exp: i32, mant: u64, format: FpFormat) -> Self {
        debug_assert!(raw_exp >= 0 && raw_exp <= format.exp_mask() as i32);
        debug_assert!(mant <= format.mant_mask());
        let bits = ((sign as u64) << (format.ebits + format.mbits))
            | ((raw_exp as u64) << format.mbits)
            | mant;
        Fp { bits, format }
    }

    fn encode_sign_zero(sign: bool, format: FpFormat) -> Self {
        Self::pack(sign, 0, 0, format)
    }

    /// True if this is a finite value (zero, subnormal or normal).
    #[inline]
    pub fn is_finite(&self) -> bool {
        matches!(self.class(), FpClass::Zero | FpClass::Subnormal | FpClass::Normal)
    }
}

impl std::fmt::Debug for Fp {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}({:#x} = {})", self.format.name, self.bits, self.to_f64())
    }
}

/// Exact powers of two as f64 (handles the full exponent range we need).
#[inline]
#[allow(clippy::disallowed_methods)] // THE encode boundary (clippy.toml)
pub fn pow2(e: i32) -> f64 {
    // f64 covers 2^±1074 comfortably for every paper format.
    f64::from_bits(if e >= -1022 && e <= 1023 {
        (((e + 1023) as u64) << 52) as u64
    } else {
        return (2f64).powi(e);
    })
}

/// Round a positive f64 to the nearest integer, ties to even.
#[allow(clippy::disallowed_methods)] // THE decode boundary (clippy.toml)
fn round_half_even(x: f64) -> u64 {
    let floor = x.floor();
    let frac = x - floor;
    let f = floor as u64;
    if frac > 0.5 {
        f + 1
    } else if frac < 0.5 {
        f
    } else if f % 2 == 0 {
        f
    } else {
        f + 1
    }
}

#[cfg(test)]
mod tests {
    use super::super::{BF16, FP32, FP8_E4M3, FP8_E5M2, FP8_E6M1, PAPER_FORMATS};
    use super::*;

    #[test]
    fn fp32_roundtrip_matches_native() {
        // Every finite f32 we can feasibly sample must round-trip exactly
        // through our FP32 codec — including subnormals.
        let samples = [
            0.0f32, -0.0, 1.0, -1.0, 1.5, 0.1, 3.14159, -2.71828, 1e-30, 1e30, 123456.789,
            f32::MAX, f32::MIN_POSITIVE,
            f32::MIN_POSITIVE / 2.0,            // subnormal
            f32::from_bits(1),                  // smallest positive subnormal
            -f32::from_bits(0x007f_ffff),       // largest negative subnormal
            1e-42,                              // mid-range subnormal
        ];
        for &x in &samples {
            let fp = Fp::from_f64(x as f64, FP32);
            let back = fp.to_f64() as f32;
            assert_eq!(back.to_bits(), x.to_bits(), "mismatch for {x}");
        }
    }

    #[test]
    fn fp32_bits_match_native_layout() {
        let x = 3.5f32;
        let fp = Fp::from_f64(x as f64, FP32);
        assert_eq!(fp.bits as u32, x.to_bits());
    }

    #[test]
    fn bf16_is_truncated_fp32_space() {
        let fp = Fp::from_f64(1.0, BF16);
        assert_eq!(fp.raw_exp(), 127);
        assert_eq!(fp.mant(), 0);
        assert_eq!(fp.to_f64(), 1.0);
    }

    #[test]
    fn subnormals_decode_and_encode_gradually() {
        for fmt in PAPER_FORMATS {
            // Smallest positive normal divided by 2 is the subnormal with
            // the top mantissa bit set.
            let min_normal = pow2(1 - fmt.bias());
            let fp = Fp::from_f64(min_normal / 2.0, fmt);
            assert_eq!(fp.class(), FpClass::Subnormal, "{fmt}");
            assert_eq!(fp.raw_exp(), 0, "{fmt}");
            assert_eq!(fp.mant(), 1 << (fmt.mbits - 1), "{fmt}");
            assert_eq!(fp.to_f64(), min_normal / 2.0, "{fmt}");
            // The largest subnormal decodes as (2^mbits - 1)·2^(1-bias-mbits)
            // and round-trips through the codec.
            let sub = Fp::pack(true, 0, fmt.mant_mask(), fmt);
            assert_eq!(sub.class(), FpClass::Subnormal, "{fmt}");
            assert_eq!(sub.eff_exp(), 1, "{fmt}");
            assert_eq!(sub.signed_sig(), -(fmt.mant_mask() as i64), "{fmt}");
            assert_eq!(Fp::from_f64(sub.to_f64(), fmt).bits, sub.bits, "{fmt}");
            // The smallest subnormal survives too.
            let tiny = Fp::pack(false, 0, 1, fmt);
            assert_eq!(tiny.to_f64(), pow2(1 - fmt.bias() - fmt.mbits as i32), "{fmt}");
            assert_eq!(Fp::from_f64(tiny.to_f64(), fmt).bits, tiny.bits, "{fmt}");
        }
    }

    #[test]
    fn subnormal_encode_rounds_rne_at_the_fixed_lsb() {
        // FP32 subnormal LSB is 2^-149; 1.5·2^-149 is exactly halfway
        // between mant 1 and mant 2 -> ties to even -> mant 2.
        let fp = Fp::from_f64(1.5 * pow2(-149), FP32);
        assert_eq!((fp.raw_exp(), fp.mant()), (0, 2));
        // Below half the smallest subnormal rounds to zero (keeping sign).
        let fp = Fp::from_f64(-0.25 * pow2(-149), FP32);
        assert_eq!(fp.class(), FpClass::Zero);
        assert!(fp.sign());
        // Just below the smallest normal rounds up into the normal range.
        let fp = Fp::from_f64(pow2(-126) * (1.0 - pow2(-30)), FP32);
        assert_eq!((fp.raw_exp(), fp.mant()), (1, 0));
    }

    #[test]
    fn fp32_subnormals_bit_match_native_f32() {
        for bits in [1u32, 2, 3, 0x7f_ffff, 0x40_0000, 0x155_555 & 0x7f_ffff] {
            let native = f32::from_bits(bits);
            assert!(native.is_subnormal());
            let fp = Fp::from_f64(native as f64, FP32);
            assert_eq!(fp.bits as u32, bits, "encode {bits:#x}");
            assert_eq!(fp.to_f64() as f32, native, "decode {bits:#x}");
        }
    }

    #[test]
    fn ieee_specials() {
        let inf = Fp::overflow(false, FP32);
        assert_eq!(inf.class(), FpClass::Inf);
        assert_eq!(inf.to_f64(), f64::INFINITY);
        let nan = Fp::nan(FP8_E5M2);
        assert_eq!(nan.class(), FpClass::Nan);
    }

    #[test]
    fn noinf_saturates() {
        // e4m3 overflow saturates to 448 (S.1111.110).
        let sat = Fp::overflow(false, FP8_E4M3);
        assert_eq!(sat.class(), FpClass::Normal);
        assert_eq!(sat.to_f64(), 448.0);
        let nan = Fp::nan(FP8_E4M3);
        assert_eq!(nan.class(), FpClass::Nan);
        // e6m1: max finite is 1.0 · 2^(63-31) = 2^32 (mantissa 0 at top exp,
        // since mantissa all-ones (=1) is NaN).
        let sat6 = Fp::overflow(false, FP8_E6M1);
        assert_eq!(sat6.to_f64(), pow2(32));
    }

    #[test]
    fn rne_ties_to_even() {
        // BF16 mantissa has 7 bits; 1 + 2^-8 is exactly halfway between
        // 1.0 (mant 0, even) and 1 + 2^-7 (mant 1, odd) -> rounds to 1.0.
        let fp = Fp::from_f64(1.0 + pow2(-8), BF16);
        assert_eq!(fp.to_f64(), 1.0);
        // 1 + 3·2^-8 is halfway between mant 1 and mant 2 -> rounds to 2 (even).
        let fp = Fp::from_f64(1.0 + 3.0 * pow2(-8), BF16);
        assert_eq!(fp.mant(), 2);
    }

    #[test]
    fn signed_sig() {
        let fp = Fp::from_f64(-1.5, FP32);
        assert_eq!(fp.signed_sig(), -(3i64 << 22));
        let fp = Fp::from_f64(1.0, BF16);
        assert_eq!(fp.signed_sig(), 1 << 7);
    }
}
