//! `repro` — the experiment launcher: regenerates every table and figure of
//! the paper and drives the end-to-end PJRT workloads.
//!
//! ```text
//! repro fig4   [--vectors 512] [--csv]        Fig. 4  (32-term BF16 area/power)
//! repro fig5                                  Fig. 5  (area vs clock, 1-4 stages)
//! repro table1 [--n 16|32|64] [--vectors 512] Table I (all formats; default all N)
//! repro add    --format bf16 --arch 8-2-2 x y z ...    one fused addition
//! repro oracle [--format all] [--vectors 2000]         differential oracle
//! repro backends                              reduction-backend registry
//! repro conform [--format all] [--vectors 20]  registry conformance suite
//! repro kernel [--format all] [--n 1024] [--blocks 1,8,64]  SoA-kernel check
//! repro eia    [--format all] [--n 1024] [--vectors 64]     EIA backend check
//! repro sweep  --format e4m3 --n 16           raw design-space dump
//! repro dse    [--json] [--n 32] [--vectors 96]        serial-vs-online DSE artifact
//! repro stats  [--prometheus|--json|--trace|--provenance] [--selftest]  live cross-tier telemetry
//! repro analyze [--gate|--json] [--netlist] [--fault NAME]  static width/overflow proof
//! repro e2e    [--sentences 4] [--requests 256]        PJRT end-to-end demo
//! ```
//!
//! Every command prints paper-vs-measured summaries where the paper
//! reports a number (see DESIGN.md for the experiment index).

use online_fp_add::arith::adder::{Architecture, MultiTermAdder};
use online_fp_add::coordinator::Coordinator;
use online_fp_add::dse::{report, SweepOptions};
use online_fp_add::formats::{format_by_name, Fp};
use online_fp_add::util::cli::Args;
use std::process::ExitCode;

fn main() -> ExitCode {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let result = match cmd {
        "fig4" => cmd_fig4(&args),
        "fig5" => cmd_fig5(&args),
        "table1" => cmd_table1(&args),
        "add" => cmd_add(&args),
        "oracle" => cmd_oracle(&args),
        "backends" => cmd_backends(&args),
        "conform" => cmd_conform(&args),
        "kernel" => cmd_kernel(&args),
        "eia" => cmd_eia(&args),
        "sweep" => cmd_sweep(&args),
        "dse" => cmd_dse(&args),
        "stats" => cmd_stats(&args),
        "analyze" => cmd_analyze(&args),
        "e2e" => cmd_e2e(&args),
        "serve" => cmd_serve(&args),
        "help" | "--help" | "-h" => {
            print!("{}", HELP);
            Ok(())
        }
        other => Err(format!("unknown command {other:?}; try `repro help`")),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

const HELP: &str = "\
repro — Online Alignment and Addition in Multi-Term FP Adders (reproduction)

commands:
  fig4    [--vectors 512] [--csv]         area/power of all 32-term BF16 configs
  fig5                                    area-vs-clock Pareto, 1-4 pipeline stages
  table1  [--n 16|32|64] [--vectors 512]  Table I rows with paper-vs-measured savings
  add     --format F --arch A x y z ...   one fused multi-term addition
  oracle  [--format F|all] [--vectors N] [--terms N] [--seed S]
                                          differential rounding oracle: fuzz
                                          adversarial operand distributions
                                          through every algorithm and diff
                                          against the independent reference
  backends [--format F] [--guard G]       list the reduction-backend
                                          registry with the capabilities
                                          each backend negotiates under the
                                          exact and truncated specs, plus
                                          the plans Auto-negotiation builds
  conform [--format F|all] [--vectors N] [--terms N] [--seed S]
                                          registry-driven conformance
                                          battery: every registered backend
                                          through the same equivalence /
                                          split-ingest / merge / codec /
                                          specials gates vs the scalar ⊙
                                          fold; exits nonzero on mismatch
  kernel  [--format F|all] [--n 1024] [--blocks 1,8,64,256] [--vectors 64]
                                          SoA-kernel equivalence + throughput:
                                          assert the batched kernel's
                                          [λ; acc; sticky] state bit-matches
                                          the scalar ⊙ fold per block size,
                                          and report the measured speedup
  eia     [--format F|all] [--n 1024] [--vectors 64] [--seed S]
                                          exponent-indexed accumulator
                                          check: assert the deferred-
                                          alignment drain bit-matches the
                                          scalar ⊙ fold, that split-merge
                                          snapshots (bytes round-tripped)
                                          equal one-shot banking, and
                                          report ingest/drain throughput
  sweep   --format F --n N [--clock 1.0]  raw design-space dump for any N
  dse     [--json] [--n 32] [--vectors 96] [--clock 1.0]
                                          serial-alignment baseline vs the
                                          online fused operator trees of
                                          radix 2/4/8 per paper format, at
                                          the paper pipeline-depth policy
                                          and one stage deeper, with
                                          workload-driven power; --json
                                          emits the byte-deterministic
                                          artifact DSE_report.json with
                                          per-format best savings flagged
                                          against the paper's 3-23 % area /
                                          4-26 % power bands
  stats   [--n 256] [--vectors 16] [--prometheus|--json|--trace|--provenance] [--selftest]
                                          exercise every registered backend,
                                          plan negotiation and the stream
                                          engine, then report the live
                                          cross-tier telemetry (DESIGN.md
                                          §Observability); --provenance
                                          prints the drained streams' audit
                                          records; --selftest exits nonzero
                                          if any expected metric family is
                                          dead, the trace ring records
                                          nothing, spans are unthreaded, or
                                          an injected panic leaves no
                                          flight-recorder postmortem
  analyze [--gate] [--json] [--netlist] [--fault NAME]
                                          static datapath width/overflow
                                          verifier (DESIGN.md §Analysis):
                                          derive the no-overflow obligation
                                          set for every format x backend and
                                          check it against the provisioned
                                          storage; --netlist appends the
                                          netlist tier (graph lints, STA,
                                          width-obligation bridge over the
                                          generated radix-N adder suite);
                                          --json emits the proof artifact
                                          ANALYSIS_report.json; --gate
                                          additionally exercises every
                                          backend and cross-checks telemetry
                                          maxima against the proved bounds;
                                          --fault injects a named storage or
                                          netlist fault (self-test; must
                                          fail)
  e2e     [--sentences 4] [--requests 256] PJRT BERT workload + batched serving demo
  serve   [--requests 2048] [--clients 8]  load-test the batched PJRT reduction path
  help                                    this text
";

fn coordinator(args: &Args) -> Result<Coordinator, String> {
    let threads = args.get_usize("threads", 0)?;
    Ok(if threads == 0 {
        Coordinator::default_parallelism()
    } else {
        Coordinator::new(threads)
    }
    .verbose(args.has("verbose")))
}

fn cmd_fig4(args: &Args) -> Result<(), String> {
    let vectors = args.get_usize("vectors", 512)?;
    let coord = coordinator(args)?;
    let (table, points) = report::fig4(vectors, &coord);
    println!("Fig. 4 — 32-term BFloat16 adders @ 1 GHz (paper §IV-A)\n");
    if args.has("csv") {
        print!("{}", table.to_csv());
    } else {
        println!("{}", table.render());
    }
    println!("{}", report::fig4_headline(&points));
    Ok(())
}

fn cmd_fig5(args: &Args) -> Result<(), String> {
    let coord = coordinator(args)?;
    println!("Fig. 5 — most area-efficient 32-term BFloat16 designs per clock target\n");
    let table = report::fig5(&coord);
    println!("{}", table.render());
    println!("{}", report::fig5_speed_headline(&coord));
    Ok(())
}

fn cmd_table1(args: &Args) -> Result<(), String> {
    let vectors = args.get_usize("vectors", 512)?;
    let coord = coordinator(args)?;
    let ns: Vec<u32> = match args.get("n") {
        Some(v) => vec![v.parse().map_err(|e| format!("--n: {e}"))?],
        None => vec![16, 32, 64],
    };
    for n in ns {
        println!("Table I — {n}-term adders (paper-vs-measured savings)\n");
        let (table, _) = report::table1(n, vectors, &coord);
        println!("{}", table.render());
    }
    Ok(())
}

fn cmd_add(args: &Args) -> Result<(), String> {
    let fmt = format_by_name(args.get_or("format", "bf16"))
        .ok_or_else(|| "unknown --format".to_string())?;
    let values: Vec<f64> = args.positional[1..]
        .iter()
        .map(|s| s.parse().map_err(|e| format!("bad value {s:?}: {e}")))
        .collect::<Result<_, _>>()?;
    if values.is_empty() {
        return Err("no values given".into());
    }
    let n = values.len().next_power_of_two().max(2);
    let arch = Architecture::parse(args.get_or("arch", "online"), n as u32)?;
    let adder = MultiTermAdder::exact(fmt, n, arch.clone());
    let terms: Vec<Fp> = values.iter().map(|&v| Fp::from_f64(v, fmt)).collect();
    let sum = adder.add(&terms);
    println!(
        "Σ ({} terms, {fmt}, {arch:?}) = {} (bits {:#x})",
        values.len(),
        sum.to_f64(),
        sum.bits
    );
    Ok(())
}

/// Differential rounding oracle (DESIGN.md §Oracle): fuzz adversarial
/// operand distributions — uniform full-range, subnormal-dense,
/// cancellation-heavy, mixed-sign near-overflow — through every algorithm
/// family under exact accumulator specs and diff bit-for-bit against the
/// independent sign-magnitude reference. Exits nonzero on any mismatch.
fn cmd_oracle(args: &Args) -> Result<(), String> {
    use online_fp_add::arith::oracle::{run_oracle, OracleConfig};
    use online_fp_add::formats::PAPER_FORMATS;

    let cfg = OracleConfig {
        vectors: args.get_usize("vectors", 2000)?,
        terms: args.get_usize("terms", 16)?,
        seed: args.get_u64("seed", 0x0D1F_F0DD)?,
    };
    if !cfg.terms.is_power_of_two() || cfg.terms < 4 {
        return Err(format!(
            "--terms {} must be a power of two >= 4 (so every radix tree applies)",
            cfg.terms
        ));
    }
    let fmts: Vec<online_fp_add::formats::FpFormat> = match args.get("format") {
        Some(name) if name != "all" => {
            vec![format_by_name(name).ok_or_else(|| "unknown --format".to_string())?]
        }
        _ => PAPER_FORMATS.to_vec(),
    };
    let mut table = online_fp_add::util::table::Table::new(vec![
        "format", "vectors", "exact checks", "mismatches", "trunc checks", "trunc max ulp",
    ]);
    let mut bad = 0usize;
    for fmt in fmts {
        let rep = run_oracle(fmt, &cfg);
        for mm in rep.mismatches.iter().take(3) {
            eprintln!(
                "MISMATCH {} [{}] {}: expected {:#x}, got {:#x}, terms {:x?}",
                mm.format,
                mm.distribution.name(),
                mm.arch,
                mm.expected_bits,
                mm.got_bits,
                mm.term_bits
            );
        }
        bad += rep.mismatches.len();
        table.row(vec![
            fmt.to_string(),
            rep.vectors.to_string(),
            rep.exact_checks.to_string(),
            rep.mismatches.len().to_string(),
            rep.truncated_checks.to_string(),
            rep.truncated_max_ulp.to_string(),
        ]);
    }
    println!("Differential rounding oracle — algorithms × formats vs independent reference\n");
    println!("{}", table.render());
    if bad > 0 {
        return Err(format!("{bad} exact-mode mismatches against the reference"));
    }
    println!("exact-mode datapaths bit-match the reference on every fuzzed vector ✓");
    Ok(())
}

/// List the reduction-backend registry (DESIGN.md §Reducer): every
/// registered backend with the capabilities it negotiates under the exact
/// spec of `--format` and under a truncated `--guard` spec, plus the plans
/// auto-negotiation builds — the inspectable replacement for the old
/// `ReduceBackend::Auto` hidden heuristics.
fn cmd_backends(args: &Args) -> Result<(), String> {
    use online_fp_add::arith::AccSpec;
    use online_fp_add::reduce::{registry, ReducePlan};

    let fmt = format_by_name(args.get_or("format", "bf16"))
        .ok_or_else(|| "unknown --format".to_string())?;
    let guard = args.get_usize("guard", 16)? as u32;
    let exact = AccSpec::exact(fmt);
    let trunc = AccSpec::truncated(guard);
    let mut table = online_fp_add::util::table::Table::new(vec![
        "backend", "spec", "fold bits", "order inv", "lossless merge", "block",
    ]);
    for entry in registry::entries() {
        let sel = entry.sel();
        for (label, spec) in [("exact", exact), ("truncated", trunc)] {
            let c = sel.capabilities(spec);
            table.row(vec![
                sel.to_string(),
                label.to_string(),
                c.fold_bit_identical.to_string(),
                c.order_invariant.to_string(),
                c.lossless_merge.to_string(),
                c.block.map(|b| b.to_string()).unwrap_or_else(|| "-".into()),
            ]);
        }
    }
    println!("Reduction-backend registry — capabilities per accumulator spec\n");
    println!("{}", table.render());
    for entry in registry::entries() {
        println!("  {:<8} {}", entry.name, entry.summary);
    }
    println!("\nnegotiated plans (the old `auto`):");
    println!("  exact({fmt}):   {}", ReducePlan::negotiate(exact).describe());
    println!("  truncated({guard}): {}", ReducePlan::negotiate(trunc).describe());
    Ok(())
}

/// Static datapath width/overflow verifier (DESIGN.md §Analysis): derive
/// the no-overflow obligation set for every paper format × registered
/// backend and check it against the provisioned storage, the registry's
/// published `Capabilities` widths and the `hw::datapath` geometry.
/// `--json` prints the byte-deterministic proof artifact and always exits
/// zero (CI diffs the bytes); the default and `--gate` modes exit nonzero
/// on any failed obligation, and `--gate` additionally drives every
/// backend over every oracle distribution and cross-checks the telemetry
/// occupancy / lane-width maxima against the statically proved bounds.
fn cmd_analyze(args: &Args) -> Result<(), String> {
    use online_fp_add::analysis::{self, netlist, StorageEnv};

    let with_netlist = args.has("netlist");
    let mut net_fault = None;
    let env = match args.get("fault") {
        Some(name) => match StorageEnv::with_fault(name) {
            Ok(env) => env,
            Err(e) => match netlist::NetlistFault::from_name(name) {
                Some(f) if with_netlist => {
                    net_fault = Some(f);
                    StorageEnv::actual()
                }
                Some(_) => {
                    return Err(format!(
                        "fault {name:?} targets the netlist tier; add --netlist"
                    ))
                }
                None => return Err(e),
            },
        },
        None => StorageEnv::actual(),
    };
    let report = if with_netlist {
        analysis::analyze_netlist(&env, net_fault)
    } else {
        analysis::analyze(&env)
    };

    if args.has("json") {
        // Machine mode: emit the artifact verbatim and let CI judge it —
        // a faulted report must still serialize so the self-test can
        // inspect it.
        print!("{}", report.to_json());
        return Ok(());
    }

    println!("Static datapath width/overflow proof — obligations per format x backend\n");
    print!("{}", report.render_table());
    let failed = report.failed();
    println!(
        "\n{} obligations, {} passed, {} failed (env: wide={} narrow={} bins={} clamp={})",
        report.obligations.len(),
        report.obligations.len() - failed.len(),
        failed.len(),
        env.wide_bits,
        env.narrow_bits,
        env.max_bins,
        env.shift_clamp,
    );

    if with_netlist {
        println!("\nSTA over the generated FP32 suite (N={}):", netlist::VERIFY_TERMS);
        for adder in
            online_fp_add::hw::generate::generate_suite(online_fp_add::formats::FP32, netlist::VERIFY_TERMS)
        {
            if let Some(s) = netlist::sta(&adder.nl) {
                println!(
                    "  {:<12} critical {:.2} ns  {}",
                    adder.config.to_string(),
                    s.critical,
                    s.path_name(&adder.nl)
                );
            }
        }
    }

    if args.has("gate") {
        let terms = args.get_usize("terms", 96)?.max(1);
        let vectors = args.get_usize("vectors", 4)?.max(1);
        let reduced = analysis::exercise_backends(terms, vectors);
        let bounds = analysis::runtime_check(&report, online_fp_add::telemetry::global());
        println!("\nruntime cross-check ({reduced} terms reduced across all backends):");
        let mut bad = 0usize;
        for b in &bounds {
            println!(
                "  {:<32} observed {:>8}  proved bound {:>8}  {}",
                b.name,
                b.observed,
                b.bound,
                if b.pass() { "ok" } else { "FAIL" }
            );
            if !b.pass() {
                bad += 1;
            }
        }
        if bad > 0 {
            return Err(format!("{bad} runtime bounds exceeded the proved widths"));
        }
    }

    if !failed.is_empty() {
        let ids: Vec<String> =
            failed.iter().map(|o| format!("{}/{}", o.format, o.id)).collect();
        return Err(format!(
            "{} width obligations failed: {}",
            failed.len(),
            ids.join(", ")
        ));
    }
    Ok(())
}

/// Registry-driven conformance battery (DESIGN.md §Reducer): every
/// registered backend through the same equivalence / split-ingest /
/// merge-associativity / partial-codec / special-value gates against the
/// scalar `⊙` fold. Exits nonzero on any failure — a backend added to the
/// registry is held to the contract automatically.
fn cmd_conform(args: &Args) -> Result<(), String> {
    use online_fp_add::formats::PAPER_FORMATS;
    use online_fp_add::reduce::conformance::{run_format, ConformanceConfig};

    let cfg = ConformanceConfig {
        vectors: args.get_usize("vectors", 20)?.max(1),
        max_terms: args.get_usize("terms", 96)?.max(1),
        seed: args.get_u64("seed", 0xC0F0_12ED)?,
    };
    let fmts: Vec<online_fp_add::formats::FpFormat> = match args.get("format") {
        Some(name) if name != "all" => {
            vec![format_by_name(name).ok_or_else(|| "unknown --format".to_string())?]
        }
        _ => PAPER_FORMATS.to_vec(),
    };
    let mut table = online_fp_add::util::table::Table::new(vec![
        "format", "backend", "checks", "reduce", "split", "merge", "codec", "specials",
    ]);
    let mut bad = 0u64;
    for fmt in fmts {
        for rep in run_format(fmt, &cfg) {
            bad += rep.failures();
            table.row(vec![
                fmt.to_string(),
                rep.backend.clone(),
                rep.checks.to_string(),
                rep.reduce_mismatches.to_string(),
                rep.split_mismatches.to_string(),
                rep.merge_mismatches.to_string(),
                rep.codec_failures.to_string(),
                rep.specials_failures.to_string(),
            ]);
        }
    }
    println!("Registry conformance battery — every backend vs the scalar ⊙ fold\n");
    println!("{}", table.render());
    if bad > 0 {
        return Err(format!("{bad} conformance failures"));
    }
    println!("every registered backend conforms on every gate ✓");
    Ok(())
}

/// SoA-kernel equivalence + throughput check (DESIGN.md §Kernel): fuzz the
/// oracle's adversarial operand distributions through kernel-backend plans
/// at several block sizes and through the scalar `⊙` fold's plan, assert
/// the `[λ; acc; sticky]` states are bit-identical (exact specs), and
/// report the measured throughput of both backends. Exits nonzero on any
/// mismatch.
fn cmd_kernel(args: &Args) -> Result<(), String> {
    use online_fp_add::arith::kernel::DEFAULT_BLOCK;
    use online_fp_add::arith::oracle::DISTRIBUTIONS;
    use online_fp_add::arith::AccSpec;
    use online_fp_add::formats::PAPER_FORMATS;
    use online_fp_add::reduce::{registry, ReducePlan};
    use online_fp_add::util::prng::XorShift;
    use std::time::Instant;

    let n = args.get_usize("n", 1024)?.max(1);
    let vectors = args.get_usize("vectors", 64)?.max(1);
    let seed = args.get_u64("seed", 0x50A0_0DD)?;
    let blocks: Vec<usize> = match args.get("blocks") {
        Some(list) => list
            .split(',')
            .map(|p| {
                p.trim()
                    .parse::<usize>()
                    .map_err(|e| format!("bad block {p:?}: {e}"))
                    .and_then(|b| if b == 0 { Err("block must be >= 1".into()) } else { Ok(b) })
            })
            .collect::<Result<_, _>>()?,
        None => vec![1, 8, DEFAULT_BLOCK, 256],
    };
    let fmts: Vec<online_fp_add::formats::FpFormat> = match args.get("format") {
        Some(name) if name != "all" => {
            vec![format_by_name(name).ok_or_else(|| "unknown --format".to_string())?]
        }
        _ => PAPER_FORMATS.to_vec(),
    };
    let mut table = online_fp_add::util::table::Table::new(vec![
        "format", "block", "scalar Mterms/s", "kernel Mterms/s", "speedup", "mismatches",
    ]);
    let mut bad = 0u64;
    for fmt in fmts {
        let spec = AccSpec::exact(fmt);
        let scalar_plan = ReducePlan::with_backend(spec, registry::sel("scalar")?);
        let mut rng =
            XorShift::new(seed ^ ((fmt.ebits as u64) << 32) ^ ((fmt.mbits as u64) << 40));
        let data: Vec<Vec<Fp>> = (0..vectors)
            .map(|v| DISTRIBUTIONS[v % DISTRIBUTIONS.len()].gen_vector(&mut rng, fmt, n))
            .collect();
        let t0 = Instant::now();
        let reference: Vec<_> = data.iter().map(|v| scalar_plan.reduce(v)).collect();
        let scalar_tput = (vectors * n) as f64 / t0.elapsed().as_secs_f64();
        for &block in &blocks {
            let plan = ReducePlan::builder(spec)
                .backend_name("kernel")
                .and_then(|b| b.block(block))
                .and_then(|b| b.build())?;
            let t0 = Instant::now();
            let got: Vec<_> = data.iter().map(|v| plan.reduce(v)).collect();
            let kernel_tput = (vectors * n) as f64 / t0.elapsed().as_secs_f64();
            let mismatches =
                got.iter().zip(&reference).filter(|(g, w)| g != w).count() as u64;
            bad += mismatches;
            table.row(vec![
                fmt.to_string(),
                block.to_string(),
                format!("{:.1}", scalar_tput / 1e6),
                format!("{:.1}", kernel_tput / 1e6),
                format!("{:.2}x", kernel_tput / scalar_tput),
                mismatches.to_string(),
            ]);
        }
    }
    println!(
        "SoA kernel vs scalar ⊙ fold — {vectors} adversarial vectors × {n} terms per format\n"
    );
    println!("{}", table.render());
    if bad > 0 {
        return Err(format!("{bad} kernel states differed from the scalar fold"));
    }
    println!("kernel [λ; acc; sticky] bit-matches the scalar fold on every vector ✓");
    Ok(())
}

/// Exponent-indexed accumulator check (DESIGN.md §Accumulator): fuzz the
/// oracle's adversarial operand distributions through the deferred-
/// alignment EIA backend, assert the drained `[λ; acc; sticky]` state
/// bit-matches the scalar `⊙` fold (exact specs), assert split-merge
/// snapshot banking (serialized to bytes and back) equals one-shot
/// banking, and report the measured throughput of both backends. Exits
/// nonzero on any mismatch.
fn cmd_eia(args: &Args) -> Result<(), String> {
    use online_fp_add::accum::{merge::snapshot_terms, EiaSnapshot};
    use online_fp_add::arith::oracle::DISTRIBUTIONS;
    use online_fp_add::arith::AccSpec;
    use online_fp_add::formats::PAPER_FORMATS;
    use online_fp_add::reduce::{registry, ReducePlan};
    use online_fp_add::util::prng::XorShift;
    use std::time::Instant;

    let n = args.get_usize("n", 1024)?.max(2);
    let vectors = args.get_usize("vectors", 64)?.max(1);
    let seed = args.get_u64("seed", 0xE1A_5EED)?;
    let fmts: Vec<online_fp_add::formats::FpFormat> = match args.get("format") {
        Some(name) if name != "all" => {
            vec![format_by_name(name).ok_or_else(|| "unknown --format".to_string())?]
        }
        _ => PAPER_FORMATS.to_vec(),
    };
    let mut table = online_fp_add::util::table::Table::new(vec![
        "format", "scalar Mterms/s", "eia Mterms/s", "speedup", "drain mism", "merge mism",
    ]);
    let mut bad = 0u64;
    for fmt in fmts {
        let spec = AccSpec::exact(fmt);
        let scalar_plan = ReducePlan::with_backend(spec, registry::sel("scalar")?);
        let eia_plan = ReducePlan::with_backend(spec, registry::sel("eia")?);
        let mut rng =
            XorShift::new(seed ^ ((fmt.ebits as u64) << 32) ^ ((fmt.mbits as u64) << 40));
        let data: Vec<Vec<Fp>> = (0..vectors)
            .map(|v| DISTRIBUTIONS[v % DISTRIBUTIONS.len()].gen_vector(&mut rng, fmt, n))
            .collect();
        let t0 = Instant::now();
        let reference: Vec<_> = data.iter().map(|v| scalar_plan.reduce(v)).collect();
        let scalar_tput = (vectors * n) as f64 / t0.elapsed().as_secs_f64();
        let t0 = Instant::now();
        let got: Vec<_> = data.iter().map(|v| eia_plan.reduce(v)).collect();
        let eia_tput = (vectors * n) as f64 / t0.elapsed().as_secs_f64();
        let drain_mismatches =
            got.iter().zip(&reference).filter(|(g, w)| g != w).count() as u64;
        // Split-merge reproducibility: banking each vector in two pieces,
        // shipping both snapshots through the byte codec and merging, must
        // equal one-shot banking — canonically (snapshot ==) and therefore
        // also after the drain.
        let mut merge_mismatches = 0u64;
        for (v, terms) in data.iter().enumerate() {
            let cut = 1 + (v * 7919) % (n - 1);
            let whole = snapshot_terms(terms);
            let halves = [&terms[..cut], &terms[cut..]].map(|half| {
                EiaSnapshot::from_bytes(&snapshot_terms(half).to_bytes())
                    .expect("valid checkpoint bytes")
            });
            if halves[0].merge(&halves[1]) != whole {
                merge_mismatches += 1;
            }
        }
        bad += drain_mismatches + merge_mismatches;
        table.row(vec![
            fmt.to_string(),
            format!("{:.1}", scalar_tput / 1e6),
            format!("{:.1}", eia_tput / 1e6),
            format!("{:.2}x", eia_tput / scalar_tput),
            drain_mismatches.to_string(),
            merge_mismatches.to_string(),
        ]);
    }
    println!(
        "EIA (deferred alignment) vs scalar ⊙ fold — {vectors} adversarial vectors × {n} terms per format\n"
    );
    println!("{}", table.render());
    if bad > 0 {
        return Err(format!("{bad} EIA states differed from the scalar fold / one-shot banking"));
    }
    println!("EIA drain bit-matches the scalar fold and split-merge banking on every vector ✓");
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<(), String> {
    let fmt = format_by_name(args.get_or("format", "bf16"))
        .ok_or_else(|| "unknown --format".to_string())?;
    let n = args.get_usize("n", 32)? as u32;
    let clock = args.get_f64("clock", 1.0)?;
    let coord = coordinator(args)?;
    let opts = SweepOptions { clock_ns: clock, ..Default::default() };
    let points = online_fp_add::dse::sweep_format(fmt, n, &opts, None, &coord);
    let mut t = online_fp_add::util::table::Table::new(vec![
        "config", "area µm²", "reg bits", "comb ns", "met clock",
    ]);
    for p in &points {
        t.row(vec![
            p.config.to_string(),
            format!("{:.0}", p.area_um2),
            p.reg_bits.to_string(),
            format!("{:.2}", p.comb_delay_ns),
            if p.feasible { "yes".into() } else { format!("min {:.2}", p.clock_ns) },
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

/// The DSE artifact (DESIGN.md §Analysis): evaluate the serial-alignment
/// baseline against the online fused operator trees of radix 2/4/8 for
/// every paper format, at the per-format pipeline-depth policy and one
/// stage deeper, with workload-driven power — then flag each format's best
/// savings against the paper's §IV-A bands. `--json` emits the
/// byte-deterministic `DSE_report.json`.
fn cmd_dse(args: &Args) -> Result<(), String> {
    use online_fp_add::dse::paper::{PAPER_AREA_BAND, PAPER_POWER_BAND};

    let n = args.get_usize("n", 32)?.max(2) as u32;
    let vectors = args.get_usize("vectors", 96)?.max(1);
    let clock = args.get_f64("clock", 1.0)?;
    let coord = coordinator(args)?;
    let report = online_fp_add::dse::dse_report(n, vectors, clock, &coord);
    if args.has("json") {
        print!("{}", report.to_json());
        return Ok(());
    }
    println!(
        "DSE — serial-alignment baseline vs online fused operator trees \
         (N={n}, {vectors} vectors, {clock:.2} ns target)\n"
    );
    let mut t = online_fp_add::util::table::Table::new(vec![
        "format", "config", "stages", "area µm²", "area Δ", "power mW", "power Δ", "met clk",
    ]);
    for r in &report.rows {
        t.row(vec![
            r.format.to_string(),
            r.config.clone(),
            r.stages.to_string(),
            format!("{:.0}", r.area_um2),
            format!("{:+.1}%", r.area_delta_pct),
            format!("{:.2}", r.power_mw),
            format!("{:+.1}%", r.power_delta_pct),
            if r.feasible { "yes".into() } else { format!("min {:.2} ns", r.clock_ns) },
        ]);
    }
    println!("{}", t.render());
    println!(
        "paper-savings summary (paper bands: area {:.0}-{:.0}%, power {:.0}-{:.0}%):",
        PAPER_AREA_BAND.0, PAPER_AREA_BAND.1, PAPER_POWER_BAND.0, PAPER_POWER_BAND.1
    );
    print!("{}", report.summary_lines());
    Ok(())
}

/// Live cross-tier telemetry (DESIGN.md §Observability): exercise every
/// registered backend through a full `Reducer` lifecycle, drive all four
/// plan-negotiation rationales, light the kernel/EIA numeric-health
/// counters with a crafted sticky pair, run a short multi-stream serving
/// session (including a wire-codec partial merge), then report the hub.
/// `--provenance` prints the drained streams' numeric audit records.
/// `--selftest` exits nonzero if any metric the workload is expected to
/// drive is absent or zero, if the (force-enabled) trace ring recorded
/// nothing or no record carries a span, or if an injected panic fails to
/// leave a flight-recorder postmortem — CI uses it as a liveness gate on
/// the instrumentation itself.
fn cmd_stats(args: &Args) -> Result<(), String> {
    use online_fp_add::arith::AccSpec;
    use online_fp_add::formats::BF16;
    use online_fp_add::reduce::{registry, Partial, ReducePlan, Reducer};
    use online_fp_add::stream::{EngineConfig, StreamService};
    use online_fp_add::telemetry::{self, MetricValue};
    use online_fp_add::util::prng::XorShift;

    let n = args.get_usize("n", 256)?.max(4);
    let vectors = args.get_usize("vectors", 16)?.max(1);
    if args.has("trace") || args.has("selftest") {
        telemetry::global().trace.set_enabled(true);
    }
    let exact = AccSpec::exact(BF16);
    let trunc = AccSpec::truncated(2);
    let mut rng = XorShift::new(0x57A7_5EED);

    // Every registered backend through the one-shot reduce path plus a full
    // split-ingest lifecycle (ingest / partial / codec roundtrip / absorb /
    // finish), so every per-backend `ofa_reduce_*` family has activity.
    for entry in registry::entries() {
        let plan = ReducePlan::with_backend(exact, entry.sel());
        for _ in 0..vectors {
            let terms: Vec<Fp> = (0..n).map(|_| rng.gen_fp_sparse(BF16, 0.2)).collect();
            let _ = plan.reduce(&terms);
            let mut head = plan.reducer();
            head.ingest(&terms[..n / 2]);
            let wire = head.partial().to_bytes();
            let partial = Partial::from_bytes(&wire).map_err(|e| format!("partial codec: {e}"))?;
            let mut rest = plan.reducer();
            rest.ingest(&terms[n / 2..]);
            rest.absorb(&partial);
            let _ = rest.finish();
        }
    }

    // All four plan rationales: the explicit plans above, plus the three
    // negotiation outcomes.
    let _ = ReducePlan::negotiate(exact);
    let _ = ReducePlan::negotiate(trunc);
    let eia_trunc = ReducePlan::builder(trunc)
        .require_order_invariant()
        .build()
        .map_err(|e| format!("order-invariant negotiation: {e}"))?;

    // Numeric-health probe: 2^20 + 1 in BF16 under a guard-2 frame drops
    // live low bits, so one kernel block sweep and one EIA drain must each
    // report sticky.
    let sticky_pair = [Fp::from_f64(1048576.0, BF16), Fp::from_f64(1.0, BF16)];
    let kernel_trunc = ReducePlan::with_backend(trunc, registry::sel("kernel")?);
    let _ = kernel_trunc.reduce(&sticky_pair);
    let _ = eia_trunc.reduce(&sticky_pair);

    // Streaming tier: a short multi-stream serving session, including one
    // cross-node partial merged in through the wire codec.
    let svc = StreamService::new(BF16, EngineConfig { spec: exact, ..Default::default() });
    for v in 0..vectors.max(4) {
        let terms: Vec<Fp> = (0..n).map(|_| rng.gen_fp_sparse(BF16, 0.2)).collect();
        svc.ingest(&format!("stats-{}", v % 4), terms)
            .map_err(|e| format!("stream ingest: {e:?}"))?;
    }
    {
        let mut peer = ReducePlan::with_backend(exact, registry::sel("eia")?).reducer();
        peer.ingest(&[Fp::from_f64(0.5, BF16)]);
        let wire = peer.partial().to_bytes();
        let partial = Partial::from_bytes(&wire).map_err(|e| format!("partial codec: {e}"))?;
        svc.engine().shards().merge_partial("stats-0", &partial);
    }
    let mut provenance = Vec::new();
    for v in 0..4 {
        if let Some((_, rec)) = svc.drain_with_provenance(&format!("stats-{v}")) {
            provenance.push(rec);
        }
    }

    let snap = svc.telemetry_snapshot();

    if args.has("selftest") {
        let mut dead: Vec<String> = Vec::new();
        for entry in registry::entries() {
            for name in [
                "ofa_reduce_ingest_calls",
                "ofa_reduce_ingest_terms",
                "ofa_reduce_absorbs",
                "ofa_reduce_finishes",
                "ofa_reduce_reduce_calls",
            ] {
                if snap.counter_labeled(name, "backend", entry.name) == 0 {
                    dead.push(format!("{name}{{backend=\"{}\"}}", entry.name));
                }
            }
        }
        // Everything the workload above is guaranteed to drive. Deliberate
        // omissions: spills / wide banks need crafted i128 snapshots (see
        // tests/telemetry.rs) and runtime counters need PJRT artifacts;
        // the trace ring and flight recorder are asserted separately below.
        const EXPECT_NONZERO: &[&str] = &[
            "ofa_plan_builds",
            "ofa_plan_explicit",
            "ofa_plan_negotiated_exact",
            "ofa_plan_negotiated_truncated",
            "ofa_plan_negotiated_order_invariant",
            "ofa_accum_drains",
            "ofa_accum_drain_bins",
            "ofa_accum_drain_sticky",
            "ofa_kernel_block_sweeps",
            "ofa_kernel_lanes",
            "ofa_kernel_narrow_blocks",
            "ofa_kernel_wide_blocks",
            "ofa_kernel_sticky_activations",
            "ofa_stream_batches",
            "ofa_stream_batch_terms",
            "ofa_stream_partial_merges",
            "ofa_stream_codec_bytes_out",
            "ofa_stream_codec_bytes_in",
            "ofa_stream_shard_merges",
            "ofa_stream_shard_terms",
            "ofa_service_batches",
            "ofa_service_ingested_terms",
            "ofa_service_segments",
            "ofa_service_merges",
            "ofa_service_drains",
        ];
        for name in EXPECT_NONZERO {
            if snap.counter(name) == 0 {
                dead.push((*name).to_string());
            }
        }
        if !dead.is_empty() {
            return Err(format!(
                "telemetry selftest: {} expected metric(s) absent or zero: {}",
                dead.len(),
                dead.join(", ")
            ));
        }
        // Span/trace liveness: the ring was force-enabled above, so the
        // serving session must have left span-tagged records behind.
        let ring = &telemetry::global().trace;
        let dump = ring.dump();
        if ring.total() == 0 || dump.is_empty() {
            return Err("telemetry selftest: trace ring enabled but recorded nothing".into());
        }
        if !dump.iter().any(|r| r.span.trace_id != 0) {
            return Err(
                "telemetry selftest: no trace record carries a span — span threading is dead"
                    .into(),
            );
        }
        // Flight-recorder liveness: an injected (and caught) panic must
        // leave a postmortem. Quiet the base hook first so the deliberate
        // panic does not spray a backtrace into CI logs; ours chains it.
        std::panic::set_hook(Box::new(|_| {}));
        telemetry::flight::install_panic_hook();
        let _ = std::panic::catch_unwind(|| panic!("stats selftest crash"));
        let _ = std::panic::take_hook();
        let path = telemetry::flight::dump_dir()
            .join(telemetry::flight::dump_file_name("panic: stats selftest crash"));
        let body = std::fs::read_to_string(&path).map_err(|e| {
            format!("telemetry selftest: no postmortem at {}: {e}", path.display())
        })?;
        if !body.contains("stats selftest crash") || !body.contains("\"trace_tail\"") {
            return Err(format!(
                "telemetry selftest: postmortem at {} lacks the panic reason or trace tail",
                path.display()
            ));
        }
        println!("telemetry selftest: every expected metric family is live ✓");
        println!(
            "telemetry selftest: trace ring live ({} records), spans threaded, \
             flight recorder dumped {} ✓",
            ring.total(),
            path.display()
        );
        return Ok(());
    }
    if args.has("provenance") {
        println!(
            "Numeric provenance — {} streams drained (DESIGN.md §Observability)\n",
            provenance.len()
        );
        for rec in &provenance {
            println!("{}\n", rec.render());
        }
        return Ok(());
    }
    if args.has("prometheus") {
        print!("{}", snap.to_prometheus());
        return Ok(());
    }
    if args.has("json") {
        println!("{}", snap.to_json());
        return Ok(());
    }
    let mut t = online_fp_add::util::table::Table::new(vec!["metric", "labels", "value"]);
    for s in &snap.samples {
        let labels =
            s.labels.iter().map(|(k, v)| format!("{k}={v}")).collect::<Vec<_>>().join(",");
        let value = match &s.value {
            MetricValue::Counter(v) => v.to_string(),
            MetricValue::Gauge(v) => v.to_string(),
            MetricValue::Histogram(h) => {
                format!("count={} sum={} min={} max={}", h.count, h.sum, h.min, h.max)
            }
        };
        t.row(vec![s.name.to_string(), labels, value]);
    }
    println!(
        "Live cross-tier telemetry — {} samples (DESIGN.md §Observability)\n",
        snap.samples.len()
    );
    println!("{}", t.render());
    if args.has("trace") {
        let ring = &telemetry::global().trace;
        println!("trace ring ({} events recorded):", ring.total());
        for span in ring.dump() {
            println!("  {span}");
        }
    }
    Ok(())
}

fn cmd_e2e(args: &Args) -> Result<(), String> {
    // The full PJRT path lives in the example so it is independently
    // runnable; keep the CLI thin by delegating.
    let _ = args;
    Err("use `cargo run --release --example bert_e2e` for the PJRT end-to-end demo".into())
}

/// Load-test the L3 serving path: concurrent clients firing random 32-term
/// BF16 reductions through the dynamic batcher into the PJRT artifact, with
/// bit-exact verification against the Rust model and a latency report.
fn cmd_serve(args: &Args) -> Result<(), String> {
    use online_fp_add::arith::tree::{tree_sum, RadixConfig};
    use online_fp_add::arith::AccSpec;
    use online_fp_add::coordinator::batcher::{Batcher, BatcherConfig};
    use online_fp_add::runtime::{OnlineReduceExe, Runtime};
    use online_fp_add::util::prng::XorShift;
    use std::time::{Duration, Instant};

    let requests = args.get_usize("requests", 2048)?;
    let clients = args.get_usize("clients", 8)?.max(1);
    let dir = Runtime::default_artifact_dir();
    if !dir.join("online_reduce_bf16_n32.hlo.txt").exists() {
        return Err("artifacts missing — run `make artifacts` first".into());
    }
    let n_terms = 32usize;
    let spec = AccSpec::truncated(16);
    let batcher = Batcher::spawn_with(
        BatcherConfig { n_terms, linger: Duration::from_micros(200), ..Default::default() },
        move || {
            let rt = Runtime::new(dir).expect("PJRT client");
            let exe = OnlineReduceExe::load_bf16_n32(&rt).expect("artifact");
            move |rows: &[(Vec<i32>, Vec<i32>)]| {
                let mut e_all = Vec::new();
                let mut m_all = Vec::new();
                for (e, m) in rows {
                    e_all.extend_from_slice(e);
                    m_all.extend_from_slice(m);
                }
                let out = exe.run(&rt, &e_all, &m_all).expect("pjrt execute");
                out.lambda.into_iter().zip(out.acc).collect::<Vec<_>>()
            }
        },
    );
    let handle = batcher.handle();
    let t0 = Instant::now();
    let per_client = requests / clients;
    let bad: usize = std::thread::scope(|scope| {
        (0..clients)
            .map(|c| {
                let h = handle.clone();
                scope.spawn(move || {
                    let mut rng = XorShift::new(0x5E21E ^ c as u64);
                    let mut bad = 0usize;
                    let cfg = RadixConfig::baseline(32);
                    for _ in 0..per_client {
                        let terms: Vec<online_fp_add::formats::Fp> = (0..n_terms)
                            .map(|_| rng.gen_fp_sparse(online_fp_add::formats::BF16, 0.1))
                            .collect();
                        // (effective exponent, signed significand) fields —
                        // subnormal lanes travel as (1, ±mantissa).
                        let e: Vec<i32> = terms.iter().map(|t| t.eff_exp()).collect();
                        let m: Vec<i32> = terms.iter().map(|t| t.signed_sig() as i32).collect();
                        match h.reduce(e, m) {
                            Ok(resp) => {
                                let want = tree_sum(&terms, &cfg, spec);
                                if resp.lambda != want.lambda
                                    || resp.acc != want.acc.to_i128() as i64
                                {
                                    bad += 1;
                                }
                            }
                            Err(_) => bad += 1,
                        }
                    }
                    bad
                })
            })
            .collect::<Vec<_>>()
            .into_iter()
            .map(|h| h.join().unwrap())
            .sum()
    });
    let served = per_client * clients;
    let dt = t0.elapsed().as_secs_f64();
    let met = batcher.metrics();
    println!("served {served} requests in {dt:.2}s  ({:.0} req/s, {clients} clients)", served as f64 / dt);
    println!("batches {} (mean fill {:.1}), rejected {}", met.batches.get(), met.mean_batch_fill(), met.rejected.get());
    println!("request latency: {}", met.latency.summary());
    println!("PJRT exec latency: {}", met.exec_latency.summary());
    if bad > 0 {
        return Err(format!("{bad} responses mismatched the bit-accurate model"));
    }
    println!("all responses bit-exact vs the Rust ⊙ tree ✓");
    Ok(())
}
