//! Chunked reduction: slices of terms become [`Segment`]s (an `AlignAcc`
//! plus its term count), and segments arriving **out of order** are merged
//! back into one state.
//!
//! This is the paper's associativity result (eq. 10) put to work for
//! streaming: because `⊙` is associative — and, in an exact accumulator
//! frame, commutative on the states it produces — a long sum can be split
//! at *any* chunk boundaries, reduced independently, and the partial states
//! merged in *any* arrival order without changing a single bit of the final
//! `(λ, acc, sticky)` state. Truncated frames keep associativity of the
//! merge but are sensitive to merge *order* in the dropped low bits; the
//! [`SegmentAssembler`] reorders segments by sequence number before merging
//! when the spec is not exact, giving a **single consumer** run-to-run
//! reproducibility either way. (The multi-threaded
//! [`crate::stream::StreamEngine`] merges in completion order and is
//! bit-deterministic only under exact specs — for deterministic truncated
//! replay, feed segments through an assembler instead.)

use crate::arith::operator::{op_combine, AlignAcc};
use crate::arith::AccSpec;
use crate::formats::Fp;
use crate::reduce::{Partial, ReducePlan};
use crate::telemetry::{self, TraceEvent};
use std::collections::BTreeMap;

/// One reduced chunk of a stream: the merged `[λ; o]` state of `terms`
/// input values. `Copy`, 64 bytes — cheap to ship between threads.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Segment {
    pub state: AlignAcc,
    pub terms: u64,
}

impl Segment {
    /// The empty segment (identity of the merge).
    pub const EMPTY: Segment = Segment { state: AlignAcc::IDENTITY, terms: 0 };

    /// Merge two segments with `⊙`.
    pub fn merge(&self, other: &Segment, spec: AccSpec) -> Segment {
        Segment {
            state: op_combine(&self.state, &other.state, spec),
            terms: self.terms + other.terms,
        }
    }

    /// Resolve a backend-agnostic [`Partial`] (e.g. deserialized from a
    /// peer shard through the unified codec) into a segment under `spec`.
    pub fn from_partial(partial: &Partial, spec: AccSpec) -> Segment {
        Segment { state: partial.resolve(spec), terms: partial.terms }
    }

    /// This segment as a mergeable, serializable [`Partial`].
    pub fn partial(&self) -> Partial {
        Partial::aligned(self.state, self.terms)
    }
}

/// Reduce one chunk of finite terms into a segment under an explicit
/// [`ReducePlan`]: on exact specs every registered backend resolves to the
/// same `[λ; acc; sticky]` bits as the scalar `⊙` fold (eq. 10), so the
/// plan's backend is a pure throughput knob there; on truncated specs the
/// backends drop different low bits (each deterministically) — pick one
/// plan and keep it for reproducible replay.
///
/// Like [`crate::arith::tree::tree_sum`], callers screen Inf/NaN first
/// (see [`crate::arith::adder`] for the screening rules).
pub fn reduce_chunk_with(plan: &ReducePlan, terms: &[Fp]) -> Segment {
    Segment { state: plan.reduce(terms), terms: terms.len() as u64 }
}

/// Reduce one chunk under the negotiated plan for `spec`
/// ([`ReducePlan::negotiate`]): the kernel for exact specs, the scalar
/// reference fold for truncated ones — bit-identical to the pre-kernel
/// serial fold in both cases.
pub fn reduce_chunk(terms: &[Fp], spec: AccSpec) -> Segment {
    reduce_chunk_with(&ReducePlan::negotiate(spec), terms)
}

/// Split `terms` at `chunk`-sized boundaries and reduce each chunk.
pub fn segment_terms(terms: &[Fp], chunk: usize, spec: AccSpec) -> Vec<Segment> {
    segment_terms_with(&ReducePlan::negotiate(spec), terms, chunk)
}

/// [`segment_terms`] with an explicit plan.
pub fn segment_terms_with(plan: &ReducePlan, terms: &[Fp], chunk: usize) -> Vec<Segment> {
    debug_assert!(chunk >= 1);
    terms.chunks(chunk.max(1)).map(|c| reduce_chunk_with(plan, c)).collect()
}

/// Reassembles a stream of sequence-numbered segments into one state,
/// tolerating out-of-order arrival.
///
/// * **Exact spec** — segments merge immediately on arrival; order cannot
///   change the result (eq. 10), so nothing is ever buffered.
/// * **Truncated spec** — segments are parked until their predecessors have
///   arrived and merged strictly in sequence order, making the dropped-bit
///   pattern (and therefore the final state) independent of arrival order.
pub struct SegmentAssembler {
    spec: AccSpec,
    merged: Segment,
    next_seq: u64,
    pending: BTreeMap<u64, Segment>,
    seen: std::collections::BTreeSet<u64>,
    merges: u64,
}

impl SegmentAssembler {
    pub fn new(spec: AccSpec) -> Self {
        SegmentAssembler {
            spec,
            merged: Segment::EMPTY,
            next_seq: 0,
            pending: BTreeMap::new(),
            seen: std::collections::BTreeSet::new(),
            merges: 0,
        }
    }

    /// Offer segment number `seq` (0-based, each number exactly once).
    ///
    /// Re-offering a sequence number is a caller bug (a retry would
    /// double-count the segment's terms in the sum) and panics loudly in
    /// both modes, release builds included.
    pub fn offer(&mut self, seq: u64, seg: Segment) {
        assert!(self.seen.insert(seq), "segment {seq} offered twice");
        let trace = &telemetry::global().trace;
        if self.spec.exact {
            trace.record(TraceEvent::SegmentOffered { seq, parked: false });
            self.merged = self.merged.merge(&seg, self.spec);
            self.merges += 1;
            trace.record(TraceEvent::SegmentMerged { seq });
            self.next_seq = self.next_seq.max(seq + 1);
            return;
        }
        trace.record(TraceEvent::SegmentOffered { seq, parked: seq != self.next_seq });
        self.pending.insert(seq, seg);
        while let Some(seg) = self.pending.remove(&self.next_seq) {
            self.merged = self.merged.merge(&seg, self.spec);
            self.merges += 1;
            trace.record(TraceEvent::SegmentMerged { seq: self.next_seq });
            self.next_seq += 1;
        }
    }

    /// The merged state over every segment consumed so far (for truncated
    /// specs: over the contiguous prefix that has fully arrived).
    pub fn state(&self) -> Segment {
        self.merged
    }

    /// Segments parked waiting for a predecessor (always 0 in exact mode).
    pub fn pending(&self) -> usize {
        self.pending.len()
    }

    /// Total segments merged into the state.
    pub fn merges(&self) -> u64 {
        self.merges
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::tree::{tree_sum, RadixConfig};
    use crate::formats::{Fp, BF16};
    use crate::util::prng::XorShift;

    fn random_terms(rng: &mut XorShift, n: usize) -> Vec<Fp> {
        (0..n).map(|_| rng.gen_fp_sparse(BF16, 0.15)).collect()
    }

    #[test]
    fn chunked_fold_matches_tree_sum_exact() {
        let spec = AccSpec::exact(BF16);
        let mut rng = XorShift::new(0x5E6);
        for n in [2usize, 5, 32, 100] {
            let terms = random_terms(&mut rng, n);
            let reference = tree_sum(&terms, &RadixConfig::baseline(n as u32), spec);
            for chunk in [1usize, 3, 8, 64] {
                let merged = segment_terms(&terms, chunk, spec)
                    .iter()
                    .fold(Segment::EMPTY, |a, s| a.merge(s, spec));
                assert_eq!(merged.state, reference, "n={n} chunk={chunk}");
                assert_eq!(merged.terms, n as u64);
            }
        }
    }

    #[test]
    fn every_registered_backend_produces_identical_segments_on_exact_specs() {
        use crate::reduce::registry;
        let spec = AccSpec::exact(BF16);
        let mut rng = XorShift::new(0x5E6C);
        for n in [1usize, 17, 64, 200] {
            let terms = random_terms(&mut rng, n);
            let want = reduce_chunk_with(
                &ReducePlan::with_backend(spec, registry::sel("scalar").unwrap()),
                &terms,
            );
            let mut plans: Vec<ReducePlan> = registry::entries()
                .iter()
                .map(|e| ReducePlan::with_backend(spec, e.sel()))
                .collect();
            plans.push(ReducePlan::with_backend(spec, registry::sel("kernel:3").unwrap()));
            plans.push(ReducePlan::negotiate(spec));
            for plan in &plans {
                let got = reduce_chunk_with(plan, &terms);
                assert_eq!(got, want, "n={n} backend={}", plan.backend());
            }
        }
    }

    #[test]
    fn exact_assembler_ignores_arrival_order() {
        let spec = AccSpec::exact(BF16);
        let mut rng = XorShift::new(0xA55);
        let terms = random_terms(&mut rng, 64);
        let segs = segment_terms(&terms, 7, spec);
        let mut in_order = SegmentAssembler::new(spec);
        for (i, s) in segs.iter().enumerate() {
            in_order.offer(i as u64, *s);
        }
        let mut order: Vec<usize> = (0..segs.len()).collect();
        rng.shuffle(&mut order);
        let mut shuffled = SegmentAssembler::new(spec);
        for &i in &order {
            shuffled.offer(i as u64, segs[i]);
        }
        assert_eq!(shuffled.state(), in_order.state());
        assert_eq!(shuffled.pending(), 0);
    }

    #[test]
    fn truncated_assembler_reorders_before_merging() {
        // With a narrow guard the merge order changes dropped bits, so the
        // assembler must produce the in-sequence result from any arrival
        // order — and hold incomplete suffixes back.
        let spec = AccSpec::truncated(3);
        let mut rng = XorShift::new(0x7D0);
        let terms = random_terms(&mut rng, 48);
        let segs = segment_terms(&terms, 5, spec);
        let mut reference = Segment::EMPTY;
        for s in &segs {
            reference = reference.merge(s, spec);
        }
        let mut order: Vec<usize> = (0..segs.len()).collect();
        rng.shuffle(&mut order);
        let mut asm = SegmentAssembler::new(spec);
        for &i in &order {
            asm.offer(i as u64, segs[i]);
        }
        assert_eq!(asm.state(), reference);
        assert_eq!(asm.merges(), segs.len() as u64);
        assert_eq!(asm.pending(), 0);
    }

    #[test]
    #[should_panic(expected = "offered twice")]
    fn duplicate_sequence_numbers_are_a_loud_error() {
        let spec = AccSpec::exact(BF16);
        let mut rng = XorShift::new(0xD0);
        let seg = reduce_chunk(&random_terms(&mut rng, 4), spec);
        let mut asm = SegmentAssembler::new(spec);
        asm.offer(0, seg);
        asm.offer(0, seg); // a retry must not silently double-count
    }

    #[test]
    fn truncated_assembler_parks_gapped_segments() {
        let spec = AccSpec::truncated(4);
        let mut rng = XorShift::new(0x9A9);
        let terms = random_terms(&mut rng, 30);
        let segs = segment_terms(&terms, 10, spec);
        let mut asm = SegmentAssembler::new(spec);
        asm.offer(2, segs[2]);
        assert_eq!(asm.pending(), 1);
        assert_eq!(asm.state().terms, 0);
        asm.offer(0, segs[0]);
        assert_eq!(asm.state().terms, 10);
        asm.offer(1, segs[1]);
        assert_eq!(asm.pending(), 0);
        assert_eq!(asm.state().terms, 30);
    }
}
