//! Request/response front-end over the [`StreamEngine`]: the serving
//! surface that turns traces into live traffic.
//!
//! Four verbs, mirroring what a reduction service owes its clients:
//!
//! * [`Request::Ingest`] — append a record batch to a named stream
//!   (non-finite values are saturated like the trace capture path does);
//! * [`Request::Query`] — the stream's current sum, **rounded once** into
//!   the service format via [`normalize_round`] (the paper's fused-add
//!   contract: one rounding over the whole history, not per batch; sums
//!   below the normal range denormalize gradually instead of flushing);
//! * [`Request::Checkpoint`] — the tiny copyable `(λ, acc, sticky, terms)`
//!   state, exact and mergeable;
//! * [`Request::Drain`] — finalize: remove the stream, return checkpoint
//!   and rounded value.

use super::engine::{EngineConfig, StreamEngine};
use super::shard::Snapshot;
use crate::arith::normalize::normalize_round;
use crate::arith::AccSpec;
use crate::coordinator::batcher::SubmitError;
use crate::formats::{Fp, FpFormat};
use crate::telemetry::{self, flight, LatencyFamily, ProvenanceRecord, TelemetrySnapshot};
use crate::workload::Trace;
use std::time::Instant;

/// One client request.
#[derive(Clone, Debug)]
pub enum Request {
    Ingest { stream: String, terms: Vec<Fp> },
    Query { stream: String },
    Checkpoint { stream: String },
    Drain { stream: String },
}

/// Why an ingest was refused.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IngestError {
    /// A term's format differs from the service format; accepting it would
    /// interpret its exponent in the wrong bias range and silently corrupt
    /// the stream, so the whole batch is rejected (checked in release
    /// builds, not just debug).
    FormatMismatch,
    /// Backpressure: the bounded queue is full.
    Overloaded,
    /// Engine shut down.
    Closed,
}

impl From<SubmitError> for IngestError {
    fn from(e: SubmitError) -> Self {
        match e {
            SubmitError::Overloaded => IngestError::Overloaded,
            SubmitError::Closed => IngestError::Closed,
        }
    }
}

/// The service's answer.
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    /// Batch accepted (`terms` values queued).
    Accepted { terms: usize },
    /// Batch refused: a term's format differs from the service format.
    FormatMismatch,
    /// Backpressure: the bounded queue is full, retry or shed load.
    Overloaded,
    /// Engine shut down.
    Closed,
    /// Stream does not exist (never ingested, or already drained).
    UnknownStream,
    /// Query result: the once-rounded sum plus the checkpoint it came from.
    Value { value: Fp, snapshot: Snapshot },
    /// Checkpoint result.
    Checkpointed(Snapshot),
    /// Drain result: final value and checkpoint; the stream is gone.
    Drained { value: Fp, snapshot: Snapshot },
}

/// A running streaming align-and-add service in one format.
pub struct StreamService {
    engine: StreamEngine,
    format: FpFormat,
    /// This format's slot in the hub-wide `ofa_stream_latency` SLO family.
    lat_slot: usize,
}

impl StreamService {
    /// A service with an explicit engine configuration. The config's
    /// [`AccSpec`] decides the rounding contract: with
    /// [`AccSpec::exact`]`(format)` every query is the correctly-rounded
    /// sum of the stream's entire history.
    pub fn new(format: FpFormat, cfg: EngineConfig) -> Self {
        let lat_slot = telemetry::global().latency.register_format(format.name);
        StreamService { engine: StreamEngine::new(cfg), format, lat_slot }
    }

    /// An exact-datapath service with default engine geometry.
    pub fn exact(format: FpFormat) -> Self {
        let cfg = EngineConfig { spec: AccSpec::exact(format), ..Default::default() };
        Self::new(format, cfg)
    }

    /// An exact-datapath service with an explicit chunk-reduction backend
    /// from the registry (see [`crate::reduce::BackendSel`]); with the
    /// exact spec every registered backend yields bit-identical stream
    /// states, so this picks throughput, not semantics.
    pub fn exact_with_backend(format: FpFormat, backend: crate::reduce::BackendSel) -> Self {
        let cfg = EngineConfig {
            spec: AccSpec::exact(format),
            backend: Some(backend),
            ..Default::default()
        };
        Self::new(format, cfg)
    }

    pub fn format(&self) -> FpFormat {
        self.format
    }

    pub fn engine(&self) -> &StreamEngine {
        &self.engine
    }

    /// Dispatch one request.
    pub fn handle(&self, req: Request) -> Response {
        match req {
            Request::Ingest { stream, terms } => match self.ingest(&stream, terms) {
                Ok(n) => Response::Accepted { terms: n },
                Err(IngestError::FormatMismatch) => Response::FormatMismatch,
                Err(IngestError::Overloaded) => Response::Overloaded,
                Err(IngestError::Closed) => Response::Closed,
            },
            Request::Query { stream } => match self.query(&stream) {
                Some((value, snapshot)) => Response::Value { value, snapshot },
                None => Response::UnknownStream,
            },
            Request::Checkpoint { stream } => match self.checkpoint(&stream) {
                Some(snap) => Response::Checkpointed(snap),
                None => Response::UnknownStream,
            },
            Request::Drain { stream } => match self.drain(&stream) {
                Some((value, snapshot)) => Response::Drained { value, snapshot },
                None => Response::UnknownStream,
            },
        }
    }

    /// Append a batch (non-blocking; `Overloaded` under backpressure).
    /// Terms must be in the service format; Inf/NaN lanes are
    /// saturated/zeroed ([`Fp::finite_or_saturated`]) before they reach
    /// the datapath, mirroring trace capture.
    pub fn ingest(&self, stream: &str, terms: Vec<Fp>) -> Result<usize, IngestError> {
        let start = Instant::now();
        let terms = screen(terms, self.format)?;
        let out = self.engine.ingest(stream, terms).map_err(IngestError::from);
        self.observe(LatencyFamily::OP_INGEST, start);
        out
    }

    /// Append a batch, blocking while the queue is full (trace replay).
    pub fn ingest_blocking(&self, stream: &str, terms: Vec<Fp>) -> Result<usize, IngestError> {
        let start = Instant::now();
        let terms = screen(terms, self.format)?;
        let out = self.engine.ingest_blocking(stream, terms).map_err(IngestError::from);
        self.observe(LatencyFamily::OP_INGEST, start);
        out
    }

    /// The stream's sum so far, rounded once into the service format, with
    /// the checkpoint it was rounded from. Waits for queued batches first.
    pub fn query(&self, stream: &str) -> Option<(Fp, Snapshot)> {
        let start = Instant::now();
        self.engine.quiesce();
        let snap = self.engine.snapshot(stream)?;
        let out = (self.round(&snap), snap);
        self.observe(LatencyFamily::OP_QUERY, start);
        Some(out)
    }

    /// [`Self::query`] plus the stream's [`ProvenanceRecord`]: the audit
    /// trail (spec, plan, work counts, numeric-health events, resolved
    /// state, order-invariant hash) behind the served value. The record is
    /// also noted in the flight recorder's in-flight ring so a later
    /// postmortem can explain what was being served.
    pub fn query_with_provenance(&self, stream: &str) -> Option<(Fp, ProvenanceRecord)> {
        let (value, snap) = self.query(stream)?;
        let rec = self.provenance(stream, &snap);
        flight::note_provenance(&rec);
        Some((value, rec))
    }

    /// The stream's exact mergeable state. Waits for queued batches first.
    pub fn checkpoint(&self, stream: &str) -> Option<Snapshot> {
        self.engine.quiesce();
        self.engine.snapshot(stream)
    }

    /// Finalize a stream: wait, remove, and return `(value, checkpoint)`.
    pub fn drain(&self, stream: &str) -> Option<(Fp, Snapshot)> {
        let start = Instant::now();
        self.engine.quiesce();
        let snap = self.engine.drain(stream)?;
        let out = (self.round(&snap), snap);
        self.observe(LatencyFamily::OP_DRAIN, start);
        Some(out)
    }

    /// [`Self::drain`] plus the final [`ProvenanceRecord`] — the complete
    /// audit trail of the finalized stream (the record is cut from the
    /// drained checkpoint, after the stream is gone).
    pub fn drain_with_provenance(&self, stream: &str) -> Option<(Fp, ProvenanceRecord)> {
        let start = Instant::now();
        self.engine.quiesce();
        let snap = self.engine.drain(stream)?;
        let value = self.round(&snap);
        self.observe(LatencyFamily::OP_DRAIN, start);
        let rec = self.provenance(stream, &snap);
        flight::note_provenance(&rec);
        Some((value, rec))
    }

    /// Cut a provenance record for `stream` from a checkpoint of it.
    fn provenance(&self, stream: &str, snap: &Snapshot) -> ProvenanceRecord {
        let plan = self.engine.plan();
        let hub = telemetry::global();
        ProvenanceRecord::new(
            stream,
            self.format.name,
            plan.spec(),
            plan.backend().name(),
            plan.rationale(),
            snap.terms,
            snap.segments,
            self.engine.metrics().merges.get(),
            hub.kernel.sticky_activations.get() + hub.accum.drain_sticky.get(),
            hub.accum.spills.get(),
            snap.lambda,
            snap.acc,
            snap.sticky,
        )
    }

    fn observe(&self, op: usize, start: Instant) {
        if telemetry::enabled() {
            telemetry::global().latency.observe(self.lat_slot, op, start.elapsed());
        }
    }

    /// Replay a workload trace as live traffic: row `i` goes to stream
    /// `"{prefix}-{i % streams}"`. Returns total terms ingested. This is
    /// how the BERT partial-product traces become serving load
    /// (`examples/stream_serve.rs`).
    pub fn replay_trace(&self, prefix: &str, trace: &Trace, streams: usize) -> u64 {
        let streams = streams.max(1);
        let mut total = 0u64;
        for (i, row) in trace.vectors.iter().enumerate() {
            let id = format!("{prefix}-{}", i % streams);
            if let Ok(n) = self.ingest_blocking(&id, row.clone()) {
                total += n as u64;
            }
        }
        total
    }

    fn round(&self, snap: &Snapshot) -> Fp {
        normalize_round(&snap.state(), self.engine.config().spec, self.format)
    }

    /// The full telemetry picture as seen from this service: the global
    /// cross-tier hub ([`crate::telemetry::TELEMETRY`]) plus this engine's
    /// own counters appended as `ofa_service_*` samples labeled with the
    /// service format — so one scrape answers both "what is the reduction
    /// stack doing" and "what is *this* serving front-end doing".
    pub fn telemetry_snapshot(&self) -> TelemetrySnapshot {
        let mut snap = telemetry::global().snapshot();
        let fmt = || vec![("format", self.format.name.to_string())];
        let m = self.engine.metrics();
        snap.push_counter("ofa_service_batches", fmt(), m.batches.get());
        snap.push_counter("ofa_service_ingested_terms", fmt(), m.ingested_terms.get());
        snap.push_counter("ofa_service_segments", fmt(), m.segments.get());
        snap.push_counter("ofa_service_merges", fmt(), m.merges.get());
        snap.push_counter("ofa_service_rejected", fmt(), m.rejected.get());
        snap.push_counter("ofa_service_drains", fmt(), m.drains.get());
        snap.push_histogram("ofa_service_ingest_latency_us", fmt(), m.ingest_latency.snapshot());
        snap
    }

    /// [`Self::telemetry_snapshot`] rendered as Prometheus text exposition.
    pub fn stats_prometheus(&self) -> String {
        self.telemetry_snapshot().to_prometheus()
    }

    /// [`Self::telemetry_snapshot`] rendered as JSON.
    pub fn stats_json(&self) -> String {
        self.telemetry_snapshot().to_json()
    }
}

fn screen(mut terms: Vec<Fp>, format: FpFormat) -> Result<Vec<Fp>, IngestError> {
    for t in terms.iter_mut() {
        if t.format != format {
            return Err(IngestError::FormatMismatch);
        }
        *t = t.finite_or_saturated();
    }
    Ok(terms)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::exact::exact_rounded_sum;
    use crate::formats::{FpClass, BF16};
    use crate::util::prng::XorShift;

    fn service() -> StreamService {
        StreamService::exact(BF16)
    }

    #[test]
    fn query_is_the_correctly_rounded_sum_of_the_history() {
        let svc = service();
        let mut rng = XorShift::new(0x51C);
        let mut all = Vec::new();
        for _ in 0..16 {
            let batch: Vec<Fp> = (0..24).map(|_| rng.gen_fp_sparse(BF16, 0.1)).collect();
            all.extend_from_slice(&batch);
            svc.ingest_blocking("q", batch).unwrap();
        }
        let (value, snap) = svc.query("q").unwrap();
        assert_eq!(value.bits, exact_rounded_sum(&all, BF16).bits);
        assert_eq!(snap.terms, all.len() as u64);
        // Query is read-only: asking again gives the same answer.
        assert_eq!(svc.query("q").unwrap().0.bits, value.bits);
    }

    #[test]
    fn request_response_roundtrip() {
        let svc = service();
        let one = Fp::from_f64(1.0, BF16);
        let r = svc.handle(Request::Ingest {
            stream: "r".into(),
            terms: vec![one; 3],
        });
        assert_eq!(r, Response::Accepted { terms: 3 });
        match svc.handle(Request::Query { stream: "r".into() }) {
            Response::Value { value, snapshot } => {
                assert_eq!(value.to_f64(), 3.0);
                assert_eq!(snapshot.terms, 3);
            }
            other => panic!("unexpected {other:?}"),
        }
        match svc.handle(Request::Drain { stream: "r".into() }) {
            Response::Drained { value, .. } => assert_eq!(value.to_f64(), 3.0),
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(
            svc.handle(Request::Query { stream: "r".into() }),
            Response::UnknownStream
        );
    }

    #[test]
    fn checkpoint_restores_into_a_fresh_service() {
        let svc = service();
        let mut rng = XorShift::new(0xC4E);
        let batch: Vec<Fp> = (0..40).map(|_| rng.gen_fp_sparse(BF16, 0.1)).collect();
        svc.ingest_blocking("s", batch.clone()).unwrap();
        let snap = svc.checkpoint("s").unwrap();
        // Restore: merge the checkpoint into a brand-new engine's shard map
        // and continue ingesting there.
        let svc2 = service();
        svc2.engine().shards().merge("s", snap.segment());
        let more: Vec<Fp> = (0..8).map(|_| rng.gen_fp_sparse(BF16, 0.1)).collect();
        svc2.ingest_blocking("s", more.clone()).unwrap();
        let (value, snap2) = svc2.query("s").unwrap();
        let mut all = batch;
        all.extend_from_slice(&more);
        assert_eq!(value.bits, exact_rounded_sum(&all, BF16).bits);
        assert_eq!(snap2.terms, 48);
    }

    #[test]
    fn foreign_format_batches_are_rejected_not_corrupting() {
        let svc = service(); // BF16
        let fp32 = Fp::from_f64(1.0, crate::formats::FP32);
        assert_eq!(
            svc.ingest_blocking("s", vec![fp32]),
            Err(IngestError::FormatMismatch)
        );
        assert_eq!(
            svc.handle(Request::Ingest { stream: "s".into(), terms: vec![fp32] }),
            Response::FormatMismatch
        );
        // Nothing was created: the stream never existed.
        assert!(svc.query("s").is_none());
    }

    #[test]
    fn non_finite_lanes_are_screened() {
        let svc = service();
        let inf = Fp::overflow(false, BF16);
        let nan = Fp::nan(BF16);
        svc.ingest_blocking("s", vec![inf, nan, Fp::from_f64(2.0, BF16)]).unwrap();
        let (value, _) = svc.query("s").unwrap();
        // Inf saturates to max-finite, NaN drops to zero: result is finite.
        assert!(matches!(value.class(), FpClass::Normal));
    }

    #[test]
    fn query_denormalizes_gradually_on_underflowed_streams() {
        use crate::formats::FP32;
        let svc = StreamService::exact(FP32);
        let tiny = Fp::pack(false, 1, 0, FP32); // 2^-126
        let minus_1p5 = Fp::pack(true, 1, 1 << 22, FP32); // -1.5·2^-126
        svc.ingest_blocking("u", vec![tiny, minus_1p5]).unwrap();
        let (value, _) = svc.query("u").unwrap();
        // The round-once query result is the exact subnormal -0.5·2^-126.
        assert_eq!(value.class(), FpClass::Subnormal);
        assert!(value.sign());
        assert_eq!((value.raw_exp(), value.mant()), (0, 1 << 22));
        // Further subnormal ingests accumulate exactly and climb back into
        // the normal range: -0.5·2^-126 + 3·(0.5·2^-126) = 2^-126.
        let half_min = Fp::pack(false, 0, 1 << 22, FP32);
        svc.ingest_blocking("u", vec![half_min, half_min, half_min]).unwrap();
        let (value, _) = svc.query("u").unwrap();
        assert_eq!(value.class(), FpClass::Normal);
        assert_eq!((value.raw_exp(), value.mant()), (1, 0));
    }

    #[test]
    fn service_samples_ride_the_telemetry_snapshot_with_a_format_label() {
        // Only the per-engine `ofa_service_*` samples are asserted — they
        // come from this service's own metrics, so parallel tests touching
        // the global hub cannot perturb them.
        let svc = service();
        let one = Fp::from_f64(1.0, BF16);
        svc.ingest_blocking("t", vec![one; 5]).unwrap();
        svc.query("t").unwrap();
        let snap = svc.telemetry_snapshot();
        assert_eq!(snap.counter_labeled("ofa_service_batches", "format", "BF16"), 1);
        assert_eq!(snap.counter_labeled("ofa_service_ingested_terms", "format", "BF16"), 5);
        let prom = svc.stats_prometheus();
        assert!(prom.contains("ofa_service_batches_total{format=\"BF16\"} 1"), "{prom}");
        assert!(svc.stats_json().contains("\"ofa_service_ingested_terms\""));
    }

    #[test]
    fn provenance_rides_query_and_drain_and_matches_the_value_facts() {
        use crate::telemetry::provenance_hash;
        let svc = service();
        let one = Fp::from_f64(1.0, BF16);
        svc.ingest_blocking("p", vec![one; 6]).unwrap();
        let (value, rec) = svc.query_with_provenance("p").unwrap();
        assert_eq!(value.to_f64(), 6.0);
        assert_eq!(rec.stream, "p");
        assert_eq!(rec.format, BF16.name);
        assert_eq!(rec.terms, 6);
        assert!(rec.exact);
        let spec = svc.engine().config().spec;
        assert_eq!(
            rec.hash,
            provenance_hash(BF16.name, spec, rec.terms, rec.lambda, &rec.acc, rec.sticky)
        );
        // Drain cuts the same value facts, so the same hash.
        let (dvalue, drec) = svc.drain_with_provenance("p").unwrap();
        assert_eq!(dvalue.bits, value.bits);
        assert_eq!(drec.hash, rec.hash);
        assert!(svc.query_with_provenance("p").is_none());
    }

    #[test]
    fn replay_fans_rows_out_over_streams() {
        let trace = crate::workload::bert::power_trace(BF16, 16, 30, 0xBEEF);
        let svc = service();
        let total = svc.replay_trace("bert", &trace, 4);
        assert_eq!(total, 30 * 16);
        let mut ids = svc.engine().shards().stream_ids();
        ids.sort();
        assert_eq!(ids, vec!["bert-0", "bert-1", "bert-2", "bert-3"]);
        let terms: u64 = ids
            .iter()
            .map(|id| svc.query(id).unwrap().1.terms)
            .sum();
        assert_eq!(terms, total);
    }
}
