//! Sharded stream state: a striped-lock map from stream id to its merged
//! [`Segment`] state, with tiny copyable snapshots and cross-shard merge.
//!
//! Striping bounds contention: a stream id hashes to one of `stripes`
//! mutex-guarded tables, so concurrent merges to *different* streams almost
//! never serialize, while merges to the *same* stream are ordered by its
//! stripe lock (which is all exact-mode `⊙` needs — any order, same bits).

use super::segment::Segment;
use crate::arith::operator::AlignAcc;
use crate::arith::{AccSpec, WideInt};
use crate::reduce::Partial;
use crate::telemetry::{self, TraceEvent, SHARD_SLOTS};
use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::{Mutex, MutexGuard, PoisonError};

type Stripe = Mutex<HashMap<String, StreamState>>;

/// Poison-tolerant stripe lock: a panic elsewhere must not cascade into
/// every later merge/snapshot (states are assigned whole, never torn).
fn lock(stripe: &Stripe) -> MutexGuard<'_, HashMap<String, StreamState>> {
    stripe.lock().unwrap_or_else(PoisonError::into_inner)
}

/// A copyable checkpoint of one stream: the full `(λ, acc, sticky)`
/// alignment state plus how many terms it covers. 64 bytes, `Copy` — cheap
/// to hand to clients, persist, or merge back in later.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Snapshot {
    pub lambda: i32,
    pub acc: WideInt,
    pub sticky: bool,
    pub terms: u64,
    /// How many segment merges produced this state.
    pub segments: u64,
}

impl Snapshot {
    /// The alignment state this checkpoint captures.
    pub fn state(&self) -> AlignAcc {
        AlignAcc { lambda: self.lambda, acc: self.acc, sticky: self.sticky }
    }

    /// Re-enter the operator domain as a segment (for restore/merge).
    pub fn segment(&self) -> Segment {
        Segment { state: self.state(), terms: self.terms }
    }

    /// This checkpoint as a backend-agnostic, wire-serializable
    /// [`Partial`] (see [`Partial::to_bytes`]).
    pub fn partial(&self) -> Partial {
        self.segment().partial()
    }
}

/// Per-stream accumulated state.
#[derive(Clone, Copy, Debug)]
struct StreamState {
    seg: Segment,
    segments: u64,
}

/// Striped-lock map from stream id to merged stream state.
pub struct ShardMap {
    stripes: Vec<Stripe>,
    spec: AccSpec,
}

impl ShardMap {
    /// `stripes` is rounded up to at least 1.
    pub fn new(stripes: usize, spec: AccSpec) -> Self {
        let stripes = stripes.max(1);
        ShardMap { stripes: (0..stripes).map(|_| Mutex::new(HashMap::new())).collect(), spec }
    }

    pub fn spec(&self) -> AccSpec {
        self.spec
    }

    pub fn stripes(&self) -> usize {
        self.stripes.len()
    }

    fn stripe_index(&self, id: &str) -> usize {
        let mut h = DefaultHasher::new();
        id.hash(&mut h);
        (h.finish() as usize) % self.stripes.len()
    }

    fn stripe_for(&self, id: &str) -> &Stripe {
        &self.stripes[self.stripe_index(id)]
    }

    /// Merge one segment into `id`'s state (creating the stream on first
    /// touch). Returns the stream's new term count.
    pub fn merge(&self, id: &str, seg: Segment) -> u64 {
        let stripe = self.stripe_index(id);
        if telemetry::enabled() {
            let s = &telemetry::global().stream;
            s.shard_merges[stripe % SHARD_SLOTS].inc();
            s.shard_terms[stripe % SHARD_SLOTS].add(seg.terms);
        }
        // Span-tagged via the caller's ambient span (the worker batch),
        // tying the stripe merge into the stream's causal trace.
        telemetry::global()
            .trace
            .record(TraceEvent::ShardMerged { stripe, terms: seg.terms });
        let mut table = lock(&self.stripes[stripe]);
        match table.get_mut(id) {
            Some(st) => {
                st.seg = st.seg.merge(&seg, self.spec);
                st.segments += 1;
                st.seg.terms
            }
            None => {
                table.insert(id.to_string(), StreamState { seg, segments: 1 });
                seg.terms
            }
        }
    }

    /// Merge a backend-agnostic [`Partial`] (e.g. deserialized from a peer
    /// shard via [`Partial::from_bytes`] — the **one** wire codec,
    /// whichever backend produced the state) into `id`'s stream state: the
    /// partial resolves under this map's spec and merges as an ordinary
    /// segment. Under an exact spec this is bit-identical to having
    /// ingested the partial's terms into this map directly — deferred
    /// partials drain to the scalar `⊙` fold's bits, and `⊙` is
    /// associative (eq. 10). Returns the stream's new term count.
    pub fn merge_partial(&self, id: &str, partial: &Partial) -> u64 {
        if telemetry::enabled() {
            telemetry::global().stream.partial_merges.inc();
        }
        self.merge(id, Segment::from_partial(partial, self.spec))
    }

    /// Copy out `id`'s current checkpoint, if the stream exists.
    pub fn snapshot(&self, id: &str) -> Option<Snapshot> {
        let table = lock(self.stripe_for(id));
        table.get(id).map(snapshot_of)
    }

    /// Remove `id` and return its final checkpoint.
    pub fn drain(&self, id: &str) -> Option<Snapshot> {
        let mut table = lock(self.stripe_for(id));
        table.remove(id).map(|st| snapshot_of(&st))
    }

    /// Number of live streams.
    pub fn len(&self) -> usize {
        self.stripes.iter().map(|s| lock(s).len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// All live stream ids (unordered).
    pub fn stream_ids(&self) -> Vec<String> {
        let mut out = Vec::new();
        for stripe in &self.stripes {
            out.extend(lock(stripe).keys().cloned());
        }
        out
    }

    /// Cross-shard merge: fold every stream of `other` into this map
    /// (matching stream ids combine with `⊙`). This is how per-worker or
    /// per-node shard maps collapse into a global one — associativity makes
    /// the grouping immaterial in exact mode.
    ///
    /// Each source stripe is copied out (states are `Copy`) before any
    /// destination lock is taken, so two maps merging from each other
    /// concurrently cannot ABBA-deadlock; concurrent writes to `other`
    /// land either before or after the per-stripe copy.
    pub fn merge_from(&self, other: &ShardMap) {
        debug_assert_eq!(self.spec, other.spec, "shard maps must share an AccSpec");
        for stripe in &other.stripes {
            let entries: Vec<(String, StreamState)> = {
                let table = lock(stripe);
                table.iter().map(|(id, st)| (id.clone(), *st)).collect()
            };
            for (id, st) in entries {
                let mut mine = lock(self.stripe_for(&id));
                match mine.get_mut(&id) {
                    Some(dst) => {
                        dst.seg = dst.seg.merge(&st.seg, self.spec);
                        dst.segments += st.segments;
                    }
                    None => {
                        mine.insert(id, st);
                    }
                }
            }
        }
    }
}

fn snapshot_of(st: &StreamState) -> Snapshot {
    Snapshot {
        lambda: st.seg.state.lambda,
        acc: st.seg.state.acc,
        sticky: st.seg.state.sticky,
        terms: st.seg.terms,
        segments: st.segments,
    }
}

#[cfg(test)]
mod tests {
    use super::super::segment::reduce_chunk;
    use super::*;
    use crate::formats::{Fp, BF16};
    use crate::util::prng::XorShift;

    fn seg(rng: &mut XorShift, n: usize, spec: AccSpec) -> Segment {
        let terms: Vec<Fp> = (0..n).map(|_| rng.gen_fp_sparse(BF16, 0.1)).collect();
        reduce_chunk(&terms, spec)
    }

    #[test]
    fn merge_snapshot_drain_roundtrip() {
        let spec = AccSpec::exact(BF16);
        let map = ShardMap::new(4, spec);
        let mut rng = XorShift::new(1);
        let (a, b) = (seg(&mut rng, 8, spec), seg(&mut rng, 8, spec));
        assert_eq!(map.merge("s", a), 8);
        assert_eq!(map.merge("s", b), 16);
        let snap = map.snapshot("s").unwrap();
        assert_eq!(snap.segment(), a.merge(&b, spec));
        assert_eq!(snap.segments, 2);
        assert_eq!(map.len(), 1);
        assert_eq!(map.drain("s").unwrap(), snap);
        assert!(map.is_empty());
        assert!(map.snapshot("s").is_none());
    }

    #[test]
    fn streams_are_isolated_across_stripes() {
        let spec = AccSpec::exact(BF16);
        let map = ShardMap::new(3, spec);
        let mut rng = XorShift::new(2);
        let segs: Vec<Segment> = (0..20).map(|_| seg(&mut rng, 4, spec)).collect();
        for (i, s) in segs.iter().enumerate() {
            map.merge(&format!("stream-{i}"), *s);
        }
        assert_eq!(map.len(), 20);
        let mut ids = map.stream_ids();
        ids.sort();
        assert_eq!(ids.len(), 20);
        for (i, s) in segs.iter().enumerate() {
            assert_eq!(map.snapshot(&format!("stream-{i}")).unwrap().segment(), *s);
        }
    }

    #[test]
    fn partials_from_any_backend_serialize_and_merge_across_shards() {
        use crate::reduce::{registry, ReducePlan, Reducer};
        let spec = AccSpec::exact(BF16);
        let mut rng = XorShift::new(4);
        let terms: Vec<Fp> = (0..120).map(|_| rng.gen_fp_sparse(BF16, 0.1)).collect();
        // Reference: the whole vector ingested directly as one segment.
        let reference = ShardMap::new(2, spec);
        reference.merge("s", reduce_chunk(&terms, spec));
        // Two worker shards reduce disjoint halves — each with a
        // *different* backend — ship their partials as bytes through the
        // one unified codec, and the destination merges the deserialized
        // states: same stream, same bits. (This used to need a dedicated
        // `merge_eia` special case.)
        let dst = ShardMap::new(4, spec);
        for (half, backend) in [(&terms[..53], "eia"), (&terms[53..], "kernel")] {
            let plan = ReducePlan::with_backend(spec, registry::sel(backend).unwrap());
            let mut reducer = plan.reducer();
            reducer.ingest(half);
            let wire = reducer.partial().to_bytes();
            let partial = Partial::from_bytes(&wire).expect("valid partial");
            dst.merge_partial("s", &partial);
        }
        let (want, got) = (reference.snapshot("s").unwrap(), dst.snapshot("s").unwrap());
        assert_eq!(got.state(), want.state());
        assert_eq!(got.terms, want.terms);
    }

    #[test]
    fn cross_shard_merge_equals_single_map() {
        let spec = AccSpec::exact(BF16);
        let mut rng = XorShift::new(3);
        let segs: Vec<Segment> = (0..12).map(|_| seg(&mut rng, 16, spec)).collect();
        // One global map vs two worker-local maps merged afterwards.
        let global = ShardMap::new(4, spec);
        let (left, right) = (ShardMap::new(2, spec), ShardMap::new(8, spec));
        for (i, s) in segs.iter().enumerate() {
            let id = format!("s{}", i % 3);
            global.merge(&id, *s);
            let _ = if i % 2 == 0 { left.merge(&id, *s) } else { right.merge(&id, *s) };
        }
        left.merge_from(&right);
        for id in ["s0", "s1", "s2"] {
            let (g, l) = (global.snapshot(id).unwrap(), left.snapshot(id).unwrap());
            assert_eq!(g.state(), l.state(), "{id}");
            assert_eq!(g.terms, l.terms, "{id}");
        }
    }
}
