//! Streaming align-and-add reduction: the serving tier.
//!
//! Everything below rides on one fact from the paper: the fused
//! align-and-add operator `⊙` (eq. 8) is **associative** (eq. 10), so a
//! multi-term sum splits across any parenthesisation — and therefore
//! across chunks ([`segment`]), across threads and shards ([`shard`],
//! [`engine`]), and across *time*: a stream's partial state is a complete,
//! mergeable summary of every term it has absorbed, never a rounded
//! intermediate. Related streaming-summation work (exponent-indexed
//! accumulators, chunk-parallel reproducible sums) frames long-running FP
//! aggregation exactly this way; here the mergeable state is the paper's
//! own `[λ; o]` vector.
//!
//! Layering, bottom up:
//!
//! * [`segment`] — chunked reduction of term slices into [`segment::Segment`]
//!   partial states; out-of-order reassembly ([`segment::SegmentAssembler`]).
//! * [`shard`] — striped-lock map from stream id to merged state, with
//!   copyable [`shard::Snapshot`] checkpoints and cross-shard merge.
//! * [`engine`] — a multi-threaded ingest pipeline on
//!   [`crate::coordinator::pool::ThreadPool`] with bounded-queue
//!   backpressure and [`crate::coordinator::metrics`] counters.
//! * [`service`] — the request/response front-end (`Ingest` / `Query` /
//!   `Checkpoint` / `Drain`), rounding once per query via
//!   [`crate::arith::normalize`].
//!
//! With an exact [`crate::arith::AccSpec`], replaying the same traffic with
//! any chunk size, thread count and arrival order yields bit-identical
//! `(λ, acc, sticky)` per stream — demonstrated in
//! `tests/stream_invariants.rs` and `examples/stream_serve.rs`.

pub mod engine;
pub mod segment;
pub mod service;
pub mod shard;

#[allow(deprecated)]
pub use crate::arith::kernel::ReduceBackend;
pub use crate::reduce::{BackendSel, Partial, ReducePlan};
pub use engine::{EngineConfig, EngineMetrics, StreamEngine};
pub use segment::{
    reduce_chunk, reduce_chunk_with, segment_terms, segment_terms_with, Segment,
    SegmentAssembler,
};
pub use service::{IngestError, Request, Response, StreamService};
pub use shard::{ShardMap, Snapshot};
