//! The streaming reduction engine: record batches in, merged `AlignAcc`
//! stream states out.
//!
//! Workers are long-running jobs on a [`ThreadPool`] draining one bounded
//! queue (the `batcher` backpressure idiom): `try_send` rejects with
//! `Overloaded` when the queue is full, so producers shed load instead of
//! buffering unboundedly. Each worker chops a batch into `chunk`-sized
//! segments ([`segment::reduce_chunk`]) and merges them into the shared
//! [`ShardMap`] under that stream's stripe lock. With an exact [`AccSpec`]
//! the final per-stream `(λ, acc, sticky)` is **bit-identical** for every
//! chunk size, thread count and arrival order (eq. 10) — which is what
//! makes this fan-out safe. Truncated specs still work (λ is exact, sticky
//! is monotone) but their dropped low bits depend on merge completion
//! order, so multi-threaded replay is not bit-reproducible; use
//! [`super::segment::SegmentAssembler`] on a single consumer when a
//! truncated datapath must replay deterministically.

use super::segment::{reduce_chunk_with, Segment};
use super::shard::{ShardMap, Snapshot};
use crate::arith::AccSpec;
use crate::reduce::{BackendSel, ReducePlan};
use crate::coordinator::batcher::SubmitError;
use crate::coordinator::metrics::{Counter, LatencyHistogram};
use crate::coordinator::pool::ThreadPool;
use crate::formats::{Fp, BF16};
use crate::telemetry::{self, span, SpanContext, TraceEvent};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::time::Instant;

/// Engine geometry and datapath knobs.
#[derive(Clone, Copy, Debug)]
pub struct EngineConfig {
    /// Worker threads reducing and merging batches.
    pub threads: usize,
    /// Terms per segment (the chunk size of the chunked reduction).
    pub chunk: usize,
    /// Bounded ingest-queue depth (backpressure threshold), in batches.
    pub queue_depth: usize,
    /// Lock stripes of the shard map.
    pub stripes: usize,
    /// Accumulator datapath; exact specs give order/chunking/thread-count
    /// invariant results.
    pub spec: AccSpec,
    /// Chunk-reduction backend: an explicit registry selection
    /// ([`BackendSel`]), or `None` to let [`ReducePlan::negotiate`] pick
    /// per spec (the SoA kernel on exact specs, the scalar fold on
    /// truncated ones). On exact specs this is a pure throughput knob —
    /// the merged states are bit-identical across backends.
    pub backend: Option<BackendSel>,
}

impl EngineConfig {
    /// The executable plan this configuration resolves to (inspect it via
    /// [`ReducePlan::describe`]).
    pub fn plan(&self) -> ReducePlan {
        match self.backend {
            Some(sel) => ReducePlan::with_backend(self.spec, sel),
            None => ReducePlan::negotiate(self.spec),
        }
    }
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            threads: ThreadPool::default_size(),
            chunk: 64,
            queue_depth: 4096,
            stripes: 16,
            spec: AccSpec::exact(BF16),
            backend: None,
        }
    }
}

/// Shared engine counters (same style as `BatcherMetrics`).
#[derive(Default, Debug)]
pub struct EngineMetrics {
    /// Batches accepted into the queue.
    pub batches: Counter,
    /// Terms accepted into the queue.
    pub ingested_terms: Counter,
    /// Segments produced by chunked reduction.
    pub segments: Counter,
    /// Segment→stream merges applied to the shard map.
    pub merges: Counter,
    /// Batches rejected by backpressure.
    pub rejected: Counter,
    /// Streams finalized (drained).
    pub drains: Counter,
    /// Queue→merge completion latency per batch.
    pub ingest_latency: LatencyHistogram,
}

struct WorkItem {
    stream: String,
    terms: Vec<Fp>,
    submitted: Instant,
    /// Worker-batch span, a child of the ingest root span ([`SpanContext::NONE`]
    /// when tracing is off — span ids are only allocated while the ring is live).
    span: SpanContext,
}

/// Monotone ingest progress: `done` converges on `accepted` (rejected and
/// panicked batches count as done), so a [`StreamEngine::quiesce`] caller
/// waits only for the batches accepted *before* its call — it stays live
/// under sustained ingest from other clients.
#[derive(Default)]
struct Progress {
    accepted: u64,
    done: u64,
}

type ProgressSync = (Mutex<Progress>, Condvar);

/// Poison-tolerant lock: a panicked worker must never turn `quiesce` into
/// a deadlock or a poison panic cascade.
fn lock_progress(p: &ProgressSync) -> MutexGuard<'_, Progress> {
    p.0.lock().unwrap_or_else(PoisonError::into_inner)
}

fn note_done(p: &ProgressSync) {
    lock_progress(p).done += 1;
    p.1.notify_all();
}

/// Multi-threaded streaming align-and-add engine.
pub struct StreamEngine {
    cfg: EngineConfig,
    plan: ReducePlan,
    shards: Arc<ShardMap>,
    metrics: Arc<EngineMetrics>,
    tx: Option<SyncSender<WorkItem>>,
    progress: Arc<ProgressSync>,
    pool: ThreadPool,
}

impl StreamEngine {
    pub fn new(cfg: EngineConfig) -> Self {
        let plan = cfg.plan();
        let pool = ThreadPool::new(cfg.threads.max(1));
        let shards = Arc::new(ShardMap::new(cfg.stripes, cfg.spec));
        let metrics = Arc::new(EngineMetrics::default());
        let progress = Arc::new((Mutex::new(Progress::default()), Condvar::new()));
        let (tx, rx) = sync_channel::<WorkItem>(cfg.queue_depth.max(1));
        let rx = Arc::new(Mutex::new(rx));
        for _ in 0..pool.size() {
            let rx = Arc::clone(&rx);
            let shards = Arc::clone(&shards);
            let metrics = Arc::clone(&metrics);
            let progress = Arc::clone(&progress);
            let chunk = cfg.chunk.max(1);
            pool.submit(move || worker_loop(&rx, &shards, &metrics, &progress, chunk, plan));
        }
        StreamEngine { cfg, plan, shards, metrics, tx: Some(tx), progress, pool }
    }

    pub fn config(&self) -> EngineConfig {
        self.cfg
    }

    /// The negotiated reduction plan every worker runs.
    pub fn plan(&self) -> ReducePlan {
        self.plan
    }

    pub fn metrics(&self) -> &EngineMetrics {
        &self.metrics
    }

    pub fn shards(&self) -> &ShardMap {
        &self.shards
    }

    pub fn threads(&self) -> usize {
        self.pool.size()
    }

    /// Queue one record batch for `stream`. Rejects with
    /// [`SubmitError::Overloaded`] when the bounded queue is full.
    pub fn ingest(&self, stream: &str, terms: Vec<Fp>) -> Result<usize, SubmitError> {
        self.ingest_inner(stream, terms, false)
    }

    /// Queue one record batch, blocking while the queue is full (the replay
    /// path: traces are fed as fast as the engine drains them).
    pub fn ingest_blocking(&self, stream: &str, terms: Vec<Fp>) -> Result<usize, SubmitError> {
        self.ingest_inner(stream, terms, true)
    }

    /// The one place the progress accounting lives: `note_accepted` must be
    /// balanced by exactly one worker `note_done` (on success) or the error
    /// path below — otherwise `quiesce` wedges.
    fn ingest_inner(
        &self,
        stream: &str,
        terms: Vec<Fp>,
        blocking: bool,
    ) -> Result<usize, SubmitError> {
        let n = terms.len();
        self.note_accepted();
        // Causal spans: one root per ingest on the stream's deterministic
        // trace, one child for the worker batch. Allocated only while the
        // ring is live so the traced-off hot path stays span-free.
        let tracing = telemetry::global().trace.enabled();
        let root = if tracing { SpanContext::for_stream(stream) } else { SpanContext::NONE };
        let item = WorkItem {
            stream: stream.to_string(),
            terms,
            submitted: Instant::now(),
            span: if tracing { root.child() } else { SpanContext::NONE },
        };
        let tx = self.tx.as_ref().expect("engine alive");
        let sent = if blocking {
            tx.send(item).map_err(|_| SubmitError::Closed)
        } else {
            match tx.try_send(item) {
                Ok(()) => Ok(()),
                Err(TrySendError::Full(_)) => {
                    self.metrics.rejected.inc();
                    Err(SubmitError::Overloaded)
                }
                Err(TrySendError::Disconnected(_)) => Err(SubmitError::Closed),
            }
        };
        match sent {
            Ok(()) => {
                self.metrics.batches.inc();
                self.metrics.ingested_terms.add(n as u64);
                if telemetry::enabled() {
                    let s = &telemetry::global().stream;
                    s.batches.inc();
                    s.batch_terms.add(n as u64);
                    s.queue_depth.inc();
                }
                telemetry::global()
                    .trace
                    .record_with(root, TraceEvent::BatchQueued { terms: n as u64 });
                Ok(n)
            }
            Err(e) => {
                note_done(&self.progress);
                Err(e)
            }
        }
    }

    /// Block until every batch accepted **before this call** has been
    /// reduced and merged. A watermark wait, not a drain-to-empty: under
    /// sustained ingest from other clients this still returns as soon as
    /// the pre-call backlog clears.
    pub fn quiesce(&self) {
        let cvar = &self.progress.1;
        let mut g = lock_progress(&self.progress);
        let target = g.accepted;
        while g.done < target {
            g = cvar.wait(g).unwrap_or_else(PoisonError::into_inner);
        }
    }

    /// Checkpoint one stream (None if it was never ingested or was
    /// drained). Does not wait for queued work — call [`Self::quiesce`]
    /// first for a consistent point-in-time read.
    pub fn snapshot(&self, stream: &str) -> Option<Snapshot> {
        self.shards.snapshot(stream)
    }

    /// Finalize one stream: remove it and return its last checkpoint.
    pub fn drain(&self, stream: &str) -> Option<Snapshot> {
        let snap = self.shards.drain(stream);
        if let Some(s) = &snap {
            self.metrics.drains.inc();
            let trace = &telemetry::global().trace;
            if trace.enabled() {
                trace.record_with(
                    SpanContext::for_stream(stream),
                    TraceEvent::StreamDrained { terms: s.terms },
                );
            }
        }
        snap
    }

    fn note_accepted(&self) {
        lock_progress(&self.progress).accepted += 1;
    }
}

impl Drop for StreamEngine {
    fn drop(&mut self) {
        // Close the queue; workers drain what was accepted, then exit, then
        // the pool's own Drop joins them.
        drop(self.tx.take());
    }
}

fn worker_loop(
    rx: &Mutex<Receiver<WorkItem>>,
    shards: &ShardMap,
    metrics: &EngineMetrics,
    progress: &ProgressSync,
    chunk: usize,
    plan: ReducePlan,
) {
    loop {
        let item = {
            let guard = rx.lock().unwrap_or_else(PoisonError::into_inner);
            guard.recv()
        };
        let item = match item {
            Ok(item) => item,
            Err(_) => return, // engine dropped and queue drained
        };
        // A panicking batch must neither kill the worker nor leak the
        // progress accounting (which would wedge quiesce forever): contain
        // it, count the batch done, keep serving.
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            // Everything this batch touches — chunk reductions, backend
            // finishes, the shard merge — inherits the batch span, so one
            // trace id reconstructs the stream's whole life.
            let _span = span::enter(item.span);
            // Chunked reduction outside any lock; only the merge serializes
            // on the stream's stripe.
            let mut segments = 0u64;
            let mut merged = Segment::EMPTY;
            for c in item.terms.chunks(chunk) {
                let seg = reduce_chunk_with(&plan, c);
                segments += 1;
                // Batch-local pre-merge: one stripe-lock acquisition per
                // batch rather than per segment (associativity again).
                merged = merged.merge(&seg, plan.spec());
            }
            if !item.terms.is_empty() {
                shards.merge(&item.stream, merged);
                metrics.merges.inc();
            }
            metrics.segments.add(segments);
            telemetry::global()
                .trace
                .record(TraceEvent::BatchReduced { terms: item.terms.len() as u64, segments });
        }));
        if outcome.is_err() {
            eprintln!(
                "stream worker: batch for stream {:?} panicked; its terms are lost",
                item.stream
            );
        }
        if telemetry::enabled() {
            telemetry::global().stream.queue_depth.dec();
        }
        metrics.ingest_latency.observe(item.submitted.elapsed());
        note_done(progress);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::tree::{tree_sum, RadixConfig};
    use crate::util::prng::XorShift;

    fn config(threads: usize, chunk: usize) -> EngineConfig {
        EngineConfig { threads, chunk, ..Default::default() }
    }

    fn rows(rng: &mut XorShift, n_rows: usize, width: usize) -> Vec<Vec<Fp>> {
        (0..n_rows)
            .map(|_| (0..width).map(|_| rng.gen_fp_sparse(BF16, 0.1)).collect())
            .collect()
    }

    fn reference(rows: &[Vec<Fp>], spec: AccSpec) -> crate::arith::operator::AlignAcc {
        let flat: Vec<Fp> = rows.iter().flatten().copied().collect();
        tree_sum(&flat, &RadixConfig::baseline(flat.len() as u32), spec)
    }

    #[test]
    fn single_stream_matches_tree_reference() {
        let spec = AccSpec::exact(BF16);
        let mut rng = XorShift::new(0xE16);
        let data = rows(&mut rng, 40, 32);
        let engine = StreamEngine::new(config(4, 16));
        for r in &data {
            engine.ingest_blocking("s", r.clone()).unwrap();
        }
        engine.quiesce();
        let snap = engine.snapshot("s").unwrap();
        assert_eq!(snap.state(), reference(&data, spec));
        assert_eq!(snap.terms, 40 * 32);
        assert_eq!(engine.metrics().batches.get(), 40);
        assert_eq!(engine.metrics().ingested_terms.get(), 40 * 32);
    }

    #[test]
    fn result_is_invariant_to_threads_chunk_and_order() {
        let spec = AccSpec::exact(BF16);
        let mut rng = XorShift::new(0x1237);
        let data = rows(&mut rng, 30, 32);
        let want = reference(&data, spec);
        for threads in [1usize, 2, 8] {
            for chunk in [1usize, 7, 64] {
                let mut shuffled = data.clone();
                rng.shuffle(&mut shuffled);
                let engine = StreamEngine::new(config(threads, chunk));
                for r in &shuffled {
                    engine.ingest_blocking("s", r.clone()).unwrap();
                }
                engine.quiesce();
                let snap = engine.snapshot("s").unwrap();
                assert_eq!(snap.state(), want, "threads={threads} chunk={chunk}");
            }
        }
    }

    #[test]
    fn backend_is_a_pure_throughput_knob_on_exact_specs() {
        use crate::reduce::registry;
        let spec = AccSpec::exact(BF16);
        let mut rng = XorShift::new(0x8ACE);
        let data = rows(&mut rng, 24, 48);
        let want = reference(&data, spec);
        // Every registered backend, an odd kernel block, and negotiation.
        let mut backends: Vec<Option<BackendSel>> =
            registry::entries().iter().map(|e| Some(e.sel())).collect();
        backends.push(Some(registry::sel("kernel:5").unwrap()));
        backends.push(None);
        for backend in backends {
            let engine = StreamEngine::new(EngineConfig { backend, ..config(4, 16) });
            for r in &data {
                engine.ingest_blocking("s", r.clone()).unwrap();
            }
            engine.quiesce();
            let label = engine.plan().describe();
            assert_eq!(engine.snapshot("s").unwrap().state(), want, "{label}");
        }
    }

    #[test]
    fn streams_do_not_interfere() {
        let spec = AccSpec::exact(BF16);
        let mut rng = XorShift::new(0x9);
        let a = rows(&mut rng, 12, 16);
        let b = rows(&mut rng, 9, 16);
        let engine = StreamEngine::new(config(4, 8));
        for (i, r) in a.iter().chain(b.iter()).enumerate() {
            let id = if i < a.len() { "a" } else { "b" };
            engine.ingest_blocking(id, r.clone()).unwrap();
        }
        engine.quiesce();
        assert_eq!(engine.snapshot("a").unwrap().state(), reference(&a, spec));
        assert_eq!(engine.snapshot("b").unwrap().state(), reference(&b, spec));
        assert_eq!(engine.shards().len(), 2);
    }

    #[test]
    fn drain_finalizes_and_removes() {
        let mut rng = XorShift::new(0xD);
        let data = rows(&mut rng, 4, 8);
        let engine = StreamEngine::new(config(2, 4));
        for r in &data {
            engine.ingest_blocking("s", r.clone()).unwrap();
        }
        engine.quiesce();
        let snap = engine.drain("s").unwrap();
        assert_eq!(snap.terms, 32);
        assert!(engine.snapshot("s").is_none());
        assert!(engine.drain("s").is_none());
        assert_eq!(engine.metrics().drains.get(), 1);
    }

    #[test]
    fn backpressure_rejects_when_saturated() {
        // A zero-worker engine is impossible (threads >= 1), so saturate by
        // queueing more than queue_depth while workers chew a huge batch.
        let cfg = EngineConfig { threads: 1, chunk: 1, queue_depth: 1, ..Default::default() };
        let engine = StreamEngine::new(cfg);
        let mut rng = XorShift::new(0xBB);
        let big: Vec<Fp> = (0..200_000).map(|_| rng.gen_fp_sparse(BF16, 0.1)).collect();
        let small: Vec<Fp> = big[..4].to_vec();
        // Keep the single worker busy, then overfill the depth-1 queue.
        engine.ingest_blocking("s", big).unwrap();
        let mut overloaded = false;
        for _ in 0..1000 {
            match engine.ingest("s", small.clone()) {
                Ok(_) => {}
                Err(SubmitError::Overloaded) => {
                    overloaded = true;
                    break;
                }
                Err(e) => panic!("unexpected {e:?}"),
            }
        }
        assert!(overloaded, "bounded queue must reject past its depth");
        assert!(engine.metrics().rejected.get() >= 1);
        engine.quiesce(); // everything accepted still completes
    }

    #[test]
    fn empty_batch_ingest_is_counted_but_merges_nothing() {
        // A zero-term batch is legal traffic (clients flush empty
        // buffers): it must be accepted, complete (quiesce stays live),
        // create no stream state, and leave later batches unaffected.
        let engine = StreamEngine::new(config(2, 8));
        assert_eq!(engine.ingest("empty", Vec::new()).unwrap(), 0);
        engine.quiesce();
        assert!(engine.snapshot("empty").is_none(), "no segment, no stream state");
        assert_eq!(engine.metrics().batches.get(), 1);
        assert_eq!(engine.metrics().ingested_terms.get(), 0);
        assert_eq!(engine.metrics().merges.get(), 0);
        let one = Fp::from_f64(1.0, BF16);
        engine.ingest_blocking("live", vec![one]).unwrap();
        engine.quiesce();
        assert_eq!(engine.snapshot("live").unwrap().terms, 1);
    }

    #[test]
    fn quiesce_on_idle_engine_returns_immediately() {
        let engine = StreamEngine::new(config(2, 8));
        engine.quiesce();
        assert!(engine.shards().is_empty());
    }

    #[test]
    #[cfg(debug_assertions)]
    fn worker_panic_does_not_wedge_quiesce() {
        // An Inf term bypasses the service-level screen and trips the
        // debug assertion in AlignAcc::leaf, panicking the worker
        // mid-batch. The engine must count the batch done (quiesce stays
        // live) and keep serving later batches.
        let engine = StreamEngine::new(config(2, 8));
        let inf = Fp::overflow(false, BF16);
        engine.ingest_blocking("bad", vec![inf]).unwrap();
        engine.quiesce(); // must return despite the panicked batch
        let one = Fp::from_f64(1.0, BF16);
        engine.ingest_blocking("good", vec![one, one]).unwrap();
        engine.quiesce();
        assert_eq!(engine.snapshot("good").unwrap().terms, 2);
        assert!(engine.snapshot("bad").is_none(), "panicked batch merged nothing");
    }
}
