//! Netlist-level static verifier: graph lints, STA, and width-obligation
//! bridging over generated radix-N align-and-add adders.
//!
//! Every area/delay/power number the `dse/` tier reports is computed *from
//! a graph* — a malformed netlist (combinational cycle, width-mismatched
//! bus, dangling node, mis-wired component) would corrupt all of them
//! silently. This pass closes that gap the same way `analysis::derive`
//! closed the software one: it re-derives what the graph must satisfy and
//! emits [`Obligation`]s into the same byte-deterministic report.
//!
//! Three layers, each independent of the machinery it checks:
//!
//! * **Structural lints** ([`lint`]) — edge-endpoint validity (second line
//!   of defense behind [`Netlist::add_edge`]), combinational-cycle
//!   detection via Kahn toposort, dangling/unreachable nodes, fan-in arity
//!   per component kind, and bus-width consistency along chain edges.
//! * **Static timing analysis** ([`sta`]) — ASAP *and* ALAP schedules,
//!   per-node slack, and a named critical path; unlike
//!   [`Netlist::schedule_asap`] it never mutates the graph and reports a
//!   cycle as a value instead of panicking.
//! * **Width-obligation bridge** — the [`MagBits`] magnitude bounds the
//!   software verifier derives for a (format × term-count) are pushed onto
//!   the hardware fraction-spine taps ([`OperatorTap`]): every partial-sum
//!   bus must be at least as wide as the proved signed magnitude.
//!
//! On top sit two pipeline audits re-checking `hw::pipeline` output from
//! first principles: stage monotonicity along every edge and an
//! independent recount of the register bits crossing stage cuts.
//!
//! The obligations run over the generated suite ([`generate_suite`]) —
//! serial baseline plus radix-{2,4,8} online trees at [`VERIFY_TERMS`]
//! terms for every paper format — and CI seeds [`NetlistFault`]s (injected
//! cycle, narrowed bus, dropped stage register, dangling node) to prove
//! the gate can fail.
//!
//! [`OperatorTap`]: crate::hw::datapath::OperatorTap
//! [`generate_suite`]: crate::hw::generate::generate_suite

use super::derive::Obligation;
use super::domain::{clog2, MagBits};
use crate::hw::components::Comp;
use crate::hw::datapath::AdderNetlist;
use crate::hw::generate;
use crate::hw::netlist::{Edge, Netlist, NodeId};
use crate::hw::pipeline;

/// Term count of the verified suite. 16 keeps the 20-netlist sweep cheap
/// enough for every `cargo test` while still exercising multi-level trees
/// (the DSE tier separately sweeps the paper's n=32 design points).
pub const VERIFY_TERMS: u32 = 16;

// ---------------------------------------------------------------------------
// Structural lints
// ---------------------------------------------------------------------------

/// What a structural lint found.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum LintKind {
    /// An edge references a missing node, loops on itself, or has width 0
    /// (possible despite [`Netlist::add_edge`] because the fields are
    /// public — the lint is the second line of defense).
    EdgeEndpoint,
    /// The graph is not a DAG; the node sits on a combinational cycle.
    Cycle,
    /// A node with no edges at all: it contributes area but no function.
    Dangling,
    /// A node no primary input can reach (only checked on acyclic graphs
    /// that have `in.*` sources).
    Unreachable,
    /// A node's in-degree contradicts its component kind.
    FanInArity,
    /// Consecutive chain edges (`*.p{k} -> *.p{k+1}`, `*.s{k} -> *.s{k+1}`)
    /// carry different bus widths.
    BusWidth,
}

/// One structural finding, anchored to a node where that makes sense.
#[derive(Clone, Debug)]
pub struct Lint {
    pub kind: LintKind,
    pub node: Option<NodeId>,
    pub detail: String,
}

/// Expected in-degree for a component kind, from the `hw::datapath` node
/// naming conventions. `None` means "any positive fan-in".
fn expected_fanin(kind: &str) -> Option<(u32, u32)> {
    if kind.starts_with("in.") {
        return Some((0, 0));
    }
    if kind.contains("unpack") || kind.ends_with(".absdiff") || kind == "norm.abs" {
        return Some((1, 1));
    }
    if kind.ends_with(".emax") || kind.ends_with(".swap") {
        return Some((3, 3)); // select + two data buses
    }
    if kind.contains(".max.l") {
        return Some((2, 2));
    }
    if kind == "norm.pack" {
        return Some((2, 2)); // mantissa + adjusted exponent
    }
    if kind.contains(".csa.l") {
        return Some((3, u32::MAX)); // >= one 3:2 compressor trio
    }
    if let Some((_, tail, idx)) = split_chain(kind) {
        return Some(match (tail, idx) {
            ('s', 0) => (2, 2),       // data + shift amount
            ('s', _) => (1, 1),       // shifter chain link
            ('p', 0) => (1, 3),       // prefix-chain head takes its feeds
            ('p', _) => (1, 1),       // prefix-chain link
            _ => unreachable!(),
        });
    }
    None // unknown kind: any positive fan-in
}

/// Split `"<head>.p<K>"` / `"<head>.s<K>"` chain names.
fn split_chain(kind: &str) -> Option<(&str, char, u32)> {
    let (head, last) = kind.rsplit_once('.')?;
    let mut chars = last.chars();
    let tag = chars.next()?;
    if tag != 'p' && tag != 's' {
        return None;
    }
    let idx: u32 = chars.as_str().parse().ok()?;
    Some((head, tag, idx))
}

/// Kahn toposort that never mutates the graph: `Ok(order)` on a DAG,
/// `Err(on_cycle)` with every node still carrying in-degree otherwise.
fn toposort(nl: &Netlist) -> Result<Vec<NodeId>, Vec<NodeId>> {
    let n = nl.nodes.len();
    let mut indeg = vec![0usize; n];
    let mut succ: Vec<Vec<NodeId>> = vec![Vec::new(); n];
    for e in &nl.edges {
        if e.from < n && e.to < n && e.from != e.to {
            indeg[e.to] += 1;
            succ[e.from].push(e.to);
        }
    }
    let mut queue: Vec<NodeId> = (0..n).filter(|&i| indeg[i] == 0).collect();
    let mut order = Vec::with_capacity(n);
    while let Some(u) = queue.pop() {
        order.push(u);
        for &v in &succ[u] {
            indeg[v] -= 1;
            if indeg[v] == 0 {
                queue.push(v);
            }
        }
    }
    if order.len() == n {
        Ok(order)
    } else {
        Err((0..n).filter(|&i| indeg[i] > 0).collect())
    }
}

/// Run every structural lint pass. An empty result is the graph-shape
/// contract the obligation family `netlist-structure` gates on.
pub fn lint(nl: &Netlist) -> Vec<Lint> {
    let n = nl.nodes.len();
    let mut out = Vec::new();

    // 1. Edge endpoints (defense in depth behind `add_edge`).
    for (ei, e) in nl.edges.iter().enumerate() {
        if e.from >= n || e.to >= n {
            out.push(Lint {
                kind: LintKind::EdgeEndpoint,
                node: None,
                detail: format!("edge #{ei} {}->{} outside 0..{n}", e.from, e.to),
            });
        } else if e.from == e.to {
            out.push(Lint {
                kind: LintKind::EdgeEndpoint,
                node: Some(e.from),
                detail: format!("edge #{ei} self-loop on {}", nl.nodes[e.from].kind),
            });
        } else if e.bits == 0 {
            out.push(Lint {
                kind: LintKind::EdgeEndpoint,
                node: Some(e.from),
                detail: format!("edge #{ei} {}->{} has zero width", e.from, e.to),
            });
        }
    }

    // 2. Combinational cycles.
    let topo = toposort(nl);
    if let Err(ref on_cycle) = topo {
        let first = on_cycle[0];
        out.push(Lint {
            kind: LintKind::Cycle,
            node: Some(first),
            detail: format!(
                "{} nodes on combinational cycles (first: {})",
                on_cycle.len(),
                nl.nodes[first].kind
            ),
        });
    }

    // In/out degree per node for the remaining passes.
    let mut indeg = vec![0u32; n];
    let mut outdeg = vec![0u32; n];
    for e in &nl.edges {
        if e.from < n && e.to < n {
            outdeg[e.from] += 1;
            indeg[e.to] += 1;
        }
    }

    // 3. Dangling nodes (no edges at all).
    for (i, node) in nl.nodes.iter().enumerate() {
        if indeg[i] == 0 && outdeg[i] == 0 {
            out.push(Lint {
                kind: LintKind::Dangling,
                node: Some(i),
                detail: format!("{} has no edges", node.kind),
            });
        }
    }

    // 4. Reachability from primary inputs (acyclic graphs with inputs).
    if topo.is_ok() {
        let sources: Vec<NodeId> =
            (0..n).filter(|&i| nl.nodes[i].kind.starts_with("in.")).collect();
        if !sources.is_empty() {
            let mut reached = vec![false; n];
            let mut stack = sources;
            for &s in &stack {
                reached[s] = true;
            }
            while let Some(u) = stack.pop() {
                reached[u] = true;
                for e in &nl.edges {
                    if e.from == u && !reached[e.to] {
                        reached[e.to] = true;
                        stack.push(e.to);
                    }
                }
            }
            for (i, node) in nl.nodes.iter().enumerate() {
                if !reached[i] && !(indeg[i] == 0 && outdeg[i] == 0) {
                    out.push(Lint {
                        kind: LintKind::Unreachable,
                        node: Some(i),
                        detail: format!("{} unreachable from primary inputs", node.kind),
                    });
                }
            }
        }
    }

    // 5. Fan-in arity vs component kind (skip fully dangling nodes — pass 3
    //    already reported them).
    for (i, node) in nl.nodes.iter().enumerate() {
        if indeg[i] == 0 && outdeg[i] == 0 {
            continue;
        }
        let (lo, hi) = expected_fanin(&node.kind).unwrap_or((1, u32::MAX));
        if indeg[i] < lo || indeg[i] > hi {
            out.push(Lint {
                kind: LintKind::FanInArity,
                node: Some(i),
                detail: format!(
                    "{} has fan-in {} (expected {}..={})",
                    node.kind,
                    indeg[i],
                    lo,
                    if hi == u32::MAX { "*".to_string() } else { hi.to_string() }
                ),
            });
        }
    }

    // 6. Bus-width consistency along chains: every `head.pK -> head.pK+1`
    //    (and `.sK`) link of one chain must carry the same width.
    let mut chains: Vec<(String, char, u32)> = Vec::new();
    for e in &nl.edges {
        if e.from >= n || e.to >= n {
            continue;
        }
        let (Some((hf, tf, inf)), Some((ht, tt, int))) =
            (split_chain(&nl.nodes[e.from].kind), split_chain(&nl.nodes[e.to].kind))
        else {
            continue;
        };
        if hf == ht && tf == tt && int == inf + 1 {
            chains.push((hf.to_string(), tf, e.bits));
        }
    }
    chains.sort();
    for w in chains.windows(2) {
        if w[0].0 == w[1].0 && w[0].1 == w[1].1 && w[0].2 != w[1].2 {
            out.push(Lint {
                kind: LintKind::BusWidth,
                node: None,
                detail: format!(
                    "chain {} carries mixed widths {} and {}",
                    w[0].0, w[0].2, w[1].2
                ),
            });
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Static timing analysis
// ---------------------------------------------------------------------------

/// Full STA result: ASAP/ALAP start times, per-node slack, and the named
/// critical path — the view `Netlist::schedule_asap` (longest path only)
/// never exposes.
#[derive(Clone, Debug)]
pub struct Sta {
    pub asap: Vec<f64>,
    pub alap: Vec<f64>,
    /// `alap - asap` per node; 0 on the critical path.
    pub slack: Vec<f64>,
    /// Critical-path delay in τ.
    pub critical: f64,
    /// Node ids along one critical path, source to sink.
    pub critical_path: Vec<NodeId>,
}

impl Sta {
    /// Human-readable critical path: `kind -> kind -> ...` (elided middle).
    pub fn path_name(&self, nl: &Netlist) -> String {
        let kinds: Vec<&str> =
            self.critical_path.iter().map(|&i| nl.nodes[i].kind.as_str()).collect();
        match kinds.len() {
            0 => "<empty>".to_string(),
            1 => kinds[0].to_string(),
            2 => format!("{} -> {}", kinds[0], kinds[1]),
            k => format!("{} -> .. {} nodes .. -> {}", kinds[0], k - 2, kinds[k - 1]),
        }
    }
}

/// Run STA over a netlist without mutating it. `None` when the graph has a
/// combinational cycle (no schedule exists).
pub fn sta(nl: &Netlist) -> Option<Sta> {
    let order = toposort(nl).ok()?;
    let n = nl.nodes.len();

    // ASAP: start when the slowest predecessor finishes.
    let mut asap = vec![0f64; n];
    for &v in &order {
        for e in &nl.edges {
            if e.to == v {
                let f = asap[e.from] + nl.nodes[e.from].delay;
                if f > asap[v] {
                    asap[v] = f;
                }
            }
        }
    }
    let critical =
        (0..n).map(|i| asap[i] + nl.nodes[i].delay).fold(0.0, f64::max);

    // ALAP: latest start keeping every successor feasible. `tail[v]` is the
    // longest delay from v's own start to the overall sink.
    let mut tail = vec![0f64; n];
    for &v in order.iter().rev() {
        let mut downstream = 0f64;
        for e in &nl.edges {
            if e.from == v {
                downstream = downstream.max(tail[e.to]);
            }
        }
        tail[v] = nl.nodes[v].delay + downstream;
    }
    let alap: Vec<f64> = (0..n).map(|i| critical - tail[i] + nl.nodes[i].delay).collect();
    // alap[i] as computed above is the latest *finish*; slack compares
    // starts, so subtract the node delay back out.
    let alap: Vec<f64> = (0..n).map(|i| alap[i] - nl.nodes[i].delay).collect();
    let slack: Vec<f64> = (0..n).map(|i| alap[i] - asap[i]).collect();

    // Critical path: walk back from the earliest argmax finish, at every
    // step taking the smallest-id predecessor on the tight edge — fully
    // deterministic.
    let mut path = Vec::new();
    let mut cur = (0..n)
        .filter(|&i| (asap[i] + nl.nodes[i].delay - critical).abs() < 1e-9)
        .min();
    while let Some(v) = cur {
        path.push(v);
        cur = nl
            .edges
            .iter()
            .filter(|e| {
                e.to == v && (asap[e.from] + nl.nodes[e.from].delay - asap[v]).abs() < 1e-9
            })
            .map(|e| e.from)
            .min();
        if asap[v] == 0.0 {
            break;
        }
    }
    path.reverse();
    Some(Sta { asap, alap, slack, critical, critical_path: path })
}

// ---------------------------------------------------------------------------
// Pipeline audits
// ---------------------------------------------------------------------------

/// Independent recheck of a pipeline stage assignment.
#[derive(Clone, Copy, Debug)]
pub struct PipelineAudit {
    /// Edges whose producer is assigned a *later* stage than their consumer.
    pub monotone_violations: u32,
    /// Register bits recounted from first principles: Σ stage-gap × width
    /// over every edge (the multiset of buses crossing each cut).
    pub recomputed_reg_bits: u64,
}

/// Recount what `hw::pipeline` reported, trusting only the edge list and
/// the per-node stage assignment.
pub fn audit_pipeline(nl: &Netlist, assignment: &[u32]) -> PipelineAudit {
    let mut monotone_violations = 0u32;
    let mut recomputed_reg_bits = 0u64;
    for e in &nl.edges {
        if e.from >= assignment.len() || e.to >= assignment.len() {
            continue; // endpoint lints own this case
        }
        let (sf, st) = (assignment[e.from], assignment[e.to]);
        if sf > st {
            monotone_violations += 1;
        }
        recomputed_reg_bits += u64::from(st.saturating_sub(sf)) * u64::from(e.bits);
    }
    PipelineAudit { monotone_violations, recomputed_reg_bits }
}

// ---------------------------------------------------------------------------
// Seeded faults
// ---------------------------------------------------------------------------

/// A seeded netlist corruption CI injects to prove the gate can fail.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum NetlistFault {
    /// Push the reverse of the last edge: a combinational cycle.
    Cycle,
    /// Halve the widest output bus of the root `⊙` operator: the width
    /// bridge must notice the accumulated sum no longer fits.
    NarrowBus,
    /// Halve the *reported* pipeline register bits: the recount must
    /// disagree (models a scheduler dropping a stage register).
    DropRegister,
    /// Add a node wired to nothing.
    Dangling,
}

impl NetlistFault {
    /// Parse the CLI fault name (`net-*` namespace, disjoint from the
    /// [`super::derive::StorageEnv`] fault names).
    pub fn from_name(name: &str) -> Option<Self> {
        match name {
            "net-cycle" => Some(NetlistFault::Cycle),
            "net-narrow-bus" => Some(NetlistFault::NarrowBus),
            "net-drop-register" => Some(NetlistFault::DropRegister),
            "net-dangling" => Some(NetlistFault::Dangling),
            _ => None,
        }
    }

    /// Every fault name [`Self::from_name`] accepts.
    pub fn fault_names() -> Vec<&'static str> {
        vec!["net-cycle", "net-narrow-bus", "net-drop-register", "net-dangling"]
    }
}

// ---------------------------------------------------------------------------
// Obligation bridge
// ---------------------------------------------------------------------------

fn nob(
    id: &'static str,
    fmt: crate::formats::FpFormat,
    backend: &str,
    required_bits: u32,
    provided_bits: u32,
    detail: String,
) -> Obligation {
    Obligation {
        id,
        format: fmt.name.to_string(),
        backend: backend.to_string(),
        required_bits,
        provided_bits,
        detail,
    }
}

/// Signed magnitude bits a partial sum of `terms` aligned terms needs:
/// term → guard lift → bounded sum → sign, exactly the software chain.
fn required_sum_bits(sig_bits: u32, guard: u32, terms: u32) -> u32 {
    MagBits::term(sig_bits).shl(guard).sum(clog2(u64::from(terms))).signed_bits()
}

/// Verify one generated adder, optionally under a seeded fault, and emit
/// the seven `netlist-*` obligation families for it.
pub fn check_adder(adder: &AdderNetlist, fault: Option<NetlistFault>) -> Vec<Obligation> {
    let fmt = adder.params.fmt;
    let backend = format!("nl:{}", adder.config);
    let n = adder.params.n_terms;
    let sig = fmt.sig_bits();
    let guard = adder.params.guard;

    // Clean references, captured before fault injection: the paper-policy
    // pipeline and the trusted longest-path delay.
    let clean_critical = adder.nl.critical_path();
    let stages = pipeline::paper_stages(fmt, n);
    let clock = pipeline::min_clock_ns(adder, stages) * 1.02;
    let pipe = pipeline::pipeline(adder, stages, clock)
        .expect("paper-depth pipeline of a generated adder is feasible");
    let root = adder.taps.last().expect("generated adders always have taps");

    // Fault injection on a private clone (the edge/node fields are public
    // precisely so corruption can bypass the validated constructors).
    let mut nl = adder.nl.clone();
    let mut reported_reg_bits = pipe.reg_bits;
    match fault {
        Some(NetlistFault::Cycle) => {
            let e = *nl.edges.last().expect("generated adders have edges");
            nl.edges.push(Edge { from: e.to, to: e.from, bits: e.bits });
        }
        Some(NetlistFault::NarrowBus) => {
            let idx = nl
                .edges
                .iter()
                .enumerate()
                .filter(|(_, e)| e.from == root.node)
                .max_by_key(|(_, e)| e.bits)
                .map(|(i, _)| i)
                .expect("root operator drives the normalize tail");
            nl.edges[idx].bits = (nl.edges[idx].bits / 2).max(1);
        }
        Some(NetlistFault::DropRegister) => reported_reg_bits = pipe.reg_bits / 2,
        Some(NetlistFault::Dangling) => {
            nl.add("dbg.orphan", Comp::new(1.0, 0.1));
        }
        None => {}
    }

    let mut out = Vec::new();

    // 1. Structural lints: the graph-shape contract. The required side is
    //    the lint count (0 on a healthy graph), so the committed artifact
    //    carries no graph-size-dependent values.
    let lints = lint(&nl);
    #[allow(clippy::cast_possible_truncation)]
    let lint_count = lints.len().min(u32::MAX as usize) as u32;
    out.push(nob(
        "netlist-structure",
        fmt,
        &backend,
        lint_count,
        0,
        match lints.first() {
            None => "structural lints over the generated adder graph".to_string(),
            Some(first) => format!("{} lint(s), first: {}", lints.len(), first.detail),
        },
    ));

    // 2 + 3. STA: slack consistency and agreement with schedule_asap.
    let sta_res = sta(&nl);
    let slack_violations = match &sta_res {
        None => 1,
        Some(s) => {
            #[allow(clippy::cast_possible_truncation)]
            let neg = s.slack.iter().filter(|&&x| x < -1e-9).count().min(u32::MAX as usize) as u32;
            neg
        }
    };
    out.push(nob(
        "netlist-sta-slack",
        fmt,
        &backend,
        slack_violations,
        0,
        "ASAP/ALAP slack must be non-negative at every node".to_string(),
    ));
    let critical_disagrees = match &sta_res {
        None => 1,
        Some(s) => u32::from((s.critical - clean_critical).abs() > 1e-9),
    };
    out.push(nob(
        "netlist-sta-critical",
        fmt,
        &backend,
        critical_disagrees,
        0,
        "STA longest path must equal schedule_asap's critical delay".to_string(),
    ));

    // 4. Width bridge at the root: the accumulated sum of all n terms must
    //    fit the bus actually leaving the root operator (read back from the
    //    possibly-faulted edge list, not from builder metadata).
    let root_bus = nl
        .edges
        .iter()
        .filter(|e| e.from == root.node)
        .map(|e| e.bits)
        .max()
        .unwrap_or(0);
    out.push(nob(
        "netlist-width-bridge",
        fmt,
        &backend,
        required_sum_bits(sig, guard, n),
        root_bus,
        format!("MagBits sum of {n} terms (sig {sig} << f {guard}) vs root output bus"),
    ));

    // 5. Width bridge along the whole spine: every tap's provisioned
    //    fraction width covers the magnitude bound of the terms it holds.
    #[allow(clippy::cast_possible_truncation)]
    let spine_violations = adder
        .taps
        .iter()
        .filter(|t| t.frac_w < required_sum_bits(sig, guard, t.terms))
        .count()
        .min(u32::MAX as usize) as u32;
    out.push(nob(
        "netlist-bus-bridge",
        fmt,
        &backend,
        spine_violations,
        0,
        format!("{} spine taps must each fit their MagBits bound", adder.taps.len()),
    ));

    // 6 + 7. Pipeline audits against the paper-policy schedule.
    let audit = audit_pipeline(&nl, &pipe.assignment);
    out.push(nob(
        "netlist-pipeline-monotone",
        fmt,
        &backend,
        audit.monotone_violations,
        0,
        format!("stage assignment monotone along every edge at {stages} stages"),
    ));
    let drift = audit.recomputed_reg_bits.abs_diff(reported_reg_bits);
    #[allow(clippy::cast_possible_truncation)]
    out.push(nob(
        "netlist-pipeline-regbits",
        fmt,
        &backend,
        drift.min(u64::from(u32::MAX)) as u32,
        0,
        format!("register-bit recount must match the scheduler's report at {stages} stages"),
    ));
    out
}

/// Derive the netlist obligation families over the full generated suite:
/// every paper format × (serial baseline + radix-{2,4,8} online trees) at
/// [`VERIFY_TERMS`] terms. Deterministic order: format outer, suite order
/// inner, the seven families per adder in a fixed sequence.
pub fn derive_netlist_obligations(fault: Option<NetlistFault>) -> Vec<Obligation> {
    let mut out = Vec::new();
    for fmt in crate::formats::PAPER_FORMATS {
        for adder in generate::generate_suite(fmt, VERIFY_TERMS) {
            out.extend(check_adder(&adder, fault));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::{BF16, FP32, PAPER_FORMATS};
    use crate::hw::generate::GenParams;

    #[test]
    fn generated_suite_is_lint_clean_for_every_format() {
        for fmt in PAPER_FORMATS {
            for adder in generate::generate_suite(fmt, VERIFY_TERMS) {
                let lints = lint(&adder.nl);
                assert!(
                    lints.is_empty(),
                    "{} {}: {:?}",
                    fmt.name,
                    adder.config,
                    lints.first()
                );
            }
        }
    }

    #[test]
    fn lint_catches_hand_broken_graphs() {
        let adder = generate::generate(&GenParams::online(BF16, 16, 4)).unwrap();

        // Cycle.
        let mut nl = adder.nl.clone();
        let e = *nl.edges.last().unwrap();
        nl.edges.push(Edge { from: e.to, to: e.from, bits: e.bits });
        assert!(lint(&nl).iter().any(|l| l.kind == LintKind::Cycle));

        // Dangling node.
        let mut nl = adder.nl.clone();
        nl.add("dbg.orphan", Comp::new(1.0, 0.1));
        assert!(lint(&nl).iter().any(|l| l.kind == LintKind::Dangling));

        // Bad endpoint pushed past the validated constructor.
        let mut nl = adder.nl.clone();
        let n = nl.nodes.len();
        nl.edges.push(Edge { from: 0, to: n + 5, bits: 8 });
        assert!(lint(&nl).iter().any(|l| l.kind == LintKind::EdgeEndpoint));

        // Arity break: unpack with a second input.
        let mut nl = adder.nl.clone();
        let unp = nl.nodes.iter().position(|x| x.kind == "unpack.3").unwrap();
        nl.edges.push(Edge { from: 0, to: unp, bits: 8 });
        assert!(lint(&nl).iter().any(|l| l.kind == LintKind::FanInArity));

        // Chain width mismatch.
        let mut nl = adder.nl.clone();
        let chain_edge = (0..nl.edges.len())
            .find(|&i| {
                let e = nl.edges[i];
                matches!(
                    (split_chain(&nl.nodes[e.from].kind), split_chain(&nl.nodes[e.to].kind)),
                    (Some((hf, tf, a)), Some((ht, tt, b)))
                        if hf == ht && tf == tt && b == a + 1
                )
            })
            .unwrap();
        nl.edges[chain_edge].bits += 7;
        assert!(lint(&nl).iter().any(|l| l.kind == LintKind::BusWidth));
    }

    #[test]
    fn sta_agrees_with_schedule_asap_and_names_the_path() {
        for cfg_radix in [0u32, 2, 8] {
            let p = if cfg_radix == 0 {
                GenParams::serial(FP32, 16)
            } else {
                GenParams::online(FP32, 16, cfg_radix)
            };
            let adder = generate::generate(&p).unwrap();
            let s = sta(&adder.nl).unwrap();
            assert!((s.critical - adder.nl.critical_path()).abs() < 1e-9);
            // Slack is non-negative everywhere, zero along the path.
            assert!(s.slack.iter().all(|&x| x > -1e-9));
            for &v in &s.critical_path {
                assert!(s.slack[v].abs() < 1e-9, "critical node {v} has slack");
            }
            // The path runs from a primary input to the packer.
            let name = s.path_name(&adder.nl);
            assert!(name.starts_with("in."), "{name}");
            assert!(name.ends_with("norm.pack"), "{name}");
        }
    }

    #[test]
    fn sta_returns_none_on_a_cycle() {
        let adder = generate::generate(&GenParams::serial(BF16, 16)).unwrap();
        let mut nl = adder.nl.clone();
        let e = *nl.edges.last().unwrap();
        nl.edges.push(Edge { from: e.to, to: e.from, bits: e.bits });
        assert!(sta(&nl).is_none());
    }

    #[test]
    fn clean_suite_obligations_are_all_green() {
        let obs = derive_netlist_obligations(None);
        // 7 families × 4 configs × 5 formats.
        assert_eq!(obs.len(), 7 * 4 * 5);
        for o in &obs {
            assert!(
                o.pass(),
                "{}/{}/{}: required {} > provided {} ({})",
                o.format,
                o.backend,
                o.id,
                o.required_bits,
                o.provided_bits,
                o.detail
            );
        }
        // The width bridge is tight: the generator provisions exactly the
        // proved bound at the root (margin 0), so any narrowing fails.
        assert!(obs
            .iter()
            .filter(|o| o.id == "netlist-width-bridge")
            .all(|o| o.margin() == 0));
    }

    #[test]
    fn every_seeded_fault_breaks_at_least_one_obligation() {
        for name in NetlistFault::fault_names() {
            let fault = NetlistFault::from_name(name).unwrap();
            let failed: Vec<_> = derive_netlist_obligations(Some(fault))
                .into_iter()
                .filter(|o| !o.pass())
                .collect();
            assert!(!failed.is_empty(), "fault {name} went undetected");
        }
        assert!(NetlistFault::from_name("no-such-fault").is_none());
    }

    #[test]
    fn fault_families_match_their_mechanisms() {
        let fails = |f: NetlistFault| -> Vec<&'static str> {
            let mut ids: Vec<_> = derive_netlist_obligations(Some(f))
                .into_iter()
                .filter(|o| !o.pass())
                .map(|o| o.id)
                .collect();
            ids.dedup();
            ids
        };
        assert!(fails(NetlistFault::Cycle).contains(&"netlist-structure"));
        assert!(fails(NetlistFault::Cycle).contains(&"netlist-sta-critical"));
        assert!(fails(NetlistFault::NarrowBus).contains(&"netlist-width-bridge"));
        assert!(fails(NetlistFault::DropRegister).contains(&"netlist-pipeline-regbits"));
        assert!(fails(NetlistFault::Dangling).contains(&"netlist-structure"));
    }
}
