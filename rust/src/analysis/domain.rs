//! Abstract domains for the static verifier: magnitude-bit intervals.
//!
//! The verifier never evaluates a datapath — it pushes *bit-width bounds*
//! through the same structure the datapath has. The single abstraction is
//! [`MagBits`]: "every value this wire can carry satisfies
//! `|v| < 2^bits`". The transfer functions below mirror the three
//! operations every align-and-add intermediate is built from — loading a
//! significand, lifting it by a shift, and summing a bounded number of
//! terms — and each one is a one-line sound bound:
//!
//! * load: a term's signed significand obeys the format bound
//!   (`|sig| < 2^sig_bits`);
//! * shift left by `k`: `|v·2^k| < 2^(bits+k)`;
//! * sum of `2^n` terms: `|Σ v_i| < 2^(bits+n)` (triangle inequality);
//! * two's-complement storage: a value with `|v| < 2^bits` needs
//!   `bits + 1` storage bits (sign included).
//!
//! Alignment *right* shifts never widen a magnitude, so they are the
//! identity in this domain — which is exactly why the derivations in
//! [`super::derive`] only ever add the load/lift/sum contributions.

/// Ceiling log2 over `u64` (`clog2(1) = 0`, `clog2(n) = ⌈log2 n⌉`).
pub fn clog2(n: u64) -> u32 {
    u64::BITS - (n.max(1) - 1).leading_zeros()
}

/// A magnitude-bit bound: every value on the wire satisfies `|v| < 2^0`
/// … `2^bits`. The domain is a join-semilattice under `max`, but the
/// datapath derivations only ever need the monotone transfer functions.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct MagBits(pub u32);

impl MagBits {
    /// A loaded term: `|signed_sig| < 2^sig_bits`.
    pub fn term(sig_bits: u32) -> Self {
        MagBits(sig_bits)
    }

    /// Lift by a left shift of `k` bits (the `sig << f` load).
    pub fn shl(self, k: u32) -> Self {
        MagBits(self.0 + k)
    }

    /// Sum of at most `2^n_log2` values with this bound.
    pub fn sum(self, n_log2: u32) -> Self {
        MagBits(self.0 + n_log2)
    }

    /// Two's-complement storage bits needed (one sign bit on top).
    pub fn signed_bits(self) -> u32 {
        self.0 + 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clog2_matches_ceil_log2() {
        assert_eq!(clog2(1), 0);
        assert_eq!(clog2(2), 1);
        assert_eq!(clog2(3), 2);
        assert_eq!(clog2(64), 6);
        assert_eq!(clog2(65), 7);
        assert_eq!(clog2(1 << 15), 15);
    }

    #[test]
    fn transfer_functions_compose() {
        // A BF16 term (9 magnitude bits incl. hidden bit? no — sig_bits=8)
        // lifted by 4 guard bits and summed 2^6 times needs 8+4+6+1 bits.
        let b = MagBits::term(8).shl(4).sum(6);
        assert_eq!(b, MagBits(18));
        assert_eq!(b.signed_bits(), 19);
    }

    #[test]
    fn soundness_on_concrete_extremes() {
        // 2^6 copies of the most negative 8-bit-bounded value, lifted by 4:
        // |Σ| = 2^6 · (2^8 − 1) · 2^4 < 2^18 — the derived bound holds and
        // is tight to within one value.
        let worst: i64 = -((1 << 8) - 1);
        let total: i64 = worst * (1 << 4) * (1 << 6);
        let bound = MagBits::term(8).shl(4).sum(6);
        assert!(total.unsigned_abs() < 1u64 << bound.0);
        assert!(total.unsigned_abs() > 1u64 << (bound.0 - 2));
    }
}
