//! Static datapath width/overflow verifier (DESIGN.md §Analysis).
//!
//! Every other tier in this crate *measures*; this tier *proves*. The
//! paper's exactness argument rests on derived no-overflow ranges — the
//! `AccSpec::exact` guard bound, the kernel's per-block i128 narrow path,
//! the EIA's carry-save lanes — and until now those ranges were enforced
//! only dynamically, by differential oracles over sampled vectors and by
//! scattered `debug_assert`s. This tier closes the gap between "tested on
//! 10k vectors" and "proved for the whole operand space":
//!
//! * [`domain`] — the abstract domain: magnitude-bit intervals with sound
//!   transfer functions for load / lift / bounded sum.
//! * [`derive`] — per-(format × backend) derivations over the registry:
//!   every intermediate whose width the exactness argument depends on
//!   becomes an [`Obligation`] (`required_bits ≤ provided_bits`), checked
//!   against the storage widths, the registry [`Capabilities`] claims,
//!   and the `hw::datapath` geometry.
//! * [`report`] — the proof artifact: a byte-deterministic
//!   `ANALYSIS_report.json` plus the human table behind `repro analyze`.
//!
//! The static pass is complemented by a **runtime cross-check**
//! ([`runtime_check`]): the telemetry hub's occupancy and lane-width
//! histograms record what the datapath actually saw, and CI asserts the
//! observed maxima never exceed the statically proved bounds — if the
//! implementation ever drifts from the model the analyzer interprets,
//! the gate trips even though both sides individually "pass".
//!
//! [`Capabilities`]: crate::reduce::Capabilities
//! [`Obligation`]: derive::Obligation

pub mod derive;
pub mod domain;
pub mod netlist;
pub mod report;

pub use derive::{Obligation, StorageEnv};
pub use report::AnalysisReport;

use crate::arith::{AccSpec, PROVED_TERMS_LOG2};
use crate::formats::PAPER_FORMATS;
use crate::reduce::registry;
use crate::telemetry::Telemetry;
use crate::util::prng::XorShift;

/// Run the full static pass against `env` (normally
/// [`StorageEnv::actual`]; a named fault for gate self-tests).
pub fn analyze(env: &StorageEnv) -> AnalysisReport {
    AnalysisReport { env: *env, obligations: derive::derive_obligations(env) }
}

/// [`analyze`] plus the netlist tier: the `netlist-*` obligation families
/// over the generated radix-N adder suite are appended after the software
/// derivations, optionally under a seeded [`netlist::NetlistFault`]. This
/// is what `repro analyze --netlist` (and the CI gate) runs.
pub fn analyze_netlist(
    env: &StorageEnv,
    fault: Option<netlist::NetlistFault>,
) -> AnalysisReport {
    let mut obligations = derive::derive_obligations(env);
    obligations.extend(netlist::derive_netlist_obligations(fault));
    AnalysisReport { env: *env, obligations }
}

/// One runtime observation checked against a statically proved bound.
#[derive(Clone, Copy, Debug)]
pub struct RuntimeBound {
    /// What was observed (telemetry metric semantics).
    pub name: &'static str,
    /// Maximum the telemetry histograms recorded.
    pub observed: u64,
    /// The statically proved ceiling.
    pub bound: u64,
}

impl RuntimeBound {
    pub fn pass(&self) -> bool {
        self.observed <= self.bound
    }
}

/// Cross-check the telemetry hub's observed maxima against the report's
/// proved bounds. An empty histogram observes 0 and trivially passes —
/// callers that want liveness run [`exercise_backends`] first.
pub fn runtime_check(report: &AnalysisReport, t: &Telemetry) -> Vec<RuntimeBound> {
    // The EIA occupancy ceiling: the widest `eia-occupancy` obligation
    // (254 occupied bins for the 8-bit-exponent formats).
    let occupancy_bound = report
        .obligations
        .iter()
        .filter(|o| o.id == "eia-occupancy")
        .map(|o| o.required_bits as u64)
        .max()
        .unwrap_or(0);
    vec![
        RuntimeBound {
            name: "ofa_accum_bin_occupancy.max",
            observed: t.accum.occupancy.max(),
            bound: occupancy_bound,
        },
        RuntimeBound {
            name: "ofa_kernel_block_lanes.max",
            observed: t.kernel.block_lanes.max(),
            bound: 1u64 << PROVED_TERMS_LOG2,
        },
    ]
}

/// Drive every registered backend over every paper format and every
/// oracle distribution so the telemetry histograms the runtime cross-check
/// reads are live. Deterministic (fixed seed), cheap (a few thousand
/// terms per combination), and registry-driven — a newly registered
/// backend is exercised automatically.
pub fn exercise_backends(terms_per_vector: usize, vectors: usize) -> u64 {
    let mut rng = XorShift::new(0xA11A_1752);
    let mut reduced = 0u64;
    for fmt in PAPER_FORMATS {
        let spec = AccSpec::exact(fmt);
        for dist in crate::arith::oracle::DISTRIBUTIONS {
            for entry in registry::entries() {
                for _ in 0..vectors {
                    let terms = dist.gen_vector(&mut rng, fmt, terms_per_vector);
                    let state = entry.sel().reduce(&terms, spec);
                    reduced += terms.len() as u64;
                    // Keep the reduction observable (and un-elided).
                    std::hint::black_box(&state);
                }
            }
        }
    }
    reduced
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn analyze_actual_env_is_all_green() {
        let report = analyze(&StorageEnv::actual());
        assert!(report.failed().is_empty());
        for fmt in PAPER_FORMATS {
            for backend in registry::names() {
                assert!(report.covers(fmt.name, backend), "{} x {backend}", fmt.name);
            }
        }
    }

    #[test]
    fn runtime_check_on_a_quiet_hub_passes_trivially() {
        let report = analyze(&StorageEnv::actual());
        let hub = Telemetry::new();
        let bounds = runtime_check(&report, &hub);
        assert_eq!(bounds.len(), 2);
        assert!(bounds.iter().all(|b| b.pass() && b.observed == 0));
        // And a synthetic out-of-bound observation trips it.
        hub.accum.occupancy.observe(100_000);
        assert!(runtime_check(&report, &hub).iter().any(|b| !b.pass()));
    }
}
