//! Per-(format × backend) width derivations: the obligation generator.
//!
//! [`StorageEnv`] captures every storage width the datapath actually uses
//! — normally read straight off the real constants
//! ([`StorageEnv::actual`]), or perturbed by a named fault
//! ([`StorageEnv::with_fault`]) so CI can prove the gate *can* fail.
//! [`derive_obligations`] then walks every paper format and every
//! registered backend and emits one [`Obligation`] per intermediate whose
//! width the exactness argument depends on: `required_bits` is the bound
//! the abstract interpretation ([`super::domain`]) derives, and
//! `provided_bits` is what the implementation provisions. An obligation
//! passes iff `required ≤ provided`.
//!
//! All derivations are taken at the analyzer's proof ceiling of
//! `2^PROVED_TERMS_LOG2` terms per accumulator (far above any in-tree
//! workload; the runtime cross-check in [`super`] keeps it honest) and
//! under the exact [`AccSpec`] of each format — the widest frame the
//! datapath ever runs.

use super::domain::{clog2, MagBits};
use crate::accum::{MAX_BINS, SPILL_LIMIT_LOG2};
use crate::arith::{simd, wide, AccSpec, PROVED_TERMS_LOG2, SIG_BOUND_BITS};
use crate::formats::FpFormat;
use crate::hw::datapath::DatapathParams;
use crate::reduce::registry;

/// The kernel's narrow-path alignment-shift clamp
/// (`(lambda - e).clamp(0, 127)` in `arith::kernel::block_state`).
const SHIFT_CLAMP: u32 = 127;

/// Every storage width the obligations are checked against. One struct so
/// a seeded fault can narrow any single width without touching the
/// derivations themselves.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct StorageEnv {
    /// `WideInt` width ([`wide::WIDE_BITS`]).
    pub wide_bits: u32,
    /// Narrow fast-path lane width (`i128`).
    pub narrow_bits: u32,
    /// Exponent bins in the EIA store ([`MAX_BINS`]).
    pub max_bins: u32,
    /// log2 of the EIA fast-lane spill threshold ([`SPILL_LIMIT_LOG2`]).
    pub spill_limit_log2: u32,
    /// Per-term significand magnitude bound ([`SIG_BOUND_BITS`]).
    pub sig_bound_bits: u32,
    /// Kernel narrow-path alignment-shift clamp.
    pub shift_clamp: u32,
}

impl StorageEnv {
    /// The widths the shipped implementation actually uses.
    pub fn actual() -> Self {
        StorageEnv {
            wide_bits: wide::WIDE_BITS as u32,
            narrow_bits: 128,
            max_bins: MAX_BINS as u32,
            spill_limit_log2: SPILL_LIMIT_LOG2,
            sig_bound_bits: SIG_BOUND_BITS,
            shift_clamp: SHIFT_CLAMP,
        }
    }

    /// The actual environment with one named width narrowed (or, for the
    /// spill threshold, raised) past its proved bound — CI seeds each of
    /// these to demonstrate the gate fails loudly.
    pub fn with_fault(name: &str) -> Result<Self, String> {
        let mut env = StorageEnv::actual();
        match name {
            // Too few exponent bins for the 8-bit-exponent formats.
            "eia-bins" => env.max_bins = 64,
            // Narrow fast path squeezed to an i64: e6m1's exact frame
            // (2 + 63 + 16 = 81 value bits) no longer fits.
            "narrow-i128" => env.narrow_bits = 64,
            // WideInt cut to three limbs: FP32's exact window overflows.
            "wide-acc" => env.wide_bits = 192,
            // Spill threshold raised by one: a post-threshold ingest now
            // needs 65 bits — one more than the i64 fast lane has.
            "spill-threshold" => env.spill_limit_log2 = 63,
            // Shift clamp below e6m1's live magnitude span (2 + 63 = 65).
            "shift-clamp" => env.shift_clamp = 63,
            other => {
                return Err(format!(
                    "unknown fault {other:?} (known: {})",
                    Self::fault_names().join(", ")
                ))
            }
        }
        Ok(env)
    }

    /// Every fault name [`Self::with_fault`] accepts.
    pub fn fault_names() -> Vec<&'static str> {
        vec!["eia-bins", "narrow-i128", "wide-acc", "spill-threshold", "shift-clamp"]
    }
}

/// One statically checked width bound: an intermediate's derived
/// requirement against the storage the implementation provisions.
#[derive(Clone, Debug)]
pub struct Obligation {
    /// Stable obligation identifier (see DESIGN.md §Analysis for the
    /// catalogue).
    pub id: &'static str,
    /// Format name (`FpFormat::name`).
    pub format: String,
    /// Registry backend name, or `"-"` for format-level obligations.
    pub backend: String,
    /// Bits the abstract interpretation proves the intermediate needs.
    pub required_bits: u32,
    /// Bits the implementation provisions for it.
    pub provided_bits: u32,
    /// One-line human explanation of what is being bounded.
    pub detail: String,
}

impl Obligation {
    pub fn pass(&self) -> bool {
        self.required_bits <= self.provided_bits
    }

    /// Spare bits (negative on failure).
    pub fn margin(&self) -> i64 {
        self.provided_bits as i64 - self.required_bits as i64
    }
}

fn ob(
    id: &'static str,
    fmt: FpFormat,
    backend: &str,
    required_bits: u32,
    provided_bits: u32,
    detail: String,
) -> Obligation {
    Obligation {
        id,
        format: fmt.name.to_string(),
        backend: backend.to_string(),
        required_bits,
        provided_bits,
        detail,
    }
}

/// The storage lane a spec accumulates in, as the environment sizes it.
fn storage_bits(env: &StorageEnv, spec: AccSpec) -> u32 {
    if spec.narrow {
        env.narrow_bits
    } else {
        env.wide_bits
    }
}

/// Signed accumulator bits after summing `2^terms_log2` aligned terms of
/// `fmt` in a frame with `f` guard bits: term → lift → sum → sign.
fn acc_bits(fmt: FpFormat, f: u32, terms_log2: u32) -> u32 {
    MagBits::term(fmt.sig_bits()).shl(f).sum(terms_log2).signed_bits()
}

/// Derive the full obligation list for every paper format × every
/// registered backend, in a fixed deterministic order (format outer,
/// format-level obligations first, then backends in registry order).
pub fn derive_obligations(env: &StorageEnv) -> Vec<Obligation> {
    let mut out = Vec::new();
    for fmt in crate::formats::PAPER_FORMATS {
        let spec = AccSpec::exact(fmt);
        let mne = fmt.max_normal_exp() as u32;
        let sig = fmt.sig_bits();
        let f = spec.f;
        let t = PROVED_TERMS_LOG2;

        // ---- format-level: the shared frame and the hw model ----------
        out.push(ob(
            "lambda-bin-range",
            fmt,
            "-",
            mne + 1,
            env.max_bins,
            format!("eff_exp 1..={mne} must index ExpBins (identity at 0)"),
        ));
        out.push(ob(
            "sig-magnitude",
            fmt,
            "-",
            sig,
            env.sig_bound_bits,
            format!("|signed_sig| < 2^{sig} fits the 2^{} per-term ingest bound", env.sig_bound_bits),
        ));
        out.push(ob(
            "exact-guard-alignment",
            fmt,
            "-",
            mne,
            f,
            format!("f={f} covers the worst alignment shift {} with >=1 LSB margin", mne - 1),
        ));
        out.push(ob(
            "acc-wide-fit",
            fmt,
            "-",
            spec.acc_width(fmt, 1usize << t),
            env.wide_bits,
            format!("exact acc_width at 2^{t} terms vs WideInt"),
        ));
        if spec.narrow {
            out.push(ob(
                "acc-narrow-fit",
                fmt,
                "-",
                acc_bits(fmt, f, t),
                env.narrow_bits,
                format!("exact narrow-lane value bits at 2^{t} terms vs i128"),
            ));
        }
        let hw = DatapathParams::new(fmt, 64, spec);
        out.push(ob(
            "hw-shifter-range",
            fmt,
            "-",
            mne - 1,
            hw.max_shift(),
            "hw shifter depth covers the effective-exponent range".to_string(),
        ));
        out.push(ob(
            "hw-root-width",
            fmt,
            "-",
            hw.leaf_frac_w() + clog2(64),
            spec.acc_width(fmt, 64),
            "netlist root fraction width (leaf + clog2(64)) inside acc_width(64)".to_string(),
        ));

        // ---- per-backend obligations, registry order ------------------
        for entry in registry::entries() {
            let caps = entry.sel().capabilities(spec);
            let lane = storage_bits(env, spec);
            match entry.name {
                "scalar" => {
                    out.push(ob(
                        "fold-acc-width",
                        fmt,
                        entry.name,
                        acc_bits(fmt, f, t),
                        lane,
                        format!("scalar fold accumulator at 2^{t} terms vs its storage lane"),
                    ));
                }
                "kernel" => {
                    let block = caps.block.unwrap_or(1) as u64;
                    let b_log2 = clog2(block);
                    out.push(ob(
                        "kernel-lane-lift",
                        fmt,
                        entry.name,
                        acc_bits(fmt, f, 0),
                        lane,
                        "single-lane (sig << f) lift vs the block accumulator lane".to_string(),
                    ));
                    out.push(ob(
                        "kernel-block-acc",
                        fmt,
                        entry.name,
                        acc_bits(fmt, f, b_log2),
                        lane,
                        format!("per-block accumulator with clog2(block={block}) carry headroom"),
                    ));
                    out.push(ob(
                        "kernel-combine-acc",
                        fmt,
                        entry.name,
                        acc_bits(fmt, f, t),
                        lane,
                        format!("cross-block combine accumulator at 2^{t} terms"),
                    ));
                    // Narrow path clamps d at SHIFT_CLAMP; that is sound
                    // only if every live magnitude bit is below the clamp.
                    // The wide d > f arm shifts a bare significand instead.
                    let live = if spec.narrow { sig + f } else { sig };
                    out.push(ob(
                        "kernel-shift-clamp",
                        fmt,
                        entry.name,
                        live,
                        env.shift_clamp,
                        format!(
                            "live magnitude bits below the {}-bit alignment-shift clamp",
                            env.shift_clamp
                        ),
                    ));
                }
                "eia" => {
                    out.push(ob(
                        "eia-bin-index",
                        fmt,
                        entry.name,
                        mne + 1,
                        env.max_bins,
                        "max effective exponent must stay inside MAX_BINS".to_string(),
                    ));
                    out.push(ob(
                        "eia-fast-lane",
                        fmt,
                        entry.name,
                        env.spill_limit_log2.max(env.sig_bound_bits) + 2,
                        64,
                        "post-threshold fast-lane ingest must fit i64".to_string(),
                    ));
                    out.push(ob(
                        "eia-spill-lane",
                        fmt,
                        entry.name,
                        MagBits::term(env.sig_bound_bits).sum(t).signed_bits(),
                        env.narrow_bits,
                        format!("per-bin spill value at 2^{t} terms vs i128"),
                    ));
                    out.push(ob(
                        "eia-drain-shift",
                        fmt,
                        entry.name,
                        acc_bits(fmt, f, t),
                        lane,
                        format!("reconcile-and-align drain accumulator at 2^{t} terms"),
                    ));
                    out.push(ob(
                        "eia-occupancy",
                        fmt,
                        entry.name,
                        mne,
                        env.max_bins.saturating_sub(1),
                        "occupied bins per drain (telemetry cross-checked bound)".to_string(),
                    ));
                }
                "simd" => {
                    // The vectorized kernel shares the scalar kernel's
                    // datapath bit-for-bit (same lift, same block/combine
                    // accumulators, same clamp), so its first four
                    // obligations mirror the kernel's exactly. The one new
                    // intermediate is the 8-lane i64 chunk sum of the
                    // portable-SIMD narrow sub-path, which is only entered
                    // when f <= VEC_NARROW_MAX_F.
                    let block = caps.block.unwrap_or(1) as u64;
                    let b_log2 = clog2(block);
                    out.push(ob(
                        "simd-lane-lift",
                        fmt,
                        entry.name,
                        acc_bits(fmt, f, 0),
                        lane,
                        "single-lane (sig << f) lift vs the block accumulator lane".to_string(),
                    ));
                    out.push(ob(
                        "simd-block-acc",
                        fmt,
                        entry.name,
                        acc_bits(fmt, f, b_log2),
                        lane,
                        format!("per-block accumulator with clog2(block={block}) carry headroom"),
                    ));
                    out.push(ob(
                        "simd-combine-acc",
                        fmt,
                        entry.name,
                        acc_bits(fmt, f, t),
                        lane,
                        format!("cross-block combine accumulator at 2^{t} terms"),
                    ));
                    let live = if spec.narrow { sig + f } else { sig };
                    out.push(ob(
                        "simd-shift-clamp",
                        fmt,
                        entry.name,
                        live,
                        env.shift_clamp,
                        format!(
                            "live magnitude bits below the {}-bit alignment-shift clamp",
                            env.shift_clamp
                        ),
                    ));
                    // Vector sub-path lane bound: sig_bound + max vector f
                    // + clog2(LANES) carry + sign must fit the i64 lanes
                    // (25 + 35 + 3 + 1 = 64 — a designed margin of zero).
                    out.push(ob(
                        "simd-vector-lane",
                        fmt,
                        entry.name,
                        env.sig_bound_bits + simd::VEC_NARROW_MAX_F + clog2(simd::LANES as u64) + 1,
                        64,
                        format!(
                            "{}-lane i64 chunk sum at the f<={} vector-path ceiling",
                            simd::LANES,
                            simd::VEC_NARROW_MAX_F
                        ),
                    ));
                }
                other => {
                    // A backend registered after this analyzer froze gets a
                    // deliberately failing obligation: extend the analyzer
                    // before shipping the backend.
                    out.push(ob(
                        "unmodeled-backend",
                        fmt,
                        other,
                        u32::MAX,
                        0,
                        format!("backend {other:?} has no width derivation yet"),
                    ));
                }
            }
            // Registry capability cross-checks, common to every backend.
            out.push(ob(
                "caps-proved-width",
                fmt,
                entry.name,
                acc_bits(fmt, f, t),
                caps.proved_acc_bits,
                "registry proved_acc_bits must cover the derived bound".to_string(),
            ));
            out.push(ob(
                "caps-storage-width",
                fmt,
                entry.name,
                caps.proved_acc_bits,
                caps.storage_acc_bits,
                "registry proved_acc_bits must fit storage_acc_bits".to_string(),
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::{FP32, FP8_E6M1};

    #[test]
    fn actual_env_reads_the_real_constants() {
        let env = StorageEnv::actual();
        assert_eq!(env.wide_bits, 384);
        assert_eq!(env.narrow_bits, 128);
        assert_eq!(env.max_bins, 256);
        assert_eq!(env.spill_limit_log2, 62);
        assert_eq!(env.sig_bound_bits, 25);
        assert_eq!(env.shift_clamp, 127);
    }

    #[test]
    fn every_obligation_passes_on_the_actual_env() {
        for o in derive_obligations(&StorageEnv::actual()) {
            assert!(
                o.pass(),
                "{}/{}/{}: required {} > provided {}",
                o.format,
                o.backend,
                o.id,
                o.required_bits,
                o.provided_bits
            );
        }
    }

    #[test]
    fn fixed_obligation_count_and_coverage() {
        let obs = derive_obligations(&StorageEnv::actual());
        // 29 per wide format (FP32, BF16) + 30 per narrow FP8 format
        // (7 simd obligations per format since the "simd" registration).
        assert_eq!(obs.len(), 2 * 29 + 3 * 30);
        for fmt in crate::formats::PAPER_FORMATS {
            for backend in registry::names() {
                assert!(
                    obs.iter().any(|o| o.format == fmt.name && o.backend == backend),
                    "no obligation covers {} x {}",
                    fmt.name,
                    backend
                );
            }
        }
    }

    #[test]
    fn each_named_fault_breaks_at_least_one_obligation() {
        for fault in StorageEnv::fault_names() {
            let env = StorageEnv::with_fault(fault).unwrap();
            let failed: Vec<_> = derive_obligations(&env)
                .into_iter()
                .filter(|o| !o.pass())
                .collect();
            assert!(!failed.is_empty(), "fault {fault} went undetected");
        }
        assert!(StorageEnv::with_fault("no-such-fault").is_err());
    }

    #[test]
    fn spot_check_key_margins() {
        let obs = derive_obligations(&StorageEnv::actual());
        let find = |id: &str, fmt: &str| {
            obs.iter().find(|o| o.id == id && o.format == fmt).unwrap()
        };
        // FP32 exact window: 24 + 254 + 17 = 295 of 384 WideInt bits.
        let wide = find("acc-wide-fit", FP32.name);
        assert_eq!((wide.required_bits, wide.provided_bits), (295, 384));
        // e6m1 narrow lane: 2 + 63 + 15 + 1 = 81 of 128 i128 bits.
        let narrow = find("acc-narrow-fit", FP8_E6M1.name);
        assert_eq!((narrow.required_bits, narrow.provided_bits), (81, 128));
        // EIA fast lane sits exactly at the i64 boundary: margin 0.
        let fast = obs
            .iter()
            .find(|o| o.id == "eia-fast-lane" && o.format == FP32.name)
            .unwrap();
        assert_eq!(fast.margin(), 0);
        // hw root width: the netlist grows one bit less than acc_width.
        let root = find("hw-root-width", FP32.name);
        assert_eq!(root.margin(), 1);
    }
}
