//! EIA acceptance battery (DESIGN.md §Accumulator): the exponent-indexed
//! accumulator's reconcile-and-round drain must be **bit-identical** to
//! the scalar `⊙` fold — the full `(λ, acc, sticky)` state — across all
//! five paper formats × the oracle's adversarial distributions × the
//! narrow-`i128` and wide-`WideInt` accumulator paths; snapshot merging at
//! arbitrary split points must equal one-shot banking; serialized
//! checkpoints must round-trip; and a dedicated ≥ 5k-vector-per-format
//! differential-oracle gate must run with **zero** mismatches against the
//! independent sign-magnitude reference. On top of the equivalence gates,
//! the deferred-alignment reproducibility property is pinned: even under
//! truncated specs the EIA result is ingest-order invariant, because
//! banking is exact and bits can only drop in the single drain.

use online_fp_add::accum::{merge::snapshot_terms, reduce_terms_eia, Eia, EiaSnapshot};
use online_fp_add::arith::adder::{Architecture, MultiTermAdder};
use online_fp_add::arith::kernel::scalar_fold;
use online_fp_add::arith::oracle::{reference_sum, DISTRIBUTIONS};
use online_fp_add::arith::AccSpec;
use online_fp_add::formats::{Fp, FpClass, FpFormat, BF16, FP32, PAPER_FORMATS};
use online_fp_add::reduce::{registry, BackendSel, ReducePlan};
use online_fp_add::util::prng::XorShift;

/// Exact spec plus, where the format's exact frame fits the i128 fast
/// path, the forced wide-`WideInt` variant — both must produce the same
/// bits as the fold does under the same spec.
fn exact_specs(fmt: FpFormat) -> Vec<AccSpec> {
    let exact = AccSpec::exact(fmt);
    let mut specs = vec![exact];
    if exact.narrow {
        specs.push(AccSpec { narrow: false, ..exact });
    }
    specs
}

#[test]
fn eia_drain_bit_matches_scalar_fold_all_formats_distributions_and_paths() {
    let mut rng = XorShift::new(0xE1A_0001);
    for fmt in PAPER_FORMATS {
        for spec in exact_specs(fmt) {
            for dist in DISTRIBUTIONS {
                for n in [1usize, 5, 16, 64, 200] {
                    let terms = dist.gen_vector(&mut rng, fmt, n);
                    let want = scalar_fold(&terms, spec);
                    assert_eq!(
                        reduce_terms_eia(&terms, spec),
                        want,
                        "{fmt} {} n={n} narrow={}",
                        dist.name(),
                        spec.narrow
                    );
                }
            }
        }
    }
}

#[test]
fn eia_oracle_gate_runs_clean_over_5k_vectors_per_format() {
    // The dedicated differential gate: ≥ 5k adversarial vectors per
    // format, rounded EIA results vs the independent big-int reference,
    // zero mismatches, on every exact accumulator path the format offers.
    let n = 16usize;
    for fmt in PAPER_FORMATS {
        let mut rng = XorShift::new(0xE1A_D1FF ^ ((fmt.ebits as u64) << 32));
        let specs = exact_specs(fmt);
        let mut checks = 0u64;
        let mut mismatches = 0u64;
        for v in 0..5_000usize {
            let dist = DISTRIBUTIONS[v % DISTRIBUTIONS.len()];
            let terms = dist.gen_vector(&mut rng, fmt, n);
            let expected = reference_sum(&terms, fmt);
            for &spec in &specs {
                let adder = MultiTermAdder {
                    format: fmt,
                    n_terms: n,
                    spec,
                    arch: Architecture::backend("eia").unwrap(),
                };
                checks += 1;
                if adder.add(&terms).bits != expected.bits {
                    mismatches += 1;
                }
            }
        }
        assert_eq!(mismatches, 0, "{fmt}: EIA oracle mismatches");
        assert!(checks >= 5_000, "{fmt}: only {checks} EIA checks ran");
    }
}

#[test]
fn snapshot_merge_at_arbitrary_split_points_equals_one_shot() {
    // Associativity of the deferred domain: chop a vector at random split
    // points, bank each piece into its own EIA, merge the snapshots in a
    // random binary grouping — the canonical snapshot, and therefore the
    // drained state, must equal one-shot banking of the whole vector.
    let mut rng = XorShift::new(0xE1A_0002);
    for fmt in PAPER_FORMATS {
        let spec = AccSpec::exact(fmt);
        for trial in 0..40 {
            let n = 2 + rng.below(260) as usize;
            let dist = DISTRIBUTIONS[trial % DISTRIBUTIONS.len()];
            let terms = dist.gen_vector(&mut rng, fmt, n);
            let whole = snapshot_terms(&terms);
            // 1..=4 random cut points -> up to 5 pieces (possibly empty).
            let mut cuts: Vec<usize> =
                (0..1 + rng.below(4) as usize).map(|_| rng.below(n as u64 + 1) as usize).collect();
            cuts.sort_unstable();
            let mut pieces: Vec<EiaSnapshot> = Vec::new();
            let mut start = 0usize;
            for &c in cuts.iter().chain(std::iter::once(&n)) {
                pieces.push(snapshot_terms(&terms[start..c]));
                start = c;
            }
            // Random parenthesisation: repeatedly merge a random adjacent
            // pair until one snapshot remains.
            while pieces.len() > 1 {
                let i = rng.below(pieces.len() as u64 - 1) as usize;
                let merged = pieces[i].merge(&pieces[i + 1]);
                pieces.remove(i + 1);
                pieces[i] = merged;
            }
            assert_eq!(pieces[0], whole, "{fmt} n={n} cuts={cuts:?}");
            assert_eq!(pieces[0].drain(spec), whole.drain(spec), "{fmt} n={n}");
            assert_eq!(whole.drain(spec), scalar_fold(&terms, spec), "{fmt} n={n}");
        }
    }
}

#[test]
fn snapshot_bytes_roundtrip_across_formats_and_restore() {
    let mut rng = XorShift::new(0xE1A_0003);
    for fmt in PAPER_FORMATS {
        let spec = AccSpec::exact(fmt);
        for (d, dist) in DISTRIBUTIONS.iter().enumerate() {
            let terms = dist.gen_vector(&mut rng, fmt, 32 + d);
            let snap = snapshot_terms(&terms);
            let back = EiaSnapshot::from_bytes(&snap.to_bytes()).expect("roundtrip");
            assert_eq!(back, snap, "{fmt} {}", dist.name());
            assert_eq!(back.drain(spec), snap.drain(spec));
            // Restoring a live accumulator and continuing to ingest equals
            // having banked everything into one accumulator.
            let extra = dist.gen_vector(&mut rng, fmt, 16);
            let mut resumed = back.restore();
            resumed.ingest_terms(&extra);
            let mut oneshot = Eia::new();
            oneshot.ingest_terms(&terms);
            oneshot.ingest_terms(&extra);
            assert_eq!(resumed.snapshot(), oneshot.snapshot(), "{fmt} {}", dist.name());
        }
    }
}

#[test]
fn truncated_eia_is_ingest_order_and_grouping_invariant() {
    // The reproducibility gate: under a truncated spec the online fold's
    // dropped-bit pattern depends on term order, but the EIA's cannot —
    // banking is exact; the only lossy step is the single drain over
    // per-exponent totals, which are order-free sums.
    let mut rng = XorShift::new(0xE1A_0004);
    for spec in [AccSpec::truncated(2), AccSpec::truncated(8), AccSpec::truncated(16)] {
        for _ in 0..60 {
            let mut terms: Vec<Fp> = (0..50).map(|_| rng.gen_fp_full(FP32)).collect();
            let want = reduce_terms_eia(&terms, spec);
            rng.shuffle(&mut terms);
            assert_eq!(reduce_terms_eia(&terms, spec), want, "order");
            // Grouped banking through snapshots drops the same bits.
            let cut = 1 + rng.below(terms.len() as u64 - 1) as usize;
            let grouped = snapshot_terms(&terms[..cut]).merge(&snapshot_terms(&terms[cut..]));
            assert_eq!(grouped.drain(spec), want, "grouping");
        }
    }
}

#[test]
fn eia_flows_through_every_seam_consumer() {
    use online_fp_add::stream::{reduce_chunk_with, EngineConfig, StreamEngine};
    use online_fp_add::workload::matmul::matmul_fused;

    let spec = AccSpec::exact(BF16);
    let mut rng = XorShift::new(0xE1A_0005);

    // The registry spelling parses through every addressing surface.
    let sel: BackendSel = "eia".parse().unwrap();
    assert_eq!(sel, registry::sel("eia").unwrap());
    assert_eq!(ReducePlan::with_backend(spec, sel).backend().name(), "eia");
    assert_eq!(Architecture::parse("eia", 16).unwrap(), Architecture::Backend(sel));
    // Truncated EIA plans advertise (and the builder can require) the
    // order-invariance capability no online backend has.
    let trunc_plan = ReducePlan::builder(AccSpec::truncated(4))
        .require_order_invariant()
        .build()
        .unwrap();
    assert_eq!(trunc_plan.backend().name(), "eia");

    // stream::segment::reduce_chunk_with.
    let scalar_plan = ReducePlan::with_backend(spec, registry::sel("scalar").unwrap());
    let eia_plan = ReducePlan::with_backend(spec, sel);
    let terms: Vec<Fp> = (0..200).map(|_| rng.gen_fp_sparse(BF16, 0.1)).collect();
    let want = reduce_chunk_with(&scalar_plan, &terms);
    assert_eq!(reduce_chunk_with(&eia_plan, &terms), want);

    // EngineConfig::backend — end to end through the threaded engine.
    let engine = StreamEngine::new(EngineConfig {
        threads: 4,
        chunk: 16,
        backend: Some(sel),
        ..Default::default()
    });
    assert_eq!(engine.plan().backend().name(), "eia");
    for row in terms.chunks(25) {
        engine.ingest_blocking("s", row.to_vec()).unwrap();
    }
    engine.quiesce();
    assert_eq!(engine.snapshot("s").unwrap().state(), want.state);

    // workload::matmul::matmul_fused — round-once dot products.
    let (m, k, n) = (3usize, 40usize, 4usize);
    let a: Vec<f32> = (0..m * k).map(|_| rng.gauss() as f32).collect();
    let b: Vec<f32> = (0..k * n).map(|_| rng.gauss() as f32).collect();
    let mspec = AccSpec::exact(FP32);
    let scalar = matmul_fused(
        &a,
        &b,
        (m, k, n),
        FP32,
        &ReducePlan::with_backend(mspec, registry::sel("scalar").unwrap()),
    );
    let eia = matmul_fused(
        &a,
        &b,
        (m, k, n),
        FP32,
        &ReducePlan::with_backend(mspec, registry::sel("eia").unwrap()),
    );
    for (s, e) in scalar.iter().zip(&eia) {
        assert_eq!(s.bits, e.bits, "matmul backends must be bit-identical on exact specs");
    }
}

#[test]
fn eia_adder_screens_special_values_like_every_architecture() {
    let adder = MultiTermAdder::exact(BF16, 4, Architecture::backend("eia").unwrap());
    let inf = Fp::overflow(false, BF16);
    let ninf = Fp::overflow(true, BF16);
    let nan = Fp::nan(BF16);
    let one = Fp::from_f64(1.0, BF16);
    assert_eq!(adder.add(&[one, nan, one, one]).class(), FpClass::Nan);
    assert_eq!(adder.add(&[inf, ninf, one, one]).class(), FpClass::Nan);
    assert_eq!(adder.add(&[inf, one, one, one]).class(), FpClass::Inf);
    let r = adder.add(&[ninf, one, one, one]);
    assert_eq!(r.class(), FpClass::Inf);
    assert!(r.sign());
    // Zero-padding of short inputs is transparent, as for every arch.
    assert_eq!(adder.add(&[one, one]).to_f64(), 2.0);
}

#[test]
fn eia_empty_and_degenerate_inputs() {
    let spec = AccSpec::exact(BF16);
    assert!(reduce_terms_eia(&[], spec).is_identity());
    let zeros = vec![Fp::zero(BF16); 9];
    assert!(reduce_terms_eia(&zeros, spec).is_identity());
    // Term counts still flow through snapshots for zero-only traffic.
    let snap = snapshot_terms(&zeros);
    assert!(snap.is_identity());
    assert_eq!(snap.terms, 9);
    assert_eq!(EiaSnapshot::from_bytes(&snap.to_bytes()).unwrap(), snap);
}
