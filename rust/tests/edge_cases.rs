//! Edge-case tests: special values, overflow/underflow, cancellation,
//! extreme exponent spreads (the FP8_e6m1 corner Table I probes), and API
//! misuse contracts.

use online_fp_add::arith::adder::{Architecture, MultiTermAdder};
use online_fp_add::arith::exact::exact_rounded_sum;
use online_fp_add::arith::tree::RadixConfig;
use online_fp_add::formats::{
    format_by_name, Fp, FpClass, BF16, FP32, FP8_E4M3, FP8_E5M2, FP8_E6M1, PAPER_FORMATS,
};

fn adder(fmt: online_fp_add::formats::FpFormat, n: usize) -> MultiTermAdder {
    MultiTermAdder::exact(fmt, n, Architecture::Tree(RadixConfig::binary(n as u32).unwrap()))
}

#[test]
fn empty_input_is_positive_zero() {
    for fmt in PAPER_FORMATS {
        let a = MultiTermAdder::exact(fmt, 16, Architecture::Baseline);
        let r = a.add(&[]);
        assert_eq!(r.class(), FpClass::Zero, "{fmt}");
        assert!(!r.sign(), "{fmt}");
    }
}

#[test]
fn single_term_passes_through_unchanged() {
    for fmt in PAPER_FORMATS {
        let a = adder(fmt, 16);
        for bits in [0u64, 1 << (fmt.width() - 1)] {
            let z = Fp::from_bits(bits, fmt);
            assert_eq!(a.add(&[z]).class(), FpClass::Zero);
        }
        let x = Fp::pack(false, fmt.max_normal_exp(), 0, fmt);
        assert_eq!(a.add(&[x]).bits, x.bits, "{fmt}");
        let tiny = Fp::pack(true, 1, 0, fmt);
        assert_eq!(a.add(&[tiny]).bits, tiny.bits, "{fmt}");
        // Subnormals pass through unchanged too (gradual underflow): both
        // the smallest and the largest subnormal of every format.
        let sub_min = Fp::pack(false, 0, 1, fmt);
        assert_eq!(a.add(&[sub_min]).bits, sub_min.bits, "{fmt}");
        let sub_max = Fp::pack(true, 0, fmt.mant_mask(), fmt);
        assert_eq!(a.add(&[sub_max]).bits, sub_max.bits, "{fmt}");
    }
}

#[test]
fn perfect_cancellation_across_architectures() {
    for fmt in PAPER_FORMATS {
        for arch in [
            Architecture::Baseline,
            Architecture::Online,
            Architecture::Tree("4-2".parse().unwrap()),
        ] {
            let a = MultiTermAdder::exact(fmt, 8, arch);
            let x = Fp::pack(false, fmt.bias() as i32, fmt.max_finite_mant() / 2, fmt);
            let nx = Fp::pack(true, x.raw_exp(), x.mant(), fmt);
            let r = a.add(&[x, nx, x, nx, x, nx, x, nx]);
            assert_eq!(r.class(), FpClass::Zero, "{fmt}");
            assert!(!r.sign(), "cancellation yields +0 ({fmt})");
        }
    }
}

#[test]
fn overflow_behaviour_per_format() {
    // IEEE formats overflow to Inf, NoInf formats saturate to max finite.
    for fmt in [FP32, BF16, FP8_E5M2] {
        let a = adder(fmt, 4);
        let big = Fp::pack(false, fmt.max_normal_exp(), fmt.max_finite_mant(), fmt);
        let r = a.add(&[big, big, big, big]);
        assert_eq!(r.class(), FpClass::Inf, "{fmt}");
        assert!(!r.sign());
    }
    for fmt in [FP8_E4M3, FP8_E6M1] {
        let a = adder(fmt, 4);
        let big = Fp::pack(true, fmt.max_normal_exp(), fmt.max_finite_mant(), fmt);
        let r = a.add(&[big, big, big, big]);
        assert_eq!(r.class(), FpClass::Normal, "{fmt} saturates");
        assert_eq!(r.raw_exp(), fmt.max_normal_exp(), "{fmt}");
        assert!(r.sign());
    }
}

#[test]
fn near_overflow_rounding_carry() {
    // A sum whose rounding carry crosses into the overflow region.
    let fmt = BF16;
    let a = adder(fmt, 2);
    let max = Fp::pack(false, fmt.max_normal_exp(), fmt.max_finite_mant(), fmt);
    // max + (ulp/2 of max) rounds up -> Inf.
    let half_ulp = Fp::pack(false, fmt.max_normal_exp() - 8, 0, fmt);
    let r = a.add(&[max, half_ulp]);
    assert_eq!(r.class(), FpClass::Inf);
}

#[test]
fn underflow_denormalizes_with_sign() {
    let fmt = FP32;
    let a = MultiTermAdder::exact(fmt, 2, Architecture::Baseline);
    let tiny = Fp::pack(false, 1, 0, fmt); // +2^-126
    let minus_1p5_tiny = Fp::pack(true, 1, 1 << 22, fmt); // -1.5·2^-126
    let r = a.add(&[tiny, minus_1p5_tiny]);
    // Gradual underflow: -0.5·2^-126 is exactly representable.
    assert_eq!(r.class(), FpClass::Subnormal);
    assert!(r.sign(), "the underflowed result keeps its sign");
    assert_eq!((r.raw_exp(), r.mant()), (0, 1 << 22));
}

#[test]
fn subnormal_operands_participate_in_every_architecture() {
    // A subnormal-only vector sums exactly in all architectures, and a
    // subnormal absorbed into a large term still drives sticky/rounding.
    for fmt in PAPER_FORMATS {
        for arch in [
            Architecture::Baseline,
            Architecture::Online,
            Architecture::Exact,
            Architecture::Tree("2-2".parse().unwrap()),
        ] {
            let a = MultiTermAdder::exact(fmt, 4, arch.clone());
            let sub = Fp::pack(false, 0, 1, fmt); // smallest subnormal
            let r = a.add(&[sub, sub, sub, sub]);
            // 4·2^(1-bias-mbits) is exactly representable in every paper
            // format (subnormal for wide mantissas, a small normal for
            // e5m2/e6m1) — and must not flush to zero.
            let want = Fp::from_f64(4.0 * sub.to_f64(), fmt);
            assert!(want.bits != 0, "{fmt}: expected a nonzero sum");
            assert_eq!(r.bits, want.bits, "{fmt} {arch:?}");
        }
    }
}

#[test]
fn e6m1_extreme_exponent_spread() {
    // The paper's corner-case format: 6-bit exponent, 1-bit mantissa —
    // alignment distances up to 62 dwarf the 2-bit significand.
    let fmt = FP8_E6M1;
    let a = adder(fmt, 16);
    let mut terms = vec![Fp::pack(false, 63, 0, fmt)]; // 2^32
    for e in 1..=15 {
        terms.push(Fp::pack(false, e, 1, fmt)); // tiny terms, all absorbed
    }
    let r = a.add(&terms);
    // Correct rounding: the tiny terms are below half an ULP of 2^32 in
    // aggregate? Σ 1.5·2^(e-31) for e=1..15 ≈ 2^-15 — far below ulp(2^32)=2^31.
    assert_eq!(r.bits, terms[0].bits, "tiny terms fully absorbed");
    // And the exact oracle agrees.
    assert_eq!(exact_rounded_sum(&terms, fmt).bits, terms[0].bits);
}

#[test]
fn e6m1_sticky_breaks_rne_tie() {
    let fmt = FP8_E6M1;
    let a = adder(fmt, 4);
    // 1.0·2^10 + 1.0·2^1: the small term is exactly at... build a tie case:
    // big = 1.0·2^k (mant 0); half-ulp term = 1.0·2^(k-2) (ulp(big)=2^(k-1-31)).
    let big = Fp::pack(false, 40, 0, fmt);
    let half_ulp = Fp::pack(false, 38, 0, fmt);
    // Exactly halfway -> ties to even -> stays at big (mant 0 is even).
    assert_eq!(a.add(&[big, half_ulp]).bits, big.bits);
    // Halfway plus a speck -> rounds up.
    let speck = Fp::pack(false, 20, 0, fmt);
    let r = a.add(&[big, half_ulp, speck]);
    assert_eq!(r.mant(), 1);
    assert_eq!(r.raw_exp(), 40);
}

#[test]
fn nan_and_inf_screening_in_every_architecture() {
    let fmt = FP8_E5M2;
    for arch in [
        Architecture::Baseline,
        Architecture::Online,
        Architecture::Exact,
        Architecture::Tree("2-2".parse().unwrap()),
    ] {
        let a = MultiTermAdder::exact(fmt, 4, arch);
        let one = Fp::from_f64(1.0, fmt);
        let nan = Fp::nan(fmt);
        let inf = Fp::overflow(false, fmt);
        let ninf = Fp::overflow(true, fmt);
        assert_eq!(a.add(&[nan, one, one, one]).class(), FpClass::Nan);
        assert_eq!(a.add(&[inf, ninf, one, one]).class(), FpClass::Nan);
        assert_eq!(a.add(&[inf, inf, one, one]).class(), FpClass::Inf);
    }
}

#[test]
fn format_lookup_rejects_unknown() {
    assert!(format_by_name("fp4").is_none());
    assert!(format_by_name("").is_none());
}

#[test]
#[should_panic(expected = "input lanes")]
fn too_many_terms_panics() {
    let a = MultiTermAdder::exact(BF16, 4, Architecture::Baseline);
    let one = Fp::from_f64(1.0, BF16);
    let _ = a.add(&[one; 5]);
}

#[test]
fn radix_config_validation() {
    assert!("0-4".parse::<RadixConfig>().is_err());
    assert!("4-x".parse::<RadixConfig>().is_err());
    assert!(RadixConfig::binary(12).is_err());
    assert!(RadixConfig::new(vec![]).is_err());
    // 4096-term cap.
    assert!(RadixConfig::new(vec![64, 64, 2]).is_err());
}

#[test]
fn zeros_never_perturb_lambda_or_sum() {
    // Interleave zeros everywhere; result must equal the dense sum.
    let fmt = BF16;
    let dense: Vec<Fp> = [1.5, -2.25, 1024.0, 0.0078125]
        .iter()
        .map(|&x| Fp::from_f64(x, fmt))
        .collect();
    let mut sparse = Vec::new();
    for t in &dense {
        sparse.push(Fp::zero(fmt));
        sparse.push(*t);
        sparse.push(Fp::from_bits(1 << (fmt.width() - 1), fmt)); // -0
    }
    let a_dense = MultiTermAdder::exact(fmt, 16, Architecture::Online);
    let a_sparse = MultiTermAdder::exact(fmt, 16, Architecture::Online);
    assert_eq!(a_dense.add(&dense).bits, a_sparse.add(&sparse).bits);
}
