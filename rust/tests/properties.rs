//! Property-based tests over the arithmetic core: the algebraic facts the
//! paper's construction rests on, checked bit-exactly over randomized
//! inputs with shrinking on failure (`util::proptest`).

use online_fp_add::arith::adder::{Architecture, MultiTermAdder};
use online_fp_add::arith::baseline::baseline_sum;
use online_fp_add::arith::exact::exact_rounded_sum;
use online_fp_add::arith::normalize::normalize_round;
use online_fp_add::arith::online::online_sum;
use online_fp_add::arith::operator::{op_combine, op_combine_many, AlignAcc};
use online_fp_add::arith::tree::{enumerate_configs, tree_sum, RadixConfig};
use online_fp_add::arith::AccSpec;
use online_fp_add::formats::{Fp, FpClass, FpFormat, BF16, FP32, PAPER_FORMATS};
use online_fp_add::util::proptest::{check, check_vec};
use online_fp_add::util::prng::XorShift;

fn random_fmt(rng: &mut XorShift) -> FpFormat {
    PAPER_FORMATS[rng.below(PAPER_FORMATS.len() as u64) as usize]
}

#[test]
fn prop_operator_associativity_random_parenthesisations() {
    // eq. 10 generalized: fold random binary parse trees over the same
    // leaves; in exact mode every parenthesisation gives the same state.
    check("⊙ associativity", 300, |g| {
        let fmt = random_fmt(&mut g.rng);
        let spec = AccSpec::exact(fmt);
        let n = 2 + g.rng.below(14) as usize;
        let leaves: Vec<AlignAcc> = g
            .fp_full_vec(fmt, n)
            .iter()
            .map(|t| AlignAcc::leaf(*t, spec))
            .collect();
        // Reference: left fold.
        let mut reference = leaves[0];
        for l in &leaves[1..] {
            reference = op_combine(&reference, l, spec);
        }
        // Random parenthesisation: repeatedly merge a random adjacent pair.
        let mut work = leaves;
        while work.len() > 1 {
            let i = g.rng.below(work.len() as u64 - 1) as usize;
            let merged = op_combine(&work[i], &work[i + 1], spec);
            work.remove(i + 1);
            work[i] = merged;
        }
        if work[0] != reference {
            return Err(format!("{fmt}: {:?} != {:?}", work[0], reference));
        }
        Ok(())
    });
}

#[test]
fn prop_permutation_invariance_exact() {
    check("permutation invariance", 300, |g| {
        let fmt = random_fmt(&mut g.rng);
        let spec = AccSpec::exact(fmt);
        let n = 1 + g.rng.below(32) as usize;
        let mut terms: Vec<Fp> = g.fp_full_vec(fmt, n);
        let a = baseline_sum(&terms, spec);
        g.rng.shuffle(&mut terms);
        let b = baseline_sum(&terms, spec);
        // λ and acc identical regardless of order (addition of exactly
        // represented values commutes).
        if a != b {
            return Err(format!("{fmt} n={n}: {a:?} != {b:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_every_tree_equals_oracle_after_rounding() {
    check("trees == correctly-rounded oracle", 120, |g| {
        let fmt = random_fmt(&mut g.rng);
        let n = [4u32, 8, 16][g.rng.below(3) as usize];
        let terms: Vec<Fp> = (0..n).map(|_| g.rng.gen_fp_sparse(fmt, 0.1)).collect();
        let oracle = exact_rounded_sum(&terms, fmt);
        let configs = enumerate_configs(n);
        let cfg = &configs[g.rng.below(configs.len() as u64) as usize];
        let adder = MultiTermAdder::exact(fmt, n as usize, Architecture::Tree(cfg.clone()));
        let got = adder.add(&terms);
        if got.bits != oracle.bits {
            return Err(format!("{fmt} {cfg}: {got:?} != {oracle:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_online_equals_baseline_every_format() {
    check("online == baseline", 300, |g| {
        let fmt = random_fmt(&mut g.rng);
        let spec = AccSpec::exact(fmt);
        let n = 1 + g.rng.below(64) as usize;
        let terms: Vec<Fp> = g.fp_full_vec(fmt, n);
        let a = baseline_sum(&terms, spec);
        let b = online_sum(&terms, spec);
        if a != b {
            return Err(format!("{fmt} n={n}"));
        }
        Ok(())
    });
}

#[test]
fn prop_negation_antisymmetry() {
    check("Σ(-x) == -Σ(x)", 200, |g| {
        let fmt = random_fmt(&mut g.rng);
        let n = 1 + g.rng.below(16) as usize;
        let terms: Vec<Fp> = (0..n).map(|_| g.rng.gen_fp_normal(fmt)).collect();
        let neg: Vec<Fp> = terms
            .iter()
            .map(|t| Fp::from_bits(t.bits ^ (1 << (fmt.width() - 1)), fmt))
            .collect();
        let s = exact_rounded_sum(&terms, fmt);
        let sn = exact_rounded_sum(&neg, fmt);
        match (s.class(), sn.class()) {
            (FpClass::Zero, FpClass::Zero) => Ok(()),
            _ => {
                let flipped = s.bits ^ (1u64 << (fmt.width() - 1));
                if flipped == sn.bits {
                    Ok(())
                } else {
                    Err(format!("{fmt}: {s:?} vs {sn:?}"))
                }
            }
        }
    });
}

#[test]
fn prop_power_of_two_scaling() {
    check("Σ(2^k·x) == 2^k·Σ(x)", 200, |g| {
        let fmt = BF16;
        let n = 1 + g.rng.below(8) as usize;
        // Keep exponents central so scaling cannot overflow/underflow.
        let terms: Vec<Fp> = (0..n)
            .map(|_| {
                let e = g.rng.range_i64(100, 150) as i32;
                let m = g.rng.next_u64() & fmt.mant_mask();
                Fp::pack(g.rng.next_u64() & 1 == 1, e, m, fmt)
            })
            .collect();
        let k = g.rng.range_i64(-20, 20) as i32;
        let scaled: Vec<Fp> = terms
            .iter()
            .map(|t| Fp::pack(t.sign(), t.raw_exp() + k, t.mant(), fmt))
            .collect();
        let s = exact_rounded_sum(&terms, fmt);
        let ss = exact_rounded_sum(&scaled, fmt);
        if s.class() == FpClass::Zero && ss.class() == FpClass::Zero {
            return Ok(());
        }
        if s.class() != FpClass::Normal || ss.class() != FpClass::Normal {
            return Ok(()); // scaled sum left the normal range; skip
        }
        if ss.raw_exp() - s.raw_exp() == k && ss.mant() == s.mant() && ss.sign() == s.sign() {
            Ok(())
        } else {
            Err(format!("k={k}: {s:?} vs {ss:?}"))
        }
    });
}

#[test]
fn prop_truncated_mode_error_is_bounded() {
    // With the hw-default guard, every architecture stays within 2 ULP of
    // the correctly-rounded sum on full-range random data.
    check("truncated error bound", 150, |g| {
        let fmt = random_fmt(&mut g.rng);
        let n = 16usize;
        let terms: Vec<Fp> = (0..n).map(|_| g.rng.gen_fp_sparse(fmt, 0.1)).collect();
        let oracle = exact_rounded_sum(&terms, fmt);
        if oracle.class() != FpClass::Normal {
            return Ok(()); // cancellation to zero can lose everything in hw
        }
        for arch in [
            Architecture::Baseline,
            Architecture::Tree("4-4".parse().unwrap()),
        ] {
            let adder = MultiTermAdder::hw(fmt, n, arch.clone());
            let got = adder.add(&terms);
            // Compare as scaled integers when both normal.
            if got.class() == FpClass::Normal {
                let diff = (got.bits as i64 - oracle.bits as i64).abs();
                // Massive cancellation amplifies the truncated datapath's
                // absolute error into many result ULPs; bound the usual
                // case and skip deep-cancellation cases (they are covered
                // by the absolute-error bound in unit tests).
                let emax = terms
                    .iter()
                    .filter(|t| t.class() == FpClass::Normal)
                    .map(|t| t.raw_exp())
                    .max()
                    .unwrap_or(0);
                if emax - oracle.raw_exp() > 2 {
                    continue;
                }
                if diff > 2 {
                    return Err(format!("{fmt} {arch:?}: {got:?} vs {oracle:?}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_shift_composition_on_wideint() {
    use online_fp_add::arith::wide::WideInt;
    check("(x≫a)≫b == x≫(a+b) with sticky OR", 500, |g| {
        let v = WideInt::from_i64(g.rng.next_u64() as i64).shl(g.rng.below(200) as u32);
        let a = g.rng.below(130) as u32;
        let b = g.rng.below(130) as u32;
        let (r1, s1a) = v.shr_sticky(a);
        let (r1, s1b) = r1.shr_sticky(b);
        let (r2, s2) = v.shr_sticky(a + b);
        if r1 != r2 || (s1a || s1b) != s2 {
            return Err(format!("a={a} b={b}"));
        }
        Ok(())
    });
}

#[test]
fn prop_two_term_addition_matches_native_f32() {
    // Over the FULL operand space — subnormals and signed zeros included —
    // the exact-mode two-term sum must bit-match native f32 addition, with
    // no flush-to-zero escape hatch: subnormal results are exact.
    check("2-term FP32 == native f32 +", 2000, |g| {
        let spec = AccSpec::exact(FP32);
        let a = g.fp_full(FP32);
        let b = g.fp_full(FP32);
        if a.class() == FpClass::Zero && b.class() == FpClass::Zero {
            // Fused adders round all-zero sums to +0; a native IEEE
            // two-operand add keeps -0 for (-0) + (-0). Documented
            // deviation (formats module docs) — skip.
            return Ok(());
        }
        let r = normalize_round(&baseline_sum(&[a, b], spec), spec, FP32);
        let native = (a.to_f64() as f32) + (b.to_f64() as f32);
        let got = r.to_f64() as f32;
        if got.to_bits() != native.to_bits() {
            return Err(format!("{a:?} + {b:?}: {got:e} vs {native:e}"));
        }
        Ok(())
    });
}

#[test]
fn prop_identity_is_neutral_over_full_operand_space() {
    // identity ⊙ x == x for every finite leaf — subnormals (λ = 1, hidden
    // bit 0) and signed zeros included — in both operand orders and inside
    // a radix-many node padded with identities.
    check("identity ⊙ x == x (full space)", 500, |g| {
        let fmt = random_fmt(&mut g.rng);
        let spec = AccSpec::exact(fmt);
        let x = AlignAcc::leaf(g.fp_full(fmt), spec);
        let l = op_combine(&AlignAcc::IDENTITY, &x, spec);
        let r = op_combine(&x, &AlignAcc::IDENTITY, spec);
        if l != x || r != x {
            return Err(format!("{fmt}: {l:?} / {r:?} != {x:?}"));
        }
        let padded = op_combine_many(
            &[AlignAcc::IDENTITY, x, AlignAcc::IDENTITY, AlignAcc::IDENTITY],
            spec,
        );
        if padded != x {
            return Err(format!("{fmt}: radix-many padding perturbed {x:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_exact_trees_match_kulisch_over_full_operand_space() {
    // Exact-mode ⊙-trees == the Kulisch window oracle over the full
    // operand space, including subnormal-dense vectors, signed zeros, and
    // results that underflow gradually.
    check("⊙-tree == Kulisch (full space)", 250, |g| {
        let fmt = random_fmt(&mut g.rng);
        let n = [4u32, 8, 16][g.rng.below(3) as usize];
        let terms = g.fp_full_vec(fmt, n as usize);
        let oracle = exact_rounded_sum(&terms, fmt);
        let configs = enumerate_configs(n);
        let cfg = &configs[g.rng.below(configs.len() as u64) as usize];
        for arch in [
            Architecture::Baseline,
            Architecture::Online,
            Architecture::Tree(cfg.clone()),
        ] {
            let adder = MultiTermAdder::exact(fmt, n as usize, arch.clone());
            let got = adder.add(&terms);
            if got.bits != oracle.bits {
                return Err(format!("{fmt} {arch:?}: {got:?} != {oracle:?}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_shrinking_vector_interface_works_on_adders() {
    // Exercise check_vec on a real adder property (it must PASS; the
    // shrinking machinery itself is covered by util::proptest unit tests).
    check_vec(
        "tree == baseline over shrinkable vectors",
        50,
        |rng| {
            let n = 8usize;
            (0..n).map(|_| rng.gen_fp_normal(BF16)).collect::<Vec<Fp>>()
        },
        |terms| {
            if terms.len() != 8 {
                return Ok(()); // shrunk lengths are padded by the adder
            }
            let spec = AccSpec::exact(BF16);
            let t = tree_sum(terms, &RadixConfig::binary(8).unwrap(), spec);
            let b = baseline_sum(terms, spec);
            if t == b {
                Ok(())
            } else {
                Err("mismatch".into())
            }
        },
    );
}

#[test]
fn prop_narrow_fast_path_is_bit_identical_to_wide_path() {
    // §Perf invariant: the i128 fast path must agree with the 384-bit
    // reference path on the full (λ, acc, sticky) state.
    check("narrow == wide", 400, |g| {
        let fmt = random_fmt(&mut g.rng);
        let guard = 2 + g.rng.below(30) as u32;
        let narrow = AccSpec::truncated(guard);
        assert!(narrow.narrow);
        let wide = AccSpec { narrow: false, ..narrow };
        let n = [2usize, 4, 8, 16][g.rng.below(4) as usize];
        let terms: Vec<Fp> = g.fp_full_vec(fmt, n);
        let cfgs = enumerate_configs(n as u32);
        let cfg = &cfgs[g.rng.below(cfgs.len() as u64) as usize];
        let a = tree_sum(&terms, cfg, narrow);
        let b = tree_sum(&terms, cfg, wide);
        if a != b {
            return Err(format!("{fmt} {cfg} guard={guard}: {a:?} != {b:?}"));
        }
        let a = baseline_sum(&terms, narrow);
        let b = baseline_sum(&terms, wide);
        if a != b {
            return Err(format!("baseline {fmt} guard={guard}"));
        }
        let a = online_sum(&terms, narrow);
        let b = online_sum(&terms, wide);
        if a != b {
            return Err(format!("online {fmt} guard={guard}"));
        }
        Ok(())
    });
}

#[test]
fn prop_backend_and_architecture_display_parse_roundtrip() {
    // CLI flags and config files address backends/architectures by their
    // printed form; the spelling must never drift from the parser — every
    // `Display` output (including `kernel:<block>`) reparses to the same
    // value. Backends are drawn from the registry, so a newly registered
    // backend is round-trip-pinned automatically.
    use online_fp_add::reduce::{registry, BackendSel};
    check("Display ↔ parse round-trip", 600, |g| {
        let entries = registry::entries();
        let entry = &entries[g.rng.below(entries.len() as u64) as usize];
        let sel = if entry.takes_block {
            entry
                .sel()
                .with_block(1 + g.rng.below(4096) as usize)
                .map_err(|e| format!("block selection: {e}"))?
        } else {
            entry.sel()
        };
        let printed = sel.to_string();
        let reparsed: BackendSel =
            printed.parse().map_err(|e: String| format!("backend {printed:?}: {e}"))?;
        if reparsed != sel {
            return Err(format!("backend {sel:?} printed {printed:?} reparsed {reparsed:?}"));
        }
        let n = [4u32, 8, 16, 32][g.rng.below(4) as usize];
        let arch = match g.rng.below(5) {
            0 => Architecture::Baseline,
            1 => Architecture::Online,
            2 => Architecture::Exact,
            3 => Architecture::Backend(sel),
            _ => {
                let cfgs = enumerate_configs(n);
                Architecture::Tree(cfgs[g.rng.below(cfgs.len() as u64) as usize].clone())
            }
        };
        let printed = arch.to_string();
        let reparsed =
            Architecture::parse(&printed, n).map_err(|e| format!("arch {printed:?}: {e}"))?;
        if reparsed != arch {
            return Err(format!("arch {arch:?} printed {printed:?} reparsed {reparsed:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_monotone_growing_one_operand_never_decreases_the_sum() {
    // Monotonicity of multi-term adders (Mikaitis, 2023): a fused adder
    // that accumulates exactly and normalizes/rounds ONCE is monotone in
    // every operand — RNE is a monotone rounding and the exact datapath
    // sums are ordered with the operands. Pin it across **every backend
    // the registry knows** (iterated, not hand-listed) over the full
    // operand space, subnormals and signed zeros included.
    use online_fp_add::reduce::{registry, ReducePlan};
    check("monotone in each operand", 500, |g| {
        let fmt = random_fmt(&mut g.rng);
        let spec = AccSpec::exact(fmt);
        let n = 2 + g.rng.below(24) as usize;
        let mut terms: Vec<Fp> = g.fp_full_vec(fmt, n);
        let i = g.rng.below(n as u64) as usize;
        let (a, b) = (terms[i], g.fp_full(fmt));
        let (small, large) = if a.to_f64() <= b.to_f64() { (a, b) } else { (b, a) };
        for entry in registry::entries() {
            let plan = ReducePlan::with_backend(spec, entry.sel());
            terms[i] = small;
            let lo = normalize_round(&plan.reduce(&terms), spec, fmt).to_f64();
            terms[i] = large;
            let hi = normalize_round(&plan.reduce(&terms), spec, fmt).to_f64();
            if hi < lo {
                return Err(format!(
                    "{fmt} {}: growing lane {i} from {small:?} to {large:?} \
                     dropped the sum {lo} -> {hi}",
                    entry.name
                ));
            }
        }
        Ok(())
    });
}
