//! Cross-model consistency: the hardware models must agree with the
//! bit-accurate arithmetic and obey basic monotonicity laws.

use online_fp_add::arith::tree::{enumerate_configs, tree_sum};
use online_fp_add::arith::AccSpec;
use online_fp_add::formats::{Fp, BF16, FP32, FP8_E5M2, PAPER_FORMATS};
use online_fp_add::hw::datapath::{build_adder, DatapathParams};
use online_fp_add::hw::design::{attach_power, evaluate_area};
use online_fp_add::hw::pipeline::{min_clock_ns, pipeline};
use online_fp_add::hw::power::ActivitySim;
use online_fp_add::util::prng::XorShift;
use online_fp_add::workload::bert::power_trace;

#[test]
fn activity_sim_matches_arith_for_every_config_and_format() {
    let mut rng = XorShift::new(0xCC);
    for fmt in [BF16, FP8_E5M2] {
        let n = 16u32;
        let spec = AccSpec::hw_default(fmt, n as usize);
        let params = DatapathParams::new(fmt, n, spec);
        for cfg in enumerate_configs(n) {
            let mut sim = ActivitySim::new(params, &cfg);
            for _ in 0..20 {
                let ts: Vec<Fp> =
                    (0..n).map(|_| rng.gen_fp_sparse(fmt, 0.1)).collect();
                sim.step(&ts);
                let want = tree_sum(&ts, &cfg, spec);
                let (lam, acc) = sim.last_state();
                assert_eq!(lam, want.lambda as i64, "{fmt} {cfg}");
                assert_eq!(acc, want.acc.to_i128(), "{fmt} {cfg}");
            }
        }
    }
}

#[test]
fn activity_sim_handles_fp32_64_terms() {
    // The widest paper configuration (i128 accumulator path).
    let mut rng = XorShift::new(0xCD);
    let spec = AccSpec::hw_default(FP32, 64);
    let params = DatapathParams::new(FP32, 64, spec);
    let cfg = "8-4-2".parse().unwrap();
    let mut sim = ActivitySim::new(params, &cfg);
    for _ in 0..10 {
        let ts: Vec<Fp> = (0..64).map(|_| rng.gen_fp_sparse(FP32, 0.05)).collect();
        sim.step(&ts);
        let want = tree_sum(&ts, &cfg, spec);
        assert_eq!(sim.last_state().0, want.lambda as i64);
        assert_eq!(sim.last_state().1, want.acc.to_i128());
    }
}

#[test]
fn min_clock_is_monotone_in_stage_count() {
    for cfg in ["16", "8-2", "2-2-2-2"] {
        let c = cfg.parse().unwrap();
        let params = DatapathParams::new(BF16, 16, AccSpec::hw_default(BF16, 16));
        let adder = build_adder(params, &c);
        let mut prev = f64::INFINITY;
        for k in 1..=5u32 {
            let t = min_clock_ns(&adder, k);
            assert!(t <= prev + 1e-9, "{cfg}: stages {k} clock {t} > {prev}");
            prev = t;
        }
    }
}

#[test]
fn relaxing_the_clock_never_increases_registers() {
    let params = DatapathParams::new(BF16, 32, AccSpec::hw_default(BF16, 32));
    let adder = build_adder(params, &"8-2-2".parse().unwrap());
    let base = min_clock_ns(&adder, 3);
    let mut prev_bits = u64::MAX;
    for mult in [1.01, 1.3, 1.8, 2.5] {
        let p = pipeline(&adder, 3, base * mult).unwrap();
        assert!(p.reg_bits <= prev_bits, "clock {mult}x: {} > {prev_bits}", p.reg_bits);
        prev_bits = p.reg_bits;
    }
}

#[test]
fn area_grows_with_precision_and_terms() {
    let mut prev = 0.0;
    for fmt in [online_fp_add::formats::FP8_E4M3, BF16, FP32] {
        let p = evaluate_area(fmt, 16, &online_fp_add::arith::tree::RadixConfig::baseline(16), 1.0);
        assert!(p.area_um2 > prev, "{fmt}");
        prev = p.area_um2;
    }
    let a16 = evaluate_area(BF16, 16, &online_fp_add::arith::tree::RadixConfig::baseline(16), 1.0);
    let a64 = evaluate_area(BF16, 64, &online_fp_add::arith::tree::RadixConfig::baseline(64), 1.0);
    assert!(a64.area_um2 > 3.0 * a16.area_um2);
}

#[test]
fn every_paper_format_evaluates_with_power() {
    for fmt in PAPER_FORMATS {
        let trace = power_trace(fmt, 16, 48, 9);
        let mut p = evaluate_area(fmt, 16, &"4-4".parse().unwrap(), 1.0);
        attach_power(&mut p, &trace.vectors);
        let mw = p.power_mw.unwrap();
        assert!(mw > 0.0 && mw < 100.0, "{fmt}: {mw} mW");
    }
}

#[test]
fn idle_trace_draws_less_power_than_busy_trace() {
    let params = DatapathParams::new(BF16, 16, AccSpec::hw_default(BF16, 16));
    let cfg = "4-4".parse().unwrap();
    let mut rng = XorShift::new(4);
    let busy: Vec<Vec<Fp>> =
        (0..200).map(|_| (0..16).map(|_| rng.gen_fp_normal(BF16)).collect()).collect();
    let idle: Vec<Vec<Fp>> = (0..200).map(|_| vec![Fp::zero(BF16); 16]).collect();
    let mut sim_busy = ActivitySim::new(params, &cfg);
    let mut sim_idle = ActivitySim::new(params, &cfg);
    for (b, i) in busy.iter().zip(&idle) {
        sim_busy.step(b);
        sim_idle.step(i);
    }
    assert!(sim_idle.power_mw(1.0, None) < 0.2 * sim_busy.power_mw(1.0, None));
}
