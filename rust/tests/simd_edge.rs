//! SIMD-backend edge battery (DESIGN.md §Kernel, SIMD subsection): the
//! vectorized kernel must be **bit-identical** — full `[λ; acc; sticky]`
//! state — to the scalar SoA kernel at every (spec, block) pair, and to
//! the scalar `⊙` fold wherever the kernel is. The edges this file owns
//! are the ones vectorization invents: lane tails at non-multiple-of-8
//! lengths, blocks smaller than one vector, all-dead-lane vectors, the
//! far-spread chunk fallback, and the narrow/wide path boundary. Whatever
//! dispatch leg the host machine selects (AVX2, portable-SIMD, scalar
//! fallback), the same bits must come out.

use online_fp_add::arith::kernel::{reduce_terms, scalar_fold, DEFAULT_BLOCK};
use online_fp_add::arith::oracle::DISTRIBUTIONS;
use online_fp_add::arith::simd::{
    active_paths, block_state_simd, reduce_terms_simd, LANES, VEC_NARROW_MAX_F,
};
use online_fp_add::arith::AccSpec;
use online_fp_add::formats::{Fp, PAPER_FORMATS};
use online_fp_add::reduce::{registry, KernelReducer, Reducer, SimdReducer};
use online_fp_add::util::prng::XorShift;

/// The exact spec plus its forced-wide twin, plus truncated frames that
/// bracket the vector sub-path ceiling (f <= VEC_NARROW_MAX_F) from both
/// sides — the battery must cross the path boundary, not sit on one side.
fn specs_under_test(fmt: online_fp_add::formats::FpFormat) -> Vec<AccSpec> {
    let exact = AccSpec::exact(fmt);
    let mut specs = vec![exact];
    if exact.narrow {
        specs.push(AccSpec { narrow: false, ..exact });
    }
    specs.push(AccSpec::truncated(3));
    specs.push(AccSpec::truncated(16));
    specs.push(AccSpec::truncated(VEC_NARROW_MAX_F + 5));
    specs
}

#[test]
fn simd_is_registered_and_parses_with_blocks() {
    assert!(registry::names().contains(&"simd"));
    let sel = registry::sel("simd:8").unwrap();
    assert_eq!(sel.name(), "simd");
    assert_eq!(sel.block(), Some(8));
    assert_eq!(registry::sel("simd").unwrap().block(), Some(DEFAULT_BLOCK));
    // Capabilities are the kernel's: same proved widths, same honesty
    // about truncated-frame fold identity at block > 1.
    for fmt in PAPER_FORMATS {
        let spec = AccSpec::exact(fmt);
        let simd = registry::sel("simd:7").unwrap().capabilities(spec);
        let kernel = registry::sel("kernel:7").unwrap().capabilities(spec);
        assert_eq!(simd.proved_acc_bits, kernel.proved_acc_bits, "{fmt}");
        assert_eq!(simd.storage_acc_bits, kernel.storage_acc_bits, "{fmt}");
        assert_eq!(simd.fold_bit_identical, kernel.fold_bit_identical, "{fmt}");
    }
    // The dispatch report names at least one live leg.
    assert!(!active_paths().is_empty(), "dispatch: {}", active_paths());
}

#[test]
fn lane_tails_and_tiny_blocks_match_the_kernel_bit_for_bit() {
    // Lengths that straddle every tail shape around the 8-lane vector
    // width, crossed with blocks smaller than one vector (1..7), at one
    // vector (8), and beyond — against the scalar kernel at the same
    // block, which is the bit-identity contract at *every* (spec, block).
    let mut rng = XorShift::new(0x51D0);
    let lens: Vec<usize> =
        vec![0, 1, 2, 5, 7, 8, 9, 15, 16, 17, 23, 31, 33, 63, 64, 65, 100, 130];
    for fmt in PAPER_FORMATS {
        for spec in specs_under_test(fmt) {
            for &n in &lens {
                let terms: Vec<Fp> = (0..n).map(|_| rng.gen_fp_full(fmt)).collect();
                for block in [1usize, 2, 3, 5, 7, 8, 13, 64, n.max(1)] {
                    assert_eq!(
                        reduce_terms_simd(&terms, block, spec),
                        reduce_terms(&terms, block, spec),
                        "{fmt} n={n} block={block} f={} narrow={}",
                        spec.f,
                        spec.narrow
                    );
                }
            }
        }
    }
}

#[test]
fn simd_matches_the_scalar_fold_wherever_the_kernel_does() {
    // Exact specs: the kernel is fold-bit-identical at every block, so the
    // SIMD backend must be too — against the fold directly, all 5 formats.
    let mut rng = XorShift::new(0xF01D);
    for fmt in PAPER_FORMATS {
        let spec = AccSpec::exact(fmt);
        for n in [1usize, 7, 9, 64, 131] {
            let terms: Vec<Fp> = (0..n).map(|_| rng.gen_fp_full(fmt)).collect();
            let want = scalar_fold(&terms, spec);
            for block in [1usize, 3, 8, 64, n] {
                assert_eq!(
                    reduce_terms_simd(&terms, block, spec),
                    want,
                    "{fmt} n={n} block={block}"
                );
            }
        }
    }
}

#[test]
fn adversarial_distributions_cannot_split_simd_from_the_kernel() {
    // The oracle's adversarial generators (subnormal-dense, cancellation,
    // near-overflow) through the vector path, the far-spread fallback and
    // the wide path — zero state mismatches against the scalar kernel.
    let mut rng = XorShift::new(0xADE5);
    for fmt in PAPER_FORMATS {
        for dist in DISTRIBUTIONS {
            for spec in specs_under_test(fmt) {
                for _ in 0..20 {
                    let n = 61; // deliberately not a lane multiple
                    let terms = dist.gen_vector(&mut rng, fmt, n);
                    for block in [1usize, 7, 8, 64] {
                        assert_eq!(
                            reduce_terms_simd(&terms, block, spec),
                            reduce_terms(&terms, block, spec),
                            "{fmt} {} block={block} narrow={}",
                            dist.name(),
                            spec.narrow
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn all_dead_lane_vectors_and_adversarial_exponents_are_identities() {
    // Whole vectors of sig == 0 lanes — including eff values a decoder
    // would never emit (i32::MIN, i32::MAX) — must produce the identity,
    // and a single live lane among 15 dead ones must produce exactly that
    // lane's lift, through both the block sweep and the Reducer lifecycle.
    let spec = AccSpec::truncated(16);
    let dead_eff: Vec<i32> =
        (0..16).map(|i| [i32::MIN, -1, 0, i32::MAX][i % 4]).collect();
    let dead_sig = vec![0i64; 16];
    let acc = block_state_simd(&dead_eff, &dead_sig, spec);
    assert!(acc.is_identity(), "all-dead vector must be the identity: {acc:?}");

    let mut eff = dead_eff.clone();
    let mut sig = dead_sig.clone();
    eff[11] = 42;
    sig[11] = -7;
    let one = block_state_simd(&eff, &sig, spec);
    assert_eq!(one.lambda, 42);
    assert!(!one.sticky);

    for fmt in PAPER_FORMATS {
        for spec in specs_under_test(fmt) {
            for block in [1usize, 3, 8, 48] {
                let mut s = SimdReducer::new(spec, block);
                let mut k = KernelReducer::new(spec, block);
                s.ingest_decoded(&eff, &sig);
                k.ingest_decoded(&eff, &sig);
                assert_eq!(
                    s.finish(),
                    k.finish(),
                    "{fmt} block={block} narrow={}",
                    spec.narrow
                );
                assert_eq!(s.finish(), one, "{fmt} block={block} narrow={}", spec.narrow);
            }
        }
    }
}

#[test]
fn reducer_lifecycle_matches_the_kernel_reducer_over_mixed_ingests() {
    // Interleaved slice ingests of ragged lengths (block boundaries
    // restart per ingest), partial round-trips, and finish — the stateful
    // surface the stream tier drives — against KernelReducer at the same
    // block.
    let mut rng = XorShift::new(0xC0DE);
    for fmt in PAPER_FORMATS {
        for spec in specs_under_test(fmt) {
            for block in [1usize, 5, 8, 64] {
                let mut s = SimdReducer::new(spec, block);
                let mut k = KernelReducer::new(spec, block);
                for len in [3usize, 17, 1, 8, 29] {
                    let terms: Vec<Fp> = (0..len).map(|_| rng.gen_fp_full(fmt)).collect();
                    s.ingest(&terms);
                    k.ingest(&terms);
                }
                assert_eq!(s.terms(), k.terms());
                assert_eq!(
                    s.partial().resolve(spec),
                    k.partial().resolve(spec),
                    "{fmt} block={block} narrow={}",
                    spec.narrow
                );
                assert_eq!(s.finish(), k.finish(), "{fmt} block={block} narrow={}", spec.narrow);
            }
        }
    }
}

#[test]
fn oracle_scale_differential_simd_vs_kernel_and_fold() {
    // The >=5k-vector differential sweep the issue gates on: randomized
    // lengths and blocks, exact and truncated frames, SIMD vs kernel
    // everywhere and vs the fold on exact frames. LANES is compile-time 8;
    // keep the sweep crossing its multiples.
    assert_eq!(LANES, 8);
    let mut rng = XorShift::new(0x5CA1E);
    let mut vectors = 0usize;
    while vectors < 5200 {
        for fmt in PAPER_FORMATS {
            let n = 1 + rng.below(97) as usize;
            let terms: Vec<Fp> = (0..n).map(|_| rng.gen_fp_full(fmt)).collect();
            let block = 1 + rng.below(70) as usize;
            let exact = AccSpec::exact(fmt);
            let got = reduce_terms_simd(&terms, block, exact);
            assert_eq!(got, reduce_terms(&terms, block, exact), "{fmt} n={n} block={block}");
            assert_eq!(got, scalar_fold(&terms, exact), "{fmt} n={n} block={block}");
            let trunc = AccSpec::truncated(1 + rng.below(40) as u32);
            assert_eq!(
                reduce_terms_simd(&terms, block, trunc),
                reduce_terms(&terms, block, trunc),
                "{fmt} n={n} block={block} f={}",
                trunc.f
            );
            vectors += 2;
        }
    }
}
