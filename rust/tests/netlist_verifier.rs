//! Netlist-verifier gate (DESIGN.md §Analysis, netlist tier): the CI-facing
//! battery behind `repro analyze --netlist --gate` and `repro dse`.
//!
//! 1. **All green on the generated suite** — the netlist obligation
//!    families pass for every paper format over the serial baseline and
//!    every [`SUITE_RADICES`] online tree, and the extended artifact is
//!    byte-deterministic.
//! 2. **The gate can fail** — every seeded `net-*` fault breaks at least
//!    one obligation, and the faulted artifact still serializes.
//! 3. **Pipeline properties** — over every generated netlist and several
//!    depths, the stage assignment is monotone along every edge, nodes of
//!    one region share a stage, and an independent register-bit recount
//!    over the edge list matches the scheduler's report exactly.
//! 4. **The DSE artifact** — the serial-vs-online sweep renders a
//!    byte-deterministic `ofa-dse-v1` report with a summary row per format.

use online_fp_add::analysis::{self, netlist, StorageEnv};
use online_fp_add::coordinator::Coordinator;
use online_fp_add::dse;
use online_fp_add::formats::PAPER_FORMATS;
use online_fp_add::hw::generate::{generate_suite, SUITE_RADICES};
use online_fp_add::hw::pipeline::{min_clock_ns, paper_stages, pipeline};
use std::collections::HashMap;

#[test]
fn netlist_obligations_all_green_over_the_generated_suite() {
    let report = analysis::analyze_netlist(&StorageEnv::actual(), None);
    let failed = report.failed();
    assert!(
        failed.is_empty(),
        "netlist obligations failed: {:?}",
        failed.iter().map(|o| format!("{}/{}/{}", o.format, o.backend, o.id)).collect::<Vec<_>>()
    );
    // Every family × format × suite entry is present.
    for fam in [
        "netlist-structure",
        "netlist-sta-slack",
        "netlist-sta-critical",
        "netlist-width-bridge",
        "netlist-bus-bridge",
        "netlist-pipeline-monotone",
        "netlist-pipeline-regbits",
    ] {
        for fmt in PAPER_FORMATS {
            let count = report
                .obligations
                .iter()
                .filter(|o| o.id == fam && o.format == fmt.name)
                .count();
            assert_eq!(count, 1 + SUITE_RADICES.len(), "{fam} x {}", fmt.name);
        }
    }
    // The software families are still all there, in front.
    assert!(report.obligations.iter().any(|o| o.id == "acc-width"));
    assert!(report.obligations[0].id != "netlist-structure");
}

#[test]
fn netlist_artifact_is_byte_deterministic() {
    let render = || analysis::analyze_netlist(&StorageEnv::actual(), None).to_json();
    let (a, b) = (render(), render());
    assert_eq!(a, b, "two netlist-extended renders differ");
    assert!(a.contains("\"id\": \"netlist-width-bridge\""));
    assert!(a.contains("\"backend\": \"nl:8-2\""));
}

#[test]
fn every_seeded_netlist_fault_trips_the_gate() {
    for name in netlist::NetlistFault::fault_names() {
        let fault = netlist::NetlistFault::from_name(name).expect("known fault name");
        let report = analysis::analyze_netlist(&StorageEnv::actual(), Some(fault));
        let failed = report.failed();
        assert!(!failed.is_empty(), "seeded fault {name:?} left every obligation green");
        assert!(
            failed.iter().all(|o| o.id.starts_with("netlist-")),
            "netlist fault {name:?} broke a software obligation: {:?}",
            failed.iter().map(|o| o.id).collect::<Vec<_>>()
        );
        assert!(report.to_json().contains("\"pass\": false"));
    }
    assert!(netlist::NetlistFault::from_name("no-such-fault").is_none());
}

/// Satellite property battery over `hw::pipeline`: stage monotonicity,
/// region atomicity, and register-bit accounting, for every paper format,
/// every suite config, and three depths.
#[test]
fn pipeline_stage_assignment_properties_hold_over_generated_netlists() {
    for fmt in PAPER_FORMATS {
        for adder in generate_suite(fmt, netlist::VERIFY_TERMS) {
            let policy = paper_stages(fmt, netlist::VERIFY_TERMS);
            for stages in [2, policy, policy + 1] {
                let clock = min_clock_ns(&adder, stages) * 1.02;
                let pipe = pipeline(&adder, stages, clock)
                    .unwrap_or_else(|| panic!("{} infeasible at its own min clock", adder.config));
                assert_eq!(pipe.stages, stages);
                assert_eq!(pipe.assignment.len(), adder.nl.nodes.len());
                assert!(pipe.assignment.iter().all(|&s| s < stages));

                // Monotone along every edge, and the register-bit recount
                // over the raw edge list matches the scheduler's report.
                let audit = netlist::audit_pipeline(&adder.nl, &pipe.assignment);
                assert_eq!(
                    audit.monotone_violations, 0,
                    "{} @{stages}: producer scheduled after consumer",
                    adder.config
                );
                assert_eq!(
                    audit.recomputed_reg_bits, pipe.reg_bits,
                    "{} @{stages}: register-bit accounting drifted",
                    adder.config
                );

                // Region atomicity: chain sub-nodes of one region never
                // straddle a cut.
                let mut region_stage: HashMap<&str, u32> = HashMap::new();
                for (i, &s) in pipe.assignment.iter().enumerate() {
                    match region_stage.entry(adder.nl.nodes[i].region.as_str()) {
                        std::collections::hash_map::Entry::Vacant(e) => {
                            e.insert(s);
                        }
                        std::collections::hash_map::Entry::Occupied(e) => assert_eq!(
                            *e.get(),
                            s,
                            "{} @{stages}: region {} split across stages",
                            adder.config,
                            adder.nl.nodes[i].region
                        ),
                    }
                }
            }
        }
    }
}

#[test]
fn dse_artifact_renders_deterministically_with_a_summary_per_format() {
    let coord = Coordinator::new(4);
    let report = dse::dse_report(16, 8, 1.0, &coord);
    assert_eq!(report.summary.len(), PAPER_FORMATS.len());
    assert_eq!(report.rows.len(), PAPER_FORMATS.len() * 2 * (1 + SUITE_RADICES.len()));
    let json = report.to_json();
    assert_eq!(json, report.to_json(), "DSE artifact is not render-stable");
    assert!(json.contains("\"schema\": \"ofa-dse-v1\""));
    assert!(json.contains("\"paper_area_band_pct\": [3.0, 23.0]"));
    for v in &report.summary {
        assert!(!v.best_area_config.is_empty());
        // The serial baseline is never its own best online config.
        assert_ne!(v.best_area_config, report.rows[0].config);
    }
}
