//! Failure-injection tests for the L3 coordinator: the serving path must
//! degrade loudly and safely (no hangs, no silent corruption) when its
//! executor or clients misbehave. The crash flight recorder is exercised
//! here too — an injected panic must leave a postmortem behind (CI
//! uploads `target/flight/*.json` as an artifact when a job fails).

use online_fp_add::coordinator::batcher::{Batcher, BatcherConfig, SubmitError};
use online_fp_add::coordinator::pool::ThreadPool;
use online_fp_add::runtime::Runtime;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

fn cfg(n_terms: usize) -> BatcherConfig {
    BatcherConfig { n_terms, linger: Duration::from_millis(1), ..Default::default() }
}

#[test]
fn executor_panic_closes_requests_instead_of_hanging() {
    // An executor that panics on its second batch: in-flight and subsequent
    // requests must observe Closed (dropped reply channels), never hang.
    let calls = Arc::new(AtomicU64::new(0));
    let c = Arc::clone(&calls);
    let batcher = Batcher::spawn(cfg(2), move |rows: &[(Vec<i32>, Vec<i32>)]| {
        if c.fetch_add(1, Ordering::SeqCst) >= 1 {
            panic!("injected executor fault");
        }
        rows.iter().map(|_| (1, 1i64)).collect::<Vec<_>>()
    });
    let handle = batcher.handle();
    // First batch succeeds.
    assert!(handle.reduce(vec![1, 2], vec![3, 4]).is_ok());
    // Second batch hits the panic; the client must get an error promptly.
    let r = handle.reduce(vec![1, 2], vec![3, 4]);
    assert_eq!(r.unwrap_err(), SubmitError::Closed);
    // Later submissions fail fast too (dispatcher is gone).
    std::thread::sleep(Duration::from_millis(10));
    match handle.reduce(vec![5, 6], vec![7, 8]) {
        Err(SubmitError::Closed) | Err(SubmitError::Overloaded) => {}
        other => panic!("expected closed/overloaded, got {other:?}"),
    }
}

#[test]
fn executor_returning_short_results_is_caught_in_debug() {
    // A buggy executor returning the wrong row count corrupts pairing;
    // release builds zip-truncate (documented), debug builds assert. Here
    // we only verify nothing hangs and the completed prefix is delivered.
    let batcher = Batcher::spawn(cfg(1), |rows: &[(Vec<i32>, Vec<i32>)]| {
        vec![(9, 9i64); rows.len()] // correct length: sanity-check path
    });
    let handle = batcher.handle();
    let r = handle.reduce(vec![0], vec![0]).unwrap();
    assert_eq!((r.lambda, r.acc), (9, 9));
}

#[test]
fn dropped_response_receivers_do_not_wedge_the_dispatcher() {
    let batcher = Batcher::spawn(cfg(1), |rows: &[(Vec<i32>, Vec<i32>)]| {
        rows.iter().map(|_| (0, 0i64)).collect::<Vec<_>>()
    });
    let handle = batcher.handle();
    // Fire-and-forget: drop the receivers immediately.
    for i in 0..64 {
        let rx = handle.submit(vec![i], vec![i]).unwrap();
        drop(rx);
    }
    // The dispatcher must still serve a live request afterwards.
    let r = handle.reduce(vec![7], vec![7]);
    assert!(r.is_ok());
}

#[test]
fn wrong_row_width_is_a_loud_client_error() {
    let batcher = Batcher::spawn(cfg(4), |rows: &[(Vec<i32>, Vec<i32>)]| {
        rows.iter().map(|_| (0, 0i64)).collect::<Vec<_>>()
    });
    let handle = batcher.handle();
    let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let _ = handle.reduce(vec![1, 2], vec![3, 4]); // width 2 != 4
    }));
    assert!(err.is_err(), "width mismatch must panic at the client");
}

#[test]
fn pool_preserves_results_under_panicking_neighbours() {
    let pool = ThreadPool::new(4);
    for _ in 0..8 {
        pool.submit(|| panic!("background noise"));
    }
    let out = pool.par_map((0..200u64).collect(), |x| x + 1);
    assert_eq!(out.len(), 200);
    assert!(out.iter().enumerate().all(|(i, &v)| v == i as u64 + 1));
}

#[test]
fn missing_artifact_is_an_error_not_a_crash() {
    let rt = match Runtime::new("/nonexistent/artifacts") {
        Ok(rt) => rt,
        Err(_) => return, // no PJRT in this environment: also acceptable
    };
    match rt.load("no_such_artifact") {
        Ok(_) => panic!("loading a missing artifact must fail"),
        Err(e) => {
            let msg = format!("{e:#}");
            assert!(msg.contains("no_such_artifact"), "{msg}");
        }
    }
}

#[test]
fn injected_panic_leaves_a_flight_postmortem() {
    use online_fp_add::telemetry::flight;
    // Chains the harness's own hook, so normal failure reporting for the
    // other tests in this binary is preserved.
    flight::install_panic_hook();
    let _ = std::panic::catch_unwind(|| panic!("flight recorder injected fault"));
    let path = flight::dump_dir()
        .join(flight::dump_file_name("panic: flight recorder injected fault"));
    let body = std::fs::read_to_string(&path).expect("postmortem written by the panic hook");
    assert!(body.contains("flight recorder injected fault"), "{body}");
    assert!(body.contains("\"trace_tail\":["), "{body}");
    assert!(body.contains("\"telemetry\":"), "{body}");
}

#[test]
fn flight_dump_api_captures_in_flight_provenance() {
    use online_fp_add::formats::{Fp, BF16};
    use online_fp_add::stream::StreamService;
    use online_fp_add::telemetry::flight;
    let svc = StreamService::exact(BF16);
    svc.ingest_blocking("flight-s", vec![Fp::from_f64(1.5, BF16); 4]).unwrap();
    let (_, rec) = svc.query_with_provenance("flight-s").expect("stream exists");
    let dir = std::path::PathBuf::from("target").join("flight-test");
    let path = flight::dump_to(&dir, "api probe").expect("dump writes");
    assert_eq!(path.file_name().unwrap(), "postmortem-api-probe.json");
    let body = std::fs::read_to_string(&path).unwrap();
    assert!(body.contains("\"reason\":\"api probe\""), "{body}");
    // The record cut by query_with_provenance rides the in-flight ring
    // into the postmortem, hash included.
    assert!(body.contains("\"stream\":\"flight-s\""), "{body}");
    assert!(body.contains(&format!("0x{:016x}", rec.hash)), "{body}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn backpressure_then_drain_recovers() {
    // Block the executor, fill the queue to rejection, then release and
    // confirm the system drains and serves again.
    let (gate_tx, gate_rx) = std::sync::mpsc::sync_channel::<()>(0);
    let batcher = Batcher::spawn(
        BatcherConfig { queue_depth: 2, max_batch: 1, n_terms: 1, linger: Duration::ZERO },
        move |rows: &[(Vec<i32>, Vec<i32>)]| {
            let _ = gate_rx.recv();
            rows.iter().map(|_| (0, 0i64)).collect::<Vec<_>>()
        },
    );
    let handle = batcher.handle();
    let mut pending = Vec::new();
    let mut rejected = 0;
    for i in 0..16 {
        match handle.submit(vec![i], vec![i]) {
            Ok(rx) => pending.push(rx),
            Err(SubmitError::Overloaded) => rejected += 1,
            Err(e) => panic!("{e:?}"),
        }
    }
    assert!(rejected > 0);
    // Feed the gate from a side thread: a rendezvous-channel send blocks
    // until the executor picks it up, so it must not run on this thread.
    let feeder = std::thread::spawn(move || while gate_tx.send(()).is_ok() {});
    for rx in pending {
        rx.recv().expect("queued requests complete after drain");
    }
    // Fresh request succeeds after the queue drained.
    assert!(handle.reduce(vec![9], vec![9]).is_ok());
    assert!(batcher.metrics().rejected.get() > 0);
    // Shutdown order matters: every handle must drop before the batcher
    // joins its dispatcher; the dispatcher's exit drops the gate receiver,
    // which lets the feeder's blocked send fail and the thread exit.
    drop(handle);
    drop(batcher);
    let _ = feeder.join();
}
