//! Integration tests across the three layers: the AOT-compiled JAX/Pallas
//! artifacts executed via PJRT must agree with the Rust bit-accurate models
//! — bit-for-bit on the `(λ, acc)` alignment state.
//!
//! Requires `make artifacts` to have produced `artifacts/*.hlo.txt`; the
//! tests are skipped (with a loud message) when artifacts are missing so
//! plain `cargo test` still works in a fresh checkout.

use online_fp_add::arith::tree::{tree_sum, RadixConfig};
use online_fp_add::arith::AccSpec;
use online_fp_add::coordinator::batcher::{Batcher, BatcherConfig};
use online_fp_add::formats::{Fp, BF16, FP32};
use online_fp_add::runtime::{BertLayerExe, BertWeights, OnlineReduceExe, Runtime};
use online_fp_add::util::prng::XorShift;

fn runtime() -> Option<Runtime> {
    let dir = Runtime::default_artifact_dir();
    if !dir.join("online_reduce_bf16_n32.hlo.txt").exists() {
        eprintln!("SKIP: artifacts missing; run `make artifacts` first");
        return None;
    }
    Some(Runtime::new(dir).expect("PJRT CPU client"))
}

/// Terms of one row as the kernel sees them: (e, m) int32 pairs.
fn encode_row(rng: &mut XorShift, fmt: online_fp_add::formats::FpFormat, n: usize) -> (Vec<i32>, Vec<i32>, Vec<Fp>) {
    let mut e = Vec::with_capacity(n);
    let mut m = Vec::with_capacity(n);
    let mut fps = Vec::with_capacity(n);
    for _ in 0..n {
        let fp = rng.gen_fp_sparse(fmt, 0.1);
        // Effective exponent + signed significand: the lane encoding under
        // the gradual-underflow λ-convention (subnormals -> (1, ±m)).
        e.push(fp.eff_exp());
        m.push(fp.signed_sig() as i32);
        fps.push(fp);
    }
    (e, m, fps)
}

#[test]
fn pallas_reduce_bf16_matches_rust_tree_bitexact() {
    let Some(rt) = runtime() else { return };
    let exe = OnlineReduceExe::load_bf16_n32(&rt).expect("load artifact");
    let spec = AccSpec::truncated(exe.guard);
    // The artifact executes the blockwise single-λ reduction — the baseline
    // (single-level) corner of the radix design space.
    let cfg = RadixConfig::baseline(32);
    let mut rng = XorShift::new(0x517E);

    for round in 0..4 {
        let mut e_all = Vec::new();
        let mut m_all = Vec::new();
        let mut rows = Vec::new();
        for _ in 0..exe.batch {
            let (e, m, fps) = encode_row(&mut rng, BF16, exe.n_terms);
            e_all.extend_from_slice(&e);
            m_all.extend_from_slice(&m);
            rows.push(fps);
        }
        let out = exe.run(&rt, &e_all, &m_all).expect("execute");
        for (i, fps) in rows.iter().enumerate() {
            let state = tree_sum(fps, &cfg, spec);
            assert_eq!(out.lambda[i], state.lambda, "row {i} round {round}: λ mismatch");
            assert_eq!(
                out.acc[i],
                state.acc.to_i128() as i64,
                "row {i} round {round}: acc mismatch"
            );
        }
    }
}

#[test]
fn pallas_reduce_fp32_matches_rust_tree_bitexact() {
    let Some(rt) = runtime() else { return };
    let exe = OnlineReduceExe::load_fp32_n16(&rt).expect("load artifact");
    let spec = AccSpec::truncated(exe.guard);
    let cfg = RadixConfig::baseline(16);
    let mut rng = XorShift::new(0xF32);

    let mut e_all = Vec::new();
    let mut m_all = Vec::new();
    let mut rows = Vec::new();
    for _ in 0..exe.batch {
        let (e, m, fps) = encode_row(&mut rng, FP32, exe.n_terms);
        e_all.extend_from_slice(&e);
        m_all.extend_from_slice(&m);
        rows.push(fps);
    }
    let out = exe.run(&rt, &e_all, &m_all).expect("execute");
    for (i, fps) in rows.iter().enumerate() {
        let state = tree_sum(fps, &cfg, spec);
        assert_eq!(out.lambda[i], state.lambda, "row {i}");
        assert_eq!(out.acc[i], state.acc.to_i128() as i64, "row {i}");
    }
}

#[test]
fn partial_batches_are_padded_with_identity() {
    let Some(rt) = runtime() else { return };
    let exe = OnlineReduceExe::load_bf16_n32(&rt).expect("load artifact");
    let mut rng = XorShift::new(1);
    let (e, m, _) = encode_row(&mut rng, BF16, exe.n_terms);
    let out = exe.run(&rt, &e, &m).expect("execute");
    assert_eq!(out.lambda.len(), 1);
    assert_eq!(out.acc.len(), 1);
}

#[test]
fn bert_layer_runs_and_is_sane() {
    let Some(rt) = runtime() else { return };
    let exe = BertLayerExe::load(&rt).expect("load bert artifact");
    let w = BertWeights::random(42);
    let mut rng = XorShift::new(7);
    let x: Vec<f32> = (0..online_fp_add::runtime::bert_dims().0 * online_fp_add::runtime::bert_dims().1)
        .map(|_| (rng.gauss() * 0.5) as f32)
        .collect();
    let acts = exe.run(&rt, &x, &w).expect("execute bert layer");
    let (seq, _d) = online_fp_add::runtime::bert_dims();
    // softmax rows sum to 1
    for row in 0..seq {
        let s: f32 = acts.attn[row * seq..(row + 1) * seq].iter().sum();
        assert!((s - 1.0).abs() < 1e-3, "attn row {row} sums to {s}");
    }
    assert!(acts.out.iter().all(|v| v.is_finite()));
    // Output must not be identically the input (the layer did something).
    let diff: f32 = acts.out.iter().zip(&x).map(|(a, b)| (a - b).abs()).sum();
    assert!(diff > 1.0);
}

#[test]
fn batcher_over_pjrt_serves_concurrent_requests_bitexactly() {
    if runtime().is_none() {
        return;
    }
    let n_terms = 32;
    let guard = 16;
    let spec = AccSpec::truncated(guard);

    // PJRT executables are not Send: build the runtime + executable on the
    // dispatcher thread itself via spawn_with.
    let batcher = Batcher::spawn_with(
        BatcherConfig { n_terms, linger: std::time::Duration::from_millis(1), ..Default::default() },
        move || {
            let rt = Runtime::new(Runtime::default_artifact_dir()).expect("PJRT client");
            let exe = OnlineReduceExe::load_bf16_n32(&rt).expect("load artifact");
            move |rows: &[(Vec<i32>, Vec<i32>)]| {
                let mut e_all = Vec::new();
                let mut m_all = Vec::new();
                for (e, m) in rows {
                    e_all.extend_from_slice(e);
                    m_all.extend_from_slice(m);
                }
                let out = exe.run(&rt, &e_all, &m_all).expect("pjrt execute");
                out.lambda.into_iter().zip(out.acc).collect::<Vec<_>>()
            }
        },
    );
    let handle = batcher.handle();

    let workers: Vec<_> = (0..48u64)
        .map(|i| {
            let h = handle.clone();
            std::thread::spawn(move || {
                let mut rng = XorShift::new(0xB000 + i);
                let (e, m, fps) = encode_row(&mut rng, BF16, n_terms);
                let resp = h.reduce(e, m).expect("batched reduce");
                let want = tree_sum(&fps, &RadixConfig::baseline(32), spec);
                assert_eq!(resp.lambda, want.lambda);
                assert_eq!(resp.acc, want.acc.to_i128() as i64);
            })
        })
        .collect();
    for w in workers {
        w.join().unwrap();
    }
    assert_eq!(batcher.metrics().requests.get(), 48);
    assert!(batcher.metrics().batches.get() <= 48);
}
