//! The streaming invariant, property-tested end to end: for an exact
//! `AccSpec`, **any** chunking of a term sequence into segments and **any**
//! merge order of those segments is bit-identical to the `⊙`-tree reference
//! (`tree_sum`) and — after one rounding — to the Kulisch exact reference
//! (`arith::exact`). Truncated specs keep λ agreement and sticky
//! monotonicity even where dropped low bits become order-dependent.
//!
//! The engine-level acceptance check lives here too: replaying the same
//! trace with chunk sizes {1, 7, 64}, 1–8 threads and shuffled arrival
//! yields bit-identical `(λ, acc, sticky)` per stream.

use online_fp_add::arith::exact::exact_rounded_sum;
use online_fp_add::arith::normalize::normalize_round;
use online_fp_add::arith::tree::{tree_sum, RadixConfig};
use online_fp_add::arith::AccSpec;
use online_fp_add::formats::{Fp, FpFormat, BF16, FP32, FP8_E5M2, PAPER_FORMATS};
use online_fp_add::stream::{
    reduce_chunk, EngineConfig, Segment, SegmentAssembler, StreamEngine, StreamService,
};
use online_fp_add::util::proptest::check;
use online_fp_add::util::prng::XorShift;
use online_fp_add::workload::bert::power_trace;

/// Random finite terms stressing the streaming edge cases: zeros, subnormal
/// values (live gradual-underflow operands entering the λ domain at
/// effective exponent 1), and runs of identical values (all-identity
/// chunks included).
fn gen_terms(rng: &mut XorShift, fmt: FpFormat, n: usize) -> Vec<Fp> {
    let mut out = Vec::with_capacity(n);
    while out.len() < n {
        match rng.below(8) {
            0 => out.push(Fp::zero(fmt)),
            1 => {
                // Subnormal pattern: raw exponent 0, nonzero mantissa.
                let m = if fmt.mant_mask() == 0 { 0 } else { 1 + rng.below(fmt.mant_mask()) };
                out.push(Fp::pack(rng.below(2) == 1, 0, m, fmt));
            }
            2 => {
                // A run of identical values — whole chunks of the same term.
                let v = rng.gen_fp_normal(fmt);
                let run = (1 + rng.below(8) as usize).min(n - out.len());
                out.extend(std::iter::repeat(v).take(run));
            }
            _ => out.push(rng.gen_fp_normal(fmt)),
        }
    }
    out
}

/// Split `terms` at random boundaries (chunk lengths 1..=17).
fn random_segments(rng: &mut XorShift, terms: &[Fp], spec: AccSpec) -> Vec<Segment> {
    let mut segs = Vec::new();
    let mut i = 0;
    while i < terms.len() {
        let len = (1 + rng.below(17) as usize).min(terms.len() - i);
        segs.push(reduce_chunk(&terms[i..i + len], spec));
        i += len;
    }
    segs
}

fn random_fmt(rng: &mut XorShift) -> FpFormat {
    PAPER_FORMATS[rng.below(PAPER_FORMATS.len() as u64) as usize]
}

#[test]
fn prop_any_chunking_any_merge_order_is_bitexact_in_exact_mode() {
    check("stream chunking ⊙ invariance", 250, |g| {
        let fmt = random_fmt(&mut g.rng);
        let spec = AccSpec::exact(fmt);
        let n = 2 + g.rng.below(250) as usize;
        let terms = gen_terms(&mut g.rng, fmt, n);
        let reference = tree_sum(&terms, &RadixConfig::baseline(n as u32), spec);

        let mut segs = random_segments(&mut g.rng, &terms, spec);
        g.rng.shuffle(&mut segs);
        let merged = segs.iter().fold(Segment::EMPTY, |a, s| a.merge(s, spec));
        if merged.state != reference {
            return Err(format!(
                "{fmt} n={n}: merged {:?} != reference {:?}",
                merged.state, reference
            ));
        }
        if merged.terms != n as u64 {
            return Err(format!("term count {} != {n}", merged.terms));
        }
        // One rounding of the merged state == the correctly-rounded sum.
        let rounded = normalize_round(&merged.state, spec, fmt);
        let oracle = exact_rounded_sum(&terms, fmt);
        if rounded.bits != oracle.bits {
            return Err(format!("{fmt}: rounded {rounded:?} != oracle {oracle:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_out_of_order_assembly_is_bitexact_in_exact_mode() {
    check("out-of-order assembly", 200, |g| {
        let fmt = random_fmt(&mut g.rng);
        let spec = AccSpec::exact(fmt);
        let n = 2 + g.rng.below(150) as usize;
        let terms = gen_terms(&mut g.rng, fmt, n);
        let reference = tree_sum(&terms, &RadixConfig::baseline(n as u32), spec);

        let segs = random_segments(&mut g.rng, &terms, spec);
        let mut order: Vec<usize> = (0..segs.len()).collect();
        g.rng.shuffle(&mut order);
        let mut asm = SegmentAssembler::new(spec);
        for &i in &order {
            asm.offer(i as u64, segs[i]);
        }
        if asm.state().state != reference {
            return Err(format!("{fmt} n={n}: assembler diverged from tree_sum"));
        }
        if asm.pending() != 0 {
            return Err(format!("{} segments stuck pending in exact mode", asm.pending()));
        }
        Ok(())
    });
}

#[test]
fn prop_truncated_specs_agree_on_lambda_and_sticky_monotonicity() {
    check("truncated λ agreement + sticky monotonicity", 200, |g| {
        let fmt = random_fmt(&mut g.rng);
        let spec = AccSpec::truncated(1 + g.rng.below(6) as u32);
        let n = 2 + g.rng.below(120) as usize;
        let terms = gen_terms(&mut g.rng, fmt, n);
        let reference = tree_sum(&terms, &RadixConfig::baseline(n as u32), spec);

        let mut segs = random_segments(&mut g.rng, &terms, spec);
        g.rng.shuffle(&mut segs);
        let mut merged = Segment::EMPTY;
        let mut sticky_seen = false;
        for s in &segs {
            sticky_seen |= s.state.sticky;
            merged = merged.merge(s, spec);
            // Monotone: once any absorbed segment carried sticky, the
            // running merge must keep reporting it.
            if sticky_seen && !merged.state.sticky {
                return Err(format!("{fmt} n={n}: sticky bit was lost by a merge"));
            }
        }
        // λ is a pure max — order and chunking can never change it.
        if merged.state.lambda != reference.lambda {
            return Err(format!(
                "{fmt} n={n}: λ {} != reference λ {}",
                merged.state.lambda, reference.lambda
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_chunked_service_query_equals_exact_reference() {
    // End to end through the service: batches of arbitrary size, query is
    // the correctly-rounded sum of everything ingested.
    check("service query == exact reference", 25, |g| {
        let fmt = [BF16, FP32, FP8_E5M2][g.rng.below(3) as usize];
        let svc = StreamService::exact(fmt);
        let mut all = Vec::new();
        for _ in 0..(1 + g.rng.below(10)) {
            let batch = gen_terms(&mut g.rng, fmt, 1 + g.rng.below(60) as usize);
            all.extend_from_slice(&batch);
            svc.ingest_blocking("p", batch).map_err(|e| format!("{e:?}"))?;
        }
        let (value, snap) = svc.query("p").ok_or("stream missing")?;
        if snap.terms != all.len() as u64 {
            return Err(format!("terms {} != {}", snap.terms, all.len()));
        }
        let oracle = exact_rounded_sum(&all, fmt);
        if value.bits != oracle.bits {
            return Err(format!("{fmt}: {value:?} != {oracle:?}"));
        }
        Ok(())
    });
}

/// End-to-end stream oracle across **every registered backend**: replay a
/// real BERT partial-product trace through a [`StreamService`] whose
/// chunks are reduced by each registry entry in turn (plus an awkward
/// kernel block size), and check every per-stream **query** (one rounding
/// over the whole history) against the independent sign-magnitude big-int
/// reference ([`reference_sum`]) bit for bit — and against a
/// scalar-backend service replaying the same traffic.
#[test]
fn every_registered_backend_service_queries_match_bigint_oracle_on_bert_trace() {
    use online_fp_add::arith::oracle::reference_sum;
    use online_fp_add::reduce::registry;

    let trace = power_trace(BF16, 32, 96, 0x4E7);
    let streams = 6usize;
    let mut backends: Vec<_> = registry::entries().iter().map(|e| e.sel()).collect();
    backends.push(registry::sel("kernel:5").unwrap());
    for backend in backends {
        let svc = StreamService::exact_with_backend(BF16, backend);
        let total = svc.replay_trace("kq", &trace, streams);
        assert_eq!(total, (trace.len() * 32) as u64);
        let scalar_svc =
            StreamService::exact_with_backend(BF16, registry::sel("scalar").unwrap());
        scalar_svc.replay_trace("kq", &trace, streams);
        let mut per_stream: Vec<Vec<Fp>> = vec![Vec::new(); streams];
        for (i, row) in trace.vectors.iter().enumerate() {
            per_stream[i % streams].extend_from_slice(row);
        }
        for (s, terms) in per_stream.iter().enumerate() {
            let id = format!("kq-{s}");
            let (value, snap) = svc.query(&id).expect("stream exists");
            assert_eq!(snap.terms, terms.len() as u64);
            let oracle = reference_sum(terms, BF16);
            assert_eq!(
                value.bits, oracle.bits,
                "stream {s}: kernel-backend query {value:?} != big-int oracle {oracle:?}"
            );
            let (scalar_value, scalar_snap) = scalar_svc.query(&id).expect("stream exists");
            assert_eq!(value.bits, scalar_value.bits, "stream {s}: backend divergence");
            assert_eq!(snap.state(), scalar_snap.state(), "stream {s}: state divergence");
        }
    }
}

/// Acceptance: the engine is order/chunking/thread-count invariant on a
/// real BERT partial-product trace.
#[test]
fn engine_invariant_over_chunk_threads_and_arrival_on_bert_trace() {
    let spec = AccSpec::exact(BF16);
    let trace = power_trace(BF16, 32, 72, 0x5EED);
    let streams = 4usize;

    // Reference per stream: one ⊙ tree over that stream's flattened terms.
    let mut per_stream: Vec<Vec<Fp>> = vec![Vec::new(); streams];
    for (i, row) in trace.vectors.iter().enumerate() {
        per_stream[i % streams].extend_from_slice(row);
    }
    let references: Vec<_> = per_stream
        .iter()
        .map(|ts| tree_sum(ts, &RadixConfig::baseline(ts.len() as u32), spec))
        .collect();

    let mut rng = XorShift::new(0x0DDE);
    for threads in [1usize, 2, 4, 8] {
        for chunk in [1usize, 7, 64] {
            // Shuffled arrival: rows land in a different global order each
            // run, and therefore in a different order per stream.
            let mut order: Vec<usize> = (0..trace.vectors.len()).collect();
            rng.shuffle(&mut order);
            let engine = StreamEngine::new(EngineConfig {
                threads,
                chunk,
                spec,
                ..Default::default()
            });
            for &i in &order {
                engine
                    .ingest_blocking(&format!("bert-{}", i % streams), trace.vectors[i].clone())
                    .unwrap();
            }
            engine.quiesce();
            for (s, want) in references.iter().enumerate() {
                let snap = engine.snapshot(&format!("bert-{s}")).unwrap();
                assert_eq!(
                    snap.state(),
                    *want,
                    "stream {s} diverged at threads={threads} chunk={chunk}"
                );
                assert_eq!(snap.terms, per_stream[s].len() as u64);
            }
        }
    }
}
