//! Analysis-tier gate (DESIGN.md §Analysis): the CI-facing battery behind
//! `repro analyze --gate`.
//!
//! Four properties, mirroring the acceptance criteria of the analysis
//! tier:
//!
//! 1. **All green on the shipped widths** — every derived obligation
//!    passes on [`StorageEnv::actual`], and the obligation set covers
//!    every registered backend under every paper format.
//! 2. **The proof artifact is byte-deterministic** — two renders of the
//!    same report are byte-identical, so CI can `cmp` the checked-in
//!    `ANALYSIS_report.json` against a fresh run.
//! 3. **The gate can fail** — each named storage fault breaks at least
//!    one obligation (a gate that cannot fail proves nothing).
//! 4. **The proved bounds hold at runtime** — after driving every
//!    registered backend over every oracle distribution, the telemetry
//!    hub's observed occupancy / kernel-lane maxima stay within the
//!    statically derived ceilings.

use online_fp_add::analysis::{self, AnalysisReport, StorageEnv};
use online_fp_add::formats::PAPER_FORMATS;
use online_fp_add::reduce::registry;
use online_fp_add::telemetry;

fn actual_report() -> AnalysisReport {
    analysis::analyze(&StorageEnv::actual())
}

#[test]
fn every_obligation_passes_and_covers_all_backends_and_formats() {
    let report = actual_report();
    let failed = report.failed();
    assert!(
        failed.is_empty(),
        "static width obligations failed: {:?}",
        failed.iter().map(|o| format!("{}/{}/{}", o.format, o.backend, o.id)).collect::<Vec<_>>()
    );
    for fmt in PAPER_FORMATS {
        // Format-level obligations (shared frame + hw model) and one set
        // per registered backend.
        assert!(report.covers(fmt.name, "-"), "no format-level obligations for {}", fmt.name);
        for backend in registry::names() {
            assert!(
                report.covers(fmt.name, backend),
                "no obligation covers {} x {backend}",
                fmt.name
            );
        }
    }
}

#[test]
fn proof_artifact_is_byte_deterministic() {
    let (a, b) = (actual_report().to_json(), actual_report().to_json());
    assert_eq!(a, b, "two analyzer runs rendered different artifacts");
    assert!(a.contains("\"schema\": \"ofa-analysis-v1\""));
    assert!(a.contains("\"failed\": 0"));
    assert!(a.ends_with("}\n"));
}

#[test]
fn every_seeded_fault_trips_the_gate() {
    for fault in StorageEnv::fault_names() {
        let env = StorageEnv::with_fault(fault).expect("known fault name");
        let report = analysis::analyze(&env);
        assert!(
            !report.failed().is_empty(),
            "seeded fault {fault:?} left every obligation green"
        );
        // The faulted artifact must still serialize (CI inspects it).
        assert!(report.to_json().contains("\"pass\": false"));
    }
}

/// The netlist tier is strictly additive: `analyze` alone emits no
/// `netlist-*` obligations, and `analyze_netlist` keeps the software
/// derivations as an unchanged prefix — the committed artifact's first 148
/// entries cannot shift when the netlist suite evolves.
#[test]
fn netlist_tier_is_an_additive_suffix() {
    let soft = actual_report();
    assert!(soft.obligations.iter().all(|o| !o.id.starts_with("netlist-")));
    let full = analysis::analyze_netlist(&StorageEnv::actual(), None);
    assert!(full.obligations.len() > soft.obligations.len());
    for (a, b) in soft.obligations.iter().zip(&full.obligations) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.format, b.format);
        assert_eq!(a.required_bits, b.required_bits);
        assert_eq!(a.provided_bits, b.provided_bits);
    }
    assert!(full.obligations[soft.obligations.len()..]
        .iter()
        .all(|o| o.id.starts_with("netlist-")));
}

/// The runtime cross-check: exercise every registered backend over every
/// oracle distribution and paper format, then assert the telemetry
/// maxima the datapath actually produced sit inside the statically
/// proved bounds. Liveness is asserted too — a gate reading empty
/// histograms would pass vacuously.
#[test]
fn telemetry_observed_maxima_stay_within_proved_bounds() {
    let report = actual_report();
    let reduced = analysis::exercise_backends(96, 4);
    assert!(reduced > 0, "exercise loop reduced no terms");

    let hub = telemetry::global();
    assert!(
        hub.kernel.block_lanes.max() > 0,
        "kernel lane-width histogram stayed empty — observation site lost?"
    );
    assert!(
        hub.accum.occupancy.max() > 0,
        "EIA occupancy histogram stayed empty — observation site lost?"
    );

    for bound in analysis::runtime_check(&report, hub) {
        assert!(
            bound.pass(),
            "{}: observed {} exceeds the proved bound {}",
            bound.name,
            bound.observed,
            bound.bound
        );
    }
}
