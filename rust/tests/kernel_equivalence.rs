//! Kernel-equivalence battery (DESIGN.md §Kernel): the batched SoA
//! align-and-add kernel must be **bit-identical** — the full
//! `[λ; acc; sticky]` state, not just the rounded value — to the scalar
//! `⊙` fold it replaces, over the entire finite operand space (signed
//! zeros, subnormals, normals), for every paper format, at every block
//! size, on both the narrow-i128 and wide-`WideInt` accumulator paths, and
//! under the adversarial oracle distributions (subnormal-dense,
//! cancellation-heavy, near-overflow). Special values must propagate
//! through the kernel-backed adder exactly as `Fp` semantics dictate.

use online_fp_add::arith::adder::{Architecture, MultiTermAdder};
use online_fp_add::arith::kernel::{reduce_terms, scalar_fold};
use online_fp_add::arith::oracle::DISTRIBUTIONS;
use online_fp_add::arith::AccSpec;
use online_fp_add::formats::{Fp, FpClass, SpecialsMode, FP8_E4M3, FP8_E6M1, PAPER_FORMATS};
use online_fp_add::reduce::{registry, ReducePlan};
use online_fp_add::util::proptest::check;
use online_fp_add::util::prng::XorShift;

const BLOCKS: [usize; 4] = [1, 3, 8, 64];

/// The exact spec plus its forced-wide twin (for formats whose exact frame
/// fits the narrow path, both accumulator paths must agree).
fn exact_specs(fmt: online_fp_add::formats::FpFormat) -> Vec<AccSpec> {
    let exact = AccSpec::exact(fmt);
    let mut specs = vec![exact];
    if exact.narrow {
        specs.push(AccSpec { narrow: false, ..exact });
    }
    specs
}

#[test]
fn prop_kernel_state_bitidentical_to_scalar_fold_full_operand_space() {
    check("kernel ≡ scalar ⊙ fold (full space)", 150, |g| {
        for fmt in PAPER_FORMATS {
            let n = 1 + g.rng.below(180) as usize;
            let terms = g.fp_full_vec(fmt, n);
            for spec in exact_specs(fmt) {
                let want = scalar_fold(&terms, spec);
                for block in BLOCKS.iter().copied().chain([n]) {
                    let got = reduce_terms(&terms, block, spec);
                    if got != want {
                        return Err(format!(
                            "{fmt} n={n} block={block} narrow={}: {got:?} != {want:?}",
                            spec.narrow
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_kernel_block_one_is_the_scalar_fold_in_truncated_frames() {
    // Truncated frames are merge-order sensitive in their dropped bits, but
    // block = 1 degenerates the kernel to exactly the radix-2 fold — the
    // bit pattern must survive, sticky included.
    check("kernel block=1 ≡ scalar fold (truncated)", 150, |g| {
        for fmt in PAPER_FORMATS {
            let spec = AccSpec::truncated(1 + g.rng.below(18) as u32);
            let n = 1 + g.rng.below(100) as usize;
            let terms = g.fp_full_vec(fmt, n);
            let want = scalar_fold(&terms, spec);
            let got = reduce_terms(&terms, 1, spec);
            if got != want {
                return Err(format!("{fmt} n={n} guard={}: {got:?} != {want:?}", spec.f));
            }
        }
        Ok(())
    });
}

#[test]
fn kernel_matches_scalar_fold_on_adversarial_distributions() {
    // The oracle's adversarial generators — subnormal-dense vectors hugging
    // the underflow boundary, ±1-ulp cancellation pairs, mixed-sign
    // near-overflow — through every block size, zero state mismatches.
    let mut rng = XorShift::new(0xADE2);
    for fmt in PAPER_FORMATS {
        for dist in DISTRIBUTIONS {
            for spec in exact_specs(fmt) {
                for _ in 0..40 {
                    let n = 64;
                    let terms = dist.gen_vector(&mut rng, fmt, n);
                    let want = scalar_fold(&terms, spec);
                    for block in BLOCKS.iter().copied().chain([n]) {
                        assert_eq!(
                            reduce_terms(&terms, block, spec),
                            want,
                            "{fmt} {} block={block} narrow={}",
                            dist.name(),
                            spec.narrow
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn prop_kernel_backend_rounds_identically_through_the_adder() {
    // End to end through MultiTermAdder: the kernel architecture's rounded
    // result must bit-match the baseline architecture on the same lanes.
    check("kernel adder ≡ baseline adder", 120, |g| {
        for fmt in PAPER_FORMATS {
            let n = 16usize;
            let terms = g.fp_full_vec(fmt, n);
            let kernel = MultiTermAdder::exact(fmt, n, Architecture::backend("kernel:5").unwrap())
                .add(&terms);
            let baseline = MultiTermAdder::exact(fmt, n, Architecture::Baseline).add(&terms);
            if kernel.bits != baseline.bits {
                return Err(format!("{fmt}: {kernel:?} != {baseline:?}"));
            }
        }
        Ok(())
    });
}

#[test]
fn special_values_propagate_identically_through_kernel_and_scalar_adders() {
    // Inf/NaN never reach the datapath (the unpack stage screens them);
    // both architectures must apply the same Fp semantics: NaN dominates,
    // opposite infinities are invalid (NaN), a lone Inf wins with its sign.
    for fmt in PAPER_FORMATS {
        let kernel = MultiTermAdder::exact(fmt, 8, Architecture::backend("kernel:3").unwrap());
        let scalar = MultiTermAdder::exact(fmt, 8, Architecture::Baseline);
        let one = Fp::from_f64(1.0, fmt);
        let nan = Fp::nan(fmt);
        let nan_vec = vec![one, nan, one, one];
        assert_eq!(kernel.add(&nan_vec).class(), FpClass::Nan, "{fmt}");
        assert_eq!(kernel.add(&nan_vec).bits, scalar.add(&nan_vec).bits, "{fmt}");
        if fmt.specials == SpecialsMode::Ieee {
            let inf = Fp::overflow(false, fmt);
            let ninf = Fp::overflow(true, fmt);
            let invalid = vec![inf, ninf, one];
            assert_eq!(kernel.add(&invalid).class(), FpClass::Nan, "{fmt}: +Inf + -Inf");
            assert_eq!(kernel.add(&invalid).bits, scalar.add(&invalid).bits, "{fmt}");
            for sign in [false, true] {
                let v = vec![Fp::overflow(sign, fmt), one, one];
                let r = kernel.add(&v);
                assert_eq!(r.class(), FpClass::Inf, "{fmt}");
                assert_eq!(r.sign(), sign, "{fmt}");
                assert_eq!(r.bits, scalar.add(&v).bits, "{fmt}");
            }
        }
    }
}

#[test]
fn noinf_formats_saturate_identically_through_kernel_and_scalar_adders() {
    // Saturating (NoInf) formats have no Inf: overflowing sums clamp to the
    // maximum finite value in both backends, and the OCP NaN still
    // dominates.
    for fmt in [FP8_E4M3, FP8_E6M1] {
        let kernel = MultiTermAdder::exact(fmt, 4, Architecture::backend("kernel:2").unwrap());
        let scalar = MultiTermAdder::exact(fmt, 4, Architecture::Baseline);
        let max = Fp::pack(false, fmt.max_normal_exp(), fmt.max_finite_mant(), fmt);
        let sat = kernel.add(&[max, max, max, max]);
        assert_eq!(sat.bits, Fp::overflow(false, fmt).bits, "{fmt}: positive saturation");
        assert_eq!(sat.bits, scalar.add(&[max, max, max, max]).bits, "{fmt}");
        let nmax = Fp::pack(true, fmt.max_normal_exp(), fmt.max_finite_mant(), fmt);
        let nsat = kernel.add(&[nmax, nmax, nmax, nmax]);
        assert_eq!(nsat.bits, Fp::overflow(true, fmt).bits, "{fmt}: negative saturation");
        assert_eq!(nsat.bits, scalar.add(&[nmax, nmax, nmax, nmax]).bits, "{fmt}");
        let nan = Fp::nan(fmt);
        assert_eq!(kernel.add(&[max, nan, max, max]).class(), FpClass::Nan, "{fmt}");
    }
}

#[test]
fn plan_negotiation_and_registry_backends_reduce_consistently() {
    // The ReducePlan seam (the old ReduceBackend::Auto, now inspectable):
    // negotiation must route exact specs to the kernel and truncated specs
    // to the scalar fold, and every *registered* backend — iterated from
    // the registry, not a hand list — must agree bit-for-bit on exact
    // specs.
    let mut rng = XorShift::new(0x5EAC);
    for fmt in PAPER_FORMATS {
        let exact = AccSpec::exact(fmt);
        assert_eq!(ReducePlan::negotiate(exact).backend().name(), "kernel", "{fmt}");
        let terms: Vec<Fp> = (0..97).map(|_| rng.gen_fp_full(fmt)).collect();
        let want = scalar_fold(&terms, exact);
        let mut plans = vec![
            ReducePlan::negotiate(exact),
            ReducePlan::with_backend(exact, registry::sel("kernel:9").unwrap()),
        ];
        plans.extend(
            registry::entries().iter().map(|e| ReducePlan::with_backend(exact, e.sel())),
        );
        for plan in &plans {
            assert_eq!(plan.reduce(&terms), want, "{fmt} {}", plan.backend());
        }
        let truncated = AccSpec::truncated(6);
        let plan = ReducePlan::negotiate(truncated);
        assert_eq!(
            plan.backend().name(),
            "scalar",
            "{fmt}: truncated frames keep the scalar reference"
        );
        assert!(plan.capabilities().fold_bit_identical);
        assert_eq!(plan.reduce(&terms), scalar_fold(&terms, truncated), "{fmt}");
    }
}

#[test]
fn shift_clamp_edges_pin_kernel_and_simd_to_the_scalar_fold() {
    // The clamp boundary itself: alignment distances {126, 127, 128, 200}
    // straddle the narrow path's `clamp(0, 127)` and the wide path's
    // `min(127)` — exactly where an off-by-one would silently truncate one
    // live bit or lose a sticky. Anchor-first term vectors keep λ constant
    // after the first combine, so the kernel's block-parenthesised reduce
    // equals the radix-2 fold even in truncated frames, making the fold
    // the pinning reference at every block size.
    use online_fp_add::arith::simd::reduce_terms_simd;
    use online_fp_add::formats::FP32;

    let narrow = AccSpec::truncated(16);
    assert!(narrow.narrow);
    let wide = AccSpec { narrow: false, ..narrow };
    for d in [126i32, 127, 128, 200] {
        assert!(1 + d <= FP32.max_normal_exp(), "anchor exponent stays finite");
        let anchor = Fp::pack(false, 1 + d, 0x2a_aaaa, FP32);
        // All three smalls sit at effective exponent 1, distance d from
        // the anchor: the minimal subnormal, the maximal negative
        // subnormal, and the negative minimal-exponent normal.
        let smalls = [
            Fp::pack(false, 0, 1, FP32),
            Fp::pack(true, 0, 0x7f_ffff, FP32),
            Fp::pack(true, 1, 0x55_5555, FP32),
        ];
        for small in smalls {
            let terms = vec![anchor, small, small];
            for spec in [narrow, wide] {
                let want = scalar_fold(&terms, spec);
                // Every live bit of the small term sits below the clamp at
                // these distances, so its whole magnitude must land in
                // sticky — on both accumulator paths.
                assert!(want.sticky, "d={d} narrow={}: sticky edge lost", spec.narrow);
                for block in [1usize, 2, 3, 8] {
                    assert_eq!(
                        reduce_terms(&terms, block, spec),
                        want,
                        "kernel d={d} block={block} narrow={}",
                        spec.narrow
                    );
                    assert_eq!(
                        reduce_terms_simd(&terms, block, spec),
                        want,
                        "simd d={d} block={block} narrow={}",
                        spec.narrow
                    );
                }
            }
        }
    }
}

#[test]
fn decoded_dead_lanes_with_adversarial_exponents_are_inert_in_every_backend() {
    // `ingest_decoded` lanes with sig == 0 are dead regardless of what eff
    // says — including i32::MIN, which used to overflow the kernel's bare
    // i32 `lambda - e` distance in debug builds. Every registered backend
    // must treat such lanes as exact identities.
    use online_fp_add::arith::wide::WideInt;

    let eff = [i32::MIN, 9, i32::MAX, i32::MIN + 1, 0];
    let sig = [0i64, 5, 0, 0, 0];
    for fmt in PAPER_FORMATS {
        let mut specs = exact_specs(fmt);
        specs.push(AccSpec::truncated(16));
        for spec in specs {
            let mut results = Vec::new();
            for entry in registry::entries() {
                let mut r = entry.sel().reducer(spec);
                r.ingest_decoded(&eff, &sig);
                let got = r.finish();
                assert_eq!(got.lambda, 9, "{fmt} {} narrow={}", entry.name, spec.narrow);
                assert!(!got.sticky, "{fmt} {} narrow={}", entry.name, spec.narrow);
                assert_eq!(
                    got.acc,
                    WideInt::from_i64_shl(5, spec.f),
                    "{fmt} {} narrow={}",
                    entry.name,
                    spec.narrow
                );
                results.push((entry.name, got));
            }
            let (ref_name, ref_acc) = results[0];
            for (name, acc) in &results[1..] {
                assert_eq!(acc, &ref_acc, "{fmt}: {name} != {ref_name}");
            }
        }
    }
}

#[test]
fn zero_block_is_rejected_at_parse_and_plan_build_time() {
    // The old seam silently clamped `Kernel { block: 0 }` to 1 deep in the
    // kernel; the plan/parse layer now rejects it with a proper error.
    let spec = AccSpec::exact(PAPER_FORMATS[0]);
    let err = "kernel:0".parse::<online_fp_add::reduce::BackendSel>().unwrap_err();
    assert!(err.contains("block must be >= 1"), "{err}");
    assert!(ReducePlan::builder(spec).block(0).is_err());
    assert!(ReducePlan::builder(spec).backend_name("kernel:0").is_err());
    assert!(registry::sel("kernel").unwrap().with_block(0).is_err());
    assert!(Architecture::parse("kernel:0", 16).is_err());
}
